"""Device-resident re-rank + autotuned quant configs (repro.quant):
the fused shortlist-gather/re-rank megastep is bitwise the oracle across
impls and index kinds, performs zero steady-state host syncs, and never
recompiles across repeating ragged batches under a cached tuning
config; plus the tuning table's persistence/lookup/override semantics
(repro.quant.autotune)."""
import numpy as np
import pytest

import repro.core.megastep as M
from repro.core import (
    JoinConfig, JoinStats, MutableIndex, build_index, knn_join)
from repro.quant import QuantMegastepEngine
from repro.quant import autotune
from repro.quant.autotune import TunedConfig, TuningTable, table_key


def _data(n, dim, seed, scale=3.0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, dim)).astype(np.float32) * scale


def _mutable_with_history(dim=5, seed=0, k=6):
    """base + sealed delta + unsealed buffer + more-than-k tombstones."""
    rng = np.random.default_rng(seed)
    cfg = JoinConfig(k=k, n_pivots=16, n_groups=4, seed=seed)
    mi = MutableIndex.build(_data(700, dim, seed + 1), cfg,
                            seal_threshold=300)
    mi.insert(_data(340, dim, seed + 2))
    mi.insert(_data(90, dim, seed + 3))
    mi.delete(rng.choice(700, 3 * k + 20, replace=False))
    return mi, cfg


# ---------------------------------------------------------------------------
# resident re-rank: bitwise matrix


@pytest.mark.parametrize("impl", ["ref", "ref_sched", "pallas_interpret"])
@pytest.mark.parametrize("kind", ["sindex", "mutable"])
def test_resident_bitwise_matrix(impl, kind):
    """The fused device re-rank (shortlist gather + canonical distances
    + stable top-k, all inside one jit) must be bitwise the oracle on
    every impl and over both index kinds — tombstones included."""
    if kind == "sindex":
        cfg = JoinConfig(k=6, n_pivots=16, n_groups=4, seed=1)
        s = _data(900, 5, 11)
        idx = build_index(s, cfg)
        oracle = lambda q: knn_join(q, s, k=cfg.k, config=cfg)  # noqa: E731
    else:
        idx, cfg = _mutable_with_history(seed=7)
        oracle = None
    eng = QuantMegastepEngine(idx, cfg, impl=impl, resident=True)
    assert eng.mode == "int8" and eng.resident
    q = _data(90, 5, 40)
    stats = JoinStats()
    d, i = eng.join_batch(q, stats=stats)
    assert stats.n_resident_rerank == q.shape[0]
    if kind == "sindex":
        ref = oracle(q)
        np.testing.assert_array_equal(d, ref.distances)
        np.testing.assert_array_equal(i, ref.indices)
    else:
        hd, hi = idx.join_batch(q, config=cfg)
        np.testing.assert_array_equal(d, hd)
        np.testing.assert_array_equal(i, hi)


def test_host_gather_matches_resident_bitwise():
    """resident=False keeps the low-memory host-gather re-rank; both
    variants are the same exact join, bit for bit."""
    cfg = JoinConfig(k=5, n_pivots=16, n_groups=4, seed=2)
    idx = build_index(_data(800, 6, 3), cfg)
    q = _data(70, 6, 4)
    st_r, st_h = JoinStats(), JoinStats()
    dr, ir = QuantMegastepEngine(idx, cfg, resident=True,
                                 tune=False).join_batch(q, stats=st_r)
    dh, ih = QuantMegastepEngine(idx, cfg, resident=False,
                                 tune=False).join_batch(q, stats=st_h)
    np.testing.assert_array_equal(dr, dh)
    np.testing.assert_array_equal(ir, ih)
    assert st_r.n_resident_rerank == q.shape[0] and st_r.n_host_rerank == 0
    assert st_h.n_host_rerank == q.shape[0] and st_h.n_resident_rerank == 0


def test_resident_zero_steady_state_syncs():
    """The device-level resident call transfers nothing host↔device in
    steady state — the fp32 megastep's invariant, restored for int8."""
    import jax

    cfg = JoinConfig(k=4, n_pivots=16, n_groups=4, seed=5)
    idx = build_index(_data(600, 6, 6), cfg)
    eng = QuantMegastepEngine(idx, cfg, resident=True, tune=False)
    q = _data(48, 6, 7)
    eng.join_batch(q)                       # warm: traces + payload upload
    qd, nv = eng.enqueue(q)
    jax.block_until_ready(eng.join_batch_device(qd, nv))
    with jax.transfer_guard("disallow"):
        jax.block_until_ready(eng.join_batch_device(qd, nv))


def test_trace_count_stable_with_tuned_config_over_ragged_batches():
    """A cached TunedConfig pins mp/tile shapes, so repeating ragged
    batch sizes reuse the compiled fused step — zero recompiles."""
    cfg = JoinConfig(k=4, n_pivots=16, n_groups=4, seed=8)
    idx = build_index(_data(500, 5, 9), cfg)
    tuned = TunedConfig(mode="int8", mp=32)
    eng = QuantMegastepEngine(idx, cfg, tune=tuned, resident=True)
    assert eng.mp == 32 and eng.mode == "int8"
    for n in (17, 23, 9):                    # warm buckets 32 and 16
        eng.join_batch(_data(n, 5, 100 + n))
    c0 = M.trace_count()
    for n in (23, 17, 9, 31, 10, 16):        # same buckets, ragged sizes
        eng.join_batch(_data(n, 5, 200 + n))
    assert M.trace_count() == c0, "ragged batch sizes re-traced"


# ---------------------------------------------------------------------------
# autotune: table semantics + engine wiring


def test_tuned_config_validation():
    with pytest.raises(ValueError):
        TunedConfig(mode="int4")
    with pytest.raises(ValueError):
        TunedConfig(mode="int8", mp=48)          # not a power of two
    assert TunedConfig(mode="fp32").mp == 0


def test_table_roundtrip_and_key_bucketing(tmp_path):
    t = TuningTable()
    cfg = TunedConfig(mode="int8", mp=64, bn=256,
                      int8_batch_s=1e-3, fp32_batch_s=2e-3)
    t.put(32, 20000, 10, "cpu", cfg)
    p = tmp_path / "tune.json"
    t.save(str(p))
    t2 = TuningTable.load(str(p))
    # n_rows buckets to the next pow2: 20000 and 17000 share a cell
    assert t2.get(32, 17000, 10, "cpu") == cfg
    assert t2.get(32, 20000, 10, "cpu") == cfg
    assert t2.get(32, 40000, 10, "cpu") is None    # different bucket
    assert t2.get(32, 20000, 5, "cpu") is None     # different k
    assert t2.get(32, 20000, 10, "tpu") is None    # different backend
    assert table_key(32, 20000, 10, "cpu") == "cpu|d32|n32768|k10"


def test_env_override_routes_engine_to_fp32(tmp_path, monkeypatch):
    """A table entry saying fp32-wins makes a default-constructed engine
    run the plain megastep (still exact); an explicit slack pins int8
    regardless — operators and tests always win over the tuner."""
    import jax

    cfg = JoinConfig(k=4, n_pivots=16, n_groups=4, seed=12)
    s = _data(700, 7, 13)
    idx = build_index(s, cfg)
    backend = jax.default_backend()
    t = TuningTable()
    t.put(7, idx.n_s, 4, backend, TunedConfig(mode="fp32"))
    p = tmp_path / "tune_fp32.json"
    t.save(str(p))
    monkeypatch.setenv("REPRO_QUANT_TUNE_TABLE", str(p))
    autotune.reset_default_table()
    try:
        eng = QuantMegastepEngine(idx, cfg)
        assert eng.mode == "fp32" and eng.autotuned and not eng.resident
        with pytest.raises(RuntimeError):
            eng.coarse_shortlist(_data(8, 7, 14))
        q = _data(60, 7, 15)
        stats = JoinStats()
        d, i = eng.join_batch(q, stats=stats)
        assert stats.quant_mode == "fp32" and stats.quant_autotuned
        ref = knn_join(q, s, k=cfg.k, config=cfg)
        np.testing.assert_array_equal(d, ref.distances)
        np.testing.assert_array_equal(i, ref.indices)
        # explicit slack overrides the table's verdict
        forced = QuantMegastepEngine(idx, cfg, slack=28)
        assert forced.mode == "int8" and forced.mp == 32
        fd, fi = forced.join_batch(q)
        np.testing.assert_array_equal(fd, ref.distances)
        np.testing.assert_array_equal(fi, ref.indices)
    finally:
        monkeypatch.delenv("REPRO_QUANT_TUNE_TABLE")
        autotune.reset_default_table()


def test_sweep_config_smoke():
    """The sweep returns a measured verdict and an engine built from it
    stays exact (whatever mode won)."""
    cfg = JoinConfig(k=4, n_pivots=8, n_groups=2, seed=20)
    s = _data(400, 6, 21)
    idx = build_index(s, cfg)
    tuned = autotune.sweep_config(idx, cfg, batch=64, iters=1)
    assert tuned.mode in ("int8", "fp32")
    assert np.isfinite(tuned.int8_batch_s) and np.isfinite(
        tuned.fp32_batch_s)
    eng = QuantMegastepEngine(idx, cfg, tune=tuned)
    q = _data(32, 6, 22)
    d, i = eng.join_batch(q)
    ref = knn_join(q, s, k=cfg.k, config=cfg)
    np.testing.assert_array_equal(d, ref.distances)
    np.testing.assert_array_equal(i, ref.indices)
