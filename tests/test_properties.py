"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; tier-1 must "
    "still collect on clean environments without it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    JoinConfig, brute_force_knn, geometric_grouping, knn_join, plan_join,
    replication_count_exact, replication_count_partitions)
from repro.core.join import topk_merge
from repro.data import expand_dataset, forest_like


@st.composite
def join_instance(draw):
    n_r = draw(st.integers(30, 120))
    n_s = draw(st.integers(40, 160))
    dim = draw(st.integers(2, 8))
    k = draw(st.integers(1, min(10, n_s)))
    m = draw(st.integers(2, min(24, n_r)))
    g = draw(st.integers(1, min(6, m)))
    grouping = draw(st.sampled_from(["geometric", "greedy"]))
    seed = draw(st.integers(0, 2**16))
    return n_r, n_s, dim, k, m, g, grouping, seed


@given(join_instance())
@settings(max_examples=25, deadline=None)
def test_join_matches_bruteforce(inst):
    n_r, n_s, dim, k, m, g, grouping, seed = inst
    rng = np.random.default_rng(seed)
    r = rng.normal(size=(n_r, dim)).astype(np.float32) * 3
    s = rng.normal(size=(n_s, dim)).astype(np.float32) * 3
    cfg = JoinConfig(k=k, n_pivots=m, n_groups=g, grouping=grouping,
                     seed=seed)
    res = knn_join(r, s, config=cfg)
    bd, _ = brute_force_knn(r, s, k)
    np.testing.assert_allclose(res.distances, bd, atol=1e-3)
    # invariants: sorted ascending, valid ids, no duplicates per row
    assert (np.diff(res.distances, axis=1) >= -1e-6).all()
    assert ((res.indices >= 0) & (res.indices < n_s)).all()
    for row in res.indices:
        assert len(set(row.tolist())) == k


@given(join_instance())
@settings(max_examples=20, deadline=None)
def test_bounds_are_bounds(inst):
    n_r, n_s, dim, k, m, g, grouping, seed = inst
    rng = np.random.default_rng(seed + 1)
    r = rng.normal(size=(n_r, dim)).astype(np.float32)
    s = rng.normal(size=(n_s, dim)).astype(np.float32)
    plan = plan_join(r, s, JoinConfig(k=k, n_pivots=m, n_groups=g,
                                      grouping=grouping, seed=seed))
    bd, _ = brute_force_knn(r, s, k)
    # θ: per-partition upper bound on k-th NN distance
    for i in np.unique(plan.r_part):
        assert (bd[plan.r_part == i, -1] <= plan.theta[i] + 1e-3).all()
    # lb(s, P_i^R) ≤ |r, s| for every r in the partition (Thm 4), checked
    # via the shipped-mask completeness (its contrapositive)
    _, bi = brute_force_knn(r, s, k)
    g_r = plan.group_of_r()
    for gg in range(plan.n_groups):
        sel = g_r == gg
        if sel.any():
            assert plan.s_replica_mask(gg)[np.unique(bi[sel])].all()


@given(join_instance())
@settings(max_examples=25, deadline=None)
def test_replication_approx_upper_bounds_exact(inst):
    """Grouping cost model: the Eq. 12 partition-level approximation
    (whole partitions counted once their replication window opens — the
    quantity greedy grouping minimizes) upper-bounds the Theorem-7 exact
    replica count per group. Per partition j: if LB ≤ U(P_j) the approx
    counts |P_j| ≥ the rows actually past LB; otherwise every row sits
    below LB and both sides count zero."""
    n_r, n_s, dim, k, m, g, grouping, seed = inst
    rng = np.random.default_rng(seed + 7)
    r = rng.normal(size=(n_r, dim)).astype(np.float32) * 2
    s = rng.normal(size=(n_s, dim)).astype(np.float32) * 2
    plan = plan_join(r, s, JoinConfig(k=k, n_pivots=m, n_groups=g,
                                      grouping=grouping, seed=seed))
    approx = replication_count_partitions(plan.lb_group, plan.t_s)
    exact = replication_count_exact(plan.lb_group, plan.s_part,
                                    plan.s_dist)
    assert (approx >= exact).all()
    # and the approximation can never promise less than shipping
    # everything to every group would
    assert (approx <= plan.t_s.counts.sum()).all()


@given(join_instance())
@settings(max_examples=25, deadline=None)
def test_geometric_grouping_balance(inst):
    """Algorithm 4's load balancing: because each step hands the
    currently-smallest group one partition, a group's final population
    can exceed the mean by at most one partition's population (the
    paper's balance factor at partition granularity)."""
    n_r, n_s, dim, k, m, g, grouping, seed = inst
    rng = np.random.default_rng(seed + 11)
    r = rng.normal(size=(n_r, dim)).astype(np.float32)
    s = rng.normal(size=(n_s, dim)).astype(np.float32)
    plan = plan_join(r, s, JoinConfig(k=k, n_pivots=m, n_groups=g,
                                      grouping="geometric", seed=seed))
    groups = geometric_grouping(plan.pivd, plan.t_r.counts, g)
    assert groups.shape == (m,) and ((groups >= 0) & (groups < g)).all()
    pops = np.bincount(groups, weights=plan.t_r.counts,
                       minlength=g).astype(np.int64)
    assert pops.sum() == plan.t_r.counts.sum()
    limit = plan.t_r.counts.sum() / g + plan.t_r.counts.max()
    assert (pops <= limit).all()


@given(st.integers(1, 200), st.integers(1, 50), st.integers(1, 20),
       st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_topk_merge_property(n, t, k, seed):
    rng = np.random.default_rng(seed)
    best_d = np.sort(rng.random((n, k)).astype(np.float32), axis=1)
    best_i = rng.integers(0, 10_000, (n, k))
    new_d = rng.random((n, t)).astype(np.float32)
    new_i = rng.integers(0, 10_000, (n, t))
    md, mi = topk_merge(best_d, best_i, new_d, new_i, k)
    ref = np.sort(np.concatenate([best_d, new_d], axis=1), axis=1)[:, :k]
    np.testing.assert_allclose(md, ref, atol=0)
    assert (np.diff(md, axis=1) >= 0).all()


@st.composite
def shard_runs(draw):
    """N id-disjoint per-shard sorted top-kp runs plus a non-empty
    subset of the shards, the way the degraded-coverage path sees them:
    every global row lives on exactly one shard, each shard reports its
    exact local top-kp as an ascending +inf/-1-padded pow2 run."""
    n_shards = draw(st.integers(1, 6))
    n_q = draw(st.integers(1, 8))
    kp = 1 << draw(st.integers(0, 4))
    n_rows = draw(st.integers(1, 60))
    seed = draw(st.integers(0, 2**16))
    subset = draw(st.sets(st.integers(0, n_shards - 1), min_size=1,
                          max_size=n_shards))
    return n_shards, n_q, kp, n_rows, seed, sorted(subset)


@given(shard_runs())
@settings(max_examples=40, deadline=None)
def test_tree_merge_subset_stability(inst):
    """Satellite: the sharded reduction is *subset-stable* — folding any
    non-empty subset of id-disjoint per-shard runs through
    ``tree_merge_runs`` yields exactly the single-device top-k
    restricted to the subset's rows, id-disjoint and ascending. This is
    the algebraic fact that lets degraded-coverage serving merge only
    the surviving shards' runs."""
    import jax.numpy as jnp

    from repro.kernels.sorted_merge import tree_merge_runs

    n_shards, n_q, kp, n_rows, seed, subset = inst
    rng = np.random.default_rng(seed)
    # distinct distances -> a unique answer to compare bitwise
    d_all = rng.permutation(n_q * n_rows).astype(np.float32)
    d_all = d_all.reshape(n_q, n_rows)
    owner = rng.integers(0, n_shards, n_rows)
    runs = []
    for sh in subset:
        rows = np.where(owner == sh)[0]
        dj = np.full((n_q, kp), np.inf, np.float32)
        ij = np.full((n_q, kp), -1, np.int32)
        take = rows[np.argsort(d_all[:, rows], axis=1, kind="stable")]
        m = min(kp, rows.size)
        if m:
            srt = np.sort(d_all[:, rows], axis=1)[:, :m]
            dj[:, :m] = srt
            ij[:, :m] = take[np.arange(n_q)[:, None],
                             np.arange(m)[None, :]]
        runs.append((jnp.asarray(dj), jnp.asarray(ij)))
    md, mi = tree_merge_runs(runs)
    md, mi = np.asarray(md), np.asarray(mi)
    # oracle: top-kp over the union of the subset's rows only
    cov = np.isin(owner, subset)
    rows = np.where(cov)[0]
    ref_d = np.full((n_q, kp), np.inf, np.float32)
    ref_i = np.full((n_q, kp), -1, np.int32)
    m = min(kp, rows.size)
    if m:
        order = np.argsort(d_all[:, rows], axis=1, kind="stable")[:, :m]
        ref_d[:, :m] = np.take_along_axis(d_all[:, rows], order, axis=1)
        ref_i[:, :m] = rows[order]
    np.testing.assert_array_equal(md, ref_d)
    np.testing.assert_array_equal(mi, ref_i)
    # order-canonical and id-disjoint: ascending with padding sunk to
    # the tail, every real id at most once (diff would NaN on inf pads)
    assert (md[:, :-1] <= md[:, 1:]).all()
    for row in mi:
        real = row[row >= 0].tolist()
        assert len(real) == len(set(real))
    # width-mismatch runs are rejected loudly, not silently truncated
    if kp > 1:
        bad = (runs[0][0][:, : kp // 2], runs[0][1][:, : kp // 2])
        with pytest.raises(ValueError, match="equal-width"):
            tree_merge_runs([runs[0], bad])


@given(st.integers(1, 5), st.integers(50, 300), st.integers(2, 8),
       st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_expand_dataset(factor, n, dim, seed):
    """Paper §6 expansion: size × factor, per-dim value support preserved
    up to rank shifting."""
    base = forest_like(n, dim, seed)
    out = expand_dataset(base, factor, seed)
    assert out.shape == (n * factor, dim)
    assert np.isfinite(out).all()
    for d in range(dim):
        assert set(np.unique(out[:, d])) <= set(np.unique(base[:, d]))
