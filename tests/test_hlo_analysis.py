"""The roofline accounting must scale loop bodies by trip counts."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, cost_analysis_dict


def test_scan_flops_scaled_by_trip_count():
    n, trips = 128, 7

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=trips)
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    totals = analyze(c.as_text())
    expect = trips * 2 * n * n * n
    assert abs(totals.flops - expect) / expect < 0.01, totals.flops
    # raw cost_analysis counts the body once — the bug this module fixes.
    # (dict on newer JAX, 1-element list on older — normalized by the
    # same helper dryrun uses)
    raw = cost_analysis_dict(c)["flops"]
    assert raw < expect / 2


def test_nested_scan():
    n, inner, outer = 64, 3, 5

    def f(x, w):
        def obody(c, _):
            def ibody(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(ibody, c, None, length=inner)
            return c2, None
        out, _ = jax.lax.scan(obody, x, None, length=outer)
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    totals = analyze(c.as_text())
    expect = outer * inner * 2 * n ** 3
    assert abs(totals.flops - expect) / expect < 0.01, totals.flops


def test_plain_matmul():
    m, k, n = 32, 48, 64
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
    totals = analyze(c.as_text())
    assert abs(totals.flops - 2 * m * k * n) / (2 * m * k * n) < 0.01
    # bytes: at least operands + result once
    assert totals.bytes >= 4 * (m * k + k * n + m * n)
