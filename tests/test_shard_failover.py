"""Shard fault tolerance (core.sharded + serve.faultinject +
serve.scheduler): replicated pivot-group placement, bitwise failover,
certified degraded-coverage serving, bounded attempt timeouts, and
background recovery.

The load-bearing property stack:

* replication ``r`` places every pivot group on r distinct shards, each
  replica the same pivot-sorted packed slice — and ``r=1`` leaves the
  packing byte-identical to the unreplicated layout;
* any owner view that serves each covered partition on exactly one live
  shard is *bitwise* the single-device engine on the covered set (the
  shard-invariance argument survives failover);
* once a populated group has no live replica, every response carries a
  *sound* per-query recall lower bound (rb ≤ true recall — verified
  against the brute-force oracle under an 8-device mesh, the PR-6
  degraded-mode guard style);
* a hung collective is converted into a shard failure by the bounded
  ``attempt_timeout`` instead of hanging ``serve_forever()``, and the
  scheduler re-checks deadlines at the failover instant
  (``n_expired_dispatched`` stays hard-zero).

Multi-shard matrices need more than one device, so they run in
subprocesses with 8 forced host devices (the test_sharded_megastep
pattern); packing invariants, health semantics, 1-shard failover
wiring, fault-plan composition and the scheduler ladder run in-process.
"""
import json
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.core import JoinConfig, StreamJoinEngine, build_index
from repro.core.megastep import MegastepEngine
from repro.core.sharded import ShardedMegastepEngine, ShardHealth
from repro.serve.faultinject import (FaultPlan, InjectedFault, ShardFault,
                                     ShardFailedError)
from repro.serve.scheduler import (SchedulerConfig, ServeScheduler,
                                   VirtualClock)

DIM = 6


def _data(n=400, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, DIM)).astype(np.float32) * 2).copy()


def _index(n=400, k=5):
    cfg = JoinConfig(k=k, n_pivots=24, n_groups=6, grouping="geometric")
    return build_index(_data(n), cfg), cfg


# ------------------------------------------------ replicated packing

def test_replicated_packing_invariants():
    idx, _ = _index()
    for n_sh, r in ((2, 2), (4, 2), (4, 3), (8, 4), (2, 5)):
        sp = idx.shard_packing(n_sh, r=r)
        r_eff = min(r, n_sh)
        assert sp.r == r_eff
        reps = sp.replicas_of_part
        assert reps.shape == (r_eff, idx.n_pivots)
        # replica 0 is the §5 primary placement, all replicas distinct
        # and in range
        assert np.array_equal(reps[0], sp.shard_of_part)
        assert ((reps >= 0) & (reps < n_sh)).all()
        for p in range(idx.n_pivots):
            assert len(set(reps[:, p].tolist())) == r_eff
        # every shard holds r copies' worth of rows in total
        assert int(sp.rows_per_shard.sum()) == r_eff * idx.n_s
        # each replica block stays in (partition, dist) packed order
        for j in range(n_sh):
            live = sp.gids_local[j] >= 0
            order = np.lexsort((sp.dist[j][live], sp.part[j][live]))
            assert np.array_equal(order, np.arange(order.size))


def test_owner_view_partitions_served_rows_exactly_once():
    """For any failed-shard set, the serve mask hands each covered row
    to exactly one live shard — the union over shards equals the
    original row set minus uncovered partitions."""
    idx, _ = _index()
    sp = idx.shard_packing(4, r=2)
    for failed in ((), (1,), (0, 2), (3, 1), (0, 1, 2)):
        owner = sp.owner_view(frozenset(failed))
        assert not set(np.unique(owner)) & set(failed)
        mask = sp.serve_mask(owner)
        served = np.sort(sp.gids_local[mask])
        covered = ~np.isin(idx.s_part_sorted, np.where(owner < 0)[0])
        expect = np.sort(idx.s_ids_sorted[covered])
        assert np.array_equal(served, expect)
        # coverage bookkeeping is consistent with the same view
        frac = sp.coverage_fraction(owner)
        assert frac == pytest.approx(expect.size / idx.n_s)
        assert sp.uncovered_parts(owner).any() == (frac < 1.0)
    # healthy view == the primary placement, bit for bit
    assert np.array_equal(sp.owner_view(()), sp.shard_of_part)


def test_owner_view_prefers_primary_then_first_live_backup():
    idx, _ = _index()
    sp = idx.shard_packing(4, r=3)
    reps = sp.replicas_of_part
    owner = sp.owner_view(frozenset({int(reps[0, 0])}))
    # partition 0 lost its primary: served by its first live backup
    assert owner[0] == reps[1, 0]
    # everything whose primary is alive stays on the primary
    alive = reps[0] != reps[0, 0]
    assert np.array_equal(owner[alive], reps[0][alive])


def test_partition_counts_deduplicate_replicas():
    idx, _ = _index()
    for r in (1, 2, 3):
        sp = idx.shard_packing(4, r=r)
        np.testing.assert_array_equal(
            sp.partition_counts(),
            np.bincount(idx.s_part, minlength=idx.n_pivots))


def test_replication_validation_and_hbm_cost():
    idx, _ = _index()
    with pytest.raises(ValueError, match="replication factor"):
        idx.shard_packing(4, r=0)
    per1 = idx.shard_packing(4, r=1).nbytes_per_shard()
    per2 = idx.shard_packing(4, r=2).nbytes_per_shard()
    # Cor. 2 shape: replication costs ~r× the resident rows, never more
    assert int(per1.sum()) == idx.nbytes_resident()
    assert int(per2.sum()) == 2 * idx.nbytes_resident()


# ------------------------------------------------------- health tracker

def test_shard_health_semantics():
    h = ShardHealth(4)
    assert h.failed == frozenset() and h.generation == 0
    assert h.mark_failed(2)
    assert h.failed == frozenset({2}) and h.generation == 1
    # duplicates / out-of-range / unattributed don't change the view
    assert not h.mark_failed(2)
    assert not h.mark_failed(7)
    assert not h.mark_failed(None)
    assert h.generation == 1 and h.n_faults == 4
    h.note_timeout()
    assert h.n_timeouts == 1
    h.reset()
    assert h.failed == frozenset() and h.generation == 2


# ---------------------------------------- 1-shard failover wiring

def test_shard_fault_marks_health_and_fails_over():
    """A ShardFault on the compute site converts into ShardFailedError
    after marking the shard; join_batch retries internally on the
    updated view (1 shard + r=1: nothing left — results are honestly
    empty with rb=0 and coverage 0)."""
    idx, cfg = _index()
    eng = ShardedMegastepEngine(idx, cfg, n_shards=1)
    q = _data(30, seed=3)
    d0, i0 = eng.join_batch(q)
    with FaultPlan().fail(
            "sharded.shard_compute", times=1,
            exc=ShardFault("sharded.shard_compute", shard=0)) as plan:
        d, i, rb = eng.join_batch_covered(q)
    assert plan.fired["sharded.shard_compute"] == 2   # fault + retry
    assert eng.health.failed == frozenset({0})
    assert eng.coverage_degraded
    assert eng.coverage_fraction() == 0.0
    assert np.isinf(d).all() and (i == -1).all() and (rb == 0.0).all()
    # recovery restores exact serving, bit for bit
    eng.recover(wait=True)
    assert not eng.health.failed and not eng.coverage_degraded
    d2, i2 = eng.join_batch(q)
    np.testing.assert_array_equal(d0, d2)
    np.testing.assert_array_equal(i0, i2)


def test_shard_failed_error_exhausts_after_bounded_retries():
    idx, cfg = _index()
    eng = ShardedMegastepEngine(idx, cfg, n_shards=1)
    q = _data(10, seed=4)
    exc = ShardFault("sharded.shard_compute", shard=0)
    with FaultPlan().fail("sharded.shard_compute", times=99, exc=exc):
        with pytest.raises(ShardFailedError):
            eng.join_batch(q)


def test_anonymous_fault_on_shard_site_stays_generic():
    """A plain InjectedFault on a sharded.* site is a generic transient:
    no health mark, no ShardFailedError — the retry ladder (not
    failover) owns it."""
    idx, cfg = _index()
    eng = ShardedMegastepEngine(idx, cfg, n_shards=1)
    with FaultPlan().fail("sharded.shard_compute", times=1):
        with pytest.raises(InjectedFault):
            eng.dispatch(_data(8, seed=5))
    assert eng.health.failed == frozenset()
    assert eng.health.n_faults == 0


def test_poisoned_collective_fails_over():
    """A ShardFault on the collective (finalize) site marks the shard
    too — the dispatch and finalize halves share one failover path."""
    idx, cfg = _index()
    eng = ShardedMegastepEngine(idx, cfg, n_shards=1)
    h = eng.dispatch(_data(8, seed=6))
    with FaultPlan().fail(
            "sharded.collective", times=1,
            exc=ShardFault("sharded.collective", shard=0)):
        with pytest.raises(ShardFailedError):
            eng.finalize(h)
    assert eng.health.failed == frozenset({0})


# ------------------------------------- fault-plan composition (sat. 2)

def test_mixed_site_plan_fires_each_site_as_armed():
    """One armed FaultPlan composes shard-level sites with the existing
    megastep/scheduler sites: each fires independently, exactly as
    armed, each producing its own failure mode."""
    idx, cfg = _index()
    eng = StreamJoinEngine(idx, cfg, megastep=True, n_shards=1)
    sched = ServeScheduler(eng, config=SchedulerConfig(max_inflight=1),
                           sleep=lambda _s: None)
    me = eng.megastep_engine
    q = _data(12, seed=7)
    ref_d, ref_i = eng.join_batch_host(q)
    plan = (FaultPlan()
            .fail("sharded.shard_compute", times=1,
                  exc=ShardFault("sharded.shard_compute", shard=0))
            .fail("megastep.fetch", times=1)
            .fail("sched.dispatch", times=1)
            .transform("sharded.collective", lambda v: v))
    with plan:
        # shard fault at dispatch → failover retry; that retry's
        # finalize hits the generic fetch fault, which is NOT a shard
        # fault and propagates as-is (retry-ladder territory)
        with pytest.raises(InjectedFault):
            me.join_batch(q)
        assert me.health.failed == frozenset({0})
        # fetch fault exhausted: the covered call now completes on the
        # failed-over (here: fully-lost) view with honest zero bounds
        d, i, rb = me.join_batch_covered(q)
        assert (rb == 0.0).all()
        # scheduler dispatch fault → ladder retries onto the exact
        # host-planned oracle, untouched by shard health
        t = sched.join_now(q)
    assert t.done and not t.degraded
    np.testing.assert_array_equal(t.distances, ref_d)
    np.testing.assert_array_equal(t.indices, ref_i)
    # every armed site fired, exactly as armed, in one plan
    assert plan.fired["sharded.shard_compute"] >= 2   # fault + retry
    assert plan.fired["megastep.fetch"] >= 2          # fault + pass
    assert plan.fired["sched.dispatch"] >= 2          # fault + retry
    assert plan.fired["sharded.collective"] >= 1      # identity cross


def test_upload_site_fires_during_payload_build():
    idx, cfg = _index()
    eng = ShardedMegastepEngine(idx, cfg, n_shards=1)
    with FaultPlan().transform("quant.eps_inflation",
                               lambda v: v) as plan:
        eng.join_batch(_data(8, seed=8))
    # upload site crossed at least once per shard-partitioned piece
    assert plan.fired.get("sharded.shard_upload", 0) >= 1


# --------------------------------- scheduler: failover + deadlines

def _sharded_sched(mi=2, **cfg_kw):
    idx, cfg = _index()
    eng = StreamJoinEngine(idx, cfg, megastep=True, n_shards=1)
    vc = VirtualClock()
    sched = ServeScheduler(
        eng, config=SchedulerConfig(max_inflight=mi, backoff_base_s=0.05,
                                    **cfg_kw),
        clock=vc.now, sleep=vc.advance)
    return sched, eng, vc, cfg


def test_scheduler_failover_serves_degraded_with_bounds():
    """Pipelined dispatch hits a shard failure → the scheduler re-enters
    the engine rung on the failed-over view and the ticket completes
    degraded, carrying the engine's certified (here: honestly zero)
    recall bounds; n_expired_dispatched stays 0."""
    sched, eng, vc, cfg = _sharded_sched()
    q = _data(9, seed=9)
    with FaultPlan().fail(
            "sharded.shard_compute", times=1,
            exc=ShardFault("sharded.shard_compute", shard=0)):
        t = sched.join_now(q)
    assert t.done and t.degraded
    assert (t.recall_bound == 0.0).all()
    assert sched.stats.n_failovers == 1
    assert sched.stats.n_expired_dispatched == 0
    assert sched.stats.join.n_failed_shards == 1
    assert sched.stats.join.coverage_bound == 0.0
    me = eng.megastep_engine
    me.recover(wait=True)
    t2 = sched.join_now(q)
    assert t2.done and not t2.degraded


def test_deadline_rechecked_at_failover_instant():
    """A request whose deadline expires *during* the failure window is
    shed at the failover re-entry, never dispatched — the
    n_expired_dispatched == 0 invariant holds across failover."""
    sched, eng, vc, cfg = _sharded_sched()

    def hang_then_die(v):
        vc.advance(10.0)        # the failure burns the whole deadline
        raise ShardFault("sharded.collective", shard=0)

    q = _data(7, seed=10)
    with FaultPlan().transform("sharded.collective", hang_then_die):
        t = sched.submit(q, deadline_s=1.0)
        sched.drain()
    assert t.status == "shed" and t.reason == "deadline"
    assert sched.stats.n_failovers == 1
    assert sched.stats.n_expired_dispatched == 0
    assert eng.megastep_engine.health.failed == frozenset({0})


def test_sync_path_failover_matches_pipelined():
    sched, eng, vc, cfg = _sharded_sched(mi=1)
    with FaultPlan().fail(
            "sharded.shard_compute", times=1,
            exc=ShardFault("sharded.shard_compute", shard=0)):
        t = sched.join_now(_data(6, seed=11))
    # sync rung: join_batch retries failover internally; the ticket
    # completes degraded on the covered path in the same step
    assert t.done and t.degraded
    assert sched.stats.n_expired_dispatched == 0


# -------------------------------------- bounded attempt timeouts (sat. 1)

def test_attempt_timeout_converts_hang_to_failover():
    """A hung collective (sleeping transform) is bounded by
    attempt_timeout and surfaces as a ShardFailedError; the internal
    retry then completes exactly — serve_forever() never hangs."""
    idx, cfg = _index()
    eng = ShardedMegastepEngine(idx, cfg, n_shards=1,
                                attempt_timeout=0.25)
    q = _data(20, seed=12)
    d0, i0 = eng.join_batch(q)

    hung_once = threading.Event()
    release = threading.Event()

    def hang_first(v):
        if not hung_once.is_set():
            hung_once.set()
            release.wait(30.0)      # "forever" — well past the timeout
        return v

    try:
        with FaultPlan().transform("sharded.collective", hang_first):
            d, i = eng.join_batch(q)
    finally:
        release.set()               # free the zombie attempt thread
    assert eng.health.n_timeouts == 1
    # timeout carries no shard attribution: view unchanged, results
    # bitwise the healthy ones after the internal retry
    assert eng.health.failed == frozenset()
    np.testing.assert_array_equal(d, d0)
    np.testing.assert_array_equal(i, i0)


def test_attempt_timeout_none_keeps_blocking_semantics():
    idx, cfg = _index()
    eng = ShardedMegastepEngine(idx, cfg, n_shards=1)
    assert eng.attempt_timeout is None
    d, i = eng.join_batch(_data(8, seed=13))   # no pool spun up
    assert eng._attempt_pool is None


# ----------------------------------------------- wiring / validation

def test_stream_engine_replication_plumbing():
    idx, cfg = _index()
    eng = StreamJoinEngine(idx, cfg, megastep=True, n_shards=1,
                           replication=2)
    # clamped at n_shards, like the engine ctor documents
    assert eng.megastep_engine.replication == 1
    with pytest.raises(ValueError, match="sharded-engine knobs"):
        StreamJoinEngine(idx, cfg, megastep=True, replication=2)
    qcfg = JoinConfig(k=5, n_pivots=24, n_groups=6, quantize="int8")
    qidx = build_index(_data(), qcfg)
    with pytest.raises(ValueError, match="does not replicate"):
        StreamJoinEngine(qidx, qcfg, quantized=True, n_shards=1,
                         replication=2)
    with pytest.raises(ValueError, match="replication must be >= 1"):
        ShardedMegastepEngine(idx, cfg, n_shards=1, replication=0)


def test_datastore_replication_and_recover_shards():
    from repro.serve.retrieval import Datastore
    keys = _data(300, seed=14)
    store = Datastore.build(keys, np.arange(300) % 17, k=4, n_pivots=16,
                            n_shards=1, replication=2)
    d0, i0, v0 = store.retrieve(_data(6, seed=15))
    me = store.engine().megastep_engine
    assert me.replication == 1          # clamped at n_shards=1
    with FaultPlan().fail(
            "sharded.shard_compute", times=1,
            exc=ShardFault("sharded.shard_compute", shard=0)):
        store.retrieve(_data(6, seed=15))
    assert me.health.failed == frozenset({0})
    threads = store.recover_shards(wait=True)
    assert threads == [] and not me.health.failed
    d1, i1, v1 = store.retrieve(_data(6, seed=15))
    np.testing.assert_array_equal(d0, d1)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(v0, v1)


def test_stats_stamp_failed_shards():
    from repro.core.types import JoinStats
    idx, cfg = _index()
    eng = ShardedMegastepEngine(idx, cfg, n_shards=1)
    stats = JoinStats()
    eng.join_batch(_data(8, seed=16), stats=stats)
    assert stats.n_shards == 1
    assert stats.n_failed_shards == 0
    assert stats.coverage_bound == 1.0 and stats.recall_bound == 1.0


# ----------------------------------------------- 8-device subprocesses

def _run_sub(script, extra_env=None, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.update(extra_env or {})
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


_COMMON = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core import JoinConfig, build_index
    from repro.core.megastep import MegastepEngine
    from repro.core.sharded import ShardedMegastepEngine
    from repro.serve.faultinject import FaultPlan, ShardFault

    def clustered(n, seed, centers=None):
        rng = np.random.default_rng(seed)
        if centers is None:
            centers = np.random.default_rng(99).normal(
                size=(40, 8)).astype(np.float32) * 20.0
        asg = rng.integers(0, centers.shape[0], n)
        return (centers[asg] + 0.5 * rng.normal(size=(n, 8))
                ).astype(np.float32), centers

    s, cents = clustered(4000, 0)
    q, _ = clustered(250, 1, cents)
    cfg = JoinConfig(k=8, n_pivots=64, n_groups=6,
                     pivot_strategy="kmeans")
    idx = build_index(s, cfg)
    ref = MegastepEngine(idx, cfg)
    d0, i0 = ref.join_batch(q)
"""

_FAILOVER_R2_SCRIPT = _COMMON + """
    eng = ShardedMegastepEngine(idx, cfg, n_shards=8, replication=2)
    d1, i1 = eng.join_batch(q)
    healthy_bitwise = (np.array_equal(d0, d1) and np.array_equal(i0, i1))

    # kill a shard mid-stream: the internal failover retry must land
    # bitwise on the replicas
    with FaultPlan().fail("sharded.shard_compute", times=1,
                          exc=ShardFault("sharded.shard_compute",
                                         shard=3)):
        d2, i2 = eng.join_batch(q)
    failover_bitwise = (np.array_equal(d0, d2) and np.array_equal(i0, i2))
    degraded_after_one = bool(eng.coverage_degraded)
    failed = sorted(eng.health.failed)

    # background (non-blocking) recovery, then bitwise again
    t = eng.recover(wait=False)
    t.join(timeout=120)
    d3, i3 = eng.join_batch(q)
    recovered_bitwise = (np.array_equal(d0, d3) and np.array_equal(i0, i3))
    print(json.dumps(dict(
        healthy_bitwise=healthy_bitwise, failover_bitwise=failover_bitwise,
        degraded_after_one=degraded_after_one, failed=failed,
        recovered=not eng.health.failed,
        recovered_bitwise=recovered_bitwise)))
"""

_RECALL_BOUND_SCRIPT = _COMMON + """
    eng = ShardedMegastepEngine(idx, cfg, n_shards=8, replication=1)
    with FaultPlan().fail("sharded.shard_compute", times=1,
                          exc=ShardFault("sharded.shard_compute",
                                         shard=2)):
        d, i, rb = eng.join_batch_covered(q)

    # brute-force oracle: per-query true recall of the degraded answer
    k = cfg.k
    dd = np.sqrt(np.maximum(
        (q * q).sum(1)[:, None] + (s * s).sum(1)[None, :]
        - 2.0 * (q @ s.T), 0.0))
    true_ids = np.argsort(dd, axis=1, kind="stable")[:, :k]
    true_recall = np.array([
        len(set(i[r].tolist()) & set(true_ids[r].tolist())) / k
        for r in range(q.shape[0])])
    sound = bool((true_recall >= rb - 1e-6).all())
    print(json.dumps(dict(
        coverage=eng.coverage_fraction(), degraded=bool(eng.coverage_degraded),
        rb_min=float(rb.min()), rb_mean=float(rb.mean()),
        rb_max=float(rb.max()), sound=sound,
        frac_fully_proven=float((rb == 1.0).mean()))))
"""


def test_r2_failover_bitwise_subprocess():
    out = _run_sub(_FAILOVER_R2_SCRIPT)
    assert out["healthy_bitwise"]
    assert out["failover_bitwise"], "failover perturbed output bits"
    assert out["failed"] == [3]
    # with r=2 a single shard loss keeps every pivot group covered
    assert not out["degraded_after_one"]
    assert out["recovered"] and out["recovered_bitwise"]


def test_r1_recall_bound_sound_subprocess():
    out = _run_sub(_RECALL_BOUND_SCRIPT)
    assert out["degraded"] and out["coverage"] < 1.0
    assert out["sound"], "reported recall_bound exceeded true recall"
    # on clustered data the certificate is non-vacuous: most queries
    # fully proven, the lost clusters' queries honestly uncertified
    assert out["frac_fully_proven"] > 0.5
    assert out["rb_max"] == 1.0
