"""Serving: batched generation + kNN-LM retrieval (the paper's join as a
serving feature)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import brute_force_knn
from repro.models import ModelOptions, forward, init_cache, init_params
from repro.serve import (
    BatchedServer, Datastore, KnnLMConfig, ServeConfig, interpolate,
    knn_logits)

OPTS = ModelOptions(dtype=jnp.float32, remat=False, max_abs_pos=96)


def test_batched_server_greedy_matches_manual():
    cfg = get_reduced("llama3.2-3b")
    params = init_params(cfg, jax.random.PRNGKey(0), OPTS)
    srv = BatchedServer(cfg, ServeConfig(batch=2, temperature=0.0), params,
                        OPTS)
    prompts = [np.array([1, 2, 3], np.int32), np.array([4, 5], np.int32),
               np.array([7, 8, 9, 10], np.int32)]
    outs = srv.generate(prompts, max_new_tokens=4)
    assert len(outs) == 3 and all(o.shape == (4,) for o in outs)

    # manual greedy for prompt 0 (no batching, fresh cache)
    toks = list(prompts[0])
    cache = init_cache(cfg, 1, len(toks) + 4, OPTS)
    logits, cache = forward(params, cfg, jnp.asarray([toks]), cache=cache,
                            opts=OPTS, mode="prefill")
    manual = []
    for _ in range(4):
        nxt = int(jnp.argmax(logits[0, -1]))
        manual.append(nxt)
        logits, cache = forward(params, cfg, jnp.asarray([[nxt]]),
                                cache=cache, opts=OPTS, mode="decode")
    assert manual == list(outs[0])


def test_knn_logits_match_bruteforce_neighbors():
    rng = np.random.default_rng(1)
    keys = rng.normal(size=(500, 16)).astype(np.float32)
    vals = rng.integers(0, 64, 500).astype(np.int32)
    store = Datastore.build(keys, vals, k=4, n_pivots=32, n_groups=4)
    q = rng.normal(size=(6, 16)).astype(np.float32)
    kcfg = KnnLMConfig(k=4)
    lg = knn_logits(q, store, kcfg, vocab=64)
    assert lg.shape == (6, 64)
    bd, bi = brute_force_knn(q, keys, 4)
    for i in range(6):
        # mass concentrates on the true neighbors' tokens
        top_tokens = set(vals[bi[i]].tolist())
        got = set(np.argsort(lg[i])[::-1][:len(top_tokens)].tolist())
        assert got & top_tokens


def test_knn_logits_join_and_kernel_paths_agree():
    """Distance-space regression: the PGBJ join path and the raw
    distance_topk kernel path must produce the same retrieval
    distribution — both feed true distances through `metrics.to_cmp`
    before softmax(−d_cmp/τ)."""
    rng = np.random.default_rng(2)
    keys = rng.normal(size=(400, 12)).astype(np.float32)
    vals = rng.integers(0, 48, 400).astype(np.int32)
    store = Datastore.build(keys, vals, k=6, n_pivots=32, n_groups=4)
    q = rng.normal(size=(5, 12)).astype(np.float32)
    kcfg = KnnLMConfig(k=6, tau=10.0)
    lg_join = knn_logits(q, store, kcfg, vocab=48, use_kernel=False)
    lg_kern = knn_logits(q, store, kcfg, vocab=48, use_kernel=True)
    np.testing.assert_allclose(lg_join, lg_kern, rtol=2e-4, atol=2e-4)


def test_datastore_index_reused_across_decode_steps():
    """The serve path never re-runs S-side phase 1: two decode batches
    against the same store plan fresh but reuse the resident index."""
    import repro.core.index as index_mod

    rng = np.random.default_rng(3)
    keys = rng.normal(size=(300, 8)).astype(np.float32)
    vals = rng.integers(0, 32, 300).astype(np.int32)
    store = Datastore.build(keys, vals, k=4, n_pivots=16, n_groups=2)
    kcfg = KnnLMConfig(k=4)
    orig = index_mod.assign_and_summarize

    def guard(*a, **kw):
        raise AssertionError("S-side phase 1 re-ran during serving")

    index_mod.assign_and_summarize = guard
    try:
        for seed in (4, 5):
            q = np.random.default_rng(seed).normal(size=(3, 8)).astype(
                np.float32)
            lg = knn_logits(q, store, kcfg, vocab=32)
            assert lg.shape == (3, 32)
    finally:
        index_mod.assign_and_summarize = orig


def test_add_entries_mid_decode_no_phase1_on_existing_segments():
    """Acceptance: `add_entries` mid-decode changes retrieval results
    without re-running S-side phase 1 on pre-existing segments — the
    only phase-1 run is over the sealed delta's own rows (pinned the
    same way tests/test_stream.py pins index reuse)."""
    import repro.core.index as index_mod

    rng = np.random.default_rng(11)
    keys = rng.normal(size=(300, 8)).astype(np.float32)
    vals = rng.integers(0, 32, 300).astype(np.int32)
    store = Datastore.build(keys, vals, k=4, n_pivots=16, n_groups=2,
                            seal_threshold=2)
    kcfg = KnnLMConfig(k=4, tau=5.0)
    q = rng.normal(size=(3, 8)).astype(np.float32)
    before = knn_logits(q, store, kcfg, vocab=40)

    phase1_sizes = []
    orig = index_mod.assign_and_summarize

    def guard(data, *a, **kw):
        phase1_sizes.append(data.shape[0])
        return orig(data, *a, **kw)

    index_mod.assign_and_summarize = guard
    try:
        # plant the queries themselves as new entries with a fresh token:
        # retrieval must now find them (distance 0) mid-decode; the
        # 3-row batch crosses seal_threshold=2 and seals into a delta
        ids = store.add_entries(q, np.full(3, 39, np.int32))
        assert store.index.n_segments == 2 and store.index.n_buffered == 0
        after = knn_logits(q, store, kcfg, vocab=40)
    finally:
        index_mod.assign_and_summarize = orig
    assert not np.array_equal(before, after)
    assert (after.argmax(1) == 39).all()        # the planted pairs dominate
    # phase 1 ran exactly once, over the 3 delta rows — never over the
    # 300 pre-existing base rows
    assert phase1_sizes == [3]
    # deletion is mid-decode too: tombstoning the planted pairs restores
    # the original retrieval distribution without touching any segment
    store.remove_entries(ids)
    restored = knn_logits(q, store, kcfg, vocab=40)
    np.testing.assert_allclose(restored, before, rtol=1e-5, atol=1e-6)


def test_knn_logits_masks_padding_and_missing_neighbors():
    """Edge cases of the padded-id fix: k > |finite neighbors| must not
    wrap around the value table (`values[-1]`) nor produce NaN."""
    rng = np.random.default_rng(12)
    keys = rng.normal(size=(40, 6)).astype(np.float32)
    vals = rng.integers(0, 8, 40).astype(np.int32)
    vals[-1] = 9                                  # the wraparound target
    store = Datastore.build(keys, vals, k=4, n_pivots=8, n_groups=2)
    q = rng.normal(size=(5, 6)).astype(np.float32)
    # leave fewer live entries than k: 3 live < k=4
    store.remove_entries(np.arange(3, 40))
    assert store.n_entries == 3
    for use_kernel in (False, True):
        lg = knn_logits(q, store, KnnLMConfig(k=4), vocab=10,
                        use_kernel=use_kernel)
        assert np.isfinite(lg).all()
        # no probability mass may leak onto the deleted rows' tokens —
        # in particular none onto token 9 via a values[-1] wraparound
        live_tokens = set(vals[:3].tolist())
        for t in range(10):
            if t not in live_tokens:
                np.testing.assert_allclose(lg[:, t], np.log(1e-9))
    # zero live entries: the all-masked row degrades to the log floor
    store.remove_entries(np.arange(3))
    lg = knn_logits(q, store, KnnLMConfig(k=4), vocab=10)
    assert np.isfinite(lg).all()
    np.testing.assert_allclose(lg, np.log(1e-9))


def test_datastore_compact_remaps_values():
    """Compaction re-bases ids; the value table must follow so retrieved
    tokens are unchanged."""
    rng = np.random.default_rng(13)
    keys = rng.normal(size=(200, 8)).astype(np.float32)
    vals = rng.integers(0, 32, 200).astype(np.int32)
    store = Datastore.build(keys, vals, k=4, n_pivots=16, n_groups=2,
                            seal_threshold=8)
    store.add_entries(rng.normal(size=(10, 8)).astype(np.float32),
                      rng.integers(0, 32, 10).astype(np.int32))
    store.remove_entries([0, 5, 203])
    q = rng.normal(size=(4, 8)).astype(np.float32)
    kcfg = KnnLMConfig(k=4, tau=5.0)
    before = knn_logits(q, store, kcfg, vocab=32)
    store.compact()
    assert store.index.n_segments == 1 and store.keys.shape[0] == 207
    after = knn_logits(q, store, kcfg, vocab=32)
    np.testing.assert_allclose(after, before, rtol=1e-5, atol=1e-6)


def test_interpolation_limits():
    lm = jnp.log(jnp.asarray([[0.7, 0.2, 0.1]]))
    knn = np.log(np.asarray([[0.05, 0.05, 0.9]], np.float32))
    p0 = np.exp(np.asarray(interpolate(lm, knn, 0.0)))
    p1 = np.exp(np.asarray(interpolate(lm, knn, 1.0)))
    np.testing.assert_allclose(p0[0] / p0[0].sum(), [0.7, 0.2, 0.1],
                               atol=1e-3)
    np.testing.assert_allclose(p1[0] / p1[0].sum(), [0.05, 0.05, 0.9],
                               atol=1e-3)


def test_bf16_ingestion_add_seal_query():
    """Models emit bfloat16 hidden states (launch/serve.py): the
    datastore boundary casts them to float32 exactly once — bf16 ⊂ f32,
    so a bf16-fed store is bitwise the f32-fed one through
    add → seal → query — and rejects non-float dtypes instead of
    coercing them."""
    from repro.core import build_index

    rng = np.random.default_rng(3)
    base = rng.normal(size=(300, 12)).astype(np.float32)
    vals = rng.integers(0, 40, 300).astype(np.int32)
    new = rng.normal(size=(180, 12)).astype(np.float32)
    nv = rng.integers(0, 40, 180).astype(np.int32)
    new_bf = jnp.asarray(new, jnp.bfloat16)         # lossy: the real input
    new_f32 = np.asarray(new_bf).astype(np.float32)  # its exact f32 image

    st_bf = Datastore.build(jnp.asarray(base, jnp.bfloat16), vals, k=5,
                            n_pivots=24, seal_threshold=120)
    st_f = Datastore.build(np.asarray(
        jnp.asarray(base, jnp.bfloat16)).astype(np.float32), vals, k=5,
        n_pivots=24, seal_threshold=120)
    assert st_bf.keys.dtype == np.float32
    ids_bf = st_bf.add_entries(new_bf, nv)           # crosses a seal
    ids_f = st_f.add_entries(new_f32, nv)
    np.testing.assert_array_equal(ids_bf, ids_f)
    assert st_bf.index.n_segments >= 2               # delta sealed

    q = rng.normal(size=(6, 12)).astype(np.float32)
    kcfg = KnnLMConfig(k=5, tau=8.0)
    np.testing.assert_array_equal(knn_logits(q, st_bf, kcfg, 40),
                                  knn_logits(q, st_f, kcfg, 40))

    # build_index takes bf16 too; ints are rejected, not coerced
    idx = build_index(jnp.asarray(base, jnp.bfloat16),
                      st_bf.config)
    assert idx.s_sorted.dtype == np.float32
    import pytest
    with pytest.raises(TypeError):
        build_index(base.astype(np.int32), st_bf.config)
    with pytest.raises(TypeError):
        st_bf.add_entries(np.ones((2, 12), np.int64),
                          np.zeros(2, np.int32))


def test_retrieve_never_serves_torn_index_across_mutation():
    """Satellite regression: a mutation racing a query must never produce
    a half-swapped result. Writer threads add entries / compact while
    query threads hammer ``Datastore.retrieve``; every returned result
    must bitwise-match the oracle of SOME index version that existed —
    never a mix of two versions."""
    import threading

    rng = np.random.default_rng(11)
    dim, k = 8, 4
    base = rng.normal(size=(400, dim)).astype(np.float32)
    vals = rng.integers(0, 50, 400).astype(np.int32)
    store = Datastore.build(base, vals, k=k, n_pivots=16,
                            seal_threshold=100)
    q = rng.normal(size=(5, dim)).astype(np.float32)

    # Oracle per version: exact brute-force over the rows live at that
    # version, keyed by the version's (keys, values) snapshot taken
    # under the store lock so the snapshot itself can't tear.
    oracles = {}

    def snapshot_oracle():
        with store._lock:
            v = store.index.version
            if v in oracles:
                return
            keys, ids = store.index.live_rows()
        d = np.linalg.norm(q[:, None, :] - keys[None, :, :], axis=-1)
        order = np.argsort(d, axis=1, kind="stable")[:, :k]
        oracles[v] = (np.take_along_axis(d, order, axis=1).astype(
            np.float32), ids[order])

    snapshot_oracle()
    store.retrieve(q, k)                 # warm the jit paths up front
    stop = threading.Event()
    errors: list = []
    results: list = []

    def writer():
        try:
            r = np.random.default_rng(7)
            for i in range(8):
                new = r.normal(size=(30, dim)).astype(np.float32)
                nv = r.integers(0, 50, 30).astype(np.int32)
                store.add_entries(new, nv)
                snapshot_oracle()
                if i == 4:
                    store.compact()
                    snapshot_oracle()
                # pace on reader progress, not wall time: wait until the
                # readers have produced at least 2 results against this
                # version before mutating again, so queries genuinely
                # interleave with mutations even when jit recompiles
                # (fresh buffer shapes) make a single query slow
                goal = len(results) + 2
                t0 = time.monotonic()
                while len(results) < goal and time.monotonic() - t0 < 10:
                    time.sleep(0.005)
        except Exception as e:          # pragma: no cover - surfaced below
            errors.append(e)
        finally:
            stop.set()

    def reader():
        try:
            while not stop.is_set():
                d, idx, _ = store.retrieve(q, k)
                results.append((np.asarray(d), np.asarray(idx)))
        except Exception as e:          # pragma: no cover - surfaced below
            errors.append(e)

    import time
    threads = [threading.Thread(target=writer)] + [
        threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    assert len(results) > 10
    assert len(oracles) >= 3            # several versions actually raced

    matched = 0
    for d, idx in results:
        ok = False
        for od, oi in oracles.values():
            # distances identify the version; ties in ids are broken the
            # same stable way by both paths
            if d.shape == od.shape and np.allclose(d, od, atol=1e-4):
                ok = True
                break
        assert ok, "result matches no single index version (torn read)"
        matched += 1
    assert matched == len(results)
