"""Mutable segmented index: online inserts/deletes/compaction stay exact.

The load-bearing acceptance test: after ANY interleaving of inserts,
deletes and compactions, `MutableIndex` query results are bitwise
identical — distances, and ids up to the documented remap — to a fresh
`build_index` over the surviving rows, for all three reducers and the
streaming path. (Continuous random data: id equality is only promised
for tie-free distances — see the caveat in core/segments.py.)
"""
import numpy as np
import pytest

from repro.core import (
    JoinConfig, MutableIndex, build_index, knn_join, knn_join_batched)


def _data(rng, n, dim=6, scale=3.0):
    return rng.normal(size=(n, dim)).astype(np.float32) * scale


def _oracle(mi, r, cfg):
    """Fresh static index over the surviving rows; fresh local ids
    remapped into the mutable index's global id space."""
    rows, gids = mi.live_rows()
    res = knn_join(r, config=cfg, index=build_index(rows, cfg))
    remapped = np.where(res.indices >= 0,
                        gids[np.clip(res.indices, 0, None)], -1)
    return res.distances, remapped


def _check(mi, r, cfg):
    res = knn_join(r, config=cfg, index=mi)
    od, oi = _oracle(mi, r, cfg)
    np.testing.assert_array_equal(res.distances, od)
    np.testing.assert_array_equal(res.indices, oi)
    return res


@pytest.mark.parametrize("reducer", ["dense", "pruned", "gather"])
def test_oracle_any_interleaving(reducer):
    """Acceptance: insert → delete → seal → compact → delete → insert,
    checked against a fresh rebuild at every step, one-shot + streaming."""
    rng = np.random.default_rng(0)
    cfg = JoinConfig(k=5, n_pivots=16, n_groups=4, seed=1, reducer=reducer)
    mi = MutableIndex.build(_data(rng, 300), cfg, seal_threshold=50)
    r = _data(rng, 40)
    _check(mi, r, cfg)

    mi.insert(_data(rng, 60))                 # crosses threshold → seals
    assert len(mi.segments) == 2
    _check(mi, r, cfg)

    mi.delete(np.arange(40))                  # tombstones inside the base
    mi.insert(_data(rng, 20))                 # stays buffered
    assert mi.n_buffered == 20 and mi.n_segments == 3
    res = _check(mi, r, cfg)
    assert res.stats.n_segments == 3 and res.stats.n_tombstones == 40

    # streaming path over the same mutable index
    batched = knn_join_batched(r, index=mi, config=cfg, batch_size=13)
    np.testing.assert_array_equal(batched.distances, res.distances)
    np.testing.assert_array_equal(batched.indices, res.indices)

    pre = res.distances
    mi.compact()
    assert (mi.n_segments, mi.n_tombstones, mi.n_buffered) == (1, 0, 0)
    res = _check(mi, r, cfg)
    # the live set did not change: distances invariant under compaction
    np.testing.assert_array_equal(res.distances, pre)

    # mutate again after the rebase: ids were remapped, results stay exact
    mi.delete(res.indices[0, :2])
    mi.insert(_data(rng, 10))
    _check(mi, r, cfg)


def test_ids_are_global_stable_and_remapped_on_compact():
    rng = np.random.default_rng(1)
    cfg = JoinConfig(k=3, n_pivots=8, n_groups=2, seed=0)
    mi = MutableIndex.build(_data(rng, 80), cfg, seal_threshold=10)
    ids = mi.insert(_data(rng, 12))
    np.testing.assert_array_equal(ids, np.arange(80, 92))  # offset id space
    mi.delete([5, 81])
    rows_before, ids_before = mi.live_rows()
    old_ids = mi.compact()
    np.testing.assert_array_equal(old_ids, ids_before)     # survivor order
    rows_after, ids_after = mi.live_rows()
    np.testing.assert_array_equal(rows_after, rows_before)
    np.testing.assert_array_equal(ids_after, np.arange(90))  # re-based dense


def test_segment_offset_ids_survive_int32_overflow():
    """Global ids past 2³¹ flow uncorrupted through planning, join and
    the (hi, lo)-split merge state — the id-truncation regression."""
    rng = np.random.default_rng(2)
    cfg = JoinConfig(k=4, n_pivots=8, n_groups=2, seed=0)
    mi = MutableIndex.build(_data(rng, 100), cfg, seal_threshold=10)
    mi._next_id = 2**31 + 7       # long-lived datastore's id watermark
    big = mi.insert(_data(rng, 12))
    assert big[0] == 2**31 + 7 and len(mi.segments) == 2
    r = _data(rng, 9)
    res = knn_join(r, config=cfg, index=mi)
    od, oi = _oracle(mi, r, cfg)
    np.testing.assert_array_equal(res.indices, oi)
    assert res.indices.max() > 2**31
    # and batched, which folds through StreamJoinState
    batched = knn_join_batched(r, index=mi, config=cfg, batch_size=4)
    np.testing.assert_array_equal(batched.indices, oi)


def test_tombstoned_nearest_neighbor_is_replaced_exactly():
    """Deleting a query's nearest neighbor surfaces the next-best LIVE
    row (per-segment over-fetch k + tombstones), never a dead id."""
    rng = np.random.default_rng(3)
    cfg = JoinConfig(k=4, n_pivots=8, n_groups=2, seed=0)
    s = _data(rng, 120)
    mi = MutableIndex.build(s, cfg)
    r = _data(rng, 15)
    first = knn_join(r, config=cfg, index=mi)
    doomed = np.unique(first.indices[:, 0])
    mi.delete(doomed)
    res = _check(mi, r, cfg)
    assert not np.isin(res.indices, doomed).any()


@pytest.mark.parametrize("reducer", ["dense", "pruned", "gather"])
def test_overfetch_escalation_stays_exact(reducer):
    """Force the adaptive over-fetch's second pass: delete far more than
    k rows, all inside one query's neighborhood, so the first-pass
    ``k + min(n_dead, k)`` prefix is provably incomplete for that query
    and it re-runs at the certain ``k + n_dead`` bound."""
    rng = np.random.default_rng(8)
    cfg = JoinConfig(k=4, n_pivots=12, n_groups=3, seed=2, reducer=reducer)
    s = _data(rng, 250)
    mi = MutableIndex.build(s, cfg)
    r = _data(rng, 10)
    # kill the 20 nearest rows of query 0 (k=4 → first pass fetches 8)
    top20 = knn_join(r[:1], k=20, config=cfg, index=mi).indices[0]
    mi.delete(top20)
    res = _check(mi, r, cfg)
    assert not np.isin(res.indices, top20).any()
    assert res.stats.n_tombstones == 20


def test_delete_validates_ids():
    rng = np.random.default_rng(4)
    mi = MutableIndex.build(_data(rng, 30),
                            JoinConfig(k=2, n_pivots=4, n_groups=2))
    with pytest.raises(ValueError):
        mi.delete([30])           # never allocated
    with pytest.raises(ValueError):
        mi.delete([-1])
    mi.delete([7])
    with pytest.raises(ValueError):
        mi.delete([7])            # already dead
    with pytest.raises(ValueError):
        mi.delete([3, 3])         # duplicate in one call


def test_k_larger_than_live_rows_raises():
    rng = np.random.default_rng(5)
    cfg = JoinConfig(k=4, n_pivots=4, n_groups=2)
    mi = MutableIndex.build(_data(rng, 6), cfg)
    mi.delete([0, 1, 2])
    assert mi.n_s == 3
    with pytest.raises(ValueError):
        knn_join(_data(rng, 2), config=cfg, index=mi)
    # k == live works and over-fetches around the tombstones
    res = knn_join(_data(rng, 2), k=3, config=cfg, index=mi)
    assert (res.indices >= 0).all()


def test_empty_after_full_delete_and_compact():
    rng = np.random.default_rng(6)
    cfg = JoinConfig(k=2, n_pivots=4, n_groups=2)
    mi = MutableIndex.build(_data(rng, 10), cfg)
    mi.delete(np.arange(10))
    assert mi.n_s == 0
    mi.compact()
    assert mi.n_s == 0 and mi.n_segments == 0
    ids = mi.insert(_data(rng, 5))            # index is reusable afterwards
    np.testing.assert_array_equal(ids, np.arange(5))
    res = knn_join(_data(rng, 3), k=2, config=cfg, index=mi)
    assert (res.indices >= 0).all()


def test_compaction_time_lands_in_stats():
    rng = np.random.default_rng(7)
    cfg = JoinConfig(k=3, n_pivots=8, n_groups=2)
    mi = MutableIndex.build(_data(rng, 60), cfg)
    mi.delete([1, 2])
    from repro.core import JoinStats
    stats = JoinStats()
    mi.compact(stats=stats)
    assert stats.compact_time_s > 0.0
    assert mi.last_compact_s == stats.compact_time_s
