"""Double-buffered dispatch (SchedulerConfig.max_inflight > 1): the
scheduler overlaps batch N's device pass with batch N+1's formation via
the engine's async dispatch/finalize split — results stay bitwise the
sync path's, deadlines are re-checked at the dispatch instant, and
dispatch/finalize faults fall back onto the host-planned retry ladder
(serve.scheduler + core.stream dispatch/finalize)."""
import numpy as np
import pytest

from repro.core import JoinConfig, StreamJoinEngine, build_index, knn_join
from repro.serve import (
    FaultPlan, SchedulerConfig, ServeScheduler, VirtualClock)

DIM = 12


def _data(n=600, seed=0):
    return np.random.default_rng(seed).normal(size=(n, DIM)).astype(
        np.float32)


def _engine(n=600, *, quantized=False, k=4, seed=0):
    s = _data(n, seed)
    cfg = JoinConfig(k=k, n_pivots=32, n_groups=4,
                     quantize="int8" if quantized else "none")
    return StreamJoinEngine(build_index(s, cfg), cfg,
                            megastep="auto", quantized=quantized), s, cfg


@pytest.mark.parametrize("quantized", [False, True])
def test_pipelined_bitwise_matches_sync(quantized):
    """Same submissions through max_inflight=1 and max_inflight=2:
    every ticket's results are identical bit for bit — pipelining is a
    scheduling change, never a numerics change."""
    eng, s, cfg = _engine(quantized=quantized)
    qs = [_data(n, seed=70 + n) for n in (9, 4, 13, 7, 11)]
    outs = []
    for mi in (1, 2):
        sched = ServeScheduler(
            eng, config=SchedulerConfig(batch_rows=16, max_inflight=mi))
        tickets = [sched.submit(q) for q in qs]
        sched.drain()
        assert all(t.done and not t.degraded for t in tickets)
        outs.append(tickets)
    for q, t_sync, t_pipe in zip(qs, *outs):
        np.testing.assert_array_equal(t_pipe.distances, t_sync.distances)
        np.testing.assert_array_equal(t_pipe.indices, t_sync.indices)
        ref = knn_join(q, s, k=cfg.k, config=cfg)
        np.testing.assert_array_equal(t_pipe.distances, ref.distances)
        np.testing.assert_array_equal(t_pipe.indices, ref.indices)


def test_pipelined_coalesces_and_splits_back():
    eng, s, cfg = _engine()
    sched = ServeScheduler(
        eng, config=SchedulerConfig(batch_rows=64, max_inflight=2))
    qs = [_data(n, seed=80 + n) for n in (3, 17, 8)]
    tickets = [sched.submit(q) for q in qs]
    sched.drain()
    assert sched.stats.n_dispatches == 1       # one coalesced dispatch
    for q, t in zip(qs, tickets):
        assert t.done
        ref = knn_join(q, s, k=cfg.k, config=cfg)
        np.testing.assert_array_equal(t.distances, ref.distances)
        np.testing.assert_array_equal(t.indices, ref.indices)


def test_pipelined_window_overlaps_then_drains():
    """While work keeps arriving, one megastep stays in flight across
    steps (the overlap); an empty queue drains the window."""
    eng, _, _ = _engine()
    sched = ServeScheduler(
        eng, config=SchedulerConfig(batch_rows=8, max_inflight=2))
    tickets = [sched.submit(_data(8, seed=90 + i)) for i in range(3)]
    assert sched.step() == 8                   # dispatch #1, nothing done
    assert sched.inflight_batches == 1
    assert tickets[0].status == "queued" and sched.has_work
    sched.step()                               # dispatch #2, finalize #1
    assert tickets[0].done and tickets[1].status == "queued"
    assert sched.inflight_batches == 1
    sched.step()                               # dispatch #3, finalize #2
    assert tickets[1].done
    assert sched.step() == 8                   # queue empty: drain window
    assert tickets[2].done and sched.inflight_batches == 0
    assert not sched.has_work and sched.step() == 0
    assert all(t.attempts == 1 for t in tickets)
    assert sched.stats.n_retries == 0


def test_pipelined_join_now_resolves():
    eng, s, cfg = _engine()
    sched = ServeScheduler(eng, config=SchedulerConfig(max_inflight=3))
    q = _data(6, seed=100)
    t = sched.join_now(q)
    assert t.done and sched.inflight_batches == 0
    ref = knn_join(q, s, k=cfg.k, config=cfg)
    np.testing.assert_array_equal(t.distances, ref.distances)


def test_pipelined_dispatch_fault_falls_back_to_host_ladder():
    """A fault at the async dispatch routes that batch onto the
    synchronous retry ladder (host-planned oracle) — bitwise exact,
    counted as a retry, and the pipeline keeps going afterwards."""
    eng, s, cfg = _engine()
    sched = ServeScheduler(
        eng, config=SchedulerConfig(max_inflight=2), sleep=lambda _s: None)
    q = _data(6, seed=110)
    with FaultPlan().fail("sched.dispatch", times=1) as plan:
        t = sched.join_now(q)
    assert t.done and t.attempts == 2
    # fired twice: the raising async dispatch + the retry's pass-through
    assert plan.fired["sched.dispatch"] == 2
    assert sched.stats.n_retries == 1
    ref = knn_join(q, s, k=cfg.k, config=cfg)
    np.testing.assert_array_equal(t.distances, ref.distances)
    np.testing.assert_array_equal(t.indices, ref.indices)
    t2 = sched.join_now(_data(5, seed=111))    # pipeline still healthy
    assert t2.done and t2.attempts == 1


def test_pipelined_finalize_fault_falls_back_to_host_ladder():
    """A fault at fetch time (the finalize half) re-runs the in-flight
    batch's tickets through the retry ladder — no result is lost."""
    eng, s, cfg = _engine()
    sched = ServeScheduler(
        eng, config=SchedulerConfig(max_inflight=2), sleep=lambda _s: None)
    q = _data(6, seed=120)
    with FaultPlan().fail("megastep.fetch", times=1) as plan:
        t = sched.join_now(q)
    assert t.done and t.attempts == 2
    assert plan.fired["megastep.fetch"] == 1
    ref = knn_join(q, s, k=cfg.k, config=cfg)
    np.testing.assert_array_equal(t.distances, ref.distances)
    np.testing.assert_array_equal(t.indices, ref.indices)


def test_pipelined_deadline_rechecked_at_dispatch():
    """Expired requests are shed before the async dispatch exactly as
    on the sync path; a request that expires only *after* dispatch
    still completes — n_expired_dispatched stays 0 either way."""
    eng, _, _ = _engine()
    vc = VirtualClock()
    sched = ServeScheduler(
        eng, config=SchedulerConfig(batch_rows=8, max_inflight=2),
        clock=vc.now, sleep=vc.advance)
    t_dead = sched.submit(_data(4, seed=130), deadline_s=0.5)
    vc.advance(1.0)
    sched.drain()
    assert t_dead.status == "shed" and t_dead.reason == "deadline"
    assert t_dead.dispatched_at is None
    # expired mid-flight: dispatched while live, allowed to finish
    t_late = sched.submit(_data(4, seed=131), deadline_s=0.5)
    sched.step()                               # dispatches, stays in flight
    assert t_late.dispatched_at is not None
    vc.advance(1.0)                            # expires while in flight
    sched.drain()
    assert t_late.done
    assert sched.stats.n_expired_dispatched == 0


def test_pipelined_degraded_rung_stays_synchronous():
    """Above the degrade watermark the certified-approximate rung is a
    blocking engine call — the in-flight window is flushed first and
    degraded responses still carry their recall bounds."""
    eng, _, _ = _engine(quantized=True)
    sched = ServeScheduler(
        eng, config=SchedulerConfig(batch_rows=32, degrade_queued_rows=0,
                                    max_inflight=2))
    tickets = [sched.submit(_data(8, seed=140 + i)) for i in range(3)]
    sched.drain()
    assert sched.inflight_batches == 0
    for t in tickets:
        assert t.done and t.degraded
        rb = t.recall_bound
        assert rb.shape == (8,) and (rb >= 0).all() and (rb <= 1).all()


def test_host_engine_ignores_max_inflight():
    """An engine without the dispatch/finalize split (host-planned
    path) silently stays synchronous — max_inflight > 1 is a no-op."""
    s = _data(300, seed=1)
    cfg = JoinConfig(k=4, n_pivots=32, n_groups=4)
    eng = StreamJoinEngine(build_index(s, cfg), cfg, megastep=False)
    assert not eng.can_dispatch
    sched = ServeScheduler(eng, config=SchedulerConfig(max_inflight=4))
    q = _data(7, seed=150)
    t = sched.join_now(q)
    assert t.done and sched.inflight_batches == 0
    ref = knn_join(q, s, k=cfg.k, config=cfg)
    np.testing.assert_array_equal(t.distances, ref.distances)


def test_max_inflight_validation():
    with pytest.raises(ValueError):
        SchedulerConfig(max_inflight=0)
