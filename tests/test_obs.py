"""Flight recorder (repro.obs): span tracer, metrics registry,
exporters, per-query explain — and their integration with the serving
stack.

The load-bearing properties:

* tracing is **off by default** and the disabled path records nothing
  (one ``None`` check; the shared ``NULL_SPAN`` sinks every call);
* enabled tracing is bounded (ring buffer drops oldest), thread-aware
  (same-thread parent links), and **never touches device values** —
  a traced steady-state megastep runs under
  ``jax.transfer_guard("disallow")`` and every recorded attribute is a
  host-side value;
* the metrics registry's fixed-bucket histograms give p50/p99/p999
  without stored samples, and render in Prometheus text format;
* ``explain(ticket)`` reconstructs one request's span tree, including
  a retried + failed-over request where the failed attempt, the
  failover remask, and the deadline re-check each appear exactly once
  (the incident-audit contract);
* ``JoinStats.merged`` folds per-attempt stats without the silent
  overwrite the shared-stats threading used to cause, and
  ``ServeScheduler.snapshot`` hands back an immutable copy.
"""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import JoinConfig, StreamJoinEngine, build_index
from repro.core.types import JoinStats
from repro.serve.faultinject import FaultPlan, ShardFault
from repro.serve.scheduler import (SchedulerConfig, ServeScheduler,
                                   VirtualClock)

DIM = 6


def _data(n=400, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, DIM)).astype(np.float32) * 2).copy()


def _index(n=400, k=5):
    cfg = JoinConfig(k=k, n_pivots=24, n_groups=6, grouping="geometric")
    return build_index(_data(n), cfg), cfg


# ------------------------------------------------------------- tracer

def test_tracing_disabled_records_nothing():
    assert not obs.enabled()
    sp = obs.span("x", a=1)
    assert sp is obs.trace.NULL_SPAN
    with sp as s:
        s.set(b=2)                       # sinks silently
    assert obs.event("y", c=3) is None
    assert obs.trace.current() is None


def test_span_nesting_parent_links_and_attrs():
    with obs.capture() as tr:
        with obs.span("outer", rows=4) as so:
            with obs.span("inner") as si:
                si.set(outcome="ok")
            obs.event("mark", at="inside")
        assert so.duration_s >= 0
    spans = tr.spans()
    by_name = {s.name: s for s in spans}
    # inner lands before outer (recorded on exit), both present
    assert [s.name for s in spans] == ["inner", "mark", "outer"]
    assert by_name["inner"].parent_id == by_name["outer"].span_id
    assert by_name["mark"].parent_id == by_name["outer"].span_id
    assert by_name["outer"].parent_id == 0
    assert by_name["inner"].attrs["outcome"] == "ok"
    assert by_name["outer"].attrs["rows"] == 4
    # tracing is off again outside the capture
    assert not obs.enabled()


def test_span_exception_stamps_error_outcome():
    with obs.capture() as tr:
        with pytest.raises(ValueError):
            with obs.span("boom"):
                raise ValueError("x")
    (sp,) = tr.spans()
    assert sp.attrs["outcome"] == "error:ValueError"


def test_ring_buffer_drops_oldest():
    with obs.capture(capacity=4) as tr:
        for i in range(10):
            obs.event("e", i=i)
    assert len(tr) == 4
    assert [s.attrs["i"] for s in tr.spans()] == [6, 7, 8, 9]


def test_parent_links_never_cross_threads():
    with obs.capture() as tr:
        with obs.span("main-side"):
            t = threading.Thread(
                target=lambda: obs.event("worker-side"))
            t.start()
            t.join()
    ev = next(s for s in tr.spans() if s.name == "worker-side")
    assert ev.parent_id == 0               # root in its own thread


# ------------------------------------------------------------ metrics

def test_counter_and_gauge():
    with obs.metrics.scoped() as reg:
        c = reg.counter("hits")
        c.inc()
        c.inc(2)
        assert c.value == 3
        with pytest.raises(ValueError):
            c.inc(-1)
        # same (name, labels) → same object; labels split series
        assert reg.counter("hits") is c
        assert reg.counter("hits", site="a") is not c
        g = reg.gauge("depth")
        g.set(7)
        g.inc(-2)
        assert g.value == 5


def test_histogram_quantiles_without_samples():
    with obs.metrics.scoped() as reg:
        h = reg.histogram("lat", buckets=tuple(float(b) for b in
                                               range(1, 101)))
        for v in range(1, 101):            # uniform 1..100
            h.observe(float(v))
        assert h.count == 100
        assert h.quantile(0.5) == pytest.approx(50.0, abs=1.0)
        assert h.quantile(0.99) == pytest.approx(99.0, abs=1.0)
        assert h.quantile(1.0) == pytest.approx(100.0, abs=1.0)
        h.observe(1e9)                     # overflow clamps to last bound
        assert h.quantile(1.0) == 100.0
        empty = reg.histogram("none")
        assert np.isnan(empty.quantile(0.5))
        with pytest.raises(ValueError):
            h.quantile(1.5)
        snap = reg.snapshot()
        assert snap["lat_count"] == 101.0
        assert "lat_p999" in snap


def test_histogram_rejects_unsorted_buckets():
    with obs.metrics.scoped() as reg:
        with pytest.raises(ValueError):
            reg.histogram("bad", buckets=(2.0, 1.0))


def test_prometheus_rendering():
    with obs.metrics.scoped() as reg:
        reg.counter("req_total", site="a").inc(3)
        reg.gauge("depth").set(2)
        h = reg.histogram("lat_s", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)
        text = obs.render_prometheus(reg)
    assert '# TYPE req_total counter' in text
    assert 'req_total{site="a"} 3' in text
    assert 'depth 2' in text
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert 'lat_s_bucket{le="1"} 2' in text
    assert 'lat_s_bucket{le="+Inf"} 3' in text
    assert 'lat_s_count 3' in text


def test_scoped_registry_restores_global():
    base = obs.metrics.REGISTRY
    with obs.metrics.scoped() as reg:
        assert obs.metrics.REGISTRY is reg
        obs.metrics.REGISTRY.counter("x").inc()
    assert obs.metrics.REGISTRY is base


# ---------------------------------------------------------- exporters

def test_jsonl_and_chrome_trace_exports(tmp_path):
    with obs.capture() as tr:
        with obs.span("stage", rows=np.int64(3), sel=np.float32(0.5)):
            obs.event("flag", shard=0)
    spans = tr.spans()
    # JSONL: one valid object per line, numpy scalars made JSON-clean
    lines = obs.spans_to_jsonl(spans).strip().split("\n")
    assert len(lines) == 2
    recs = [json.loads(ln) for ln in lines]
    assert {r["name"] for r in recs} == {"stage", "flag"}
    stage = next(r for r in recs if r["name"] == "stage")
    assert stage["attrs"] == {"rows": 3, "sel": 0.5}
    # Chrome trace: durations are "X" phase in µs, instants are "i"
    p = tmp_path / "trace.json"
    obs.write_chrome_trace(spans, str(p))
    doc = json.loads(p.read_text())
    evs = {e["name"]: e for e in doc["traceEvents"]}
    assert evs["stage"]["ph"] == "X" and evs["stage"]["dur"] >= 0
    assert evs["flag"]["ph"] == "i"
    assert evs["flag"]["args"]["parent_id"] == evs["stage"]["args"][
        "span_id"]


def test_explain_builds_request_tree():
    with obs.capture() as tr:
        obs.event("serve.admission", ticket=7, outcome="admitted")
        with obs.span("serve.attempt", tickets=(7, 9), rung="engine"):
            with obs.span("megastep.device_step", bucket=16):
                pass
        obs.event("other.noise", ticket=8)
    roots = obs.explain(7, tr.spans())
    names = [n.span.name for r in roots for n in r.walk()]
    assert names == ["serve.admission", "serve.attempt",
                     "megastep.device_step"]
    # the engine child carries no ticket attr — pulled in via parent
    att = next(n for r in roots for n in r.walk()
               if n.span.name == "serve.attempt")
    assert att.children[0].span.name == "megastep.device_step"
    assert obs.explain(12345, tr.spans()) == []
    text = obs.format_explain(roots)
    assert "serve.attempt" in text and "megastep.device_step" in text
    with pytest.raises(ValueError):
        obs.explain(7)                     # no tracer, no spans
    with pytest.raises(TypeError):
        obs.explain("nope", tr.spans())


# ------------------------------------------------- JoinStats.merged

def test_joinstats_merged_semantics():
    a = JoinStats(n_r=10, n_s=400, pairs_computed=100,
                  pivot_pairs_computed=40, tiles_total=8, tiles_visited=4,
                  replicas_s=50, n_batches=1, recall_bound=0.9,
                  coverage_bound=0.8, n_failed_shards=1, n_shards=4,
                  quant_mode="int8", quant_mp=64, quant_autotuned=True,
                  n_segments=2, n_tombstones=3)
    b = JoinStats(n_r=5, n_s=400, pairs_computed=60,
                  pivot_pairs_computed=20, tiles_total=4, tiles_visited=1,
                  replicas_s=25, n_batches=1, recall_bound=0.95,
                  coverage_bound=0.7, n_failed_shards=2)
    m = a.merged(b)
    # counters sum; the originals are untouched
    assert (m.n_r, m.pairs_computed, m.pivot_pairs_computed) == (15, 160, 60)
    assert (m.tiles_total, m.tiles_visited, m.replicas_s) == (12, 5, 75)
    assert a.n_r == 10 and b.n_r == 5
    # n_s is a size, not work: max, so selectivity stays work-weighted
    assert m.n_s == 400
    assert m.selectivity == pytest.approx(220 / (15 * 400))
    # degradation keeps the worst
    assert m.recall_bound == 0.9
    assert m.coverage_bound == 0.7
    assert m.n_failed_shards == 2
    # routing fields keep the last writer iff it stamped them
    assert m.quant_mode == "int8" and m.quant_mp == 64
    assert m.n_shards == 4                 # b never stamped a mesh
    assert (m.n_segments, m.n_tombstones) == (2, 3)
    b2 = JoinStats(quant_mode="fp32", n_segments=5, n_tombstones=0,
                   n_shards=8)
    m2 = m.merged(b2)
    assert m2.quant_mode == "fp32" and m2.quant_autotuned is False
    assert (m2.n_segments, m2.n_tombstones) == (5, 0)
    assert m2.n_shards == 8


# -------------------------------------------- scheduler integration

def _host_sched():
    idx, cfg = _index()
    eng = StreamJoinEngine(idx, cfg)
    vc = VirtualClock()
    sched = ServeScheduler(eng, config=SchedulerConfig(),
                           clock=vc.now, sleep=vc.advance)
    return sched, eng


def test_scheduler_spans_carry_paper_metrics():
    """A traced request's span tree carries the §6 numbers live:
    tiles visited vs pruned, selectivity, replicas — as span attrs."""
    sched, eng = _host_sched()
    q = _data(8, seed=3)
    sched.join_now(q)                      # warm (untraced)
    with obs.capture() as tr:
        t = sched.join_now(q)
    assert t.done
    roots = obs.explain(t, tracer=tr)
    names = [n.span.name for r in roots for n in r.walk()]
    assert "serve.admission" in names
    assert "serve.coalesce" in names
    att = next(n.span for r in roots for n in r.walk()
               if n.span.name == "serve.attempt")
    assert att.attrs["outcome"] == "ok"
    assert att.attrs["tiles_total"] > 0
    assert att.attrs["tiles_pruned"] == (att.attrs["tiles_total"]
                                         - att.attrs["tiles_visited"])
    assert 0 < att.attrs["selectivity"] < 1
    assert att.attrs["replicas"] > 0
    assert "serve.complete" in names
    # every recorded attribute is host-side (the zero-sync contract)
    import jax
    for s in tr.spans():
        for v in s.attrs.values():
            assert not isinstance(v, jax.Array), (s.name, v)


def test_scheduler_metrics_published():
    sched, eng = _host_sched()
    with obs.metrics.scoped() as reg:
        sched.join_now(_data(8, seed=4))
        snap = reg.snapshot()
    assert snap["serve_submitted_total"] == 1
    assert snap["serve_completed_total"] == 1
    assert snap["serve_dispatch_total"] == 1
    assert snap["serve_latency_s_count"] == 1
    assert snap["serve_latency_s_p99"] >= 0


def test_snapshot_returns_independent_copy():
    sched, eng = _host_sched()
    sched.join_now(_data(4, seed=5))
    snap = sched.snapshot()
    assert snap.n_completed == 1
    assert snap is not sched.stats
    assert snap.join is not sched.stats.join
    snap.n_completed = 99
    snap.join.n_r = 12345
    assert sched.stats.n_completed == 1
    assert sched.stats.join.n_r != 12345


def test_retry_merges_join_stats_instead_of_overwriting():
    """A transient fault forces dispatch → host-oracle retry; the
    aggregate JoinStats must hold the *sum* of both attempts' work,
    not whichever attempt wrote last."""
    sched, eng = _host_sched()
    q = _data(8, seed=6)
    sched.join_now(q)
    base = sched.snapshot().join
    with FaultPlan().fail("sched.dispatch", times=1):
        t = sched.join_now(q)
    assert t.done
    js = sched.snapshot().join
    assert sched.snapshot().n_retries == 1
    # the retried request contributes exactly one batch of rows once
    # (the faulted attempt died before the engine ran)
    assert js.n_r == base.n_r + q.shape[0]
    assert js.pairs_computed > base.pairs_computed


# ------------------------------- trace correctness under faults (sat. 3)

def test_fault_trace_failed_attempt_failover_recheck_once():
    """Armed FaultPlan (shard_compute fault → failover → re-check →
    retry rung): the request's span tree shows the failed attempt, the
    failover remask, and the deadline re-check each exactly once, with
    correct shard id / generation attributes."""
    idx, cfg = _index()
    eng = StreamJoinEngine(idx, cfg, megastep=True, n_shards=1)
    vc = VirtualClock()
    sched = ServeScheduler(
        eng, config=SchedulerConfig(max_inflight=2, backoff_base_s=0.05),
        clock=vc.now, sleep=vc.advance)
    q = _data(9, seed=9)
    sched.join_now(q)                      # warm the serving view
    with obs.capture() as tr:
        with FaultPlan().fail(
                "sharded.shard_compute", times=1,
                exc=ShardFault("sharded.shard_compute", shard=0)):
            t = sched.join_now(q)
    assert t.done and t.degraded
    spans = tr.spans()
    roots = obs.explain(t, spans)
    tree = [n.span for r in roots for n in r.walk()]

    failed = [s for s in tree if s.name == "serve.attempt"
              and s.attrs.get("outcome") == "shard_failed"]
    assert len(failed) == 1
    assert failed[0].attrs["shard"] == 0
    assert failed[0].attrs["pipelined"] is True

    remasks = [s for s in spans if s.name == "sharded.failover_remask"]
    assert len(remasks) == 1
    assert remasks[0].attrs["shard"] == 0
    # generation bumped 0 → 1 by exactly this failure
    assert remasks[0].attrs["generation"] == 1
    assert eng.megastep_engine.health.generation == 1
    # the remask is parented inside the failed attempt (same thread)
    assert remasks[0].parent_id == failed[0].span_id

    rechecks = [s for s in tree if s.name == "serve.deadline_recheck"]
    assert len(rechecks) == 1
    assert rechecks[0].attrs["shed"] == 0

    failovers = [s for s in tree if s.name == "serve.failover"]
    assert len(failovers) == 1
    assert failovers[0].attrs["shard"] == 0
    # the failed-over attempt then completed on the covered rung
    ok = [s for s in tree if s.name == "serve.attempt"
          and s.attrs.get("outcome") == "ok"]
    assert len(ok) == 1
    assert ok[0].attrs["rung"] == "covered"
    assert ok[0].attrs["coverage_bound"] == 0.0


def test_traced_megastep_steady_state_stays_transfer_free():
    """The zero-steady-state-sync invariant with tracing ENABLED:
    the fused device step runs under jax.transfer_guard("disallow")
    with a tracer installed — recording spans must not fetch."""
    import jax
    idx, cfg = _index()
    eng = StreamJoinEngine(idx, cfg, megastep=True)
    me = eng.megastep_engine
    q = _data(16, seed=2)
    eng.join_batch(q)                      # warm + compile
    qd, nv = me.enqueue(q)
    jax.block_until_ready(me.join_batch_device(qd, nv))
    with obs.capture() as tr:
        with jax.transfer_guard("disallow"):
            jax.block_until_ready(me.join_batch_device(qd, nv))
    names = [s.name for s in tr.spans()]
    assert "megastep.device_step" in names
    assert "megastep.gather_topk" in names


def test_faultinject_publishes_crossing_metrics():
    with obs.metrics.scoped() as reg:
        with FaultPlan().fail("sched.dispatch", times=1):
            sched, eng = _host_sched()
            t = sched.join_now(_data(4, seed=8))
        assert t.done
        snap = reg.snapshot()
    assert snap['fault_crossings_total{site="sched.dispatch"}'] >= 2
    assert snap['fault_injected_total{site="sched.dispatch"}'] == 1
