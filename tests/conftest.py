import os

# Tests run on the real host device topology (1 CPU device) — the 512-way
# dry-run device forcing is strictly scoped to launch/dryrun.py and the
# subprocess-based distributed tests. Do NOT set
# xla_force_host_platform_device_count here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
