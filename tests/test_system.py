"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import JoinConfig, brute_force_knn, hbrj_join, knn_join
from repro.data import expand_dataset, forest_like, osm_like


def test_forest_selfjoin_end_to_end():
    """Paper §6 default setup in miniature: Forest-like self-join, k=10,
    random pivots + geometric grouping — exact result, lower shuffle and
    fewer computed pairs than H-BRJ. Paper-like regime: replication α is
    scale-dependent (α ≈ N at toy sizes — Fig 10's worst case), so the
    shuffle comparison uses the paper's 36-reducer setting at the largest
    size that stays fast on CPU."""
    data = forest_like(8000, 10, seed=0)
    k = 10
    cfg = JoinConfig(k=k, n_pivots=256, n_groups=36, grouping="geometric",
                     pivot_strategy="random")
    pgbj = knn_join(data, data, config=cfg)
    sample = np.random.default_rng(0).choice(8000, 400, replace=False)
    bd, _ = brute_force_knn(data[sample], data, k)
    np.testing.assert_allclose(pgbj.distances[sample], bd, atol=1e-2)

    hbrj = hbrj_join(data, data, k, n_reducers=36)
    # Fig 8(c)/11(c): PGBJ shuffles less than H-BRJ
    assert pgbj.stats.shuffle_tuples < hbrj.stats.shuffle_tuples
    # Fig 7(a)/11(b): selectivity below brute force and below H-BRJ
    assert pgbj.stats.selectivity < 1.0
    assert pgbj.stats.pairs_computed < hbrj.stats.pairs_computed


def test_osm_selfjoin_low_dim():
    """2-d OSM-like data — where Voronoi pruning shines (paper Fig 9)."""
    data = osm_like(2000, seed=1)
    cfg = JoinConfig(k=10, n_pivots=128, n_groups=9)
    res = knn_join(data, data, config=cfg)
    bd, _ = brute_force_knn(data, data, 10)
    np.testing.assert_allclose(res.distances, bd, atol=1e-3)
    # low-dim clustered data: strong pruning expected
    assert res.stats.selectivity < 0.30


def test_scalability_expansion_keeps_exactness():
    base = forest_like(400, 6, seed=2)
    for t in (2, 3):
        data = expand_dataset(base, t, seed=2)
        res = knn_join(data, data, k=5,
                       config=JoinConfig(k=5, n_pivots=48, n_groups=6))
        bd, _ = brute_force_knn(data, data, 5)
        np.testing.assert_allclose(res.distances, bd, atol=1e-2)


def test_knn_join_powers_kmeans_iteration():
    """The paper motivates kNN join via k-means/outlier detection: one
    Lloyd iteration expressed as a 1-NN join against the centroids."""
    rng = np.random.default_rng(3)
    centers = rng.uniform(-10, 10, (5, 4)).astype(np.float32)
    pts = (centers[rng.integers(0, 5, 600)]
           + rng.normal(size=(600, 4)).astype(np.float32) * 0.3)
    res = knn_join(pts, centers, k=1,
                   config=JoinConfig(k=1, n_pivots=5, n_groups=2))
    assign = res.indices[:, 0]
    d = ((pts[:, None] - centers[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(assign, d.argmin(1))
