"""Fused device-resident query megastep (core.megastep): bitwise equality
with the host-planned oracle across reducers / index kinds / ragged
splits, bucketed compile reuse (no re-plan, no recompile on repeating
batch shapes), and the zero-host-transfer steady state."""
import numpy as np
import pytest

import repro.core.megastep as M
from repro.core import (
    JoinConfig, MegastepEngine, MutableIndex, StreamJoinEngine,
    brute_force_knn, build_index, compact_visit_mask, compact_visits_jnp,
    knn_join, knn_join_batched)


def _data(n, dim, seed, scale=3.0, offset=0.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, dim)).astype(np.float32) * scale
            + np.float32(offset))


def _ids64(hi, lo):
    return ((np.asarray(hi, np.int64) << 32)
            | (np.asarray(lo, np.int64) & np.int64(0xFFFFFFFF)))


def _mutable_with_history(dim=5, seed=0, k=6):
    """base + sealed delta + unsealed buffer + tombstones (more than k
    dead in one neighborhood, exercising the widened θ)."""
    rng = np.random.default_rng(seed)
    cfg = JoinConfig(k=k, n_pivots=16, n_groups=4, seed=seed)
    mi = MutableIndex.build(_data(700, dim, seed + 1), cfg,
                            seal_threshold=300)
    mi.insert(_data(340, dim, seed + 2))          # seals a delta segment
    mi.insert(_data(90, dim, seed + 3))           # stays in the buffer
    mi.delete(rng.choice(700, 3 * k + 20, replace=False))
    return mi, cfg


@pytest.mark.parametrize("reducer", ["dense", "pruned", "gather"])
def test_megastep_matches_host_sindex(reducer):
    """Acceptance: distances and int64 ids of the megastep are bitwise
    the host-planned path's, for every host reducer engine."""
    r = _data(217, 6, 0)
    s = _data(530, 6, 1)
    cfg = JoinConfig(k=7, n_pivots=24, n_groups=5, seed=3, reducer=reducer)
    index = build_index(s, cfg)
    host = knn_join(r, config=cfg, index=index)
    bd, _ = brute_force_knn(r, s, 7)
    np.testing.assert_allclose(host.distances, bd, atol=1e-4)
    mega = knn_join(r, config=cfg, index=index, megastep=True)
    np.testing.assert_array_equal(mega.distances, host.distances)
    np.testing.assert_array_equal(mega.indices, host.indices)
    assert mega.indices.dtype == np.int64


@pytest.mark.parametrize("reducer", ["dense", "pruned", "gather"])
def test_megastep_matches_host_mutable_tombstones(reducer):
    """MutableIndex fan-out (base + delta + buffer, > k tombstones) in
    one megastep call == the host per-segment adaptive-over-fetch path."""
    import dataclasses

    mi, cfg = _mutable_with_history(seed=11)
    cfg = dataclasses.replace(cfg, reducer=reducer)
    q = _data(143, 5, 99)
    host = knn_join(q, config=cfg, index=mi)
    mega = knn_join(q, config=cfg, index=mi, megastep=True)
    np.testing.assert_array_equal(mega.distances, host.distances)
    np.testing.assert_array_equal(mega.indices, host.indices)


def test_megastep_ragged_splits_bitwise():
    """Any micro-batch split through the megastep equals the one-shot
    host join — including final ragged batches of every size."""
    r = _data(201, 5, 4)
    s = _data(460, 5, 5)
    cfg = JoinConfig(k=5, n_pivots=16, n_groups=4, seed=1)
    index = build_index(s, cfg)
    one = knn_join(r, config=cfg, index=index)
    for bs in (201, 64, 33, 7):
        res = knn_join_batched(r, index=index, config=cfg, batch_size=bs,
                               megastep=True)
        np.testing.assert_array_equal(res.distances, one.distances)
        np.testing.assert_array_equal(res.indices, one.indices)


def test_megastep_far_from_origin_selection():
    """The shared-center selection math stays exact on data far from the
    origin (the cancellation regime cmp_dist centers against)."""
    r = _data(90, 4, 6, offset=50.0)
    s = _data(300, 4, 7, offset=50.0)
    cfg = JoinConfig(k=4, n_pivots=12, n_groups=3)
    index = build_index(s, cfg)
    host = knn_join(r, config=cfg, index=index)
    mega = knn_join(r, config=cfg, index=index, megastep=True)
    np.testing.assert_array_equal(mega.distances, host.distances)
    np.testing.assert_array_equal(mega.indices, host.indices)


def test_megastep_rejects_non_l2():
    index = build_index(_data(60, 3, 8),
                        JoinConfig(k=3, metric="l1", n_pivots=8))
    with pytest.raises(ValueError, match="l2"):
        MegastepEngine(index)
    # "auto" falls back to the host path instead of raising
    eng = StreamJoinEngine(index, megastep="auto")
    assert eng.megastep_engine is None


def test_no_recompile_across_identical_ragged_batches():
    """Satellite: a repeating ragged batch size re-pads into the same
    bucket and hits the jit cache — zero traces after the first; a
    *different* ragged size in the same bucket also re-traces nothing."""
    s = _data(400, 5, 9)
    cfg = JoinConfig(k=5, n_pivots=16, n_groups=4)
    engine = StreamJoinEngine(build_index(s, cfg), cfg, megastep=True)
    engine.join_batch(_data(77, 5, 10))       # warm the (128,)-bucket step
    c0 = M.trace_count()
    for i in range(3):
        engine.join_batch(_data(77, 5, 20 + i))
    assert M.trace_count() == c0, "identical ragged batches re-traced"
    engine.join_batch(_data(70, 5, 30))       # same bucket, different size
    assert M.trace_count() == c0, "bucket-mate batch size re-traced"
    engine.join_batch(_data(130, 5, 31))      # new bucket: may trace once
    assert M.trace_count() <= c0 + 1


class _fetch_counter:
    """Counts device→host conversions (np.asarray / np.array over a
    jax.Array — the fetch path this codebase uses; ArrayImpl is a C type
    and cannot be instrumented directly)."""

    def __enter__(self):
        import jax

        self._asarray, self._array = np.asarray, np.array
        self.count = 0

        def wrap(fn):
            def inner(obj=None, *a, **kw):
                if isinstance(obj, jax.Array):
                    self.count += 1
                return fn(obj, *a, **kw)
            return inner

        np.asarray = wrap(self._asarray)
        np.array = wrap(self._array)
        return self

    def __exit__(self, *exc):
        np.asarray, np.array = self._asarray, self._array
        return False


def test_megastep_zero_host_transfers_steady_state():
    """Acceptance: between input enqueue and result fetch a steady-state
    megastep call performs zero host transfers — pinned two ways: the
    JAX transfer guard (catches any host→device re-upload) and a
    device→host fetch counter (proved non-vacuous on the host path)."""
    import jax

    s = _data(500, 6, 12)
    cfg = JoinConfig(k=5, n_pivots=16, n_groups=4)
    index = build_index(s, cfg)
    eng = MegastepEngine(index, cfg)
    qd, nv = eng.enqueue(_data(100, 6, 13))
    jax.block_until_ready(eng.join_batch_device(qd, nv))   # warm + upload

    # sanity: the counter sees the host-planned path's fetches
    with _fetch_counter() as fc:
        StreamJoinEngine(index, cfg).join_batch(_data(100, 6, 13))
    assert fc.count > 0, "fetch counter is vacuous"

    with _fetch_counter() as fc:
        with jax.transfer_guard("disallow"):
            out = eng.join_batch_device(qd, nv)
            jax.block_until_ready(out)
    assert fc.count == 0, f"steady state fetched {fc.count} arrays"
    # the result is still the exact join once fetched
    host = knn_join(_data(100, 6, 13), config=cfg, index=eng.index)
    d, hi, lo = out
    np.testing.assert_array_equal(np.asarray(d)[:100], host.distances)
    np.testing.assert_array_equal(_ids64(hi, lo)[:100], host.indices)


def test_megastep_device_state_merge_dedups():
    """The carried-state merge is the dedup sorted-run merge: revisiting
    the same queries with overlapping candidates keeps each row once."""
    import jax

    s = _data(420, 5, 14)
    cfg = JoinConfig(k=6, n_pivots=16, n_groups=4)
    eng = MegastepEngine(build_index(s, cfg), cfg)
    q = _data(80, 5, 15)
    qd, nv = eng.enqueue(q)
    first = eng.join_batch_device(qd, nv)
    merged = eng.join_batch_device(qd, nv, state=first)
    jax.block_until_ready(merged)
    host = knn_join(q, config=cfg, index=eng.index)
    d, hi, lo = merged
    np.testing.assert_array_equal(np.asarray(d)[:80], host.distances)
    np.testing.assert_array_equal(_ids64(hi, lo)[:80], host.indices)


def test_serving_engine_survives_mutations():
    """One resident engine absorbs insert/seal/delete through the index
    version — results always match a fresh host join."""
    mi, cfg = _mutable_with_history(seed=21)
    eng = StreamJoinEngine(mi, cfg, megastep=True)
    q = _data(60, 5, 77)   # seed disjoint from the index rows: coincident
    # rows create exact distance ties, whose order is documented as
    # unspecified between engines (core.segments docstring)
    for step in range(3):
        d, ids = eng.join_batch(q)
        host = knn_join(q, config=cfg, index=mi)
        np.testing.assert_array_equal(d, host.distances)
        np.testing.assert_array_equal(ids, host.indices)
        if step == 0:
            mi.insert(_data(120, 5, 123))  # fresh rows (a repeated seed
            # would duplicate existing coordinates → exact-tie ids)
        elif step == 1:
            alive = np.setdiff1d(np.arange(mi._next_id),
                                 mi.tombstones_sorted())
            mi.delete(alive[:: max(1, alive.size // 10)][:10])


@pytest.mark.parametrize("impl", ["ref_sched", "pallas_interpret"])
def test_megastep_impl_variants_match_host(impl):
    """The schedule-consuming execution variants — the lax.scan twin and
    the real Pallas kernel body (interpret mode) — walk the in-jit
    concatenated schedule and still reproduce the host path bitwise:
    the visit lists lowered on device lose no true neighbor."""
    mi, cfg = _mutable_with_history(seed=41, k=5)
    q = _data(97, 5, 55)
    host = knn_join(q, config=cfg, index=mi)
    eng = MegastepEngine(mi, cfg, impl=impl)
    d, ids = eng.join_batch(q)
    np.testing.assert_array_equal(d, host.distances)
    np.testing.assert_array_equal(ids, host.indices)


def test_buffer_segment_cache_survives_compact_reinsert():
    """Regression: ``compact()`` re-bases ``_next_id`` downward, so a
    post-compact write buffer can reproduce the ephemeral buffer-segment
    cache key of a pre-compact buffer while holding different rows —
    the snapshot must not serve the stale index."""
    cfg = JoinConfig(k=3, n_pivots=8, n_groups=2)
    mi = MutableIndex.build(_data(40, 4, 60), cfg, seal_threshold=1 << 30)
    first = _data(5, 4, 61)
    ids = mi.insert(first)                    # buffered, key (45, 5)
    # a megastep query builds + caches the ephemeral buffer index
    knn_join(_data(8, 4, 62), config=cfg, index=mi, megastep=True)
    assert mi._buffer_seg is not None
    mi.delete(ids)
    mi.compact()                              # next_id back to 40
    second = _data(5, 4, 63) + 100.0          # same key (45, 5), new rows
    mi.insert(second)
    q = second[:4] + 0.01
    host = knn_join(q, config=cfg, index=mi)
    assert np.all(host.distances[:, 0] < 1.0), "stale buffer index served"
    mega = knn_join(q, config=cfg, index=mi, megastep=True)
    np.testing.assert_array_equal(mega.distances, host.distances)
    np.testing.assert_array_equal(mega.indices, host.indices)


def test_compact_visits_jnp_matches_host_compaction():
    """The in-jit segment-sum-rank + flat-scatter compaction reproduces
    the host `compact_visit_mask` (schedule prefix, counts, repeat-last
    padding); all-empty rows get the fallback tile-0 visit."""
    import jax.numpy as jnp

    rng = np.random.default_rng(33)
    for trial in range(5):
        visit = rng.random((6, 11)) < 0.3
        visit[2] = False                       # an all-empty row
        sched_j, cnt_j = compact_visits_jnp(jnp.asarray(visit))
        sched_j, cnt_j = np.asarray(sched_j), np.asarray(cnt_j)
        host_visit = visit.copy()
        host_visit[~host_visit.any(axis=1), 0] = True   # documented fallback
        sched_h, cnt_h = compact_visit_mask(host_visit,
                                            max_visits=visit.shape[1])
        np.testing.assert_array_equal(cnt_j, cnt_h)
        np.testing.assert_array_equal(sched_j, sched_h)


def test_bench_regression_guard_logic():
    """The CI guard trips on >2× regressions of the guarded rows and on
    any nonzero steady-state host-sync count, and passes otherwise."""
    import importlib.util
    import pathlib

    path = (pathlib.Path(__file__).resolve().parent.parent
            / "benchmarks" / "guard.py")
    spec = importlib.util.spec_from_file_location("bench_guard", path)
    guard = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(guard)

    base = [
        {"bench": "kernel_streaming_vs_oneshot", "overhead_frac": 0.05},
        {"bench": "kernel_index_build_amortization",
         "plan_frac_of_batch": 0.10},
        {"bench": "kernel_megastep_vs_hostplanned", "speedup": 10.0,
         "device_steady_state_syncs": 0.0},
    ]
    ok = [
        {"bench": "kernel_streaming_vs_oneshot", "overhead_frac": 0.12},
        {"bench": "kernel_index_build_amortization",
         "plan_frac_of_batch": 0.15},
        {"bench": "kernel_megastep_vs_hostplanned", "speedup": 6.0,
         "device_steady_state_syncs": 0.0},
    ]
    assert guard.check(base, ok) == []
    bad_overhead = [dict(ok[0], overhead_frac=0.5)] + ok[1:]
    assert any("overhead_frac" in f for f in guard.check(base, bad_overhead))
    bad_speedup = ok[:2] + [dict(ok[2], speedup=1.0)]
    assert any("speedup" in f for f in guard.check(base, bad_speedup))
    bad_syncs = ok[:2] + [dict(ok[2], device_steady_state_syncs=3.0)]
    assert any("zero host syncs" in f for f in guard.check(base, bad_syncs))
    missing = ok[1:]   # a guarded row vanished from the sweep
    assert any("missing" in f for f in guard.check(base, missing))
    # a negative baseline (streaming beat one-shot outright) keeps a
    # sane absolute limit (the slack) instead of a nonsensical negative
    # 2x bound: small positive drift passes, a real regression fails
    neg = [dict(base[0], overhead_frac=-0.9)] + base[1:]
    drift = [dict(ok[0], overhead_frac=0.05)] + ok[1:]
    assert guard.check(neg, drift) == []
    assert any("overhead_frac" in f for f in guard.check(neg, bad_overhead))


def test_hypothesis_property_megastep_bitwise():
    pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis; tier-1 must "
        "still collect on clean environments without it")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def instance(draw):
        n_r = draw(st.integers(10, 90))
        n_s = draw(st.integers(40, 160))
        k = draw(st.integers(1, 8))
        bs = draw(st.integers(1, n_r))
        n_del = draw(st.integers(0, 12))
        seed = draw(st.integers(0, 2**16))
        return n_r, n_s, k, bs, n_del, seed

    @given(instance())
    @settings(max_examples=10, deadline=None)
    def prop(inst):
        n_r, n_s, k, bs, n_del, seed = inst
        rng = np.random.default_rng(seed)
        r = rng.normal(size=(n_r, 5)).astype(np.float32) * 3
        s = rng.normal(size=(n_s, 5)).astype(np.float32) * 3
        cfg = JoinConfig(k=k, n_pivots=16, n_groups=4, seed=seed)
        mi = MutableIndex.build(s, cfg, seal_threshold=1 << 30)
        if n_del and n_s - n_del >= k:
            mi.delete(rng.choice(n_s, n_del, replace=False))
        host = knn_join(r, config=cfg, index=mi)
        res = knn_join_batched(r, index=mi, config=cfg, batch_size=bs,
                               megastep=True)
        np.testing.assert_array_equal(res.distances, host.distances)
        np.testing.assert_array_equal(res.indices, host.indices)

    prop()
