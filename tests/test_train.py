"""Training substrate: optimizers, accumulation equivalence, checkpoints."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.models import ModelOptions, init_params
from repro.train import (
    OptConfig, TrainConfig, checkpoint, make_optimizer, make_train_step)

OPTS = ModelOptions(dtype=jnp.float32, remat=False, max_abs_pos=64)


def test_adamw_matches_reference_quadratic():
    """AdamW on f(x)=||x||²/2 follows the textbook trajectory."""
    cfg = OptConfig(name="adamw", lr=0.1, weight_decay=0.0, grad_clip=1e9,
                    warmup_steps=0, decay_steps=10**9, min_lr_ratio=1.0)
    init, update = make_optimizer(cfg)
    x = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    state = init(x)
    mu = np.zeros(3)
    nu = np.zeros(3)
    ref = np.asarray([1.0, -2.0, 3.0])
    for t in range(1, 6):
        g = ref.copy()          # grad of ||x||²/2 = x
        x, state, m = update({"w": jnp.asarray(g)}, state, x)
        mu = 0.9 * mu + 0.1 * g
        nu = 0.999 * nu + 0.001 * g * g
        mu_hat = mu / (1 - 0.9 ** t)
        nu_hat = nu / (1 - 0.999 ** t)
        ref = ref - 0.1 * mu_hat / (np.sqrt(nu_hat) + 1e-8)
        np.testing.assert_allclose(np.asarray(x["w"]), ref, rtol=1e-5)


def test_adafactor_converges_quadratic():
    cfg = OptConfig(name="adafactor", lr=0.1, weight_decay=0.0,
                    warmup_steps=0, decay_steps=10**9, min_lr_ratio=1.0)
    init, update = make_optimizer(cfg)
    x = {"w": jnp.ones((8, 4)) * 3.0}
    state = init(x)
    for _ in range(60):
        g = {"w": x["w"]}
        x, state, _ = update(g, state, x)
    assert float(jnp.abs(x["w"]).max()) < 0.5


def test_chunked_update_equals_unchunked():
    """lax.map-chunked optimizer == whole-leaf math (3D+ leaves)."""
    from repro.train.optimizer import adamw_update
    cfg = OptConfig(name="adamw", grad_clip=1e9, warmup_steps=0)
    key = jax.random.PRNGKey(0)
    big = {"w": jax.random.normal(key, (6, 8, 4))}       # chunked path
    flat = {"w": big["w"].reshape(6 * 8, 4)}             # unchunked path
    from repro.train.optimizer import adamw_init
    sb, sf = adamw_init(big), adamw_init(flat)
    g = jax.random.normal(jax.random.PRNGKey(1), (6, 8, 4))
    nb, _, _ = adamw_update({"w": g}, sb, big, cfg)
    nf, _, _ = adamw_update({"w": g.reshape(6 * 8, 4)}, sf, flat, cfg)
    np.testing.assert_allclose(np.asarray(nb["w"]).reshape(48, 4),
                               np.asarray(nf["w"]), rtol=1e-6)


def test_accum_equivalence():
    """accum=4 over 4 microbatches == accum=1 over the concatenated batch."""
    cfg = get_reduced("llama3.2-3b")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key, OPTS)
    b, t = 8, 16
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab)
    labs = jax.random.randint(jax.random.PRNGKey(4), (b, t), 0, cfg.vocab)

    ocfg = OptConfig(grad_clip=1e9)
    t1 = TrainConfig(opt=ocfg, accum=1, z_loss=0.0)
    t4 = TrainConfig(opt=ocfg, accum=4, z_loss=0.0)
    oi1, s1 = make_train_step(cfg, t1, OPTS)
    oi4, s4 = make_train_step(cfg, t4, OPTS)
    p1, _, m1 = s1(params, oi1(params), {"tokens": toks, "labels": labs})
    batch4 = {"tokens": toks.reshape(4, 2, t), "labels": labs.reshape(4, 2, t)}
    p4, _, m4 = s4(params, oi4(params), batch4)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(p1)
    l4 = jax.tree_util.tree_leaves(p4)
    for a, b_ in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-5, rtol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_reduced("qwen3-14b")
    params = init_params(cfg, jax.random.PRNGKey(5), OPTS)
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 7, {"params": params})
    avals = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), {"params": params})
    restored, step = checkpoint.restore(d, avals)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """Latest checkpoint survives a failed save (tmp dir + rename)."""
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, {"x": jnp.ones((4,))})
    assert checkpoint.latest_step(d) == 1
    # a crashed save leaves only a .tmp dir — latest_step must ignore it
    os.makedirs(os.path.join(d, ".tmp_ckpt_dead"), exist_ok=True)
    assert checkpoint.latest_step(d) == 1
    restored, _ = checkpoint.restore(
        d, {"x": jax.ShapeDtypeStruct((4,), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(4))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 0, {"x": jnp.ones((4,))})
    with pytest.raises(ValueError):
        checkpoint.restore(d, {"x": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_data_pipeline_stateless_replay():
    from repro.data import DataConfig, synthetic_lm_batch
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=9)
    a = synthetic_lm_batch(cfg, 123)
    b = synthetic_lm_batch(cfg, 123)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_lm_batch(cfg, 124)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shard disjointness: different shards → different streams
    s0 = synthetic_lm_batch(DataConfig(vocab=100, seq_len=16, global_batch=4,
                                       seed=9, n_shards=2, shard=0), 5)
    s1 = synthetic_lm_batch(DataConfig(vocab=100, seq_len=16, global_batch=4,
                                       seed=9, n_shards=2, shard=1), 5)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
