"""Fault tolerance: retries, speculation, elastic regrouping."""
import threading
import time

import numpy as np
import pytest

from repro.core import JoinConfig, brute_force_knn, knn_join, plan_join
from repro.distributed.fault import (
    GroupExecutor, grow_groups, regroup, shrink_groups)


def test_retry_on_transient_failure():
    fails = {3: 2, 5: 1}   # group -> number of times to fail first
    lock = threading.Lock()

    def group_fn(g):
        with lock:
            if fails.get(g, 0) > 0:
                fails[g] -= 1
                raise RuntimeError(f"injected failure in group {g}")
        return g * 10

    ex = GroupExecutor(max_retries=3, speculate=False, max_workers=2)
    runs = ex.run(group_fn, list(range(8)))
    assert all(r.done for r in runs.values())
    assert runs[3].result == 30 and runs[3].attempts >= 3
    assert runs[5].attempts >= 2


def test_permanent_failure_raises():
    def group_fn(g):
        if g == 2:
            raise RuntimeError("dead node")
        return g

    ex = GroupExecutor(max_retries=1, speculate=False, max_workers=2)
    with pytest.raises(RuntimeError):
        ex.run(group_fn, list(range(4)))


def test_speculative_execution_on_straggler():
    """A straggling group gets a backup attempt; first finisher wins."""
    slow_started = threading.Event()

    def group_fn(g):
        if g == 0 and not slow_started.is_set():
            slow_started.set()
            time.sleep(3.0)         # straggler's first attempt
        return g

    ex = GroupExecutor(max_retries=2, speculate=True, speculate_after=0.5,
                       max_workers=4)
    t0 = time.monotonic()
    runs = ex.run(group_fn, list(range(6)))
    elapsed = time.monotonic() - t0
    assert all(r.done for r in runs.values())
    assert runs[0].speculated
    assert elapsed < 2.9, "backup task should beat the 3s straggler"


def test_group_results_idempotent():
    """Re-executing a group yields identical results (MapReduce contract)."""
    rng = np.random.default_rng(0)
    r = rng.normal(size=(200, 4)).astype(np.float32)
    s = rng.normal(size=(300, 4)).astype(np.float32)
    cfg = JoinConfig(k=4, n_pivots=16, n_groups=4)
    a = knn_join(r, s, config=cfg)
    b = knn_join(r, s, config=cfg)
    np.testing.assert_array_equal(a.indices, b.indices)


@pytest.mark.parametrize("new_n", [2, 3])
def test_shrink_groups_exact(new_n):
    rng = np.random.default_rng(1)
    r = rng.normal(size=(250, 5)).astype(np.float32)
    s = rng.normal(size=(400, 5)).astype(np.float32)
    plan = plan_join(r, s, JoinConfig(k=5, n_pivots=20, n_groups=6))
    plan2 = shrink_groups(plan, new_n)
    assert plan2.n_groups == new_n
    res = knn_join(r, s, config=plan2.config, plan=plan2)
    bd, _ = brute_force_knn(r, s, 5)
    np.testing.assert_allclose(res.distances, bd, atol=1e-3)


@pytest.mark.parametrize("new_n", [8, 12])
def test_grow_groups_exact(new_n):
    rng = np.random.default_rng(2)
    r = rng.normal(size=(250, 5)).astype(np.float32)
    s = rng.normal(size=(400, 5)).astype(np.float32)
    plan = plan_join(r, s, JoinConfig(k=5, n_pivots=20, n_groups=4))
    plan2 = grow_groups(plan, new_n)
    assert plan2.n_groups >= plan.n_groups
    res = knn_join(r, s, config=plan2.config, plan=plan2)
    bd, _ = brute_force_knn(r, s, 5)
    np.testing.assert_allclose(res.distances, bd, atol=1e-3)


def test_regroup_noop():
    rng = np.random.default_rng(3)
    r = rng.normal(size=(100, 3)).astype(np.float32)
    plan = plan_join(r, r, JoinConfig(k=3, n_pivots=8, n_groups=4))
    assert regroup(plan, 4) is plan


def test_attempt_timeout_reissues_hung_group():
    """A hung group_fn attempt times out, counts as a failure, and is
    re-issued — the pool no longer blocks forever on one wedged task."""
    hung_once = threading.Event()
    release = threading.Event()     # set at test end: frees the zombie
                                    # thread so pytest exit isn't delayed

    def group_fn(g):
        if g == 1 and not hung_once.is_set():
            hung_once.set()
            release.wait(30.0)      # "forever" — well past the timeout
        return g * 10

    try:
        ex = GroupExecutor(max_retries=2, speculate=False, max_workers=4,
                           attempt_timeout=0.3)
        t0 = time.monotonic()
        runs = ex.run(group_fn, list(range(4)))
        elapsed = time.monotonic() - t0
    finally:
        release.set()
    assert all(r.done for r in runs.values())
    assert runs[1].result == 10 and runs[1].attempts >= 2
    assert elapsed < 5.0, "the hung attempt must not be waited out"


def test_attempt_timeout_exhausted_raises_with_attempt_counts():
    """Every attempt of one group hangs: the run fails with a TimeoutError
    cause and the error message reports per-group attempt counts."""
    release = threading.Event()

    def group_fn(g):
        if g == 0:
            release.wait(30.0)
            raise RuntimeError("released before completing")
        return g

    try:
        ex = GroupExecutor(max_retries=1, speculate=False, max_workers=4,
                           attempt_timeout=0.2)
        with pytest.raises(RuntimeError, match="attempt counts") as ei:
            ex.run(group_fn, [0, 1])
    finally:
        release.set()
    assert "group 0 failed after 2 attempts" in str(ei.value)
    assert isinstance(ei.value.__cause__, TimeoutError)


def test_attempt_timeout_none_keeps_blocking_semantics():
    """Default attempt_timeout=None: slow-but-finite work completes
    normally (no spurious re-issues)."""
    def group_fn(g):
        time.sleep(0.05)
        return g

    ex = GroupExecutor(max_retries=0, speculate=False, max_workers=2)
    runs = ex.run(group_fn, list(range(4)))
    assert all(r.done and r.attempts == 1 for r in runs.values())


def test_failure_message_includes_attempt_counts():
    """The exception-path RuntimeError also carries the per-group
    attempt counts (the satellite's observability ask)."""
    def group_fn(g):
        if g == 2:
            raise RuntimeError("dead node")
        return g

    ex = GroupExecutor(max_retries=1, speculate=False, max_workers=2)
    with pytest.raises(RuntimeError, match="attempt counts"):
        ex.run(group_fn, list(range(4)))
