"""Distributed (shard_map) join — runs in a subprocess with 8 forced host
devices so the main pytest process keeps the real (1-device) topology.

``distributed_knn_join`` is a compatibility wrapper over two SPMD
executions: the default ``reducer="sharded"`` routes L2 joins through
the sharded megastep (core.sharded — payload partitioned once, bitwise
the single-device megastep), and ``reducer="shuffle"`` keeps the
explicit Theorem-6-routed all_to_all + dense scan mapping.

Mesh construction goes through ``repro.core.jax_compat.make_mesh``: the
seed failure here was ``jax.sharding.AxisType`` not existing on the
installed JAX (it appeared after 0.4.x), not device-count flakiness.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core import JoinConfig, brute_force_knn, plan_join
    from repro.core.distributed import build_shuffle_spec, distributed_knn_join
    from repro.core.jax_compat import make_mesh
    from repro.core.megastep import MegastepEngine
    from repro.distributed.fault import regroup

    rng = np.random.default_rng(7)
    R = rng.normal(size=(400, 5)).astype(np.float32) * 2
    S = rng.normal(size=(700, 5)).astype(np.float32) * 2
    k = 5
    out = {}

    cfg = JoinConfig(k=k, n_pivots=32, n_groups=8, grouping="geometric")
    plan = plan_join(R, S, cfg)
    bd, bi = brute_force_knn(R, S, k)

    # default reducer="auto" resolves to the sharded megastep for L2
    mesh = make_mesh((8,), ("data",))
    res = distributed_knn_join(R, S, plan, mesh, axis="data")
    out["sharded_exact"] = bool(np.allclose(res.distances, bd, atol=1e-3))
    out["n_shards"] = int(res.stats.n_shards)
    out["replicas_sharded"] = int(res.stats.replicas_s)

    # pointer test: the wrapper's sharded route is *bitwise* the
    # single-device megastep over the same index/config — the wrapper
    # adds no numerics of its own
    import dataclasses
    cfg_t = dataclasses.replace(plan.query.config, tile_s=512, tile_r=128)
    d1, i1 = MegastepEngine(plan.index, cfg_t).join_batch(R)
    out["sharded_bitwise_single"] = bool(
        np.array_equal(res.distances, d1)
        and np.array_equal(res.indices, i1))

    # explicit shuffle reducer: the Theorem-6 all_to_all mapping, dense
    # per-device scan — must agree on distances
    res_d = distributed_knn_join(R, S, plan, mesh, axis="data",
                                 reducer="shuffle")
    out["shuffle_exact"] = bool(np.allclose(res_d.distances, bd, atol=1e-3))
    out["shuffle_n_shards"] = int(res_d.stats.n_shards)
    out["replicas"] = int(res_d.stats.replicas_s)
    out["tiles"] = [int(res_d.stats.tiles_visited),
                    int(res_d.stats.tiles_total)]

    # the sharded route flattens any device grid into a 1-D shard mesh;
    # the shuffle route runs SPMD over the joint axes
    mesh2 = make_mesh((4, 2), ("data", "model"))
    res2 = distributed_knn_join(R, S, plan, mesh2, axis=("data", "model"),
                                reducer="shuffle")
    out["two_axis_exact"] = bool(np.allclose(res2.distances, bd, atol=1e-3))
    res2s = distributed_knn_join(R, S, plan, mesh2, axis=("data", "model"))
    out["two_axis_sharded_bitwise"] = bool(
        np.array_equal(res2s.distances, d1))

    # elastic: shrink to 4 groups, run on a 4-device submesh (sharded is
    # group-count-invariant; shuffle needs groups == mesh extent)
    plan4 = regroup(plan, 4)
    mesh4 = make_mesh((4,), ("data",))
    res4 = distributed_knn_join(R, S, plan4, mesh4, axis="data")
    out["shrunk_exact"] = bool(np.allclose(res4.distances, bd, atol=1e-3))
    res4s = distributed_knn_join(R, S, plan4, mesh4, axis="data",
                                 reducer="shuffle")
    out["shrunk_shuffle_exact"] = bool(
        np.allclose(res4s.distances, bd, atol=1e-3))

    # capacity model must bound actual packing (Thm 7 load-bearing)
    spec = build_shuffle_spec(plan, 8)
    out["caps"] = [spec.cap_r_send, spec.cap_s_send]

    # SPMD phase-1 (psum/pmin/pmax-merged summaries) == host phase-1
    from repro.core import assign_and_summarize, select_pivots
    from repro.core.distributed import distributed_phase1
    pivots = select_pivots(S, 16, "random", seed=3)
    pd_, dd_, td_ = distributed_phase1(S, pivots, mesh, k=4)
    ph_, dh_, th_ = assign_and_summarize(S, pivots, k=4)
    fin = np.isfinite(th_.knn_dists)
    out["phase1_exact"] = bool(
        (pd_ == ph_).all() and np.allclose(dd_, dh_, atol=1e-5)
        and (td_.counts == th_.counts).all()
        and np.allclose(td_.knn_dists[fin], th_.knn_dists[fin], atol=1e-5))
    print(json.dumps(out))
""")


def test_distributed_join_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["sharded_exact"]
    assert out["sharded_bitwise_single"]
    assert out["n_shards"] == 8
    assert out["shuffle_exact"]
    assert out["shuffle_n_shards"] == 0  # shuffle path: single-device stats
    assert out["two_axis_exact"]
    assert out["two_axis_sharded_bitwise"]
    assert out["shrunk_exact"]
    assert out["shrunk_shuffle_exact"]
    assert out["phase1_exact"]
    assert out["caps"][0] >= 1 and out["caps"][1] >= 1
    # shuffle ships self+replication ≥ |S| once; sharded is resident —
    # every row lives on exactly one shard
    assert out["replicas"] >= 700
    assert out["replicas_sharded"] == 700
    # dense reducer accounting: every received tile is visited
    assert out["tiles"][0] == out["tiles"][1] > 0
