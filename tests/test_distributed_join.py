"""Distributed (shard_map) join — runs in a subprocess with 8 forced host
devices so the main pytest process keeps the real (1-device) topology.

Mesh construction goes through ``repro.core.jax_compat.make_mesh``: the
seed failure here was ``jax.sharding.AxisType`` not existing on the
installed JAX (it appeared after 0.4.x), not device-count flakiness.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core import JoinConfig, brute_force_knn, plan_join
    from repro.core.distributed import build_shuffle_spec, distributed_knn_join
    from repro.core.jax_compat import make_mesh
    from repro.distributed.fault import regroup

    rng = np.random.default_rng(7)
    R = rng.normal(size=(400, 5)).astype(np.float32) * 2
    S = rng.normal(size=(700, 5)).astype(np.float32) * 2
    k = 5
    out = {}

    cfg = JoinConfig(k=k, n_pivots=32, n_groups=8, grouping="geometric")
    plan = plan_join(R, S, cfg)
    bd, bi = brute_force_knn(R, S, k)

    mesh = make_mesh((8,), ("data",))
    res = distributed_knn_join(R, S, plan, mesh, axis="data")
    out["single_axis_exact"] = bool(np.allclose(res.distances, bd, atol=1e-3))
    out["replicas"] = int(res.stats.replicas_s)
    # pruned-schedule accounting: the reducers execute exactly the
    # compacted schedules, never the pruned remainder
    out["tiles"] = [int(res.stats.tiles_visited), int(res.stats.tiles_total)]

    # dense (unscheduled) reducer must agree bit-for-bit on distances
    res_d = distributed_knn_join(R, S, plan, mesh, axis="data",
                                 use_schedule=False)
    out["dense_exact"] = bool(np.allclose(res_d.distances, bd, atol=1e-3))

    mesh2 = make_mesh((4, 2), ("data", "model"))
    res2 = distributed_knn_join(R, S, plan, mesh2, axis=("data", "model"))
    out["two_axis_exact"] = bool(np.allclose(res2.distances, bd, atol=1e-3))

    # elastic: shrink to 4 groups, run on a 4-device submesh
    plan4 = regroup(plan, 4)
    mesh4 = make_mesh((4,), ("data",))
    res4 = distributed_knn_join(R, S, plan4, mesh4, axis="data")
    out["shrunk_exact"] = bool(np.allclose(res4.distances, bd, atol=1e-3))

    # capacity model must bound actual packing (Thm 7 load-bearing)
    spec = build_shuffle_spec(plan, 8)
    out["caps"] = [spec.cap_r_send, spec.cap_s_send]

    # SPMD phase-1 (psum/pmin/pmax-merged summaries) == host phase-1
    from repro.core import assign_and_summarize, select_pivots
    from repro.core.distributed import distributed_phase1
    pivots = select_pivots(S, 16, "random", seed=3)
    pd_, dd_, td_ = distributed_phase1(S, pivots, mesh, k=4)
    ph_, dh_, th_ = assign_and_summarize(S, pivots, k=4)
    fin = np.isfinite(th_.knn_dists)
    out["phase1_exact"] = bool(
        (pd_ == ph_).all() and np.allclose(dd_, dh_, atol=1e-5)
        and (td_.counts == th_.counts).all()
        and np.allclose(td_.knn_dists[fin], th_.knn_dists[fin], atol=1e-5))
    print(json.dumps(out))
""")


def test_distributed_join_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["single_axis_exact"]
    assert out["dense_exact"]
    assert out["two_axis_exact"]
    assert out["shrunk_exact"]
    assert out["phase1_exact"]
    assert out["caps"][0] >= 1 and out["caps"][1] >= 1
    assert out["replicas"] >= 700  # self+replication ≥ |S| shipped once
    assert 0 < out["tiles"][0] <= out["tiles"][1]
