"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret
mode (the kernel body runs in Python on CPU; on TPU the same body runs
compiled)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("nr,ns,dim,k", [
    (64, 128, 8, 4),
    (100, 257, 10, 7),     # non-tile-aligned
    (128, 512, 2, 10),     # paper's OSM dimensionality
    (33, 70, 54, 5),       # forest-width features
    (16, 2048, 16, 25),    # many tiles, k large
])
def test_distance_topk_shapes(nr, ns, dim, k):
    rng = np.random.default_rng(nr * ns)
    r = jnp.asarray(rng.normal(size=(nr, dim)).astype(np.float32))
    s = jnp.asarray(rng.normal(size=(ns, dim)).astype(np.float32))
    d, i = ops.distance_topk(r, s, k, bm=32, bn=64, impl="interpret")
    rd, ri = ref.distance_topk_ref(r, s, k)
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), atol=1e-4)
    assert (np.asarray(i) == np.asarray(ri)).mean() > 0.999


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distance_topk_dtypes(dtype):
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.normal(size=(48, 8))).astype(dtype)
    s = jnp.asarray(rng.normal(size=(96, 8))).astype(dtype)
    d, i = ops.distance_topk(r, s, 5, bm=16, bn=32, impl="interpret")
    rd, ri = ref.distance_topk_ref(r, s, 5)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), atol=tol)


def test_distance_topk_visit_mask():
    """Masked-out tiles must not contribute (bound-pruned schedule)."""
    from repro.kernels.distance_topk import distance_topk_pallas
    rng = np.random.default_rng(1)
    r = jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))
    s_near = rng.normal(size=(32, 4)).astype(np.float32)
    s_far = s_near + 100.0
    s = jnp.asarray(np.concatenate([s_near, s_far]))
    mask = jnp.asarray([[1, 0]], jnp.int8)   # skip the far tile
    d, i = distance_topk_pallas(r, s, 3, visit_mask=mask, bm=32, bn=32,
                                interpret=True)
    rd, ri = ref.distance_topk_ref(r, jnp.asarray(s_near), 3)
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), atol=1e-4)
    assert (np.asarray(i) < 32).all()


@pytest.mark.parametrize("nr,ns,dim,k", [
    (64, 128, 8, 4),
    (100, 257, 10, 7),     # non-tile-aligned
    (33, 70, 54, 5),       # forest-width features
    (16, 1024, 16, 25),    # many tiles, k large
])
def test_distance_topk_gather_full_schedule(nr, ns, dim, k):
    """With an everything-visits schedule the gather kernel must equal the
    dense reference exactly — scalar-prefetch plumbing changes nothing."""
    rng = np.random.default_rng(nr + ns)
    r = jnp.asarray(rng.normal(size=(nr, dim)).astype(np.float32))
    s = jnp.asarray(rng.normal(size=(ns, dim)).astype(np.float32))
    bm, bn = 32, 64
    nr_t, ns_t = -(-nr // bm), -(-ns // bn)
    sched = jnp.asarray(np.tile(np.arange(ns_t, dtype=np.int32), (nr_t, 1)))
    cnt = jnp.full((nr_t,), ns_t, jnp.int32)
    d, i = ops.distance_topk(r, s, k, schedule=sched, counts=cnt,
                             bm=bm, bn=bn, impl="gather_interpret")
    rd, ri = ops.distance_topk(r, s, k, impl="ref")
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), atol=1e-4)
    assert (np.asarray(i) == np.asarray(ri)).mean() > 0.999


@pytest.mark.parametrize("nr,ns,dim,k,seed", [
    (96, 300, 6, 5, 0),
    (50, 500, 3, 9, 1),
    (128, 640, 12, 16, 2),
])
def test_distance_topk_gather_pruned_schedule(nr, ns, dim, k, seed):
    """Random pruned schedules: kernel (interpret) == jnp oracle, and the
    repeat-last padding never leaks extra candidates."""
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(nr, dim)).astype(np.float32))
    s = jnp.asarray(rng.normal(size=(ns, dim)).astype(np.float32))
    bm, bn = 32, 64
    nr_t, ns_t = -(-nr // bm), -(-ns // bn)
    # random ragged visit lists, >= 1 tile each, ascending, repeat-pad
    counts = rng.integers(1, ns_t + 1, nr_t)
    width = int(counts.max())
    sched = np.zeros((nr_t, width), np.int32)
    for t in range(nr_t):
        picks = np.sort(rng.choice(ns_t, counts[t], replace=False))
        sched[t, :counts[t]] = picks
        sched[t, counts[t]:] = picks[-1]
    sched_j = jnp.asarray(sched)
    cnt_j = jnp.asarray(counts.astype(np.int32))
    d, i = ops.distance_topk(r, s, k, schedule=sched_j, counts=cnt_j,
                             bm=bm, bn=bn, impl="gather_interpret")
    rd, ri = ops.distance_topk(r, s, k, schedule=sched_j, counts=cnt_j,
                               bm=bm, bn=bn, impl="gather_ref")
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), atol=1e-4)
    fin = np.isfinite(np.asarray(rd))
    assert (np.asarray(i) == np.asarray(ri))[fin].mean() > 0.999


@pytest.mark.parametrize("seed,dead_frac", [(0, 0.2), (1, 0.6), (2, 0.95)])
def test_distance_topk_gather_alive_mask(seed, dead_frac):
    """The megastep's liveness mask: rows with alive == 0 (tombstones,
    per-segment padding in a concatenated layout) can never enter the
    top-k, and the kernel (interpret) matches the masked jnp oracle —
    including when live rows run short and (-1, +inf) slots appear."""
    rng = np.random.default_rng(seed)
    nr, ns, dim, k = 64, 320, 5, 8
    r = jnp.asarray(rng.normal(size=(nr, dim)).astype(np.float32))
    s = jnp.asarray(rng.normal(size=(ns, dim)).astype(np.float32))
    alive_np = (rng.random(ns) >= dead_frac).astype(np.float32)
    alive = jnp.asarray(alive_np)
    bm, bn = 32, 64
    nr_t, ns_t = -(-nr // bm), -(-ns // bn)
    sched = jnp.asarray(np.tile(np.arange(ns_t, dtype=np.int32), (nr_t, 1)))
    cnt = jnp.full((nr_t,), ns_t, jnp.int32)
    d, i = ops.distance_topk(r, s, k, schedule=sched, counts=cnt,
                             alive=alive, bm=bm, bn=bn,
                             impl="gather_interpret")
    rd, ri = ops.distance_topk(r, s, k, schedule=sched, counts=cnt,
                               alive=alive, bm=bm, bn=bn, impl="gather_ref")
    d, i, rd, ri = map(np.asarray, (d, i, rd, ri))
    np.testing.assert_allclose(d, rd, atol=1e-4)
    fin = np.isfinite(rd)
    assert (i == ri)[fin].mean() > 0.999
    # no dead row ever surfaces; short live sets pad with -1/+inf
    dead_ids = np.where(alive_np == 0)[0]
    assert not np.isin(i[fin], dead_ids).any()
    n_live = int(alive_np.sum())
    if n_live < k:
        assert (i[:, n_live:] == -1).all() and not np.isfinite(
            d[:, n_live:]).any()


def test_distance_topk_gather_dtypes():
    rng = np.random.default_rng(3)
    r = jnp.asarray(rng.normal(size=(48, 8))).astype(jnp.bfloat16)
    s = jnp.asarray(rng.normal(size=(96, 8))).astype(jnp.bfloat16)
    sched = jnp.asarray(np.arange(3, dtype=np.int32)[None].repeat(3, 0))
    cnt = jnp.full((3,), 3, jnp.int32)
    d, i = ops.distance_topk(r, s, 5, schedule=sched, counts=cnt,
                             bm=16, bn=32, impl="gather_interpret")
    rd, ri = ref.distance_topk_ref(r, s, 5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), atol=5e-2)


@pytest.mark.parametrize("n,m,dim", [(100, 16, 6), (257, 50, 12),
                                     (64, 7, 3)])
def test_assign(n, m, dim):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    p = jnp.asarray(rng.normal(size=(m, dim)).astype(np.float32))
    pid, dist = ops.assign(x, p, bm=32, bp=8, impl="interpret")
    rpid, rdist = ref.assign_ref(x, p)
    assert (np.asarray(pid) == np.asarray(rpid)).all()
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rdist), atol=1e-5)


@pytest.mark.parametrize("nq,nk,h,kvh,window,causal", [
    (64, 64, 4, 4, None, True),
    (64, 64, 4, 1, None, True),      # MQA
    (32, 96, 8, 2, None, True),      # GQA + decode-style offset
    (64, 64, 4, 2, 16, True),        # local window
    (48, 48, 2, 2, None, False),     # bidirectional (encoder)
])
def test_flash_attention(nq, nk, h, kvh, window, causal):
    rng = np.random.default_rng(nq + nk)
    q = jnp.asarray(rng.normal(size=(2, nq, h, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, nk, kvh, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, nk, kvh, 16)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            bq=16, bk=16, impl="interpret")
    ro = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ro), atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 8))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 8))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 8))).astype(jnp.bfloat16)
    o = ops.flash_attention(q, k, v, bq=16, bk=16, impl="interpret")
    ro = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ro, np.float32), atol=5e-2)
