"""Serving scheduler: admission control, deadlines, priority lanes,
coalescing, the degradation ladder, and fault-injected retries
(serve.scheduler + serve.faultinject)."""
import threading

import numpy as np
import pytest

from repro.core import JoinConfig, StreamJoinEngine, build_index, knn_join
from repro.serve import (
    Arrival, FaultPlan, InjectedFault, LoadReport, Priority,
    SchedulerConfig, ServeScheduler, VirtualClock, bursty_times,
    poisson_times, run_open_loop)

DIM = 12


def _data(n=600, seed=0):
    return np.random.default_rng(seed).normal(size=(n, DIM)).astype(
        np.float32)


def _engine(n=600, *, quantized=False, k=4, seed=0):
    s = _data(n, seed)
    cfg = JoinConfig(k=k, n_pivots=32, n_groups=4,
                     quantize="int8" if quantized else "none")
    return StreamJoinEngine(build_index(s, cfg), cfg,
                            megastep="auto", quantized=quantized), s, cfg


def test_exact_path_bitwise_oracle():
    """A scheduled request's result is the engine's own output verbatim
    — admission/coalescing must not perturb a single bit."""
    eng, s, cfg = _engine()
    sched = ServeScheduler(eng)
    q = _data(10, seed=1)
    t = sched.join_now(q)
    assert t.done and not t.degraded
    ref = knn_join(q, s, k=cfg.k, config=cfg)
    np.testing.assert_array_equal(t.distances, ref.distances)
    np.testing.assert_array_equal(t.indices, ref.indices)
    np.testing.assert_array_equal(t.recall_bound, np.ones(10, np.float32))


def test_coalescing_splits_back_per_request():
    """Ragged requests coalesce into one dispatch and split back — each
    ticket's rows get exactly their own one-shot results."""
    eng, s, cfg = _engine()
    sched = ServeScheduler(eng, config=SchedulerConfig(batch_rows=64))
    qs = [_data(n, seed=10 + n) for n in (3, 17, 8, 5)]
    tickets = [sched.submit(q) for q in qs]
    assert sched.queued_rows == 33
    n_resolved = sched.step()
    assert n_resolved == 33
    assert sched.stats.n_dispatches == 1         # one coalesced batch
    for q, t in zip(qs, tickets):
        assert t.done
        ref = knn_join(q, s, k=cfg.k, config=cfg)
        np.testing.assert_array_equal(t.distances, ref.distances)
        np.testing.assert_array_equal(t.indices, ref.indices)


def test_batch_rows_caps_coalescing():
    eng, _, _ = _engine()
    sched = ServeScheduler(eng, config=SchedulerConfig(batch_rows=16))
    for _ in range(4):
        sched.submit(_data(10, seed=3))
    sched.drain()
    # 10-row requests against a 16-row cap: never two whole requests in
    # one dispatch, but an oversized request alone would still dispatch
    assert sched.stats.n_dispatches == 4


def test_expired_requests_shed_before_dispatch():
    """The hard invariant: a request whose deadline passed is shed at
    batch formation — the engine never sees it."""
    eng, _, _ = _engine()
    vc = VirtualClock()
    sched = ServeScheduler(eng, clock=vc.now, sleep=vc.advance)
    t_live = sched.submit(_data(4, seed=4), deadline_s=10.0)
    t_dead = sched.submit(_data(4, seed=5), deadline_s=0.5)
    vc.advance(1.0)                    # t_dead expires in the queue
    sched.drain()
    assert t_live.done
    assert t_dead.status == "shed" and t_dead.reason == "deadline"
    assert t_dead.dispatched_at is None
    assert sched.stats.n_shed_deadline == 1
    assert sched.stats.n_expired_dispatched == 0


def test_priority_lanes_interactive_first():
    eng, _, _ = _engine()
    sched = ServeScheduler(eng, config=SchedulerConfig(batch_rows=8))
    t_bulk = sched.submit(_data(8, seed=6), priority=Priority.BULK)
    t_int = sched.submit(_data(8, seed=7), priority=Priority.INTERACTIVE)
    sched.step()
    assert t_int.done and t_bulk.status == "queued"   # bulk waits
    sched.step()
    assert t_bulk.done


def test_admission_bound_rejects_and_interactive_evicts_bulk():
    eng, _, _ = _engine()
    cfg = SchedulerConfig(batch_rows=8, max_queued_rows=16,
                          degrade_queued_rows=16, shed_queued_rows=16)
    sched = ServeScheduler(eng, config=cfg)
    t1 = sched.submit(_data(10, seed=8), priority=Priority.BULK)
    # bulk over the cap: explicit rejection, not an unbounded queue
    t2 = sched.submit(_data(10, seed=9), priority=Priority.BULK)
    assert t2.status == "rejected" and t2.reason == "queue_full"
    # interactive over the cap: evicts queued bulk to get in
    t3 = sched.submit(_data(12, seed=10), priority=Priority.INTERACTIVE)
    assert t3.status == "queued"
    assert t1.status == "shed" and t1.reason == "overload"
    sched.drain()
    assert t3.done
    assert sched.stats.n_rejected == 1 and sched.stats.n_shed_overload == 1
    assert sched.queued_rows == 0


def test_overload_sheds_bulk_at_watermark():
    eng, _, _ = _engine()
    cfg = SchedulerConfig(batch_rows=8, max_queued_rows=64,
                          degrade_queued_rows=8, shed_queued_rows=24)
    sched = ServeScheduler(eng, config=cfg)
    bulk = [sched.submit(_data(8, seed=20 + i), priority=Priority.BULK)
            for i in range(4)]
    t_int = sched.submit(_data(8, seed=30))
    sched.drain()
    assert t_int.done
    # backlog was 40 > 24: newest bulk shed down to the watermark
    assert [b.status for b in bulk] == ["done", "done", "shed", "shed"]
    assert all(b.reason == "overload" for b in bulk if b.status == "shed")


def test_degraded_mode_certified_recall_bounds():
    """Above the degrade watermark a quantized engine serves coarse-only:
    responses are flagged degraded and carry a *valid* certified recall
    bound — checked against the true top-k, not just well-formedness."""
    eng, s, cfg = _engine(quantized=True)
    sched = ServeScheduler(
        eng, config=SchedulerConfig(batch_rows=32, degrade_queued_rows=0))
    assert sched.degraded_engine is not None
    qs = [_data(8, seed=40 + i) for i in range(3)]
    tickets = [sched.submit(q) for q in qs]
    sched.drain()
    for q, t in zip(qs, tickets):
        assert t.done and t.degraded
        rb = t.recall_bound
        assert rb.shape == (8,) and (rb >= 0).all() and (rb <= 1).all()
        ref = knn_join(q, s, k=cfg.k, config=cfg)
        # the bound is a guarantee: true recall >= reported bound
        for i in range(q.shape[0]):
            true_set = set(ref.indices[i].tolist())
            got = [x for x in t.indices[i].tolist() if x >= 0]
            recall = len(true_set & set(got)) / cfg.k
            assert recall >= float(rb[i]) - 1e-6
        # degraded distances are still exact per reported neighbor
        np.testing.assert_allclose(
            t.distances, np.asarray(
                [[np.linalg.norm(q[i] - s[j]) if j >= 0 else np.inf
                  for j in t.indices[i]] for i in range(q.shape[0])]),
            rtol=1e-5, atol=1e-5)
    assert sched.stats.n_degraded_requests == 3
    assert sched.stats.join.n_degraded == 24
    assert sched.stats.join.recall_bound <= 1.0


def test_no_degraded_engine_serves_exact_under_pressure():
    eng, s, cfg = _engine()                    # fp32: no coarse tier
    sched = ServeScheduler(
        eng, config=SchedulerConfig(batch_rows=32, degrade_queued_rows=0))
    assert sched.degraded_engine is None
    t = sched.join_now(_data(5, seed=50))
    assert t.done and not t.degraded


def test_transient_fault_retried_onto_host_path():
    """An injected dispatch fault is retried with backoff onto the
    host-planned oracle — the result is still bitwise exact and the
    backoff slept through the injected sleep fn."""
    eng, s, cfg = _engine()
    slept = []
    sched = ServeScheduler(
        eng, config=SchedulerConfig(backoff_base_s=0.01, backoff_cap_s=0.04,
                                    max_retries=3),
        sleep=slept.append)
    q = _data(6, seed=60)
    with FaultPlan().fail("sched.dispatch", times=2) as plan:
        t = sched.join_now(q)
    assert t.done and t.attempts == 3
    assert plan.fired["sched.dispatch"] == 3
    assert sched.stats.n_retries == 2
    assert slept == [0.01, 0.02]              # capped exponential backoff
    ref = knn_join(q, s, k=cfg.k, config=cfg)
    np.testing.assert_array_equal(t.distances, ref.distances)
    np.testing.assert_array_equal(t.indices, ref.indices)


def test_payload_upload_fault_recovered():
    """A device-OOM-on-upload fault (megastep payload rebuild) recovers
    via the host-planned retry path — bitwise again."""
    eng, s, cfg = _engine()
    eng.megastep_engine._payload = None       # force a rebuild
    sched = ServeScheduler(eng, sleep=lambda _s: None)
    q = _data(6, seed=61)
    with FaultPlan().fail("megastep.payload_upload", times=1) as plan:
        t = sched.join_now(q)
    assert t.done and plan.fired["megastep.payload_upload"] == 1
    ref = knn_join(q, s, k=cfg.k, config=cfg)
    np.testing.assert_array_equal(t.distances, ref.distances)
    np.testing.assert_array_equal(t.indices, ref.indices)


def test_fetch_fault_recovered():
    eng, s, cfg = _engine()
    sched = ServeScheduler(eng, sleep=lambda _s: None)
    q = _data(6, seed=62)
    with FaultPlan().fail("megastep.fetch", times=1):
        t = sched.join_now(q)
    assert t.done and t.attempts == 2
    ref = knn_join(q, s, k=cfg.k, config=cfg)
    np.testing.assert_array_equal(t.distances, ref.distances)


def test_permanent_fault_marks_failed_not_hung():
    eng, _, _ = _engine()
    sched = ServeScheduler(
        eng, config=SchedulerConfig(max_retries=2), sleep=lambda _s: None)
    t = sched.submit(_data(4, seed=63))
    boom = RuntimeError("wedged device")
    with FaultPlan().fail("sched.dispatch", times=99, exc=boom):
        sched.drain()
    assert t.status == "failed" and "wedged device" in t.reason
    assert sched.stats.n_failed == 1 and sched.queued_rows == 0


def test_deadline_enforced_across_backoff():
    """A request that expires while the batch backs off between retries
    is shed, never re-dispatched — n_expired_dispatched stays 0."""
    eng, _, _ = _engine()
    vc = VirtualClock()
    sched = ServeScheduler(
        eng, config=SchedulerConfig(backoff_base_s=1.0, backoff_cap_s=1.0),
        clock=vc.now, sleep=vc.advance)     # backoff advances the clock
    t = sched.submit(_data(4, seed=64), deadline_s=0.5)
    with FaultPlan().fail("sched.dispatch", times=1):
        sched.drain()
    assert t.status == "shed" and t.reason == "deadline"
    assert t.attempts == 1                  # dispatched once, pre-fault
    assert sched.stats.n_expired_dispatched == 0


def test_submit_thread_safe_under_concurrent_consumer():
    eng, _, _ = _engine()
    sched = ServeScheduler(eng, config=SchedulerConfig(batch_rows=64))
    tickets, lock = [], threading.Lock()

    def producer(seed):
        for i in range(5):
            t = sched.submit(_data(7, seed=seed * 100 + i))
            with lock:
                tickets.append(t)

    sched.serve_forever()
    try:
        threads = [threading.Thread(target=producer, args=(s,))
                   for s in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        deadline = 50.0
        import time as _time
        t0 = _time.monotonic()
        while sched.has_work and _time.monotonic() - t0 < deadline:
            _time.sleep(0.01)
    finally:
        sched.shutdown()
    assert len(tickets) == 20 and all(t.done for t in tickets)
    assert sched.stats.rows_completed == 140


def test_open_loop_overload_smoke():
    """2× overload through the virtual clock: goodput nonzero, nothing
    expired was ever dispatched, every degraded response carries a
    bound, and the report's accounting adds up."""
    eng, _, _ = _engine(n=400, quantized=True)
    vc = VirtualClock()
    sched = ServeScheduler(
        eng,
        config=SchedulerConfig(batch_rows=32, degrade_queued_rows=64,
                               shed_queued_rows=96, max_queued_rows=128,
                               default_deadline_s=0.05),
        clock=vc.now, sleep=vc.advance)
    rng = np.random.default_rng(5)
    # service cost model: each step advances the virtual clock by a
    # fixed per-batch cost via the measure hook (deterministic — no
    # wall-clock flakiness in CI)
    fake = iter(np.arange(1, 100000) * 0.004)
    times = bursty_times(2000.0, 0.5, rng, burst=4)   # 2× of 32/0.004/2
    arrivals = [Arrival(t=float(t), rows=_data(8, seed=200 + j),
                        priority=(Priority.BULK if j % 3 == 0
                                  else Priority.INTERACTIVE))
                for j, t in enumerate(times)]
    tickets = run_open_loop(sched, arrivals, vc,
                            measure=lambda: next(fake))
    rep = LoadReport.from_tickets(tickets, sched.stats)
    assert rep.n_requests == len(arrivals)
    assert (rep.n_completed + rep.n_shed + rep.n_rejected + rep.n_failed
            == rep.n_requests)
    assert rep.n_completed > 0 and rep.goodput_rows_s > 0
    assert rep.n_shed + rep.n_rejected > 0          # overload engaged
    assert rep.n_expired_dispatched == 0            # the hard invariant
    assert np.isfinite(rep.p50_s) and rep.p50_s <= rep.p99_s <= rep.p999_s
    for t in tickets:
        if t.done and t.degraded:
            assert 0.0 <= float(t.recall_bound.min()) <= 1.0
    assert 0.0 <= rep.recall_bound_min <= 1.0


def test_arrival_generators():
    rng = np.random.default_rng(0)
    p = poisson_times(100.0, 2.0, rng)
    assert p.size > 0 and (np.diff(p) >= 0).all() and p[-1] < 2.0
    # mean rate within 3 sigma of nominal
    assert abs(p.size - 200) < 3 * np.sqrt(200)
    b = bursty_times(100.0, 2.0, rng, burst=8)
    assert b.size % 8 == 0 and (np.diff(b) >= 0).all()
    assert poisson_times(0.0, 2.0, rng).size == 0


def test_scheduler_config_validation():
    with pytest.raises(ValueError):
        SchedulerConfig(batch_rows=0)
    with pytest.raises(ValueError):
        SchedulerConfig(degrade_queued_rows=100, shed_queued_rows=50)
    with pytest.raises(ValueError):
        SchedulerConfig(shed_queued_rows=5000, max_queued_rows=4096)
    eng, _, _ = _engine(n=100)
    sched = ServeScheduler(eng)
    with pytest.raises(ValueError):
        sched.submit(np.zeros((0, DIM), np.float32))


def test_fault_plan_arming():
    plan = FaultPlan().fail("x", times=1)
    with pytest.raises(InjectedFault):
        with plan:
            from repro.serve import faultinject
            faultinject.fire("x")
    # outside the with block sites are dead
    from repro.serve import faultinject
    faultinject.fire("x")
    with FaultPlan():
        with pytest.raises(RuntimeError):
            with FaultPlan():                  # double-arm rejected
                pass


def test_knn_logits_through_scheduler():
    """The kNN-LM path accepts a scheduler: same logits as the direct
    path when unloaded; a rejected batch degrades to the log floor."""
    from repro.serve import Datastore, KnnLMConfig, knn_logits

    rng = np.random.default_rng(9)
    keys = rng.normal(size=(400, DIM)).astype(np.float32)
    vals = rng.integers(0, 32, 400).astype(np.int32)
    store = Datastore.build(keys, vals, k=4, n_pivots=32, n_groups=4)
    kcfg = KnnLMConfig(k=4)
    q = rng.normal(size=(5, DIM)).astype(np.float32)
    direct = knn_logits(q, store, kcfg, vocab=32)
    sched = ServeScheduler.for_datastore(store)
    via = knn_logits(q, store, kcfg, vocab=32, scheduler=sched)
    np.testing.assert_array_equal(direct, via)
    # a scheduler that rejects everything -> LM-only fallback rows
    full = ServeScheduler.for_datastore(
        store, config=SchedulerConfig(max_queued_rows=2,
                                      degrade_queued_rows=1,
                                      shed_queued_rows=2))
    lg = knn_logits(q, store, kcfg, vocab=32, scheduler=full)
    np.testing.assert_allclose(lg, np.log(1e-9))
