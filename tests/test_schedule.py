"""The pruned tile schedule: compaction invariants, exactness of the
schedule-driven engines, and the tiles-visited accounting contract."""
import numpy as np
import pytest

from repro.core import JoinConfig, brute_force_knn, knn_join, plan_join
from repro.core.join import join_group_dense, join_group_gather
from repro.core.schedule import build_tile_schedule, compact_visit_mask


from repro.data import clustered_like


def _clustered(n, dim, seed, n_centers=8):
    return clustered_like(n, dim, seed, n_centers=n_centers)


def test_compact_visit_mask_invariants():
    rng = np.random.default_rng(0)
    visit = rng.random((13, 9)) < 0.4
    visit[:, 0] |= ~visit.any(axis=1)          # no empty rows
    sched, counts = compact_visit_mask(visit)
    assert (counts == visit.sum(axis=1)).all()
    assert sched.shape[1] == counts.max()
    for t in range(visit.shape[0]):
        c = counts[t]
        row = sched[t]
        assert (np.sort(row[:c]) == row[:c]).all()          # ascending
        assert set(row[:c]) == set(np.flatnonzero(visit[t]))
        assert (row[c:] == row[c - 1]).all()                # repeat-pad
    # widening keeps repeat-pad semantics
    wide, _ = compact_visit_mask(visit, max_visits=visit.shape[1] + 3)
    assert wide.shape[1] == visit.shape[1] + 3
    assert (wide[:, :sched.shape[1]] == sched).all()


def test_compact_visit_mask_rejects_empty_rows():
    visit = np.zeros((2, 4), bool)
    visit[0, 1] = True
    with pytest.raises(ValueError):
        compact_visit_mask(visit)


def _schedule_setup(n_r=1500, n_s=2500, dim=6, k=7, bm=64, bn=128):
    r = _clustered(n_r, dim, seed=0)
    s = _clustered(n_s, dim, seed=1)
    cfg = JoinConfig(k=k, n_pivots=24, n_groups=1, seed=3,
                     tile_r=bm, tile_s=bn)
    plan = plan_join(r, s, cfg)
    ord_r = np.argsort(plan.r_part, kind="stable")
    ord_s = np.lexsort((plan.s_dist, plan.s_part))
    rr, ss = r[ord_r], s[ord_s]
    sched = build_tile_schedule(
        rr, plan.r_part[ord_r], plan.s_part[ord_s], plan.s_dist[ord_s],
        plan.pivots, plan.pivd, plan.theta, bm=bm, bn=bn,
        knn_dists=plan.t_s.knn_dists, k=k)
    return rr, ss, np.arange(n_s, dtype=np.int64)[ord_s], k, sched


def test_schedule_exact_and_pruning():
    """Scheduled engine == dense engine, while visiting strictly fewer
    tiles on clustered data."""
    rr, ss, sids, k, sched = _schedule_setup()
    dd, di = join_group_dense(rr, ss, sids, k,
                              tile_r=sched.bm, tile_s=sched.bn)
    gd, gi = join_group_gather(rr, ss, sids, k, sched)
    np.testing.assert_allclose(gd, dd, atol=1e-4)
    assert (gi == di).mean() > 0.999
    assert sched.n_visits < sched.nr_tiles * sched.ns_tiles
    assert 0.0 < sched.density < 1.0


def test_gather_kernel_follows_schedule():
    """The interpret-mode Pallas gather kernel on a real plan-derived
    schedule equals its jnp oracle and the host engine."""
    import jax.numpy as jnp
    from repro.kernels import ops

    rr, ss, sids, k, sched = _schedule_setup(n_r=200, n_s=400, bm=32, bn=64)
    kd, ki = ops.distance_topk(
        jnp.asarray(rr), jnp.asarray(ss), k,
        schedule=jnp.asarray(sched.schedule),
        counts=jnp.asarray(sched.counts),
        bm=sched.bm, bn=sched.bn, impl="gather_interpret")
    od, oi = ops.distance_topk(
        jnp.asarray(rr), jnp.asarray(ss), k,
        schedule=jnp.asarray(sched.schedule),
        counts=jnp.asarray(sched.counts),
        bm=sched.bm, bn=sched.bn, impl="gather_ref")
    # clustered data sits at ±20, so the ‖r‖²−2rsᵀ+‖s‖² form carries
    # O(‖x‖²·eps) cancellation noise — tolerance reflects that
    np.testing.assert_allclose(np.asarray(kd), np.asarray(od), atol=1e-3)
    hd, hi = join_group_gather(rr, ss, sids, k, sched)
    np.testing.assert_allclose(np.asarray(kd), hd, atol=1e-3)
    # local kernel ids map to the host engine's global ids
    assert (sids[np.asarray(ki)] == hi).mean() > 0.999


def test_knn_join_gather_reducer_exact_and_accounted():
    """End-to-end gather path: exact vs brute force, and tiles_visited
    equals the schedule length (pruned tiles provably never execute)."""
    r = _clustered(1200, 6, seed=0)
    s = _clustered(2000, 6, seed=1)
    k = 7
    cfg = JoinConfig(k=k, n_pivots=24, n_groups=4, reducer="gather",
                     tile_r=64, tile_s=128, seed=3)
    res = knn_join(r, s, config=cfg)
    bd, bi = brute_force_knn(r, s, k)
    np.testing.assert_allclose(res.distances, bd, atol=1e-4)
    assert (res.indices == bi).mean() > 0.999
    st = res.stats
    assert 0 < st.tiles_visited < st.tiles_total

    # re-derive every group's schedule: the stats must be exactly the sum
    # of schedule lengths — nothing else ran
    plan = plan_join(r, s, cfg)
    total = 0
    g_r = plan.group_of_r()
    for g in range(plan.n_groups):
        r_sel = np.where(g_r == g)[0]
        if r_sel.size == 0:
            continue
        s_sel = np.where(plan.s_replica_mask(g))[0]
        ord_r = np.argsort(plan.r_part[r_sel], kind="stable")
        ord_s = np.lexsort((plan.s_dist[s_sel], plan.s_part[s_sel]))
        sched = build_tile_schedule(
            r[r_sel][ord_r], plan.r_part[r_sel][ord_r],
            plan.s_part[s_sel][ord_s], plan.s_dist[s_sel][ord_s],
            plan.pivots, plan.pivd, plan.theta,
            bm=cfg.tile_r, bn=cfg.tile_s,
            knn_dists=plan.t_s.knn_dists, k=k)
        total += sched.n_visits
    assert st.tiles_visited == total


def test_gather_matches_pruned_and_dense_reducers():
    r = _clustered(800, 5, seed=4)
    s = _clustered(1000, 5, seed=5)
    results = {}
    for reducer in ("dense", "pruned", "gather"):
        cfg = JoinConfig(k=5, n_pivots=16, n_groups=3, reducer=reducer,
                         tile_r=64, tile_s=128, seed=3)
        results[reducer] = knn_join(r, s, config=cfg)
    np.testing.assert_allclose(results["gather"].distances,
                               results["dense"].distances, atol=1e-4)
    np.testing.assert_allclose(results["gather"].distances,
                               results["pruned"].distances, atol=1e-4)
