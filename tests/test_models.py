"""Per-arch smoke tests (reduced configs): forward/train/decode on CPU,
output shapes + finiteness, decode-vs-forward consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import (
    ModelOptions, count_params, forward, init_cache, init_params)
from repro.train import TrainConfig, cross_entropy, make_train_step

OPTS = ModelOptions(dtype=jnp.float32, remat=False, max_abs_pos=96)


def _inputs(cfg, b, t, key):
    kw = {}
    if cfg.n_enc_layers:
        kw["enc_frames"] = jax.random.normal(
            key, (b, cfg.enc_len, cfg.d_model))
    if cfg.n_vision_embeds:
        kw["vision_embeds"] = jax.random.normal(
            key, (b, cfg.n_vision_embeds, cfg.d_model))
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, OPTS)
    assert count_params(params) > 0
    b, t = 2, 24
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab)
    logits, _ = forward(params, cfg, toks, opts=OPTS, mode="train",
                        **_inputs(cfg, b, t, key))
    assert logits.shape == (b, t, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One optimizer step on CPU: loss finite, params change, no NaNs."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key, OPTS)
    tcfg = TrainConfig(accum=1, z_loss=1e-4)
    opt_init, step = make_train_step(cfg, tcfg, OPTS)
    opt = opt_init(params)
    b, t = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (b, t), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, t), 0, cfg.vocab),
        **_inputs(cfg, b, t, key),
    }
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    before = jax.tree_util.tree_leaves(params)[0]
    after = jax.tree_util.tree_leaves(new_params)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))
    leaves = jax.tree_util.tree_leaves(new_params)
    assert all(bool(jnp.isfinite(x).all()) for x in leaves)


@pytest.mark.parametrize("arch", ["granite-34b", "qwen3-14b",
                                  "deepseek-v2-lite-16b", "xlstm-350m",
                                  "recurrentgemma-9b", "llama3.2-3b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode with cache == one-shot forward logits."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key, OPTS)
    b, t = 2, 12
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, toks, opts=OPTS, mode="train")
    cache = init_cache(cfg, b, t + 4, OPTS)
    outs = []
    for i in range(t):
        lg, cache = forward(params, cfg, toks[:, i:i + 1], cache=cache,
                            opts=OPTS, mode="decode")
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_whisper_decode_matches_forward():
    cfg = get_reduced("whisper-small")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key, OPTS)
    b, t = 2, 10
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab)
    frames = jax.random.normal(key, (b, cfg.enc_len, cfg.d_model))
    full_logits, _ = forward(params, cfg, toks, enc_frames=frames,
                             opts=OPTS, mode="train")
    cache = init_cache(cfg, b, t + 2, OPTS)
    outs = []
    for i in range(t):
        lg, cache = forward(params, cfg, toks[:, i:i + 1], cache=cache,
                            enc_frames=frames, opts=OPTS, mode="decode")
        outs.append(np.asarray(lg[:, 0]))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_local_attention_window_matches_ref():
    """recurrentgemma's ring-buffer decode == windowed full forward."""
    cfg = get_reduced("recurrentgemma-9b")
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key, OPTS)
    b, t = 1, 24   # > window (16) to exercise the ring wrap
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, toks, opts=OPTS, mode="train")
    cache = init_cache(cfg, b, t + 2, OPTS)
    outs = []
    for i in range(t):
        lg, cache = forward(params, cfg, toks[:, i:i + 1], cache=cache,
                            opts=OPTS, mode="decode")
        outs.append(np.asarray(lg[:, 0]))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_mrope_positions_change_output():
    cfg = get_reduced("qwen2-vl-7b")
    key = jax.random.PRNGKey(5)
    params = init_params(cfg, key, OPTS)
    b, t = 1, 8
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab)
    base = jnp.broadcast_to(jnp.arange(t)[None, None], (3, b, t))
    shifted = base.at[1].add(5)   # different spatial positions
    l1, _ = forward(params, cfg, toks, positions=base, opts=OPTS)
    l2, _ = forward(params, cfg, toks, positions=shifted, opts=OPTS)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_loss_decreases_tiny_model():
    """End-to-end sanity: 30 steps on learnable synthetic data."""
    from repro.data import DataConfig, synthetic_lm_batch
    cfg = get_reduced("llama3.2-3b")
    key = jax.random.PRNGKey(6)
    params = init_params(cfg, key, OPTS)
    from repro.train import OptConfig
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=5,
                                     decay_steps=100), accum=1)
    opt_init, step = make_train_step(cfg, tcfg, OPTS)
    opt = opt_init(params)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    jstep = jax.jit(step)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic_lm_batch(dcfg, i).items()}
        params, opt, m = jstep(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
