"""Quantized index tier (repro.quant): ε-soundness of the int8 coarse
pass, bitwise equality with the fp32 oracle across
{SIndex, MutableIndex+tombstones} × {one-shot, batched, megastep-mode},
the certification/fallback safety net, memory accounting, and the
seal/compact rebuild contract."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    JoinConfig, JoinStats, MutableIndex, StreamJoinEngine, brute_force_knn,
    build_index, knn_join, knn_join_batched)
from repro.quant import (
    QuantMegastepEngine, quantize_queries_np, quantize_rows)


def _data(n, dim, seed, scale=3.0, offset=0.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, dim)).astype(np.float32) * scale
            + np.float32(offset))


def _mutable_with_history(dim=5, seed=0, k=6):
    """base + sealed delta + unsealed buffer + more-than-k tombstones."""
    rng = np.random.default_rng(seed)
    cfg = JoinConfig(k=k, n_pivots=16, n_groups=4, seed=seed)
    mi = MutableIndex.build(_data(700, dim, seed + 1), cfg,
                            seal_threshold=300)
    mi.insert(_data(340, dim, seed + 2))          # seals a delta segment
    mi.insert(_data(90, dim, seed + 3))           # stays in the buffer
    mi.delete(rng.choice(700, 3 * k + 20, replace=False))
    return mi, cfg


# ---------------------------------------------------------------------------
# the ε lemma


def test_quantize_rows_roundtrip_and_bounds():
    rows = _data(1000, 12, 0, scale=2.5, offset=1.0)
    qr = quantize_rows(rows, bn=128)
    assert qr.q.dtype == np.int8 and np.abs(qr.q.astype(int)).max() <= 127
    assert qr.eps.dtype == np.float16 and np.isfinite(
        qr.eps.astype(np.float32)).all()
    # stored ε (rounded up into f16) dominates the exact f64 error
    recon = qr.dequantized().astype(np.float64)[:1000]
    err = np.sqrt(((rows.astype(np.float64) - recon) ** 2).sum(1))
    assert (qr.eps.astype(np.float64)[:1000] >= err).all()
    # padding rows quantize to exact zeros
    assert (qr.q[1000:] == 0).all() and (qr.eps[1000:] == 0).all()


def _soundness_case(dim, n_s, n_q, scale, offset, seed):
    """One instance of the ε lemma: geometric bound exact in f64, engine
    lower bound certified against the true distance."""
    s = _data(n_s, dim, seed, scale=scale, offset=offset)
    q = _data(n_q, dim, seed + 1, scale=scale, offset=offset)
    bn = 32
    qr = quantize_rows(s, bn)
    qi, qs, qe = quantize_queries_np(q)
    # geometric lemma, exact in f64: |d(q, ŝ) − d(q, s)| ≤ ε_s and
    # |d(q̂, ŝ) − d(q, s)| ≤ ε_s + ε_q
    s64 = s.astype(np.float64)
    shat = qr.dequantized().astype(np.float64)[:n_s]
    qhat = (qi.astype(np.float64) * qs.astype(np.float64)[:, None])
    d_true = np.sqrt(
        ((q.astype(np.float64)[:, None] - s64[None]) ** 2).sum(-1))
    d_shat = np.sqrt(
        ((q.astype(np.float64)[:, None] - shat[None]) ** 2).sum(-1))
    d_qhat = np.sqrt(((qhat[:, None] - shat[None]) ** 2).sum(-1))
    eps_s = qr.eps.astype(np.float64)[:n_s]
    assert (np.abs(d_shat - d_true) <= eps_s[None, :] + 1e-9).all()
    both = eps_s[None, :] + qe.astype(np.float64)[:, None]
    assert (np.abs(d_qhat - d_true) <= both + 1e-9).all()
    # engine formula, f32 end to end (the kernel's shared tile
    # function): the selection key is a certified lower bound
    import jax.numpy as jnp
    from repro.kernels.quant_topk import coarse_lb_tile
    n_pad = qr.q.shape[0]
    lb = np.concatenate(
        [np.asarray(coarse_lb_tile(
            jnp.asarray(qi), jnp.asarray(qs), jnp.asarray(qe),
            jnp.asarray(qr.q[t * bn:(t + 1) * bn]),
            jnp.asarray(qr.scales[t]),
            jnp.asarray(qr.eps[t * bn:(t + 1) * bn], jnp.float32)))
         for t in range(n_pad // bn)], axis=1)[:, :n_s]
    assert (lb <= d_true + 1e-6).all()


@pytest.mark.parametrize("dim,n_s,n_q,scale,offset,seed", [
    (2, 64, 16, 1.0, 0.0, 0),
    (8, 200, 40, 3.0, 0.0, 1),
    (12, 150, 20, 0.2, 5.0, 2),
    (16, 96, 8, 25.0, -40.0, 3),       # far-from-origin: big scales/ε
    (5, 33, 7, 1e-3, 0.0, 4),          # near-degenerate spread
])
def test_coarse_distance_soundness_seeded(dim, n_s, n_q, scale, offset,
                                          seed):
    _soundness_case(dim, n_s, n_q, scale, offset, seed)


def test_coarse_distance_soundness_lemma_hypothesis():
    """|d_coarse − d| ≤ ε_s + ε_q + ε_num swept over random instances —
    the bound the shortlist keys and the θ inflation rest on."""
    pytest.importorskip(
        "hypothesis", reason="ε-soundness sweep needs hypothesis; the "
        "seeded grid above still runs without it")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(2, 16), st.integers(16, 200), st.integers(1, 40),
           st.floats(0.1, 30.0), st.floats(-50.0, 50.0),
           st.integers(0, 2**16))
    @settings(max_examples=40, deadline=None)
    def run(dim, n_s, n_q, scale, offset, seed):
        _soundness_case(dim, n_s, n_q, scale, offset, seed)

    run()


# ---------------------------------------------------------------------------
# bitwise equality with the fp32 oracle


def test_quant_bitwise_sindex_oneshot():
    r = _data(217, 6, 0)
    s = _data(530, 6, 1)
    cfg = JoinConfig(k=7, n_pivots=24, n_groups=5, seed=3)
    index = build_index(s, cfg)
    host = knn_join(r, config=cfg, index=index)
    bd, _ = brute_force_knn(r, s, 7)
    np.testing.assert_allclose(host.distances, bd, atol=1e-4)
    quant = knn_join(r, config=cfg, index=index, quantized=True)
    np.testing.assert_array_equal(quant.distances, host.distances)
    np.testing.assert_array_equal(quant.indices, host.indices)
    assert quant.indices.dtype == np.int64


def test_quant_bitwise_batched_any_split():
    r = _data(300, 5, 4)
    s = _data(620, 5, 5)
    cfg = JoinConfig(k=6, n_pivots=20, n_groups=4, seed=1)
    index = build_index(s, cfg)
    one = knn_join(r, config=cfg, index=index)
    for bs in (37, 128, 300):
        res = knn_join_batched(r, index=index, config=cfg, batch_size=bs,
                               quantized=True)
        np.testing.assert_array_equal(res.distances, one.distances)
        np.testing.assert_array_equal(res.indices, one.indices)


def test_quant_bitwise_mutable_tombstones():
    mi, cfg = _mutable_with_history()
    r = _data(180, 5, 9)
    hd, hi = mi.join_batch(r, config=cfg)
    stats = JoinStats()
    qd, qi = QuantMegastepEngine(mi, cfg).join_batch(r, stats=stats)
    np.testing.assert_array_equal(qd, hd)
    np.testing.assert_array_equal(qi, hi)
    assert stats.n_segments == 3 and stats.n_tombstones > cfg.k


def test_quant_stream_engine_matches_megastep_engine():
    """The megastep-mode cell of the equality matrix: the quantized
    engine inside StreamJoinEngine == the fp32 megastep engine, batch by
    batch, over a mutating index."""
    mi, cfg = _mutable_with_history(seed=3)
    q_eng = StreamJoinEngine(mi, cfg, quantized=True)
    m_eng = StreamJoinEngine(mi, cfg, megastep=True)
    for seed in (20, 21):
        r = _data(100, 5, seed)
        qd, qi = q_eng.join_batch(r)
        md, mi_ids = m_eng.join_batch(r)
        np.testing.assert_array_equal(qd, md)
        np.testing.assert_array_equal(qi, mi_ids)
    mi.insert(_data(50, 5, 40))        # mutation picked up via version
    mi.delete([10, 11])
    r = _data(64, 5, 22)
    qd, qi = q_eng.join_batch(r)
    md, mi_ids = m_eng.join_batch(r)
    np.testing.assert_array_equal(qd, md)
    np.testing.assert_array_equal(qi, mi_ids)


@pytest.mark.parametrize("impl", ["pallas_interpret", "ref_sched"])
def test_quant_schedule_driven_impls_end_to_end(impl):
    """The real coarse kernel body (scalar-prefetch schedule, int8 dot,
    VMEM sorted run) through the interpreter, and its schedule-consuming
    scan twin — both == the oracle, bitwise."""
    r = _data(150, 8, 10)
    s = _data(700, 8, 11)
    cfg = JoinConfig(k=6, n_pivots=16, n_groups=4, seed=3,
                     tile_s=128, tile_r=64)
    index = build_index(s, cfg)
    host = knn_join(r, config=cfg, index=index)
    stats = JoinStats()
    d, i = QuantMegastepEngine(index, cfg, impl=impl) \
        .join_batch(r, stats=stats)
    np.testing.assert_array_equal(d, host.distances)
    np.testing.assert_array_equal(i, host.indices)


def test_quant_kernel_shortlist_matches_ref_bounds():
    """Op-level: the interpret kernel's shortlist lower bounds agree
    with the dense jnp oracle's for the same (full) schedule."""
    import jax.numpy as jnp
    from repro.kernels import ops

    bn, bm, mp = 64, 32, 8
    s = _data(256, 7, 12)
    q = _data(64, 7, 13)
    qr = quantize_rows(s, bn)
    qi, qs, qe = quantize_queries_np(q)
    ns_tiles = qr.q.shape[0] // bn
    alive = (np.arange(qr.q.shape[0]) < s.shape[0]).astype(np.float32)
    theta = np.full((q.shape[0],), np.inf, np.float32)
    sched = np.broadcast_to(np.arange(ns_tiles, dtype=np.int32),
                            (q.shape[0] // bm, ns_tiles)).copy()
    cnt = np.full((q.shape[0] // bm,), ns_tiles, np.int32)
    args = (jnp.asarray(qi), jnp.asarray(qs), jnp.asarray(qe),
            jnp.asarray(theta), jnp.asarray(qr.q), jnp.asarray(qr.scales),
            jnp.asarray(qr.eps), jnp.asarray(alive), mp)
    lb_ref, pos_ref = ops.quant_coarse_topk(*args, bn=bn, impl="ref")
    lb_k, pos_k = ops.quant_coarse_topk(
        *args, schedule=jnp.asarray(sched), counts=jnp.asarray(cnt),
        bm=bm, bn=bn, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(lb_k), np.asarray(lb_ref),
                               rtol=1e-5, atol=1e-5)


def test_quant_duplicate_rows_tie_contract():
    """Exact duplicate rows (a kNN-LM store ingesting identical
    contexts): distances stay bitwise the oracle's; where ids differ
    they must be exact float ties — the same caveat every engine pair
    in this codebase carries (core.segments docstring)."""
    rng = np.random.default_rng(0)
    base = _data(300, 6, 1)
    dup = np.repeat(base[:1], 40, axis=0)        # 40 copies of one row
    s = np.concatenate([base, dup], axis=0)
    r = np.concatenate([_data(60, 6, 2),
                        base[:1] + rng.normal(scale=1e-3, size=(20, 6))
                        .astype(np.float32)])
    cfg = JoinConfig(k=10, n_pivots=16, n_groups=4, seed=3)
    index = build_index(s, cfg)
    host = knn_join(r, config=cfg, index=index)
    # regression (bounds.pad_theta): duplicated-at-a-pivot rows make the
    # Thm-3 θ exactly tight, and the unpadded ring test dropped them on
    # small batches (batch-dependent results — a latent exactness bug
    # this dataset exposed in the host oracle itself, pre-quantization)
    bd, _ = brute_force_knn(r, s, cfg.k)
    np.testing.assert_allclose(host.distances, bd, atol=1e-4)
    for j in (36, 61):
        one = knn_join(r[j:j + 1], config=cfg, index=index)
        np.testing.assert_array_equal(one.distances[0], host.distances[j])
    for slack in (None, 0):
        quant = knn_join(r, config=cfg, index=index, quantized=True) \
            if slack is None else None
        if quant is None:
            stats = JoinStats()
            d, i = QuantMegastepEngine(index, cfg, slack=0).join_batch(
                r, stats=stats)
        else:
            d, i = quant.distances, quant.indices
        np.testing.assert_array_equal(d, host.distances)
        diff = i != host.indices
        # any id disagreement sits at an exactly-tied distance
        assert (d[diff] == host.distances[diff]).all()


# ---------------------------------------------------------------------------
# certification / fallback safety net


def test_quant_fallback_stays_exact():
    """Loosened-but-still-sound ε (inflation keeps every bound valid)
    must break certification, and the fallback must keep the output
    bitwise the oracle's — exactness is unconditional."""
    r = _data(150, 8, 10)
    s = _data(700, 8, 11)
    cfg = JoinConfig(k=6, n_pivots=16, n_groups=4, seed=3,
                     tile_s=128, tile_r=64)
    index = build_index(s, cfg)
    host = knn_join(r, config=cfg, index=index)
    qr = index.ensure_quant(cfg.tile_s)
    qr.eps = (qr.eps.astype(np.float32) * 50 + 5.0).astype(np.float16)
    stats = JoinStats()
    d, i = QuantMegastepEngine(index, cfg, slack=0).join_batch(
        r, stats=stats)
    assert stats.n_quant_fallback == r.shape[0]
    np.testing.assert_array_equal(d, host.distances)
    np.testing.assert_array_equal(i, host.indices)


def test_quant_slack_config_plumbs_through():
    cfg = JoinConfig(k=5, quant_slack=11)
    eng = QuantMegastepEngine(build_index(_data(200, 4, 0), cfg), cfg)
    assert eng.mp == 16                     # next_pow2(5 + 11)
    cfg2 = dataclasses.replace(cfg, quant_slack=-1)
    eng2 = QuantMegastepEngine(build_index(_data(200, 4, 0), cfg2), cfg2)
    assert eng2.mp == 128                   # auto: max(pow2(4k), 128)
    eng3 = QuantMegastepEngine(build_index(_data(200, 4, 0), cfg2), cfg2,
                               slack=3)
    assert eng3.mp == 8                     # explicit slack wins


# ---------------------------------------------------------------------------
# memory accounting + lifecycle


def test_nbytes_resident_ratio():
    s = _data(4096, 32, 0)
    index = build_index(s, JoinConfig(k=8, n_pivots=32, seed=0))
    fp32 = index.nbytes_resident(quantized=False)
    int8 = index.nbytes_resident(quantized=True)
    assert fp32 == s.nbytes
    assert fp32 / int8 >= 3.5


def test_quant_rebuilt_on_seal_and_compact():
    """`quantize="int8"` in the config makes every segment — base,
    sealed deltas, the buffer's ephemeral view, compacted rebuilds —
    carry codes, with queries bitwise the oracle throughout."""
    cfg = JoinConfig(k=5, n_pivots=16, n_groups=4, seed=0,
                     quantize="int8")
    mi = MutableIndex.build(_data(400, 6, 1), cfg, seal_threshold=150)
    assert all(bool(si._quant) for si, _ in mi.segment_snapshot())
    mi.insert(_data(170, 6, 2))                  # seals a delta
    mi.insert(_data(40, 6, 3))                   # buffered
    assert all(bool(si._quant) for si, _ in mi.segment_snapshot())
    mi.delete(np.arange(10))
    r = _data(90, 6, 4)
    hd, hi = mi.join_batch(r, config=dataclasses.replace(
        cfg, quantize="none"))
    res = knn_join(r, config=cfg, index=mi)      # quantized by config
    np.testing.assert_array_equal(res.distances, hd)
    np.testing.assert_array_equal(res.indices, hi)
    mi.compact()
    assert all(bool(si._quant) for si, _ in mi.segment_snapshot())
    assert mi.nbytes_resident(quantized=True) \
        < mi.nbytes_resident(quantized=False)
    res2 = knn_join(r, config=cfg, index=mi)
    np.testing.assert_array_equal(res2.distances, hd)


def test_build_index_quantize_kwarg():
    s = _data(300, 6, 0)
    index = build_index(s, JoinConfig(k=5), quantize="int8")
    assert index.config.quantize == "int8"
    assert bool(index._quant)
    with pytest.raises(ValueError):
        build_index(s, JoinConfig(k=5), quantize="int4")


def test_quant_forced_cert_failure_via_fault_hook():
    """Satellite of the serving-runtime PR: *force* certificate failures
    through the ``quant.eps_inflation`` fault hook (deflating the
    certified lower bounds is what inflated ε would do) and pin that the
    fallback engages (``n_quant_fallback``) while the output stays
    bitwise the oracle's — the fallback branch exercised deliberately,
    not incidentally."""
    from repro.serve import FaultPlan

    r = _data(60, 8, 20)
    s = _data(500, 8, 21)
    cfg = JoinConfig(k=5, n_pivots=16, n_groups=4, seed=1)
    index = build_index(s, cfg)
    host = knn_join(r, config=cfg, index=index)
    eng = QuantMegastepEngine(index, cfg)

    stats = JoinStats()
    with FaultPlan().transform("quant.eps_inflation",
                               lambda lb: lb - np.float32(1e9)) as plan:
        lb, _, _ = eng.coarse_shortlist(r)
        d, i = eng.join_batch(r, stats=stats)
    assert plan.fired["quant.eps_inflation"] == 2
    # every filled shortlist must fail its certificate (an *unfilled*
    # shortlist excluded nothing — lm stays +inf and certifies soundly
    # no matter how far the bounds are deflated)
    expected = int(np.isfinite(lb[:, -1]).sum())
    assert 0 < expected == stats.n_quant_fallback
    np.testing.assert_array_equal(d, host.distances)
    np.testing.assert_array_equal(i, host.indices)

    # hook disarmed: certification recovers, fallback back to rare
    stats2 = JoinStats()
    d2, i2 = eng.join_batch(r, stats=stats2)
    assert stats2.n_quant_fallback < r.shape[0]
    np.testing.assert_array_equal(d2, host.distances)


def test_quant_degraded_mode_recall_bound_sound():
    """join_batch_approx (the scheduler's degraded rung): distances are
    exact per reported neighbor and the certified recall bound never
    exceeds the true recall — including under adversarially shrunk
    shortlists and fault-deflated bounds (bound collapses toward 0,
    never lies)."""
    from repro.serve import FaultPlan

    r = _data(80, 8, 30)
    s = _data(600, 8, 31)
    cfg = JoinConfig(k=6, n_pivots=16, n_groups=4, seed=2)
    index = build_index(s, cfg)
    host = knn_join(r, config=cfg, index=index)

    for slack, plan_fn in [(0, None), (64, None),
                           (64, lambda: FaultPlan().transform(
                               "quant.eps_inflation",
                               lambda lb: lb * np.float32(0.5)))]:
        eng = QuantMegastepEngine(index, cfg, slack=slack)
        stats = JoinStats()
        if plan_fn is None:
            d, i, rb = eng.join_batch_approx(r, stats=stats)
        else:
            with plan_fn():
                d, i, rb = eng.join_batch_approx(r, stats=stats)
        assert rb.shape == (r.shape[0],)
        assert (rb >= 0).all() and (rb <= 1).all()
        assert stats.n_degraded == r.shape[0]
        assert stats.recall_bound == pytest.approx(float(rb.min()))
        for q in range(r.shape[0]):
            true_set = set(host.indices[q].tolist())
            got = set(x for x in i[q].tolist() if x >= 0)
            true_recall = len(true_set & got) / cfg.k
            assert true_recall >= float(rb[q]) - 1e-6
            # reported distances are exact for the reported ids
            alive = i[q] >= 0
            np.testing.assert_allclose(
                d[q][alive],
                np.linalg.norm(r[q][None, :] - s[i[q][alive]], axis=1),
                rtol=1e-5, atol=1e-5)
