"""Sharded megastep (core.sharded): shard-invariance, payload packing,
and per-shard residency.

The load-bearing property is *bitwise shard-invariance*: for any shard
count the sharded engines must return exactly the single-device
megastep's bits (θ is global, schedules are per shard, only final
k-runs cross the mesh — see the core.sharded module docstring for the
argument). The full {shards} × {index kind} × {impl} matrix needs more
than one device, so it runs in a subprocess with 8 forced host devices
(the test_distributed_join pattern); everything that works on one
device — packing invariants, 1-shard bitwise equality, wiring and
error paths, the per-shard residency arithmetic — runs in-process.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (JoinConfig, MutableIndex, StreamJoinEngine,
                        build_index, knn_join)
from repro.core.megastep import MegastepEngine
from repro.core.sharded import ShardedMegastepEngine


def _data(n=360, dim=5, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, dim)).astype(np.float32) * 2).copy()


def _index(n=360, dim=5, k=5, quantize="none"):
    cfg = JoinConfig(k=k, n_pivots=24, n_groups=6, grouping="geometric",
                     quantize=quantize)
    return build_index(_data(n, dim), cfg), cfg


# --------------------------------------------------------- host packing

def test_shard_packing_conserves_rows():
    idx, _ = _index()
    for n_sh in (1, 2, 3, 8, 64):   # 64 > n_pivots exercises the clamp
        sp = idx.shard_packing(n_sh)
        assert sp.n_shards == n_sh
        assert int(sp.rows_per_shard.sum()) == idx.n_s
        # every row id lands on exactly one shard, none invented
        gids = sp.gids_local[sp.gids_local >= 0]
        assert np.array_equal(np.sort(gids), np.arange(idx.n_s))
        # per-shard blocks stay in (partition, pivot-distance) order so
        # tiles are partition-coherent (what makes Thm-2 stats tight);
        # stable lexsort of an already-sorted block is the identity
        for j in range(n_sh):
            live = sp.gids_local[j] >= 0
            order = np.lexsort((sp.dist[j][live], sp.part[j][live]))
            assert np.array_equal(order, np.arange(order.size))


def test_shard_packing_nbytes_and_resident():
    idx, _ = _index()
    whole = idx.nbytes_resident()
    for n_sh in (1, 2, 4):
        per = idx.shard_packing(n_sh).nbytes_per_shard()
        assert per.shape == (n_sh,)
        assert int(per.sum()) == whole          # disjoint partition of S
        assert idx.nbytes_resident(n_shards=n_sh) == int(per.max())
        qper = idx.shard_packing(n_sh).nbytes_per_shard(quantized=True)
        assert (qper < per).all()               # int8 tier is smaller
    # sharding strictly shrinks the per-device figure once n_sh > 1
    assert idx.nbytes_resident(n_shards=4) < whole


# ------------------------------------------------- 1-device engine paths

def test_single_shard_bitwise_and_stream_wiring():
    idx, cfg = _index()
    q = _data(90, 5, seed=1)
    d0, i0 = MegastepEngine(idx, cfg).join_batch(q)
    eng = ShardedMegastepEngine(idx, cfg, n_shards=1)
    d1, i1 = eng.join_batch(q)
    assert np.array_equal(d0, d1) and np.array_equal(i0, i1)

    # StreamJoinEngine routes n_shards to the sharded engine and stamps
    # the shard count into the stats
    from repro.core.types import JoinStats
    st = JoinStats()
    se = StreamJoinEngine(idx, cfg, megastep="auto", n_shards=1)
    ds, is_ = se.join_batch(q, stats=st)
    assert st.n_shards == 1
    assert np.array_equal(ds, d0) and np.array_equal(is_, i0)

    # oracle check, not just self-consistency
    res = knn_join(_data(90, 5, seed=1), _data(360, 5, seed=0), config=cfg)
    assert np.allclose(d0, res.distances, atol=1e-5)


def test_n_shards_exceeds_devices_raises():
    import jax
    idx, cfg = _index()
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        ShardedMegastepEngine(idx, cfg, n_shards=len(jax.devices()) + 1)


def test_host_path_rejects_n_shards():
    idx, cfg = _index()
    with pytest.raises(ValueError, match="megastep-mode"):
        StreamJoinEngine(idx, cfg, megastep=False, n_shards=2)


def test_datastore_n_shards_wiring():
    from repro.serve import Datastore
    keys = _data(240, 5, seed=3)
    vals = np.arange(240, dtype=np.int32)
    ds0 = Datastore.build(keys, vals, k=4, n_pivots=16, seal_threshold=512)
    ds1 = Datastore.build(keys, vals, k=4, n_pivots=16, seal_threshold=512,
                          n_shards=1)
    q = _data(40, 5, seed=4)
    d0, i0, _ = ds0.retrieve(q)
    d1, i1, _ = ds1.retrieve(q)
    assert np.array_equal(d0, d1) and np.array_equal(i0, i1)
    assert type(ds1.engine().megastep_engine).__name__ == \
        "ShardedMegastepEngine"


# ----------------------------------------------- per-shard residency (c)

def test_resident_fit_is_per_shard(monkeypatch):
    """The quantized tier's residency check must size against the
    *largest shard*, not the whole index — that is what lets a mesh hold
    an index no single device fits."""
    import repro.quant.engine as qe
    from repro.quant.engine import QuantMegastepEngine
    from repro.quant.quantize import resident_extra_bytes

    idx, cfg = _index(quantize="int8")
    whole = resident_extra_bytes(idx.n_s, idx.dim)
    per4 = idx.shard_packing(4).rows_per_shard
    biggest4 = resident_extra_bytes(int(per4.max()), idx.dim)
    assert biggest4 < whole          # the unlock exists arithmetically

    # a cap between the two: whole-index engine degrades to host re-rank
    cap = (biggest4 + whole) // 2
    monkeypatch.setattr(qe, "_RESIDENT_MAX_BYTES", cap)
    single = QuantMegastepEngine(idx, cfg, slack=8)
    assert single.mode == "int8" and not single.resident
    # ...and the per-shard fit hook reports the shard figure, which fits
    from repro.quant.engine import ShardedQuantMegastepEngine
    sh1 = None
    try:
        sh1 = ShardedQuantMegastepEngine(idx, cfg, slack=8, n_shards=1)
    except ValueError as e:
        # 1 shard == whole index: correctly refuses residency
        assert "add shards" in str(e)
    assert sh1 is None
    # engine-level unlock at n_shards>1 needs >1 device — covered by the
    # quant arm of the subprocess matrix below


# ------------------------------------------------- 8-device mesh matrix

_COMMON = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core import JoinConfig, MutableIndex, build_index
    from repro.core.megastep import MegastepEngine
    from repro.core.sharded import ShardedMegastepEngine

    def data(n, dim=5, seed=0):
        rng = np.random.default_rng(seed)
        return (rng.normal(size=(n, dim)).astype(np.float32) * 2).copy()

    def mutable(cfg):
        # base + sealed delta + write buffer + tombstones, the
        # test_quant_resident shape at smaller scale
        mut = MutableIndex.build(data(500, seed=0), cfg,
                                 seal_threshold=200)
        ids1 = mut.insert(data(230, seed=1))
        mut.insert(data(60, seed=2))
        mut.delete(np.arange(0, 40))     # base tombstones
        mut.delete(ids1[:5])             # delta tombstones
        return mut

    cfg = JoinConfig(k=6, n_pivots=24, n_groups=6, grouping="geometric")
    Q = data(96, seed=9)
    out = {"cells": []}
"""

_FP32_SCRIPT = _COMMON + """
    for kind in ("static", "mutable"):
        idx = (build_index(data(700, seed=0), cfg) if kind == "static"
               else mutable(cfg))
        oracle = MegastepEngine(idx, cfg).join_batch(Q)
        for impl in ("ref", "pallas_interpret"):
            shard_set = (1, 2, 4, 8) if impl == "ref" else (1, 8)
            for n_sh in shard_set:
                eng = ShardedMegastepEngine(idx, cfg, n_shards=n_sh,
                                            impl=impl)
                d, i = eng.join_batch(Q)
                ok = (np.array_equal(d, oracle[0])
                      and np.array_equal(i, oracle[1]))
                out["cells"].append([kind, impl, n_sh, bool(ok)])

    # steady state moves zero bytes: enqueue commits to the mesh, then
    # the jitted call runs under a full transfer guard
    idx = build_index(data(700, seed=0), cfg)
    eng = ShardedMegastepEngine(idx, cfg, n_shards=8)
    qd, nv = eng.enqueue(Q)
    jax.block_until_ready(eng.join_batch_device(qd, nv))   # warm/trace
    with jax.transfer_guard("disallow"):
        jax.block_until_ready(eng.join_batch_device(qd, nv))
    out["steady_guarded"] = True
    print(json.dumps(out))
"""

_QUANT_SCRIPT = _COMMON + """
    import repro.quant.engine as qe
    from repro.quant.engine import (QuantMegastepEngine,
                                    ShardedQuantMegastepEngine)
    from repro.quant.quantize import resident_extra_bytes

    for kind in ("static", "mutable"):
        idx = (build_index(data(700, seed=0), cfg) if kind == "static"
               else mutable(cfg))
        oracle = QuantMegastepEngine(idx, cfg, slack=8).join_batch(Q)
        for impl, shard_set in (("ref", (1, 2, 4, 8)),
                                ("pallas_interpret", (4,))):
            for n_sh in shard_set:
                eng = ShardedQuantMegastepEngine(
                    idx, cfg, slack=8, n_shards=n_sh, impl=impl)
                d, i = eng.join_batch(Q)
                ok = (np.array_equal(d, oracle[0])
                      and np.array_equal(i, oracle[1]))
                out["cells"].append([kind, impl, n_sh, bool(ok)])

    # drop the residency cap between the 8-shard fit and the whole-index
    # size: one device refuses residency, the mesh unlocks it. The 700-row
    # dim-5 index needs 700*(4*5+8) = 19600 extra bytes whole; 8 balanced
    # shards hold ~100 rows (~2.8 kB) each — 12000 sits cleanly between.
    idx = build_index(data(700, seed=0), cfg)
    qe._RESIDENT_MAX_BYTES = 12000
    single = QuantMegastepEngine(idx, cfg, slack=8)
    sharded = ShardedQuantMegastepEngine(idx, cfg, slack=8, n_shards=8)
    out["whole_extra"] = int(resident_extra_bytes(idx.n_s, idx.dim))
    out["cap"] = int(qe._RESIDENT_MAX_BYTES)
    out["single_resident"] = bool(single.resident)
    out["sharded_resident"] = bool(sharded.resident)
    d, i = sharded.join_batch(Q)
    ds, is_ = single.join_batch(Q)   # host re-rank path, still exact
    out["unlock_bitwise"] = bool(np.array_equal(d, ds)
                                 and np.array_equal(i, is_))

    # steady state under a full transfer guard, quant payload included
    qd, nv = sharded.enqueue(Q)
    jax.block_until_ready(sharded.join_batch_device(qd, nv))
    with jax.transfer_guard("disallow"):
        jax.block_until_ready(sharded.join_batch_device(qd, nv))
    out["steady_guarded"] = True
    print(json.dumps(out))
"""


def _run_sub(script, extra_env=None, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.update(extra_env or {})
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_shard_invariance_fp32_subprocess():
    out = _run_sub(_FP32_SCRIPT)
    bad = [c for c in out["cells"] if not c[3]]
    assert not bad, f"non-bitwise cells: {bad}"
    assert len(out["cells"]) == 2 * (4 + 2)
    assert out["steady_guarded"]


def test_shard_invariance_quant_subprocess():
    out = _run_sub(_QUANT_SCRIPT)
    bad = [c for c in out["cells"] if not c[3]]
    assert not bad, f"non-bitwise cells: {bad}"
    assert out["whole_extra"] > out["cap"]
    assert not out["single_resident"]
    assert out["sharded_resident"]
    assert out["unlock_bitwise"]
    assert out["steady_guarded"]
