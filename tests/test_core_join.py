"""Correctness of the PGBJ core: partitioning, bounds, grouping, join."""
import numpy as np
import pytest

from repro.core import (
    JoinConfig, assign_and_summarize, brute_force_knn, compute_theta,
    group_lower_bounds, hbrj_join, knn_join, pbj_join, pivot_distance_matrix,
    plan_join, replication_count_exact, replication_count_partitions,
    replication_lower_bounds, select_pivots)


def _data(n, dim, seed, clusters=True):
    rng = np.random.default_rng(seed)
    if not clusters:
        return rng.normal(size=(n, dim)).astype(np.float32)
    centers = rng.uniform(-20, 20, (8, dim))
    who = rng.integers(0, 8, n)
    return (centers[who] + rng.normal(size=(n, dim))).astype(np.float32)


@pytest.mark.parametrize("strategy", ["random", "farthest", "kmeans"])
def test_build_index_pivot_strategy_kwarg(strategy):
    """`build_index(pivot_strategy=...)` plumbs §4.1 selection through
    the public build path (previously the k-means path needed a
    hand-built config or hand-passed pivots) — each strategy yields a
    valid, exact index."""
    from repro.core import build_index

    r = _data(150, 5, 4)
    s = _data(400, 5, 5)
    index = build_index(s, JoinConfig(k=6, n_pivots=16, n_groups=4,
                                      seed=2), pivot_strategy=strategy)
    assert index.config.pivot_strategy == strategy
    assert index.pivots.shape == (16, 5)
    res = knn_join(r, config=index.config, index=index)
    bd, _ = brute_force_knn(r, s, 6)
    np.testing.assert_allclose(res.distances, bd, atol=1e-4)
    with pytest.raises(ValueError):
        build_index(s, pivot_strategy="voronoi-magic")


@pytest.mark.parametrize("grouping", ["geometric", "greedy", "none"])
@pytest.mark.parametrize("strategy", ["random", "farthest", "kmeans"])
def test_pgbj_exact_vs_bruteforce(grouping, strategy):
    r = _data(300, 6, 0)
    s = _data(500, 6, 1)
    k = 7
    cfg = JoinConfig(k=k, n_pivots=24,
                     n_groups=24 if grouping == "none" else 5,
                     grouping=grouping, pivot_strategy=strategy, seed=3)
    res = knn_join(r, s, config=cfg)
    bd, bi = brute_force_knn(r, s, k)
    np.testing.assert_allclose(res.distances, bd, atol=1e-4)
    assert (res.indices == bi).mean() > 0.999  # ties only


def test_self_join():
    """Paper's experiments are self-joins (R = S)."""
    r = _data(400, 4, 2)
    res = knn_join(r, r, k=3, config=JoinConfig(k=3, n_pivots=16, n_groups=4))
    # nearest neighbor of each point in a self-join is itself at distance
    # ~0 (the MXU-form ‖r‖²−2rs+‖s‖² carries O(‖x‖²·eps) cancellation noise)
    np.testing.assert_allclose(res.distances[:, 0], 0.0, atol=2e-2)
    assert (res.indices[:, 0] == np.arange(400)).all()


def test_baselines_exact():
    r = _data(200, 5, 4)
    s = _data(350, 5, 5)
    bd, _ = brute_force_knn(r, s, 5)
    h = hbrj_join(r, s, 5, n_reducers=9)
    np.testing.assert_allclose(h.distances, bd, atol=1e-4)
    p = pbj_join(r, s, 5, JoinConfig(k=5, n_pivots=16), n_reducers=9)
    np.testing.assert_allclose(p.distances, bd, atol=1e-4)


def test_summary_tables():
    s = _data(300, 4, 6)
    pivots = select_pivots(s, 10, "random", seed=0)
    part, dist, table = assign_and_summarize(s, pivots, k=4)
    assert table.counts.sum() == 300
    for j in range(10):
        sel = part == j
        if not sel.any():
            assert table.counts[j] == 0
            continue
        np.testing.assert_allclose(table.lower[j], dist[sel].min(), rtol=1e-5)
        np.testing.assert_allclose(table.upper[j], dist[sel].max(), rtol=1e-5)
        expect = np.sort(dist[sel])[:4]
        got = table.knn_dists[j][:len(expect)]
        np.testing.assert_allclose(got[np.isfinite(got)],
                                   expect[:np.isfinite(got).sum()], rtol=1e-5)


def test_theta_is_valid_bound():
    """θ_i upper-bounds the true kNN distance of every r in partition i."""
    r = _data(250, 5, 7)
    s = _data(400, 5, 8)
    k = 5
    plan = plan_join(r, s, JoinConfig(k=k, n_pivots=12, n_groups=3))
    bd, _ = brute_force_knn(r, s, k)
    worst = bd[:, -1]
    for i in range(12):
        sel = plan.r_part == i
        if sel.any():
            assert (worst[sel] <= plan.theta[i] + 1e-4).all(), i


def test_replication_rule_completeness():
    """Every true kNN of every r must be shipped to r's group (Thm 5/6)."""
    r = _data(250, 5, 9)
    s = _data(400, 5, 10)
    k = 5
    plan = plan_join(r, s, JoinConfig(k=k, n_pivots=12, n_groups=4))
    _, bi = brute_force_knn(r, s, k)
    g_r = plan.group_of_r()
    for g in range(plan.n_groups):
        mask = plan.s_replica_mask(g)
        needed = np.unique(bi[g_r == g])
        assert mask[needed].all(), f"group {g} misses true neighbors"


def test_cost_model_exact_vs_runtime():
    """Thm 7 count == what the runtime actually ships."""
    r = _data(300, 4, 11)
    s = _data(450, 4, 12)
    plan = plan_join(r, s, JoinConfig(k=4, n_pivots=16, n_groups=4))
    exact = replication_count_exact(plan.lb_group, plan.s_part, plan.s_dist)
    shipped = np.array([plan.s_replica_mask(g).sum()
                        for g in range(plan.n_groups)])
    np.testing.assert_array_equal(exact, shipped)
    # Eq. 12 partition-level approximation is an upper bound
    approx = replication_count_partitions(plan.lb_group, plan.t_s)
    assert (approx >= exact).all()


def test_grouping_balance():
    """Geometric grouping balances group populations (paper Table 3)."""
    r = _data(2000, 4, 13)
    plan = plan_join(r, r, JoinConfig(k=4, n_pivots=64, n_groups=8,
                                      grouping="geometric"))
    sizes = np.bincount(plan.group_of_r(), minlength=8)
    assert sizes.max() <= 2.0 * sizes.mean()


def test_greedy_replicates_less_or_equal():
    r = _data(800, 4, 14)
    s = _data(800, 4, 15)
    geo = knn_join(r, s, config=JoinConfig(
        k=5, n_pivots=48, n_groups=6, grouping="geometric"))
    grd = knn_join(r, s, config=JoinConfig(
        k=5, n_pivots=48, n_groups=6, grouping="greedy"))
    # paper Fig 7(b): greedy ≤ geometric on average (allow slack — greedy
    # optimizes the Eq. 12 approximation, not the exact count)
    assert grd.stats.replicas_s <= geo.stats.replicas_s * 1.2


def test_pruning_reduces_pairs():
    r = _data(600, 4, 16)
    s = _data(900, 4, 17)
    cfg = JoinConfig(k=4, n_pivots=32, n_groups=4, use_tile_pruning=True)
    pruned = knn_join(r, s, config=cfg)
    dense = knn_join(r, s, config=JoinConfig(
        k=4, n_pivots=32, n_groups=4, use_tile_pruning=False))
    np.testing.assert_allclose(pruned.distances, dense.distances, atol=1e-4)
    assert pruned.stats.pairs_computed < dense.stats.pairs_computed


def test_k_larger_than_some_partition():
    """k exceeding individual partition sizes must still be exact."""
    r = _data(100, 3, 18)
    s = _data(120, 3, 19)
    res = knn_join(r, s, config=JoinConfig(k=30, n_pivots=16, n_groups=4))
    bd, _ = brute_force_knn(r, s, 30)
    np.testing.assert_allclose(res.distances, bd, atol=1e-4)


def test_join_result_dtypes():
    """JoinResult contract: indices are int64 (segment-offset ids from
    the mutable index overflow int32 by design), distances float32 —
    across every reducer engine."""
    r = _data(60, 4, 30)
    s = _data(90, 4, 31)
    for reducer in ("dense", "pruned", "gather"):
        res = knn_join(r, s, config=JoinConfig(
            k=4, n_pivots=8, n_groups=2, reducer=reducer))
        assert res.indices.dtype == np.int64, reducer
        assert res.distances.dtype == np.float32, reducer


def test_errors():
    r = _data(50, 3, 20)
    with pytest.raises(ValueError):
        knn_join(r, r[:5], k=10)        # k > |S|
    with pytest.raises(ValueError):
        JoinConfig(k=0)
    with pytest.raises(ValueError):
        JoinConfig(grouping="nope")


@pytest.mark.parametrize("metric", ["l1", "linf"])
def test_metric_generality(metric):
    """Paper §2.1: the bounds transfer to any triangle-inequality metric.
    JoinConfig.metric threads end-to-end: verified against an independent
    numpy oracle (not our own engine) and the brute-force baseline."""
    rng = np.random.default_rng(21)
    r = rng.normal(size=(250, 5)).astype(np.float32) * 3
    s = rng.normal(size=(400, 5)).astype(np.float32) * 3
    cfg = JoinConfig(k=6, metric=metric, n_pivots=20, n_groups=4)
    res = knn_join(r, s, config=cfg)
    diff = np.abs(r[:, None] - s[None])
    d = diff.sum(-1) if metric == "l1" else diff.max(-1)
    ref = np.sort(d, axis=1)[:, :6]
    np.testing.assert_allclose(res.distances, ref, atol=1e-3)
    bd, bi = brute_force_knn(r, s, 6, metric=metric)
    np.testing.assert_allclose(res.distances, bd, atol=1e-3)
    assert (res.indices == bi).mean() > 0.999
    assert res.stats.selectivity < 1.0


@pytest.mark.parametrize("metric", ["l1", "linf"])
@pytest.mark.parametrize("reducer", ["dense", "pruned", "gather"])
def test_metric_generality_all_reducers(metric, reducer):
    """Every reducer engine honors JoinConfig.metric (L1/L∞ vs the
    brute-force baseline)."""
    rng = np.random.default_rng(22)
    r = rng.normal(size=(150, 4)).astype(np.float32) * 3
    s = rng.normal(size=(260, 4)).astype(np.float32) * 3
    cfg = JoinConfig(k=5, metric=metric, n_pivots=16, n_groups=3,
                     reducer=reducer)
    res = knn_join(r, s, config=cfg)
    bd, _ = brute_force_knn(r, s, 5, metric=metric)
    np.testing.assert_allclose(res.distances, bd, atol=1e-3)
