"""Streaming engine + index/planner split: build-once reuse, any-split
equality with the one-shot join, and the sorted-run merge state."""
import numpy as np
import pytest

from repro.core import (
    JoinConfig, StreamJoinState, brute_force_knn, build_index, knn_join,
    knn_join_batched, plan_queries)


def _data(n, dim, seed, scale=3.0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, dim)).astype(np.float32) * scale


def test_batched_equals_oneshot_bitwise():
    """Acceptance: knn_join_batched over any split of R is exactly the
    one-shot knn_join against the same index — distances and indices."""
    r = _data(313, 6, 0)
    s = _data(521, 6, 1)
    cfg = JoinConfig(k=7, n_pivots=24, n_groups=5, seed=3)
    index = build_index(s, cfg)
    one = knn_join(r, config=cfg, index=index)
    bd, _ = brute_force_knn(r, s, 7)
    np.testing.assert_allclose(one.distances, bd, atol=1e-4)
    for bs in (400, 128, 57, 9):
        res = knn_join_batched(r, index=index, config=cfg, batch_size=bs)
        np.testing.assert_array_equal(res.distances, one.distances)
        np.testing.assert_array_equal(res.indices, one.indices)
        assert res.stats.n_batches == -(-313 // bs)


@pytest.mark.parametrize("reducer", ["dense", "pruned", "gather"])
def test_batched_equals_oneshot_all_reducers(reducer):
    r = _data(200, 5, 2)
    s = _data(340, 5, 3)
    cfg = JoinConfig(k=5, n_pivots=16, n_groups=4, seed=1, reducer=reducer)
    index = build_index(s, cfg)
    one = knn_join(r, config=cfg, index=index)
    res = knn_join_batched(r, index=index, config=cfg, batch_size=61)
    np.testing.assert_array_equal(res.distances, one.distances)
    np.testing.assert_array_equal(res.indices, one.indices)


def test_batched_accepts_iterable_of_batches():
    r = _data(150, 4, 4)
    s = _data(260, 4, 5)
    cfg = JoinConfig(k=4, n_pivots=12, n_groups=3, seed=2)
    index = build_index(s, cfg)
    one = knn_join(r, config=cfg, index=index)
    res = knn_join_batched(
        iter([r[:40], r[40:41], r[41:130], r[130:]]), index=index,
        config=cfg)
    np.testing.assert_array_equal(res.distances, one.distances)
    np.testing.assert_array_equal(res.indices, one.indices)


def test_index_built_once_reused_across_batches():
    """Acceptance: one SIndex serves ≥2 distinct R batches with no re-run
    of S-side phase 1 (assignment + summaries)."""
    import repro.core.index as index_mod

    s = _data(400, 5, 6)
    cfg = JoinConfig(k=5, n_pivots=20, n_groups=4, seed=0)
    index = build_index(s, cfg)
    calls = {"n": 0}
    orig = index_mod.assign_and_summarize

    def guard(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    index_mod.assign_and_summarize = guard
    try:
        batches = [_data(90, 5, seed) for seed in (7, 8, 9)]
        for b in batches:
            res = knn_join(b, config=cfg, index=index)
            bd, _ = brute_force_knn(b, s, 5)
            np.testing.assert_allclose(res.distances, bd, atol=1e-4)
        res = knn_join_batched(np.concatenate(batches), index=index,
                               config=cfg, batch_size=64)
        assert res.stats.n_batches == 5
    finally:
        index_mod.assign_and_summarize = orig
    # S-side phase 1 ran zero times after build: plan_queries only
    # re-derives the R side (jitted assignment + θ/LB)
    assert calls["n"] == 0


def test_per_batch_plans_differ_but_results_exact():
    """The per-batch planner really is query-dependent: different batches
    produce different θ/grouping, yet every batch's results are exact."""
    s = _data(300, 4, 10)
    cfg = JoinConfig(k=4, n_pivots=16, n_groups=3, seed=0)
    index = build_index(s, cfg)
    near = _data(60, 4, 11, scale=1.0)
    far = _data(60, 4, 12, scale=8.0)
    qp_near = plan_queries(near, index, cfg)
    qp_far = plan_queries(far, index, cfg)
    assert not np.array_equal(qp_near.theta, qp_far.theta)
    for batch in (near, far):
        res = knn_join(batch, config=cfg, index=index)
        bd, _ = brute_force_knn(batch, s, 4)
        np.testing.assert_allclose(res.distances, bd, atol=1e-4)


def test_stream_state_merges_revisited_slots():
    """StreamJoinState is a genuine sorted-run merger: presenting the
    same slots twice keeps the k best across both runs."""
    state = StreamJoinState(n=3, k=4)
    rows = np.arange(3)
    d1 = np.sort(np.float32([[1, 3, 5, 7], [2, 4, 6, 8], [0, 1, 2, 3]]), 1)
    i1 = np.arange(12).reshape(3, 4)
    state.update(rows, d1, i1)
    np.testing.assert_array_equal(state.distances, d1)
    d2 = np.sort(np.float32([[0, 2, 9, 9], [5, 5, 5, 5], [4, 5, 6, 7]]), 1)
    i2 = 100 + np.arange(12).reshape(3, 4)
    state.update(rows, d2, i2)
    ref = np.sort(np.concatenate([d1, d2], 1), 1)[:, :4]
    np.testing.assert_array_equal(state.distances, ref)
    # ids track their distances through the merge
    assert state.indices[0, 0] == 100 and state.indices[0, 1] == 0


def test_stream_state_keeps_int64_ids():
    """Regression: ids above the int32 range must survive the merge —
    the old path truncated S row ids to int32 before the kernel, silently
    corrupting segment-offset ids ≥ 2³¹ (and |S| ≥ 2³¹)."""
    state = StreamJoinState(n=2, k=4)
    base = np.int64(2**31)
    big = np.array([[base + 3, base + 9, 2**33 + 1, base + 40],
                    [7, base, 2**40, 2**40 + 123]], np.int64)
    d = np.float32([[1, 2, 3, 4], [1, 2, 3, 4]])
    state.update(np.arange(2), d, big)
    np.testing.assert_array_equal(state.indices, big)
    # revisit with a better run: merged ids still exact at 64 bits
    d2 = np.float32([[0.5, 5, 6, 7], [0.1, 9, 9, 9]])
    i2 = np.array([[2**35, -1, -1, -1], [2**36 + 17, -1, -1, -1]], np.int64)
    state.update(np.arange(2), d2, i2)
    np.testing.assert_array_equal(
        state.indices[:, 0], [2**35, 2**36 + 17])
    np.testing.assert_array_equal(state.indices[0, 1:], big[0, :3])


def test_stream_state_dedups_revisited_overlap():
    """A slot revisited with an overlapping candidate set keeps each S
    row at most once (the odd-even merge alone would return duplicates),
    at its smaller distance, and backfills with the next-best rows."""
    state = StreamJoinState(n=1, k=4)
    state.update(np.array([0]), np.float32([[1, 2, 3, 4]]),
                 np.array([[10, 11, 12, 13]], np.int64))
    # rows 11/12 offered again (same canonical distances), plus new rows:
    # the duplicates collapse, 20@3.5 takes the freed slot
    state.update(np.array([0]), np.float32([[2, 3, 3.5, 5]]),
                 np.array([[11, 12, 20, 21]], np.int64))
    np.testing.assert_array_equal(state.indices, [[10, 11, 12, 20]])
    np.testing.assert_array_equal(state.distances,
                                  np.float32([[1, 2, 3, 3.5]]))
    # overlap where the revisit is strictly better: min distance survives
    state2 = StreamJoinState(n=1, k=4)
    state2.update(np.array([0]), np.float32([[1, 2, 3, 4]]),
                  np.array([[10, 11, 12, 13]], np.int64))
    state2.update(np.array([0]), np.float32([[0.5, 2.5, 6, 7]]),
                  np.array([[12, 30, 31, 32]], np.int64))
    np.testing.assert_array_equal(state2.indices, [[12, 10, 11, 30]])
    np.testing.assert_array_equal(state2.distances, [[0.5, 1, 2, 2.5]])
    # ids with identical low 32 bits are NOT duplicates (hi/lo compare)
    state3 = StreamJoinState(n=1, k=2)
    state3.update(np.array([0]), np.float32([[1, 2]]),
                  np.array([[5, 6]], np.int64))
    state3.update(np.array([0]), np.float32([[0.5, 1.5]]),
                  np.array([[2**32 + 5, 2**33 + 6]], np.int64))
    np.testing.assert_array_equal(state3.indices, [[2**32 + 5, 5]])


@pytest.mark.parametrize("metric", ["l1", "linf"])
def test_batched_metric_generality(metric):
    """L1/L∞ threads through index build + per-batch planning + join."""
    rng = np.random.default_rng(13)
    r = rng.normal(size=(180, 5)).astype(np.float32) * 3
    s = rng.normal(size=(300, 5)).astype(np.float32) * 3
    cfg = JoinConfig(k=5, metric=metric, n_pivots=16, n_groups=3)
    index = build_index(s, cfg)
    res = knn_join_batched(r, index=index, config=cfg, batch_size=47)
    one = knn_join(r, config=cfg, index=index)
    np.testing.assert_array_equal(res.distances, one.distances)
    bd, _ = brute_force_knn(r, s, 5, metric=metric)
    np.testing.assert_allclose(res.distances, bd, atol=1e-3)


def test_hypothesis_property_any_split():
    pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis; tier-1 must "
        "still collect on clean environments without it")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def instance(draw):
        n_r = draw(st.integers(20, 100))
        n_s = draw(st.integers(30, 140))
        dim = draw(st.integers(2, 6))
        k = draw(st.integers(1, min(8, n_s)))
        m = draw(st.integers(2, 16))
        g = draw(st.integers(1, min(5, m)))
        bs = draw(st.integers(1, n_r))
        seed = draw(st.integers(0, 2**16))
        return n_r, n_s, dim, k, m, g, bs, seed

    @given(instance())
    @settings(max_examples=20, deadline=None)
    def prop(inst):
        n_r, n_s, dim, k, m, g, bs, seed = inst
        rng = np.random.default_rng(seed)
        r = rng.normal(size=(n_r, dim)).astype(np.float32) * 3
        s = rng.normal(size=(n_s, dim)).astype(np.float32) * 3
        cfg = JoinConfig(k=k, n_pivots=m, n_groups=g, seed=seed)
        index = build_index(s, cfg)
        one = knn_join(r, config=cfg, index=index)
        res = knn_join_batched(r, index=index, config=cfg, batch_size=bs)
        np.testing.assert_array_equal(res.distances, one.distances)
        np.testing.assert_array_equal(res.indices, one.indices)
        bd, _ = brute_force_knn(r, s, k)
        np.testing.assert_allclose(one.distances, bd, atol=1e-3)

    prop()
