"""Data pipeline: seeded, stateless, shard-aware.

Reproducibility contract (fault tolerance): every batch is a pure function
of (seed, step, shard) — restart from any checkpoint replays the exact
stream with no iterator state to persist. That is the MapReduce
"deterministic re-execution" property, ported to the input pipeline.

Also hosts the paper's datasets (§6): Forest-like / OSM-like synthetic
generators and the paper's frequency-rank expansion trick for "Forest×t".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0


def synthetic_lm_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Markov token stream: 3/4 of rows follow a fixed random successor
    table (a function of cfg.seed only — learnable across steps), 1/4 are
    uniform noise. Optimal loss ≈ 0.25·ln(V): plenty of headroom for
    loss-decreases tests while keeping an irreducible component."""
    assert cfg.global_batch % cfg.n_shards == 0
    b = cfg.global_batch // cfg.n_shards
    table_rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 7]))
    successor = table_rng.permutation(cfg.vocab)
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard]))
    base = rng.integers(0, cfg.vocab, (b, cfg.seq_len + 1), dtype=np.int64)
    chain = np.empty((b, cfg.seq_len + 1), np.int64)
    chain[:, 0] = rng.integers(0, cfg.vocab, b)
    for t in range(1, cfg.seq_len + 1):
        chain[:, t] = successor[chain[:, t - 1]]
    use_chain = rng.random((b, 1)) < 0.75
    toks = np.where(use_chain, chain, base)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def batch_iterator(cfg: DataConfig, start_step: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthetic_lm_batch(cfg, step)
        step += 1


# ---------------------------------------------------------------- joins
def forest_like(n: int, dim: int = 10, seed: int = 0,
                n_clusters: int = 32) -> np.ndarray:
    """Clustered integer-valued features mimicking Forest CoverType's
    10 integer attributes. Anisotropic like the real dataset: the paper
    (§6.3) observes attributes 6-10 have low variance — effective
    dimensionality is ~5-6, which is where Voronoi pruning still works.
    """
    rng = np.random.default_rng(seed)
    # per-dimension spread decays: first dims dominate distances
    dim_scale = 1.0 / (1.0 + 0.9 * np.arange(dim))
    centers = rng.uniform(0, 1000, (n_clusters, dim)) * dim_scale
    scales = rng.uniform(5, 60, (n_clusters, dim)) * dim_scale
    who = rng.integers(0, n_clusters, n)
    pts = centers[who] + rng.normal(size=(n, dim)) * scales[who]
    return np.round(pts).astype(np.float32)


def clustered_like(n: int, dim: int, seed: int, *, n_centers: int = 16,
                   centers_seed: int = 42) -> np.ndarray:
    """Gaussian blobs around shared uniform centers in [-20, 20]^dim.

    ``centers_seed`` fixes the centers independently of ``seed`` so R and
    S drawn with different seeds share cluster structure — the regime
    where the paper's bounds bite (kNN radius ≪ dataset diameter). The
    one generator behind both the schedule tests and the kernel benches,
    so test and benchmark regimes cannot drift apart.
    """
    centers = np.random.default_rng(centers_seed).uniform(
        -20, 20, (n_centers, dim)).astype(np.float32)
    rng = np.random.default_rng(seed)
    who = rng.integers(0, n_centers, n)
    return (centers[who] + rng.normal(size=(n, dim))).astype(np.float32)


def osm_like(n: int, seed: int = 0) -> np.ndarray:
    """2-d lon/lat-like point cloud: dense cities + sparse countryside."""
    rng = np.random.default_rng(seed)
    n_city = int(n * 0.7)
    cities = rng.uniform(-180, 180, (64, 2)) * np.array([1.0, 0.45])
    who = rng.integers(0, 64, n_city)
    urban = cities[who] + rng.normal(size=(n_city, 2)) * 0.5
    rural = np.stack([rng.uniform(-180, 180, n - n_city),
                      rng.uniform(-81, 81, n - n_city)], 1)
    return np.concatenate([urban, rural]).astype(np.float32)


def expand_dataset(data: np.ndarray, factor: int, seed: int = 0) -> np.ndarray:
    """The paper's §6 expansion: per dimension, replace each value by its
    neighbors in the frequency-sorted value list (distribution-preserving).
    """
    if factor <= 1:
        return data
    rng = np.random.default_rng(seed)
    out = [data]
    n, dim = data.shape
    # per-dim sorted unique values by ascending frequency (paper's order)
    orders = []
    for d in range(dim):
        vals, counts = np.unique(data[:, d], return_counts=True)
        orders.append(vals[np.argsort(counts, kind="stable")])
    for t in range(1, factor):
        new = np.empty_like(data)
        for d in range(dim):
            srt = orders[d]
            idx = np.searchsorted(srt, data[:, d])
            idx = np.minimum(idx + t, len(srt) - 1)   # value ranked next
            new[:, d] = srt[idx]
        out.append(new)
    return np.concatenate(out, axis=0)
