from .pipeline import (
    DataConfig, batch_iterator, clustered_like, expand_dataset, forest_like,
    osm_like, synthetic_lm_batch)

__all__ = ["DataConfig", "batch_iterator", "clustered_like",
           "expand_dataset", "forest_like", "osm_like",
           "synthetic_lm_batch"]
