"""Pallas int8 coarse-scan kernel: schedule-driven shortlist selection
over quantized S tiles.

The quantized tier's phase-1 kernel (see `repro.quant`): queries and S
rows arrive as symmetric int8 codes, the ``-2 Q Sᵀ`` contraction runs as
an int8 dot with **int32 accumulation**, and one float32 rescale per
(query tile, S tile) step recovers coarse squared distances. The
selection key per candidate is the *certified lower bound*

    lb = max(d_coarse − (ε_s + ε_q + ε_num), 0)

where ε_s / ε_q are the stored per-row / per-query reconstruction-error
bounds (`repro.quant.quantize`) and ε_num = δ / max(d_coarse, √δ) with
δ = NUM_DELTA_REL·(‖q̂‖² + ‖ŝ‖²) dominates the float32 rescale/sqrt
rounding (see the NUM_DELTA_REL comment below for the derivation; the
bound is tight ≈ δ/d for d ≫ √δ and exactly √δ at d = 0). Candidates whose
lower bound already exceeds the query's θ are masked: that is the
paper's pruning rule with the threshold *inflated by ε*, so a true
neighbor (d ≤ θ) can never be dropped — its lb ≤ d ≤ θ.

Like `distance_topk_gather_pallas`, the grid is (R tile, visit slot)
with the S-tile index scalar-prefetched from a compacted schedule:
pruned tiles are never DMA'd, and the tiles that *are* streamed move
int8 — 4× fewer bytes than the fp32 gather kernel. The running
shortlist (an ascending sorted mp-run of (lb, row) pairs) lives in VMEM
scratch across the whole concatenated multi-segment schedule.

The kernel returns a *shortlist*, not a result: `repro.quant.engine`
re-ranks the shortlisted rows with exact fp32 canonical distances and
certifies per query that the exclusion was sound. The jnp oracle is
`kernels.ref.quant_coarse_topk_ref` (dense, same rescale formula).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .distance_topk import pl_scratch
from .sorted_merge import merge_sorted_runs, tile_topk

__all__ = ["quant_coarse_gather_kernel", "quant_coarse_gather_pallas",
           "coarse_lb_tile"]

# float32 rounding allowance of the rescale + sqrt (see coarse_lb_tile):
# |d2_f32 − d2_exact| ≤ δ = NUM_DELTA_REL·(‖q̂‖² + ‖ŝ‖²) — the int8 dot
# and the squared norms are exact in int32, so only ~5 fp32 ops round,
# each against a term of at most 2(‖q̂‖²+‖ŝ‖²); 2e-6 ≈ 16 ulp is a 3×
# margin over that. In distance space the error is then at most
# δ / max(d, √δ) (tight for d ≫ √δ, √δ exactly at d = 0).
NUM_DELTA_REL = 2e-6
NUM_TOL_ABS = 1e-7


def coarse_lb_tile(qi, qscale, qeps, si, sscale, seps, *,
                   f32_dot: bool = False):
    """Certified per-pair lower bounds for one (query, S) code tile.

    qi (bm, dim) int8, qscale/qeps (bm,) f32; si (bn, dim) int8,
    sscale a scalar f32 (one tile — the kernel/scan form) or a (bn,)
    per-row vector (several tiles fused into one call — the dense
    oracle's form), seps (bn,) f32. Returns (bm, bn) float32
    ``max(d_coarse − ε_total, 0)`` — shared verbatim by the Pallas body,
    the dense jnp oracle and the engine's scan twin, so every impl keys
    its shortlist on the same certified bound.

    ``f32_dot`` computes the int8 contraction in float32 instead of
    int32. This is **exact, bit-for-bit the int32 path**, whenever
    ``dim · 127² < 2²⁴`` (every partial sum is an integer below the f32
    exact-integer ceiling, under any accumulation order) — the CPU refs
    use it because XLA lowers a float32 matmul to the fast BLAS gemm
    while an int8→int32 dot falls back to a naive loop. The Pallas TPU
    body keeps the int32 form: there the int8 MXU dot *is* the fast
    path. Callers asking for f32 beyond the exactness ceiling get the
    int32 form back silently (correctness over speed).
    """
    dim = qi.shape[1]
    if f32_dot and dim * 127 * 127 < 2 ** 24:
        qf = qi.astype(jnp.float32)
        sf = si.astype(jnp.float32)
        c = jax.lax.dot_general(qf, sf, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        af = jnp.sum(jnp.square(qf), axis=1)                   # (bm,)
        bf = jnp.sum(jnp.square(sf), axis=1)                   # (bn,)
    else:
        c = jax.lax.dot_general(qi, si, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.int32)
        a = jnp.sum(jnp.square(qi.astype(jnp.int32)), axis=1)  # (bm,)
        b = jnp.sum(jnp.square(si.astype(jnp.int32)), axis=1)  # (bn,)
        af = a.astype(jnp.float32)
        bf = b.astype(jnp.float32)
    q2 = (qscale * qscale) * af                                # ‖q̂‖²
    s2 = (sscale * sscale) * bf                                # ‖ŝ‖²  (bn,)
    d2 = (q2[:, None] + s2[None, :]
          - 2.0 * (qscale[:, None] * sscale) * c.astype(jnp.float32))
    dc = jnp.sqrt(jnp.maximum(d2, 0.0))
    delta = NUM_DELTA_REL * (q2[:, None] + s2[None, :])
    eps_num = delta / jnp.maximum(dc, jnp.sqrt(delta))
    eps_t = seps[None, :] + qeps[:, None] + eps_num + NUM_TOL_ABS
    return jnp.maximum(dc - eps_t, 0.0)


def quant_coarse_gather_kernel(
    # scalar-prefetch refs, then tensor refs:
    sched_ref, cnt_ref, qi_ref, qsc_ref, qeps_ref, th_ref,
    si_ref, ssc_ref, seps_ref, alive_ref, out_lb_ref, out_pos_ref,
    scratch_d, scratch_i,
    *, mp: int, bn: int, max_visits: int,
):
    """One (R tile, visit slot) step: int8 dot → int32 → rescale → fold
    the tile's certified lower bounds into the running sorted mp-run.

    ``si_ref``/``ssc_ref``/``seps_ref``/``alive_ref`` already hold the
    tile the schedule names for this slot (scalar-prefetch index maps),
    so pruned tiles cost zero bytes and zero FLOPs. ``alive`` is the
    *only* row mask — the quantizer's tile-padded layout must ship
    padding rows with ``alive == 0`` (the engine's liveness mask, built
    from ``gids >= 0``, already does).
    """
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        scratch_d[...] = jnp.full_like(scratch_d, jnp.inf)
        scratch_i[...] = jnp.full_like(scratch_i, -1)

    @pl.when(j < cnt_ref[i])
    def _compute():
        tile = sched_ref[i, j]
        lb = coarse_lb_tile(
            qi_ref[...], qsc_ref[...][:, 0], qeps_ref[...][:, 0],
            si_ref[...], ssc_ref[0, 0],
            seps_ref[...][0].astype(jnp.float32))
        gid = tile * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
        # liveness (covers tombstones AND tile padding) + the ε-inflated
        # θ prune (lb ≤ θ keeps every true neighbor: its lb lower-bounds
        # a distance that is ≤ θ)
        keep = (alive_ref[...] > 0.0) & (lb <= th_ref[...])
        lb = jnp.where(keep, lb, jnp.inf)
        td, ti = tile_topk(lb, jnp.broadcast_to(gid, lb.shape), mp)
        scratch_d[...], scratch_i[...] = merge_sorted_runs(
            scratch_d[...], scratch_i[...], td, ti)

    @pl.when(j == max_visits - 1)
    def _flush():
        lbr = scratch_d[...]
        out_lb_ref[...] = lbr
        out_pos_ref[...] = jnp.where(jnp.isfinite(lbr), scratch_i[...], -1)


def quant_coarse_gather_pallas(
    qi: jnp.ndarray,          # (n_r, dim) int8 query codes
    qscale: jnp.ndarray,      # (n_r,) f32
    qeps: jnp.ndarray,        # (n_r,) f32
    theta: jnp.ndarray,       # (n_r,) f32 — ε-inflatable prune threshold
    si: jnp.ndarray,          # (n_s, dim) int8 S codes (tile-padded)
    sscale: jnp.ndarray,      # (ns_tiles,) f32 per-tile scales
    seps: jnp.ndarray,        # (n_s,) f16/f32 per-row error bounds
    alive: jnp.ndarray,       # (n_s,) f32 liveness (>0 = live)
    mp: int,
    schedule: jnp.ndarray,    # (nr_tiles, max_visits) int32
    counts: jnp.ndarray,      # (nr_tiles,) int32
    *,
    bm: int = 128,
    bn: int = 512,
    interpret: bool = False,
):
    """Coarse int8 shortlist: ascending (lb (n_r, mp), pos (n_r, mp)).

    ``pos`` indexes rows of ``si`` (the packed multi-segment layout);
    slots that never saw a live candidate are (-1, +inf). ``mp`` must be
    a power of two. S-side operands must already be padded to whole
    ``bn`` tiles (the quantizer's layout).
    """
    from jax.experimental.pallas import tpu as pltpu

    n_r, d = qi.shape
    n_s = si.shape[0]
    nr_tiles = -(-n_r // bm)
    ns_tiles = n_s // bn
    if ns_tiles * bn != n_s:
        raise ValueError(f"quantized S must be tile-padded: {n_s} % {bn}")
    if schedule.shape[0] != nr_tiles:
        raise ValueError(
            f"schedule has {schedule.shape[0]} rows for {nr_tiles} R tiles")
    if mp & (mp - 1):
        raise ValueError(f"mp must be a power of two, got {mp}")
    max_visits = schedule.shape[1]

    pad_r = nr_tiles * bm - n_r
    qi_p = jnp.pad(qi, ((0, pad_r), (0, 0)))
    col = lambda x, fill: jnp.pad(                      # noqa: E731
        x.astype(jnp.float32), (0, pad_r),
        constant_values=fill).reshape(nr_tiles * bm, 1)
    # padding queries: θ = -inf schedules/keeps nothing
    qsc_p = col(qscale, 1.0)
    qeps_p = col(qeps, 0.0)
    th_p = col(theta, -jnp.inf)
    ssc2 = sscale.astype(jnp.float32).reshape(ns_tiles, 1)
    seps2 = seps.reshape(ns_tiles, bn)
    alive2 = alive.astype(jnp.float32).reshape(ns_tiles, bn)

    kernel = functools.partial(
        quant_coarse_gather_kernel, mp=mp, bn=bn, max_visits=max_visits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nr_tiles, max_visits),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j, sched, cnt: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, sched, cnt: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, sched, cnt: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, sched, cnt: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j, sched, cnt: (sched[i, j], 0)),
            pl.BlockSpec((1, 1), lambda i, j, sched, cnt: (sched[i, j], 0)),
            pl.BlockSpec((1, bn), lambda i, j, sched, cnt: (sched[i, j], 0)),
            pl.BlockSpec((1, bn), lambda i, j, sched, cnt: (sched[i, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, mp), lambda i, j, sched, cnt: (i, 0)),
            pl.BlockSpec((bm, mp), lambda i, j, sched, cnt: (i, 0)),
        ],
        scratch_shapes=[
            pl_scratch((bm, mp), jnp.float32),
            pl_scratch((bm, mp), jnp.int32),
        ],
    )
    out_lb, out_pos = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nr_tiles * bm, mp), jnp.float32),
            jax.ShapeDtypeStruct((nr_tiles * bm, mp), jnp.int32),
        ],
        interpret=interpret,
    )(schedule.astype(jnp.int32), counts.astype(jnp.int32),
      qi_p, qsc_p, qeps_p, th_p, si, ssc2, seps2, alive2)
    return out_lb[:n_r], out_pos[:n_r]
