"""Jit'd public wrappers around the Pallas kernels with backend dispatch.

On TPU the Pallas path runs compiled; everywhere else (CPU CI, the
dry-run's 512 fake host devices) the jnp reference executes — identical
math, so tests interchange them freely. ``interpret=True`` forces the
Pallas kernel body through the interpreter for correctness validation on
CPU (this is how tests/test_kernels.py sweeps shapes/dtypes).

Pruned-DMA note: `distance_topk` has two pruning levels. ``impl="pallas"``
takes the PGBJ visit mask per tile and `pl.when` elides the tile's
*compute* — its HBM→VMEM stream still runs. ``impl="gather"`` runs the
real `distance_topk_gather` kernel: a scalar-prefetch grid
(PrefetchScalarGridSpec) reads each step's S-tile index from the
compacted schedule that `core.schedule.build_tile_schedule` lowers from
the plan's bounds, so pruned tiles are never DMA'd at all — zero bytes,
zero FLOPs. ``impl="gather_interpret"`` pushes the same kernel body
through the interpreter (CPU validation), and
`ref.distance_topk_gather_ref` is the jnp oracle for both.

Serving note: the brute-force ``distance_topk`` path is what
`serve.retrieval.knn_logits(use_kernel=True)` runs over the SIndex's
device-resident pivot-sorted rows (`SIndex.device_rows`) — local row
ids map back to global ones via ``s_ids_sorted``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .assign import assign_pallas
from .distance_topk import distance_topk_gather_pallas, distance_topk_pallas
from .flash_attention import flash_attention_pallas
from .quant_topk import quant_coarse_gather_pallas

__all__ = ["distance_topk", "quant_coarse_topk", "assign",
           "flash_attention", "use_pallas"]


def use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "impl"))
def distance_topk(
    r: jnp.ndarray, s: jnp.ndarray, k: int,
    *, visit_mask: Optional[jnp.ndarray] = None,
    schedule: Optional[jnp.ndarray] = None,
    counts: Optional[jnp.ndarray] = None,
    alive: Optional[jnp.ndarray] = None,
    bm: int = 128, bn: int = 512, impl: str = "auto",
):
    """k nearest rows of s per row of r → (dists ascending, ids int32).

    impl="gather" / "gather_interpret" run the pruned-schedule kernel and
    require ``schedule`` (nr_tiles, max_visits) + ``counts`` (nr_tiles,);
    impl="gather_ref" is its jnp oracle. ``alive`` (optional (n_s,)
    float32 row mask, >0 = live) masks tombstoned / padding rows on the
    gather impls — the megastep's concatenated multi-segment layout.
    Other impls ignore schedule/counts/alive.
    """
    impl = ("pallas" if use_pallas() else "ref") if impl == "auto" else impl
    if impl == "ref":
        return ref.distance_topk_ref(r, s, k)
    if impl in ("gather", "gather_interpret", "gather_ref"):
        if schedule is None or counts is None:
            raise ValueError(f"impl={impl!r} requires schedule and counts")
        if impl == "gather_ref":
            return ref.distance_topk_gather_ref(
                r, s, k, schedule, counts, bm=bm, bn=bn, alive=alive)
        return distance_topk_gather_pallas(
            r, s, k, schedule, counts, alive=alive, bm=bm, bn=bn,
            interpret=impl == "gather_interpret")
    return distance_topk_pallas(
        r, s, k, visit_mask=visit_mask, bm=bm, bn=bn,
        interpret=impl == "interpret")


@functools.partial(jax.jit, static_argnames=("mp", "bm", "bn", "impl"))
def quant_coarse_topk(
    qi: jnp.ndarray, qscale: jnp.ndarray, qeps: jnp.ndarray,
    theta: jnp.ndarray, si: jnp.ndarray, sscale: jnp.ndarray,
    seps: jnp.ndarray, alive: jnp.ndarray, mp: int,
    *, schedule: Optional[jnp.ndarray] = None,
    counts: Optional[jnp.ndarray] = None,
    bm: int = 128, bn: int = 512, impl: str = "auto",
):
    """Int8 coarse shortlist for the quantized tier (`repro.quant`):
    ascending certified lower bounds + packed row positions, (n, mp).

    impl="pallas"/"pallas_interpret" run the schedule-driven gather
    kernel (requires ``schedule`` + ``counts``; int8 tiles are the only
    bytes streamed); impl="ref_sched" is its schedule-consuming scan
    twin (same visit list, CPU validation); impl="ref" is the dense jnp
    oracle (ignores the schedule — a sound candidate superset). The
    shortlist is NOT a result: callers must re-rank it with exact fp32
    distances and certify the exclusion (see `repro.quant.engine`).
    """
    impl = ("pallas" if use_pallas() else "ref") if impl == "auto" else impl
    if impl == "ref":
        return ref.quant_coarse_topk_ref(
            qi, qscale, qeps, theta, si, sscale, seps, alive, mp, bn=bn)
    if impl in ("pallas", "pallas_interpret", "ref_sched"):
        if schedule is None or counts is None:
            raise ValueError(f"impl={impl!r} requires schedule and counts")
        if impl == "ref_sched":
            return ref.quant_coarse_sched_ref(
                qi, qscale, qeps, theta, si, sscale, seps, alive, mp,
                schedule, counts, bm=bm, bn=bn)
        return quant_coarse_gather_pallas(
            qi, qscale, qeps, theta, si, sscale, seps, alive, mp,
            schedule, counts, bm=bm, bn=bn,
            interpret=impl == "pallas_interpret")
    raise ValueError(f"unknown quant_coarse_topk impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("bm", "bp", "impl"))
def assign(
    x: jnp.ndarray, pivots: jnp.ndarray,
    *, bm: int = 256, bp: int = 512, impl: str = "auto",
):
    """Nearest-pivot id + distance per row."""
    impl = ("pallas" if use_pallas() else "ref") if impl == "auto" else impl
    if impl == "ref":
        return ref.assign_ref(x, pivots)
    return assign_pallas(x, pivots, bm=bm, bp=bp,
                         interpret=impl == "interpret")


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "impl"))
def flash_attention(
    q, k, v, *, causal: bool = True, window: int | None = None,
    scale: float | None = None, bq: int = 128, bk: int = 128,
    impl: str = "auto",
):
    """Attention over (b, n, h, d) tensors; GQA via kv-head broadcast."""
    impl = ("pallas" if use_pallas() else "ref") if impl == "auto" else impl
    if impl == "ref":
        return ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        bq=bq, bk=bk, interpret=impl == "interpret")
