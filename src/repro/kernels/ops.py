"""Jit'd public wrappers around the Pallas kernels with backend dispatch.

On TPU the Pallas path runs compiled; everywhere else (CPU CI, the
dry-run's 512 fake host devices) the jnp reference executes — identical
math, so tests interchange them freely. ``interpret=True`` forces the
Pallas kernel body through the interpreter for correctness validation on
CPU (this is how tests/test_kernels.py sweeps shapes/dtypes).

Pruned-DMA note: `distance_topk` takes the PGBJ visit mask per tile.
`pl.when` elides the tile's *compute*; eliding its HBM→VMEM stream too
requires a scalar-prefetch grid (PrefetchScalarGridSpec) that reorders the
S tiles per R tile — implemented as `distance_topk_gather` via host-side
schedule compaction instead (the schedule is static given the plan, so we
compact the S tile list before launch and keep the kernel dense).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .assign import assign_pallas
from .distance_topk import distance_topk_pallas
from .flash_attention import flash_attention_pallas

__all__ = ["distance_topk", "assign", "flash_attention", "use_pallas"]


def use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "impl"))
def distance_topk(
    r: jnp.ndarray, s: jnp.ndarray, k: int,
    *, visit_mask: Optional[jnp.ndarray] = None,
    bm: int = 128, bn: int = 512, impl: str = "auto",
):
    """k nearest rows of s per row of r → (dists ascending, ids int32)."""
    impl = ("pallas" if use_pallas() else "ref") if impl == "auto" else impl
    if impl == "ref":
        return ref.distance_topk_ref(r, s, k)
    return distance_topk_pallas(
        r, s, k, visit_mask=visit_mask, bm=bm, bn=bn,
        interpret=impl == "interpret")


@functools.partial(jax.jit, static_argnames=("bm", "bp", "impl"))
def assign(
    x: jnp.ndarray, pivots: jnp.ndarray,
    *, bm: int = 256, bp: int = 512, impl: str = "auto",
):
    """Nearest-pivot id + distance per row."""
    impl = ("pallas" if use_pallas() else "ref") if impl == "auto" else impl
    if impl == "ref":
        return ref.assign_ref(x, pivots)
    return assign_pallas(x, pivots, bm=bm, bp=bp,
                         interpret=impl == "interpret")


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "impl"))
def flash_attention(
    q, k, v, *, causal: bool = True, window: int | None = None,
    scale: float | None = None, bq: int = 128, bk: int = 128,
    impl: str = "auto",
):
    """Attention over (b, n, h, d) tensors; GQA via kv-head broadcast."""
    impl = ("pallas" if use_pallas() else "ref") if impl == "auto" else impl
    if impl == "ref":
        return ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        bq=bq, bk=bk, interpret=impl == "interpret")
