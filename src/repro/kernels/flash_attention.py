"""Pallas TPU kernel: flash attention (forward) for the LM substrate.

Used by the serving path (prefill) and available to training; the jnp
reference path (ref.flash_attention_ref) is what the dry-run lowers, so
kernels never block CPU compilation. Supports causal masking, GQA
(kv_heads dividing q heads) and local windows (recurrentgemma).

Grid: ``(batch*heads, q_tiles, kv_tiles)`` — online softmax statistics
(running max m, normalizer l, accumulator acc) live in VMEM scratch across
the kv (minor) dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .distance_topk import pl_scratch

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None,
    bq: int, bk: int, nk_tiles: int, n_q: int, n_k: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global positions; queries are right-aligned to the kv sequence
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (n_k - n_q)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # tile-level skip: fully-masked tiles never touch the MXU
    first_q = qi * bq + (n_k - n_q)
    last_q = first_q + bq - 1
    first_k, last_k = kj * bk, kj * bk + bk - 1
    relevant = jnp.bool_(True)
    if causal:
        relevant &= first_k <= last_q
    if window is not None:
        relevant &= last_k > first_q - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        mask = (k_pos < n_k) & (q_pos < n_k)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_scr[..., 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[..., 0] = l_scr[..., 0] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[..., 0] = m_new

    @pl.when(kj == nk_tiles - 1)
    def _flush():
        l = l_scr[..., 0]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,   # (b, nq, h, d)
    k: jnp.ndarray,   # (b, nk, kvh, d)
    v: jnp.ndarray,   # (b, nk, kvh, d)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    b, nq, h, d = q.shape
    _, nk, kvh, _ = k.shape
    assert h % kvh == 0
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    nq_tiles = -(-nq // bq)
    nk_tiles = -(-nk // bk)
    # layout: (b*h, seq, d) with kv heads repeated logically via index_map
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, nq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, nk, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, nk, d)
    qr = jnp.pad(qr, ((0, 0), (0, nq_tiles * bq - nq), (0, 0)))
    kr = jnp.pad(kr, ((0, 0), (0, nk_tiles * bk - nk), (0, 0)))
    vr = jnp.pad(vr, ((0, 0), (0, nk_tiles * bk - nk), (0, 0)))
    rep = h // kvh

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk_tiles=nk_tiles, n_q=nq, n_k=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq_tiles, nk_tiles),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            # kv head shared across `rep` q heads (GQA)
            pl.BlockSpec((1, bk, d), lambda g, i, j, rep=rep: (g // rep, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j, rep=rep: (g // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq_tiles * bq, d), q.dtype),
        scratch_shapes=[
            pl_scratch((bq, 1), jnp.float32),
            pl_scratch((bq, 1), jnp.float32),
            pl_scratch((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out[:, :nq].reshape(b, h, nq, d).transpose(0, 2, 1, 3)
