"""Pallas TPU kernel: nearest-pivot assignment (PGBJ phase-1 hot loop).

Fuses the paper's job-1 map: for each object tile, distances to every
pivot tile (MXU) with a running (min, argmin) in VMEM — one pass over the
data, no materialized (n, M) distance matrix in HBM.

Grid: ``(n_tiles, m_tiles)`` — pivots minor, so the running min persists
per data tile and flushes on the last pivot step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .distance_topk import pl_scratch

__all__ = ["assign_kernel", "assign_pallas"]


def assign_kernel(
    x_ref, p_ref, pid_ref, dist_ref, min_d, min_i,
    *, m: int, bp: int, mp_tiles: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        min_d[...] = jnp.full_like(min_d, jnp.inf)
        min_i[...] = jnp.full_like(min_i, -1)

    x = x_ref[...].astype(jnp.float32)                    # (bm, d)
    p = p_ref[...].astype(jnp.float32)                    # (bp, d)
    d2 = (jnp.sum(x * x, axis=1, keepdims=True)
          + jnp.sum(p * p, axis=1)[None, :]
          - 2.0 * jax.lax.dot_general(
              x, p, (((1,), (1,)), ((), ())),
              preferred_element_type=jnp.float32))
    d2 = jnp.maximum(d2, 0.0)
    gid = j * bp + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where(gid < m, d2, jnp.inf)                  # mask pivot padding
    tile_min = jnp.min(d2, axis=1)
    tile_arg = jnp.argmin(d2, axis=1).astype(jnp.int32) + j * bp
    better = tile_min < min_d[..., 0]
    min_i[..., 0] = jnp.where(better, tile_arg, min_i[..., 0])
    min_d[..., 0] = jnp.where(better, tile_min, min_d[..., 0])

    @pl.when(j == mp_tiles - 1)
    def _flush():
        pid_ref[..., 0] = min_i[..., 0]
        dist_ref[..., 0] = jnp.sqrt(min_d[..., 0])


def assign_pallas(
    x: jnp.ndarray,
    pivots: jnp.ndarray,
    *,
    bm: int = 256,
    bp: int = 512,
    interpret: bool = False,
):
    """(part_id (n,), dist (n,)) — nearest pivot per row of x."""
    n, d = x.shape
    m, _ = pivots.shape
    n_tiles = -(-n // bm)
    mp_tiles = -(-m // bp)
    x_pad = jnp.pad(x, ((0, n_tiles * bm - n), (0, 0)))
    p_pad = jnp.pad(pivots, ((0, mp_tiles * bp - m), (0, 0)))
    kernel = functools.partial(assign_kernel, m=m, bp=bp, mp_tiles=mp_tiles)
    pid, dist = pl.pallas_call(
        kernel,
        grid=(n_tiles, mp_tiles),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles * bm, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles * bm, 1), jnp.float32),
        ],
        scratch_shapes=[
            pl_scratch((bm, 1), jnp.float32),
            pl_scratch((bm, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x_pad, p_pad)
    return pid[:n, 0], dist[:n, 0]
