"""Pallas TPU kernels for the compute hot-spots (+ jnp oracles).

- distance_topk: the PGBJ reducer loop (paper Alg. 3)   [core hot-spot]
- assign:        phase-1 nearest-pivot map               [core hot-spot]
- flash_attention: LM substrate prefill/train attention  [substrate]
"""
from .ops import distance_topk, assign, flash_attention, use_pallas
from . import ref

__all__ = ["distance_topk", "assign", "flash_attention", "use_pallas", "ref"]
