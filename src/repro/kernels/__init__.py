"""Pallas TPU kernels for the compute hot-spots (+ jnp oracles).

- distance_topk: the PGBJ reducer loop (paper Alg. 3)   [core hot-spot]
- quant_coarse_topk: int8 coarse shortlist scan          [quantized tier]
- assign:        phase-1 nearest-pivot map               [core hot-spot]
- flash_attention: LM substrate prefill/train attention  [substrate]
"""
from .ops import (
    distance_topk, quant_coarse_topk, assign, flash_attention, use_pallas)
from . import ref

__all__ = ["distance_topk", "quant_coarse_topk", "assign",
           "flash_attention", "use_pallas", "ref"]
