"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["distance_topk_ref", "distance_topk_gather_ref", "assign_ref",
           "flash_attention_ref"]


def distance_topk_ref(r: jnp.ndarray, s: jnp.ndarray, k: int):
    """Exact k smallest L2 distances of each r row over s rows.

    Returns (dists (nr, k) ascending true distances, ids (nr, k) int32).
    """
    r = r.astype(jnp.float32)
    s = s.astype(jnp.float32)
    d2 = (jnp.sum(r * r, 1)[:, None] + jnp.sum(s * s, 1)[None, :]
          - 2.0 * (r @ s.T))
    d2 = jnp.maximum(d2, 0.0)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(-neg), idx.astype(jnp.int32)


def distance_topk_gather_ref(
    r: jnp.ndarray, s: jnp.ndarray, k: int,
    schedule: jnp.ndarray, counts: jnp.ndarray, *, bm: int, bn: int,
    alive: jnp.ndarray | None = None,
):
    """Oracle for the pruned-schedule kernel: mask unscheduled tiles.

    Computes the dense distance matrix, then restricts each R tile's
    candidate columns to the S tiles its schedule row names — the same
    candidate set ``distance_topk_gather_pallas`` ever sees. ``alive``
    (optional (n_s,) float32, >0 = live) additionally masks tombstoned /
    per-segment-padding rows, mirroring the kernel's megastep mask.
    """
    r = r.astype(jnp.float32)
    s = s.astype(jnp.float32)
    n_r, n_s = r.shape[0], s.shape[0]
    nr_tiles = -(-n_r // bm)
    ns_tiles = -(-n_s // bn)
    # (nr_tiles, ns_tiles) allowed mask from the compacted schedule
    slot = jnp.arange(schedule.shape[1])[None, :, None]          # (1, V, 1)
    hit = (schedule[:, :, None] == jnp.arange(ns_tiles)[None, None, :])
    allowed = jnp.any(hit & (slot < counts[:, None, None]), axis=1)
    row_tile = jnp.arange(n_r) // bm
    col_tile = jnp.arange(n_s) // bn
    mask = allowed[row_tile][:, col_tile]                        # (n_r, n_s)
    if alive is not None:
        mask = mask & (alive.astype(jnp.float32) > 0.0)[None, :]
    d2 = (jnp.sum(r * r, 1)[:, None] + jnp.sum(s * s, 1)[None, :]
          - 2.0 * (r @ s.T))
    d2 = jnp.where(mask, jnp.maximum(d2, 0.0), jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(-neg), idx.astype(jnp.int32)


def assign_ref(x: jnp.ndarray, pivots: jnp.ndarray):
    """Nearest pivot per row: (part_id int32, true distance f32)."""
    x = x.astype(jnp.float32)
    p = pivots.astype(jnp.float32)
    d2 = (jnp.sum(x * x, 1)[:, None] + jnp.sum(p * p, 1)[None, :]
          - 2.0 * (x @ p.T))
    d2 = jnp.maximum(d2, 0.0)
    pid = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return pid, jnp.sqrt(jnp.take_along_axis(d2, pid[:, None], 1))[:, 0]


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window: int | None = None,
    scale: float | None = None,
):
    """Reference attention. q (b, nq, h, d); k/v (b, nk, kvh, d).

    GQA: h must be a multiple of kvh; kv heads are repeated.
    ``window``: local attention — query i sees keys in (i-window, i].
    """
    b, nq, h, d = q.shape
    _, nk, kvh, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(nq)[:, None] + (nk - nq)   # align to right edge (decode)
    ki = jnp.arange(nk)[None, :]
    mask = jnp.ones((nq, nk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out
