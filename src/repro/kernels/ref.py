"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["distance_topk_ref", "distance_topk_gather_ref",
           "quant_coarse_topk_ref", "quant_coarse_sched_ref",
           "assign_ref", "flash_attention_ref"]


def distance_topk_ref(r: jnp.ndarray, s: jnp.ndarray, k: int):
    """Exact k smallest L2 distances of each r row over s rows.

    Returns (dists (nr, k) ascending true distances, ids (nr, k) int32).
    """
    r = r.astype(jnp.float32)
    s = s.astype(jnp.float32)
    d2 = (jnp.sum(r * r, 1)[:, None] + jnp.sum(s * s, 1)[None, :]
          - 2.0 * (r @ s.T))
    d2 = jnp.maximum(d2, 0.0)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(-neg), idx.astype(jnp.int32)


def distance_topk_gather_ref(
    r: jnp.ndarray, s: jnp.ndarray, k: int,
    schedule: jnp.ndarray, counts: jnp.ndarray, *, bm: int, bn: int,
    alive: jnp.ndarray | None = None,
):
    """Oracle for the pruned-schedule kernel: mask unscheduled tiles.

    Computes the dense distance matrix, then restricts each R tile's
    candidate columns to the S tiles its schedule row names — the same
    candidate set ``distance_topk_gather_pallas`` ever sees. ``alive``
    (optional (n_s,) float32, >0 = live) additionally masks tombstoned /
    per-segment-padding rows, mirroring the kernel's megastep mask.
    """
    r = r.astype(jnp.float32)
    s = s.astype(jnp.float32)
    n_r, n_s = r.shape[0], s.shape[0]
    nr_tiles = -(-n_r // bm)
    ns_tiles = -(-n_s // bn)
    # (nr_tiles, ns_tiles) allowed mask from the compacted schedule
    slot = jnp.arange(schedule.shape[1])[None, :, None]          # (1, V, 1)
    hit = (schedule[:, :, None] == jnp.arange(ns_tiles)[None, None, :])
    allowed = jnp.any(hit & (slot < counts[:, None, None]), axis=1)
    row_tile = jnp.arange(n_r) // bm
    col_tile = jnp.arange(n_s) // bn
    mask = allowed[row_tile][:, col_tile]                        # (n_r, n_s)
    if alive is not None:
        mask = mask & (alive.astype(jnp.float32) > 0.0)[None, :]
    d2 = (jnp.sum(r * r, 1)[:, None] + jnp.sum(s * s, 1)[None, :]
          - 2.0 * (r @ s.T))
    d2 = jnp.where(mask, jnp.maximum(d2, 0.0), jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(-neg), idx.astype(jnp.int32)


def quant_coarse_topk_ref(
    qi: jnp.ndarray, qscale: jnp.ndarray, qeps: jnp.ndarray,
    theta: jnp.ndarray, si: jnp.ndarray, sscale: jnp.ndarray,
    seps: jnp.ndarray, alive: jnp.ndarray, mp: int, *, bn: int,
):
    """Oracle for the int8 coarse-scan kernel (`kernels.quant_topk`):
    dense certified-lower-bound matrix + top-mp selection.

    Same rescale formula (int8 dot → int32 → f32 rescale → ε-inflated
    lower bound, see `quant_topk.coarse_lb_tile`) over *all* S rows —
    a candidate superset of any schedule, which is fine: the quantized
    tier's exactness rests on the shortlist's re-rank + certification,
    not on which sound shortlist an impl picks. ``sscale`` is per tile
    ((n_s // bn,)); ``theta`` is the per-query ε-inflatable prune
    threshold; ``alive`` masks tombstones/padding. Returns ascending
    (lb (n, mp), pos (n, mp)); empty slots are (+inf, -1).
    """
    from .quant_topk import coarse_lb_tile

    # the kernel's exact bound formula over all tiles fused into one
    # call: coarse_lb_tile takes the per-tile scales as a per-row
    # vector, so the int8 contraction stays a single matmul. f32_dot:
    # bit-identical to the int32 form (exact-integer f32 sums) but hits
    # the BLAS gemm on CPU instead of a scalar int32 loop
    lb = coarse_lb_tile(
        qi, qscale, qeps, si,
        jnp.repeat(sscale.astype(jnp.float32), bn),
        seps.astype(jnp.float32), f32_dot=True)
    keep = (alive.astype(jnp.float32) > 0.0)[None, :] \
        & (lb <= theta[:, None])
    lb = jnp.where(keep, lb, jnp.inf)
    mp_eff = min(mp, lb.shape[-1])     # shortlist wider than S: take all
    neg, pos = jax.lax.top_k(-lb, mp_eff)
    lb_run = -neg
    pos = jnp.where(jnp.isfinite(lb_run), pos, -1).astype(jnp.int32)
    if mp_eff < mp:
        pad = ((0, 0), (0, mp - mp_eff))
        lb_run = jnp.pad(lb_run, pad, constant_values=jnp.inf)
        pos = jnp.pad(pos, pad, constant_values=-1)
    return lb_run, pos


def quant_coarse_sched_ref(
    qi: jnp.ndarray, qscale: jnp.ndarray, qeps: jnp.ndarray,
    theta: jnp.ndarray, si: jnp.ndarray, sscale: jnp.ndarray,
    seps: jnp.ndarray, alive: jnp.ndarray, mp: int,
    schedule: jnp.ndarray, counts: jnp.ndarray, *, bm: int, bn: int,
):
    """Schedule-driven scan twin of the int8 coarse kernel: the same
    visit list, the same per-tile `coarse_lb_tile` rescale, the same
    carried sorted mp-run — the CPU validation path for the quantized
    tier's in-jit schedule consumption (mirrors the fp32 megastep's
    ``ref_sched``). Query operands must already be padded to whole
    ``bm`` tiles (the engine's bucketing guarantees it)."""
    from .quant_topk import coarse_lb_tile
    from .sorted_merge import merge_sorted_runs, tile_topk

    n_r = qi.shape[0]
    nr_tiles = n_r // bm
    ns_tiles = si.shape[0] // bn
    dim = qi.shape[1]
    q3 = qi.reshape(nr_tiles, bm, dim)
    qs3 = qscale.reshape(nr_tiles, bm)
    qe3 = qeps.reshape(nr_tiles, bm)
    th3 = theta.reshape(nr_tiles, bm)
    s3 = si.reshape(ns_tiles, bn, dim)
    seps3 = seps.astype(jnp.float32).reshape(ns_tiles, bn)
    alive3 = alive.astype(jnp.float32).reshape(ns_tiles, bn)
    lb_of_tile = jax.vmap(
        lambda a, b, c, d, e, f: coarse_lb_tile(a, b, c, d, e, f,
                                                f32_dot=True))

    def body(carry, xs):
        cd, ci = carry
        tile_idx, j = xs                          # (nr_tiles,), ()
        lb = lb_of_tile(q3, qs3, qe3, s3[tile_idx],
                        sscale[tile_idx], seps3[tile_idx])
        pos = tile_idx[:, None] * bn + jnp.arange(bn)[None, :]
        keep = ((j < counts)[:, None, None]
                & (alive3[tile_idx][:, None, :] > 0.0)
                & (lb <= th3[..., None]))
        lb = jnp.where(keep, lb, jnp.inf)
        td, ti = tile_topk(
            lb, jnp.broadcast_to(pos[:, None, :], lb.shape), mp)
        return merge_sorted_runs(cd, ci, td, ti), None

    carry0 = (jnp.full((nr_tiles, bm, mp), jnp.inf, jnp.float32),
              jnp.full((nr_tiles, bm, mp), -1, jnp.int32))
    (cd, ci), _ = jax.lax.scan(
        body, carry0,
        (schedule.T, jnp.arange(schedule.shape[1], dtype=jnp.int32)))
    lb_run = cd.reshape(n_r, mp)
    pos = ci.reshape(n_r, mp)
    return lb_run, jnp.where(jnp.isfinite(lb_run), pos, -1)


def assign_ref(x: jnp.ndarray, pivots: jnp.ndarray):
    """Nearest pivot per row: (part_id int32, true distance f32)."""
    x = x.astype(jnp.float32)
    p = pivots.astype(jnp.float32)
    d2 = (jnp.sum(x * x, 1)[:, None] + jnp.sum(p * p, 1)[None, :]
          - 2.0 * (x @ p.T))
    d2 = jnp.maximum(d2, 0.0)
    pid = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return pid, jnp.sqrt(jnp.take_along_axis(d2, pid[:, None], 1))[:, 0]


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window: int | None = None,
    scale: float | None = None,
):
    """Reference attention. q (b, nq, h, d); k/v (b, nk, kvh, d).

    GQA: h must be a multiple of kvh; kv heads are repeated.
    ``window``: local attention — query i sees keys in (i-window, i].
    """
    b, nq, h, d = q.shape
    _, nk, kvh, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(nq)[:, None] + (nk - nq)   # align to right edge (decode)
    ki = jnp.arange(nk)[None, :]
    mask = jnp.ones((nq, nk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out
