"""Pallas TPU kernels: tiled pairwise L2 + streaming top-k.

This is the PGBJ reducer hot loop (Algorithm 3, lines 16-25) as fused
kernels: the `-2 R Sᵀ` contraction runs on the MXU; a per-row running
top-k lives in VMEM scratch across the S grid dimension as a *sorted
run* (see kernels.sorted_merge); the paper's pruning rules (Cor. 1 /
Thm 2 evaluated at tile granularity — DESIGN.md §2.1) enter two ways:

* ``distance_topk_pallas`` — dense ``(nr_tiles, ns_tiles)`` grid with an
  optional per-tile visit mask. ``pl.when`` elides a pruned tile's
  *compute* but its HBM→VMEM stream still runs.

* ``distance_topk_gather_pallas`` — pruned-schedule execution. The grid
  is ``(nr_tiles, max_visits)`` and the S-tile index of each step is read
  from a scalar-prefetched compacted schedule (core.schedule), so pruned
  tiles are **never DMA'd**: skipped tiles cost zero bytes and zero
  FLOPs. Schedule rows are padded by repeating their last entry — an
  unchanged block index means the pipeline re-uses the resident VMEM
  block instead of issuing a new copy.

  The optional ``alive`` row mask (float32, >0 = live) serves the fused
  megastep (core.megastep): the schedule may concatenate the tile ranges
  of *several* index segments, and the per-query running top-k then
  carries across segment boundaries in VMEM scratch — one launch per
  micro-batch instead of one per segment, with no per-segment (n, k)
  runs round-tripping through HBM. Tombstoned rows and per-segment
  padding rows arrive with ``alive == 0`` and are masked to +inf
  *before* selection, so the flushed run is the exact top-k over live
  rows only.

VMEM budget per step (bm=128, bn=512, d≤128, k≤64, f32):
  R tile 64 KiB + S tile 256 KiB + dist tile 256 KiB + scratch 2·32 KiB
  + sort temporaries ≈ 1 MiB  — comfortably inside the ~16 MiB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sorted_merge import merge_sorted_runs, next_pow2, tile_topk

__all__ = [
    "distance_topk_kernel", "distance_topk_pallas",
    "distance_topk_gather_kernel", "distance_topk_gather_alive_kernel",
    "distance_topk_gather_pallas",
]


def _sq_dists(r_ref, s_ref):
    """(bm, bn) squared L2 distances between the resident tiles."""
    r = r_ref[...].astype(jnp.float32)                    # (bm, d)
    s = s_ref[...].astype(jnp.float32)                    # (bn, d)
    d2 = (jnp.sum(r * r, axis=1, keepdims=True)
          + jnp.sum(s * s, axis=1)[None, :]
          - 2.0 * jax.lax.dot_general(
              r, s, (((1,), (1,)), ((), ())),
              preferred_element_type=jnp.float32))
    return jnp.maximum(d2, 0.0)


def _merge_tile(scratch_d, scratch_i, d2, ids, kp: int):
    """Fold one tile of candidates into the running sorted kp-run."""
    td, ti = tile_topk(d2, ids, kp)
    scratch_d[...], scratch_i[...] = merge_sorted_runs(
        scratch_d[...], scratch_i[...], td, ti)


def distance_topk_kernel(
    # refs:
    r_ref, s_ref, mask_ref, out_d_ref, out_i_ref, scratch_d, scratch_i,
    *, k: int, kp: int, n_s: int, bn: int, ns_tiles: int,
):
    """One (R tile, S tile) grid step of the dense (masked) kernel."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        scratch_d[...] = jnp.full_like(scratch_d, jnp.inf)
        scratch_i[...] = jnp.full_like(scratch_i, -1)

    visit = mask_ref[0, 0] != 0

    @pl.when(visit)
    def _compute():
        d2 = _sq_dists(r_ref, s_ref)
        # mask S padding rows (global id >= n_s)
        gid = j * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
        d2 = jnp.where(gid < n_s, d2, jnp.inf)
        _merge_tile(scratch_d, scratch_i, d2,
                    jnp.broadcast_to(gid, d2.shape), kp)

    @pl.when(j == ns_tiles - 1)
    def _flush():
        out_d_ref[...] = jnp.sqrt(scratch_d[...][:, :k])
        out_i_ref[...] = scratch_i[...][:, :k]


def distance_topk_pallas(
    r: jnp.ndarray,
    s: jnp.ndarray,
    k: int,
    *,
    visit_mask: jnp.ndarray | None = None,
    bm: int = 128,
    bn: int = 512,
    interpret: bool = False,
):
    """k nearest rows of ``s`` for each row of ``r`` (dists ascending, ids).

    visit_mask: optional (nr_tiles, ns_tiles) int8 — tiles proved
    irrelevant by the PGBJ bounds are never computed (their DMA still
    streams; use ``distance_topk_gather_pallas`` to skip the load too).
    """
    n_r, d = r.shape
    n_s, _ = s.shape
    nr_tiles = -(-n_r // bm)
    ns_tiles = -(-n_s // bn)
    kp = next_pow2(k)
    r_pad = jnp.pad(r, ((0, nr_tiles * bm - n_r), (0, 0)))
    s_pad = jnp.pad(s, ((0, ns_tiles * bn - n_s), (0, 0)))
    if visit_mask is None:
        visit_mask = jnp.ones((nr_tiles, ns_tiles), jnp.int8)

    kernel = functools.partial(
        distance_topk_kernel, k=k, kp=kp, n_s=n_s, bn=bn, ns_tiles=ns_tiles)
    out_d, out_i = pl.pallas_call(
        kernel,
        grid=(nr_tiles, ns_tiles),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr_tiles * bm, k), jnp.float32),
            jax.ShapeDtypeStruct((nr_tiles * bm, k), jnp.int32),
        ],
        scratch_shapes=[
            pl_scratch((bm, kp), jnp.float32),
            pl_scratch((bm, kp), jnp.int32),
        ],
        interpret=interpret,
    )(r_pad, s_pad, visit_mask)
    return out_d[:n_r], out_i[:n_r]


def distance_topk_gather_kernel(
    # scalar-prefetch refs, then tensor refs:
    sched_ref, cnt_ref, r_ref, s_ref, out_d_ref, out_i_ref,
    scratch_d, scratch_i,
    *, k: int, kp: int, n_s: int, bn: int, max_visits: int,
):
    """One (R tile, visit slot) step of the pruned-schedule kernel.

    ``s_ref`` already holds the tile the schedule names for this slot —
    the BlockSpec index map reads ``sched_ref`` before the body runs, so
    only scheduled tiles ever cross HBM→VMEM.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        scratch_d[...] = jnp.full_like(scratch_d, jnp.inf)
        scratch_i[...] = jnp.full_like(scratch_i, -1)

    @pl.when(j < cnt_ref[i])
    def _compute():
        tile = sched_ref[i, j]
        d2 = _sq_dists(r_ref, s_ref)
        gid = tile * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
        d2 = jnp.where(gid < n_s, d2, jnp.inf)
        _merge_tile(scratch_d, scratch_i, d2,
                    jnp.broadcast_to(gid, d2.shape), kp)

    @pl.when(j == max_visits - 1)
    def _flush():
        out_d_ref[...] = jnp.sqrt(scratch_d[...][:, :k])
        out_i_ref[...] = scratch_i[...][:, :k]


def distance_topk_gather_alive_kernel(
    # scalar-prefetch refs, then tensor refs:
    sched_ref, cnt_ref, r_ref, s_ref, alive_ref, out_d_ref, out_i_ref,
    scratch_d, scratch_i,
    *, k: int, kp: int, n_s: int, bn: int, max_visits: int,
):
    """The gather kernel with a per-row liveness mask — the megastep's
    in-VMEM cross-segment scan step. ``alive_ref`` holds the scheduled
    tile's (1, bn) float32 mask (tombstones and per-segment padding are
    0); masked rows are +inf *before* the sorted-run fold, so the carried
    VMEM run is always the exact top-k over live rows seen so far."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        scratch_d[...] = jnp.full_like(scratch_d, jnp.inf)
        scratch_i[...] = jnp.full_like(scratch_i, -1)

    @pl.when(j < cnt_ref[i])
    def _compute():
        tile = sched_ref[i, j]
        d2 = _sq_dists(r_ref, s_ref)
        gid = tile * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
        live = (alive_ref[...] > 0.0) & (gid < n_s)
        d2 = jnp.where(live, d2, jnp.inf)
        _merge_tile(scratch_d, scratch_i, d2,
                    jnp.broadcast_to(gid, d2.shape), kp)

    @pl.when(j == max_visits - 1)
    def _flush():
        out_d_ref[...] = jnp.sqrt(scratch_d[...][:, :k])
        out_i_ref[...] = scratch_i[...][:, :k]


def distance_topk_gather_pallas(
    r: jnp.ndarray,
    s: jnp.ndarray,
    k: int,
    schedule: jnp.ndarray,
    counts: jnp.ndarray,
    *,
    alive: jnp.ndarray | None = None,
    bm: int = 128,
    bn: int = 512,
    interpret: bool = False,
):
    """Pruned-schedule top-k: each R tile visits only its scheduled S tiles.

    schedule: (nr_tiles, max_visits) int32 S-tile indices, rows padded by
              repeating the last valid entry (core.schedule.TileSchedule).
    counts:   (nr_tiles,) int32 — number of real entries per row.
    alive:    optional (n_s,) float32 row-liveness mask (>0 = live). Used
              by the megastep to mask tombstoned rows and per-segment
              padding inside a concatenated multi-segment layout; rows
              with ``alive == 0`` can never enter the top-k.

    Ids are row indices into ``s`` as laid out here; callers that sorted S
    for tile coherence translate back through their permutation.
    """
    from jax.experimental.pallas import tpu as pltpu

    n_r, d = r.shape
    n_s, _ = s.shape
    nr_tiles = -(-n_r // bm)
    ns_tiles = -(-n_s // bn)
    if schedule.shape[0] != nr_tiles:
        raise ValueError(
            f"schedule has {schedule.shape[0]} rows for {nr_tiles} R tiles "
            f"(bm={bm})")
    max_visits = schedule.shape[1]
    kp = next_pow2(k)
    r_pad = jnp.pad(r, ((0, nr_tiles * bm - n_r), (0, 0)))
    s_pad = jnp.pad(s, ((0, ns_tiles * bn - n_s), (0, 0)))

    kern = (distance_topk_gather_kernel if alive is None
            else distance_topk_gather_alive_kernel)
    kernel = functools.partial(
        kern, k=k, kp=kp, n_s=n_s, bn=bn, max_visits=max_visits)
    in_specs = [
        pl.BlockSpec((bm, d), lambda i, j, sched, cnt: (i, 0)),
        pl.BlockSpec((bn, d), lambda i, j, sched, cnt: (sched[i, j], 0)),
    ]
    args = [schedule.astype(jnp.int32), counts.astype(jnp.int32),
            r_pad, s_pad]
    if alive is not None:
        alive_pad = jnp.pad(alive.astype(jnp.float32),
                            (0, ns_tiles * bn - n_s)).reshape(ns_tiles, bn)
        in_specs.append(
            pl.BlockSpec((1, bn), lambda i, j, sched, cnt: (sched[i, j], 0)))
        args.append(alive_pad)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nr_tiles, max_visits),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j, sched, cnt: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j, sched, cnt: (i, 0)),
        ],
        scratch_shapes=[
            pl_scratch((bm, kp), jnp.float32),
            pl_scratch((bm, kp), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nr_tiles * bm, k), jnp.float32),
            jax.ShapeDtypeStruct((nr_tiles * bm, k), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
    return out_d[:n_r], out_i[:n_r]


def pl_scratch(shape, dtype):
    """VMEM scratch allocation that also works in interpret mode."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
