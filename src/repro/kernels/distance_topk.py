"""Pallas TPU kernel: tiled pairwise L2 + streaming top-k.

This is the PGBJ reducer hot loop (Algorithm 3, lines 16-25) as one fused
kernel: the `-2 R Sᵀ` contraction runs on the MXU; a per-row running
top-k lives in VMEM scratch across the S-chunk grid dimension; the
paper's pruning rules enter as an optional per-tile visit mask (Cor. 1 /
Thm 2 evaluated at partition/tile granularity — DESIGN.md §2.1).

Grid: ``(nr_tiles, ns_tiles)`` — S is the minor (inner, sequential on TPU)
dimension, so the scratch accumulator is valid for a fixed R tile and is
flushed to HBM on the last S step.

VMEM budget per step (bm=128, bn=512, d≤128, k≤64, f32):
  R tile 64 KiB + S tile 256 KiB + dist tile 256 KiB + scratch 2·32 KiB
  + merge temp ≈ 0.9 MiB  — comfortably inside the ~16 MiB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["distance_topk_kernel", "distance_topk_pallas"]



def _merge_topk(run_d, run_i, new_d, new_i, k: int):
    """Merge running (bm, k) with candidate (bm, t) by iterative extract-min.

    k is small (≤64); extract-min k times is branch-free and vectorizes on
    the VPU — the TPU replacement for the paper's priority queue.
    """
    cand_d = jnp.concatenate([run_d, new_d], axis=1)      # (bm, k+t)
    cand_i = jnp.concatenate([run_i, new_i], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, cand_d.shape, 1)

    def step(t, carry):
        cand_d, cand_i, out_d, out_i = carry
        cur = jnp.min(cand_d, axis=1)                     # (bm,)
        pos = jnp.argmin(cand_d, axis=1).astype(jnp.int32)
        sel = cols == pos[:, None]
        cur_i = jnp.max(jnp.where(sel, cand_i, -1), axis=1)
        out_d = jax.lax.dynamic_update_index_in_dim(out_d, cur, t, 1)
        out_i = jax.lax.dynamic_update_index_in_dim(out_i, cur_i, t, 1)
        cand_d = jnp.where(sel, jnp.inf, cand_d)          # retire the min
        return cand_d, cand_i, out_d, out_i

    out_d = jnp.zeros_like(run_d)
    out_i = jnp.zeros_like(run_i)
    _, _, out_d, out_i = jax.lax.fori_loop(
        0, k, step, (cand_d, cand_i, out_d, out_i))
    return out_d, out_i


def distance_topk_kernel(
    # refs:
    r_ref, s_ref, mask_ref, out_d_ref, out_i_ref, scratch_d, scratch_i,
    *, k: int, n_s: int, bn: int, ns_tiles: int,
):
    """One (R tile, S tile) grid step."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        scratch_d[...] = jnp.full_like(scratch_d, jnp.inf)
        scratch_i[...] = jnp.full_like(scratch_i, -1)

    visit = mask_ref[0, 0] != 0

    @pl.when(visit)
    def _compute():
        r = r_ref[...].astype(jnp.float32)                # (bm, d)
        s = s_ref[...].astype(jnp.float32)                # (bn, d)
        d2 = (jnp.sum(r * r, axis=1, keepdims=True)
              + jnp.sum(s * s, axis=1)[None, :]
              - 2.0 * jax.lax.dot_general(
                  r, s, (((1,), (1,)), ((), ())),
                  preferred_element_type=jnp.float32))
        d2 = jnp.maximum(d2, 0.0)
        # mask S padding rows (global id >= n_s)
        gid = j * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
        d2 = jnp.where(gid < n_s, d2, jnp.inf)
        ids = jnp.broadcast_to(gid, d2.shape)
        scratch_d[...], scratch_i[...] = _merge_topk(
            scratch_d[...], scratch_i[...], d2, ids, k)

    @pl.when(j == ns_tiles - 1)
    def _flush():
        out_d_ref[...] = jnp.sqrt(scratch_d[...])
        out_i_ref[...] = scratch_i[...]


def distance_topk_pallas(
    r: jnp.ndarray,
    s: jnp.ndarray,
    k: int,
    *,
    visit_mask: jnp.ndarray | None = None,
    bm: int = 128,
    bn: int = 512,
    interpret: bool = False,
):
    """k nearest rows of ``s`` for each row of ``r`` (dists ascending, ids).

    visit_mask: optional (nr_tiles, ns_tiles) int8 — tiles proved
    irrelevant by the PGBJ bounds are never computed (their DMA still
    streams; skipping the *load* needs scalar prefetch, see ops.py note).
    """
    n_r, d = r.shape
    n_s, _ = s.shape
    nr_tiles = -(-n_r // bm)
    ns_tiles = -(-n_s // bn)
    r_pad = jnp.pad(r, ((0, nr_tiles * bm - n_r), (0, 0)))
    s_pad = jnp.pad(s, ((0, ns_tiles * bn - n_s), (0, 0)))
    if visit_mask is None:
        visit_mask = jnp.ones((nr_tiles, ns_tiles), jnp.int8)

    kernel = functools.partial(
        distance_topk_kernel, k=k, n_s=n_s, bn=bn, ns_tiles=ns_tiles)
    out_d, out_i = pl.pallas_call(
        kernel,
        grid=(nr_tiles, ns_tiles),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr_tiles * bm, k), jnp.float32),
            jax.ShapeDtypeStruct((nr_tiles * bm, k), jnp.int32),
        ],
        scratch_shapes=[
            pl_scratch((bm, k), jnp.float32),
            pl_scratch((bm, k), jnp.int32),
        ],
        interpret=interpret,
    )(r_pad, s_pad, visit_mask)
    return out_d[:n_r], out_i[:n_r]


def pl_scratch(shape, dtype):
    """VMEM scratch allocation that also works in interpret mode."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
