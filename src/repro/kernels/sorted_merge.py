"""Sorted-run top-k primitives shared by the Pallas kernels and the XLA
scan reducer (core.distributed).

The paper's reducer keeps a priority queue per query (Algorithm 3, line
18). The previous TPU replacement was iterative extract-min — O(k·(k+t))
VPU work per (R tile, S tile) step with an argmin reduction per extracted
element. Here the running top-k is instead maintained as a *sorted run*:

* ``tile_topk``  — bitonic full sort of the tile's candidate columns
  (once per tile), then slice the smallest ``kp``;
* ``merge_sorted_runs`` — odd-even/bitonic merge of two ascending k-runs
  in log2(2k) compare-exchange stages.

Per tile the cost drops to O(t·log²t + k·log k) fully-vectorized
min/max/where ops. Everything below is expressed as jnp ops on a fixed
(bm, n) shape — no gather, no sort primitive, no data-dependent control
flow — so the same code runs inside a Mosaic kernel body, under
``interpret=True``, and in a plain ``jax.lax.scan`` on any backend.

Compare-exchange uses the XOR-partner formulation: the partner of lane
``x`` at distance ``dist`` is ``x ^ dist``, materialized with two lane
rolls and a select (roll lowers to slice+concatenate, which Mosaic
supports on the lane dimension).

Id payloads: every primitive accepts the id argument either as a single
int array or as a **tuple of arrays** permuted in lockstep with the
distances. The tuple form is how wide ids travel through the network —
jnp arrays are int32 under default JAX config, so a 64-bit row id is
carried as a (hi, lo) int32 pair (see ``core.stream.StreamJoinState``)
instead of being silently truncated.

Consumers: the Pallas tile kernels (`kernels.distance_topk`) fold each
tile through ``tile_topk`` + ``merge_sorted_runs`` in VMEM scratch; the
fused megastep (`core.megastep`) carries the same sorted run across a
*concatenated multi-segment* schedule — one scan/launch instead of one
per segment — and dedup-merges its carried device stream state with
``merge_sorted_runs_unique``; the host ``StreamJoinState`` uses the same
unique merge for revisited query slots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["next_pow2", "bitonic_sort", "tile_topk", "merge_sorted_runs",
           "mask_duplicate_ids", "merge_sorted_runs_unique",
           "tree_merge_runs"]


def next_pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())


def _lane_iota(shape, ndim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, ndim - 1)


def _as_tuple(i):
    return i if isinstance(i, tuple) else (i,)


def _like(i, parts):
    return parts if isinstance(i, tuple) else parts[0]


def _cmp_swap(d, i, dist: int, asc):
    """One compare-exchange stage over XOR-partners at ``dist`` lanes.

    ``asc`` is a bool array broadcastable against ``d`` giving the sort
    direction of each lane's enclosing bitonic block. Ties never swap, so
    duplicate distances keep their original ids. ``i`` is one id array or
    a tuple of id arrays permuted together.
    """
    bitc = (_lane_iota(d.shape, d.ndim) & dist) == 0

    def partner(x):
        return jnp.where(bitc, jnp.roll(x, -dist, axis=-1),
                         jnp.roll(x, dist, axis=-1))

    p_d = partner(d)
    ids = _as_tuple(i)
    p_ids = tuple(partner(x) for x in ids)
    d_gt_p = d > p_d
    p_gt_d = p_d > d
    take = jnp.where(asc, jnp.where(bitc, d_gt_p, p_gt_d),
                     jnp.where(bitc, p_gt_d, d_gt_p))
    out = tuple(jnp.where(take, p, x) for p, x in zip(p_ids, ids))
    return jnp.where(take, p_d, d), _like(i, out)


def bitonic_sort(d, i):
    """Sort ``d`` ascending along the last axis, permuting ``i`` alongside.

    Last-axis length must be a power of two (pad with +inf first).
    Stages are unrolled at trace time: ½·log²n compare-exchanges.
    """
    n = d.shape[-1]
    assert n & (n - 1) == 0, f"bitonic_sort needs pow2 width, got {n}"
    log_n = n.bit_length() - 1
    lanes = _lane_iota(d.shape, d.ndim)
    for s in range(1, log_n + 1):
        asc = ((lanes >> s) & 1) == 0      # final stage: all ascending
        for dist in (1 << p for p in range(s - 1, -1, -1)):
            d, i = _cmp_swap(d, i, dist, asc)
    return d, i


def _pad_cols(d, i, width: int):
    pad = width - d.shape[-1]
    if pad <= 0:
        return d, i
    cfg = [(0, 0)] * (d.ndim - 1) + [(0, pad)]
    return (jnp.pad(d, cfg, constant_values=jnp.inf),
            jnp.pad(i, cfg, constant_values=-1))


def tile_topk(d, i, kp: int):
    """Smallest ``kp`` of each row as an ascending sorted run.

    ``kp`` must be a power of two; columns are +inf-padded up to a power
    of two if needed. Returns (bm, kp) distances/ids.
    """
    assert kp & (kp - 1) == 0, f"tile_topk needs pow2 kp, got {kp}"
    d, i = _pad_cols(d, i, max(next_pow2(d.shape[-1]), kp))
    d, i = bitonic_sort(d, i)
    return d[..., :kp], i[..., :kp]


def merge_sorted_runs(ad, ai, bd, bi):
    """Merge two ascending runs of equal pow2 length; keep the smallest.

    ``concat(A, reverse(B))`` is bitonic, so log2(2k)+1 compare-exchange
    stages sort it; the first k lanes are the merged smallest-k run.
    Ids may be single arrays or matching tuples of arrays.
    """
    kp = ad.shape[-1]
    assert kp == bd.shape[-1] and kp & (kp - 1) == 0
    d = jnp.concatenate([ad, jnp.flip(bd, axis=-1)], axis=-1)
    i = _like(ai, tuple(
        jnp.concatenate([a, jnp.flip(b, axis=-1)], axis=-1)
        for a, b in zip(_as_tuple(ai), _as_tuple(bi))))
    dist = kp
    while dist >= 1:
        d, i = _cmp_swap(d, i, dist, True)
        dist //= 2
    return d[..., :kp], _like(ai, tuple(
        x[..., :kp] for x in _as_tuple(i)))


def mask_duplicate_ids(ad, ai, bd, bi):
    """Suppress B-run entries whose id already appears in the A run.

    An id that occurs in both runs references the same underlying row, so
    both copies carry the same distance in this codebase (every engine
    reports ``metrics.canonical_topk`` distances, a pure function of the
    (query, row) pair); A absorbs the elementwise-min of its duplicates'
    distances anyway so the smaller value survives even if a caller feeds
    diverging copies, and B's copy is demoted to (+inf, -1) so the merge
    can never return the same row twice. Padding lanes (id -1, +inf) are
    "duplicates" of each other by this rule, which is a no-op. O(k²)
    fully-vectorized compares — tuple ids match on every component.
    """
    ais, bis = _as_tuple(ai), _as_tuple(bi)
    eq = None
    for a, b in zip(ais, bis):
        e = a[..., :, None] == b[..., None, :]       # (..., ka, kb)
        eq = e if eq is None else eq & e
    ad = jnp.minimum(
        ad, jnp.min(jnp.where(eq, bd[..., None, :], jnp.inf), axis=-1))
    b_dup = jnp.any(eq, axis=-2)
    bd = jnp.where(b_dup, jnp.inf, bd)
    bis = tuple(jnp.where(b_dup, -1, x) for x in bis)
    return ad, ai, bd, _like(bi, bis)


def merge_sorted_runs_unique(ad, ai, bd, bi):
    """Top-k merge with id dedup: a row present in both runs (the same
    query slot revisited with overlapping candidate sets — the
    multi-segment / re-query-after-compaction path) contributes one
    entry, at its smaller distance, instead of occupying two top-k slots.

    Dedup masking punches +inf holes into the middle of the runs, so the
    bitonic precondition of the cheap odd-even merge no longer holds;
    the merged order is re-established with a full bitonic sort of the
    concatenation — ½·log²(2k) stages instead of log2(2k), paid only on
    the streaming-state path, never inside the tile kernels.
    """
    ad, ai, bd, bi = mask_duplicate_ids(ad, ai, bd, bi)
    kp = ad.shape[-1]
    assert kp == bd.shape[-1] and kp & (kp - 1) == 0
    d = jnp.concatenate([ad, bd], axis=-1)
    i = _like(ai, tuple(
        jnp.concatenate([a, b], axis=-1)
        for a, b in zip(_as_tuple(ai), _as_tuple(bi))))
    d, i = bitonic_sort(d, i)
    return d[..., :kp], _like(ai, tuple(
        x[..., :kp] for x in _as_tuple(i)))


def tree_merge_runs(runs, *, unique: bool = False):
    """Fold N ascending ``(d, ids)`` runs into one through a balanced
    pairwise merge tree — ⌈log2 N⌉ rounds of `merge_sorted_runs`.

    This is the sharded megastep's reduction (`core.sharded`): each mesh
    shard contributes its exact per-shard top-kp run, and because rows
    live on exactly one shard the runs are id-disjoint, so the cheap
    odd-even merge suffices — padding lanes (+inf, id −1) just sink to
    the tail. Pass ``unique=True`` when the runs may overlap (carried
    stream states); that routes each fold through the dedup merge
    instead. All runs must share the same pow2 width; ids may be single
    arrays or lockstep tuples.

    The fold is subset-stable: merging any non-empty *subset* of
    id-disjoint runs yields exactly the top-k restricted to that
    subset's rows (the degraded-coverage serving path merges only the
    surviving shards' runs — pinned by the property tests).
    """
    assert runs, "tree_merge_runs needs at least one run"
    widths = {int(d.shape[-1]) for d, _ in runs}
    if len(widths) != 1:
        raise ValueError(
            f"tree_merge_runs needs equal-width runs, got widths "
            f"{sorted(widths)} — pad every run to one pow2 width first")
    fold = merge_sorted_runs_unique if unique else merge_sorted_runs
    runs = list(runs)
    while len(runs) > 1:
        nxt = []
        for a in range(0, len(runs) - 1, 2):
            (ad, ai), (bd, bi) = runs[a], runs[a + 1]
            nxt.append(fold(ad, ai, bd, bi))
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    return runs[0]
