"""Sorted-run top-k primitives shared by the Pallas kernels and the XLA
scan reducer (core.distributed).

The paper's reducer keeps a priority queue per query (Algorithm 3, line
18). The previous TPU replacement was iterative extract-min — O(k·(k+t))
VPU work per (R tile, S tile) step with an argmin reduction per extracted
element. Here the running top-k is instead maintained as a *sorted run*:

* ``tile_topk``  — bitonic full sort of the tile's candidate columns
  (once per tile), then slice the smallest ``kp``;
* ``merge_sorted_runs`` — odd-even/bitonic merge of two ascending k-runs
  in log2(2k) compare-exchange stages.

Per tile the cost drops to O(t·log²t + k·log k) fully-vectorized
min/max/where ops. Everything below is expressed as jnp ops on a fixed
(bm, n) shape — no gather, no sort primitive, no data-dependent control
flow — so the same code runs inside a Mosaic kernel body, under
``interpret=True``, and in a plain ``jax.lax.scan`` on any backend.

Compare-exchange uses the XOR-partner formulation: the partner of lane
``x`` at distance ``dist`` is ``x ^ dist``, materialized with two lane
rolls and a select (roll lowers to slice+concatenate, which Mosaic
supports on the lane dimension).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["next_pow2", "bitonic_sort", "tile_topk", "merge_sorted_runs"]


def next_pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())


def _lane_iota(shape, ndim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, ndim - 1)


def _cmp_swap(d, i, dist: int, asc):
    """One compare-exchange stage over XOR-partners at ``dist`` lanes.

    ``asc`` is a bool array broadcastable against ``d`` giving the sort
    direction of each lane's enclosing bitonic block. Ties never swap, so
    duplicate distances keep their original ids.
    """
    bitc = (_lane_iota(d.shape, d.ndim) & dist) == 0
    p_d = jnp.where(bitc, jnp.roll(d, -dist, axis=-1),
                    jnp.roll(d, dist, axis=-1))
    p_i = jnp.where(bitc, jnp.roll(i, -dist, axis=-1),
                    jnp.roll(i, dist, axis=-1))
    d_gt_p = d > p_d
    p_gt_d = p_d > d
    take = jnp.where(asc, jnp.where(bitc, d_gt_p, p_gt_d),
                     jnp.where(bitc, p_gt_d, d_gt_p))
    return jnp.where(take, p_d, d), jnp.where(take, p_i, i)


def bitonic_sort(d, i):
    """Sort ``d`` ascending along the last axis, permuting ``i`` alongside.

    Last-axis length must be a power of two (pad with +inf first).
    Stages are unrolled at trace time: ½·log²n compare-exchanges.
    """
    n = d.shape[-1]
    assert n & (n - 1) == 0, f"bitonic_sort needs pow2 width, got {n}"
    log_n = n.bit_length() - 1
    lanes = _lane_iota(d.shape, d.ndim)
    for s in range(1, log_n + 1):
        asc = ((lanes >> s) & 1) == 0      # final stage: all ascending
        for dist in (1 << p for p in range(s - 1, -1, -1)):
            d, i = _cmp_swap(d, i, dist, asc)
    return d, i


def _pad_cols(d, i, width: int):
    pad = width - d.shape[-1]
    if pad <= 0:
        return d, i
    cfg = [(0, 0)] * (d.ndim - 1) + [(0, pad)]
    return (jnp.pad(d, cfg, constant_values=jnp.inf),
            jnp.pad(i, cfg, constant_values=-1))


def tile_topk(d, i, kp: int):
    """Smallest ``kp`` of each row as an ascending sorted run.

    ``kp`` must be a power of two; columns are +inf-padded up to a power
    of two if needed. Returns (bm, kp) distances/ids.
    """
    assert kp & (kp - 1) == 0, f"tile_topk needs pow2 kp, got {kp}"
    d, i = _pad_cols(d, i, max(next_pow2(d.shape[-1]), kp))
    d, i = bitonic_sort(d, i)
    return d[..., :kp], i[..., :kp]


def merge_sorted_runs(ad, ai, bd, bi):
    """Merge two ascending runs of equal pow2 length; keep the smallest.

    ``concat(A, reverse(B))`` is bitonic, so log2(2k)+1 compare-exchange
    stages sort it; the first k lanes are the merged smallest-k run.
    """
    kp = ad.shape[-1]
    assert kp == bd.shape[-1] and kp & (kp - 1) == 0
    d = jnp.concatenate([ad, jnp.flip(bd, axis=-1)], axis=-1)
    i = jnp.concatenate([ai, jnp.flip(bi, axis=-1)], axis=-1)
    dist = kp
    while dist >= 1:
        d, i = _cmp_swap(d, i, dist, True)
        dist //= 2
    return d[..., :kp], i[..., :kp]
