"""Sharded optimizers: AdamW and Adafactor (factored second moment).

States inherit the parameter sharding (ZeRO-3: every state leaf gets the
same PartitionSpec as its param), so optimizer memory scales 1/N_devices.
Adafactor exists because a 480B-param AdamW state (12 bytes/param) cannot
fit a 256-chip v5e pod; factored second moments + no momentum brings the
per-chip state under HBM (DESIGN.md §4.1, EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999            # adafactor: decay exponent handled below
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(
        step < cfg.warmup_steps, warm,
        cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def _clip(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    # cast the scale, not the grads: bf16·f32 would promote every leaf to a
    # full-size f32 temporary (observed: 3×2.4 GiB on arctic's expert stacks)
    return jax.tree_util.tree_map(
        lambda g: g * scale.astype(g.dtype), grads), norm


def _maybe_chunk(upd, leaf_ndim: int, leading: int):
    """Run a per-leaf update slice-by-slice over the scan-stack axis.

    Stacked super-block params are single huge leaves (e.g. arctic experts:
    35×128×7168×4864). Elementwise optimizer math on the whole leaf
    materializes several f32 temporaries of full leaf size; lax.map over
    the leading axis bounds temporaries to one layer's worth — exact same
    result (the update has no cross-slice reduction)."""
    if leaf_ndim >= 3 and leading > 1:
        return lambda *args: jax.lax.map(lambda a: upd(*a), args)
    return upd


# ------------------------------------------------------------------ AdamW
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        # copy=True: with f32 params astype would alias the param buffer and
        # double-donation (params + master) would crash at execute time
        "master": jax.tree_util.tree_map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: OptConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = _clip(grads, cfg.grad_clip)

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        master = master - lr * (delta + cfg.weight_decay * master)
        return mu, nu, master

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_ma = treedef.flatten_up_to(state["master"])
    new_mu, new_nu, new_ma, new_p = [], [], [], []
    for g, mu, nu, ma, p in zip(flat_g, flat_mu, flat_nu, flat_ma, flat_p):
        fn = _maybe_chunk(upd, p.ndim, p.shape[0] if p.ndim else 1)
        m, n, a = fn(g, mu, nu, ma)
        new_mu.append(m)
        new_nu.append(n)
        new_ma.append(a)
        new_p.append(a.astype(p.dtype))
    unf = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    return unf(new_p), {"mu": unf(new_mu), "nu": unf(new_nu),
                        "master": unf(new_ma), "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# -------------------------------------------------------------- Adafactor
def _factored_dims(shape):
    """Last two non-trivial dims, or None if the tensor is ≤1D."""
    if len(shape) < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def adafactor_init(params):
    def make(p):
        dims = _factored_dims(p.shape)
        if dims is None:
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        r, c = dims
        vr = jnp.zeros(p.shape[:c] + p.shape[c + 1:], jnp.float32)
        vc = jnp.zeros(p.shape[:r] + p.shape[r + 1:], jnp.float32)
        return {"vr": vr, "vc": vc}
    return {
        "v": jax.tree_util.tree_map(make, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads, state, params, cfg: OptConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = _clip(grads, cfg.grad_clip)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        dims = _factored_dims(g.shape)
        if dims is None:
            nv = {"v": decay * v["v"] + (1 - decay) * g2}
            prec = jax.lax.rsqrt(nv["v"] + 1e-30)
        else:
            r, c = dims
            # vr: per-row stats (mean over the column dim); vc: per-column
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=c)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=r)
            nv = {"vr": vr, "vc": vc}
            # standard factored preconditioner
            r_ = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            prec = jax.lax.rsqrt(
                jnp.expand_dims(r_, c) * jnp.expand_dims(vc, r) + 1e-30)
        u = g * prec
        # update clipping (Shazeer & Stern): RMS(u) <= 1
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        newp = (p.astype(jnp.float32)
                - lr * (u + cfg.weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), nv

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    new_p, new_v = [], []
    for g, v, p in zip(flat_g, flat_v, flat_p):
        fn = _maybe_chunk(upd, p.ndim, p.shape[0] if p.ndim else 1)
        np_, nv_ = fn(g, v, p)
        new_p.append(np_)
        new_v.append(nv_)
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            {"v": jax.tree_util.tree_unflatten(treedef, new_v), "step": step},
            {"grad_norm": gnorm, "lr": lr})


def make_optimizer(cfg: OptConfig) -> Tuple[Callable, Callable]:
    if cfg.name == "adamw":
        return adamw_init, lambda g, s, p: adamw_update(g, s, p, cfg)
    if cfg.name == "adafactor":
        return adafactor_init, lambda g, s, p: adafactor_update(g, s, p, cfg)
    raise ValueError(cfg.name)
