"""Checkpoint/restore with elastic resharding (orbax-free, npz-based).

Layout:  <dir>/step_<N>/
            manifest.json           — paths, shapes, dtypes, step, mesh
            shard_<i>.npz           — flattened param/opt leaves (chunked)

Fault-tolerance contract:
* writes are atomic (tmp dir + rename) — a crash mid-save never corrupts
  the latest checkpoint;
* ``restore`` takes the *current* mesh/sharding: leaves are loaded on host
  and re-placed, so a 256-chip checkpoint restores onto 512 chips or 8
  (elastic scaling / shrink-to-debug);
* every leaf is keyed by its tree path — adding new params (warm start)
  or dropping optimizer state (inference) degrades gracefully.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_MAX_SHARD_BYTES = 512 * 1024 * 1024


def _path_str(path) -> str:
    parts = []
    for e in path:
        parts.append(str(e.key) if hasattr(e, "key") else str(getattr(e, "idx", e)))
    return "/".join(parts)


def save(directory: str, step: int, tree: Any) -> str:
    """Serialize a pytree (params / opt state / anything) atomically."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    manifest = {"step": step, "leaves": {}, "shards": []}
    shard, shard_bytes, shard_id = {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if not shard:
            return
        fname = f"shard_{shard_id}.npz"
        np.savez(os.path.join(tmp, fname), **shard)
        manifest["shards"].append(fname)
        shard, shard_bytes, shard_id = {}, 0, shard_id + 1

    for path, leaf in leaves:
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"][key] = {
            "shard": shard_id, "dtype": str(arr.dtype),
            "shape": list(arr.shape)}
        shard[key.replace("/", "__")] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _MAX_SHARD_BYTES:
            flush()
    flush()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of
    NamedShardings for direct sharded placement (elastic restore)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    cache = {}

    def load(key):
        meta = manifest["leaves"][key]
        fname = manifest["shards"][meta["shard"]]
        if fname not in cache:
            cache[fname] = np.load(os.path.join(d, fname))
        return cache[fname][key.replace("/", "__")]

    paths = jax.tree_util.tree_flatten_with_path(target)[0]
    treedef = jax.tree_util.tree_structure(target)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    out = []
    for (path, tgt), sh in zip(paths, shard_leaves):
        key = _path_str(path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint misses leaf {key}")
        arr = load(key)
        if list(arr.shape) != list(tgt.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {tgt.shape}")
        arr = arr.astype(tgt.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
