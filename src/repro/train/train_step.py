"""Training step: loss, gradient accumulation, remat — pjit-ready.

Memory contract: the per-microbatch activation footprint times one layer
(remat) is what lives in HBM; ``accum`` scales the global batch without
scaling memory. The dry-run memory_analysis validates this per arch.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ModelOptions, forward
from .optimizer import OptConfig, make_optimizer

Batch = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    accum: int = 1               # gradient-accumulation microbatches
    z_loss: float = 1e-4         # logit normalizer regularizer (PaLM-style)
    # f32 accumulation is the default; bf16 halves the accumulator HBM for
    # models whose f32 grads alone blow the per-chip budget (arctic-480b)
    accum_dtype: Any = jnp.float32


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 0.0):
    """Mean token CE (+ z-loss). logits (B,T,V) f32, labels (B,T) int32.
    Labels < 0 are masked."""
    mask = labels >= 0
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    denom = jnp.maximum(mask.sum(), 1)
    return (nll * mask).sum() / denom


def loss_fn(params, cfg: ArchConfig, batch: Batch, opts: ModelOptions,
            z_loss: float = 0.0):
    extra = {k: batch[k] for k in ("enc_frames", "vision_embeds", "positions")
             if k in batch}
    logits, _ = forward(params, cfg, batch["tokens"], opts=opts,
                        mode="train", **extra)
    return cross_entropy(logits, batch["labels"], z_loss)


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig,
                    opts: ModelOptions = ModelOptions()):
    """Returns train_step(params, opt_state, batch) → (params, state, metrics).

    ``batch["tokens"]`` is (accum, mb, T) when tcfg.accum > 1 — the scan
    accumulates grads in f32 before one optimizer application.
    """
    opt_init, opt_update = make_optimizer(tcfg.opt)

    def train_step(params, opt_state, batch: Batch):
        if tcfg.accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                params, cfg, batch, opts, tcfg.z_loss)
        else:
            def micro(carry, mb):
                acc, loss_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(
                    params, cfg, mb, opts, tcfg.z_loss)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(tcfg.accum_dtype), acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, tcfg.accum_dtype), params)
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree_util.tree_map(lambda g: g / tcfg.accum, grads)
            loss = loss / tcfg.accum
        new_params, new_state, om = opt_update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss, **om}

    return opt_init, train_step
