from .optimizer import OptConfig, make_optimizer, lr_schedule, global_norm
from .train_step import TrainConfig, cross_entropy, loss_fn, make_train_step
from . import checkpoint

__all__ = ["OptConfig", "make_optimizer", "lr_schedule", "global_norm",
           "TrainConfig", "cross_entropy", "loss_fn", "make_train_step",
           "checkpoint"]
