"""Fault tolerance & straggler mitigation for the join runtime.

MapReduce's resilience model — deterministic, idempotent tasks re-executed
on failure — is the paper's implicit substrate (§2.2 JobTracker). Ported
here explicitly:

* ``GroupExecutor`` runs join groups as independent work units with
  bounded retries; a group's output depends only on (plan, group id), so
  re-execution is always safe.
* Speculative execution: after ``speculate_after`` fraction of groups
  finish, still-running groups are re-issued (first finisher wins) —
  Hadoop's backup tasks. On a real pod the backup lands on an idle device;
  here both run on host, and the *scheduling logic* is what's under test.
* ``ElasticPlan`` regroups partitions when the device count changes:
  scale-down merges groups (θ/LB stay valid — Thm 6 min over a superset is
  still a lower bound); scale-up splits the most-loaded groups (bounds
  recomputed per new group: cheap host work on T_R/T_S).

Training-side fault tolerance lives in train/checkpoint.py (atomic save,
elastic restore) and data/pipeline.py (stateless stream).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core import group_lower_bounds
from repro.core.api import JoinPlan


@dataclasses.dataclass
class GroupRun:
    group: int
    attempts: int = 0
    done: bool = False
    result: Any = None
    seconds: float = 0.0
    speculated: bool = False


class GroupExecutor:
    """Run per-group work with retries + speculative re-issue."""

    def __init__(self, max_retries: int = 2, speculate: bool = True,
                 speculate_after: float = 0.75, max_workers: int = 4,
                 attempt_timeout: Optional[float] = None):
        self.max_retries = max_retries
        self.speculate = speculate
        self.speculate_after = speculate_after
        self.max_workers = max_workers
        # per-attempt wall-clock budget (seconds): an attempt that
        # exceeds it counts as a failure and is re-issued like any other
        # — a hung group_fn can no longer stall the pool forever. None
        # keeps the old block-until-done behavior.
        self.attempt_timeout = attempt_timeout

    def run(self, group_fn: Callable[[int], Any], groups: List[int],
            ) -> Dict[int, GroupRun]:
        runs = {g: GroupRun(group=g) for g in groups}

        def attempt(g):
            t0 = time.monotonic()
            out = group_fn(g)
            return g, out, time.monotonic() - t0

        def fail(g, r, cause):
            counts = {gg: rr.attempts for gg, rr in runs.items()}
            raise RuntimeError(
                f"group {g} failed after {r.attempts} attempts "
                f"(per-group attempt counts: {counts})") from cause

        pool = ThreadPoolExecutor(max_workers=self.max_workers)
        try:
            fut_group = {pool.submit(attempt, g): g for g in groups}
            expiry = ({f: time.monotonic() + self.attempt_timeout
                       for f in fut_group}
                      if self.attempt_timeout is not None else {})
            pending = set(fut_group)

            def reissue(g):
                nf = pool.submit(attempt, g)
                fut_group[nf] = g
                if self.attempt_timeout is not None:
                    expiry[nf] = time.monotonic() + self.attempt_timeout
                pending.add(nf)

            speculated = False
            while pending:
                if all(r.done for r in runs.values()):
                    break   # stragglers' twins won; don't wait for losers
                budget = None
                if self.attempt_timeout is not None:
                    budget = max(0.0, min(expiry[f] for f in pending)
                                 - time.monotonic())
                done, pending = wait(pending, timeout=budget,
                                     return_when=FIRST_COMPLETED)
                for fut in done:
                    g = fut_group[fut]
                    r = runs[g]
                    r.attempts += 1
                    if fut.exception() is not None:
                        if r.done:
                            continue  # a speculative twin already finished
                        if r.attempts > self.max_retries:
                            fail(g, r, fut.exception())
                        reissue(g)
                        continue
                    _, out, secs = fut.result()
                    if not r.done:
                        r.done, r.result, r.seconds = True, out, secs
                # timed-out attempts count as failures and are re-issued;
                # the stuck thread is orphaned (threads can't be killed)
                # and its eventual result, if any, is ignored
                if self.attempt_timeout is not None:
                    now = time.monotonic()
                    for fut in [f for f in pending if expiry[f] <= now]:
                        pending.discard(fut)
                        g = fut_group[fut]
                        r = runs[g]
                        if r.done:
                            continue
                        r.attempts += 1
                        if r.attempts > self.max_retries:
                            fail(g, r, TimeoutError(
                                f"group {g} attempt exceeded "
                                f"{self.attempt_timeout}s"))
                        reissue(g)
                n_done = sum(r.done for r in runs.values())
                if (self.speculate and not speculated
                        and n_done >= self.speculate_after * len(groups)
                        and n_done < len(groups)):
                    speculated = True
                    for g, r in runs.items():
                        if not r.done:
                            r.speculated = True
                            reissue(g)
        finally:
            pool.shutdown(wait=False, cancel_futures=True)
        return runs

    def run_with_retries(self, group_fn: Callable[[int], Any],
                         groups: List[int]) -> Dict[int, GroupRun]:
        """Retry loop around `run` for fault injection tests."""
        runs: Dict[int, GroupRun] = {g: GroupRun(group=g) for g in groups}
        remaining = list(groups)
        for attempt_no in range(self.max_retries + 1):
            failed = []
            for g in remaining:
                runs[g].attempts += 1
                try:
                    t0 = time.monotonic()
                    runs[g].result = group_fn(g)
                    runs[g].seconds = time.monotonic() - t0
                    runs[g].done = True
                except Exception:
                    failed.append(g)
            remaining = failed
            if not remaining:
                break
        if remaining:
            raise RuntimeError(
                f"groups {remaining} failed after {self.max_retries + 1} attempts")
        return runs


# ----------------------------------------------------------- elasticity
def _with_grouping(plan, groups: np.ndarray, lb_group: np.ndarray):
    """Replace the grouping on a composite ``JoinPlan`` (regroup its
    per-batch ``QueryPlan``; the S index is untouched — elasticity never
    re-runs S-side phase 1) or on a bare ``QueryPlan``."""
    if isinstance(plan, JoinPlan):
        return dataclasses.replace(
            plan, query=dataclasses.replace(
                plan.query, groups=groups, lb_group=lb_group))
    return dataclasses.replace(plan, groups=groups, lb_group=lb_group)


def shrink_groups(plan: JoinPlan, new_n: int) -> JoinPlan:
    """Merge groups for a smaller device count (θ, LB stay valid)."""
    old_n = plan.n_groups
    assert new_n < old_n
    mapping = np.arange(old_n) % new_n
    groups = mapping[plan.groups]
    lb_group = group_lower_bounds(plan.lb, groups, new_n)
    return _with_grouping(plan, groups.astype(np.int32), lb_group)


def grow_groups(plan: JoinPlan, new_n: int) -> JoinPlan:
    """Split the most-populated groups for a larger device count."""
    old_n = plan.n_groups
    assert new_n > old_n
    groups = plan.groups.copy().astype(np.int64)
    counts = plan.t_r.counts.astype(np.int64)
    next_id = old_n
    while next_id < new_n:
        load = np.zeros(next_id, np.int64)
        np.add.at(load, groups, counts)
        heavy = int(np.argmax(load))
        members = np.where(groups == heavy)[0]
        if members.size <= 1:
            break  # cannot split single-partition groups further
        # move the later half of its partitions (by pivot order) out
        movers = members[members.size // 2:]
        groups[movers] = next_id
        next_id += 1
    lb_group = group_lower_bounds(plan.lb, groups.astype(np.int32), next_id)
    return _with_grouping(plan, groups.astype(np.int32), lb_group)


def regroup(plan: JoinPlan, new_n: int) -> JoinPlan:
    if new_n == plan.n_groups:
        return plan
    return shrink_groups(plan, new_n) if new_n < plan.n_groups \
        else grow_groups(plan, new_n)
