"""Logical-axis sharding: one rules table maps param/activation logical
axes onto mesh axes; models stay mesh-agnostic.

- ``param_pspecs(params)`` derives PartitionSpecs from leaf *paths* (the
  param naming convention is the contract — see _PARAM_RULES).
- ``shard(x, *axes)`` constrains activations inside model code; it is a
  no-op unless a rules context is active, so CPU unit tests never touch
  mesh machinery.

Default mapping (DESIGN.md §5):
  batch  → ("pod", "data")   (pod absent on single-pod meshes)
  model-parallel width (heads/ff/experts/vocab) → "model"
  fsdp (parameter d_model / reduction dims)     → "data"  (ZeRO-3)
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _axes(mesh: Mesh) -> dict:
    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names) or (None,)
    return {
        "batch": batch if len(batch) > 1 else batch[0],
        "fsdp": "data" if "data" in names else None,
        "model": "model" if "model" in names else None,
        None: None,
    }


@contextlib.contextmanager
def axis_rules(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def logical_to_pspec(axes: Sequence[Optional[str]],
                     mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or current_mesh()
    if mesh is None:
        return P(*axes)
    table = _axes(mesh)
    return P(*(table.get(a, a) for a in axes))


def shard(x, *axes):
    """Constrain an activation to logical axes (no-op without rules)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_pspec(axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---- parameter specs ---------------------------------------------------
# path regex → logical axes of the *trailing* dims (leading scan/stack
# dims are padded with None automatically)
_PARAM_RULES = [
    (r"embed$", ("model", "fsdp")),               # (V, D) vocab-TP + FSDP
    (r"pos_embed$", (None, "fsdp")),
    (r"(q|up|gate|in|ffn_up|ffn_gate|q_rope)/w$", ("fsdp", "model")),
    # GQA/MQA kv projections: sharding (kvh·dh) over more ways than there
    # are kv heads splits heads mid-vector — XLA then all-reduces full f32
    # attention logits (measured 1.7 TB/step on qwen3 prefill, §Perf iter 3).
    # "kv" resolves to "model" only when kv heads divide the axis.
    (r"(k|v)/w$", ("fsdp", "kv")),
    (r"(o|down|out|ffn_down)/w$", ("model", "fsdp")),
    (r"(dkv|k_rope)/w$", ("fsdp", None)),         # MLA latent projections
    (r"(uk|uv)/w$", (None, "model")),
    (r"router/w$", ("fsdp", None)),
    (r"moe/(gate|up)$", ("model", "fsdp", None)),  # (E, D, F) expert-sharded
    (r"moe/down$", ("model", None, "fsdp")),       # (E, F, D)
    (r"(igate|fgate)/w$", (None, None)),
    (r"r_[ifzo]$", (None, None, None)),   # (H, dh, dh): H is tiny, replicate
    (r"conv/w$", (None, "model")),
    (r"(w_a|b_a|w_x|b_x|lam)$", ("model",)),
    (r"(scale|bias|f_bias|fgate_bias)$", (None,)),
    (r"lm_head$", ("fsdp", "model")),              # (D, V)
]


def _spec_for_path(path: str, ndim: int) -> P:
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            axes = tuple(axes)
            if len(axes) > ndim:      # e.g. vector param matched a 2d rule
                axes = axes[-ndim:]
            pad = (None,) * (ndim - len(axes))
            return tuple(pad) + axes
    return (None,) * ndim


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def param_logical_axes(params: Any):
    """Tree of logical-axis tuples matching the params tree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: _spec_for_path(_path_str(p), x.ndim), params)


def param_pspecs(params: Any, mesh: Mesh, *, mode: str = "train",
                 kv_heads_divide: bool = True, fsdp_over_pod: bool = False):
    """PartitionSpecs for a params (or optimizer-state) tree.

    Shape-aware: a mesh axis that does not divide its dimension is dropped
    (e.g. whisper's vocab 51865 on a 16-wide model axis stays replicated
    rather than requiring padding).

    mode="serve": inference keeps weights tensor-parallel only ("model")
    and replicates across the data axis — ZeRO-style "fsdp" sharding would
    re-all-gather every layer's weights on every decode step (measured:
    the dominant collective term on qwen3-14b prefill, EXPERIMENTS.md
    §Perf iter 2)."""
    table = dict(_axes(mesh))
    if fsdp_over_pod and "pod" in mesh.axis_names:
        # ZeRO-3 across pods too: a 480B model's params/grads must shard
        # over all 512 chips (crossing the DCI per layer gather) — the only
        # way arctic-class training fits 16 GiB/chip (§Perf iter 7)
        table["fsdp"] = ("pod", "data")
    table["kv"] = "model" if kv_heads_divide else None
    serve_table = dict(table)
    serve_table["fsdp"] = None

    def axis_size(a) -> int:
        if a is None:
            return 1
        if isinstance(a, tuple):
            n = 1
            for x in a:
                n *= mesh.shape[x]
            return n
        return mesh.shape[a]

    # serve mode drops fsdp (TP-only weights, no per-layer gathers) EXCEPT
    # for leaves that stay big after model-sharding — replicating arctic's
    # expert stacks across the data axis would cost ~60 GiB/device.
    # 128 MiB ≈ 1% of HBM: below it replication is free; above it, keeping
    # the gathers is cheaper than the memory.
    _SERVE_REPLICATION_BUDGET = 128 * 2**20

    def _resolved_per_device_bytes(axes, leaf) -> float:
        factor = 1
        for dim, a in zip(leaf.shape, [table.get(x, x) for x in axes]):
            if a and a != "data" and dim % axis_size(a) == 0:
                factor *= axis_size(a)
        return leaf.size * leaf.dtype.itemsize / max(factor, 1)

    def to_pspec(axes, leaf):
        use = table
        if mode == "serve" and \
                _resolved_per_device_bytes(axes, leaf) <= _SERVE_REPLICATION_BUDGET:
            use = serve_table
        mesh_axes = [use.get(a, a) for a in axes]
        out = []
        for dim, a in zip(leaf.shape, mesh_axes):
            out.append(a if a and dim % axis_size(a) == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map(
        to_pspec, param_logical_axes(params), params,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, (str, tuple)) for a in x))


def param_shardings(params: Any, mesh: Mesh, *, mode: str = "train",
                    kv_heads_divide: bool = True,
                    fsdp_over_pod: bool = False):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        param_pspecs(params, mesh, mode=mode,
                     kv_heads_divide=kv_heads_divide,
                     fsdp_over_pod=fsdp_over_pod),
        is_leaf=lambda x: isinstance(x, P))
