from .sharding import (
    axis_rules, current_mesh, logical_to_pspec, param_logical_axes,
    param_pspecs, param_shardings, shard)
from .fault import GroupExecutor, GroupRun, grow_groups, regroup, shrink_groups

__all__ = ["axis_rules", "current_mesh", "logical_to_pspec",
           "param_logical_axes", "param_pspecs", "param_shardings", "shard",
           "GroupExecutor", "GroupRun", "grow_groups", "regroup",
           "shrink_groups"]
