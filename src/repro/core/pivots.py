"""Pivot selection strategies (paper §4.1).

All three strategies from the paper are implemented. They run on the
"master node" (host) over a sample, exactly as the paper prescribes —
selection cost must not scale with |R|.

The distance computations are vectorized jnp so the same code JITs on
TPU for large samples, but they gracefully run on host numpy inputs too.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["select_pivots", "pairwise_sqdist"]


def pairwise_sqdist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distances (na, nb).  ``-2ab`` term hits the MXU on TPU."""
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)       # (na, 1)
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T      # (1, nb)
    d2 = a2 + b2 - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def _sample(data: np.ndarray, n: int, rng: np.random.Generator) -> np.ndarray:
    if data.shape[0] <= n:
        return np.asarray(data)
    idx = rng.choice(data.shape[0], size=n, replace=False)
    return np.asarray(data[idx])


def _random_selection(data, m, *, n_sets, rng):
    """Paper: draw T random candidate sets, keep the one with max total
    pairwise distance (a spread heuristic).

    All T candidate sets are scored in one batched device call (a
    single (T, m, m) einsum + one fetch) instead of T sequential
    pairwise-distance round-trips — same rng draw order, same argmax,
    ~T× fewer host↔device syncs on the build/seal path.
    """
    cands = np.stack([_sample(data, m, rng).astype(np.float32)
                      for _ in range(max(1, n_sets))])        # (T, m, dim)
    c = jnp.asarray(cands)
    n2 = jnp.sum(c * c, axis=-1)                              # (T, m)
    d2 = n2[:, :, None] + n2[:, None, :] \
        - 2.0 * jnp.einsum("tmd,tnd->tmn", c, c)
    scores = jnp.sqrt(jnp.maximum(d2, 0.0)).sum(axis=(1, 2))  # (T,)
    return cands[int(np.argmax(np.asarray(scores)))]


def _farthest_selection(data, m, *, sample, rng):
    """Iterative farthest-point: maximize sum of distance to chosen pivots."""
    pts = _sample(data, sample, rng).astype(np.float32)
    first = int(rng.integers(pts.shape[0]))
    chosen = [first]
    # running sum of distances from each candidate to the chosen set
    acc = np.sqrt(
        np.asarray(pairwise_sqdist(jnp.asarray(pts), jnp.asarray(pts[first : first + 1])))
    )[:, 0]
    for _ in range(1, m):
        acc[chosen] = -np.inf  # never re-pick
        nxt = int(np.argmax(acc))
        chosen.append(nxt)
        acc = np.where(
            np.isneginf(acc), acc,
            acc + np.sqrt(np.asarray(
                pairwise_sqdist(jnp.asarray(pts), jnp.asarray(pts[nxt : nxt + 1]))))[:, 0],
        )
    return pts[np.asarray(chosen)]


def _kmeans_selection(data, m, *, sample, rng, iters: int = 10):
    """k-means on a sample; cluster centers become pivots."""
    pts = jnp.asarray(_sample(data, sample, rng).astype(np.float32))
    init_idx = rng.choice(pts.shape[0], size=m, replace=False)
    centers = pts[jnp.asarray(init_idx)]

    @jax.jit
    def step(centers):
        d2 = pairwise_sqdist(pts, centers)                  # (n, m)
        assign = jnp.argmin(d2, axis=1)
        one_hot = jax.nn.one_hot(assign, m, dtype=pts.dtype)  # (n, m)
        sums = one_hot.T @ pts                              # (m, dim)
        cnts = one_hot.sum(axis=0)[:, None]                 # (m, 1)
        # empty cluster keeps its previous center
        return jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1.0), centers)

    for _ in range(iters):
        centers = step(centers)
    return np.asarray(centers)


def select_pivots(
    data: np.ndarray,
    m: int,
    strategy: str = "random",
    *,
    sample: int = 4096,
    n_sets: int = 8,
    seed: int = 0,
) -> np.ndarray:
    """Select ``m`` pivots from ``data`` using a paper §4.1 strategy."""
    data = np.asarray(data)
    if m > data.shape[0]:
        raise ValueError(f"cannot select {m} pivots from {data.shape[0]} objects")
    rng = np.random.default_rng(seed)
    if strategy == "random":
        out = _random_selection(data, m, n_sets=n_sets, rng=rng)
    elif strategy == "farthest":
        out = _farthest_selection(data, m, sample=max(sample, m), rng=rng)
    elif strategy == "kmeans":
        out = _kmeans_selection(data, m, sample=max(sample, m), rng=rng)
    else:
        raise ValueError(f"unknown pivot strategy {strategy!r}")
    return np.ascontiguousarray(out, dtype=np.float32)
