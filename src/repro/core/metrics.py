"""Distance metrics for the join (paper §2.1: the methods apply to any
metric with the triangle inequality — L2, L1 (Manhattan), L∞ (max)).

The bounds (Theorems 3-6) use only true distances + triangle inequality,
so they transfer unchanged. L2 keeps its MXU-friendly squared fast path;
L1/L∞ run on the VPU path (elementwise |a-b| reductions).
"""
from __future__ import annotations

import numpy as np

METRICS = ("l2", "l1", "linf")


def pairwise_dist(a: np.ndarray, b: np.ndarray, metric: str = "l2",
                  *, block: int = 2048) -> np.ndarray:
    """True (non-squared) distances, shape (na, nb). Blocked over rows."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    if metric == "l2":
        a2 = (a * a).sum(-1)[:, None]
        b2 = (b * b).sum(-1)[None, :]
        d2 = a2 + b2 - 2.0 * (a @ b.T)
        return np.sqrt(np.maximum(d2, 0.0, out=d2))
    out = np.empty((a.shape[0], b.shape[0]), np.float32)
    for lo in range(0, a.shape[0], block):
        hi = min(lo + block, a.shape[0])
        diff = np.abs(a[lo:hi, None, :] - b[None, :, :])
        out[lo:hi] = (diff.sum(-1) if metric == "l1"
                      else diff.max(-1))
    return out


def cmp_dist(a: np.ndarray, b: np.ndarray, metric: str = "l2",
             *, block: int = 2048) -> np.ndarray:
    """Distances in *comparable* space (monotone in true distance):
    squared for L2 (cheaper; no sqrt), true distance otherwise."""
    if metric == "l2":
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        d2 = ((a * a).sum(-1)[:, None] + (b * b).sum(-1)[None, :]
              - 2.0 * (a @ b.T))
        return np.maximum(d2, 0.0, out=d2)
    return pairwise_dist(a, b, metric, block=block)


def from_cmp(d: np.ndarray, metric: str) -> np.ndarray:
    """Comparable space → true distance."""
    return np.sqrt(d) if metric == "l2" else d


def to_cmp(d: np.ndarray, metric: str) -> np.ndarray:
    """True distance → comparable space."""
    return np.square(d) if metric == "l2" else d
