"""Distance metrics for the join (paper §2.1: the methods apply to any
metric with the triangle inequality — L2, L1 (Manhattan), L∞ (max)).

The bounds (Theorems 3-6) use only true distances + triangle inequality,
so they transfer unchanged. L2 keeps its MXU-friendly squared fast path;
L1/L∞ run on the VPU path (elementwise |a-b| reductions).
"""
from __future__ import annotations

import numpy as np

METRICS = ("l2", "l1", "linf")


def pairwise_dist(a: np.ndarray, b: np.ndarray, metric: str = "l2",
                  *, block: int = 2048) -> np.ndarray:
    """True (non-squared) distances, shape (na, nb). Blocked over rows."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    if metric == "l2":
        a2 = (a * a).sum(-1)[:, None]
        b2 = (b * b).sum(-1)[None, :]
        d2 = a2 + b2 - 2.0 * (a @ b.T)
        return np.sqrt(np.maximum(d2, 0.0, out=d2))
    out = np.empty((a.shape[0], b.shape[0]), np.float32)
    for lo in range(0, a.shape[0], block):
        hi = min(lo + block, a.shape[0])
        diff = np.abs(a[lo:hi, None, :] - b[None, :, :])
        out[lo:hi] = (diff.sum(-1) if metric == "l1"
                      else diff.max(-1))
    return out


def cmp_dist(a: np.ndarray, b: np.ndarray, metric: str = "l2",
             *, block: int = 2048) -> np.ndarray:
    """Distances in *comparable* space (monotone in true distance):
    squared for L2 (cheaper; no sqrt), true distance otherwise.

    The L2 path recenters both sets by b's mean first: distances are
    translation-invariant, but the ‖a‖²+‖b‖²−2ab cancellation noise is
    O(‖x‖²·eps) — on data far from the origin (e.g. map coordinates)
    that noise dwarfs real kNN gaps and corrupts top-k *selection*.
    Centering shrinks it to O(spread²·eps) for two O(n·dim) passes.
    """
    if metric == "l2":
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        c = np.mean(b, axis=0, dtype=np.float64).astype(np.float32) \
            if b.shape[0] else np.zeros((b.shape[1],), np.float32)
        a = a - c
        b = b - c
        d2 = ((a * a).sum(-1)[:, None] + (b * b).sum(-1)[None, :]
              - 2.0 * (a @ b.T))
        return np.maximum(d2, 0.0, out=d2)
    return pairwise_dist(a, b, metric, block=block)


def canonical_gathered(q, neigh, metric: str = "l2"):
    """The canonical per-pair distance graph, on jnp arrays (traceable).

    ``q`` (n, dim) vs ``neigh`` (n, k, dim) → (n, k) float32 true
    distances. The reduction over ``dim`` is an *unrolled* left-to-right
    chain of elementwise float32 ops: XLA never reassociates explicit
    elementwise adds, so every (q, s) pair produces the same bits no
    matter the leading shape, the backend fusion decisions, or whether
    the graph is embedded in a larger jit (the fused query megastep
    inlines exactly this graph on device). Both the host canonicalizer
    (:func:`gathered_dist`) and the megastep call this one function —
    bitwise equality between the two execution paths rests on it.
    """
    import jax.numpy as jnp

    d = q[:, None, :].astype(jnp.float32) - neigh.astype(jnp.float32)
    if metric == "l2":
        acc = d[..., 0] * d[..., 0]
        for t in range(1, d.shape[-1]):
            acc = acc + d[..., t] * d[..., t]
        return jnp.sqrt(acc)
    a = jnp.abs(d)
    acc = a[..., 0]
    for t in range(1, a.shape[-1]):
        acc = acc + a[..., t] if metric == "l1" else jnp.maximum(acc, a[..., t])
    return acc


_gathered_jit: dict = {}


def gathered_dist(q: np.ndarray, neigh: np.ndarray, metric: str = "l2",
                  *, block: int = 8192) -> np.ndarray:
    """True distances of each query to its gathered neighbor rows.

    ``q`` (n, dim) vs ``neigh`` (n, k, dim) → (n, k). Shape-canonical:
    every pair reduces over ``dim`` with the same fixed-order unrolled
    elementwise chain (`canonical_gathered`) no matter how many rows
    surround it, so the value of a (q, s) pair is independent of batch
    composition — unlike BLAS matmul, whose kernel dispatch (gemm vs
    gemv, blocking) varies with operand shape. This is what lets the
    streaming engine promise bitwise-identical results for any
    micro-batch split, and what makes the device-resident megastep
    (core.megastep) report the same bits as the host-planned path.

    Rows are processed in ``block``-sized chunks (bounded device memory
    for huge one-shot joins), each padded to a power-of-two bucket so
    the jit cache stays small across ragged batch sizes — per-row
    values are unaffected by both the chunking and the padding rows.
    """
    q = np.asarray(q, np.float32)
    neigh = np.asarray(neigh, np.float32)
    n, k = neigh.shape[:2]
    if n == 0 or k == 0 or q.shape[1] == 0:
        return np.zeros((n, k), np.float32)
    if n <= block:
        return _gathered_block(q, neigh, metric)
    out = np.empty((n, k), np.float32)
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        out[lo:hi] = _gathered_block(q[lo:hi], neigh[lo:hi], metric)
    return out


def _gathered_block(q: np.ndarray, neigh: np.ndarray,
                    metric: str) -> np.ndarray:
    import jax

    n, k = neigh.shape[:2]
    bucket = 1 << max(3, (n - 1).bit_length())
    key = (metric, int(q.shape[1]), int(k), bucket)
    fn = _gathered_jit.get(key)
    if fn is None:
        fn = jax.jit(lambda qq, nn: canonical_gathered(qq, nn, metric))
        _gathered_jit[key] = fn
    if bucket != n:
        q = np.pad(q, ((0, bucket - n), (0, 0)))
        neigh = np.pad(neigh, ((0, bucket - n), (0, 0), (0, 0)))
    return np.asarray(fn(q, neigh))[:n]


def canonical_topk(q: np.ndarray, ids: np.ndarray, neigh: np.ndarray,
                   metric: str = "l2") -> tuple[np.ndarray, np.ndarray]:
    """Finalize a top-k result: recompute the k selected distances in the
    shape-canonical form and re-sort each row ascending by them (stable,
    so engine tie order survives). ``ids < 0`` slots stay at +inf/-1.
    The *selection* of the k set remains the engine's (exact over a
    superset); only the reported values and their order are re-derived.
    """
    d = gathered_dist(q, neigh, metric)
    d = np.where(ids >= 0, d, np.float32(np.inf)).astype(np.float32)
    order = np.argsort(d, axis=1, kind="stable")
    return (np.take_along_axis(d, order, axis=1),
            np.take_along_axis(ids, order, axis=1))


def from_cmp(d: np.ndarray, metric: str) -> np.ndarray:
    """Comparable space → true distance."""
    return np.sqrt(d) if metric == "l2" else d


def to_cmp(d: np.ndarray, metric: str) -> np.ndarray:
    """True distance → comparable space."""
    return np.square(d) if metric == "l2" else d
