"""Phase-1 of PGBJ: Voronoi assignment + summary tables (paper §4.2).

This is the paper's first MapReduce job: each object of R ∪ S is mapped to
its nearest pivot; per-partition statistics (count, L, U and — for S — the
k smallest object→pivot distances) are aggregated into the summary tables
T_R / T_S.

Under the split planner (core.index) the two halves run on different
cadences: the S half exactly once inside ``build_index`` (the SIndex),
the R half per query batch inside ``plan_queries`` — the jitted
``_assign_blocked`` below is that per-batch hot path.

The assignment hot-loop is also available as a Pallas TPU kernel
(`repro.kernels.assign`); this module is the jnp reference path used by the
single-host engine and by the distributed runtime on CPU.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .pivots import pairwise_sqdist
from .types import SummaryTable

__all__ = ["assign_to_pivots", "build_summary", "assign_and_summarize"]


@partial(jax.jit, static_argnames=("block",))
def _assign_blocked(data: jnp.ndarray, pivots: jnp.ndarray, block: int = 4096):
    """(part_id, dist) for every object, computed in row blocks.

    Tie-break note: jnp.argmin picks the lowest pivot index on exact ties.
    The paper breaks ties toward the smaller partition; ties have
    probability ~0 on real-valued data and the join is correct under any
    deterministic tie-break (the bounds only use the *assigned* distance).
    """
    n = data.shape[0]
    pad = (-n) % block
    padded = jnp.pad(data, ((0, pad), (0, 0)))

    def body(chunk):
        d2 = pairwise_sqdist(chunk, pivots)           # (block, M)
        pid = jnp.argmin(d2, axis=1)
        dist = jnp.sqrt(jnp.take_along_axis(d2, pid[:, None], axis=1))[:, 0]
        return pid.astype(jnp.int32), dist

    chunks = padded.reshape(-1, block, data.shape[1])
    pids, dists = jax.lax.map(body, chunks)
    return pids.reshape(-1)[:n], dists.reshape(-1)[:n]


def assign_to_pivots(
    data: np.ndarray, pivots: np.ndarray, *, block: int = 4096,
    metric: str = "l2",
) -> Tuple[np.ndarray, np.ndarray]:
    """Nearest-pivot assignment. Returns (part_ids (n,), dists (n,)).

    L2 uses the jnp/MXU path; L1/L∞ use the blocked numpy VPU path
    (paper §2.1 metric generality)."""
    if data.shape[0] == 0:
        return (np.zeros((0,), np.int32), np.zeros((0,), np.float32))
    if metric == "l2":
        pid, dist = _assign_blocked(jnp.asarray(data, jnp.float32),
                                    jnp.asarray(pivots, jnp.float32),
                                    block=block)
        return np.asarray(pid), np.asarray(dist)
    from .metrics import pairwise_dist
    pid = np.empty((data.shape[0],), np.int32)
    dist = np.empty((data.shape[0],), np.float32)
    for lo in range(0, data.shape[0], block):
        hi = min(lo + block, data.shape[0])
        d = pairwise_dist(data[lo:hi], pivots, metric)
        pid[lo:hi] = d.argmin(1)
        dist[lo:hi] = d.min(1)
    return pid, dist


@partial(jax.jit, static_argnames=("m", "k"))
def _summarize(part_ids: jnp.ndarray, dists: jnp.ndarray, *, m: int, k: int | None):
    counts = jnp.zeros((m,), jnp.int32).at[part_ids].add(1)
    lower = jnp.full((m,), jnp.inf, jnp.float32).at[part_ids].min(dists)
    upper = jnp.zeros((m,), jnp.float32).at[part_ids].max(dists)
    knn = None
    if k is not None:
        # k smallest |s, p_j| per partition: segmented top-k via sort.
        # Sort by (partition, distance), then the first k entries of each
        # partition segment are its k nearest-to-pivot objects.
        order = jnp.lexsort((dists, part_ids))
        sp, sd = part_ids[order], dists[order]
        # rank within segment
        idx = jnp.arange(sp.shape[0])
        seg_start = jnp.full((m,), sp.shape[0], jnp.int32).at[sp].min(
            idx.astype(jnp.int32))
        rank = idx - seg_start[sp]
        knn = jnp.full((m, k), jnp.inf, jnp.float32)
        keep = rank < k
        knn = knn.at[jnp.where(keep, sp, m - 1),
                     jnp.where(keep, rank, k - 1)].min(
                         jnp.where(keep, sd, jnp.inf))
    return counts, lower, upper, knn


def build_summary(
    part_ids: np.ndarray, dists: np.ndarray, m: int, k: int | None = None
) -> SummaryTable:
    """Build T_R (k=None) or T_S (k=paper's k) from phase-1 output."""
    counts, lower, upper, knn = _summarize(
        jnp.asarray(part_ids), jnp.asarray(dists), m=m, k=k)
    return SummaryTable(
        counts=np.asarray(counts),
        lower=np.asarray(lower),
        upper=np.asarray(upper),
        knn_dists=None if knn is None else np.asarray(knn),
    )


@partial(jax.jit, static_argnames=("block", "m", "k", "order"))
def _assign_summarize(data, pivots, *, block, m, k, order):
    """Assignment + summary (+ the packing lexsort) as ONE jitted call.

    The seal path used to pay three separate device round-trips per
    sealed segment (assign fetch, summary fetch, host lexsort); fusing
    them means one dispatch and one coherent fetch — the summary's own
    internal lexsort and the packing order share one sort via CSE."""
    pid, dist = _assign_blocked(data, pivots, block=block)
    counts, lower, upper, knn = _summarize(pid, dist, m=m, k=k)
    so = jnp.lexsort((dist, pid)) if order else None
    return pid, dist, counts, lower, upper, knn, so


def assign_and_summarize(
    data: np.ndarray, pivots: np.ndarray, *, k: int | None = None,
    metric: str = "l2", return_order: bool = False,
):
    """Fused phase-1 for one dataset: (part_ids, dists, summary table).

    ``return_order=True`` appends the packed-layout sort order
    (``np.lexsort((dists, part_ids))``, int64) as a fourth element —
    computed inside the same jitted call on the L2 path, so a segment
    seal costs one device round-trip total.
    """
    m = pivots.shape[0]
    if metric == "l2" and data.shape[0] > 0:
        pid, dist, counts, lower, upper, knn, so = _assign_summarize(
            jnp.asarray(data, jnp.float32), jnp.asarray(pivots, jnp.float32),
            block=4096, m=m, k=k, order=return_order)
        table = SummaryTable(
            counts=np.asarray(counts), lower=np.asarray(lower),
            upper=np.asarray(upper),
            knn_dists=None if knn is None else np.asarray(knn))
        part_ids, dists = np.asarray(pid), np.asarray(dist)
        if return_order:
            return part_ids, dists, table, np.asarray(so, np.int64)
        return part_ids, dists, table
    part_ids, dists = assign_to_pivots(data, pivots, metric=metric)
    table = build_summary(part_ids, dists, m, k=k)
    if return_order:
        return part_ids, dists, table, np.lexsort((dists, part_ids))
    return part_ids, dists, table
