"""Streaming R micro-batch engine over a resident ``SIndex``.

The build-once/query-many split (core.index) makes the R side cheap to
re-plan, so R no longer has to exist up front: it can arrive in
micro-batches of configurable size. Two per-batch execution paths share
this engine:

* **host-planned** (the reference oracle): each batch plans
  (`plan_queries`, jitted assignment + bounds, host grouping) and joins
  (`api.execute_join`) against the resident index, and its top-k rows
  land in a ``StreamJoinState`` that merges runs with the same odd-even
  sorted-run merge the Pallas kernels use
  (`kernels.sorted_merge.merge_sorted_runs`).
* **megastep** (``megastep=True``, L2 only): the whole per-batch path —
  assignment, θ/LB bounds, visit-schedule build, the gather top-k and
  the cross-segment merge — runs as *one jitted device pass*
  (`core.megastep.MegastepEngine`). Ragged batch sizes are padded to
  power-of-two buckets and the compiled step is cached per (bucket, k,
  segment structure), so a repeating batch size re-plans nothing and
  recompiles nothing. Bitwise-identical results to the host path.

Either way device memory is bounded by the batch and the resident index
— |R| ≫ VMEM/HBM streams through without ever materializing an
|R|-sized plan.

Semantics: every engine here is exact, and a query's result depends
only on (query row, index) — the candidate supersets the bounds ship
vary with the batch composition, but an exact top-k over any superset
of the true neighbors is the same top-k. ``knn_join_batched`` over any
split of R therefore reproduces the one-shot ``knn_join`` against the
same index (asserted bitwise in tests/test_stream.py).

The kNN-LM serve loop (serve.retrieval.Datastore) drives the same
``StreamJoinEngine``: one decode step's hidden-state batch is just one
more R micro-batch against the datastore's index.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Union

import numpy as np

from .index import SIndex, build_index, plan_queries
from .types import JoinConfig, JoinResult, JoinStats

__all__ = ["StreamJoinEngine", "StreamJoinState", "knn_join_batched"]


def _merge_runs_jit(ad, ai, bd, bi):
    """Jitted dedup + odd-even merge (compiled once per run shape — the
    bitonic network is ~log2(2k) stages of eager ops otherwise, and
    per-batch dispatch overhead would swamp the merge itself)."""
    global _merge_runs_compiled
    if _merge_runs_compiled is None:
        import jax
        from repro.kernels.sorted_merge import merge_sorted_runs_unique
        _merge_runs_compiled = jax.jit(merge_sorted_runs_unique)
    return _merge_runs_compiled(ad, ai, bd, bi)


_merge_runs_compiled = None


def _split_ids(ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """int64 row ids → (hi, lo) int32 pair. jnp arrays are int32 under
    default JAX config, so 64-bit ids must travel through the merge
    network as two lanes — a plain ``.astype(np.int32)`` silently
    truncates once segment-offset ids pass 2³¹. ``-1`` maps to
    (-1, -1) and back."""
    ids = np.asarray(ids, np.int64)
    hi = (ids >> 32).astype(np.int32)
    lo = (ids & np.int64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
    return hi, lo


def _join_ids(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """(hi, lo) int32 pair → int64 row ids (inverse of ``_split_ids``)."""
    return ((np.asarray(hi, np.int64) << 32)
            | (np.asarray(lo, np.int64) & np.int64(0xFFFFFFFF)))


@dataclasses.dataclass
class StreamJoinState:
    """Running top-k per query slot, maintained as ascending sorted runs.

    ``update`` merges a batch's (dists, ids) runs into the named slots
    via ``merge_sorted_runs_unique`` — a no-op for slots seen once
    (merging with the +inf run), a genuine k-way merge when a slot is
    revisited (e.g. the same queries joined against another index
    segment or shard). Equal ids across the two runs are deduplicated
    (the smaller distance survives), so a row offered twice — a
    compaction/re-query overlap — never occupies two top-k slots. Ids
    are int64 end to end: they cross the jnp merge as (hi, lo) int32
    pairs, so segment-offset ids beyond 2³¹ survive uncorrupted.
    """

    n: int
    k: int
    distances: np.ndarray = dataclasses.field(init=False)
    indices: np.ndarray = dataclasses.field(init=False)
    _seen: np.ndarray = dataclasses.field(init=False, repr=False)

    def __post_init__(self):
        self.distances = np.full((self.n, self.k), np.inf, np.float32)
        self.indices = np.full((self.n, self.k), -1, np.int64)
        self._seen = np.zeros((self.n,), bool)

    def update(self, rows: np.ndarray, d: np.ndarray, i: np.ndarray) -> None:
        """Merge ascending (|rows|, k) runs into the tracked slots."""
        import jax.numpy as jnp
        from repro.kernels.sorted_merge import next_pow2

        rows = np.asarray(rows)
        d = np.asarray(d, np.float32)
        i = np.asarray(i, np.int64)
        # first touch of a slot is a plain store: merging an ascending
        # k-run with the all-(+inf, -1) initial run is the identity, so
        # the disjoint-batch fold (knn_join_batched) never pays the
        # dedup merge — only genuinely revisited slots do
        fresh = ~self._seen[rows]
        if fresh.any():
            fr = rows[fresh]
            self.distances[fr] = d[fresh]
            self.indices[fr] = i[fresh]
            self._seen[fr] = True
            if fresh.all():
                return
            rows, d, i = rows[~fresh], d[~fresh], i[~fresh]

        kp = next_pow2(self.k)
        pad = ((0, 0), (0, kp - self.k))
        ahi, alo = _split_ids(np.pad(self.indices[rows], pad,
                                     constant_values=-1))
        bhi, blo = _split_ids(np.pad(np.asarray(i, np.int64), pad,
                                     constant_values=-1))
        md, (mhi, mlo) = _merge_runs_jit(
            jnp.asarray(np.pad(self.distances[rows], pad,
                               constant_values=np.inf)),
            (jnp.asarray(ahi), jnp.asarray(alo)),
            jnp.asarray(np.pad(d, pad, constant_values=np.inf)),
            (jnp.asarray(bhi), jnp.asarray(blo)))
        self.distances[rows] = np.asarray(md)[:, :self.k]
        self.indices[rows] = _join_ids(
            np.asarray(mhi), np.asarray(mlo))[:, :self.k]


class StreamJoinEngine:
    """Plan + join every incoming R micro-batch against one resident index.

    Holds nothing per-batch: the expensive S-side artifacts live in the
    index (packed pivot-sorted rows, T_S, ``pivd``), each ``join_batch``
    call pays only jitted R assignment + θ/LB + the group joins — or,
    with ``megastep`` enabled, one fused device pass that also folds the
    schedule build and the cross-segment merge into the same jit
    (`core.megastep`), bucketed so repeating ragged batch sizes reuse
    the compiled step instead of re-padding and re-planning.

    ``index`` may be a build-once ``SIndex`` or a mutable segmented
    ``core.segments.MutableIndex`` — the latter fans each batch over all
    live segments (base + deltas + write buffer); the host path folds
    the per-segment sorted runs through the dedup merge, the megastep
    carries the running top-k across segments in VMEM/scan state.

    ``megastep``: ``True`` | ``False`` | ``"auto"`` — auto enables the
    fused path when the metric supports it (L2); ``True`` raises on
    unsupported configs rather than silently falling back.

    ``quantized``: ``True`` routes every batch through the two-tier
    quantized engine (`repro.quant.QuantMegastepEngine`, L2 only):
    int8-resident index payload, coarse scan + exact fp32 re-rank,
    bitwise the oracle's results. Takes precedence over ``megastep``
    (it *is* a megastep-mode engine). Default ``None`` follows
    ``config.quantize``.

    ``n_shards``: partition the resident payload across a mesh of that
    many devices and run the fused pass SPMD (`core.sharded` — bitwise
    the single-device engines, zero steady-state host syncs per shard).
    Requires a megastep-mode path (the host-planned engines have no
    mesh payload); ``n_shards=None`` stays single-device.

    ``replication``: place every pivot group on that many shards (a
    primary + r−1 backups) so the sharded fp32 engine survives shard
    loss bitwise (`core.sharded` failover); ``attempt_timeout`` bounds
    each sharded device attempt so a hung collective counts as a shard
    failure. fp32 sharded path only — the quantized sharded engine does
    not replicate (its HBM budget is the point of int8).
    """

    def __init__(self, index, config: Optional[JoinConfig] = None, *,
                 megastep: object = False, quantized: Optional[bool] = None,
                 n_shards: Optional[int] = None, replication: int = 1,
                 attempt_timeout: Optional[float] = None):
        self.index = index
        self.config = config or index.config
        if quantized is None:
            quantized = self.config.quantize != "none"
        if megastep == "auto":
            megastep = self.config.metric == "l2"
        if (replication != 1 or attempt_timeout is not None) \
                and n_shards is None:
            raise ValueError(
                "replication/attempt_timeout are sharded-engine knobs — "
                "pass n_shards too")
        self._megastep = None
        if quantized:
            if replication != 1:
                raise ValueError(
                    "replication > 1 is the fp32 sharded engine's "
                    "fault-tolerance knob; the quantized sharded engine "
                    "does not replicate (drop quantized, or accept "
                    "r=1)")
            if n_shards is not None:
                from repro.quant.engine import ShardedQuantMegastepEngine
                self._megastep = ShardedQuantMegastepEngine(
                    index, self.config, n_shards=n_shards)
            else:
                from repro.quant.engine import QuantMegastepEngine
                self._megastep = QuantMegastepEngine(index, self.config)
        elif megastep:
            if n_shards is not None:
                from .sharded import ShardedMegastepEngine
                self._megastep = ShardedMegastepEngine(
                    index, self.config, n_shards=n_shards,
                    replication=replication,
                    attempt_timeout=attempt_timeout)
            else:
                from .megastep import MegastepEngine
                self._megastep = MegastepEngine(index, self.config)
        elif n_shards is not None:
            raise ValueError(
                "n_shards requires a megastep-mode engine (megastep=True/"
                "'auto' or quantized=True) — the host-planned path has "
                "no mesh-resident payload to shard")

    @property
    def megastep_engine(self):
        """The fused-path driver when enabled (None on the host path) —
        exposes the device-level `enqueue` / `join_batch_device` API."""
        return self._megastep

    def join_batch(
        self, queries: np.ndarray, *, stats: Optional[JoinStats] = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """(dists, ids) for one micro-batch — true distances ascending,
        global S row indices."""
        queries = np.ascontiguousarray(queries, np.float32)
        if stats is not None:
            stats.n_batches += 1
        if self._megastep is not None:
            return self._megastep.join_batch(queries, stats=stats)
        return self._join_batch_host(queries, stats=stats)

    @property
    def can_dispatch(self) -> bool:
        """True when this engine can split a batch into an async
        ``dispatch`` + ``finalize`` pair (megastep-backed paths only) —
        what the serving scheduler's double-buffered mode keys on."""
        return self._megastep is not None

    def dispatch(self, queries: np.ndarray, *,
                 stats: Optional[JoinStats] = None):
        """Async half of ``join_batch``: enqueue one micro-batch on the
        fused device path and return an opaque ``JoinHandle`` without
        blocking on the result. Pair with :meth:`finalize`. Raises
        ``RuntimeError`` on the host-planned path (no device pipeline
        to overlap with)."""
        if self._megastep is None:
            raise RuntimeError(
                "dispatch() needs a megastep-backed engine; the "
                "host-planned path has no async device half "
                "(use join_batch)")
        queries = np.ascontiguousarray(queries, np.float32)
        if stats is not None:
            stats.n_batches += 1
        return self._megastep.dispatch(queries, stats=stats)

    def finalize(self, handle, *, stats: Optional[JoinStats] = None
                 ) -> tuple[np.ndarray, np.ndarray]:
        """Blocking half of ``join_batch``: fetch + post-process one
        previously dispatched handle into (dists, ids)."""
        if self._megastep is None:
            raise RuntimeError("finalize() needs a megastep-backed engine")
        return self._megastep.finalize(handle, stats=stats)

    def join_batch_host(
        self, queries: np.ndarray, *, stats: Optional[JoinStats] = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """The host-planned oracle path for one micro-batch, regardless
        of how this engine was constructed. Bitwise the same results as
        ``join_batch`` (the exactness contract), but it owns no
        device-resident payload — the serving scheduler retries
        transiently-failed batches here, where an upload/fetch fault
        cannot recur."""
        queries = np.ascontiguousarray(queries, np.float32)
        if stats is not None:
            stats.n_batches += 1
        from repro import obs
        with obs.span("stream.host_join", rows=queries.shape[0]):
            return self._join_batch_host(queries, stats=stats)

    def _join_batch_host(self, queries, *, stats=None):
        from .api import execute_join
        from .segments import MutableIndex

        if stats is not None:
            stats.n_r += queries.shape[0]
            stats.n_s = max(stats.n_s, self.index.n_s)
        if isinstance(self.index, MutableIndex):
            return self.index.join_batch(queries, config=self.config,
                                         stats=stats)
        qplan = plan_queries(queries, self.index, self.config)
        if stats is not None:
            stats.pivot_pairs_computed += (
                queries.shape[0] * self.index.n_pivots)
        return execute_join(queries, self.index, qplan, stats=stats)


def _iter_batches(r, batch_size: int):
    if isinstance(r, np.ndarray):
        for lo in range(0, r.shape[0], batch_size):
            yield r[lo:lo + batch_size]
    else:
        yield from r


def knn_join_batched(
    r: Union[np.ndarray, Iterable[np.ndarray]],
    s: Optional[np.ndarray] = None,
    k: int | None = None,
    config: Optional[JoinConfig] = None,
    *,
    index=None,
    batch_size: int = 0,
    megastep: object = False,
    quantized: Optional[bool] = None,
    n_shards: Optional[int] = None,
    replication: int = 1,
) -> JoinResult:
    """Streaming PGBJ join: R in micro-batches against a build-once index.

    ``r`` is either one array (split into ``batch_size`` chunks; 0 =
    ``config.batch_size`` or single batch) or an iterable of micro-batch
    arrays. ``index=`` reuses a prebuilt ``SIndex`` (or a mutable
    segmented ``MutableIndex``) — S-side phase 1 never re-runs on
    pre-existing segments; otherwise the index is built here from ``s``
    (pivots sampled from S: the query set is not assumed to exist up
    front). ``megastep=True`` (or "auto") runs each batch through the
    fused device-resident megastep instead of the host-planned path —
    identical results, one jitted pass per batch. ``quantized=True``
    runs each batch through the two-tier int8 engine (`repro.quant`) —
    identical results again, 4× smaller resident index. ``n_shards=N``
    shards either megastep-mode payload across an N-device mesh
    (`core.sharded`) — identical results once more, N× the HBM.
    ``replication=r`` (fp32 sharded path) additionally places every
    pivot group on r shards so the join survives shard loss bitwise.

    Exactness: equals one-shot ``knn_join`` against the same index for
    any batch split. Results are ordered by arrival: row ``j`` of the
    output is the ``j``-th query row seen across the batches.
    """
    if index is not None:
        config = config or index.config
    config = config or JoinConfig(k=k or 10)
    if k is not None and k != config.k:
        config = dataclasses.replace(config, k=k)
    built_here = index is None
    if index is None:
        if s is None:
            raise ValueError("knn_join_batched needs s= or a prebuilt index")
        s = np.ascontiguousarray(s, np.float32)
        if config.k > s.shape[0]:
            raise ValueError(f"k={config.k} > |S|={s.shape[0]}")
        index = build_index(s, config)
    else:
        if s is not None and s.shape[0] != index.n_s:
            raise ValueError(
                f"s has {s.shape[0]} rows but the prebuilt index holds "
                f"{index.n_s}; results would index the wrong dataset")
        if config.k > index.n_s:
            raise ValueError(f"k={config.k} > |S|={index.n_s}")

    if batch_size <= 0:
        batch_size = config.batch_size
    if batch_size <= 0:
        batch_size = r.shape[0] if isinstance(r, np.ndarray) else 1 << 62
    batch_size = max(1, batch_size)   # |R| = 0 must not zero the stride

    engine = StreamJoinEngine(index, config, megastep=megastep,
                              quantized=quantized, n_shards=n_shards,
                              replication=replication)
    stats = JoinStats(n_s=index.n_s)
    if built_here:   # a reused index's S phase 1 was paid at build time
        stats.pivot_pairs_computed += index.n_s * index.n_pivots
    chunks_d, chunks_i, seen = [], [], 0
    state: Optional[StreamJoinState] = None
    for batch in _iter_batches(r, batch_size):
        batch = np.ascontiguousarray(batch, np.float32)
        if batch.shape[0] == 0:
            continue
        bd, bi = engine.join_batch(batch, stats=stats)
        chunks_d.append(bd)
        chunks_i.append(bi)
        seen += batch.shape[0]
    stats.n_r = seen
    if seen == 0:
        return JoinResult(
            indices=np.zeros((0, config.k), np.int64),
            distances=np.zeros((0, config.k), np.float32), stats=stats)
    # fold the per-batch runs into one result through the sorted-run
    # merge state (identity merges for disjoint slots — the same path a
    # revisiting caller exercises with genuine merges)
    state = StreamJoinState(n=seen, k=config.k)
    lo = 0
    for bd, bi in zip(chunks_d, chunks_i):
        state.update(np.arange(lo, lo + bd.shape[0]), bd, bi)
        lo += bd.shape[0]
    return JoinResult(indices=state.indices, distances=state.distances,
                      stats=stats)
