"""Version portability for the distributed path.

The sharding surface moved between JAX releases: ``shard_map`` graduated
from ``jax.experimental`` to ``jax.shard_map``; its replication check was
renamed ``check_rep`` → ``check_vma``; ``jax.lax.pvary`` and
``jax.sharding.AxisType`` only exist with the newer varying-manual-axes
type system; ``jax.make_mesh`` gained ``axis_types``. Every caller in
this repo goes through the aliases below so both API generations run the
same code (CI pins whatever the image ships).
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax

__all__ = ["shard_map", "pvary", "make_mesh"]


if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KEYS = ("check_vma", "check_rep")
else:  # pre-graduation JAX
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KEYS = ("check_rep", "check_vma")

_CHECK_KEY = next(
    (key for key in _CHECK_KEYS
     if key in inspect.signature(_shard_map).parameters), None)


def shard_map(f=None, /, **kwargs):
    """``jax.shard_map`` with ``check_vma``/``check_rep`` normalized.

    Pass ``check_vma=False`` regardless of JAX version; it is renamed (or
    dropped, if neither spelling exists) to fit the installed API.
    """
    for alias in ("check_vma", "check_rep"):
        if alias in kwargs and alias != _CHECK_KEY:
            val = kwargs.pop(alias)
            if _CHECK_KEY is not None:
                kwargs.setdefault(_CHECK_KEY, val)
    if f is None:
        return lambda g: _shard_map(g, **kwargs)
    return _shard_map(f, **kwargs)


def pvary(x, axis_names):
    """``jax.lax.pvary`` or identity where the VMA type system is absent.

    Older shard_map has no varying/unvarying distinction, so scan carries
    need no adjustment there — identity is exactly right, not a stub.
    """
    fn = getattr(jax.lax, "pvary", None)
    if fn is None or not axis_names:
        return x
    return fn(x, axis_names)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API has them;
    falls back to a hand-built ``jax.sharding.Mesh`` on JAX versions
    that predate ``jax.make_mesh`` entirely."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if hasattr(jax, "make_mesh"):
        axis_type = getattr(jax.sharding, "AxisType", None)
        if axis_type is not None:
            try:
                return jax.make_mesh(
                    axis_shapes, axis_names,
                    axis_types=(axis_type.Auto,) * len(axis_names))
            except TypeError:  # make_mesh without axis_types kwarg
                pass
        return jax.make_mesh(axis_shapes, axis_names)
    import numpy as np
    n = int(np.prod(axis_shapes))
    devices = np.asarray(jax.devices()[:n]).reshape(axis_shapes)
    return jax.sharding.Mesh(devices, axis_names)
