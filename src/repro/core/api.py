"""Public entry points for the PGBJ kNN join (single-host engine).

The planner is split into two artifacts (see ``core.index``):

* ``SIndex``    — build-once S side: pivots, ``pivd``, S assignment,
                  T_S, and the S rows packed into pivot-sorted tiles.
* ``QueryPlan`` — per-R-batch: assignment, θ, LB matrices, grouping
                  (jitted jnp assignment/bounds math).

``knn_join`` composes them: preprocessing (pivots) → S-side phase 1
(once, or reused via ``index=``) → per-batch query planning → job 2
(replicate + per-group join). ``JoinPlan`` survives as a thin facade
over ``(SIndex, QueryPlan)`` for callers of the pre-split API.

The streaming micro-batch engine lives in ``core.stream``
(``knn_join_batched``); the distributed (shard_map) execution in
``core.distributed`` — both share the index, the planner and the
per-group executor below. ``core.megastep`` fuses the whole per-batch
path (assignment → bounds → schedule → gather top-k → merge) into one
jitted device pass; ``knn_join(megastep=True)`` runs it one-shot, and
the host-planned pipeline here remains its reference oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .index import SIndex, QueryPlan, build_index, plan_queries
from .join import join_group
from .metrics import canonical_topk
from .types import JoinConfig, JoinResult, JoinStats, SummaryTable

__all__ = ["knn_join", "JoinPlan", "plan_join", "execute_join"]


@dataclasses.dataclass
class JoinPlan:
    """Facade over the split planner: one build-once ``SIndex`` + one
    per-batch ``QueryPlan`` presented with the monolithic plan's field
    layout (paper §4.3/§5 — the "compLBOfReplica" product).

    Kept so pre-split callers (baseline benchmarks, the fault-tolerance
    regrouping, existing tests) keep working; new code should hold the
    two parts directly and reuse ``index`` across batches.
    """

    index: SIndex
    query: QueryPlan

    # ---- forwarded S-side (build-once) fields
    @property
    def config(self) -> JoinConfig:
        return self.query.config

    @property
    def pivots(self) -> np.ndarray:
        return self.index.pivots

    @property
    def pivd(self) -> np.ndarray:
        return self.index.pivd

    @property
    def s_part(self) -> np.ndarray:
        return self.index.s_part

    @property
    def s_dist(self) -> np.ndarray:
        return self.index.s_dist

    @property
    def t_s(self) -> SummaryTable:
        return self.index.t_s

    # ---- forwarded R-side (per-batch) fields
    @property
    def r_part(self) -> np.ndarray:
        return self.query.r_part

    @property
    def r_dist(self) -> np.ndarray:
        return self.query.r_dist

    @property
    def t_r(self) -> SummaryTable:
        return self.query.t_r

    @property
    def theta(self) -> np.ndarray:
        return self.query.theta

    @property
    def lb(self) -> np.ndarray:
        return self.query.lb

    @property
    def groups(self) -> np.ndarray:
        return self.query.groups

    @property
    def lb_group(self) -> np.ndarray:
        return self.query.lb_group

    @property
    def n_groups(self) -> int:
        return self.query.n_groups

    def group_of_r(self) -> np.ndarray:
        return self.query.group_of_r()

    def s_replica_mask(self, g: int) -> np.ndarray:
        """Theorem 6 membership test: which S rows (original order) ship
        to group g."""
        return self.index.s_dist >= self.query.lb_group[self.index.s_part, g]


def plan_join(r: np.ndarray, s: np.ndarray, config: JoinConfig) -> JoinPlan:
    """Run preprocessing + job 1 + bound/grouping computation.

    Pivots are selected from R (the paper's prescription); the S side
    then builds once and the R side plans against it — callers that
    reuse S across many query sets should call ``build_index`` +
    ``plan_queries`` directly instead.
    """
    r = np.ascontiguousarray(r, np.float32)
    index = build_index(s, config, pivot_data=r)
    return JoinPlan(index=index, query=plan_queries(r, index, config))


def execute_join(
    r: np.ndarray,
    index: SIndex,
    qplan: QueryPlan,
    *,
    stats: Optional[JoinStats] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Job 2 over one query batch: per-group replicate + join against the
    resident index. Returns (dists (|R|, k), ids (|R|, k)) — ids are
    global S row indices, distances true (non-squared), ascending."""
    cfg = qplan.config
    r = np.ascontiguousarray(r, np.float32)
    out_i = np.full((r.shape[0], cfg.k), -1, np.int64)
    group_of_r = qplan.group_of_r()
    for g in range(qplan.n_groups):
        r_sel = np.where(group_of_r == g)[0]
        if r_sel.size == 0:
            continue
        _, gi = join_group(g, r, r_sel, index, qplan, stats=stats)
        out_i[r_sel] = gi
    # report distances in the shape-canonical form (metrics.canonical_topk)
    # so a query's output is bitwise-independent of its batch's makeup —
    # the contract the streaming engine's any-split equality rests on
    return canonical_topk(r, out_i, index.rows_for_ids(out_i), cfg.metric)


def knn_join(
    r: np.ndarray,
    s: Optional[np.ndarray] = None,
    k: int | None = None,
    config: Optional[JoinConfig] = None,
    *,
    plan: Optional[JoinPlan] = None,
    index=None,
    megastep: bool = False,
    quantized: Optional[bool] = None,
) -> JoinResult:
    """PGBJ kNN join: for every row of ``r``, the k nearest rows of ``s``.

    Returns global S row indices (int64) and true distances, ascending
    per query.

    ``index=`` joins against a prebuilt ``SIndex`` — or a mutable
    segmented ``core.segments.MutableIndex``, whose batch fans over all
    live segments — (S-side phase 1 is *not* re-run; ``s`` may be
    omitted); ``plan=`` additionally reuses a query plan. Otherwise the
    index is built from ``s`` with pivots selected from ``r`` — the
    paper's one-shot pipeline.

    ``megastep=True`` executes the batch through the fused
    device-resident megastep (`core.megastep`, L2 only) instead of the
    host-planned engines — identical results, one jitted pass. This
    one-shot form builds a fresh engine per call; streaming / serving
    callers should hold a ``StreamJoinEngine(megastep=True)`` so the
    uploaded index payload and the compiled step persist across batches.

    ``quantized=True`` (default: on when ``config.quantize != "none"``)
    runs the two-tier quantized engine (`repro.quant`, L2 only): int8
    coarse scan over the index's error-bounded codes, exact fp32
    re-rank of a k+slack shortlist — bitwise the oracle's result with a
    ~4× smaller device-resident index. Implies the megastep-style fused
    planner; like it, a one-shot call builds a fresh engine per call.
    """
    from .segments import MutableIndex

    if plan is not None:
        index = plan.index
    if index is not None:
        config = config or index.config
    config = config or JoinConfig(k=k or 10)
    if k is not None and k != config.k:
        config = dataclasses.replace(config, k=k)
    if quantized is None:
        quantized = config.quantize != "none"
    r = np.ascontiguousarray(r, np.float32)
    if quantized:
        if plan is not None:
            raise ValueError(
                "quantized=True plans on device and cannot reuse plan=; "
                "pass index= instead")
        from repro.quant.engine import QuantMegastepEngine

        built_here = index is None
        if index is None:
            if s is None:
                raise ValueError("knn_join needs s= or a prebuilt index")
            index = build_index(s, config, pivot_data=r)
            s = None
        if s is not None and s.shape[0] != index.n_s:
            raise ValueError(
                f"s has {s.shape[0]} rows but the index holds "
                f"{index.n_s}; results would index the wrong dataset")
        if config.k > index.n_s:
            raise ValueError(f"k={config.k} > |S|={index.n_s}")
        stats = JoinStats(n_r=r.shape[0], n_s=index.n_s)
        if built_here:            # always a plain SIndex from build_index
            stats.pivot_pairs_computed += index.n_s * index.n_pivots
        out_d, out_i = QuantMegastepEngine(index, config).join_batch(
            r, stats=stats)
        return JoinResult(indices=out_i, distances=out_d, stats=stats)
    if isinstance(index, MutableIndex):
        if s is not None and s.shape[0] != index.n_s:
            raise ValueError(
                f"s has {s.shape[0]} rows but the mutable index holds "
                f"{index.n_s} live; results would index the wrong dataset")
        if config.k > index.n_s:
            raise ValueError(f"k={config.k} > live |S|={index.n_s}")
        stats = JoinStats(n_r=r.shape[0], n_s=index.n_s)
        if megastep:
            from .megastep import MegastepEngine
            out_d, out_i = MegastepEngine(index, config).join_batch(
                r, stats=stats)
        else:
            out_d, out_i = index.join_batch(r, config=config, stats=stats)
        return JoinResult(indices=out_i, distances=out_d, stats=stats)
    built_here = index is None
    if index is None:
        if s is None:
            raise ValueError("knn_join needs s= or a prebuilt plan/index")
        s = np.ascontiguousarray(s, np.float32)
        if config.k > s.shape[0]:
            raise ValueError(f"k={config.k} > |S|={s.shape[0]}")
        index = build_index(s, config, pivot_data=r)
    else:
        if s is not None and s.shape[0] != index.n_s:
            raise ValueError(
                f"s has {s.shape[0]} rows but the prebuilt index holds "
                f"{index.n_s}; results would index the wrong dataset")
        if config.k > index.n_s:
            raise ValueError(f"k={config.k} > |S|={index.n_s}")
    if megastep:
        # the fused path plans on device inside its own jit — a caller's
        # prebuilt QueryPlan cannot be honored, so reject rather than
        # silently discard it
        if plan is not None:
            raise ValueError(
                "megastep=True plans on device and cannot reuse plan=; "
                "pass index= (the megastep re-derives the query side "
                "in-jit) or drop megastep")
        from .megastep import MegastepEngine
        stats = JoinStats(n_r=r.shape[0], n_s=index.n_s)
        if built_here:
            stats.pivot_pairs_computed += index.n_s * index.n_pivots
        out_d, out_i = MegastepEngine(index, config).join_batch(
            r, stats=stats)
        return JoinResult(indices=out_i, distances=out_d, stats=stats)
    if plan is not None:
        qplan = plan.query
        if config is not qplan.config:
            # honor the caller's k/reducer/tile knobs against the reused
            # bounds; θ/LB computed for plan.k stay sound only for k at
            # most plan.k (smaller k needs fewer candidates shipped) and
            # only in the metric they were derived for
            if config.k > qplan.config.k:
                raise ValueError(
                    f"k={config.k} > plan was built for k={qplan.config.k}; "
                    f"re-plan with plan_queries")
            if config.metric != qplan.config.metric:
                raise ValueError(
                    f"metric={config.metric!r} but the plan was built with "
                    f"{qplan.config.metric!r}")
            qplan = dataclasses.replace(qplan, config=config)
    else:
        qplan = plan_queries(r, index, config)
    stats = JoinStats(n_r=r.shape[0], n_s=index.n_s)
    # job-1 mapper pivot distances count toward Eq. 13 (paper §6 note);
    # a reused index's S-side phase 1 was paid at build, not here
    if built_here:
        stats.pivot_pairs_computed += index.n_s * index.n_pivots
    stats.pivot_pairs_computed += r.shape[0] * index.n_pivots
    out_d, out_i = execute_join(r, index, qplan, stats=stats)
    return JoinResult(indices=out_i, distances=out_d, stats=stats)
