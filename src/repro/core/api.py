"""Public entry points for the PGBJ kNN join (single-host engine).

``knn_join`` runs the full paper pipeline:
   preprocessing (pivots) → job 1 (partition + summaries) →
   host grouping/bounds → job 2 (replicate + per-group join).

The distributed (shard_map) execution lives in ``core.distributed``; it
shares every stage of this module except the final per-group loop, which
it runs as SPMD over the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import bounds as B
from . import grouping as G
from .join import join_group_dense, join_group_gather, join_group_pruned
from .partition import assign_and_summarize
from .pivots import select_pivots
from .schedule import build_tile_schedule
from .types import JoinConfig, JoinResult, JoinStats, SummaryTable

__all__ = ["knn_join", "JoinPlan", "plan_join"]


@dataclasses.dataclass
class JoinPlan:
    """Everything job 2 needs, computed before any shuffle (paper §4.3/§5).

    This is the "compLBOfReplica" product: pivots, summary tables, θ, the
    LB matrices and the grouping. It is cheap (O(M²)) and host-resident —
    the distributed runtime broadcasts it to every worker like the paper
    loads pivots into every mapper.
    """

    config: JoinConfig
    pivots: np.ndarray           # (M, dim)
    pivd: np.ndarray             # (M, M)
    r_part: np.ndarray           # (|R|,)
    r_dist: np.ndarray           # (|R|,)
    s_part: np.ndarray           # (|S|,)
    s_dist: np.ndarray           # (|S|,)
    t_r: SummaryTable
    t_s: SummaryTable
    theta: np.ndarray            # (M,)
    lb: np.ndarray               # (M_s, M_r)   Cor. 2
    groups: np.ndarray           # (M,) group id per R-partition
    lb_group: np.ndarray         # (M_s, N)     Thm 6

    @property
    def n_groups(self) -> int:
        return int(self.lb_group.shape[1])

    def group_of_r(self) -> np.ndarray:
        return self.groups[self.r_part]

    def s_replica_mask(self, g: int) -> np.ndarray:
        """Theorem 6 membership test: which S rows ship to group g."""
        return self.s_dist >= self.lb_group[self.s_part, g]


def plan_join(r: np.ndarray, s: np.ndarray, config: JoinConfig) -> JoinPlan:
    """Run preprocessing + job 1 + host-side bound/grouping computation."""
    r = np.ascontiguousarray(r, np.float32)
    s = np.ascontiguousarray(s, np.float32)
    m = min(config.n_pivots, r.shape[0])
    pivots = select_pivots(
        r, m, config.pivot_strategy,
        sample=config.pivot_sample,
        n_sets=config.pivot_candidate_sets,
        seed=config.seed)
    r_part, r_dist, t_r = assign_and_summarize(r, pivots,
                                               metric=config.metric)
    s_part, s_dist, t_s = assign_and_summarize(s, pivots, k=config.k,
                                               metric=config.metric)
    pivd = B.pivot_distance_matrix(pivots, config.metric)
    theta = B.compute_theta(pivd, t_r, t_s, config.k)
    lb = B.replication_lower_bounds(pivd, t_r, theta)
    n_groups = min(config.n_groups, m)
    groups = G.group_partitions(
        config.grouping, pivd, t_r, n_groups, lb=lb, t_s=t_s)
    lb_group = B.group_lower_bounds(lb, groups, n_groups)
    return JoinPlan(
        config=config, pivots=pivots, pivd=pivd,
        r_part=r_part, r_dist=r_dist, s_part=s_part, s_dist=s_dist,
        t_r=t_r, t_s=t_s, theta=theta, lb=lb,
        groups=groups, lb_group=lb_group)


def knn_join(
    r: np.ndarray,
    s: np.ndarray,
    k: int | None = None,
    config: Optional[JoinConfig] = None,
    *,
    plan: Optional[JoinPlan] = None,
) -> JoinResult:
    """PGBJ kNN join: for every row of ``r``, the k nearest rows of ``s``.

    Returns global S row indices and true distances, ascending per query.
    """
    config = config or JoinConfig(k=k or 10)
    if k is not None and k != config.k:
        config = dataclasses.replace(config, k=k)
    if config.k > s.shape[0]:
        raise ValueError(f"k={config.k} > |S|={s.shape[0]}")
    r = np.ascontiguousarray(r, np.float32)
    s = np.ascontiguousarray(s, np.float32)
    if plan is None:
        plan = plan_join(r, s, config)
    stats = JoinStats(n_r=r.shape[0], n_s=s.shape[0])
    # job-1 mapper pivot distances count toward Eq. 13 (paper §6 note)
    stats.pivot_pairs_computed += (r.shape[0] + s.shape[0]) * plan.pivots.shape[0]

    out_d = np.full((r.shape[0], config.k), np.inf, np.float32)
    out_i = np.full((r.shape[0], config.k), -1, np.int64)
    s_ids_all = np.arange(s.shape[0], dtype=np.int64)
    group_of_r = plan.group_of_r()
    reducer = config.resolved_reducer
    for g in range(plan.n_groups):
        r_sel = np.where(group_of_r == g)[0]
        if r_sel.size == 0:
            continue
        s_mask = plan.s_replica_mask(g)
        stats.replicas_s += int(s_mask.sum())
        s_sel = np.where(s_mask)[0]
        if reducer == "gather":
            gd, gi = _join_group_gather(
                r, s, r_sel, s_sel, s_ids_all, plan, config, stats)
        elif reducer == "pruned":
            gd, gi = join_group_pruned(
                r[r_sel], plan.r_part[r_sel],
                s[s_sel], plan.s_part[s_sel], plan.s_dist[s_sel],
                s_ids_all[s_sel],
                plan.pivots, plan.pivd, plan.theta,
                plan.t_s.lower, plan.t_s.upper, config.k,
                tile_r=config.tile_r, tile_s=config.tile_s, stats=stats,
                metric=config.metric)
        else:
            gd, gi = join_group_dense(
                r[r_sel], s[s_sel], s_ids_all[s_sel], config.k,
                tile_r=config.tile_r, tile_s=config.tile_s, stats=stats,
                metric=config.metric)
        out_d[r_sel] = gd
        out_i[r_sel] = gi
    return JoinResult(indices=out_i, distances=out_d, stats=stats)


def _join_group_gather(r, s, r_sel, s_sel, s_ids_all, plan, config, stats):
    """One group through the pruned-schedule path.

    Queries are sorted by home partition and S replicas by (partition,
    pivot distance) so tiles are partition-coherent — that layout is what
    makes the tile-granular ring bounds bite. On TPU the compacted
    schedule feeds the scalar-prefetch Pallas kernel (pruned tiles never
    DMA); elsewhere its host twin walks the identical schedule.
    """
    order_r = np.argsort(plan.r_part[r_sel], kind="stable")
    rr = np.ascontiguousarray(r[r_sel][order_r])
    rp = plan.r_part[r_sel][order_r]
    order_s = np.lexsort((plan.s_dist[s_sel], plan.s_part[s_sel]))
    ss = np.ascontiguousarray(s[s_sel][order_s])
    sp = plan.s_part[s_sel][order_s]
    sd = plan.s_dist[s_sel][order_s]
    sids = s_ids_all[s_sel][order_s]

    sched = build_tile_schedule(
        rr, rp, sp, sd, plan.pivots, plan.pivd, plan.theta,
        bm=config.tile_r, bn=config.tile_s, metric=config.metric,
        knn_dists=plan.t_s.knn_dists, k=config.k, stats=stats)

    from repro.kernels import ops
    if config.metric == "l2" and ops.use_pallas():
        import jax.numpy as jnp
        d, i_local = ops.distance_topk(
            jnp.asarray(rr), jnp.asarray(ss), config.k,
            schedule=jnp.asarray(sched.schedule),
            counts=jnp.asarray(sched.counts),
            bm=config.tile_r, bn=config.tile_s, impl="gather")
        gd = np.asarray(d)
        il = np.asarray(i_local)
        gi = np.where(il >= 0, sids[np.clip(il, 0, len(sids) - 1)], -1)
        stats.tiles_total += sched.nr_tiles * sched.ns_tiles
        stats.tiles_visited += sched.n_visits
        stats.pairs_computed += sched.n_visits * config.tile_r * config.tile_s
    else:
        gd, gi = join_group_gather(
            rr, ss, sids, config.k, sched, stats=stats,
            metric=config.metric)
    # undo the query sort
    inv = np.empty_like(order_r)
    inv[order_r] = np.arange(order_r.size)
    return gd[inv], gi[inv]
