"""Fused device-resident query megastep — one jitted pass per micro-batch.

The split planner (core.index) made per-batch planning cheap; this module
makes it *disappear from the host entirely*. One jitted function runs, per
R micro-batch and with no host round-trip in steady state:

1. **assign** — query→pivot distances + home partitions for every live
   index segment (shared with the schedule bounds);
2. **bounds** — a per-query kNN-radius θ from the union of all segments'
   T_S pivot-kNN lists (Thm 3 evaluated at the query), widened by the
   live tombstone count so masking dead rows can never starve the top-k;
3. **schedule** — Cor. 1 / Thm 2 lowered to jnp (`core.schedule.
   visit_mask_jnp`) per segment, concatenated over the segments' tile
   ranges and prefix-compacted with segment-sum ranks + a flat scatter
   (`compact_visits_jnp`) — same shapes every call, so it traces once;
4. **gather top-k** — the scalar-prefetch Pallas kernel
   (`kernels.distance_topk.distance_topk_gather_pallas`, alive-masked) on
   TPU, or its schedule-driven `lax.scan` twin here on CPU. The running
   per-query top-k is carried across the *whole concatenated schedule* in
   VMEM scratch (scan carry on CPU), so multi-segment fan-out is one
   launch and per-segment runs never round-trip through HBM;
5. **merge** — canonical distance recompute (`metrics.canonical_gathered`
   — bitwise the same graph the host path's `gathered_dist` runs),
   global-id mapping as (hi, lo) int32 pairs, the canonical stable
   re-sort, and optionally an odd-even dedup merge with a carried
   device-resident stream state (`kernels.sorted_merge.
   merge_sorted_runs_unique`).

Ragged batch sizes are padded to power-of-two buckets and the compiled
megastep is cached per (bucket, k, segment-structure) — jax.jit's cache
keyed by the static metadata — so steady-state serving never recompiles
and never re-plans: three identical ragged batches cost one trace
(`trace_count` lets tests pin this).

Exactness: the scheduled candidate set is a superset of the true live
top-k (θ is a sound union-level radius bound: the (k + dead)-th smallest
of the per-row upper bounds dominates the k-th nearest live row), the
selection over it is exact, and the reported distances are the canonical
per-pair values — so the megastep is bitwise-identical (distances and
int64 ids, up to float-tie ordering) to the host-planned reference path
it shadows. The host engines stay untouched as the oracle.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro import obs

from .metrics import canonical_gathered
from .schedule import compact_visits_jnp, visit_mask_jnp
from .types import JoinConfig, JoinStats

__all__ = ["MegastepEngine", "JoinHandle", "trace_count"]

_TRACE_COUNT = 0


def trace_count() -> int:
    """Number of megastep traces (== jit cache misses) this process has
    paid. Steady-state serving must not grow this — pinned by tests."""
    return _TRACE_COUNT


def _bump_trace() -> None:
    """Called from inside a jitted megastep body: runs at trace time only,
    so every execution after the first is invisible to `trace_count`.
    Shared with the quantized tier's fused megastep (repro.quant.engine)."""
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def _next_pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())


# ---------------------------------------------------------------------------
# the jitted megastep


def _assign_bounds_schedule(q, n_valid, dead_total, segs, center, *,
                            k: int, bm: int, metric: str,
                            n_finite_total: int, seg_meta: tuple,
                            primary: int):
    """Stages 1–3 of the megastep (assign → union θ → compacted tile
    schedule), shared — called inside a jit — by the fp32 megastep and
    the quantized tier's coarse pass (`repro.quant.engine`), so both
    consume the identical schedule/θ graph.

    Returns ``(qs, qcs, valid_s, perm, inv, th_q, sched, cnt)``: the
    home-partition-sorted queries (raw and center-relative), their
    validity mask, the sort permutation and its inverse, the per-query
    θ (−inf on padding rows), and the compacted concatenated visit
    schedule with its per-R-tile counts.
    """
    import jax.numpy as jnp

    b = q.shape[0]
    valid_q = jnp.arange(b) < n_valid
    qc = q - center[None, :]

    # ---- 1. assignment against every segment's pivots (shared with the
    # schedule bounds: the same (B, M) distance matrix feeds both)
    qps, homes = [], []
    for g, (m, kk, _) in enumerate(seg_meta):
        pc = segs[g]["pivots_c"]
        d2 = (jnp.sum(qc * qc, 1)[:, None] + jnp.sum(pc * pc, 1)[None, :]
              - 2.0 * jax.lax.dot_general(
                  qc, pc, (((1,), (1,)), ((), ())),
                  preferred_element_type=jnp.float32))
        d2 = jnp.maximum(d2, 0.0)
        qps.append(jnp.sqrt(d2))
        homes.append(jnp.argmin(d2, axis=1).astype(jnp.int32))

    # sort queries by the primary (largest) segment's home partition so R
    # tiles are partition-coherent — the layout the tile bounds bite on;
    # padding rows sort last. Undone on the way out via ``inv``.
    m_primary = seg_meta[primary][0]
    sort_key = jnp.where(valid_q, homes[primary], m_primary)
    perm = jnp.argsort(sort_key, stable=True)
    inv = jnp.argsort(perm)
    qs = q[perm]
    qcs = qc[perm]
    valid_s = valid_q[perm]
    qps = [qp[perm] for qp in qps]
    homes = [h[perm] for h in homes]

    # ---- 2. union θ: k-th (+ dead widening) smallest upper bound over
    # every segment's pivot-kNN candidates (Thm 3 at the query, exact for
    # the union top-k; see module docstring)
    ubs = [(qps[g][:, :, None] + segs[g]["knn"][None, :, :kk]
            ).reshape(b, m * kk)
           for g, (m, kk, _) in enumerate(seg_meta)]
    ub = jnp.concatenate(ubs, axis=1)
    c_total = ub.shape[1]
    # capped order statistic instead of a full sort (XLA sort is the slow
    # op here): bounds for up to w_cap − k tombstones stay tight, beyond
    # that θ degrades to +inf (visit everything — still exact; compaction
    # is overdue anyway at that point)
    w_cap = min(c_total, max(2 * k, 64))
    small = -jax.lax.top_k(-ub, w_cap)[0]            # ascending smallest
    dead = jnp.maximum(dead_total.astype(jnp.int32), 0)
    j = k - 1 + dead
    idx = jnp.broadcast_to(jnp.minimum(j, w_cap - 1), (b, 1))
    th = jnp.take_along_axis(small, idx, axis=1)[:, 0]
    fits = ((k + dead) <= n_finite_total) & (j < w_cap)
    th = jnp.where(fits, th, jnp.inf)          # no valid bound: visit all
    th_q = jnp.where(valid_s, th, -jnp.inf)    # padding: schedule nothing

    # ---- 3. per-segment visit masks, concatenated + prefix-compacted
    visits = [visit_mask_jnp(qps[g], homes[g], th_q, valid_s,
                             segs[g]["pivd"], segs[g]["sd_min"],
                             segs[g]["sd_max"], segs[g]["present"],
                             bm=bm, metric=metric)
              for g in range(len(seg_meta))]
    sched, cnt = compact_visits_jnp(jnp.concatenate(visits, axis=1))
    return qs, qcs, valid_s, perm, inv, th_q, sched, cnt


def _gather_topk_run(qs, qcs, valid_s, sched, cnt, tiles, *,
                     k: int, bm: int, bn: int, metric: str, dim: int,
                     impl: str):
    """Stage 4 of the megastep: gather-top-kp over the (possibly
    per-shard) compacted schedule. Factored out of `_megastep` so the
    sharded engine (`core.sharded`) can run the identical graph inside a
    ``shard_map`` body against one shard's tiles. The run keeps
    kp ≥ k candidates so the canonical re-rank resolves the rank-k
    boundary with exact distances, not the selection metric's fp noise.

    Returns ``(d_run, pos, valid_sel)``: the ascending selection-metric
    run, packed-row positions (−1 = empty slot) and the validity mask.
    """
    import jax.numpy as jnp

    from repro.kernels.sorted_merge import merge_sorted_runs, next_pow2

    b = qs.shape[0]
    nr_tiles = b // bm
    kp = next_pow2(k)
    center = tiles["center"]
    t_total = sched.shape[1]

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.distance_topk import distance_topk_gather_pallas
        d_run, pos = distance_topk_gather_pallas(
            qs, tiles["s"], kp, sched, cnt, alive=tiles["alive"],
            bm=bm, bn=bn, interpret=impl == "pallas_interpret")
        valid_sel = (pos >= 0) & jnp.isfinite(d_run)
    elif impl == "ref_sched":
        # schedule-driven scan twin of the Pallas kernel: same visit
        # list, same carried sorted run — the CPU validation path for
        # the in-jit schedule consumption
        s_tiles = tiles["s"].reshape(t_total, bn, dim)
        alive_t = tiles["alive"].reshape(t_total, bn)
        q3 = qcs.reshape(nr_tiles, bm, dim)
        q3n = jnp.sum(q3 * q3, axis=-1)
        kt = min(kp, bn)

        def body(carry, xs):
            cd, ci = carry
            tile_idx, j = xs
            st = s_tiles[tile_idx] - center[None, None, :]
            al = alive_t[tile_idx]                       # (nr_tiles, bn)
            d2 = (q3n[..., None] + jnp.sum(st * st, -1)[:, None, :]
                  - 2.0 * jnp.einsum("abd,acd->abc", q3, st))
            d2 = jnp.maximum(d2, 0.0)
            live = ((j < cnt)[:, None, None]) & (al[:, None, :] > 0.0)
            d2 = jnp.where(live, d2, jnp.inf)
            pos_row = tile_idx[:, None] * bn + jnp.arange(bn)[None, :]
            neg, ii = jax.lax.top_k(-d2, kt)
            td = -neg
            ti = jnp.take_along_axis(
                jnp.broadcast_to(pos_row[:, None, :], d2.shape), ii, axis=2)
            if kt < kp:
                padc = [(0, 0)] * 2 + [(0, kp - kt)]
                td = jnp.pad(td, padc, constant_values=jnp.inf)
                ti = jnp.pad(ti, padc, constant_values=-1)
            return merge_sorted_runs(cd, ci, td, ti), None

        carry0 = (jnp.full((nr_tiles, bm, kp), jnp.inf, jnp.float32),
                  jnp.full((nr_tiles, bm, kp), -1, jnp.int32))
        (cd, ci), _ = jax.lax.scan(
            body, carry0,
            (sched.T, jnp.arange(t_total, dtype=jnp.int32)))
        d_run = cd.reshape(b, kp)
        pos = ci.reshape(b, kp)
        valid_sel = (pos >= 0) & jnp.isfinite(d_run)
    else:
        # "ref": dense alive-masked selection — one gemm + one top_k. On
        # CPU the scan/kernel's per-slot pruning cannot elide FLOPs (the
        # schedule width is static), so the dense form is strictly
        # faster; XLA dead-code-eliminates the unused schedule here. The
        # TPU path and ref_sched consume it for real.
        sc = tiles["s"] - center[None, :]
        d2 = (jnp.sum(qcs * qcs, 1)[:, None] + jnp.sum(sc * sc, 1)[None, :]
              - 2.0 * jax.lax.dot_general(
                  qcs, sc, (((1,), (1,)), ((), ())),
                  preferred_element_type=jnp.float32))
        d2 = jnp.where(tiles["alive"][None, :] > 0.0,
                       jnp.maximum(d2, 0.0), jnp.inf)
        neg, pos = jax.lax.top_k(-d2, kp)
        d_run = -neg
        valid_sel = (pos >= 0) & jnp.isfinite(d_run)
    return d_run, pos, valid_sel


def _canonical_runs(qs, tiles, pos, valid_sel, metric: str, take: int):
    """Stage-5 head of the megastep: canonical distance recompute over
    the gathered kp-run + global-id mapping + the stable exact re-sort,
    keeping the best ``take`` columns as an ascending sorted run.
    ``take=k`` is the single-device output; the sharded engine keeps the
    full ``take=kp`` run so the in-mesh tree merge sees every column.
    Returns ``(d_can, hi, lo)`` in schedule-sorted query order."""
    import jax.numpy as jnp

    pos_c = jnp.clip(pos, 0, tiles["s"].shape[0] - 1)
    neigh = tiles["s"][pos_c]                               # (b, kp, dim)
    d_can = canonical_gathered(qs, neigh, metric)
    d_can = jnp.where(valid_sel, d_can, jnp.inf)
    hi = jnp.where(valid_sel, tiles["id_hi"][pos_c], -1)
    lo = jnp.where(valid_sel, tiles["id_lo"][pos_c], -1)
    order = jnp.argsort(d_can, axis=1, stable=True)
    d_can = jnp.take_along_axis(d_can, order, axis=1)[:, :take]
    hi = jnp.take_along_axis(hi, order, axis=1)[:, :take]
    lo = jnp.take_along_axis(lo, order, axis=1)[:, :take]
    return d_can, hi, lo


@functools.partial(
    jax.jit,
    static_argnames=("k", "bm", "bn", "metric", "dim", "n_finite_total",
                     "seg_meta", "primary", "impl"))
def _megastep(q, n_valid, dead_total, segs, tiles, state, *,
              k: int, bm: int, bn: int, metric: str, dim: int,
              n_finite_total: int, seg_meta: tuple, primary: int,
              impl: str):
    """assign → bounds → schedule → gather-top-k → merge, one trace.

    ``q`` (B, dim) bucket-padded queries; ``n_valid`` traced scalar;
    ``dead_total`` traced tombstone count; ``segs`` a tuple of per-segment
    device dicts; ``tiles`` the concatenated device S-side; ``state`` an
    optional carried (d, id_hi, id_lo) device run to dedup-merge into.
    ``seg_meta`` is the static per-segment (M, kk, ns_tiles) signature —
    part of the jit cache key, so a changed segment structure retraces
    while steady-state batches hit the cache.
    """
    _bump_trace()              # runs at trace time only == jit cache miss

    import jax.numpy as jnp

    from repro.kernels.sorted_merge import merge_sorted_runs_unique, \
        next_pow2

    kp = next_pow2(k)
    center = tiles["center"]
    qs, qcs, valid_s, perm, inv, th_q, sched, cnt = _assign_bounds_schedule(
        q, n_valid, dead_total, segs, center, k=k, bm=bm, metric=metric,
        n_finite_total=n_finite_total, seg_meta=seg_meta, primary=primary)

    d_run, pos, valid_sel = _gather_topk_run(
        qs, qcs, valid_s, sched, cnt, tiles, k=k, bm=bm, bn=bn,
        metric=metric, dim=dim, impl=impl)

    # ---- 5. canonical distances + global ids + stable re-sort (the
    # exact re-rank over the kp-run) + optional carried-state merge
    d_can, hi, lo = _canonical_runs(qs, tiles, pos, valid_sel, metric, k)
    d_can, hi, lo = d_can[inv], hi[inv], lo[inv]

    if state is not None:
        sd, shi, slo = state
        pad = ((0, 0), (0, kp - k))
        md, (mhi, mlo) = merge_sorted_runs_unique(
            jnp.pad(sd, pad, constant_values=jnp.inf),
            (jnp.pad(shi, pad, constant_values=-1),
             jnp.pad(slo, pad, constant_values=-1)),
            jnp.pad(d_can, pad, constant_values=jnp.inf),
            (jnp.pad(hi, pad, constant_values=-1),
             jnp.pad(lo, pad, constant_values=-1)))
        d_can, hi, lo = md[:, :k], mhi[:, :k], mlo[:, :k]
    return d_can, hi, lo


# ---------------------------------------------------------------------------
# device-resident index payload


@dataclasses.dataclass
class _Payload:
    """Everything the jitted megastep consumes, already on device."""

    segs: tuple           # per-segment dicts of jnp arrays
    tiles: dict           # concatenated: s, alive, id_hi, id_lo, center
    dead_total: object    # () int32 device scalar
    seg_meta: tuple       # static ((M, kk, ns_tiles), ...)
    dim: int
    n_finite_total: int
    primary: int


@dataclasses.dataclass
class JoinHandle:
    """An in-flight batch: device futures from :meth:`MegastepEngine.
    dispatch`, redeemed by :meth:`MegastepEngine.finalize`.

    JAX dispatches jitted calls asynchronously (on CPU too), so the
    arrays in ``dev`` are futures — the device computes while the host
    does other work (the serving scheduler's double-buffered dispatch
    overlaps batch N's finalize with batch N+1's dispatch through exactly
    this split). ``kind`` routes finalize: the fp32 megastep ("mega"),
    the quantized tier's fused resident path ("quant_resident") and its
    low-memory host-gather fallback ("quant_host"); ``q`` keeps the
    original query rows only where finalize may need a host-side
    fallback re-run (the quantized certification paths).
    """

    kind: str
    n: int
    dev: tuple = ()
    q: Optional[np.ndarray] = None


def _in_sorted(ids: np.ndarray, sorted_ids: np.ndarray) -> np.ndarray:
    if sorted_ids.size == 0:
        return np.zeros(ids.shape, bool)
    pos = np.clip(np.searchsorted(sorted_ids, ids), 0, sorted_ids.size - 1)
    return sorted_ids[pos] == ids


class MegastepEngine:
    """Bucketed, compile-cached driver of the fused query megastep.

    Holds the index's device-resident artifacts (packed rows, per-tile
    Thm-2 stats, pivot geometry, pivot-kNN lists, liveness mask) and
    re-uploads them only when the index version changes; every
    ``join_batch`` in between is one upload (the queries), one jitted
    call, one fetch. Accepts a build-once ``SIndex`` or a mutable
    segmented ``core.segments.MutableIndex`` — all live segments
    (including the unsealed write buffer, viewed through an ephemeral
    delta index) fan through a single concatenated-schedule kernel
    launch. L2 only: the megastep's fused bound math is the Euclidean
    Cor. 1 / Thm 2 lowering; other metrics stay on the host engines.

    Cost model: a mutation (insert/seal/delete/compact) bumps the index
    version, and the next batch pays a host-side payload rebuild +
    re-upload (O(|S|) concat; per-segment geometry is cached, so only
    changed segments recompute). Insert-heavy streams should size
    ``seal_threshold`` so queries between mutations amortize the
    refresh — the steady state between mutations transfers nothing.
    """

    # the join_batch path splits into an async dispatch() + finalize()
    # pair — what StreamJoinEngine.can_dispatch and the serving
    # scheduler's double-buffered mode key on
    can_dispatch = True

    def __init__(self, index, config: Optional[JoinConfig] = None, *,
                 bucket_min: int = 16, impl: Optional[str] = None):
        self.index = index
        self.config = config or index.config
        if self.config.metric != "l2":
            raise ValueError(
                f"megastep supports metric='l2' only, got "
                f"{self.config.metric!r}; use the host-planned engines")
        if impl not in (None, "pallas", "pallas_interpret", "ref",
                        "ref_sched"):
            raise ValueError(f"unknown megastep impl {impl!r}")
        self.impl = impl           # None = auto (pallas on TPU, ref here)
        self.bucket_min = max(1, int(bucket_min))
        # tile shapes actually used on device. Defaults follow the config;
        # the quantized tier overrides them from its measured tuning table
        # (repro.quant.autotune) after super().__init__.
        self._bn = int(self.config.tile_s)
        self._bm_cap = 1 << (int(self.config.tile_r).bit_length() - 1)
        # the quantized subclass (repro.quant.engine) keeps the fp32 rows
        # host-side and uploads int8 codes instead — 4× less HBM resident
        # — and resolves global ids host-side, so it skips the (hi, lo)
        # id upload too
        self._upload_fp32 = True
        self._upload_ids = True
        self._struct = None        # (skey, struct dict)
        self._payload = None       # (vkey, _Payload)
        self._seg_cache: dict = {}
        # payload rebuilds read multi-field index state (segments,
        # tombstones, version); a mutation racing that read could cache
        # a torn payload under a *valid* version key. Owners that mutate
        # the index concurrently (serve.Datastore) point this at the
        # same lock their mutations hold, making rebuild and mutation
        # mutually exclusive. Reentrant so an owner already holding it
        # can query.
        self.refresh_lock: threading.RLock = threading.RLock()

    # ---- bucketing

    def bucket_for(self, n: int) -> int:
        return _next_pow2(max(self.bucket_min, n))

    # ---- device payload lifecycle

    def _index_parts(self):
        from .segments import MutableIndex
        if isinstance(self.index, MutableIndex):
            segs = [(si, off) for si, off in self.index.segment_snapshot()
                    if si.n_s > 0]
            return (segs, self.index.tombstones_sorted(),
                    ("mut", id(self.index), self.index.version))
        return ([(self.index, 0)], np.zeros((0,), np.int64),
                ("static", id(self.index)))

    def _refresh(self) -> _Payload:
        import jax.numpy as jnp

        from repro.serve import faultinject

        with self.refresh_lock:
            segs, tomb, vkey = self._index_parts()
            vkey = self._payload_key(vkey)
            if self._payload is not None and self._payload[0] == vkey:
                return self._payload[1]
            if not segs:
                raise ValueError("megastep over an empty index")
            with obs.span("megastep.refresh", n_segments=len(segs),
                          n_tombstones=int(tomb.size)):
                obs.metrics.REGISTRY.counter(
                    "megastep_payload_refresh_total").inc()
                # fault hook: a failure here simulates a device OOM on
                # the payload (re)upload — nothing is cached, the next
                # call rebuilds from scratch
                faultinject.fire("megastep.payload_upload")
                bn = self._bn
                k = self.config.k
                skey = (tuple(id(si) for si, _ in segs), bn, k)
                if self._struct is None or self._struct[0] != skey:
                    self._struct = (skey, self._build_struct(segs, bn, k))
                st = self._struct[1]
                # liveness + tombstone count change per index version;
                # the rows, geometry and tile stats above change only
                # with the structure
                alive = self._alive_mask(st, tomb)
                payload = _Payload(
                    segs=self._segs_for_view(st),
                    tiles=dict(st["tiles_dev"],
                               alive=self._put_alive(alive)),
                    dead_total=self._put_rep(np.int32(tomb.size)),
                    seg_meta=st["seg_meta"], dim=st["dim"],
                    n_finite_total=st["n_finite_total"],
                    primary=st["primary"])
                self._payload = (vkey, payload)
                return payload

    # serving-view hooks: the sharded engines (core.sharded) key the
    # cached payload on shard health, mask rows not served under the
    # current owner view, and gate per-shard `present` to owned
    # partitions. The single-device engine has exactly one view.

    def _payload_key(self, vkey):
        return vkey

    def _alive_mask(self, st, tomb) -> np.ndarray:
        return (st["gids"] >= 0) & ~_in_sorted(st["gids"], tomb)

    def _segs_for_view(self, st):
        return st["segs_dev"]

    # device-placement hooks: the single-device engine just uploads; the
    # sharded engine (core.sharded) overrides these with mesh shardings
    # so liveness lands shard-partitioned and scalars land replicated

    def _put_alive(self, alive: np.ndarray):
        import jax.numpy as jnp
        return jnp.asarray(alive.astype(np.float32))

    def _put_rep(self, x):
        import jax.numpy as jnp
        return jnp.asarray(x)

    def _build_struct(self, segs, bn: int, k: int) -> dict:
        import jax.numpy as jnp

        live_ids = set(id(si) for si, _ in segs)
        self._seg_cache = {key: v for key, v in self._seg_cache.items()
                           if key[0] in live_ids}
        dim = segs[0][0].dim
        rows_parts, gid_parts = [], []
        seg_meta, segs_dev = [], []
        n_finite_total = 0
        sizes = []
        for si, off in segs:
            key = (id(si), bn)
            ent = self._seg_cache.get(key)
            if ent is None:
                ns_tiles = max(1, -(-si.n_s // bn))
                pad = ns_tiles * bn - si.n_s
                rows = np.pad(si.s_sorted, ((0, pad), (0, 0)))
                gids_local = np.pad(si.s_ids_sorted, (0, pad),
                                    constant_values=-1)
                sd_min, sd_max, present = si.tile_stats(bn)
                ent = dict(
                    si=si, ns_tiles=ns_tiles, rows=rows,
                    gids_local=gids_local, pivots=si.pivots,
                    knn_np=si.t_s.knn_dists,
                    pivd=jnp.asarray(si.pivd.astype(np.float32)),
                    knn=jnp.asarray(si.t_s.knn_dists.astype(np.float32)),
                    sd_min=jnp.asarray(sd_min), sd_max=jnp.asarray(sd_max),
                    present=jnp.asarray(present))
                self._seg_cache[key] = ent
            kk = min(k, ent["knn_np"].shape[1])
            n_finite = int(np.isfinite(ent["knn_np"][:, :kk]).sum())
            n_finite_total += n_finite
            seg_meta.append((si.n_pivots, kk, ent["ns_tiles"]))
            rows_parts.append(ent["rows"])
            gid_parts.append(np.where(ent["gids_local"] >= 0,
                                      ent["gids_local"] + off, -1))
            sizes.append(si.n_s)
        rows_all = np.concatenate(rows_parts, axis=0)
        gids = np.concatenate(gid_parts)
        # one shared center for the selection math: distances stay
        # comparable across segments and the ‖x‖²·eps cancellation noise
        # shrinks to O(spread²·eps) (see metrics.cmp_dist)
        n_real = sum(sizes)
        center = (rows_all[gids >= 0].mean(axis=0, dtype=np.float64)
                  .astype(np.float32) if n_real else
                  np.zeros((dim,), np.float32))
        for si, off in segs:
            ent = self._seg_cache[(id(si), bn)]
            segs_dev.append(dict(
                pivots_c=jnp.asarray(ent["pivots"] - center[None, :]),
                pivd=ent["pivd"], knn=ent["knn"], sd_min=ent["sd_min"],
                sd_max=ent["sd_max"], present=ent["present"]))
        tiles_dev = dict(center=jnp.asarray(center))
        if self._upload_ids:
            hi = (gids >> 32).astype(np.int32)
            lo = (gids & np.int64(0xFFFFFFFF)).astype(np.uint32) \
                .view(np.int32)
            tiles_dev["id_hi"] = jnp.asarray(hi)
            tiles_dev["id_lo"] = jnp.asarray(lo)
        if self._upload_fp32:
            tiles_dev["s"] = jnp.asarray(rows_all)
        return dict(
            segs_dev=tuple(segs_dev),
            tiles_dev=tiles_dev,
            # the packed fp32 rows, host-side: only the quantized tier
            # needs them (its exact re-rank gathers shortlists from here
            # instead of HBM) — the fp32 engine must not pin a second
            # full host copy of the index
            rows_host=None if self._upload_fp32 else rows_all,
            gids=gids, seg_meta=tuple(seg_meta), dim=dim,
            n_finite_total=n_finite_total,
            primary=int(np.argmax(sizes)))

    # ---- query API

    def enqueue(self, queries: np.ndarray):
        """Pad one micro-batch to its bucket and upload: returns device
        ``(q, n_valid)`` ready for :meth:`join_batch_device`. This is the
        only host→device transfer a steady-state batch performs."""
        q = np.ascontiguousarray(queries, np.float32)
        import jax.numpy as jnp
        n = q.shape[0]
        bucket = self.bucket_for(n)
        if bucket != n:
            q = np.pad(q, ((0, bucket - n), (0, 0)))
        return jnp.asarray(q), jnp.asarray(np.int32(n))

    def join_batch_device(self, q_dev, n_valid_dev, *, state=None):
        """The zero-host-transfer steady-state call: device-padded
        queries in, device ``(dists, id_hi, id_lo)`` out — one jitted
        megastep, nothing fetched, nothing re-uploaded (the index payload
        is already resident; refresh only re-uploads after a mutation).
        ``state`` optionally carries a previous (dists, id_hi, id_lo) run
        for the same query slots; it is dedup-merged on device.
        """
        from repro.kernels import ops

        payload = self._refresh()
        bucket = int(q_dev.shape[0])
        # largest power of two <= tile_r, so pow2 buckets always reshape
        bm = min(bucket, self._bm_cap)
        impl = self.impl or ("pallas" if ops.use_pallas() else "ref")
        # span timing = host launch bracket of the one fused call; the
        # stage instants record the fused pipeline's structure with
        # host-known attrs only — nothing here fetches or blocks on the
        # device (the zero-steady-state-sync invariant)
        with obs.span("megastep.device_step", bucket=bucket, bm=bm,
                      bn=self._bn, k=self.config.k, impl=impl,
                      n_segments=len(payload.seg_meta)) as sp:
            if obs.enabled():
                for stage in ("assign", "bounds", "schedule",
                              "gather_topk", "merge"):
                    obs.event(f"megastep.{stage}", fused=True)
            out = _megastep(
                q_dev, n_valid_dev, payload.dead_total, payload.segs,
                payload.tiles, state,
                k=self.config.k, bm=bm, bn=self._bn,
                metric=self.config.metric, dim=payload.dim,
                n_finite_total=payload.n_finite_total,
                seg_meta=payload.seg_meta, primary=payload.primary,
                impl=impl)
            sp.set(outcome="launched")
            return out

    def _validated_queries(self, queries: np.ndarray):
        q = np.ascontiguousarray(queries, np.float32)
        if self.config.k > self.index.n_s:
            raise ValueError(f"k={self.config.k} > |S|={self.index.n_s}")
        return q

    def dispatch(self, queries: np.ndarray, *,
                 stats: Optional[JoinStats] = None) -> JoinHandle:
        """The async half of :meth:`join_batch`: validate → refresh the
        resident payload → enqueue → launch the jitted megastep. Returns
        a :class:`JoinHandle` without blocking on the device result —
        redeem it with :meth:`finalize`. The serving scheduler uses this
        split to overlap batch N's fetch/split with batch N+1's device
        pass (double-buffered dispatch)."""
        q = self._validated_queries(queries)
        n = q.shape[0]
        if n == 0:
            return JoinHandle(kind="empty", n=0)
        payload = self._refresh()
        if stats is not None:
            stats.n_r += n
            stats.n_s = max(stats.n_s, self.index.n_s)
            stats.n_segments = len(payload.seg_meta)
            stats.n_tombstones = int(np.asarray(payload.dead_total))
            stats.pivot_pairs_computed += n * sum(
                m for m, _, _ in payload.seg_meta)
        qd, nv = self.enqueue(q)
        d, hi, lo = self.join_batch_device(qd, nv)
        return JoinHandle(kind="mega", n=n, dev=(d, hi, lo))

    def finalize(self, handle: JoinHandle, *,
                 stats: Optional[JoinStats] = None
                 ) -> tuple[np.ndarray, np.ndarray]:
        """Block on a dispatched batch and return ``(dists, int64 ids)``
        — the synchronous tail of :meth:`join_batch`."""
        k = self.config.k
        if handle.kind == "empty":
            return (np.zeros((0, k), np.float32),
                    np.full((0, k), -1, np.int64))
        if handle.kind != "mega":
            raise ValueError(f"cannot finalize handle kind {handle.kind!r}")
        from repro.serve import faultinject
        # the fetch below is the one boundary that synchronizes anyway —
        # bracketing it costs no extra sync, and its wall time is the
        # device-step completion time
        t0 = time.perf_counter()
        with obs.span("megastep.fetch", rows=handle.n):
            faultinject.fire("megastep.fetch")     # simulated lost fetch
            n = handle.n
            d, hi, lo = handle.dev
            d = np.asarray(d)[:n]
            ids = ((np.asarray(hi, np.int64) << 32)
                   | (np.asarray(lo, np.int64)
                      & np.int64(0xFFFFFFFF)))[:n]
        obs.metrics.REGISTRY.histogram("megastep_finalize_s") \
            .observe(time.perf_counter() - t0)
        return np.ascontiguousarray(d), np.ascontiguousarray(ids)

    def join_batch(
        self, queries: np.ndarray, *, stats: Optional[JoinStats] = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """(dists, int64 global ids) for one micro-batch — numpy in/out.
        enqueue → one fused device pass → fetch; bitwise-identical to the
        host-planned path over the same index. Exactly ``finalize(
        dispatch(q))`` — the scheduler calls the halves separately."""
        return self.finalize(self.dispatch(queries, stats=stats),
                             stats=stats)
