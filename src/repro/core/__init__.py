"""PGBJ kNN join — the paper's contribution as a composable JAX module."""
from .types import JoinConfig, JoinResult, JoinStats, SummaryTable
from .pivots import select_pivots
from .partition import assign_to_pivots, build_summary, assign_and_summarize
from .bounds import (
    pivot_distance_matrix, compute_theta, theta_and_lb,
    replication_lower_bounds, group_lower_bounds, hyperplane_distances,
    ring_bounds)
from .grouping import (
    geometric_grouping, greedy_grouping, group_partitions,
    replication_count_exact, replication_count_partitions)
from .index import (
    SIndex, QueryPlan, ShardPacking, build_index, plan_queries,
    as_float32_rows)
from .api import knn_join, plan_join, execute_join, JoinPlan
from .stream import StreamJoinEngine, StreamJoinState, knn_join_batched
from .segments import MutableIndex, Segment
from .megastep import MegastepEngine
from .sharded import ShardedMegastepEngine
from .schedule import (
    TileSchedule, build_tile_schedule, compact_visit_mask,
    segment_tile_stats, visit_mask_jnp, compact_visits_jnp)
from .metrics import pairwise_dist
from .baselines import brute_force_knn, hbrj_join, pbj_join

__all__ = [
    "JoinConfig", "JoinResult", "JoinStats", "SummaryTable",
    "select_pivots", "assign_to_pivots", "build_summary",
    "assign_and_summarize", "pivot_distance_matrix", "compute_theta",
    "theta_and_lb", "replication_lower_bounds", "group_lower_bounds",
    "hyperplane_distances", "ring_bounds",
    "geometric_grouping", "greedy_grouping", "group_partitions",
    "replication_count_exact", "replication_count_partitions",
    "SIndex", "QueryPlan", "ShardPacking", "build_index", "plan_queries",
    "as_float32_rows",
    "knn_join", "plan_join", "execute_join", "JoinPlan",
    "StreamJoinEngine", "StreamJoinState", "knn_join_batched",
    "MutableIndex", "Segment", "MegastepEngine", "ShardedMegastepEngine",
    "TileSchedule", "build_tile_schedule", "compact_visit_mask",
    "segment_tile_stats", "visit_mask_jnp", "compact_visits_jnp",
    "pairwise_dist",
    "brute_force_knn", "hbrj_join", "pbj_join",
]
