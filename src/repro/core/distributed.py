"""Distributed PGBJ execution with shard_map (the MapReduce mapping).

Stage layout (DESIGN.md §2):

  phase 1  (SPMD)  — every device assigns its R/S shard to pivots and
                     computes partial summary tables; ``psum/pmin/pmax``
                     merge them (the paper's job-1 map + stat merge).
                     The S half of this runs **once** per dataset and
                     lives in the ``SIndex`` (core.index); per batch
                     only the R half re-runs inside ``plan_queries``.
  planning (host)  — θ, LB, grouping, **capacity** from the cost model
                     (Thm 7): the static shapes of the shuffle buffers —
                     plus the per-device pruned tile **schedules**
                     (core.schedule) lowered from Cor. 1 / Thm 2.
  phase 2a (SPMD)  — the shuffle: each device packs (group, slot)-addressed
                     send buffers and a single ``all_to_all`` delivers every
                     group's R rows and replicated S rows (paper's job-2
                     map + shuffle). Packing is a vectorized scatter over
                     rows pre-sorted by (partition, pivot distance) — the
                     S side straight from the index's build-once packed
                     layout (no per-batch sort; buffers are reused when
                     ``lb_group`` repeats), R re-packed per batch — so
                     received tiles stay partition-coherent and the
                     schedules bite.
  phase 2b (SPMD)  — per-device reducer: dense top-k join over the
                     received buffers (paper's job-2 reduce) keeping the
                     running top-k as a *sorted run*
                     (kernels.sorted_merge) in a two-level ``lax.scan``.

The schedule-pruned resident reducer that used to live here was subsumed
by the **sharded megastep** (``core.sharded``): it partitions the index
payload across the mesh instead of shuffling rows per batch, runs the
Cor. 1 / Thm 2 compacted schedules per shard, and all-gathers only the
final k-runs. ``distributed_knn_join(reducer="sharded")`` (the default
for L2) routes there; this module keeps the explicit Theorem-6-routed
``all_to_all`` shuffle + dense scan as the any-metric reference mapping
of the paper's job 2.

Static-shape contract: MapReduce shuffles ragged lists; XLA cannot. The
capacities are derived *before* the shuffle from LB/T_S — this is exactly
the paper's replication cost model (Eq. 10) made load-bearing. Padding
rows carry ``valid=False`` and are masked in the join.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.sorted_merge import merge_sorted_runs, next_pow2, tile_topk
from .api import JoinPlan
from .index import QueryPlan, SIndex
from .jax_compat import pvary, shard_map
from .metrics import canonical_topk
from .types import JoinResult, JoinStats

__all__ = ["DistributedJoinSpec", "DistributedJoinEngine",
           "build_shuffle_spec", "distributed_knn_join"]


@dataclasses.dataclass(frozen=True)
class DistributedJoinSpec:
    """Static shapes + host-computed routing for one distributed join."""

    n_devices: int
    cap_r_send: int   # max R rows any device sends to any group
    cap_s_send: int   # max S replicas any device sends to any group
    dim: int
    k: int


def _route_counts(dest: np.ndarray, n_src: int, n_dst: int,
                  src_of_row: np.ndarray) -> int:
    """Max rows on any (src → dst) edge (static capacity)."""
    cnt = np.zeros((n_src, n_dst), np.int64)
    np.add.at(cnt, (src_of_row, dest), 1)
    return int(cnt.max())


def _shuffle_spec(index: SIndex, qplan: QueryPlan,
                  n_devices: int) -> DistributedJoinSpec:
    """Capacities from (index, query plan) (cost model, Thm 7) — no data
    touched."""
    n_r = qplan.r_part.shape[0]
    n_s = index.n_s
    src_r = (np.arange(n_r) * n_devices) // max(n_r, 1)
    g_r = qplan.group_of_r()
    cap_r = _route_counts(g_r, n_devices, qplan.n_groups, src_r)
    # S: replicated edges — count each (src, dst) with multiplicity
    src_s = (np.arange(n_s) * n_devices) // max(n_s, 1)
    ship = index.s_dist[:, None] >= qplan.lb_group[index.s_part]  # (n_s, G)
    cnt = np.zeros((n_devices, qplan.n_groups), np.int64)
    np.add.at(cnt, (np.repeat(src_s, qplan.n_groups),
                    np.tile(np.arange(qplan.n_groups), n_s)), ship.ravel())
    cap_s = int(cnt.max())
    return DistributedJoinSpec(
        n_devices=n_devices,
        cap_r_send=max(1, cap_r),
        cap_s_send=max(1, cap_s),
        dim=index.dim,
        k=qplan.config.k)


def build_shuffle_spec(plan: JoinPlan, n_devices: int) -> DistributedJoinSpec:
    """Capacities from the composite plan (cost model, Thm 7)."""
    return _shuffle_spec(plan.index, plan.query, n_devices)


def _pack_send_buffers(rows, aux, dest, src_of_row, n_src, n_dst, cap):
    """Host-side packing: (n_src, n_dst, cap) buffers + validity.

    ``dest`` may contain a row multiple times (S replication); callers
    pre-expand. aux is a dict of per-row int/float arrays packed alongside.

    Vectorized: a stable lexsort groups rows by (src, dst), the rank of
    each row inside its bucket is its slot, and one fancy-indexed scatter
    lands everything — no per-row Python. Input order within a bucket is
    preserved (callers pre-sort rows by (partition, pivot distance) so the
    receiver's tiles are partition-coherent).
    """
    n = rows.shape[0]
    nbuf = {k: np.zeros((n_src, n_dst, cap) + v.shape[1:], v.dtype)
            for k, v in aux.items()}
    buf = np.zeros((n_src, n_dst, cap, rows.shape[1]), rows.dtype)
    valid = np.zeros((n_src, n_dst, cap), bool)
    if n == 0:
        return buf, nbuf, valid
    key = src_of_row.astype(np.int64) * n_dst + dest
    order = np.argsort(key, kind="stable")
    sk = key[order]
    # rank within each equal-key bucket: position − bucket start
    starts = np.flatnonzero(np.r_[True, sk[1:] != sk[:-1]])
    slot = np.arange(n) - np.repeat(starts, np.diff(np.r_[starts, n]))
    if slot.max(initial=0) >= cap:
        raise AssertionError("capacity model violated — bug in Thm 7 path")
    flat = sk * cap + slot                   # bucket-major landing position
    buf.reshape(-1, rows.shape[1])[flat] = rows[order]
    for k, v in aux.items():
        nbuf[k].reshape((-1,) + v.shape[1:])[flat] = v[order]
    valid.reshape(-1)[flat] = True
    return buf, nbuf, valid


def _reducer_join(r_buf, r_valid, s_buf, s_valid, s_ids, k, tile_s,
                  axis_names=(), tile_r=128):
    """Per-device dense join: exact top-k of valid R rows over valid S.

    The running top-k is a sorted run merged with each tile's sorted
    candidates (kernels.sorted_merge) — the same primitive the Pallas
    kernels use. Every received S tile is visited: Theorem 6 already
    pruned at shuffle time, and the tile-granular Cor. 1 / Thm 2 pruning
    lives in the sharded megastep (core.sharded), which subsumed the
    host-planned scheduled reducer that used to sit here.
    """
    nq = r_buf.shape[0]
    ns = s_buf.shape[0]
    kp = next_pow2(k)

    n_tiles = -(-ns // tile_s)
    s_pad = jnp.pad(s_buf, ((0, n_tiles * tile_s - ns), (0, 0)))
    sv_pad = jnp.pad(s_valid, (0, n_tiles * tile_s - ns))
    si_pad = jnp.pad(s_ids, (0, n_tiles * tile_s - ns), constant_values=-1)

    nr_tiles = -(-nq // tile_r)
    r_pad = jnp.pad(r_buf, ((0, nr_tiles * tile_r - nq), (0, 0)))

    init_d = jnp.full((tile_r, kp), jnp.inf, jnp.float32)
    init_i = jnp.full((tile_r, kp), -1, jnp.int32)
    if axis_names:
        # inside shard_map the scan carry must match the tiles' varying
        # manual axes; fresh constants start unvarying
        init_d = pvary(init_d, axis_names)
        init_i = pvary(init_i, axis_names)

    def one_r_tile(_, rt):
        r2 = jnp.sum(rt * rt, axis=-1)

        def visit(carry, t_idx):
            bd, bi = carry
            st = jax.lax.dynamic_slice_in_dim(s_pad, t_idx * tile_s, tile_s)
            sv = jax.lax.dynamic_slice_in_dim(sv_pad, t_idx * tile_s, tile_s)
            si = jax.lax.dynamic_slice_in_dim(si_pad, t_idx * tile_s, tile_s)
            d2 = (r2[:, None] + jnp.sum(st * st, axis=-1)[None, :]
                  - 2.0 * (rt @ st.T))
            d2 = jnp.where(sv[None, :], jnp.maximum(d2, 0.0), jnp.inf)
            td, ti = tile_topk(d2, jnp.broadcast_to(si[None, :], d2.shape),
                               kp)
            return merge_sorted_runs(bd, bi, td, ti), None

        (bd, bi), _ = jax.lax.scan(visit, (init_d, init_i),
                                   jnp.arange(n_tiles, dtype=jnp.int32))
        return None, (bd, bi)

    _, (best_d, best_i) = jax.lax.scan(
        one_r_tile, None, r_pad.reshape(nr_tiles, tile_r, -1))
    best_d = best_d.reshape(nr_tiles * tile_r, kp)[:nq, :k]
    best_i = best_i.reshape(nr_tiles * tile_r, kp)[:nq, :k]
    best_d = jnp.where(r_valid[:, None], jnp.sqrt(best_d), jnp.inf)
    best_i = jnp.where(r_valid[:, None], best_i, -1)
    return best_d, best_i


class DistributedJoinEngine:
    """Resident-index SPMD runtime: build-once S side, per-batch R side.

    The index's S rows are already packed in pivot-sorted order, so the
    per-batch S work is only the Theorem-6 destination selection + a
    vectorized scatter into send buffers — no per-batch sort, no re-run
    of S-side phase 1. The packed S send buffers are cached and reused
    verbatim whenever consecutive batches produce the same ``lb_group``
    (e.g. a re-used query plan, or repeated identically-planned
    micro-batches); R rows are re-shuffled on every batch.
    """

    def __init__(
        self,
        index: SIndex,
        mesh: Mesh,
        *,
        axis: str | Tuple[str, ...] = "data",
        tile_s: int = 512,
        tile_r: int = 128,
    ):
        self.index = index
        self.mesh = mesh
        self.axes = (axis,) if isinstance(axis, str) else tuple(axis)
        self.n_dev = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.tile_s = tile_s
        self.tile_r = tile_r
        # home device of each packed S row (by original row id, the shard
        # the row lived on before any query arrived) — static forever
        self._src_s_sorted = ((index.s_order.astype(np.int64) * self.n_dev)
                              // max(index.n_s, 1))
        self._s_cache_key: object = None
        self._s_cache: object = None
        self._job2_cache: dict = {}

    def _s_side(self, qplan: QueryPlan):
        """S capacity + send buffers for one plan, cached on ``lb_group``
        (the only query-dependent input). On a cache hit the batch pays
        zero S-side work — no Theorem-6 mask, no scatter. The mask is
        evaluated once, over the sorted layout, and shared between the
        capacity count (Thm 7) and the packing."""
        key = qplan.lb_group.tobytes()
        if self._s_cache_key == key:
            return self._s_cache
        idx = self.index
        n_dev = self.n_dev
        mask = (idx.s_dist_sorted[:, None]
                >= qplan.lb_group[idx.s_part_sorted])        # (n_s, G)
        row, dst = np.nonzero(mask)   # rows already in (part, dist) order
        src = self._src_s_sorted[row]
        cnt = np.zeros((n_dev, qplan.n_groups), np.int64)
        np.add.at(cnt, (src, dst), 1)
        cap_s = max(1, int(cnt.max()))
        s_buf, s_aux, s_valid = _pack_send_buffers(
            idx.s_sorted[row],
            {"id": idx.s_ids_sorted[row].astype(np.int32),
             "part": idx.s_part_sorted[row].astype(np.int32),
             "pdist": idx.s_dist_sorted[row].astype(np.float32)},
            dst, src, n_dev, n_dev, cap_s)
        self._s_cache_key = key
        self._s_cache = (s_buf, s_aux, s_valid, row.shape[0], cap_s)
        return self._s_cache

    def _job2(self, k: int):
        """The jitted SPMD shuffle+reduce program, built once per engine
        (cached on k — everything else it closes over is engine-static).
        A fresh closure per batch would defeat jax.jit's identity-keyed
        cache and recompile every micro-batch."""
        if k in self._job2_cache:
            return self._job2_cache[k]
        axes, tile_r, tile_s = self.axes, self.tile_r, self.tile_s
        pspec = P(axes if len(axes) > 1 else axes[0])

        @partial(shard_map, mesh=self.mesh,
                 in_specs=(pspec,) * 6,
                 out_specs=(pspec, pspec, pspec, pspec))
        def job2(r_buf, r_valid, r_id, s_buf, s_valid, s_id):
            # collapse the leading sharded axis (size 1 per device)
            r_buf, r_valid, r_id = r_buf[0], r_valid[0], r_id[0]
            s_buf, s_valid, s_id = s_buf[0], s_valid[0], s_id[0]
            # ---- the shuffle: one all_to_all per payload
            a2a = partial(jax.lax.all_to_all,
                          axis_name=axes if len(axes) > 1 else axes[0],
                          split_axis=0, concat_axis=0, tiled=True)
            r_buf, r_valid, r_id = a2a(r_buf), a2a(r_valid), a2a(r_id)
            s_buf, s_valid, s_id = a2a(s_buf), a2a(s_valid), a2a(s_id)
            # ---- the reducer: flatten received buffers, dense join
            rb = r_buf.reshape(-1, r_buf.shape[-1])
            rv = r_valid.reshape(-1)
            ri = r_id.reshape(-1)
            sb = s_buf.reshape(-1, s_buf.shape[-1])
            sv = s_valid.reshape(-1)
            si = s_id.reshape(-1)
            bd, bi = _reducer_join(rb, rv, sb, sv, si, k, tile_s,
                                   axis_names=axes, tile_r=tile_r)
            return (bd[None], bi[None], ri[None], rv[None])

        self._job2_cache[k] = jax.jit(job2)
        return self._job2_cache[k]

    def join_batch(
        self, r: np.ndarray, qplan: QueryPlan,
    ) -> JoinResult:
        """Execute job 2 for one R batch as SPMD over the mesh (one group
        per device along ``axis``).

        The shuffle is a genuine ``jax.lax.all_to_all`` on (n_dev, n_dev,
        cap) send buffers; the reducers never see rows the Theorem-6
        bounds did not ship. (Tile-granular pruning beyond that lives in
        the sharded megastep — ``distributed_knn_join`` routes L2 joins
        there by default.)
        """
        index, n_dev = self.index, self.n_dev
        tile_r, tile_s = self.tile_r, self.tile_s
        axes = self.axes
        if qplan.n_groups != n_dev:
            raise ValueError(f"plan has {qplan.n_groups} groups but mesh "
                             f"axis size is {n_dev}")
        k = qplan.config.k
        r = np.ascontiguousarray(r, np.float32)
        n_r, n_s = r.shape[0], index.n_s

        # ---- host-side packing (the mapper emit; becomes device-side
        # sort/scatter on a real pod — see DESIGN.md §2.1 ragged-shuffle
        # note). Rows are pre-sorted by (partition, pivot distance):
        # bucket packing is order-preserving, so every received run is
        # partition-coherent. The S side comes pre-sorted from the
        # index packing.
        g_r = qplan.group_of_r()
        src_r = (np.arange(n_r) * n_dev) // max(n_r, 1)
        cap_r = max(1, _route_counts(g_r, n_dev, qplan.n_groups, src_r))
        # int32 on device: x64 is disabled by default; |R|,|S| < 2^31 here
        r_ids = np.arange(n_r, dtype=np.int32)
        ord_r = np.lexsort((qplan.r_dist, qplan.r_part))
        r_buf, r_aux, r_valid = _pack_send_buffers(
            r[ord_r],
            {"id": r_ids[ord_r], "part": qplan.r_part[ord_r].astype(np.int32)},
            g_r[ord_r], src_r[ord_r], n_dev, n_dev, cap_r)

        s_buf, s_aux, s_valid, n_replicas, cap_s = self._s_side(qplan)

        stats = JoinStats(n_r=n_r, n_s=n_s)
        stats.n_batches = 1
        stats.replicas_s = n_replicas
        # per-batch cost only; the resident index's S-side phase 1 was
        # paid once at build (the one-shot wrapper re-adds it)
        stats.pivot_pairs_computed = n_r * index.n_pivots

        nq_dev = n_dev * cap_r
        ns_dev = n_dev * cap_s
        nr_tiles = -(-nq_dev // tile_r)
        ns_tiles = -(-ns_dev // tile_s)
        stats.tiles_total = stats.tiles_visited = (
            n_dev * nr_tiles * ns_tiles)
        stats.pairs_computed = int(
            (r_valid.sum(axis=(0, 2))[None, :]
             * s_valid.sum(axis=(0, 2))[:, None]).trace())

        pspec = P(axes if len(axes) > 1 else axes[0])

        with self.mesh:
            sh = NamedSharding(self.mesh, pspec)
            args = [r_buf, r_valid, r_aux["id"], s_buf, s_valid, s_aux["id"]]
            args = [jax.device_put(x, sh) for x in args]
            bd, bi, ri, rv = self._job2(k)(*args)

        bd, bi, ri, rv = map(np.asarray, (bd, bi, ri, rv))
        out_d = np.full((n_r, k), np.inf, np.float32)
        out_i = np.full((n_r, k), -1, np.int64)
        flat_v = rv.reshape(-1)
        flat_r = ri.reshape(-1)[flat_v]
        out_d[flat_r] = bd.reshape(-1, k)[flat_v]
        out_i[flat_r] = bi.reshape(-1, k)[flat_v]
        # report in the shape-canonical distance form (matches the host
        # engines bitwise when the selected neighbor sets agree)
        out_d, out_i = canonical_topk(
            r, out_i, index.rows_for_ids(out_i), qplan.config.metric)
        return JoinResult(indices=out_i, distances=out_d, stats=stats)


def distributed_knn_join(
    r: np.ndarray,
    s: np.ndarray,
    plan: JoinPlan,
    mesh: Mesh,
    *,
    axis: str | Tuple[str, ...] = "data",
    tile_s: int = 512,
    tile_r: int = 128,
    reducer: str = "auto",
) -> JoinResult:
    """One-shot multi-device join from a composite plan (callers that
    stream batches should hold an engine and call its per-batch entry
    point instead). ``s`` must be the dataset the plan's index was built
    from (its rows are served from the index's packed copy).

    ``reducer`` picks the SPMD execution:

    * ``"sharded"`` — the sharded megastep (``core.sharded``): the
      plan's index payload is partitioned across the mesh devices once
      (pivot groups → shards via the §5 geometric grouping), θ stays
      global, every shard runs its own compacted Cor. 1 / Thm 2
      schedule, and only the final k-runs are all-gathered. This
      subsumed the old host-planned per-device scheduled reducer; its
      output is bitwise the single-device megastep's. L2 only.
    * ``"shuffle"`` — the explicit MapReduce mapping kept in this
      module: Theorem-6-routed ``all_to_all`` shuffle + dense
      per-device scan reduce (any metric; groups must equal the mesh
      extent along ``axis``).
    * ``"auto"`` (default) — ``"sharded"`` for L2, else ``"shuffle"``.
    """
    if s is not None and s.shape[0] != plan.index.n_s:
        raise ValueError(f"s has {s.shape[0]} rows but the plan's index "
                         f"holds {plan.index.n_s}")
    if reducer == "auto":
        reducer = ("sharded" if plan.query.config.metric == "l2"
                   else "shuffle")
    if reducer == "sharded":
        from .sharded import ShardedMegastepEngine
        if plan.query.config.metric != "l2":
            raise ValueError(
                "reducer='sharded' supports metric='l2' only; use "
                "reducer='shuffle' for other metrics")
        # the sharded megastep wants a 1-D "shard" mesh; flatten whatever
        # device grid the caller handed us (the shard count need not
        # match the plan's group count — exactness is shard-invariant)
        devs = np.asarray(mesh.devices).reshape(-1)
        smesh = Mesh(devs, ("shard",))
        cfg = dataclasses.replace(plan.query.config,
                                  tile_s=tile_s, tile_r=tile_r)
        engine = ShardedMegastepEngine(plan.index, cfg,
                                       n_shards=int(devs.size), mesh=smesh)
        stats = JoinStats(n_r=r.shape[0], n_s=plan.index.n_s)
        d, ids = engine.join_batch(np.ascontiguousarray(r, np.float32),
                                   stats=stats)
        stats.n_batches = 1
        # shards partition S disjointly — every row is resident exactly
        # once, nothing reshuffles per batch
        stats.replicas_s = plan.index.n_s
        stats.pivot_pairs_computed = (
            r.shape[0] * plan.index.n_pivots
            + plan.index.n_s * plan.index.n_pivots)
        return JoinResult(indices=ids, distances=d, stats=stats)
    if reducer != "shuffle":
        raise ValueError(f"unknown reducer {reducer!r}")
    engine = DistributedJoinEngine(
        plan.index, mesh, axis=axis, tile_s=tile_s, tile_r=tile_r)
    res = engine.join_batch(r, plan.query)
    # one-shot semantics: this call's plan paid S-side phase 1 too
    res.stats.pivot_pairs_computed += plan.index.n_s * plan.index.n_pivots
    return res


# --------------------------------------------------------------- phase 1
def distributed_phase1(
    data: np.ndarray,
    pivots: np.ndarray,
    mesh: Mesh,
    *,
    k: int | None = None,
    axis: str = "data",
):
    """SPMD job-1: every device assigns its shard and computes partial
    summary tables; ``psum/pmin/pmax`` merge them (the paper's map-side
    stats + merge-on-completion, DESIGN.md §2 table).

    Returns (part_ids (n,), dists (n,), SummaryTable) — bit-identical to
    the host `assign_and_summarize` (the merge operators are exact).
    """
    from .types import SummaryTable

    n = data.shape[0]
    n_dev = mesh.shape[axis]
    m = pivots.shape[0]
    pad = (-n) % n_dev
    padded = np.pad(np.asarray(data, np.float32), ((0, pad), (0, 0)))
    kk = 0 if k is None else k

    @partial(shard_map, mesh=mesh,
             in_specs=(P(axis), P()),
             out_specs=(P(axis), P(axis), P(), P(), P(), P()),
             check_vma=False)  # all_gather+sort output is replicated in
                               # value; the static VMA check can't see it
    def phase1(x, piv):
        d2 = (jnp.sum(x * x, 1)[:, None] + jnp.sum(piv * piv, 1)[None, :]
              - 2.0 * (x @ piv.T))
        d2 = jnp.maximum(d2, 0.0)
        pid = jnp.argmin(d2, axis=1).astype(jnp.int32)
        dist = jnp.sqrt(jnp.take_along_axis(d2, pid[:, None], 1))[:, 0]
        # padding rows: assign to partition 0 at +inf so they never alter
        # mins/maxes or the top-k lists
        row = jax.lax.axis_index(axis) * x.shape[0] + jnp.arange(x.shape[0])
        valid = row < n
        dist = jnp.where(valid, dist, jnp.inf)
        pid = jnp.where(valid, pid, 0)
        counts = jnp.zeros((m,), jnp.int32).at[pid].add(
            valid.astype(jnp.int32))
        lower = jnp.full((m,), jnp.inf, jnp.float32).at[pid].min(dist)
        upper = jnp.zeros((m,), jnp.float32).at[pid].max(
            jnp.where(valid, dist, 0.0))
        counts = jax.lax.psum(counts, axis)
        lower = jax.lax.pmin(lower, axis)
        upper = jax.lax.pmax(upper, axis)
        if kk:
            # local k smallest per partition, then gather + global k smallest
            order = jnp.lexsort((dist, pid))
            sp, sd = pid[order], dist[order]
            idx = jnp.arange(sp.shape[0])
            seg = jnp.full((m,), sp.shape[0], jnp.int32).at[sp].min(
                idx.astype(jnp.int32))
            rank = idx - seg[sp]
            keep = rank < kk
            local = jnp.full((m, kk), jnp.inf, jnp.float32)
            local = local.at[jnp.where(keep, sp, m - 1),
                             jnp.where(keep, rank, kk - 1)].min(
                                 jnp.where(keep, sd, jnp.inf))
            gathered = jax.lax.all_gather(local, axis, axis=1)  # (m, ndev, k)
            knn = jax.lax.sort(gathered.reshape(m, -1), dimension=1)[:, :kk]
        else:
            knn = jnp.zeros((m, 1), jnp.float32)
        return (pid, jnp.where(valid, dist, 0.0), counts, lower, upper, knn)

    with mesh:
        pid, dist, counts, lower, upper, knn = phase1(
            jnp.asarray(padded), jnp.asarray(pivots, jnp.float32))
    table = SummaryTable(
        counts=np.asarray(counts), lower=np.asarray(lower),
        upper=np.asarray(upper),
        knn_dists=np.asarray(knn) if kk else None)
    return (np.asarray(pid)[:n], np.asarray(dist)[:n], table)
