"""Baselines the paper compares against (§3, §6): brute force oracle,
H-BRJ [Zhang et al., EDBT'12] and PBJ (PGBJ bounds without grouping).

H-BRJ on TPU: the original uses per-reducer R-trees; tree traversal is
pointer-chasing and has no sensible TPU mapping (DESIGN.md §7), so each
(R_i, S_j) block join is a blocked brute-force top-k — the same reducer
compute its shuffle pattern implies. Shuffle accounting follows §3:
√N·(|R|+|S|) for job 1 plus k·|R|·√N partial results for the merge job.
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from .join import join_group_dense, topk_merge
from .metrics import canonical_topk
from .partition import assign_and_summarize
from .pivots import select_pivots
from .types import JoinConfig, JoinResult, JoinStats
from . import bounds as B

__all__ = ["brute_force_knn", "hbrj_join", "pbj_join"]


def brute_force_knn(
    r: np.ndarray, s: np.ndarray, k: int, *, tile_r: int = 256,
    tile_s: int = 2048, metric: str = "l2",
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact oracle: (dists, ids), ascending. O(|R||S|).

    Selection runs in float64 (an oracle must out-resolve the engines'
    float32 noise — on data far from the origin real kNN gaps can sit
    below f32 cancellation error); reported distances then go through
    the same shape-canonical float32 form (`metrics.canonical_topk`) the
    engines emit, so oracle and engine outputs are directly comparable.
    """
    r = np.asarray(r, np.float32)
    s = np.asarray(s, np.float32)
    r64 = r.astype(np.float64)
    s64 = s.astype(np.float64)
    out_i = np.empty((r.shape[0], k), np.int64)
    if metric == "l2":
        s2 = (s64 * s64).sum(-1)
    for lo in range(0, r.shape[0], tile_r):
        hi = min(lo + tile_r, r.shape[0])
        if metric == "l2":
            q = r64[lo:hi]
            d = (q * q).sum(-1)[:, None] + s2[None, :] - 2.0 * (q @ s64.T)
        else:
            diff = np.abs(r64[lo:hi, None, :] - s64[None, :, :])
            d = diff.sum(-1) if metric == "l1" else diff.max(-1)
        part = np.argpartition(d, k - 1, axis=1)[:, :k]
        dk = np.take_along_axis(d, part, axis=1)
        order = np.argsort(dk, axis=1, kind="stable")
        out_i[lo:hi] = np.take_along_axis(part, order, axis=1)
    out_d, out_i = canonical_topk(
        r, out_i, s[np.clip(out_i, 0, s.shape[0] - 1)], metric)
    return out_d, out_i


def hbrj_join(
    r: np.ndarray, s: np.ndarray, k: int, *, n_reducers: int = 16, seed: int = 0
) -> JoinResult:
    """H-BRJ: random √N × √N block join + merge job."""
    r = np.asarray(r, np.float32); s = np.asarray(s, np.float32)
    root = max(1, int(math.isqrt(n_reducers)))
    rng = np.random.default_rng(seed)
    r_blk = rng.integers(0, root, r.shape[0])
    s_blk = rng.integers(0, root, s.shape[0])
    stats = JoinStats(n_r=r.shape[0], n_s=s.shape[0])
    # job-1 shuffle: each R_i goes to √N reducers, each S_j to √N reducers
    stats.replicas_s = root * s.shape[0] + (root - 1) * r.shape[0]
    out_d = np.full((r.shape[0], k), np.inf, np.float32)
    out_i = np.full((r.shape[0], k), -1, np.int64)
    s_ids = np.arange(s.shape[0], dtype=np.int64)
    for i in range(root):
        r_sel = np.where(r_blk == i)[0]
        if r_sel.size == 0:
            continue
        bd = np.full((r_sel.size, k), np.inf, np.float32)
        bi = np.full((r_sel.size, k), -1, np.int64)
        for j in range(root):
            s_sel = np.where(s_blk == j)[0]
            if s_sel.size == 0:
                continue
            kk = min(k, s_sel.size)
            gd, gi = join_group_dense(
                r[r_sel], s[s_sel], s_ids[s_sel], kk, stats=stats)
            # merge job (the 2nd MapReduce): combine partial top-k
            bd, bi = topk_merge(bd, bi, gd.astype(np.float32) ** 2, gi, k)
        out_d[r_sel] = np.sqrt(bd)
        out_i[r_sel] = bi
    out_d, out_i = canonical_topk(
        r, out_i, s[np.clip(out_i, 0, s.shape[0] - 1)])
    return JoinResult(indices=out_i, distances=out_d, stats=stats)


def pbj_join(
    r: np.ndarray, s: np.ndarray, k: int,
    config: JoinConfig | None = None, *, n_reducers: int = 16,
) -> JoinResult:
    """PBJ: PGBJ's pivots/bounds, H-BRJ's ungrouped √N×√N framework.

    R is randomly split into √N subsets and S into √N subsets; a reducer
    joins (R_i, S_j) using a θ bound derived from the objects it received
    (paper §6: "without grouping ... randomness results in a loose distance
    bound"), then a merge job combines partials.
    """
    config = config or JoinConfig(k=k)
    r = np.asarray(r, np.float32); s = np.asarray(s, np.float32)
    root = max(1, int(math.isqrt(n_reducers)))
    rng = np.random.default_rng(config.seed)
    m = min(config.n_pivots, r.shape[0])
    pivots = select_pivots(r, m, config.pivot_strategy,
                           sample=config.pivot_sample, seed=config.seed)
    r_part, r_dist, t_r = assign_and_summarize(r, pivots)
    s_part, s_dist, t_s = assign_and_summarize(s, pivots, k=k)
    pivd = B.pivot_distance_matrix(pivots)

    stats = JoinStats(n_r=r.shape[0], n_s=s.shape[0])
    stats.pivot_pairs_computed += (r.shape[0] + s.shape[0]) * m
    stats.replicas_s = root * s.shape[0] + (root - 1) * r.shape[0]

    r_blk = rng.integers(0, root, r.shape[0])
    s_blk = rng.integers(0, root, s.shape[0])
    s_ids = np.arange(s.shape[0], dtype=np.int64)
    out_d = np.full((r.shape[0], k), np.inf, np.float32)
    out_i = np.full((r.shape[0], k), -1, np.int64)
    from .join import join_group_pruned  # local to avoid cycle at import
    for i in range(root):
        r_sel = np.where(r_blk == i)[0]
        if r_sel.size == 0:
            continue
        bd = np.full((r_sel.size, k), np.inf, np.float32)
        bi = np.full((r_sel.size, k), -1, np.int64)
        for j in range(root):
            s_sel = np.where(s_blk == j)[0]
            if s_sel.size == 0:
                continue
            kk = min(k, s_sel.size)
            # per-reducer θ from the received S_j subset only (loose, as
            # the paper observes): k-th smallest ub over T_S restricted to
            # the subset is not available, so bound from subset stats.
            sub_t_s = _subset_table(s_part[s_sel], s_dist[s_sel], m, kk)
            theta = B.compute_theta(pivd, t_r, sub_t_s, kk)
            gd, gi = join_group_pruned(
                r[r_sel], r_part[r_sel],
                s[s_sel], s_part[s_sel], s_dist[s_sel], s_ids[s_sel],
                pivots, pivd, theta, sub_t_s.lower, sub_t_s.upper, kk,
                tile_r=config.tile_r, tile_s=config.tile_s, stats=stats)
            bd, bi = topk_merge(bd, bi, gd.astype(np.float32) ** 2, gi, k)
        out_d[r_sel] = np.sqrt(bd)
        out_i[r_sel] = bi
    out_d, out_i = canonical_topk(
        r, out_i, s[np.clip(out_i, 0, s.shape[0] - 1)])
    return JoinResult(indices=out_i, distances=out_d, stats=stats)


def _subset_table(part: np.ndarray, dist: np.ndarray, m: int, k: int):
    from .partition import build_summary
    return build_summary(part, dist, m, k=k)
