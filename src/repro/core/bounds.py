"""Distance bounds of PGBJ (paper §4.3, Theorems 1-6, Algorithms 1-2).

Everything here is a function of the summary tables and the pivot-pivot
distance matrix only — O(M^2 + M·k) work, independent of |R|, |S|. This is
the paper's point: the bounds let the second job ship and prune data
without ever joining.

Vectorization note: Algorithm 1 (boundingKNN) walks each sorted T_S row
with a priority queue and early exit. The vectorized form below computes
the identical θ_i = k-th smallest of {|p_i,p_j| + p_j.d_l} + U(P_i^R)
without the queue; early exit is a sequential-machine optimization with no
TPU analogue (and no effect on the result).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .types import SummaryTable

__all__ = [
    "pivot_distance_matrix",
    "compute_theta",
    "theta_and_lb",
    "replication_lower_bounds",
    "group_lower_bounds",
    "hyperplane_distances",
    "ring_bounds",
    "pad_theta",
]


def pad_theta(th):
    """θ with a few-ulp safety margin, for *pruning comparisons only*.

    The quantities compared against θ (per-batch |q, p| distances, ring
    bounds, hyperplane distances) come out of different float32 graphs
    than θ itself — a centered per-batch gemm on one side, the planner's
    jitted θ reduction on the other. In real arithmetic the paper's
    prune rules are exact at equality, but when true neighbors sit at
    distance *exactly* θ (e.g. ≥ k rows duplicated at a pivot make the
    Thm-3 bound tight), a one-ulp discrepancy between two computations
    of the same real quantity can prune a true neighbor. Comparing
    against a θ padded by ~30 ulp relative + a tiny absolute term keeps
    every prune sound — a looser θ only widens the candidate superset —
    at negligible pruning-power cost. Works on numpy and jnp arrays;
    ±inf are fixed points. Regression: tests/test_quant.py's
    duplicate-row cases, which fail without the pad on singleton
    batches.
    """
    return th * np.float32(1.000004) + np.float32(1e-6)


def pivot_distance_matrix(pivots: np.ndarray, metric: str = "l2"
                          ) -> np.ndarray:
    """(M, M) true pivot-pivot distances |p_i, p_j|."""
    if metric != "l2":
        from .metrics import pairwise_dist
        out = pairwise_dist(pivots, pivots, metric)
        np.fill_diagonal(out, 0.0)
        return out
    p = np.asarray(pivots, np.float64)
    sq = (p * p).sum(-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (p @ p.T)
    np.maximum(d2, 0.0, out=d2)
    out = np.sqrt(d2, dtype=np.float64)
    np.fill_diagonal(out, 0.0)
    return out.astype(np.float32)


def compute_theta(
    pivd: np.ndarray,
    t_r: SummaryTable,
    t_s: SummaryTable,
    k: int,
    *,
    block: int = 512,
) -> np.ndarray:
    """θ_i for every R-partition (Eq. 6 / Algorithm 1).

    θ_i = k-th smallest ub(s, P_i^R) over the per-partition pivot-kNN lists
    of T_S, where ub(s, P_i^R) = U(P_i^R) + |p_i, p_j| + |p_j, s| (Thm 3).
    Empty R-partitions get θ_i = -inf (nothing to bound, nothing shipped).

    Exactness caveat (inherited from the paper): T_S keeps only the k
    nearest objects per S-partition, so θ uses at most k candidates per
    partition — precisely the set the paper proves sufficient (text under
    Eq. 6: only the k closest objects of each P_j^S can contribute).
    """
    m_r = t_r.n_partitions
    assert t_s.knn_dists is not None, "T_S must carry pivot-kNN distances"
    knn = t_s.knn_dists[:, :k]                      # (M_s, k), +inf padded
    u_r = t_r.upper                                  # (M_r,)
    theta = np.full((m_r,), -np.inf, np.float32)
    occupied = t_r.counts > 0
    # total candidates must be at least k for a valid bound
    if np.isfinite(knn).sum() < k:
        raise ValueError(
            f"T_S holds {int(np.isfinite(knn).sum())} finite candidates; "
            f"need >= k={k} (is |S| >= k?)")
    for lo in range(0, m_r, block):
        hi = min(lo + block, m_r)
        rows = np.arange(lo, hi)
        # ub without the U term: (rows, M_s, k)
        ub = pivd[rows][:, :, None] + knn[None, :, :]
        flat = ub.reshape(hi - lo, -1)
        kth = np.partition(flat, k - 1, axis=1)[:, k - 1]
        theta[rows] = np.where(occupied[rows], kth + u_r[rows], -np.inf)
    return theta.astype(np.float32)


@partial(jax.jit, static_argnames=("k",))
def _theta_and_lb_jit(pivd, knn, u_r, occupied, *, k: int):
    """Jitted fused θ (Eq. 6 / Alg. 1) + LB matrix (Cor. 2).

    Selection (k-th smallest) and the additions mirror the host
    `compute_theta`/`replication_lower_bounds` bit-for-bit: identical
    float32 operands combined in the same order, with `top_k` replacing
    `np.partition` (both exact selections of existing values).
    """
    ub = pivd[:, :, None] + knn[None, :, :]           # (M_r, M_s, <=k)
    flat = ub.reshape(pivd.shape[0], -1)
    kth = -jax.lax.top_k(-flat, k)[0][:, -1]          # k-th smallest
    theta = jnp.where(occupied, kth + u_r, -jnp.inf)
    # LB is derived from the ulp-padded θ (pad_theta): the shipping test
    # |s, p_j| >= LB compares the phase-1 assign graph against this one,
    # and a neighbor at exactly LB must ship — a slightly smaller LB
    # only widens the replica superset
    lb = pivd.T - u_r[None, :] - pad_theta(theta)[None, :]
    lb = jnp.where(jnp.isfinite(theta)[None, :], lb, jnp.inf)
    return theta, jnp.maximum(lb, 0.0)


def theta_and_lb(
    pivd: np.ndarray, t_r: SummaryTable, t_s: SummaryTable, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Per-batch bound math on the jitted jnp path: returns (θ (M_r,),
    LB (M_s, M_r)) — `compute_theta` + `replication_lower_bounds` fused
    into one device computation (the per-batch planner's hot loop).
    Callers must ensure T_S holds >= k finite candidates in total."""
    assert t_s.knn_dists is not None, "T_S must carry pivot-kNN distances"
    knn = t_s.knn_dists.astype(np.float32)
    theta, lb = _theta_and_lb_jit(
        jnp.asarray(pivd), jnp.asarray(knn[:, :k]),
        jnp.asarray(t_r.upper), jnp.asarray(t_r.counts > 0), k=k)
    return (np.asarray(theta, np.float32), np.asarray(lb, np.float32))


def replication_lower_bounds(
    pivd: np.ndarray, t_r: SummaryTable, theta: np.ndarray
) -> np.ndarray:
    """LB(P_j^S, P_i^R) matrix of Corollary 2 / Algorithm 2, shape (M_s, M_r).

    s ∈ P_j^S must be shipped to partition i iff |s, p_j| >= LB[j, i].
    Empty R-partitions get LB = +inf (never ship). Derived from the
    ulp-padded θ (`pad_theta`, mirroring `_theta_and_lb_jit`): a
    neighbor sitting at exactly LB must survive the fp discrepancy
    between the assign graph's |s, p_j| and this bound.
    """
    lb = pivd.T - t_r.upper[None, :] - pad_theta(theta)[None, :]
    lb = np.where(np.isfinite(theta)[None, :], lb, np.inf)
    return np.maximum(lb, 0.0).astype(np.float32)


def group_lower_bounds(lb: np.ndarray, groups: np.ndarray, n_groups: int) -> np.ndarray:
    """LB(P_j^S, G_g) = min_{i ∈ G_g} LB(P_j^S, P_i^R)  (Theorem 6).

    Parameters
    ----------
    lb:      (M_s, M_r) from `replication_lower_bounds`
    groups:  (M_r,) int — group id of each R-partition
    Returns (M_s, n_groups).
    """
    out = np.full((lb.shape[0], n_groups), np.inf, np.float32)
    np.minimum.at(out.T, groups, lb.T)  # scatter-min over partitions
    return out


def hyperplane_distances(
    query_to_pivots: np.ndarray, pivd: np.ndarray, home: np.ndarray
) -> np.ndarray:
    """d(q, HP(p_home, p_j)) for each query and every other pivot (Thm 1).

    d = (|q,p_j|^2 - |q,p_home|^2) / (2 |p_home, p_j|);  Corollary 1: if
    d > θ the whole partition P_j can be skipped for q.

    Parameters
    ----------
    query_to_pivots: (n, M) true distances from each query to every pivot
    pivd:            (M, M) pivot-pivot distances
    home:            (n,) int — home partition of each query
    Returns (n, M); the home column is +inf (never prune own partition).
    """
    q2 = query_to_pivots.astype(np.float64) ** 2
    home_sq = np.take_along_axis(q2, home[:, None], axis=1)        # (n,1)
    denom = 2.0 * pivd[home]                                       # (n, M)
    with np.errstate(divide="ignore", invalid="ignore"):
        d = (q2 - home_sq) / denom
    n = np.arange(home.shape[0])
    d[n, home] = np.inf
    return d.astype(np.float32)


def ring_bounds(
    dist_to_pivot: np.ndarray,
    theta: np.ndarray,
    t_s: SummaryTable,
    s_part: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Theorem 2 interval per (query, S-partition) pair.

    Candidates s ∈ P_j^S can matter for query q only if
      max{L(P_j^S), |p_j,q| - θ} <= |p_j, s| <= min{U(P_j^S), |p_j,q| + θ}.

    Parameters
    ----------
    dist_to_pivot: (n, M_s) |q, p_j|
    theta:         (n,) per-query kNN radius bound
    s_part:        partitions under consideration (column index space)
    Returns (lo, hi) arrays of shape (n, len(s_part)).
    """
    lo = np.maximum(t_s.lower[s_part][None, :],
                    dist_to_pivot[:, s_part] - theta[:, None])
    hi = np.minimum(t_s.upper[s_part][None, :],
                    dist_to_pivot[:, s_part] + theta[:, None])
    return lo.astype(np.float32), hi.astype(np.float32)
