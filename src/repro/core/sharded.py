"""Sharded megastep: one logical datastore across a JAX device mesh.

The fused megastep (`core.megastep`) applies the paper's Cor. 1 / Thm 2
mapper-side filtering on exactly one device, so the resident payload —
even ~3.7× smaller via int8 — caps the datastore at one HBM. This module
re-expresses the paper's shuffle as **mesh partitioning**: pivot groups
are assigned to shards by the §5 geometric grouping
(`SIndex.shard_packing`), each shard holds only its groups' packed rows
(+ int8 twins + ε bounds) and their Thm-2 tile stats, and the whole
assign → θ → schedule → gather-top-k → exact-re-rank body runs SPMD
inside ``shard_map`` (via `core.jax_compat`):

* **θ is global, schedules are per shard.** Every shard carries the
  replicated pivot geometry and T_S pivot-kNN lists of *all* segments,
  so `megastep._assign_bounds_schedule` computes the identical union-θ
  on every shard (Thm 3 over the union candidate set — bitwise the
  single-device value). Its visit masks, though, are evaluated against
  the shard's own tile stats: partitions a shard doesn't own are never
  ``present``, so the compacted schedule visits only local tiles — the
  paper's per-reducer pruning, reborn per shard.
* **Only final k-runs cross the mesh.** Each shard's gather-top-kp run
  is exactly re-ranked with canonical distances *locally*, then the
  (kp-wide) sorted runs are all-gathered and folded through the
  id-disjoint tree merge (`kernels.sorted_merge.tree_merge_runs`) —
  never raw candidates, never row payloads. For the quantized tier the
  per-shard certification lower bound is combined with ``lax.pmin`` so
  the usual per-query soundness certificate covers rows *any* shard
  coarse-pruned.
* **Zero steady-state host syncs, per shard.** Every payload piece —
  including the tombstone-count scalar and the enqueued queries — is
  committed to the mesh (replicated or shard-partitioned) at
  enqueue/refresh time, so the steady state runs entirely under
  ``jax.transfer_guard("disallow")``, exactly like the single-device
  engine it is bitwise-equal to.

Exactness under sharding: the merged union of per-shard exact top-kp
runs contains the true top-k (each true neighbor lives on exactly one
shard and survives that shard's θ-schedule superset + exact re-rank;
a row a shard drops at rank > kp has exact distance ≥ that shard's
k-th ≥ the merged k-th). Shard count therefore never changes the
output — pinned by the shard-invariance tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import numpy as np

from .jax_compat import make_mesh, shard_map
from .megastep import (MegastepEngine, _assign_bounds_schedule, _bump_trace,
                       _canonical_runs, _gather_topk_run)
from .types import JoinConfig

__all__ = ["ShardedMegastepEngine"]

# per-segment geometry keys that are shard-partitioned (leading shard
# axis); everything else in a segment dict is replicated
_SEG_SHARDED = ("sd_min", "sd_max", "present")
# tile-payload keys that are replicated; everything else (rows, ids,
# liveness, int8 twins) is shard-partitioned on its leading axis
_TILES_REP = ("center",)


def _mesh_specs(segs, tiles):
    """PartitionSpecs matching the sharded payload layout: per-shard
    arrays split on their leading axis over the "shard" mesh axis,
    geometry/scalars replicated."""
    from jax.sharding import PartitionSpec as P
    seg_specs = tuple(
        {key: (P("shard") if key in _SEG_SHARDED else P())
         for key in sd}
        for sd in segs)
    tile_specs = {key: (P() if key in _TILES_REP else P("shard"))
                  for key in tiles}
    return seg_specs, tile_specs


def _strip_shard(segs, tiles):
    """Inside the shard_map body the partitioned arrays arrive with a
    leading shard axis of extent 1 — strip it so the payload has exactly
    the single-device shapes the shared megastep stages expect."""
    segs = tuple(
        {key: (val[0] if key in _SEG_SHARDED else val)
         for key, val in sd.items()}
        for sd in segs)
    tiles = {key: (val if key in _TILES_REP else val[0])
             for key, val in tiles.items()}
    return segs, tiles


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "n_shards", "k", "bm", "bn", "metric", "dim",
                     "n_finite_total", "seg_meta", "primary", "impl"))
def _sharded_megastep(q, n_valid, dead_total, segs, tiles, state, *,
                      mesh, n_shards: int, k: int, bm: int, bn: int,
                      metric: str, dim: int, n_finite_total: int,
                      seg_meta: tuple, primary: int, impl: str):
    """The fp32 megastep under shard_map: per-shard schedule + gather +
    exact re-rank, all-gather of the final kp-runs, in-mesh tree merge.
    Bitwise the single-device `megastep._megastep` for any shard count.
    """
    _bump_trace()

    import jax.numpy as jnp

    from repro.kernels.sorted_merge import (merge_sorted_runs_unique,
                                            next_pow2, tree_merge_runs)
    from jax.sharding import PartitionSpec as P

    kp = next_pow2(k)
    seg_specs, tile_specs = _mesh_specs(segs, tiles)

    @shard_map(mesh=mesh,
               in_specs=(P(), P(), P(), seg_specs, tile_specs),
               # all_gather + tree merge leaves every shard holding the
               # identical final run — replicated in value, which the
               # static VMA check can't see (same pattern as
               # distributed.distributed_phase1)
               out_specs=(P(), P(), P()), check_vma=False)
    def body(q, n_valid, dead_total, segs, tiles):
        segs, tiles = _strip_shard(segs, tiles)
        # θ below is computed from the replicated union T_S lists —
        # identical on every shard; the visit masks see only this
        # shard's tile stats, so the compacted schedule is local
        qs, qcs, valid_s, _perm, inv, _th, sched, cnt = \
            _assign_bounds_schedule(
                q, n_valid, dead_total, segs, tiles["center"], k=k, bm=bm,
                metric=metric, n_finite_total=n_finite_total,
                seg_meta=seg_meta, primary=primary)
        d_run, pos, valid_sel = _gather_topk_run(
            qs, qcs, valid_s, sched, cnt, tiles, k=k, bm=bm, bn=bn,
            metric=metric, dim=dim, impl=impl)
        # keep the full kp run: the cross-shard merge must see every
        # column to resolve the global rank-k boundary exactly
        d_can, hi, lo = _canonical_runs(qs, tiles, pos, valid_sel,
                                        metric, kp)
        d_can, hi, lo = d_can[inv], hi[inv], lo[inv]
        if n_shards > 1:
            gd = jax.lax.all_gather(d_can, "shard")
            ghi = jax.lax.all_gather(hi, "shard")
            glo = jax.lax.all_gather(lo, "shard")
            d_can, (hi, lo) = tree_merge_runs(
                [(gd[j], (ghi[j], glo[j])) for j in range(n_shards)])
        return d_can[:, :k], hi[:, :k], lo[:, :k]

    d, hi, lo = body(q, n_valid, dead_total, segs, tiles)

    if state is not None:
        sd, shi, slo = state
        pad = ((0, 0), (0, kp - k))
        md, (mhi, mlo) = merge_sorted_runs_unique(
            jnp.pad(sd, pad, constant_values=jnp.inf),
            (jnp.pad(shi, pad, constant_values=-1),
             jnp.pad(slo, pad, constant_values=-1)),
            jnp.pad(d, pad, constant_values=jnp.inf),
            (jnp.pad(hi, pad, constant_values=-1),
             jnp.pad(lo, pad, constant_values=-1)))
        d, hi, lo = md[:, :k], mhi[:, :k], mlo[:, :k]
    return d, hi, lo


class _ShardedPayloadMixin:
    """Shared mesh/payload machinery of the sharded engines: mesh
    construction, replicated/partitioned device placement, and the
    shard-laid-out `_build_struct` both the fp32 and quantized sharded
    engines consume. Mixed in *before* the single-device engine so its
    placement hooks and payload build win the MRO."""

    def _init_mesh(self, n_shards, mesh) -> None:
        if mesh is not None:
            if "shard" not in mesh.axis_names:
                raise ValueError(
                    f"sharded megastep needs a mesh with a 'shard' axis, "
                    f"got axes {mesh.axis_names}")
            self.mesh = mesh
            self.n_shards = int(mesh.shape["shard"])
            if n_shards is not None and int(n_shards) != self.n_shards:
                raise ValueError(
                    f"n_shards={n_shards} disagrees with the mesh's "
                    f"'shard' extent {self.n_shards}")
            return
        avail = len(jax.devices())
        n_shards = avail if n_shards is None else int(n_shards)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if n_shards > avail:
            raise ValueError(
                f"n_shards={n_shards} exceeds the {avail} visible "
                f"device(s); for a simulated mesh set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{n_shards} before importing jax")
        self.mesh = make_mesh((n_shards,), ("shard",))
        self.n_shards = n_shards

    # ---- device placement: commit everything to the mesh so the jit
    # over sharded args never sees a single-device-committed array (that
    # raises "incompatible devices") and the steady state never moves a
    # byte — both replicated and partitioned pieces land at refresh /
    # enqueue time, outside any transfer guard

    def _put_rep(self, x):
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(jnp.asarray(x),
                              NamedSharding(self.mesh, P()))

    def _put_shard(self, x):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(np.ascontiguousarray(x),
                              NamedSharding(self.mesh, P("shard")))

    def _put_alive(self, alive: np.ndarray):
        return self._put_shard(alive.astype(np.float32))

    def enqueue(self, queries: np.ndarray):
        q = np.ascontiguousarray(queries, np.float32)
        n = q.shape[0]
        bucket = self.bucket_for(n)
        if bucket != n:
            q = np.pad(q, ((0, bucket - n), (0, 0)))
        return self._put_rep(q), self._put_rep(np.int32(n))

    def dispatch(self, queries, *, stats=None):
        if stats is not None:
            stats.n_shards = self.n_shards
        return super().dispatch(queries, stats=stats)

    def nbytes_per_shard(self, *,
                         quantized: Optional[bool] = None) -> np.ndarray:
        """Resident row-payload bytes per shard, summed over live
        segments — the per-device HBM figure `SIndex.nbytes_resident(
        n_shards=...)` reports the max of (see `index.ShardPacking`)."""
        segs, _, _ = self._index_parts()
        out = np.zeros((self.n_shards,), np.int64)
        for si, _ in segs:
            qz = ((si.config.quantize != "none")
                  if quantized is None else quantized)
            sp = si.shard_packing(self.n_shards, self._bn)
            out += sp.nbytes_per_shard(quantized=qz)
        return out

    # ---- the shard-laid-out payload

    def _build_struct(self, segs, bn: int, k: int) -> dict:
        n_sh = self.n_shards
        live_ids = set(id(si) for si, _ in segs)
        self._seg_cache = {key: v for key, v in self._seg_cache.items()
                           if key[0] in live_ids}
        dim = segs[0][0].dim
        quant = getattr(self, "mode", "fp32") == "int8"
        seg_meta = []
        n_finite_total = 0
        sizes = []
        packs = []
        for si, off in segs:
            key = (id(si), bn, n_sh)
            ent = self._seg_cache.get(key)
            if ent is None:
                ent = dict(si=si, sp=si.shard_packing(n_sh, bn),
                           knn_np=si.t_s.knn_dists)
                self._seg_cache[key] = ent
            sp = ent["sp"]
            kk = min(k, ent["knn_np"].shape[1])
            n_finite_total += int(np.isfinite(ent["knn_np"][:, :kk]).sum())
            seg_meta.append((si.n_pivots, kk, sp.tiles_per_shard))
            sizes.append(si.n_s)
            packs.append((si, off, sp))
        # the selection center must be bitwise the single-device one:
        # same rows, same (segment, partition, dist) order, same f64
        # mean — sharding must not perturb the selection metric
        all_rows = (np.concatenate([si.s_sorted for si, _, _ in packs])
                    if sum(sizes) else np.zeros((0, dim), np.float32))
        center = (all_rows.mean(axis=0, dtype=np.float64)
                  .astype(np.float32) if all_rows.shape[0] else
                  np.zeros((dim,), np.float32))
        segs_dev = []
        for si, off, sp in packs:
            segs_dev.append(dict(
                pivots_c=self._put_rep(si.pivots - center[None, :]),
                pivd=self._put_rep(si.pivd.astype(np.float32)),
                knn=self._put_rep(si.t_s.knn_dists.astype(np.float32)),
                sd_min=self._put_shard(sp.sd_min),
                sd_max=self._put_shard(sp.sd_max),
                present=self._put_shard(sp.present)))
        rows_all = np.concatenate([sp.rows for _, _, sp in packs], axis=1)
        gids = np.concatenate(
            [np.where(sp.gids_local >= 0, sp.gids_local + off, -1)
             for _, off, sp in packs], axis=1)
        hi = (gids >> 32).astype(np.int32)
        lo = (gids & np.int64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
        tiles_dev = dict(center=self._put_rep(center),
                         id_hi=self._put_shard(hi),
                         id_lo=self._put_shard(lo),
                         s=self._put_shard(rows_all))
        if quant:
            sqs, scs, eps = zip(*(sp.ensure_quant()
                                  for _, _, sp in packs))
            tiles_dev["sq"] = self._put_shard(np.concatenate(sqs, axis=1))
            tiles_dev["sscale"] = self._put_shard(
                np.concatenate(scs, axis=1))
            tiles_dev["seps"] = self._put_shard(np.concatenate(eps, axis=1))
        return dict(
            segs_dev=tuple(segs_dev), tiles_dev=tiles_dev, rows_host=None,
            gids=gids, seg_meta=tuple(seg_meta), dim=dim,
            n_finite_total=n_finite_total, primary=int(np.argmax(sizes)))

    def _sharded_fp32_call(self, q_dev, n_valid_dev, state=None):
        from repro.kernels import ops
        payload = self._refresh()
        bucket = int(q_dev.shape[0])
        bm = min(bucket, self._bm_cap)
        impl = self.impl or ("pallas" if ops.use_pallas() else "ref")
        return _sharded_megastep(
            q_dev, n_valid_dev, payload.dead_total, payload.segs,
            payload.tiles, state, mesh=self.mesh, n_shards=self.n_shards,
            k=self.config.k, bm=bm, bn=self._bn,
            metric=self.config.metric, dim=payload.dim,
            n_finite_total=payload.n_finite_total,
            seg_meta=payload.seg_meta, primary=payload.primary, impl=impl)


class ShardedMegastepEngine(_ShardedPayloadMixin, MegastepEngine):
    """`MegastepEngine` over a 1-D "shard" mesh: the same dispatch() /
    finalize() surface and the same bitwise output, with the index
    payload partitioned across shards by `SIndex.shard_packing` and the
    megastep running SPMD (see module docstring).

    ``n_shards=None`` spans every visible device; pass an explicit
    ``mesh`` (with a "shard" axis) to co-locate with other meshes.
    """

    def __init__(self, index, config: Optional[JoinConfig] = None, *,
                 n_shards: Optional[int] = None, mesh=None,
                 bucket_min: int = 16, impl: Optional[str] = None):
        self._init_mesh(n_shards, mesh)
        super().__init__(index, config, bucket_min=bucket_min, impl=impl)

    def join_batch_device(self, q_dev, n_valid_dev, *, state=None):
        return self._sharded_fp32_call(q_dev, n_valid_dev, state)
