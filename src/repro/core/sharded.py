"""Sharded megastep: one logical datastore across a JAX device mesh.

The fused megastep (`core.megastep`) applies the paper's Cor. 1 / Thm 2
mapper-side filtering on exactly one device, so the resident payload —
even ~3.7× smaller via int8 — caps the datastore at one HBM. This module
re-expresses the paper's shuffle as **mesh partitioning**: pivot groups
are assigned to shards by the §5 geometric grouping
(`SIndex.shard_packing`), each shard holds only its groups' packed rows
(+ int8 twins + ε bounds) and their Thm-2 tile stats, and the whole
assign → θ → schedule → gather-top-k → exact-re-rank body runs SPMD
inside ``shard_map`` (via `core.jax_compat`):

* **θ is global, schedules are per shard.** Every shard carries the
  replicated pivot geometry and T_S pivot-kNN lists of *all* segments,
  so `megastep._assign_bounds_schedule` computes the identical union-θ
  on every shard (Thm 3 over the union candidate set — bitwise the
  single-device value). Its visit masks, though, are evaluated against
  the shard's own tile stats: partitions a shard doesn't own are never
  ``present``, so the compacted schedule visits only local tiles — the
  paper's per-reducer pruning, reborn per shard.
* **Only final k-runs cross the mesh.** Each shard's gather-top-kp run
  is exactly re-ranked with canonical distances *locally*, then the
  (kp-wide) sorted runs are all-gathered and folded through the
  id-disjoint tree merge (`kernels.sorted_merge.tree_merge_runs`) —
  never raw candidates, never row payloads. For the quantized tier the
  per-shard certification lower bound is combined with ``lax.pmin`` so
  the usual per-query soundness certificate covers rows *any* shard
  coarse-pruned.
* **Zero steady-state host syncs, per shard.** Every payload piece —
  including the tombstone-count scalar and the enqueued queries — is
  committed to the mesh (replicated or shard-partitioned) at
  enqueue/refresh time, so the steady state runs entirely under
  ``jax.transfer_guard("disallow")``, exactly like the single-device
  engine it is bitwise-equal to.

Exactness under sharding: the merged union of per-shard exact top-kp
runs contains the true top-k (each true neighbor lives on exactly one
shard and survives that shard's θ-schedule superset + exact re-rank;
a row a shard drops at rank > kp has exact distance ≥ that shard's
k-th ≥ the merged k-th). Shard count therefore never changes the
output — pinned by the shard-invariance tests.

**Fault tolerance.** The exactness argument above holds for *any*
assignment that serves each partition on exactly one shard — which is
what makes failover bitwise. `SIndex.shard_packing(r=...)` places each
pivot group on a primary plus ``r−1`` backup shards (every replica the
same pivot-sorted packed slice); a :class:`ShardHealth` tracker — fed
by the ``sharded.*`` fault-injection sites and by bounded attempt
timeouts — picks a per-partition serving *owner view*
(`ShardPacking.owner_view`). Failover is a host-side mask swap: the
``alive`` mask keeps only owner-served rows (masked rows canonicalize
to (+inf, −1) exactly like padding, so output bits cannot move) and
``present`` is gated so schedules skip standby tiles; the resident row
payload never re-uploads. With no live replica the surviving shards'
runs still merge through `tree_merge_runs` and every query carries a
*sound* certified recall bound (see `_sharded_megastep`); `recover()`
rebuilds and re-uploads the full payload behind ``refresh_lock``
without blocking serving.
"""
from __future__ import annotations

import functools
import threading
from typing import Optional

import jax
import numpy as np

from repro import obs

from .jax_compat import make_mesh, shard_map
from .megastep import (JoinHandle, MegastepEngine, _assign_bounds_schedule,
                       _bump_trace, _canonical_runs, _gather_topk_run)
from .types import JoinConfig, JoinStats

__all__ = ["ShardHealth", "ShardedMegastepEngine"]


class ShardHealth:
    """Thread-safe failed-shard tracker for one sharded engine.

    ``mark_failed`` records a failed shard and bumps ``generation``;
    the engine's payload cache keys on the generation, so the next
    ``_refresh`` rebuilds the *serving view* (owner failover masks)
    without re-uploading resident rows. ``reset`` restores full health
    (recovery). Timeouts with no attributable shard only count — the
    view can't change without knowing whom to evict."""

    def __init__(self, n_shards: int):
        self.n_shards = int(n_shards)
        self._lock = threading.Lock()
        self._failed: set = set()
        self.generation = 0
        self.n_faults = 0
        self.n_timeouts = 0

    @property
    def failed(self) -> frozenset:
        with self._lock:
            return frozenset(self._failed)

    def mark_failed(self, shard: Optional[int]) -> bool:
        """Record a shard failure; True iff it newly changed the view."""
        with self._lock:
            self.n_faults += 1
            if shard is None:
                return False
            shard = int(shard)
            if not (0 <= shard < self.n_shards) or shard in self._failed:
                return False
            self._failed.add(shard)
            self.generation += 1
            return True

    def note_timeout(self) -> None:
        with self._lock:
            self.n_timeouts += 1

    def reset(self) -> None:
        with self._lock:
            self._failed.clear()
            self.generation += 1

# per-segment geometry keys that are shard-partitioned (leading shard
# axis); everything else in a segment dict is replicated
_SEG_SHARDED = ("sd_min", "sd_max", "present")
# tile-payload keys that are replicated; everything else (rows, ids,
# liveness, int8 twins) is shard-partitioned on its leading axis
_TILES_REP = ("center",)


def _mesh_specs(segs, tiles):
    """PartitionSpecs matching the sharded payload layout: per-shard
    arrays split on their leading axis over the "shard" mesh axis,
    geometry/scalars replicated."""
    from jax.sharding import PartitionSpec as P
    seg_specs = tuple(
        {key: (P("shard") if key in _SEG_SHARDED else P())
         for key in sd}
        for sd in segs)
    tile_specs = {key: (P() if key in _TILES_REP else P("shard"))
                  for key in tiles}
    return seg_specs, tile_specs


def _strip_shard(segs, tiles):
    """Inside the shard_map body the partitioned arrays arrive with a
    leading shard axis of extent 1 — strip it so the payload has exactly
    the single-device shapes the shared megastep stages expect."""
    segs = tuple(
        {key: (val[0] if key in _SEG_SHARDED else val)
         for key, val in sd.items()}
        for sd in segs)
    tiles = {key: (val if key in _TILES_REP else val[0])
             for key, val in tiles.items()}
    return segs, tiles


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "n_shards", "k", "bm", "bn", "metric", "dim",
                     "n_finite_total", "seg_meta", "primary", "impl"))
def _sharded_megastep(q, n_valid, dead_total, segs, tiles, state, *,
                      mesh, n_shards: int, k: int, bm: int, bn: int,
                      metric: str, dim: int, n_finite_total: int,
                      seg_meta: tuple, primary: int, impl: str):
    """The fp32 megastep under shard_map: per-shard schedule + gather +
    exact re-rank, all-gather of the final kp-runs, in-mesh tree merge.
    Bitwise the single-device `megastep._megastep` for any shard count.

    Returns ``(d, id_hi, id_lo, lm)``: the fourth output is the
    per-query certified degraded-coverage bound (+inf when the serving
    view covers every pivot group — the healthy case). Degraded views
    pass per-segment ``uncovered`` masks + T_S ``upper`` bounds in the
    seg dicts; soundness of the certificate: every row of an uncovered
    group p is ≥ max(d(q, pivot_p) − U(p), 0) away (triangle inequality
    on the pivot), and θ upper-bounds the distance of anything a visit
    schedule pruned — so a reported neighbor with d ≤ lm =
    min(min_p lb_p, θ) is provably in the true global top-k.
    """
    _bump_trace()

    import jax.numpy as jnp

    from repro.kernels.sorted_merge import (merge_sorted_runs_unique,
                                            next_pow2, tree_merge_runs)
    from jax.sharding import PartitionSpec as P

    kp = next_pow2(k)
    seg_specs, tile_specs = _mesh_specs(segs, tiles)

    @shard_map(mesh=mesh,
               in_specs=(P(), P(), P(), seg_specs, tile_specs),
               # all_gather + tree merge leaves every shard holding the
               # identical final run — replicated in value, which the
               # static VMA check can't see (same pattern as
               # distributed.distributed_phase1)
               out_specs=(P(), P(), P(), P()), check_vma=False)
    def body(q, n_valid, dead_total, segs, tiles):
        segs, tiles = _strip_shard(segs, tiles)
        # θ below is computed from the replicated union T_S lists —
        # identical on every shard; the visit masks see only this
        # shard's tile stats, so the compacted schedule is local
        qs, qcs, valid_s, _perm, inv, th_q, sched, cnt = \
            _assign_bounds_schedule(
                q, n_valid, dead_total, segs, tiles["center"], k=k, bm=bm,
                metric=metric, n_finite_total=n_finite_total,
                seg_meta=seg_meta, primary=primary)
        d_run, pos, valid_sel = _gather_topk_run(
            qs, qcs, valid_s, sched, cnt, tiles, k=k, bm=bm, bn=bn,
            metric=metric, dim=dim, impl=impl)
        # keep the full kp run: the cross-shard merge must see every
        # column to resolve the global rank-k boundary exactly
        d_can, hi, lo = _canonical_runs(qs, tiles, pos, valid_sel,
                                        metric, kp)
        # degraded-coverage certificate (replicated math — every shard
        # computes the identical bound from the replicated geometry);
        # healthy views carry no "uncovered" key and get a constant +inf
        lm = jnp.full((q.shape[0],), jnp.inf, jnp.float32)
        if any("uncovered" in sd for sd in segs):
            any_u = jnp.zeros((), bool)
            lb_min = jnp.full((q.shape[0],), jnp.inf, jnp.float32)
            for g in range(len(seg_meta)):
                sd = segs[g]
                if "uncovered" not in sd:
                    continue
                pc = sd["pivots_c"]
                d2 = (jnp.sum(qcs * qcs, axis=1)[:, None]
                      + jnp.sum(pc * pc, axis=1)[None, :]
                      - 2.0 * (qcs @ pc.T))
                dqp = jnp.sqrt(jnp.maximum(d2, 0.0))
                lb = jnp.maximum(
                    dqp - sd["upper"][None, :].astype(jnp.float32), 0.0)
                lb = jnp.where(sd["uncovered"][None, :], lb, jnp.inf)
                lb_min = jnp.minimum(lb_min, jnp.min(lb, axis=1))
                any_u = any_u | jnp.any(sd["uncovered"])
            # the θ cap is load-bearing: a covered row the schedule
            # θ-pruned could be closer than a counted neighbor, so only
            # d ≤ θ neighbors can claim a provable global rank
            lm = jnp.where(any_u, jnp.minimum(lb_min, th_q), jnp.inf)
        d_can, hi, lo, lm = d_can[inv], hi[inv], lo[inv], lm[inv]
        if n_shards > 1:
            gd = jax.lax.all_gather(d_can, "shard")
            ghi = jax.lax.all_gather(hi, "shard")
            glo = jax.lax.all_gather(lo, "shard")
            d_can, (hi, lo) = tree_merge_runs(
                [(gd[j], (ghi[j], glo[j])) for j in range(n_shards)])
        return d_can[:, :k], hi[:, :k], lo[:, :k], lm

    d, hi, lo, lm = body(q, n_valid, dead_total, segs, tiles)

    if state is not None:
        sd, shi, slo = state[:3]
        if len(state) > 3:
            # min of two sound per-query bounds is sound
            lm = jnp.minimum(lm, state[3])
        pad = ((0, 0), (0, kp - k))
        md, (mhi, mlo) = merge_sorted_runs_unique(
            jnp.pad(sd, pad, constant_values=jnp.inf),
            (jnp.pad(shi, pad, constant_values=-1),
             jnp.pad(slo, pad, constant_values=-1)),
            jnp.pad(d, pad, constant_values=jnp.inf),
            (jnp.pad(hi, pad, constant_values=-1),
             jnp.pad(lo, pad, constant_values=-1)))
        d, hi, lo = md[:, :k], mhi[:, :k], mlo[:, :k]
    return d, hi, lo, lm


class _ShardedPayloadMixin:
    """Shared mesh/payload machinery of the sharded engines: mesh
    construction, replicated/partitioned device placement, and the
    shard-laid-out `_build_struct` both the fp32 and quantized sharded
    engines consume. Mixed in *before* the single-device engine so its
    placement hooks and payload build win the MRO."""

    def _init_mesh(self, n_shards, mesh) -> None:
        if mesh is not None:
            if "shard" not in mesh.axis_names:
                raise ValueError(
                    f"sharded megastep needs a mesh with a 'shard' axis, "
                    f"got axes {mesh.axis_names}")
            self.mesh = mesh
            self.n_shards = int(mesh.shape["shard"])
            if n_shards is not None and int(n_shards) != self.n_shards:
                raise ValueError(
                    f"n_shards={n_shards} disagrees with the mesh's "
                    f"'shard' extent {self.n_shards}")
            self._init_health()
            return
        avail = len(jax.devices())
        n_shards = avail if n_shards is None else int(n_shards)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if n_shards > avail:
            raise ValueError(
                f"n_shards={n_shards} exceeds the {avail} visible "
                f"device(s); for a simulated mesh set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{n_shards} before importing jax")
        self.mesh = make_mesh((n_shards,), ("shard",))
        self.n_shards = n_shards
        self._init_health()

    def _init_health(self) -> None:
        # shard-failure state shared by every sharded engine. The quant
        # engine never wires the fault sites, so its health stays clean
        # and the view fast paths below are identity for it; the fp32
        # engine overrides replication/attempt_timeout from its ctor.
        self.health = ShardHealth(self.n_shards)
        self.replication = 1
        self.attempt_timeout: Optional[float] = None
        self._attempt_pool = None
        self._cov_cache = None
        self._recover_lock = threading.Lock()

    # ---- device placement: commit everything to the mesh so the jit
    # over sharded args never sees a single-device-committed array (that
    # raises "incompatible devices") and the steady state never moves a
    # byte — both replicated and partitioned pieces land at refresh /
    # enqueue time, outside any transfer guard

    def _put_rep(self, x):
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(jnp.asarray(x),
                              NamedSharding(self.mesh, P()))

    def _put_shard(self, x):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.serve import faultinject
        # fault hook: a ShardFault here simulates a device lost while
        # its partitioned payload slice was being committed to the mesh
        faultinject.fire("sharded.shard_upload")
        return jax.device_put(np.ascontiguousarray(x),
                              NamedSharding(self.mesh, P("shard")))

    def _put_alive(self, alive: np.ndarray):
        return self._put_shard(alive.astype(np.float32))

    def enqueue(self, queries: np.ndarray):
        q = np.ascontiguousarray(queries, np.float32)
        n = q.shape[0]
        bucket = self.bucket_for(n)
        if bucket != n:
            q = np.pad(q, ((0, bucket - n), (0, 0)))
        return self._put_rep(q), self._put_rep(np.int32(n))

    def dispatch(self, queries, *, stats=None):
        if stats is not None:
            stats.n_shards = self.n_shards
        return super().dispatch(queries, stats=stats)

    def nbytes_per_shard(self, *,
                         quantized: Optional[bool] = None) -> np.ndarray:
        """Resident row-payload bytes per shard, summed over live
        segments — the per-device HBM figure `SIndex.nbytes_resident(
        n_shards=...)` reports the max of (see `index.ShardPacking`)."""
        segs, _, _ = self._index_parts()
        out = np.zeros((self.n_shards,), np.int64)
        for si, _ in segs:
            qz = ((si.config.quantize != "none")
                  if quantized is None else quantized)
            sp = si.shard_packing(self.n_shards, self._bn,
                                  r=self.replication)
            out += sp.nbytes_per_shard(quantized=qz)
        return out

    # ---- the shard-laid-out payload

    def _build_struct(self, segs, bn: int, k: int) -> dict:
        n_sh = self.n_shards
        r = self.replication
        live_ids = set(id(si) for si, _ in segs)
        self._seg_cache = {key: v for key, v in self._seg_cache.items()
                           if key[0] in live_ids}
        dim = segs[0][0].dim
        quant = getattr(self, "mode", "fp32") == "int8"
        seg_meta = []
        n_finite_total = 0
        sizes = []
        packs = []
        for si, off in segs:
            key = (id(si), bn, n_sh, r)
            ent = self._seg_cache.get(key)
            if ent is None:
                ent = dict(si=si, sp=si.shard_packing(n_sh, bn, r=r),
                           knn_np=si.t_s.knn_dists)
                self._seg_cache[key] = ent
            sp = ent["sp"]
            kk = min(k, ent["knn_np"].shape[1])
            n_finite_total += int(np.isfinite(ent["knn_np"][:, :kk]).sum())
            seg_meta.append((si.n_pivots, kk, sp.tiles_per_shard))
            sizes.append(si.n_s)
            packs.append((si, off, sp))
        # the selection center must be bitwise the single-device one:
        # same rows, same (segment, partition, dist) order, same f64
        # mean — sharding must not perturb the selection metric
        all_rows = (np.concatenate([si.s_sorted for si, _, _ in packs])
                    if sum(sizes) else np.zeros((0, dim), np.float32))
        center = (all_rows.mean(axis=0, dtype=np.float64)
                  .astype(np.float32) if all_rows.shape[0] else
                  np.zeros((dim,), np.float32))
        segs_dev = []
        for si, off, sp in packs:
            segs_dev.append(dict(
                pivots_c=self._put_rep(si.pivots - center[None, :]),
                pivd=self._put_rep(si.pivd.astype(np.float32)),
                knn=self._put_rep(si.t_s.knn_dists.astype(np.float32)),
                # T_S per-partition upper bounds, replicated: the
                # degraded-coverage certificate reads them in-body
                upper=self._put_rep(si.t_s.upper.astype(np.float32)),
                sd_min=self._put_shard(sp.sd_min),
                sd_max=self._put_shard(sp.sd_max),
                present=self._put_shard(sp.present)))
        rows_all = np.concatenate([sp.rows for _, _, sp in packs], axis=1)
        gids = np.concatenate(
            [np.where(sp.gids_local >= 0, sp.gids_local + off, -1)
             for _, off, sp in packs], axis=1)
        hi = (gids >> 32).astype(np.int32)
        lo = (gids & np.int64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
        tiles_dev = dict(center=self._put_rep(center),
                         id_hi=self._put_shard(hi),
                         id_lo=self._put_shard(lo),
                         s=self._put_shard(rows_all))
        if quant:
            sqs, scs, eps = zip(*(sp.ensure_quant()
                                  for _, _, sp in packs))
            tiles_dev["sq"] = self._put_shard(np.concatenate(sqs, axis=1))
            tiles_dev["sscale"] = self._put_shard(
                np.concatenate(scs, axis=1))
            tiles_dev["seps"] = self._put_shard(np.concatenate(eps, axis=1))
        return dict(
            segs_dev=tuple(segs_dev), tiles_dev=tiles_dev, rows_host=None,
            gids=gids, seg_meta=tuple(seg_meta), dim=dim,
            n_finite_total=n_finite_total, primary=int(np.argmax(sizes)),
            # host-side packings, for the health-driven serving views
            packs_sp=tuple(sp for _, _, sp in packs))

    # ---- serving view (failover): the payload cache keys on shard
    # health, and the alive/present masks follow the owner view. With
    # r=1 and full health (the quant engines always, the fp32 engine in
    # steady state) every hook is identity — bitwise and free.

    def _payload_key(self, vkey):
        return vkey + ("health", self.health.generation)

    def _view_packs(self, st):
        failed = self.health.failed
        return [(sp, sp.owner_view(failed)) for sp in st["packs_sp"]]

    def _alive_mask(self, st, tomb) -> np.ndarray:
        alive = super()._alive_mask(st, tomb)
        if self.replication == 1 and not self.health.failed:
            return alive
        mask = np.concatenate(
            [sp.serve_mask(owner) for sp, owner in self._view_packs(st)],
            axis=1)
        return alive & mask

    def _segs_for_view(self, st):
        if self.replication == 1 and not self.health.failed:
            return st["segs_dev"]
        out = []
        for base, (sp, owner) in zip(st["segs_dev"], self._view_packs(st)):
            sd = dict(base)
            sd["present"] = self._put_shard(sp.present_view(owner))
            sd["uncovered"] = self._put_rep(sp.uncovered_parts(owner))
            out.append(sd)
        return tuple(out)

    # ---- the sharded device call

    def _sharded_fp32_call(self, q_dev, n_valid_dev, state=None):
        return self._mega_call(self._refresh(), q_dev, n_valid_dev, state)

    def _mega_call(self, payload, q_dev, n_valid_dev, state=None):
        """The lock-free tail of the sharded fp32 call: launch the SPMD
        megastep against an already-refreshed payload. Split out so a
        timeout-bounded attempt thread never re-enters refresh_lock."""
        from repro.kernels import ops
        bucket = int(q_dev.shape[0])
        bm = min(bucket, self._bm_cap)
        impl = self.impl or ("pallas" if ops.use_pallas() else "ref")
        return _sharded_megastep(
            q_dev, n_valid_dev, payload.dead_total, payload.segs,
            payload.tiles, state, mesh=self.mesh, n_shards=self.n_shards,
            k=self.config.k, bm=bm, bn=self._bn,
            metric=self.config.metric, dim=payload.dim,
            n_finite_total=payload.n_finite_total,
            seg_meta=payload.seg_meta, primary=payload.primary, impl=impl)


class ShardedMegastepEngine(_ShardedPayloadMixin, MegastepEngine):
    """`MegastepEngine` over a 1-D "shard" mesh: the same dispatch() /
    finalize() surface and the same bitwise output, with the index
    payload partitioned across shards by `SIndex.shard_packing` and the
    megastep running SPMD (see module docstring).

    ``n_shards=None`` spans every visible device; pass an explicit
    ``mesh`` (with a "shard" axis) to co-locate with other meshes.

    ``replication=r`` places every pivot group on a primary plus r-1
    backup shards (`SIndex.shard_packing(r=...)`). On a detected shard
    failure (a :class:`~repro.serve.faultinject.ShardFault` from a
    ``sharded.*`` site, or a bounded ``attempt_timeout`` expiring) the
    engine marks the shard failed and raises
    :class:`~repro.serve.faultinject.ShardFailedError`; the next attempt
    serves the updated owner view — bitwise-identical while every
    populated group keeps a live replica, certified degraded coverage
    (per-query ``rb`` from :meth:`finalize_covered`) once groups are
    lost. :meth:`recover` re-uploads and re-admits failed shards in the
    background without blocking serving.
    """

    def __init__(self, index, config: Optional[JoinConfig] = None, *,
                 n_shards: Optional[int] = None, mesh=None,
                 bucket_min: int = 16, impl: Optional[str] = None,
                 replication: int = 1,
                 attempt_timeout: Optional[float] = None):
        self._init_mesh(n_shards, mesh)
        replication = int(replication)
        if replication < 1:
            raise ValueError(f"replication must be >= 1, got {replication}")
        self.replication = min(replication, self.n_shards)
        self.attempt_timeout = (float(attempt_timeout)
                                if attempt_timeout else None)
        super().__init__(index, config, bucket_min=bucket_min, impl=impl)

    def join_batch_device(self, q_dev, n_valid_dev, *, state=None):
        return self._sharded_fp32_call(q_dev, n_valid_dev, state)

    # ---- failure handling

    def _shard_failed(self, fault):
        """Record a failed shard and convert the fault into the
        retriable :class:`ShardFailedError` (the caller's next attempt
        runs on the updated owner view)."""
        from repro.serve.faultinject import ShardFailedError
        shard = getattr(fault, "shard", None)
        self.health.mark_failed(shard)
        self._cov_cache = None
        # the remask: the serving view just changed — the next refresh
        # rebuilds owner-failover masks keyed on this generation
        obs.event("sharded.failover_remask", shard=shard,
                  generation=self.health.generation,
                  n_failed=len(self.health.failed))
        reg = obs.metrics.REGISTRY
        reg.counter("shard_failover_total").inc()
        reg.gauge("shard_failed").set(len(self.health.failed))
        reg.gauge("shard_generation").set(self.health.generation)
        return ShardFailedError(
            shard, f"shard {shard} failed "
                   f"({len(self.health.failed)}/{self.n_shards} down): "
                   f"{fault}")

    def _bounded_attempt(self, fn, what: str):
        """Run one device attempt under ``attempt_timeout`` so a hung
        shard/collective surfaces as a :class:`ShardFailedError` instead
        of hanging ``serve_forever()``. ``fn`` must not take
        ``refresh_lock`` (the caller thread may already hold it via
        ``Datastore``'s serialize-under-lock path — refresh therefore
        always runs in the caller thread, never here)."""
        timeout = self.attempt_timeout
        if not timeout:
            return fn()
        import concurrent.futures as cf
        if self._attempt_pool is None:
            self._attempt_pool = cf.ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="shard-attempt")
        fut = self._attempt_pool.submit(fn)
        try:
            return fut.result(timeout=timeout)
        except cf.TimeoutError:
            from repro.serve.faultinject import ShardFailedError
            fut.cancel()
            self.health.note_timeout()
            obs.metrics.REGISTRY.counter("shard_timeout_total").inc()
            raise ShardFailedError(
                None, f"{what} exceeded attempt_timeout={timeout}s "
                      f"(hung shard or collective)") from None

    # ---- coverage certification

    def _coverage(self):
        segs, _, _ = self._index_parts()
        ck = (tuple(id(si) for si, _ in segs), self.health.generation)
        if self._cov_cache is not None and self._cov_cache[0] == ck:
            return self._cov_cache[1]
        failed = self.health.failed
        total = covered = 0
        any_unc = False
        for si, _ in segs:
            sp = si.shard_packing(self.n_shards, self._bn,
                                  r=self.replication)
            owner = sp.owner_view(failed)
            pc = sp.partition_counts()
            total += int(pc.sum())
            covered += int(pc[owner >= 0].sum())
            any_unc = any_unc or bool(sp.uncovered_parts(owner).any())
        out = ((covered / total) if total else 1.0, any_unc)
        self._cov_cache = (ck, out)
        return out

    @property
    def coverage_degraded(self) -> bool:
        """True when some populated pivot group has no live replica —
        results carry sound per-query recall bounds < 1 instead of the
        bitwise-exactness guarantee."""
        if not self.health.failed:
            return False
        return self._coverage()[1]

    def coverage_fraction(self) -> float:
        """Certified fraction of resident S rows in covered groups."""
        if not self.health.failed:
            return 1.0
        return self._coverage()[0]

    # ---- query API (failover-aware dispatch/finalize)

    def dispatch(self, queries: np.ndarray, *,
                 stats: Optional[JoinStats] = None) -> JoinHandle:
        from repro.serve import faultinject
        q = self._validated_queries(queries)
        n = q.shape[0]
        if stats is not None:
            stats.n_shards = self.n_shards
            stats.n_failed_shards = len(self.health.failed)
        if n == 0:
            return JoinHandle(kind="empty", n=0)
        try:
            # refresh (payload rebuild under refresh_lock) stays in the
            # caller thread: Datastore points refresh_lock at the lock
            # its mutations hold, and a bounded-attempt pool thread
            # taking it could deadlock against a caller holding it
            payload = self._refresh()
            if stats is not None:
                stats.n_r += n
                stats.n_s = max(stats.n_s, self.index.n_s)
                stats.n_segments = len(payload.seg_meta)
                stats.n_tombstones = int(np.asarray(payload.dead_total))
                stats.pivot_pairs_computed += n * sum(
                    m for m, _, _ in payload.seg_meta)
            qd, nv = self.enqueue(q)

            def launch():
                # fault hook: a shard dying mid-stream, at launch
                faultinject.fire("sharded.shard_compute")
                return self._mega_call(payload, qd, nv, None)

            d, hi, lo, lm = self._bounded_attempt(
                launch, "sharded dispatch")
        except faultinject.ShardFault as e:
            raise self._shard_failed(e) from e
        return JoinHandle(kind="sharded", n=n, dev=(d, hi, lo, lm))

    def finalize(self, handle: JoinHandle, *,
                 stats: Optional[JoinStats] = None
                 ) -> tuple[np.ndarray, np.ndarray]:
        d, ids, _rb = self.finalize_covered(handle, stats=stats)
        return d, ids

    def finalize_covered(self, handle: JoinHandle, *,
                         stats: Optional[JoinStats] = None):
        """:meth:`finalize` + the per-query certified recall lower bound
        ``rb`` (shape ``(n,)`` float32, 1.0 everywhere on a healthy
        mesh): reported neighbor j of query q is provably in the global
        top-k iff ``d_j <= lm_q`` (see the lm certificate in
        ``_sharded_megastep``), so at least ``rb*k`` of the k reported
        neighbors are true global kNN."""
        from repro.serve import faultinject
        k = self.config.k
        if handle.kind == "empty":
            return (np.zeros((0, k), np.float32),
                    np.full((0, k), -1, np.int64),
                    np.ones((0,), np.float32))
        if handle.kind != "sharded":
            raise ValueError(f"cannot finalize handle kind {handle.kind!r}")
        n = handle.n

        def fetch():
            faultinject.fire("megastep.fetch")   # simulated lost fetch
            dd, hh, ll, lmv = handle.dev
            # fault hook over the fetched cross-shard merge result: a
            # .fail is a poisoned all-gather; a sleeping .transform is a
            # hung one, which attempt_timeout must bound
            dd = faultinject.cross("sharded.collective", dd)
            return (np.asarray(dd), np.asarray(hh), np.asarray(ll),
                    np.asarray(lmv))

        try:
            # the cross-shard tree-merge result lands here — this fetch
            # synchronizes anyway, so the span costs no extra sync
            with obs.span("sharded.collective", rows=n,
                          n_shards=self.n_shards,
                          generation=self.health.generation,
                          n_failed=len(self.health.failed)) as sp:
                d, hi, lo, lm = self._bounded_attempt(
                    fetch, "sharded finalize")
                sp.set(outcome="merged")
        except faultinject.ShardFault as e:
            raise self._shard_failed(e) from e
        d = np.ascontiguousarray(d[:n])
        ids = ((hi.astype(np.int64) << 32)
               | (lo.astype(np.int64) & np.int64(0xFFFFFFFF)))[:n]
        lm = lm[:n]
        rb = ((d <= lm[:, None]).sum(axis=1) / k).astype(np.float32)
        if stats is not None and n and self.coverage_degraded:
            stats.n_degraded += n
            stats.recall_bound = min(stats.recall_bound, float(rb.min()))
            stats.coverage_bound = min(stats.coverage_bound,
                                       self.coverage_fraction())
        return d, np.ascontiguousarray(ids), rb

    def join_batch(
        self, queries: np.ndarray, *, stats: Optional[JoinStats] = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        d, ids, _rb = self.join_batch_covered(queries, stats=stats)
        return d, ids

    def join_batch_covered(self, queries: np.ndarray, *,
                           stats: Optional[JoinStats] = None):
        """:meth:`join_batch` + per-query certified recall bounds, with
        bounded internal failover: a :class:`ShardFailedError` re-enters
        on the updated owner view, at most once per shard (the serving
        scheduler instead catches the error itself so it can re-check
        deadlines at the failover instant)."""
        from repro.serve.faultinject import ShardFailedError
        last = None
        for _ in range(self.n_shards + 1):
            try:
                return self.finalize_covered(
                    self.dispatch(queries, stats=stats), stats=stats)
            except ShardFailedError as e:
                last = e
                continue
        raise last

    # ---- background recovery

    def recover(self, *, wait: bool = True):
        """Re-admit failed shards: rebuild + re-upload the full
        shard-partitioned payload, swap it in under ``refresh_lock``,
        and reset health — serving keeps answering on the degraded view
        while the upload runs. ``wait=False`` returns the daemon thread
        doing the work; ``wait=True`` blocks until recovered."""
        if wait:
            self._recover_work()
            return None
        t = threading.Thread(target=self._recover_work,
                             name="shard-recover", daemon=True)
        t.start()
        return t

    def _recover_work(self) -> None:
        with self._recover_lock:
            if not self.health.failed:
                return
            with self.refresh_lock:
                segs, _, _ = self._index_parts()
            if not segs:
                with self.refresh_lock:
                    self.health.reset()
                    self._payload = None
                    self._cov_cache = None
                return
            bn, k = self._bn, self.config.k
            # the expensive half — re-uploading every shard's slice —
            # runs outside refresh_lock so serving never blocks on it
            with obs.span("sharded.recover", n_shards=self.n_shards,
                          n_failed=len(self.health.failed)):
                st = self._build_struct(segs, bn, k)
                skey = (tuple(id(si) for si, _ in segs), bn, k)
                with self.refresh_lock:
                    self._struct = (skey, st)
                    self.health.reset()
                    self._payload = None
                    self._cov_cache = None
            reg = obs.metrics.REGISTRY
            reg.counter("shard_recover_total").inc()
            reg.gauge("shard_failed").set(0)
            reg.gauge("shard_generation").set(self.health.generation)
