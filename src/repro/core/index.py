"""Build-once S-index + per-batch query planner — the planning split.

The paper's pipeline is asymmetric: everything on the S side (Voronoi
partitioning against the pivots, the T_S summary table, the
pivot-sorted row layout the tile engines want) is a function of S
alone, while everything on the R side (assignment, θ, the LB matrices,
grouping, tile schedules) depends on the query set. This module splits
the former monolithic ``JoinPlan`` along exactly that line:

* ``SIndex`` — built **once** per dataset S by :func:`build_index`:
  pivots, the pivot-distance matrix, S's partition assignment and
  summary table, and the S rows pre-packed into pivot-sorted
  (partition, pivot-distance) order so every downstream engine gets
  partition-coherent tiles without re-sorting. The packed rows can be
  pinned on device (:meth:`SIndex.device_rows`) and reused across any
  number of query batches.

* ``QueryPlan`` — built **per R batch** by :func:`plan_queries`: the
  batch's pivot assignment, T_R, θ (Alg. 1 / Thm 3), the replication
  lower-bound matrix (Cor. 2) and the reducer grouping (§5). The
  assignment and θ/LB math run as jitted jnp (`partition._assign_blocked`
  + `bounds.theta_and_lb_jit`), so per-batch planning cost is a couple of
  fused device launches, not a host O(M²·k) numpy pass.

One index, many scenarios: the one-shot join (``core.api.knn_join``),
the streaming micro-batch engine (``core.stream``), the shard_map
runtime (``core.distributed.DistributedJoinEngine``) and the kNN-LM
serve path (``serve.retrieval.Datastore``) all consume the same
``(SIndex, QueryPlan)`` pair.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import bounds as B
from . import grouping as G
from .partition import assign_and_summarize, assign_to_pivots, build_summary
from .pivots import select_pivots
from .types import JoinConfig, SummaryTable

__all__ = ["SIndex", "QueryPlan", "ShardPacking", "build_index",
           "plan_queries", "as_float32_rows"]


@dataclasses.dataclass
class ShardPacking:
    """One segment's packed payload laid out per shard of a device mesh.

    Pivot groups are assigned to shards by the paper's §5 geometric
    grouping (`core.grouping.geometric_grouping`) balanced by partition
    population — the same heuristic that balances reducers balances
    shards. Rows are selected from the pivot-sorted packed layout, so
    each shard's block stays in (partition, pivot-distance) order and
    per-shard tiles remain partition-coherent; every shard is padded to
    the same ``tiles_per_shard`` tile count (rows 0, gids/part −1) so a
    single SPMD trace serves all shards. Per-shard Thm-2 tile stats are
    computed over each shard's own pivot subset — absent partitions are
    simply never ``present``, which is exactly what makes the Cor. 1 /
    Thm 2 visit schedules compact *per shard* inside the sharded
    megastep (`core.sharded`).

    With replication factor ``r > 1`` each pivot group additionally
    lands on ``r−1`` backup shards (the paper's reducer replication,
    Cor. 2, turned into fault tolerance): every replica holds the same
    pivot-sorted packed slice of its partitions, so any *serving view*
    — a choice of one live owner per partition, see :meth:`owner_view`
    — presents exactly the single-device row set and the sharded
    megastep stays bitwise-exact across failovers.
    """

    n_shards: int
    bn: int
    shard_of_part: np.ndarray   # (M,) int32 — primary shard per partition
    tiles_per_shard: int        # uniform (max-padded) S-tile count
    rows: np.ndarray            # (n_shards, tiles*bn, dim) float32
    gids_local: np.ndarray      # (n_shards, tiles*bn) int64, -1 padding
    part: np.ndarray            # (n_shards, tiles*bn) int32, -1 padding
    dist: np.ndarray            # (n_shards, tiles*bn) float32
    rows_per_shard: np.ndarray  # (n_shards,) int64 — real rows per shard
    sd_min: np.ndarray          # (n_shards, tiles, M) per-shard Thm-2 stats
    sd_max: np.ndarray          # (n_shards, tiles, M)
    present: np.ndarray         # (n_shards, tiles, M) bool
    # replication factor and the (r, M) replica table: row 0 is the
    # primary (== shard_of_part), rows 1..r−1 the backup shards, all
    # distinct per partition
    r: int = 1
    replicas_of_part: Optional[np.ndarray] = None
    _quant: object = dataclasses.field(
        default=None, repr=False, compare=False)

    # ---- failover serving views (core.sharded health tracking) ------

    def owner_view(self, failed=()) -> np.ndarray:
        """(M,) int32 — the shard that *serves* each partition under a
        set of failed shards: the primary while it lives, else the
        first live backup (replica order is deterministic, so every
        caller derives the identical view), else −1: an **uncovered**
        pivot group. ``owner_view(())`` is ``shard_of_part`` itself."""
        failed = frozenset(int(f) for f in failed)
        if not failed:
            return self.shard_of_part
        reps = (self.replicas_of_part if self.replicas_of_part is not None
                else self.shard_of_part[None, :])
        bad = np.asarray(sorted(failed), np.int32)
        owner = np.full((reps.shape[1],), -1, np.int32)
        for c in range(reps.shape[0]):
            cand = reps[c]
            take = (owner < 0) & ~np.isin(cand, bad)
            owner[take] = cand[take]
        return owner

    def serve_mask(self, owner: np.ndarray) -> np.ndarray:
        """(n_shards, tiles*bn) bool — which held rows each shard serves
        under a per-partition ``owner`` view. Exactly one shard serves
        each row of a covered partition (padding and non-owned replica
        copies are False): the union of served rows over shards equals
        the single-device row set minus uncovered partitions — what
        keeps any failover view bitwise on the covered set."""
        safe = np.clip(self.part, 0, owner.shape[0] - 1)
        return ((self.part >= 0)
                & (owner[safe] == np.arange(self.n_shards,
                                            dtype=np.int32)[:, None]))

    def present_view(self, owner: np.ndarray) -> np.ndarray:
        """(n_shards, tiles, M) bool — Thm-2 ``present`` gated to the
        partitions each shard currently *serves*, so per-shard visit
        schedules skip standby replica tiles entirely."""
        gate = (owner[None, :] == np.arange(self.n_shards,
                                            dtype=np.int32)[:, None])
        return self.present & gate[:, None, :]

    def partition_counts(self) -> np.ndarray:
        """(M,) int64 — real rows per partition, each row counted once
        (every populated partition holds exactly ``r`` replica copies)."""
        m = self.shard_of_part.shape[0]
        flat = self.part[self.part >= 0]
        cnt = np.bincount(flat.ravel(), minlength=m)
        return (cnt // max(1, self.r)).astype(np.int64)

    def uncovered_parts(self, owner: np.ndarray) -> np.ndarray:
        """(M,) bool — populated partitions no live shard serves."""
        return (owner < 0) & (self.partition_counts() > 0)

    def coverage_fraction(self, owner: np.ndarray) -> float:
        """Fraction of the segment's real rows that live in covered
        (owner ≥ 0) partitions under this view — 1.0 when healthy."""
        cnt = self.partition_counts()
        tot = int(cnt.sum())
        if tot == 0:
            return 1.0
        return float(cnt[owner >= 0].sum()) / tot

    def ensure_quant(self):
        """Per-shard int8 twins ``(codes, scales, eps)`` of the shard
        blocks (stacked on a leading shard axis), quantized per ``bn``
        tile like the single-device payload (`repro.quant.quantize`).
        Padding rows quantize to exact zeros (code 0, ε 0) and stay
        masked by liveness, so the per-shard ε bounds are sound for the
        rows that matter."""
        if self._quant is None:
            from repro.quant.quantize import quantize_rows
            qs = [quantize_rows(self.rows[j], self.bn)
                  for j in range(self.n_shards)]
            self._quant = (np.stack([q.q for q in qs]),
                           np.stack([q.scales for q in qs]),
                           np.stack([q.eps for q in qs]))
        return self._quant

    def nbytes_per_shard(self, *, quantized: bool = False) -> np.ndarray:
        """Resident row-payload bytes each shard holds — real rows (and
        their real tiles), not the uniform padding — mirroring what
        `SIndex.nbytes_resident` counts for the single-device payload.
        The spread across shards is the balance signal benches report."""
        dim = int(self.rows.shape[-1])
        rows = self.rows_per_shard.astype(np.int64)
        if not quantized:
            return rows * (4 * dim)
        tiles = -(-rows // self.bn)
        # int8 codes + one f32 scale per tile + one f16 ε per row
        return rows * dim + tiles * 4 + rows * 2


def as_float32_rows(x, *, what: str = "rows") -> np.ndarray:
    """Boundary cast for model-emitted hidden states.

    Serving models emit bfloat16/float16 activations (see
    `launch/serve.py`); the join engines are float32 end to end. This is
    the single place the cast happens: bf16/f16 (including jax arrays —
    ml_dtypes registers the numpy casts) and f64 become C-contiguous
    float32 in **one** ``astype`` — never a silent float64 round-trip —
    and non-float dtypes are rejected instead of being coerced.
    """
    x = np.asarray(x)
    if x.dtype == np.float32:
        return np.ascontiguousarray(x)
    if x.dtype.name not in ("float64", "float16", "bfloat16"):
        raise TypeError(
            f"{what} must be floating point (float32/float16/bfloat16), "
            f"got dtype {x.dtype}")
    return np.ascontiguousarray(x.astype(np.float32))


@dataclasses.dataclass
class SIndex:
    """Everything derivable from S alone — computed once, reused forever.

    The S rows are stored in pivot-sorted order (stable lexsort by
    (partition, pivot distance)): the subset of a sorted array is sorted,
    so per-group replica selection never re-sorts, and tiles cut from
    the packed rows are partition-coherent — the layout the pruned tile
    schedules (core.schedule) and the Pallas gather kernel rely on.
    """

    config: JoinConfig           # build-time knobs (k, metric, pivots, …)
    pivots: np.ndarray           # (M, dim)
    pivd: np.ndarray             # (M, M) true pivot-pivot distances
    s_part: np.ndarray           # (|S|,) partition id, original row order
    s_dist: np.ndarray           # (|S|,) |s, p(s)|, original row order
    t_s: SummaryTable            # counts / L / U / pivot-kNN lists (§4.2)
    s_order: np.ndarray          # (|S|,) sorted position -> original row
    s_sorted: np.ndarray         # (|S|, dim) rows in (part, dist) order
    s_part_sorted: np.ndarray    # (|S|,) int32
    s_dist_sorted: np.ndarray    # (|S|,) float32
    s_ids_sorted: np.ndarray     # (|S|,) int64 == s_order
    s_inv: np.ndarray            # (|S|,) original row -> sorted position
    _device_rows: object = dataclasses.field(
        default=None, repr=False, compare=False)
    _tile_stats: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _quant: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _shards: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def n_s(self) -> int:
        return int(self.s_part.shape[0])

    @property
    def dim(self) -> int:
        return int(self.pivots.shape[1])

    @property
    def n_pivots(self) -> int:
        return int(self.pivots.shape[0])

    def device_rows(self):
        """The packed pivot-sorted S rows as a device-resident jnp array
        (uploaded lazily, cached for the index's lifetime)."""
        if self._device_rows is None:
            import jax.numpy as jnp
            self._device_rows = jnp.asarray(self.s_sorted)
        return self._device_rows

    def tile_stats(self, bn: int):
        """Per-S-tile Thm-2 statistics ``(sd_min, sd_max, present)`` over
        the packed layout at tile size ``bn`` (see
        `core.schedule.segment_tile_stats`) — query-independent, computed
        once and cached for the index's lifetime. The device-resident
        megastep uploads these as constants so its in-jit schedule build
        touches only query-dependent math."""
        if bn not in self._tile_stats:
            from .schedule import segment_tile_stats
            self._tile_stats[bn] = segment_tile_stats(
                self.s_part_sorted, self.s_dist_sorted, self.n_pivots, bn)
        return self._tile_stats[bn]

    def ensure_quant(self, bn: Optional[int] = None):
        """The packed rows' int8 representation at tile size ``bn``
        (default ``config.tile_s``): per-tile symmetric codes + scales +
        per-row reconstruction-error bounds ε (`repro.quant.quantize`).
        Built lazily on first use, cached for the index's lifetime —
        segments are immutable, so seal/compact produce fresh indexes
        and thereby fresh quantizations (the invalidation story)."""
        bn = int(self.config.tile_s if bn is None else bn)
        if bn not in self._quant:
            from repro.quant.quantize import quantize_rows
            self._quant[bn] = quantize_rows(self.s_sorted, bn)
        return self._quant[bn]

    def shard_packing(self, n_shards: int, bn: Optional[int] = None, *,
                      r: int = 1) -> ShardPacking:
        """This segment's payload re-laid-out across ``n_shards`` mesh
        shards at tile size ``bn`` (default ``config.tile_s``): pivot
        groups → shards via §5 geometric grouping balanced by partition
        population, rows/ids/tile-stats per shard (see `ShardPacking`).
        With replication ``r > 1`` each pivot group additionally lands
        on ``r−1`` backup shards (clamped at ``n_shards``), placed
        heaviest-partition-first on the least-loaded shard not already
        holding it — the same balance-aware greedy shape as the §5
        grouping, bounded by Cor. 2's ``r·|S|`` total replicated rows.
        ``r=1`` is byte-identical to the unreplicated layout. Cached per
        ``(n_shards, bn, r)`` for the index's lifetime, like
        `tile_stats` / `ensure_quant` — segments are immutable."""
        bn = int(self.config.tile_s if bn is None else bn)
        n_shards = int(n_shards)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        r = int(r)
        if r < 1:
            raise ValueError(f"replication factor r must be >= 1, got {r}")
        r = min(r, n_shards)
        key = (n_shards, bn, r)
        if key not in self._shards:
            m = self.n_pivots
            # geometric_grouping rejects more groups than partitions —
            # clamp; surplus shards simply hold no partitions (their
            # tiles are never `present`, so schedules skip them)
            eff = min(n_shards, m)
            if eff == 1:
                shard_of_part = np.zeros((m,), np.int32)
            else:
                shard_of_part = np.ascontiguousarray(
                    G.geometric_grouping(self.pivd, self.t_s.counts, eff)
                    .astype(np.int32))
            replicas = np.zeros((r, m), np.int32)
            replicas[0] = shard_of_part
            if r > 1:
                pcount = self.t_s.counts.astype(np.int64)
                load = np.bincount(shard_of_part, weights=pcount,
                                   minlength=n_shards).astype(np.int64)
                order = np.argsort(-pcount, kind="stable")
                for c in range(1, r):
                    for p in order:
                        held = {int(x) for x in replicas[:c, p]}
                        j = min((s for s in range(n_shards)
                                 if s not in held),
                                key=lambda s: (load[s], s))
                        replicas[c, p] = j
                        load[j] += pcount[p]
            # shard j holds every copy of its partitions; boolean
            # selection keeps each block in (partition, dist) packed
            # order, so every replica is the same pivot-sorted slice
            holds = np.zeros((n_shards, m), bool)
            holds[replicas, np.arange(m)[None, :]] = True
            held_rows = holds[:, self.s_part_sorted]   # (n_shards, n_s)
            counts = held_rows.sum(axis=1)
            tiles = max(1, int(-(-counts.max() // bn)))
            rpad = tiles * bn
            rows = np.zeros((n_shards, rpad, self.dim), np.float32)
            gids = np.full((n_shards, rpad), -1, np.int64)
            part = np.full((n_shards, rpad), -1, np.int32)
            dist = np.zeros((n_shards, rpad), np.float32)
            for j in range(n_shards):
                sel = held_rows[j]
                nj = int(counts[j])
                rows[j, :nj] = self.s_sorted[sel]
                gids[j, :nj] = self.s_ids_sorted[sel]
                part[j, :nj] = self.s_part_sorted[sel]
                dist[j, :nj] = self.s_dist_sorted[sel]
            from .schedule import segment_tile_stats
            stats = [segment_tile_stats(part[j], dist[j], m, bn)
                     for j in range(n_shards)]
            self._shards[key] = ShardPacking(
                n_shards=n_shards, bn=bn, shard_of_part=shard_of_part,
                tiles_per_shard=tiles, rows=rows, gids_local=gids,
                part=part, dist=dist,
                rows_per_shard=counts.astype(np.int64),
                sd_min=np.stack([st[0] for st in stats]),
                sd_max=np.stack([st[1] for st in stats]),
                present=np.stack([st[2] for st in stats]),
                r=r, replicas_of_part=replicas)
        return self._shards[key]

    def nbytes_resident(self, *, quantized: Optional[bool] = None,
                        n_shards: Optional[int] = None) -> int:
        """Device-resident bytes of the index's **row payload**: the
        fp32 packed rows, or — quantized — the int8 codes + per-tile
        scales + per-row ε bounds. Mode-independent per-row metadata
        (global ids, liveness masks) is excluded: it is identical in
        both tiers, and this accessor exists to report what quantization
        buys (benchmarks report it as bytes/row). The default mode
        follows ``config.quantize`` alone — a lazily-built quantization
        (an explicit ``quantized=True`` query against an unquantized
        config) never flips what the bare call reports, and a
        ``MutableIndex`` sum stays single-mode across its segments.

        With ``n_shards`` set, reports what sharding buys instead: the
        **largest single shard's** row-payload bytes under the
        `shard_packing` layout — the number that must fit one device's
        HBM when the index runs sharded across a mesh."""
        if quantized is None:
            quantized = self.config.quantize != "none"
        if n_shards is not None and int(n_shards) > 0:
            sp = self.shard_packing(int(n_shards))
            return int(sp.nbytes_per_shard(quantized=quantized).max())
        if not quantized:
            return int(self.s_sorted.nbytes)
        return int(self.ensure_quant().nbytes())

    def replica_mask_sorted(self, lb_group: np.ndarray, g: int) -> np.ndarray:
        """Theorem 6 membership over the *sorted* row layout: which packed
        S rows ship to group ``g`` under a query plan's ``lb_group``."""
        return self.s_dist_sorted >= lb_group[self.s_part_sorted, g]

    def rows_for_ids(self, ids: np.ndarray) -> np.ndarray:
        """Gather S rows by original (global) row id from the packed
        layout; negative ids yield arbitrary rows (callers mask them)."""
        pos = self.s_inv[np.clip(ids, 0, self.n_s - 1)]
        return self.s_sorted[pos]


@dataclasses.dataclass
class QueryPlan:
    """Everything job 2 needs that depends on the query batch (paper
    §4.3/§5): assignment, θ, the LB matrices and the grouping. O(M²)
    host-resident — broadcast to every worker like the paper loads
    pivots into every mapper."""

    config: JoinConfig
    r_part: np.ndarray           # (|R|,)
    r_dist: np.ndarray           # (|R|,)
    t_r: SummaryTable
    theta: np.ndarray            # (M,)       Eq. 6 / Algorithm 1
    lb: np.ndarray               # (M_s, M_r) Cor. 2
    groups: np.ndarray           # (M,) group id per R-partition
    lb_group: np.ndarray         # (M_s, N)   Thm 6

    @property
    def n_r(self) -> int:
        return int(self.r_part.shape[0])

    @property
    def n_groups(self) -> int:
        return int(self.lb_group.shape[1])

    def group_of_r(self) -> np.ndarray:
        return self.groups[self.r_part]


def build_index(
    s: np.ndarray,
    config: Optional[JoinConfig] = None,
    *,
    pivot_data: Optional[np.ndarray] = None,
    pivots: Optional[np.ndarray] = None,
    pivot_strategy: Optional[str] = None,
    quantize: Optional[str] = None,
) -> SIndex:
    """S-side phase 1, once: pivot selection, Voronoi assignment, T_S,
    and the pivot-sorted row packing.

    ``pivot_data`` chooses where pivots are sampled from: the paper
    selects them from R, which a build-once index cannot see — the
    default samples from S instead (any pivot set is correct; only the
    pruning rate changes). The one-shot ``knn_join`` passes its R to
    reproduce the paper's preprocessing exactly. ``pivots`` overrides
    selection entirely (e.g. pivots recovered from a checkpoint).

    ``pivot_strategy`` overrides the config's §4.1 selection strategy
    ("random" | "farthest" | "kmeans") without hand-building a config.
    ``quantize="int8"`` additionally attaches the packed rows' int8
    representation (codes + scales + per-row ε, `repro.quant`) and
    stamps the mode into the index's config, so a ``MutableIndex``
    holding this index rebuilds the quantization on every seal/compact.
    ``s`` may arrive as bfloat16/float16 hidden states — cast once here
    (`as_float32_rows`), never silently widened to float64.
    """
    config = config or JoinConfig()
    if pivot_strategy is not None and pivot_strategy != config.pivot_strategy:
        config = dataclasses.replace(config, pivot_strategy=pivot_strategy)
    if quantize is not None and quantize != config.quantize:
        config = dataclasses.replace(config, quantize=quantize)
    s = as_float32_rows(s, what="S rows")
    if pivots is None:
        src = s if pivot_data is None else np.asarray(pivot_data)
        m = min(config.n_pivots, src.shape[0])
        pivots = select_pivots(
            src, m, config.pivot_strategy,
            sample=config.pivot_sample,
            n_sets=config.pivot_candidate_sets,
            seed=config.seed)
    else:
        pivots = np.ascontiguousarray(pivots, np.float32)
    # pack once: stable (partition, pivot distance) order — every engine
    # slices partition-coherent tiles out of this layout from now on.
    # The order comes out of the same fused jit as assignment + T_S
    # (one device round-trip per build/seal instead of three)
    s_part, s_dist, t_s, order = assign_and_summarize(
        s, pivots, k=config.k, metric=config.metric, return_order=True)
    pivd = B.pivot_distance_matrix(pivots, config.metric)
    inv = np.empty_like(order)
    inv[order] = np.arange(order.shape[0])
    index = SIndex(
        config=config, pivots=pivots, pivd=pivd,
        s_part=s_part, s_dist=s_dist, t_s=t_s,
        s_order=order,
        s_sorted=np.ascontiguousarray(s[order]),
        s_part_sorted=np.ascontiguousarray(s_part[order].astype(np.int32)),
        s_dist_sorted=np.ascontiguousarray(s_dist[order].astype(np.float32)),
        s_ids_sorted=order.astype(np.int64),
        s_inv=inv)
    if config.quantize == "int8":
        index.ensure_quant(config.tile_s)
    return index


def plan_queries(
    r: np.ndarray,
    index: SIndex,
    config: Optional[JoinConfig] = None,
) -> QueryPlan:
    """R-side planning for one query batch against a resident index.

    Assignment runs on the jitted jnp path (`partition.assign_to_pivots`),
    θ and the LB matrix on `bounds.theta_and_lb_jit` — one fused device
    computation per batch instead of the former blocked host loop.
    Grouping stays host-side: O(M²) scalar work with data-dependent
    control flow, negligible next to assignment.
    """
    config = config or index.config
    if config.metric != index.config.metric:
        raise ValueError(
            f"metric={config.metric!r} but the index was built with "
            f"{index.config.metric!r}; pivd/T_S bounds do not transfer "
            f"between metrics — rebuild the index")
    r = np.ascontiguousarray(r, np.float32)
    m = index.n_pivots
    if index.t_s.knn_dists is None:
        raise ValueError("index was built without T_S pivot-kNN lists")
    finite = int(np.isfinite(
        index.t_s.knn_dists[:, :config.k]).sum())
    if finite < config.k:
        raise ValueError(
            f"T_S holds {finite} finite candidates; need >= k={config.k} "
            f"(is |S| >= k?)")
    r_part, r_dist = assign_to_pivots(r, index.pivots, metric=config.metric)
    t_r = build_summary(r_part, r_dist, m)
    theta, lb = B.theta_and_lb(index.pivd, t_r, index.t_s, config.k)
    n_groups = min(config.n_groups, m)
    groups = G.group_partitions(
        config.grouping, index.pivd, t_r, n_groups, lb=lb, t_s=index.t_s)
    lb_group = B.group_lower_bounds(lb, groups, n_groups)
    return QueryPlan(
        config=config, r_part=r_part, r_dist=r_dist, t_r=t_r,
        theta=theta, lb=lb, groups=groups, lb_group=lb_group)
