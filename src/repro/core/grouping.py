"""Partition grouping strategies (paper §5) and the replication cost model.

Both strategies pack the M R-partitions into N groups (N = reducer count).
Geometric grouping (Algorithm 4) is distance-driven and load-balanced;
greedy grouping grows each group by the partition that minimizes the
*replication increment* RP(S, G ∪ {P}) − RP(S, G) under the Eq. 12
whole-partition approximation.

Cost model: RP(S) (Theorem 7) — the exact replica count needs every
|s, p_j| (Eq. 10); `replication_count_exact` computes it from phase-1
output, while `replication_count_partitions` is the Eq. 12 partition-level
approximation used by the greedy strategy (and by the runtime to size the
static shuffle buffers, see core/distributed.py).
"""
from __future__ import annotations

import numpy as np

from .types import SummaryTable

__all__ = [
    "geometric_grouping",
    "greedy_grouping",
    "group_partitions",
    "replication_count_exact",
    "replication_count_partitions",
]


def _seed_groups(pivd: np.ndarray, n_groups: int) -> list[int]:
    """Algorithm 4 lines 1-5: spread the N seed pivots far apart."""
    m = pivd.shape[0]
    first = int(np.argmax(pivd.sum(axis=1)))
    seeds = [first]
    acc = pivd[first].copy()
    for _ in range(1, n_groups):
        acc[seeds] = -np.inf
        nxt = int(np.argmax(acc))
        seeds.append(nxt)
        acc = np.where(np.isneginf(acc), acc, acc + pivd[nxt])
    return seeds


def geometric_grouping(
    pivd: np.ndarray, counts: np.ndarray, n_groups: int
) -> np.ndarray:
    """Algorithm 4. Returns (M,) int32 group id per R-partition.

    Iteratively gives the currently-smallest group (by object population,
    the paper's load-balancing device) its nearest unassigned pivot.
    """
    m = pivd.shape[0]
    if n_groups > m:
        raise ValueError(f"n_groups={n_groups} > n_pivots={m}")
    groups = np.full((m,), -1, np.int64)
    seeds = _seed_groups(pivd, n_groups)
    group_sizes = np.zeros((n_groups,), np.int64)
    # running sum of distance from each pivot to each group's member pivots
    dist_to_group = np.zeros((n_groups, m), np.float64)
    for g, s in enumerate(seeds):
        groups[s] = g
        group_sizes[g] += int(counts[s])
        dist_to_group[g] = pivd[s]
    unassigned = groups < 0
    while unassigned.any():
        g = int(np.argmin(group_sizes))
        cand = np.where(unassigned, dist_to_group[g], np.inf)
        p = int(np.argmin(cand))
        groups[p] = g
        group_sizes[g] += int(counts[p])
        dist_to_group[g] += pivd[p]
        unassigned[p] = False
    return groups.astype(np.int32)


def replication_count_partitions(
    lb_group: np.ndarray, t_s: SummaryTable
) -> np.ndarray:
    """Eq. 12 approximation: per group, count of S objects in partitions
    whose replication window is non-empty (whole partition counted).

    lb_group: (M_s, n_groups) from `group_lower_bounds`.
    Returns (n_groups,) int64.
    """
    hit = lb_group <= t_s.upper[:, None]                 # (M_s, G)
    hit &= (t_s.counts > 0)[:, None]
    return (hit * t_s.counts[:, None].astype(np.int64)).sum(axis=0)


def replication_count_exact(
    lb_group: np.ndarray, s_part: np.ndarray, s_dist: np.ndarray
) -> np.ndarray:
    """Theorem 7 exactly: |{s : |s,p_j| >= LB(P_j^S, G_g)}| per group."""
    n_groups = lb_group.shape[1]
    out = np.zeros((n_groups,), np.int64)
    thr = lb_group[s_part]                               # (n_s, G)
    out += (s_dist[:, None] >= thr).sum(axis=0)
    return out


def greedy_grouping(
    pivd: np.ndarray,
    counts: np.ndarray,
    n_groups: int,
    lb: np.ndarray,
    t_s: SummaryTable,
) -> np.ndarray:
    """§5.2.2 greedy grouping under the Eq. 12 approximation.

    Seeds like Algorithm 4, then repeatedly extends the smallest group with
    the unassigned partition whose addition brings in the fewest *new* S
    objects (whole-partition granularity).

    lb: (M_s, M_r) per-partition replication bounds (Cor. 2).
    """
    m = pivd.shape[0]
    if n_groups > m:
        raise ValueError(f"n_groups={n_groups} > n_pivots={m}")
    groups = np.full((m,), -1, np.int64)
    seeds = _seed_groups(pivd, n_groups)
    group_sizes = np.zeros((n_groups,), np.int64)
    # member[g, j] — is S-partition j already replicated to group g?
    member = np.zeros((n_groups, lb.shape[0]), bool)
    s_counts = t_s.counts.astype(np.int64)
    hit = lb <= t_s.upper[:, None]                       # (M_s, M_r): adding
    hit &= (t_s.counts > 0)[:, None]                     # partition i pulls j
    for g, s in enumerate(seeds):
        groups[s] = g
        group_sizes[g] += int(counts[s])
        member[g] = hit[:, s]
    unassigned = groups < 0
    while unassigned.any():
        g = int(np.argmin(group_sizes))
        # replication increment of adding partition i to group g
        new = hit & ~member[g][:, None]                  # (M_s, M_r)
        inc = (new * s_counts[:, None]).sum(axis=0)      # (M_r,)
        inc = np.where(unassigned, inc, np.iinfo(np.int64).max)
        p = int(np.argmin(inc))
        groups[p] = g
        group_sizes[g] += int(counts[p])
        member[g] |= hit[:, p]
        unassigned[p] = False
    return groups.astype(np.int32)


def group_partitions(
    strategy: str,
    pivd: np.ndarray,
    t_r: SummaryTable,
    n_groups: int,
    *,
    lb: np.ndarray | None = None,
    t_s: SummaryTable | None = None,
) -> np.ndarray:
    """Dispatch on the configured strategy. 'none' = 1 partition : 1 group
    (requires n_groups == M, the ungrouped §4 algorithm)."""
    if strategy == "none":
        return np.arange(t_r.n_partitions, dtype=np.int32) % n_groups
    if strategy == "geometric":
        return geometric_grouping(pivd, t_r.counts, n_groups)
    if strategy == "greedy":
        if lb is None or t_s is None:
            raise ValueError("greedy grouping needs lb and t_s")
        return greedy_grouping(pivd, t_r.counts, n_groups, lb, t_s)
    raise ValueError(f"unknown grouping {strategy!r}")
