"""Shared types for the PGBJ kNN-join core.

Conventions
-----------
* Datasets are dense float arrays of shape ``(n, dim)``.
* ``M`` is the number of pivots; partitions are indexed ``0..M-1``.
* All *bounds* (Theorems 1-6 of the paper) operate on true Euclidean
  distances, never squared distances — the triangle inequality the paper
  leans on does not survive squaring. Squared distances are used only
  inside dense tile computations where monotonicity suffices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class JoinConfig:
    """Configuration of one kNN-join execution (paper §4-§5 knobs)."""

    k: int = 10
    metric: str = "l2"              # l2 | l1 | linf  (paper §2.1)
    # §4.1 preprocessing
    n_pivots: int = 64
    pivot_strategy: str = "random"  # random | farthest | kmeans
    pivot_sample: int = 4096        # sample size for farthest/kmeans selection
    pivot_candidate_sets: int = 8   # T random sets for random selection
    # §5 grouping
    n_groups: int = 8
    grouping: str = "geometric"     # geometric | greedy | none
    # reducer engine
    tile_r: int = 128               # R rows per distance tile
    tile_s: int = 512               # S rows per distance tile
    use_tile_pruning: bool = True   # Cor. 1 / Thm 2 adapted to tile masking
    # auto → "pruned"/"dense" per use_tile_pruning; "gather" runs the
    # static compacted schedule (core.schedule) — the pruned-DMA path
    # (Pallas scalar-prefetch kernel on TPU, its host twin elsewhere)
    reducer: str = "auto"           # auto | dense | pruned | gather
    # streaming engine (core.stream): R micro-batch rows per plan+join
    # round; 0 = one-shot (whole query set in a single batch)
    batch_size: int = 0
    # quantized tier (repro.quant): "int8" attaches per-tile symmetric
    # int8 codes + per-row error bounds ε to every built index / sealed
    # segment, and routes knn_join(quantized=True) & friends through the
    # two-tier coarse-scan → exact-re-rank engine (L2 only, results
    # bitwise the fp32 oracle's)
    quantize: str = "none"          # none | int8
    # coarse shortlist over-fetch: k + quant_slack candidates survive
    # the int8 pass into the exact fp32 re-rank (rounded up to a power
    # of two); -1 = auto (shortlist max(pow2(4k), 128)). Smaller slack =
    # cheaper re-rank but more certification failures falling back to
    # the host oracle (exactness is unconditional either way).
    quant_slack: int = -1
    seed: int = 0

    def __post_init__(self):
        if self.pivot_strategy not in ("random", "farthest", "kmeans"):
            raise ValueError(f"unknown pivot strategy {self.pivot_strategy!r}")
        if self.grouping not in ("geometric", "greedy", "none"):
            raise ValueError(f"unknown grouping {self.grouping!r}")
        if self.reducer not in ("auto", "dense", "pruned", "gather"):
            raise ValueError(f"unknown reducer {self.reducer!r}")
        if self.k < 1:
            raise ValueError("k must be >= 1")
        if self.batch_size < 0:
            raise ValueError("batch_size must be >= 0")
        if self.metric not in ("l2", "l1", "linf"):
            raise ValueError(f"unknown metric {self.metric!r}")
        if self.quantize not in ("none", "int8"):
            raise ValueError(f"unknown quantize mode {self.quantize!r}")
        if self.quantize != "none" and self.metric != "l2":
            raise ValueError(
                f"quantize={self.quantize!r} requires metric='l2' (the "
                f"int8 coarse kernel is the Euclidean lowering); got "
                f"{self.metric!r} — drop quantize or use the fp32 host "
                f"engines")
        if self.quant_slack < -1:
            raise ValueError("quant_slack must be >= 0, or -1 for auto")

    @property
    def resolved_reducer(self) -> str:
        """The engine "auto" selects (back-compat with use_tile_pruning)."""
        if self.reducer != "auto":
            return self.reducer
        return "pruned" if self.use_tile_pruning else "dense"


@dataclasses.dataclass
class SummaryTable:
    """Per-partition statistics — the paper's summary tables T_R / T_S (§4.2).

    Attributes
    ----------
    counts:    (M,) int32   — |P_i|
    lower:     (M,) float32 — L(P_i) = min object->pivot distance (+inf if empty)
    upper:     (M,) float32 — U(P_i) = max object->pivot distance (0 if empty)
    knn_dists: (M, k) float32 or None — for T_S only: |p_i, o| of the k
               objects of P_i^S nearest to p_i, ascending, padded with +inf.
               (``p_i.d_j`` in the paper's Figure 3.)
    """

    counts: np.ndarray
    lower: np.ndarray
    upper: np.ndarray
    knn_dists: Optional[np.ndarray] = None

    @property
    def n_partitions(self) -> int:
        return int(self.counts.shape[0])


@dataclasses.dataclass
class JoinStats:
    """Instrumentation mirroring the paper's reported metrics (§6)."""

    n_r: int = 0
    n_s: int = 0
    # shuffling cost:  |R| + sum of replicas of S  (paper §3)
    replicas_s: int = 0
    # of object pairs whose distance was actually computed (Eq. 13 numerator)
    pairs_computed: int = 0
    # pivot-distance computations (included in selectivity per paper §6)
    pivot_pairs_computed: int = 0
    # tile bookkeeping for the TPU-adapted engine
    tiles_total: int = 0
    tiles_visited: int = 0
    # streaming engine: planned+joined R micro-batches (0 = one-shot path)
    n_batches: int = 0
    # sharded megastep (core.sharded): mesh shards the batch fanned over
    # (0 = single-device path)
    n_shards: int = 0
    # mutable segmented index (core.segments): live segments fanned over
    # at query time (sealed deltas + write buffer), tombstoned rows
    # masked during the merge, and total time spent in compact()
    n_segments: int = 0
    n_tombstones: int = 0
    compact_time_s: float = 0.0
    # quantized tier (repro.quant): queries whose coarse-pass
    # certification failed and re-ran through the fp32 host oracle
    # (exactness is unconditional; this counts how often the int8
    # shortlist alone could not prove it)
    n_quant_fallback: int = 0
    # quantized-tier routing decisions (repro.quant.engine /
    # repro.quant.autotune): the mode the engine resolved ("int8" two-tier
    # or "fp32" tuned fallback; "" when no quant engine ran), whether a
    # tuning-table entry drove it, the shortlist size in force, and how
    # many queries each exact-re-rank variant handled — the fused
    # device-resident gather vs the low-memory host-gather round-trip
    quant_mode: str = ""
    quant_autotuned: bool = False
    quant_mp: int = 0
    n_resident_rerank: int = 0
    n_host_rerank: int = 0
    # serving degradation (serve.scheduler): queries answered by the
    # certified-approximate coarse-only path instead of the exact
    # engine, and the minimum per-query certified recall lower bound
    # across them (1.0 when nothing degraded — the exact paths always
    # have recall 1)
    n_degraded: int = 0
    recall_bound: float = 1.0
    # sharded failover (core.sharded): shards the serving view currently
    # marks failed, and the certified fraction of resident rows still in
    # covered pivot groups (1.0 = every populated group has a live
    # replica; < 1.0 only on the no-replica degraded-coverage path, in
    # which case recall_bound above carries the per-batch minimum of the
    # sound per-query certificates)
    n_failed_shards: int = 0
    coverage_bound: float = 1.0

    def merged(self, other: "JoinStats") -> "JoinStats":
        """Fold ``other`` (a later attempt / retried / failed-over batch
        of the same serving stream) into a new aggregate — the fix for
        stats from retries silently overwriting each other when one
        shared ``JoinStats`` is threaded through every engine call.

        Per-field semantics:

        * **counters sum** — ``n_r``, ``replicas_s``,
          ``pairs_computed``/``pivot_pairs_computed``,
          ``tiles_total``/``tiles_visited``, ``n_batches``,
          ``n_quant_fallback``, ``n_resident_rerank``/``n_host_rerank``,
          ``n_degraded``, and the ``compact_time_s`` accumulator
          (selectivity/tile-selectivity stay meaningful as
          work-weighted aggregates);
        * **sizes keep the max** — ``n_s`` is the S side every attempt
          joined against, not work performed: summing it across retries
          of the *same* index would deflate the aggregate selectivity
          (Σpairs / (Σn_r · max n_s) is the work-weighted mean);
        * **degradation keeps the worst** — ``recall_bound`` and
          ``coverage_bound`` take the min (a sound bound for the union
          of answers is the worst per-batch bound),
          ``n_failed_shards`` the max (it is a view size, not a rate);
        * **routing fields keep the last writer** — ``quant_mode`` /
          ``quant_autotuned`` / ``quant_mp`` describe which engine the
          *most recent* batch ran on, ``n_shards`` the mesh it ran
          over, ``n_segments``/``n_tombstones`` the index snapshot it
          saw; ``other`` wins whenever it actually stamped them.
        """
        out = JoinStats(
            n_r=self.n_r + other.n_r,
            n_s=max(self.n_s, other.n_s),
            replicas_s=self.replicas_s + other.replicas_s,
            pairs_computed=self.pairs_computed + other.pairs_computed,
            pivot_pairs_computed=(self.pivot_pairs_computed
                                  + other.pivot_pairs_computed),
            tiles_total=self.tiles_total + other.tiles_total,
            tiles_visited=self.tiles_visited + other.tiles_visited,
            n_batches=self.n_batches + other.n_batches,
            compact_time_s=self.compact_time_s + other.compact_time_s,
            n_quant_fallback=(self.n_quant_fallback
                              + other.n_quant_fallback),
            n_resident_rerank=(self.n_resident_rerank
                               + other.n_resident_rerank),
            n_host_rerank=self.n_host_rerank + other.n_host_rerank,
            n_degraded=self.n_degraded + other.n_degraded,
            recall_bound=min(self.recall_bound, other.recall_bound),
            coverage_bound=min(self.coverage_bound, other.coverage_bound),
            n_failed_shards=max(self.n_failed_shards,
                                other.n_failed_shards),
            n_shards=other.n_shards or self.n_shards,
        )
        # quant routing: the trio travels together (autotuned=False is a
        # meaningful stamp once a mode is set)
        if other.quant_mode:
            out.quant_mode = other.quant_mode
            out.quant_autotuned = other.quant_autotuned
            out.quant_mp = other.quant_mp
        else:
            out.quant_mode = self.quant_mode
            out.quant_autotuned = self.quant_autotuned
            out.quant_mp = self.quant_mp
        # index snapshot: tombstones ride with the segment count (0
        # tombstones under live segments is a real observation)
        if other.n_segments:
            out.n_segments = other.n_segments
            out.n_tombstones = other.n_tombstones
        else:
            out.n_segments = self.n_segments
            out.n_tombstones = self.n_tombstones
        return out

    @property
    def selectivity(self) -> float:
        """Computation selectivity, Eq. 13 (pivot distances included)."""
        denom = float(self.n_r) * float(self.n_s)
        if denom == 0:
            return 0.0
        return (self.pairs_computed + self.pivot_pairs_computed) / denom

    @property
    def shuffle_tuples(self) -> int:
        return self.n_r + self.replicas_s

    @property
    def tile_selectivity(self) -> float:
        if self.tiles_total == 0:
            return 0.0
        return self.tiles_visited / self.tiles_total


@dataclasses.dataclass
class JoinResult:
    """kNN-join output:  indices into S and distances, per object of R.

    Indices are **int64** (every engine returns int64; segment-offset
    ids from the mutable index overflow int32 by design): row ids into
    S for a static ``SIndex``, global segment-offset ids for a
    ``core.segments.MutableIndex`` (stable until ``compact``). ``-1``
    marks padding slots (fewer than k live candidates), always paired
    with a ``+inf`` distance.
    """

    indices: np.ndarray    # (|R|, k) int64 — row ids into S, by ascending distance
    distances: np.ndarray  # (|R|, k) float32 — true (non-squared) distances
    stats: JoinStats
