"""The reducer-side kNN join (paper Algorithm 3) — tile-adapted.

``join_group`` is the group executor: it consumes the split planner's
``(SIndex, QueryPlan)`` pair — replica selection slices the index's
pivot-sorted packing, so no per-group sort runs — and dispatches to one
of three engines, all exact:

* ``join_group_dense`` — blocked brute force between R_g and the shipped
  S_g. Correct because Cor. 2 guarantees S_g ⊇ KNN(r, S) for r ∈ R_g.
  This is what the dense Pallas kernel implements on TPU (repro.kernels).

* ``join_group_pruned`` — the paper's Algorithm 3 adapted from per-object
  branching to per-tile masking: per R-partition, S-partitions are visited
  in ascending pivot distance (line 14), Corollary 1 (hyperplane) skips
  whole partitions per query, Theorem 2 (ring) masks candidates inside a
  tile, and θ tightens *between tiles* from the running top-k (the block
  analogue of lines 18-24). Selectivity instrumentation mirrors Eq. 13.

* ``join_group_gather`` — the static-schedule engine: walks exactly the
  compacted visit list `core.schedule.build_tile_schedule` lowered from
  the same bounds. This is the host twin of the scalar-prefetch Pallas
  kernel (``distance_topk_gather``): same schedule, same visited tiles,
  same result — so `JoinStats.tiles_visited` is comparable across CPU
  and TPU runs.

Host numpy orchestrates the tile schedules (value-dependent skipping has
no static-shape analogue); the arithmetic inside a tile is the same
``‖r‖² − 2rsᵀ + ‖s‖²`` contraction the TPU kernel uses.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .bounds import pad_theta
from .metrics import cmp_dist, from_cmp
from .types import JoinStats

__all__ = ["join_group", "join_group_dense", "join_group_pruned",
           "join_group_gather", "topk_merge"]

_INF = np.float32(np.inf)


def topk_merge(
    best_d: np.ndarray, best_i: np.ndarray,
    new_d: np.ndarray, new_i: np.ndarray, k: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Merge running (nq, k) top-k with a (nq, t) tile; ascending by dist."""
    cat_d = np.concatenate([best_d, new_d], axis=1)
    cat_i = np.concatenate([best_i, new_i], axis=1)
    if cat_d.shape[1] > k:
        part = np.argpartition(cat_d, k - 1, axis=1)[:, :k]
        cat_d = np.take_along_axis(cat_d, part, axis=1)
        cat_i = np.take_along_axis(cat_i, part, axis=1)
    order = np.argsort(cat_d, axis=1, kind="stable")
    return (np.take_along_axis(cat_d, order, axis=1),
            np.take_along_axis(cat_i, order, axis=1))


def join_group_dense(
    r: np.ndarray, s: np.ndarray, s_ids: np.ndarray, k: int,
    *, tile_r: int = 128, tile_s: int = 512,
    stats: Optional[JoinStats] = None, metric: str = "l2",
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact blocked brute-force top-k of each r over the shipped s."""
    nq, ns = r.shape[0], s.shape[0]
    if ns < k:
        raise ValueError(f"group received {ns} S objects < k={k}")
    out_d = np.full((nq, k), _INF, np.float32)
    out_i = np.full((nq, k), -1, np.int64)
    for qlo in range(0, nq, tile_r):
        qhi = min(qlo + tile_r, nq)
        bd = np.full((qhi - qlo, k), _INF, np.float32)
        bi = np.full((qhi - qlo, k), -1, np.int64)
        for slo in range(0, ns, tile_s):
            shi = min(slo + tile_s, ns)
            d2 = cmp_dist(r[qlo:qhi], s[slo:shi], metric)
            bd, bi = topk_merge(bd, bi, d2,
                                np.broadcast_to(s_ids[slo:shi], d2.shape), k)
            if stats is not None:
                stats.pairs_computed += d2.size
                stats.tiles_total += 1
                stats.tiles_visited += 1
        out_d[qlo:qhi] = bd
        out_i[qlo:qhi] = bi
    return from_cmp(out_d, metric), out_i


def join_group_gather(
    r: np.ndarray, s: np.ndarray, s_ids: np.ndarray, k: int,
    sched,
    *, stats: Optional[JoinStats] = None, metric: str = "l2",
) -> Tuple[np.ndarray, np.ndarray]:
    """Walk a precompiled `core.schedule.TileSchedule` — exact top-k over
    exactly the scheduled (R tile, S tile) pairs, nothing else touched.

    ``s``/``s_ids`` must be in the layout the schedule was built for
    (sorted by (partition, pivot distance) for tight tiles).
    """
    nq, ns = r.shape[0], s.shape[0]
    bm, bn = sched.bm, sched.bn
    out_d = np.full((nq, k), _INF, np.float32)
    out_i = np.full((nq, k), -1, np.int64)
    for t in range(sched.nr_tiles):
        qlo, qhi = t * bm, min((t + 1) * bm, nq)
        if qlo >= qhi:
            continue
        bd = np.full((qhi - qlo, k), _INF, np.float32)
        bi = np.full((qhi - qlo, k), -1, np.int64)
        for j in sched.schedule[t, :sched.counts[t]]:
            slo, shi = int(j) * bn, min((int(j) + 1) * bn, ns)
            if slo >= shi:
                continue
            d2 = cmp_dist(r[qlo:qhi], s[slo:shi], metric)
            bd, bi = topk_merge(
                bd, bi, d2, np.broadcast_to(s_ids[slo:shi], d2.shape), k)
            if stats is not None:
                stats.pairs_computed += d2.size
        out_d[qlo:qhi] = from_cmp(bd, metric)
        out_i[qlo:qhi] = bi
    if stats is not None:
        stats.tiles_total += sched.nr_tiles * sched.ns_tiles
        stats.tiles_visited += sched.n_visits
    return out_d, out_i


def join_group(
    g: int,
    r: np.ndarray,
    r_sel: np.ndarray,
    index,
    qplan,
    *,
    stats: Optional[JoinStats] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """One reducer group through the configured engine, consuming the
    build-once ``SIndex`` + per-batch ``QueryPlan`` pair.

    The group's S replicas are sliced from the index's pivot-sorted
    packing (a masked subset of a sorted array is sorted), so no
    per-group lexsort runs — the schedule/gather engines get their
    partition-coherent layout for free. Returns (dists, ids) rows
    aligned with ``r_sel``.
    """
    cfg = qplan.config
    k = cfg.k
    mask = index.replica_mask_sorted(qplan.lb_group, g)
    if stats is not None:
        stats.replicas_s += int(mask.sum())
    ss = index.s_sorted[mask]
    sp = index.s_part_sorted[mask]
    sd = index.s_dist_sorted[mask]
    sids = index.s_ids_sorted[mask]
    reducer = cfg.resolved_reducer
    if reducer == "gather":
        return _join_group_gather_scheduled(
            r, r_sel, ss, sp, sd, sids, index, qplan, cfg, stats)
    if reducer == "pruned":
        return join_group_pruned(
            r[r_sel], qplan.r_part[r_sel], ss, sp, sd, sids,
            index.pivots, index.pivd, qplan.theta,
            index.t_s.lower, index.t_s.upper, k,
            tile_r=cfg.tile_r, tile_s=cfg.tile_s, stats=stats,
            metric=cfg.metric)
    return join_group_dense(
        r[r_sel], ss, sids, k,
        tile_r=cfg.tile_r, tile_s=cfg.tile_s, stats=stats,
        metric=cfg.metric)


def _join_group_gather_scheduled(r, r_sel, ss, sp, sd, sids, index, qplan,
                                 cfg, stats):
    """One group through the pruned-schedule path.

    Queries are sorted by home partition (the S side arrives already
    pivot-sorted from the index packing) so tiles are partition-coherent
    — that layout is what makes the tile-granular ring bounds bite. On
    TPU the compacted schedule feeds the scalar-prefetch Pallas kernel
    (pruned tiles never DMA); elsewhere its host twin walks the
    identical schedule.
    """
    from .schedule import schedule_for_group

    k = cfg.k
    order_r = np.argsort(qplan.r_part[r_sel], kind="stable")
    rr = np.ascontiguousarray(r[r_sel][order_r])
    rp = qplan.r_part[r_sel][order_r]

    sched = schedule_for_group(index, qplan, rr, rp, sp, sd, stats=stats)

    from repro.kernels import ops
    if cfg.metric == "l2" and ops.use_pallas():
        import jax.numpy as jnp
        d, i_local = ops.distance_topk(
            jnp.asarray(rr), jnp.asarray(ss), k,
            schedule=jnp.asarray(sched.schedule),
            counts=jnp.asarray(sched.counts),
            bm=cfg.tile_r, bn=cfg.tile_s, impl="gather")
        gd = np.asarray(d)
        il = np.asarray(i_local)
        gi = np.where(il >= 0, sids[np.clip(il, 0, len(sids) - 1)], -1)
        if stats is not None:
            stats.tiles_total += sched.nr_tiles * sched.ns_tiles
            stats.tiles_visited += sched.n_visits
            stats.pairs_computed += sched.n_visits * cfg.tile_r * cfg.tile_s
    else:
        gd, gi = join_group_gather(
            rr, ss, sids, k, sched, stats=stats, metric=cfg.metric)
    # undo the query sort
    inv = np.empty_like(order_r)
    inv[order_r] = np.arange(order_r.size)
    return gd[inv], gi[inv]


def join_group_pruned(
    r: np.ndarray,
    r_part: np.ndarray,
    s: np.ndarray,
    s_part: np.ndarray,
    s_dist: np.ndarray,
    s_ids: np.ndarray,
    pivots: np.ndarray,
    pivd: np.ndarray,
    theta: np.ndarray,
    t_s_lower: np.ndarray,
    t_s_upper: np.ndarray,
    k: int,
    *,
    tile_r: int = 128,
    tile_s: int = 512,
    stats: Optional[JoinStats] = None,
    metric: str = "l2",
) -> Tuple[np.ndarray, np.ndarray]:
    """Algorithm 3 (lines 13-25), tile-masked. Returns (dists, ids) in the
    order of ``r``.

    Parameters mirror what a reducer holds: its R rows (+ their home
    partitions), the shipped S rows (+ partitions, pivot distances, global
    ids), and the summary-table columns it needs.
    """
    nq = r.shape[0]
    out_d = np.full((nq, k), _INF, np.float32)
    out_i = np.full((nq, k), -1, np.int64)
    if nq == 0:
        return out_d, out_i

    # organize shipped S by partition (the reducer's "parse S_i" — line 13)
    s_order = np.argsort(s_part, kind="stable")
    s = s[s_order]; s_part = s_part[s_order]
    s_dist = s_dist[s_order]; s_ids = s_ids[s_order]
    uniq_sp, sp_start = np.unique(s_part, return_index=True)
    sp_end = np.append(sp_start[1:], s_part.shape[0])

    for pi in np.unique(r_part):
        q_sel = np.where(r_part == pi)[0]
        q = r[q_sel]
        # line 14: visit S partitions ascending |p_i, p_j|
        order = np.argsort(pivd[pi, uniq_sp], kind="stable")
        # per-query state
        th = np.full((q.shape[0],), theta[pi], np.float32)
        bd = np.full((q.shape[0], k), _INF, np.float32)
        bi = np.full((q.shape[0], k), -1, np.int64)
        # |q, p_j| for candidate partitions, needed by Cor. 1 and Thm 2
        qp = from_cmp(cmp_dist(q, pivots[uniq_sp], metric), metric)
        if stats is not None:
            stats.pivot_pairs_computed += qp.size
        d_home = from_cmp(cmp_dist(q, pivots[pi:pi + 1], metric),
                          metric)[:, 0]
        for jj in order:
            j = uniq_sp[jj]
            lo_j, hi_j = sp_start[jj], sp_end[jj]
            # Corollary 1 per query: d(q, HP(p_i, p_j)) > θ ⇒ skip partition
            # (the generalized-hyperplane formula Thm 1 is Euclidean-only;
            # for L1/L∞ only the metric-generic ring test applies)
            thp = pad_theta(th)      # ulp-robust at exact-θ neighbors
            if j == pi or metric != "l2":
                alive = np.ones((q.shape[0],), bool)
            else:
                denom = 2.0 * pivd[pi, j]
                d_hp = (qp[:, jj] ** 2 - d_home ** 2) / max(denom, 1e-30)
                alive = d_hp <= thp
            if not alive.any():
                if stats is not None:
                    stats.tiles_total += int(np.ceil((hi_j - lo_j) / tile_s))
                continue
            # Theorem 2 interval for this partition
            ring_lo = np.maximum(t_s_lower[j], qp[:, jj] - thp)
            ring_hi = np.minimum(t_s_upper[j], qp[:, jj] + thp)
            for slo in range(lo_j, hi_j, tile_s):
                shi = min(slo + tile_s, hi_j)
                if stats is not None:
                    stats.tiles_total += 1
                sd = s_dist[slo:shi]
                mask = (alive[:, None]
                        & (sd[None, :] >= ring_lo[:, None])
                        & (sd[None, :] <= ring_hi[:, None]))
                if not mask.any():
                    continue
                if stats is not None:
                    stats.tiles_visited += 1
                    stats.pairs_computed += int(mask.sum())
                d2 = cmp_dist(q, s[slo:shi], metric)
                d2 = np.where(mask, d2, _INF)
                bd, bi = topk_merge(
                    bd, bi, d2, np.broadcast_to(s_ids[slo:shi], d2.shape), k)
                # θ tightens between tiles (block analogue of lines 22-24)
                kth = from_cmp(bd[:, k - 1], metric)
                th = np.minimum(th, kth)
                thp = pad_theta(th)
                ring_lo = np.maximum(t_s_lower[j], qp[:, jj] - thp)
                ring_hi = np.minimum(t_s_upper[j], qp[:, jj] + thp)
        out_d[q_sel] = from_cmp(bd, metric)
        out_i[q_sel] = bi
    return out_d, out_i
