"""Static pruned tile schedules — the plan's bounds lowered to DMA level.

The paper's pruning rules (Cor. 1 hyperplane, Thm 2 ring) cut both
*computational* and *shuffling* cost. On a TPU the second half only
materializes if a pruned tile never crosses HBM→VMEM: a visit *mask*
elides compute but the pipelined copy still streams. This module lowers
the plan's bounds, evaluated at R-tile × S-tile granularity, into a
**compacted visit list** — a dense ``(nr_tiles, max_visits)`` int32
schedule plus per-row counts — that the scalar-prefetch kernel
(`kernels.distance_topk.distance_topk_gather_pallas`) and the
schedule-driven ``lax.scan`` reducer (`core.distributed`) consume
directly. Skipped tiles cost zero bytes and zero FLOPs.

Tile-granular bound evaluation (exactness argument):

* Cor. 1 — an S-partition j is skipped for an R tile only when *every*
  query q in the tile has ``d(q, HP(p_home(q), p_j)) > θ_home(q)``
  (Euclidean metric only, as in Algorithm 3).
* Thm 2 — per (R tile, partition) the ring ``[min_q |q,p_j| − θ,
  max_q |q,p_j| + θ]`` over the tile's un-pruned queries is intersected
  with each S tile's actual ``|p_j, s|`` range. A tile is visited iff any
  partition present in it overlaps.

Both reductions take the loosest bound over the tile's queries, so the
scheduled candidate set is a superset of the per-query Algorithm-3 set —
the join stays exact, θ is just not adaptively tightened (the schedule is
static; it must be, to be prefetchable).

Rows with ``part < 0`` (shuffle-padding slots in the distributed path)
contribute no constraints on the R side and are never candidates on the
S side. Schedule rows are padded by repeating their last entry: an
unchanged block index lets the Pallas pipeline reuse the resident block
instead of issuing a fresh DMA.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .bounds import pad_theta
from .metrics import cmp_dist, from_cmp
from .types import JoinStats

__all__ = ["TileSchedule", "build_tile_schedule", "compact_visit_mask",
           "schedule_for_group", "segment_tile_stats", "visit_mask_jnp",
           "compact_visits_jnp"]


def segment_tile_stats(
    s_part_sorted: np.ndarray, s_dist_sorted: np.ndarray, m: int, bn: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-S-tile Thm-2 statistics, precomputed once per index upload.

    Returns ``(sd_min, sd_max, present)`` of shape (ns_tiles, M): the
    min/max ``|p_j, s|`` over each tile's rows of partition j and whether
    partition j has any row in the tile. A pure function of the packed S
    layout — query-independent, so the device-resident megastep receives
    it as a constant instead of recomputing it per batch.
    """
    n_s = int(s_part_sorted.shape[0])
    ns_tiles = max(1, -(-n_s // bn))
    sd_min = np.full((ns_tiles, m), np.inf, np.float32)
    sd_max = np.full((ns_tiles, m), -np.inf, np.float32)
    valid = s_part_sorted >= 0
    tile_of_s = (np.arange(n_s) // bn).astype(np.int64)
    idx = (tile_of_s[valid], s_part_sorted[valid])
    np.minimum.at(sd_min, idx, s_dist_sorted[valid].astype(np.float32))
    np.maximum.at(sd_max, idx, s_dist_sorted[valid].astype(np.float32))
    present = sd_max > -np.inf
    return sd_min, sd_max, present


def visit_mask_jnp(qp, home, th_q, valid_q, pivd,
                   sd_min, sd_max, present, *, bm: int, metric: str = "l2"):
    """Cor. 1 + Thm 2 lowered to jnp for one segment — the host
    ``build_tile_schedule`` bound evaluation as a traced graph, so the
    megastep computes its schedule under the same jit as the kernel.

    ``qp`` (B, M) true query→pivot distances, ``home`` (B,) int32,
    ``th_q`` (B,) per-query kNN radius bound (−inf for padding rows),
    ``valid_q`` (B,) bool; ``sd_min``/``sd_max``/``present`` from
    :func:`segment_tile_stats`. B must be a multiple of ``bm``. Returns a
    (B // bm, ns_tiles) bool visit mask. Tile reductions take the loosest
    bound over the tile's valid queries, exactly like the host builder —
    the scheduled candidate set is a superset of the per-query set, so
    the join stays exact.
    """
    import jax.numpy as jnp

    b, m = qp.shape
    nr_tiles = b // bm
    home_c = jnp.clip(home, 0, m - 1)
    # prune against the ulp-padded θ (bounds.pad_theta): qp and th_q come
    # from different fp graphs, and neighbors at exactly θ must survive
    thp = pad_theta(th_q)
    if metric == "l2":
        q2 = qp.astype(jnp.float32) ** 2
        home_sq = jnp.take_along_axis(q2, home_c[:, None], axis=1)
        denom = jnp.maximum(2.0 * pivd[home_c], 1e-30)
        d_hp = (q2 - home_sq) / denom
        alive = d_hp <= thp[:, None]
    else:
        alive = jnp.ones((b, m), bool)
    alive = alive.at[jnp.arange(b), home_c].set(True)
    alive = alive & valid_q[:, None]

    alive_t = alive.reshape(nr_tiles, bm, m).any(axis=1)
    lo_q = jnp.where(alive, qp - thp[:, None], jnp.inf)
    hi_q = jnp.where(alive, qp + thp[:, None], -jnp.inf)
    lo_t = lo_q.reshape(nr_tiles, bm, m).min(axis=1)
    hi_t = hi_q.reshape(nr_tiles, bm, m).max(axis=1)

    overlap = (alive_t[:, None, :] & present[None, :, :]
               & (sd_max[None, :, :] >= lo_t[:, None, :])
               & (sd_min[None, :, :] <= hi_t[:, None, :]))
    return overlap.any(axis=2)


def compact_visits_jnp(visit):
    """(nr_tiles, T) bool → prefix-compacted (schedule, counts) in jnp:
    the `compact_visit_mask` lowering — segment-sum ranks (a cumulative
    sum along the tile axis) plus a flat scatter, all static shapes.

    Rows with zero visits get one fallback visit of tile 0 so every R
    tile's output flush runs (the host builder's fallback rule). Padding
    slots repeat the row's last valid entry, so the scalar-prefetched
    block index never changes on dead steps and the Pallas pipeline
    reuses the resident block instead of issuing a fresh DMA.
    """
    import jax.numpy as jnp

    nr_tiles, t = visit.shape
    empty = ~visit.any(axis=1)
    visit = visit.at[:, 0].set(visit[:, 0] | empty)
    counts = visit.sum(axis=1).astype(jnp.int32)
    rank = jnp.cumsum(visit.astype(jnp.int32), axis=1) - 1
    # flat scatter into one spare trash column for unvisited tiles
    pos = jnp.where(visit, rank, t)
    row = jnp.broadcast_to(jnp.arange(nr_tiles)[:, None], (nr_tiles, t))
    tile = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None, :],
                            (nr_tiles, t))
    sched = jnp.zeros((nr_tiles, t + 1), jnp.int32)
    sched = sched.at[row, pos].set(tile)[:, :t]
    last = jnp.take_along_axis(sched, (counts - 1)[:, None], axis=1)
    slot = jnp.arange(t, dtype=jnp.int32)[None, :]
    sched = jnp.where(slot < counts[:, None], sched, last)
    return sched, counts


def schedule_for_group(
    index, qplan, rr: np.ndarray, rp: np.ndarray,
    sp: np.ndarray, sd: np.ndarray, *,
    stats: Optional["JoinStats"] = None,
) -> "TileSchedule":
    """`build_tile_schedule` driven by the split planner: the build-once
    ``SIndex`` supplies the geometry (pivots, ``pivd``, T_S pivot-kNN
    lists), the per-batch ``QueryPlan`` supplies θ and the tile sizes.
    ``rr``/``rp`` are the group's queries in kernel layout; ``sp``/``sd``
    the group's S replicas (already pivot-sorted via the index packing).
    """
    cfg = qplan.config
    return build_tile_schedule(
        rr, rp, sp, sd, index.pivots, index.pivd, qplan.theta,
        bm=cfg.tile_r, bn=cfg.tile_s, metric=cfg.metric,
        knn_dists=index.t_s.knn_dists, k=cfg.k, stats=stats)


@dataclasses.dataclass
class TileSchedule:
    """Compacted per-R-tile visit list over S tiles."""

    schedule: np.ndarray    # (nr_tiles, max_visits) int32, pad = last entry
    counts: np.ndarray      # (nr_tiles,) int32, >= 1
    visit_mask: np.ndarray  # (nr_tiles, ns_tiles) bool — the dense view
    bm: int
    bn: int

    @property
    def nr_tiles(self) -> int:
        return int(self.visit_mask.shape[0])

    @property
    def ns_tiles(self) -> int:
        return int(self.visit_mask.shape[1])

    @property
    def n_visits(self) -> int:
        """Total scheduled (R tile, S tile) steps — the schedule length."""
        return int(self.counts.sum())

    @property
    def density(self) -> float:
        """Visited fraction of the dense grid (1.0 = no pruning)."""
        total = self.nr_tiles * self.ns_tiles
        return self.n_visits / total if total else 0.0

    def padded_to(self, max_visits: int) -> "TileSchedule":
        """Widen the schedule to ``max_visits`` slots (repeat-last pad) —
        used to equalize static shapes across devices."""
        cur = self.schedule.shape[1]
        if max_visits < cur:
            raise ValueError(f"cannot shrink schedule {cur} -> {max_visits}")
        if max_visits == cur:
            return self
        pad = np.repeat(self.schedule[:, -1:], max_visits - cur, axis=1)
        return dataclasses.replace(
            self, schedule=np.concatenate([self.schedule, pad], axis=1))


def compact_visit_mask(
    visit: np.ndarray, *, max_visits: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray]:
    """(nr_tiles, ns_tiles) bool → (schedule, counts), ascending per row.

    Every row must have at least one visited tile (callers guarantee a
    fallback); padding slots repeat the row's last valid entry so the
    prefetched index never changes on dead steps.
    """
    nr_tiles, ns_tiles = visit.shape
    counts = visit.sum(axis=1).astype(np.int32)
    if (counts == 0).any():
        raise ValueError("visit mask has empty rows; add a fallback tile")
    width = int(counts.max()) if max_visits is None else int(max_visits)
    if width < int(counts.max()):
        raise ValueError(f"max_visits={width} < widest row {counts.max()}")
    # stable argsort of ~visit puts visited tile indices first, ascending;
    # slots past a row's count re-select its last entry (repeat-pad), so
    # slot values never reach ns_tiles and no explicit padding is needed
    order = np.argsort(~visit, axis=1, kind="stable").astype(np.int32)
    slot = np.minimum(np.arange(width, dtype=np.int32)[None, :],
                      counts[:, None] - 1)
    schedule = np.take_along_axis(order, slot, axis=1)
    return np.ascontiguousarray(schedule), counts


def build_tile_schedule(
    r: np.ndarray,
    r_part: np.ndarray,
    s_part: np.ndarray,
    s_dist: np.ndarray,
    pivots: np.ndarray,
    pivd: np.ndarray,
    theta: np.ndarray,
    *,
    bm: int,
    bn: int,
    metric: str = "l2",
    knn_dists: Optional[np.ndarray] = None,
    k: Optional[int] = None,
    stats: Optional[JoinStats] = None,
    theta_block: int = 8192,
) -> TileSchedule:
    """Lower Cor. 1 + Thm 2 to an (R tile × S tile) visit schedule.

    ``r``/``r_part`` are the reducer's queries in their kernel layout;
    ``s_part``/``s_dist`` describe the S rows in *their* kernel layout
    (sort S by (partition, pivot distance) first for tight tiles — the
    builder is correct for any layout, only the pruning rate changes).
    ``part < 0`` marks padding rows on either side.

    When T_S's pivot-kNN lists (``knn_dists`` (M, >=k) + ``k``) are given,
    θ is tightened *per query* to the k-th smallest ``|q,p_j| + p_j.d_i``
    over all partitions — Thm 3 / Algorithm 1 evaluated at the query
    instead of its partition, dropping the U(P^R) slack. Still a sound
    kNN-radius upper bound, still computable before any join, so the
    schedule stays static and prefetchable.
    """
    n_r, n_s = r_part.shape[0], s_part.shape[0]
    m = pivots.shape[0]
    nr_tiles = max(1, -(-n_r // bm))
    ns_tiles = max(1, -(-n_s // bn))

    valid_q = r_part >= 0
    home = np.clip(r_part, 0, m - 1)
    th_q = np.where(valid_q, theta[home], -np.inf).astype(np.float32)

    # |q, p_j| for every pivot — the job-2 mapper's pivot distances
    qp = from_cmp(cmp_dist(np.asarray(r, np.float32), pivots, metric),
                  metric)                                    # (n_r, M)
    if stats is not None:
        stats.pivot_pairs_computed += int(valid_q.sum()) * m

    kk = 0 if knn_dists is None or k is None else min(k, knn_dists.shape[1])
    if kk and m * kk >= k:
        knn = np.where(np.isfinite(knn_dists[:, :kk]),
                       knn_dists[:, :kk], np.inf)            # (M, kk)
        for lo in range(0, n_r, theta_block):
            hi = min(lo + theta_block, n_r)
            ub = (qp[lo:hi, :, None] + knn[None, :, :]).reshape(hi - lo, -1)
            kth = np.partition(ub, k - 1, axis=1)[:, k - 1]
            th_q[lo:hi] = np.where(valid_q[lo:hi],
                                   np.minimum(th_q[lo:hi], kth), -np.inf)

    # Cor. 1 per (query, partition); home column never pruned. All θ
    # comparisons use the ulp-padded θ (bounds.pad_theta) so neighbors
    # at exactly θ survive fp discrepancies between the qp and θ graphs.
    thp = pad_theta(th_q)
    if metric == "l2":
        q2 = qp.astype(np.float64) ** 2
        home_sq = np.take_along_axis(q2, home[:, None], axis=1)
        denom = np.maximum(2.0 * pivd[home], 1e-30)          # (n_r, M)
        d_hp = (q2 - home_sq) / denom
        alive = d_hp <= thp[:, None]
    else:
        alive = np.ones((n_r, m), bool)
    alive[np.arange(n_r), home] = True
    alive &= valid_q[:, None]

    # reduce to R-tile granularity: any-alive, loosest ring per partition
    tile_of_r = (np.arange(n_r) // bm).astype(np.int64)
    alive_t = np.zeros((nr_tiles, m), bool)
    np.logical_or.at(alive_t, tile_of_r, alive)
    lo_q = np.where(alive, qp - thp[:, None], np.inf)
    hi_q = np.where(alive, qp + thp[:, None], -np.inf)
    lo_t = np.full((nr_tiles, m), np.inf, np.float32)
    hi_t = np.full((nr_tiles, m), -np.inf, np.float32)
    np.minimum.at(lo_t, tile_of_r, lo_q.astype(np.float32))
    np.maximum.at(hi_t, tile_of_r, hi_q.astype(np.float32))

    # S-tile × partition |p_j, s| ranges (Thm 2's L/U at tile resolution)
    valid_s = s_part >= 0
    tile_of_s = (np.arange(n_s) // bn).astype(np.int64)
    sd_min = np.full((ns_tiles, m), np.inf, np.float32)
    sd_max = np.full((ns_tiles, m), -np.inf, np.float32)
    idx = (tile_of_s[valid_s], s_part[valid_s])
    np.minimum.at(sd_min, idx, s_dist[valid_s].astype(np.float32))
    np.maximum.at(sd_max, idx, s_dist[valid_s].astype(np.float32))
    present = sd_max > -np.inf                               # (ns_tiles, M)

    # visit[t, u] = ∃ partition j present in u with ring overlap
    overlap = (alive_t[:, None, :] & present[None, :, :]
               & (sd_max[None, :, :] >= lo_t[:, None, :])
               & (sd_min[None, :, :] <= hi_t[:, None, :]))
    visit = overlap.any(axis=2)                              # (nr, ns) tiles

    # fallback: an R tile with live queries must visit >= 1 tile so its
    # output flush runs; empty rows (all-padding tiles) get one free visit
    # of the first non-empty S tile (cheap, keeps the kernel uniform)
    any_s = present.any(axis=1)
    fallback = int(np.argmax(any_s)) if any_s.any() else 0
    empty = ~visit.any(axis=1)
    visit[empty, fallback] = True

    schedule, counts = compact_visit_mask(visit)
    return TileSchedule(schedule=schedule, counts=counts, visit_mask=visit,
                        bm=bm, bn=bn)
