"""Mutable segmented index — online inserts/deletes over the serving
datastore (LSM-flavored, exact).

The paper's pipeline assumes a static S: phase 1 (pivots, Voronoi
assignment, T_S) runs once and is never revisited. A serving datastore
is not static — it grows and shrinks while it answers queries — so this
module layers mutability *on top of* the build-once ``SIndex`` without
ever re-running phase 1 on data that already has one:

* ``MutableIndex`` holds an ordered list of sealed segments (each a
  full ``SIndex`` over its own rows) plus a small write buffer.
  ``insert`` appends to the buffer; when the buffer crosses
  ``seal_threshold`` rows it is *sealed* into a new delta ``SIndex``
  (phase 1 runs over the delta rows only). ``delete`` records global
  ids in a tombstone set — no segment is touched. ``compact`` folds all
  segments + buffer − tombstones back into one rebuilt base (the only
  operation that re-runs phase 1 over old rows; eligible to run between
  decode steps).

* Ids are **global and 64-bit**: each segment owns a contiguous id
  range starting at its ``id_offset``; a row's global id is
  ``offset + local``. Ids are stable across inserts/deletes and only
  change at ``compact``, which re-bases survivors to ``0..n_live-1``
  (ascending old-id order) and returns the old ids so callers can remap
  row-aligned payloads (e.g. the kNN-LM value table).

* Queries stay **exact**: a batch fans out over every live segment
  (per-segment ``plan_queries`` + ``execute_join`` — the same engines
  as the static path, any reducer), each segment over-fetches
  adaptively (``k + min(dead, k)`` first, escalating to the certain
  ``k + dead`` bound for queries whose masked run proves incomplete)
  so masking dead rows can never surface an incomplete top-k, and the
  per-segment sorted runs fold through ``StreamJoinState``'s dedup
  merge. Results are
  bitwise-identical (distances, and ids up to the documented remap) to
  a fresh ``build_index`` over the surviving rows — every engine
  reports shape-canonical distances (``metrics.canonical_topk``), a
  pure function of the (query, row) pair, so segment boundaries are
  invisible in the output. One caveat: when *distinct* rows tie at
  exactly the same float32 distance, which of the tied ids is reported
  (or their order) may differ from the fresh rebuild — both answers are
  exact kNN sets; only the tie-break differs between the merge network
  and a single engine's selection order.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro import obs

from .api import execute_join
from .index import SIndex, as_float32_rows, build_index, plan_queries
from .metrics import canonical_topk, cmp_dist
from .partition import build_summary
from .stream import StreamJoinState
from .types import JoinConfig, JoinStats

__all__ = ["Segment", "MutableIndex"]


@dataclasses.dataclass
class Segment:
    """One sealed immutable segment: a full ``SIndex`` over its rows plus
    the global id range it owns (``id_offset .. id_offset + n_rows``)."""

    index: SIndex
    id_offset: int
    _t_s_wide: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def n_rows(self) -> int:
        return self.index.n_s

    def index_for_k(self, k: int) -> SIndex:
        """The segment's index with a T_S wide enough for a k-row fetch.

        Tombstone masking over-fetches (k + dead rows), which can exceed
        the pivot-kNN list width T_S was built with. The lists are a pure
        function of the stored (s_part, s_dist), so widening is a cheap
        re-summarize — no assignment, no distance computation. Widths are
        rounded up to the next power of two and cached so the cache stays
        O(log k) as tombstones accumulate.
        """
        width = self.index.t_s.knn_dists.shape[1]
        if k <= width:
            return self.index
        cap = 1 << max(0, (min(k, self.n_rows) - 1).bit_length())
        cap = min(max(cap, k), self.n_rows)
        if cap not in self._t_s_wide:
            t_s = build_summary(self.index.s_part, self.index.s_dist,
                                self.index.n_pivots, k=cap)
            self._t_s_wide[cap] = dataclasses.replace(self.index, t_s=t_s)
        return self._t_s_wide[cap]


class MutableIndex:
    """A mutable, segmented, exact kNN index over a changing dataset S.

    Drop-in for ``SIndex`` everywhere a query-side caller goes:
    ``knn_join(r, index=mi)``, ``knn_join_batched(r, index=mi)``,
    ``StreamJoinEngine(mi)`` and ``serve.retrieval.Datastore`` all
    accept it. See the module docstring for the id-space and exactness
    contracts.
    """

    def __init__(self, base: Optional[SIndex] = None,
                 config: Optional[JoinConfig] = None, *,
                 seal_threshold: int = 4096):
        if base is None and config is None:
            raise ValueError("MutableIndex needs a base SIndex or a config")
        if seal_threshold < 1:
            raise ValueError("seal_threshold must be >= 1")
        self.config = config or base.config
        self.seal_threshold = int(seal_threshold)
        self.segments: list[Segment] = []
        self._next_id = 0
        if base is not None:
            self.segments.append(Segment(base, 0))
            self._next_id = base.n_s
        self._tombstones: set[int] = set()
        self._tomb_sorted: Optional[np.ndarray] = None
        self._buffer: list[np.ndarray] = []
        self._buffer_ids: list[np.ndarray] = []
        self._n_buffer = 0
        self._version = 0
        self._live_cache = None
        self._buffer_seg = None
        self.last_compact_s = 0.0

    @classmethod
    def build(cls, s: np.ndarray, config: Optional[JoinConfig] = None, *,
              seal_threshold: int = 4096) -> "MutableIndex":
        """Phase 1 over the initial S, wrapped mutable."""
        config = config or JoinConfig()
        return cls(build_index(s, config), config,
                   seal_threshold=seal_threshold)

    # ---- sizes / introspection

    @property
    def n_s(self) -> int:
        """Live row count (matches the ``SIndex`` property every caller
        validates ``k`` against)."""
        return self._next_id - len(self._tombstones)

    @property
    def n_live(self) -> int:
        return self.n_s

    @property
    def n_segments(self) -> int:
        """Sealed segments plus the write buffer if it holds rows."""
        return len(self.segments) + (1 if self._n_buffer else 0)

    @property
    def n_tombstones(self) -> int:
        return len(self._tombstones)

    @property
    def n_buffered(self) -> int:
        return self._n_buffer

    @property
    def dim(self) -> int:
        if self.segments:
            return self.segments[0].index.dim
        if self._buffer:
            return self._buffer[0].shape[1]
        raise ValueError("empty MutableIndex has no dimensionality yet")

    # ---- mutation

    def insert(self, rows: np.ndarray) -> np.ndarray:
        """Append rows; returns their newly-assigned global int64 ids.

        Rows land in the write buffer (queryable immediately, by brute
        force) and seal into a delta ``SIndex`` once the buffer crosses
        ``seal_threshold`` — phase 1 runs over the delta only, never
        over pre-existing segments. Model-emitted bfloat16/float16
        hidden states are cast to float32 once at this boundary
        (`core.index.as_float32_rows`); non-float dtypes are rejected.
        """
        rows = as_float32_rows(rows, what="inserted rows")
        if rows.ndim != 2 or rows.shape[0] == 0:
            raise ValueError(f"insert needs (n, dim) rows, got {rows.shape}")
        if self.segments or self._buffer:
            if rows.shape[1] != self.dim:
                raise ValueError(
                    f"insert dim {rows.shape[1]} != index dim {self.dim}")
        ids = np.arange(self._next_id, self._next_id + rows.shape[0],
                        dtype=np.int64)
        self._next_id += rows.shape[0]
        self._buffer.append(rows)
        self._buffer_ids.append(ids)
        self._n_buffer += rows.shape[0]
        self._version += 1
        reg = obs.metrics.REGISTRY
        reg.counter("index_insert_rows_total").inc(rows.shape[0])
        reg.gauge("index_segments").set(self.n_segments)
        if self._n_buffer >= self.seal_threshold:
            self.seal()
        return ids

    def seal(self) -> Optional[Segment]:
        """Flush the write buffer into a sealed delta segment (no-op when
        empty). Phase 1 (pivots from the delta, assignment, T_S, packed
        layout) runs over the buffered rows only."""
        if self._n_buffer == 0:
            return None
        rows = np.concatenate(self._buffer, axis=0)
        offset = int(self._buffer_ids[0][0])
        self._buffer, self._buffer_ids, self._n_buffer = [], [], 0
        self._buffer_seg = None
        with obs.span("index.seal", rows=rows.shape[0]):
            seg = Segment(build_index(rows, self.config), offset)
        self.segments.append(seg)
        self._version += 1
        reg = obs.metrics.REGISTRY
        reg.counter("index_seal_total").inc()
        reg.gauge("index_segments").set(self.n_segments)
        return seg

    def delete(self, ids) -> None:
        """Tombstone rows by global id. O(|ids|); no segment is touched.
        Raises on ids that were never allocated or are already dead."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        bad = ids[(ids < 0) | (ids >= self._next_id)]
        if bad.size:
            raise ValueError(f"unknown row ids {bad[:5].tolist()} "
                             f"(allocated id space is [0, {self._next_id}))")
        new = set(ids.tolist())
        if len(new) != ids.size:
            raise ValueError("duplicate ids in one delete call")
        dead = new & self._tombstones
        if dead:
            raise ValueError(
                f"ids already deleted: {sorted(dead)[:5]}")
        self._tombstones |= new
        self._tomb_sorted = None
        self._version += 1
        reg = obs.metrics.REGISTRY
        reg.counter("index_delete_rows_total").inc(ids.size)
        reg.gauge("index_tombstones").set(len(self._tombstones))

    def compact(self, *, stats: Optional[JoinStats] = None) -> np.ndarray:
        """Fold segments + buffer − tombstones into one rebuilt base.

        The only operation that re-runs phase 1 over pre-existing rows;
        cheap enough to run between decode steps at serving scale.
        Survivors are re-based to ids ``0..n_live-1`` in ascending old-id
        order; returns the old global ids in new-id order so callers can
        remap row-aligned payloads (``payload_new = payload_old[ret]``).
        """
        t0 = time.perf_counter()
        with obs.span("index.compact", n_segments=self.n_segments,
                      n_tombstones=self.n_tombstones):
            rows, old_ids = self.live_rows()
            self.segments = []
            self._buffer, self._buffer_ids, self._n_buffer = [], [], 0
            # drop the ephemeral buffer-segment view: compact re-bases
            # _next_id downward, so a later buffer could reproduce the
            # cache key (_next_id, n_buffer) while holding different rows
            self._buffer_seg = None
            self._tombstones.clear()
            self._tomb_sorted = None
            self._next_id = rows.shape[0]
            if rows.shape[0]:
                self.segments.append(
                    Segment(build_index(rows, self.config), 0))
            self._version += 1
        self.last_compact_s = time.perf_counter() - t0
        reg = obs.metrics.REGISTRY
        reg.counter("index_compact_total").inc()
        reg.histogram("index_compact_s").observe(self.last_compact_s)
        reg.gauge("index_segments").set(self.n_segments)
        reg.gauge("index_tombstones").set(0)
        if stats is not None:
            stats.compact_time_s += self.last_compact_s
        return old_ids

    # ---- views

    @property
    def version(self) -> int:
        """Monotonic mutation counter: bumps on every insert / seal /
        delete / compact. Device-resident consumers (the megastep engine)
        key their uploaded payload on it and re-upload only when it
        moves — steady-state queries never re-ship the index."""
        return self._version

    def tombstones_sorted(self) -> np.ndarray:
        """The tombstoned global ids as an ascending int64 array (the
        liveness mask the megastep uploads is derived from this)."""
        return self._tomb_array()

    def segment_snapshot(self) -> list[tuple[SIndex, int]]:
        """(index, id_offset) views of every live segment, *including*
        the unsealed write buffer presented through an ephemeral delta
        ``SIndex`` (phase 1 over the buffered rows only, cached until the
        buffer changes, never mutating this index). This is the fan-out
        set a single fused megastep call covers — the buffer stays
        queryable without waiting for ``seal``.
        """
        out = [(seg.index, seg.id_offset) for seg in self.segments]
        if self._n_buffer:
            key = (self._next_id, self._n_buffer)
            if self._buffer_seg is None or self._buffer_seg[0] != key:
                rows = np.concatenate(self._buffer, axis=0)
                offset = int(self._buffer_ids[0][0])
                self._buffer_seg = (key, build_index(rows, self.config),
                                    offset)
            out.append((self._buffer_seg[1], self._buffer_seg[2]))
        return out

    def nbytes_resident(self, *, quantized: Optional[bool] = None) -> int:
        """Device-resident row-payload bytes summed over all live
        segments (including the write buffer's ephemeral view) — the
        mutable-index counterpart of ``SIndex.nbytes_resident``."""
        return sum(si.nbytes_resident(quantized=quantized)
                   for si, _ in self.segment_snapshot())

    def live_rows(self) -> tuple[np.ndarray, np.ndarray]:
        """(rows, global ids) of all surviving rows, ascending by id —
        the canonical enumeration ``compact`` re-bases to, and the order
        a fresh ``build_index`` oracle sees them in."""
        tomb = self._tomb_array()
        chunks, idchunks = [], []
        for seg in self.segments:
            gids = seg.id_offset + np.arange(seg.n_rows, dtype=np.int64)
            rows = seg.index.rows_for_ids(
                np.arange(seg.n_rows, dtype=np.int64))
            keep = ~_in_sorted(gids, tomb)
            chunks.append(rows[keep])
            idchunks.append(gids[keep])
        for rows, gids in zip(self._buffer, self._buffer_ids):
            keep = ~_in_sorted(gids, tomb)
            chunks.append(rows[keep])
            idchunks.append(gids[keep])
        if not chunks:
            d = self.dim if (self.segments or self._buffer) else 0
            return (np.zeros((0, d), np.float32), np.zeros((0,), np.int64))
        return np.concatenate(chunks, axis=0), np.concatenate(idchunks)

    def live_device_rows(self):
        """Live rows as a device-resident jnp array + their global ids,
        cached until the next mutation (the brute-force kernel path's
        view of the mutable datastore)."""
        if self._live_cache is None or self._live_cache[0] != self._version:
            import jax.numpy as jnp
            rows, gids = self.live_rows()
            self._live_cache = (self._version, jnp.asarray(rows), gids)
        return self._live_cache[1], self._live_cache[2]

    def _tomb_array(self) -> np.ndarray:
        if self._tomb_sorted is None:
            self._tomb_sorted = np.fromiter(
                sorted(self._tombstones), np.int64, len(self._tombstones))
        return self._tomb_sorted

    # ---- query

    def join_batch(
        self, queries: np.ndarray, *,
        config: Optional[JoinConfig] = None,
        stats: Optional[JoinStats] = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Exact (dists, global ids) of the batch's k nearest live rows.

        Fans the batch over every live segment — per-segment planning +
        join through the configured reducer, over-fetching by the
        segment's tombstone count — masks dead rows, and folds the runs
        through the ``StreamJoinState`` dedup merge.
        """
        cfg = config or self.config
        k = cfg.k
        queries = np.ascontiguousarray(queries, np.float32)
        nq = queries.shape[0]
        if k > self.n_s:
            raise ValueError(f"k={k} > live rows |S|={self.n_s}")
        if stats is not None:
            stats.n_segments = self.n_segments
            stats.n_tombstones = self.n_tombstones
        if nq == 0:
            return (np.zeros((0, k), np.float32),
                    np.full((0, k), -1, np.int64))
        tomb = self._tomb_array()
        state = StreamJoinState(n=nq, k=k)
        all_rows = np.arange(nq)
        for seg in self.segments:
            # the segment owns the contiguous id range [offset, offset+n),
            # so its tombstone count is one sorted-range probe, not a scan
            n_dead = int(np.searchsorted(tomb, seg.id_offset + seg.n_rows)
                         - np.searchsorted(tomb, seg.id_offset))
            if seg.n_rows == n_dead:
                continue   # fully tombstoned segment
            d, gids = self._join_segment(queries, seg, n_dead, tomb, cfg,
                                         stats)
            state.update(all_rows, d, gids)
        if self._n_buffer:
            d, gids = self._join_buffer(queries, k, tomb, cfg, stats)
            if d is not None:
                state.update(all_rows, d, gids)
        return state.distances, state.indices

    def _join_segment(self, queries, seg: Segment, n_dead: int,
                      tomb: np.ndarray, cfg: JoinConfig, stats):
        """One segment's masked top-k runs, with adaptive over-fetch.

        A fetch of the segment's exact top-m contains the top-j *live*
        rows, where j is however many of the m survive masking — so any
        query that still shows ≥ min(k, live) live entries is complete.
        Fetching ``k + n_dead`` is always sufficient but degrades to a
        near-full scan as tombstones pile up, so the first pass fetches
        only ``k + min(n_dead, k)`` (covers up to k dead rows in the
        query's neighborhood) and the rare queries that prove incomplete
        — more than k tombstones inside their fetched prefix — re-run at
        the certain bound.
        """
        k = cfg.k
        need = min(k, seg.n_rows - n_dead)
        m_full = min(seg.n_rows, k + n_dead)
        m1 = min(m_full, k + min(n_dead, k))
        d, gids = self._fetch_segment_topm(queries, seg, m1, cfg, stats)
        d, gids = _mask_dead(d, gids, tomb)
        if m1 < m_full:
            lack = (gids >= 0).sum(axis=1) < need
            if lack.any():
                d2, g2 = self._fetch_segment_topm(
                    queries[lack], seg, m_full, cfg, stats)
                d2, g2 = _mask_dead(d2, g2, tomb)
                d, gids = _trim(d, gids, k)
                d2, g2 = _trim(d2, g2, k)
                d[lack], gids[lack] = d2, g2
                return d, gids
        return _trim(d, gids, k)

    def _fetch_segment_topm(self, queries, seg: Segment, m: int,
                            cfg: JoinConfig, stats):
        """Exact top-m of one segment (global ids, canonical distances)
        through the configured reducer engine."""
        seg_cfg = cfg if m == cfg.k else dataclasses.replace(cfg, k=m)
        index = seg.index_for_k(m)
        qplan = plan_queries(queries, index, seg_cfg)
        if stats is not None:
            stats.pivot_pairs_computed += queries.shape[0] * index.n_pivots
        d, local = execute_join(queries, index, qplan, stats=stats)
        return d, np.where(local >= 0, local + seg.id_offset, -1)

    def _join_buffer(self, queries, k, tomb, cfg, stats):
        """Brute-force the unsealed write buffer (small by construction:
        |buffer| < seal_threshold), reported through the same canonical
        distance path as the segment engines."""
        rows = np.concatenate(self._buffer, axis=0)
        gids = np.concatenate(self._buffer_ids)
        dead = _in_sorted(gids, tomb)
        n_dead = int(dead.sum())
        if n_dead == rows.shape[0]:
            return None, None
        k_fetch = min(rows.shape[0], k + n_dead)
        dc = cmp_dist(queries, rows, cfg.metric)
        if stats is not None:
            stats.pairs_computed += dc.size
        if k_fetch < rows.shape[0]:
            sel = np.argpartition(dc, k_fetch - 1, axis=1)[:, :k_fetch]
        else:
            sel = np.broadcast_to(np.arange(rows.shape[0]),
                                  (queries.shape[0], rows.shape[0]))
        d, ids = canonical_topk(queries, gids[sel], rows[sel], cfg.metric)
        return _trim(*_mask_dead(d, ids, tomb), k)

    def __repr__(self) -> str:
        return (f"MutableIndex(n_live={self.n_s}, "
                f"segments={len(self.segments)}, "
                f"buffered={self._n_buffer}, "
                f"tombstones={self.n_tombstones})")


def _in_sorted(ids: np.ndarray, sorted_ids: np.ndarray) -> np.ndarray:
    """Membership of ``ids`` in an ascending id array (vectorized; -1
    query padding is never a member)."""
    if sorted_ids.size == 0:
        return np.zeros(ids.shape, bool)
    pos = np.searchsorted(sorted_ids, ids)
    pos = np.clip(pos, 0, sorted_ids.size - 1)
    return sorted_ids[pos] == ids


def _mask_dead(d: np.ndarray, ids: np.ndarray, tomb: np.ndarray,
               ) -> tuple[np.ndarray, np.ndarray]:
    """Demote tombstoned ids to (+inf, -1) and restore ascending order
    (stable, so the surviving run order is untouched)."""
    if tomb.size:
        dead = _in_sorted(ids, tomb) & (ids >= 0)
        if dead.any():
            d = np.where(dead, np.float32(np.inf), d)
            ids = np.where(dead, np.int64(-1), ids)
            order = np.argsort(d, axis=1, kind="stable")
            d = np.take_along_axis(d, order, axis=1)
            ids = np.take_along_axis(ids, order, axis=1)
    return d, ids


def _trim(d: np.ndarray, ids: np.ndarray, k: int,
          ) -> tuple[np.ndarray, np.ndarray]:
    """Normalize a masked run to exactly k columns (truncate an
    over-fetch, pad an under-full segment with (+inf, -1))."""
    if d.shape[1] > k:
        d, ids = d[:, :k], ids[:, :k]
    elif d.shape[1] < k:
        pad = ((0, 0), (0, k - d.shape[1]))
        d = np.pad(d, pad, constant_values=np.inf)
        ids = np.pad(ids, pad, constant_values=-1)
    return np.ascontiguousarray(d), np.ascontiguousarray(ids)
