"""whisper-small [audio]: enc-dec, conv frontend stubbed to precomputed
frame embeddings. 12L(dec) d=768 12H (kv=12 ⇒ MHA) d_ff=3072 vocab=51865.
[arXiv:2212.04356]"""
import dataclasses

from .base import ArchConfig, XATTN

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    act="relu2",          # whisper uses GELU MLP; relu2 slot reused → see note
    norm="ln",
    rope="none",
    abs_pos=True,         # learned absolute positions
    pattern=(XATTN,),
    n_enc_layers=12,
    enc_len=1500,          # 30 s of audio at 50 Hz after the conv stub
)
# NOTE: whisper's MLP is GELU (non-gated). We model it as the non-gated
# 2-matrix MLP path ("relu2" kind uses square-relu; whisper uses "gelu").
CONFIG = dataclasses.replace(CONFIG, act="gelu")


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, enc_len=16)
