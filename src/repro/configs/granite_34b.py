"""granite-34b [dense]: llama-arch code model, MQA.
88L d=6144 48H (kv=1) d_ff=24576 vocab=49152. [arXiv:2405.04324]"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    act="swiglu",
    norm="rms",
    rope="std",
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=256, vocab=256)
