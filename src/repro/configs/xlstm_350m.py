"""xlstm-350m [ssm]: mLSTM + sLSTM blocks at 7:1 (xLSTM[7:1]), no FFN —
blocks carry their own projections. 24L d=1024 4H vocab=50304.
[arXiv:2405.04517]"""
import dataclasses

from .base import ArchConfig, MLSTM, SLSTM

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                       # blocks are self-contained
    vocab=50304,
    act="swiglu",
    norm="ln",
    rope="none",
    pattern=(MLSTM,) * 7 + (SLSTM,),   # 7:1 → 21 mLSTM + 3 sLSTM over 24L
    conv_width=4,
    expand=2.0,                   # mLSTM pf=2 inner width
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, vocab=256,
        pattern=(MLSTM,) * 3 + (SLSTM,))
