"""qwen3-14b [dense]: GQA + per-head q/k RMSNorm.
40L d=5120 40H (kv=8) d_ff=17408 vocab=151936. [hf:Qwen/Qwen3-14B]"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab=151936,
    head_dim=128,
    act="swiglu",
    norm="rms",
    qk_norm=True,
    rope="std",
    rope_theta=1000000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=192, vocab=512)
