"""arctic-480b [moe]: dense-MoE hybrid — every layer has a dense FFN
residual IN PARALLEL with a 128-expert top-2 MoE.
35L d=7168 56H (kv=8) expert_ff=4864 vocab=32000.
[hf:Snowflake/snowflake-arctic-base]"""
import dataclasses

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,            # per-expert ff (the assignment's d_ff)
    vocab=32000,
    act="swiglu",
    norm="rms",
    rope="std",
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=128,
        top_k=2,
        expert_ff=4864,
        dense_residual_ff=7168,  # arctic's parallel dense MLP (2×d ratio ≈ hf cfg)
        capacity_factor=1.25,
    ),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, expert_ff=96,
                      dense_residual_ff=64, capacity_factor=8.0))
