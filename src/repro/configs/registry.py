"""Registry: --arch <id> → ArchConfig (full) and reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = (
    "whisper-small",
    "granite-34b",
    "nemotron-4-15b",
    "qwen3-14b",
    "llama3.2-3b",
    "arctic-480b",
    "deepseek-v2-lite-16b",
    "qwen2-vl-7b",
    "xlstm-350m",
    "recurrentgemma-9b",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_arch(name: str):
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; known: {', '.join(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.CONFIG


def get_reduced(name: str):
    """Small same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.reduced()


def all_archs():
    return {a: get_arch(a) for a in ARCH_IDS}
