"""recurrentgemma-9b [hybrid]: Griffin — RG-LRU blocks + local attention
at 1:2 (two recurrent per one local-attn), MQA kv=1, window 2048.
38L d=4096 16H d_ff=12288 vocab=256000. [arXiv:2402.19427]"""
import dataclasses

from .base import ArchConfig, LOCAL, RGLRU

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    act="geglu",
    norm="rms",
    rope="std",
    rope_theta=10000.0,
    pattern=(RGLRU, RGLRU, LOCAL),   # ×12 = 36
    pattern_tail=(RGLRU, RGLRU),     # + 2 → 38
    local_window=2048,
    conv_width=4,
    expand=1.0,                      # rg-lru width == d_model (9b uses 4096)
    attn_logit_softcap=0.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=192, vocab=512, pattern=(RGLRU, RGLRU, LOCAL), pattern_tail=(),
        local_window=16)
