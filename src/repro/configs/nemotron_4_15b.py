"""nemotron-4-15b [dense]: GQA + squared-ReLU MLP, LayerNorm.
32L d=6144 48H (kv=8) d_ff=24576 vocab=256000. [arXiv:2402.16819]"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    act="relu2",          # squared ReLU, non-gated (2 matrices)
    norm="ln",
    rope="std",
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512)
