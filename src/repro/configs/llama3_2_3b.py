"""llama3.2-3b [dense]: small llama3 — GQA, SwiGLU, tied embeddings.
28L d=3072 24H (kv=8) d_ff=8192 vocab=128256. [hf:meta-llama/Llama-3.2-3B]"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    act="swiglu",
    norm="rms",
    rope="std",
    rope_theta=500000.0,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=512)
