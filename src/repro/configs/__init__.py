"""Architecture and shape configs (one module per assigned arch)."""
from .base import (
    ArchConfig, MLAConfig, MoEConfig, ShapeConfig, SHAPES,
    SUBQUADRATIC, runnable_cells)
from .registry import ARCH_IDS, all_archs, get_arch, get_reduced

__all__ = [
    "ArchConfig", "MLAConfig", "MoEConfig", "ShapeConfig", "SHAPES",
    "SUBQUADRATIC", "runnable_cells",
    "ARCH_IDS", "all_archs", "get_arch", "get_reduced",
]
