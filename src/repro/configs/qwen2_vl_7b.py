"""qwen2-vl-7b [vlm]: backbone only — M-RoPE 3-axis rotary; the vision
tower is a STUB (input_specs supplies 64 precomputed patch embeddings).
28L d=3584 28H (kv=4) d_ff=18944 vocab=152064. [arXiv:2409.12191]"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    act="swiglu",
    norm="rms",
    rope="mrope",
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),   # halves of head_dim=128 → 64 = 16+24+24
    n_vision_embeds=64,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=512, mrope_sections=(2, 3, 3), n_vision_embeds=4)
