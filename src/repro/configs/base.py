"""Architecture + shape configuration schema.

Every assigned architecture is a frozen ``ArchConfig``; the four shape
cells are ``ShapeConfig``s. ``layout()`` expresses the layer stack as
(repeating unit, count) pairs so heterogeneous stacks (Griffin 1:2,
xLSTM 7:1, DeepSeek first-dense) scan over homogeneous super-blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# layer kinds understood by models/blocks.py
ATTN = "attn"            # causal self-attention + MLP
ATTN_BIDIR = "attn_bidir"  # bidirectional (encoder) self-attention + MLP
XATTN = "xattn"          # causal self-attn + cross-attn + MLP (decoder of enc-dec)
LOCAL = "local"          # sliding-window causal attention + MLP
MLSTM = "mlstm"          # xLSTM matrix-memory block (self-contained)
SLSTM = "slstm"          # xLSTM scalar-memory block (self-contained)
RGLRU = "rglru"          # Griffin RG-LRU recurrent block + MLP


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_ff: int
    n_shared: int = 0           # shared (always-on) experts, DeepSeek-style
    dense_residual_ff: int = 0  # parallel dense FFN (Arctic-style dense+MoE)
    capacity_factor: float = 1.25
    first_dense: int = 0        # leading layers that use a dense FFN instead
    first_dense_ff: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    v_head_dim: int = 128
    qk_nope_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads
    act: str = "swiglu"         # swiglu | geglu | relu2
    norm: str = "rms"           # rms | ln
    qk_norm: bool = False
    rope: str = "std"           # std | mrope | none
    abs_pos: bool = False       # learned absolute positions (whisper)
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    tie_embeddings: bool = False
    # hybrid stacks: repeating unit of layer kinds; () → all ATTN
    pattern: Tuple[str, ...] = ()
    pattern_tail: Tuple[str, ...] = ()   # remainder layers after the repeats
    local_window: int = 2048
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # enc-dec (whisper): encoder layers + fixed source length (frames)
    n_enc_layers: int = 0
    enc_len: int = 0
    # vlm stub: number of precomputed patch embeddings prepended
    n_vision_embeds: int = 0
    # ssm sizing
    conv_width: int = 4          # rglru/mlstm short conv
    expand: float = 1.0          # rnn width multiplier (Griffin uses 4/3)
    attn_logit_softcap: float = 0.0

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layout(self) -> Sequence[Tuple[Tuple[str, ...], int]]:
        """[(unit, repeats), ...] covering all n_layers, in order."""
        unit = self.pattern or (ATTN,)
        tail = self.pattern_tail
        if self.moe and self.moe.first_dense:
            head = (unit[0] + "_dense",) * self.moe.first_dense
            body_layers = self.n_layers - self.moe.first_dense - len(tail)
            assert body_layers % len(unit) == 0, (self.name, body_layers, unit)
            out = [(head, 1), (unit, body_layers // len(unit))]
        else:
            body_layers = self.n_layers - len(tail)
            assert body_layers % len(unit) == 0, (self.name, body_layers, unit)
            out = [(unit, body_layers // len(unit))]
        if tail:
            out.append((tail, 1))
        return out

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, dh = self.d_model, self.dh
        kv = self.n_kv_heads
        att = d * (self.n_heads * dh) + 2 * d * kv * dh + (self.n_heads * dh) * d
        if self.mla:
            c = self.mla
            att = (d * self.n_heads * (c.qk_nope_head_dim + c.rope_head_dim)
                   + d * (c.kv_lora_rank + c.rope_head_dim)
                   + c.kv_lora_rank * self.n_heads * (c.qk_nope_head_dim + c.v_head_dim)
                   + self.n_heads * c.v_head_dim * d)
        mlp_mult = 3 if self.act in ("swiglu", "geglu") else 2
        per_kind = {}
        per_kind[ATTN] = att + mlp_mult * d * self.d_ff
        per_kind[ATTN_BIDIR] = per_kind[ATTN]
        per_kind[XATTN] = 2 * att + mlp_mult * d * self.d_ff
        per_kind[LOCAL] = per_kind[ATTN]
        rnn_d = int(d * self.expand)
        per_kind[RGLRU] = 2 * d * rnn_d + rnn_d * d + 2 * rnn_d + mlp_mult * d * self.d_ff
        per_kind[MLSTM] = 2 * d * 2 * d + 2 * d * d + 3 * (2 * d) * 3  # qkv on 2d inner
        per_kind[SLSTM] = 4 * d * d + 4 * (d // max(self.n_heads, 1)) * d + 2 * d * int(d * 4 / 3)
        if self.moe:
            mo = self.moe
            moe_params = mo.n_experts * mlp_mult * d * mo.expert_ff
            moe_params += mo.n_shared * mlp_mult * d * mo.expert_ff
            moe_params += d * mo.n_experts
            if mo.dense_residual_ff:
                moe_params += mlp_mult * d * mo.dense_residual_ff
            per_kind[ATTN] = att + moe_params
            per_kind[ATTN + "_dense"] = att + mlp_mult * d * (
                mo.first_dense_ff or self.d_ff)
        total = 0
        for unit, reps in self.layout():
            for kind in unit:
                base = kind.replace("_dense", "") if kind not in per_kind else kind
                total += per_kind[kind if kind in per_kind else base] * reps
        total += self.n_enc_layers * per_kind.get(ATTN_BIDIR, 0)
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-active experts)."""
        if not self.moe:
            return self.param_count()
        mo = self.moe
        mlp_mult = 3 if self.act in ("swiglu", "geglu") else 2
        inactive = (mo.n_experts - mo.top_k) * mlp_mult * self.d_model * mo.expert_ff
        n_moe_layers = self.n_layers - mo.first_dense
        return int(self.param_count() - inactive * n_moe_layers)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode
    # decode/long: KV cache length (context already processed)
    cache_len: int = 0


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 1, 128, "decode", cache_len=32768),
    "long_500k": ShapeConfig("long_500k", 1, 1, "decode", cache_len=524288),
}

# archs that may run long_500k (sub-quadratic serving memory/compute)
SUBQUADRATIC = ("xlstm-350m", "recurrentgemma-9b")


def runnable_cells(arch: "ArchConfig") -> list[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.name in SUBQUADRATIC:
        cells.append("long_500k")
    return cells
