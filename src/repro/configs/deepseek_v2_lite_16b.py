"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + 2 shared / 64 routed
top-6 experts, first layer dense. 27L d=2048 16H expert_ff=1408
vocab=102400. [arXiv:2405.04434]

Assignment-line discrepancy (see DESIGN.md §4.1): header says "MoE 64e
top-6", trailer says "160 routed" (that's the 236B model). We follow the
header: 64 routed experts, top-6, plus 2 shared.
"""
import dataclasses

from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,        # MLA: kv heads == q heads after up-projection
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    act="swiglu",
    norm="rms",
    rope="std",
    rope_theta=10000.0,
    mla=MLAConfig(
        kv_lora_rank=512,
        rope_head_dim=64,
        v_head_dim=128,
        qk_nope_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        expert_ff=1408,
        n_shared=2,
        capacity_factor=1.25,
        first_dense=1,
        first_dense_ff=10944,   # DSv2-lite dense layer-1 intermediate size
    ),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=96, vocab=256,
        mla=MLAConfig(kv_lora_rank=32, rope_head_dim=8,
                      v_head_dim=16, qk_nope_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, expert_ff=96, n_shared=1,
                      # dropless at smoke scale: decode-vs-forward tests
                      # need no capacity truncation
                      capacity_factor=8.0, first_dense=1, first_dense_ff=128))
