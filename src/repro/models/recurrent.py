"""Recurrent blocks: xLSTM (mLSTM chunkwise, sLSTM scan) and Griffin's
RG-LRU. All three expose (init, apply) where apply handles both full
sequences (train/prefill) and single-step decode via a state dict.

TPU notes:
- mLSTM runs in *chunkwise* form: intra-chunk is parallel matmuls (MXU),
  inter-chunk is a short scan over T/chunk steps carrying (C, n, m) —
  exact, stabilized in log-space.
- RG-LRU is a diagonal linear recurrence → jax.lax.associative_scan
  (log-depth, maps to efficient TPU loops); decode is one fused step.
- sLSTM has memory mixing (h_{t-1} enters the gates through dense
  recurrent weights) and is *inherently sequential* (xLSTM paper §2.1) —
  a lax.scan over time; its cost is the architecture's, not an
  implementation artifact.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import dense, dense_init, norm_init, apply_norm

Params = Dict[str, Any]


# ------------------------------------------------------- causal conv 1d
def conv1d_init(key, width: int, channels: int, dtype):
    return {"w": (jax.random.normal(key, (width, channels), jnp.float32)
                  * width ** -0.5).astype(dtype)}


def conv1d_apply(p: Params, x: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x (B,T,C); state (B,W-1,C) for decode.
    Returns (y, new_state)."""
    w = p["w"]                                   # (W, C)
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)       # (B, T+W-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return y, xp[:, -(width - 1):]


# --------------------------------------------------------------- RG-LRU
_RGLRU_C = 8.0


def rglru_block_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    dr = int(d * cfg.expand)
    ks = jax.random.split(key, 7)
    return {
        "in": dense_init(ks[0], d, dr, dtype),
        "gate": dense_init(ks[1], d, dr, dtype),
        "conv": conv1d_init(ks[2], cfg.conv_width, dr, dtype),
        # elementwise (diagonal) RG-LRU gates
        "w_a": jnp.zeros((dr,), dtype), "b_a": jnp.zeros((dr,), dtype),
        "w_x": jnp.zeros((dr,), dtype), "b_x": jnp.zeros((dr,), dtype),
        # Λ init so a ≈ 0.9..0.999 (Griffin's init range)
        "lam": (jnp.log(jnp.expm1(
            -jnp.log(jax.random.uniform(
                ks[3], (dr,), jnp.float32, 0.9, 0.999)) / _RGLRU_C))
            ).astype(jnp.float32),
        "out": dense_init(ks[4], dr, d, dtype),
    }


def rglru_block_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                      state: Optional[Params] = None):
    """x (B,T,D) → (B,T,D); state {"h": (B,Dr), "conv": (B,W-1,Dr)}."""
    u = dense(p["in"], x)                                       # (B,T,Dr)
    g = jax.nn.gelu(dense(p["gate"], x))
    u, conv_state = conv1d_apply(
        p["conv"], u, None if state is None else state["conv"])

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["w_a"].astype(jnp.float32)
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * p["w_x"].astype(jnp.float32)
                       + p["b_x"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r            # (B,T,Dr)
    a = jnp.exp(log_a)
    gated_x = i * uf
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    if x.shape[1] > 1:
        # h_t = a_t h_{t-1} + b_t — associative; fold a carried state into
        # the first step so prefill can continue from a checkpointed state
        if state is not None:
            b = b.at[:, 0].add(a[:, 0] * state["h"].astype(jnp.float32))
        _, h = jax.lax.associative_scan(_lru_op, (a, b), axis=1)
        new_state = {"h": h[:, -1].astype(x.dtype), "conv": conv_state}
    else:
        h_prev = (jnp.zeros_like(b[:, 0]) if state is None
                  else state["h"].astype(jnp.float32))[:, None]
        h = a * h_prev + b          # T == 1 for decode
        new_state = {"h": h[:, -1].astype(x.dtype), "conv": conv_state}
    y = dense(p["out"], (h.astype(x.dtype) * g))
    return y, new_state


def _lru_op(left, right):
    a_l, b_l = left
    a_r, b_r = right
    return a_l * a_r, a_r * b_l + b_r


# ---------------------------------------------------------------- mLSTM
def mlstm_block_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    di = int(d * cfg.expand)                    # inner width (pf=2)
    h = cfg.n_heads
    ks = jax.random.split(key, 9)
    return {
        "up": dense_init(ks[0], d, di, dtype),
        "up_gate": dense_init(ks[1], d, di, dtype),
        "conv": conv1d_init(ks[2], cfg.conv_width, di, dtype),
        "q": dense_init(ks[3], di, di, dtype),
        "k": dense_init(ks[4], di, di, dtype),
        "v": dense_init(ks[5], di, di, dtype),
        "igate": dense_init(ks[6], di, h, dtype, scale=0.01),
        "fgate": dense_init(ks[7], di, h, dtype, scale=0.01),
        "fgate_bias": jnp.full((h,), 3.0, jnp.float32),  # long-memory init
        "gn": norm_init(di, "rms", dtype),      # per-head group norm (rms)
        "down": dense_init(ks[8], di, d, dtype),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk: int, init=None):
    """Exact chunkwise mLSTM. q,k,v (B,H,T,dh); gates (B,H,T) log-space.
    Returns (h (B,H,T,dh), final (C, n, m))."""
    b, h, t, dh = q.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    qc = q.reshape(b, h, nc, chunk, dh)
    kc = k.reshape(b, h, nc, chunk, dh)
    vc = v.reshape(b, h, nc, chunk, dh)
    li = log_i.reshape(b, h, nc, chunk)
    lf = log_f.reshape(b, h, nc, chunk)

    # cumulative log-forget within chunk (inclusive)
    lf_cum = jnp.cumsum(lf, axis=-1)                    # (B,H,nc,c)
    lf_tot = lf_cum[..., -1]                            # (B,H,nc)

    def body(carry, xs):
        C, n, m = carry          # (B,H,dh,dh), (B,H,dh), (B,H)
        qt, kt, vt, lit, lfct, lftot = xs
        # decay of the incoming state to each position: prod f_1..f_j
        dstate = lfct                                    # (B,H,c)
        # gate weight of key j surviving to position i (i>=j):
        # log w_ij = lf_cum[i] - lf_cum[j] + li[j]
        log_w = (lfct[..., :, None] - lfct[..., None, :] + lit[..., None, :])
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        log_w = jnp.where(causal, log_w, -jnp.inf)
        # stabilizer per position: running max of (m_prev + dstate, max log_w)
        m_intra = jnp.max(log_w, axis=-1)                       # (B,H,c)
        m_new = jnp.maximum(m[..., None] + dstate, m_intra)     # (B,H,c)
        # intra-chunk contribution
        w = jnp.exp(log_w - m_new[..., None])                   # (B,H,c,c)
        scores = jnp.einsum("bhid,bhjd->bhij", qt, kt) * (dh ** -0.5)
        num_intra = jnp.einsum("bhij,bhjd->bhid", scores * w, vt)
        den_intra = jnp.einsum("bhij,bhj->bhi", scores * w,
                               jnp.ones_like(lit))
        # inter-chunk (state) contribution
        sw = jnp.exp(m[..., None] + dstate - m_new)             # (B,H,c)
        num_inter = jnp.einsum("bhid,bhde->bhie", qt, C) * sw[..., None] \
            * (dh ** -0.5)
        den_inter = jnp.einsum("bhid,bhd->bhi", qt, n) * sw * (dh ** -0.5)
        den = jnp.maximum(jnp.abs(den_intra + den_inter),
                          jnp.exp(-m_new))                      # xLSTM max(|n|,1)
        h_out = (num_intra + num_inter) / den[..., None]
        # state update to end of chunk
        m_next = jnp.maximum(
            m + lftot, jnp.max(lit + lftot[..., None] - lfct, axis=-1))
        kw = jnp.exp(lit + lftot[..., None] - lfct
                     - m_next[..., None])                       # (B,H,c)
        C_next = (C * jnp.exp(m + lftot - m_next)[..., None, None]
                  + jnp.einsum("bhjd,bhje,bhj->bhde", kt, vt, kw))
        n_next = (n * jnp.exp(m + lftot - m_next)[..., None]
                  + jnp.einsum("bhjd,bhj->bhd", kt, kw))
        return (C_next, n_next, m_next), h_out

    if init is None:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = init
    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (qc, kc, vc, li, lf_cum, lf_tot))
    final, hs = jax.lax.scan(body, (C0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 2).reshape(b, h, t, dh), final


def mlstm_block_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                      state: Optional[Params] = None, chunk: int = 64):
    b, t, d = x.shape
    di = int(d * cfg.expand)
    h = cfg.n_heads
    dh = di // h
    x1 = dense(p["up"], x)
    x2 = dense(p["up_gate"], x)
    xc, conv_state = conv1d_apply(
        p["conv"], x1, None if state is None else state["conv"])
    xc = jax.nn.silu(xc)
    q = dense(p["q"], xc).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    k = dense(p["k"], xc).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = dense(p["v"], x1).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    q, k, v = (a.astype(jnp.float32) for a in (q, k, v))
    log_i = dense(p["igate"], xc).astype(jnp.float32).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(
        dense(p["fgate"], xc).astype(jnp.float32)
        + p["fgate_bias"]).transpose(0, 2, 1)                   # (B,H,T)

    if t > 1:
        pad = (-t) % chunk
        if pad:
            q, k, v = (jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
                       for a in (q, k, v))
            # pad gates so the tail steps are identity: i=0 (no write),
            # f=1 (state preserved) — the final carried state stays exact
            log_i = jnp.pad(log_i, ((0, 0), (0, 0), (0, pad)),
                            constant_values=-1e30)
            log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)))
        init = None if state is None else (state["C"], state["n"], state["m"])
        hout, (Cf, nf, mf) = _mlstm_chunk_scan(
            q, k, v, log_i, log_f, chunk, init=init)
        hout = hout[:, :, :t]
        new_state = {"C": Cf, "n": nf, "m": mf, "conv": conv_state}
    else:
        # single-step decode: C ← f C + i v kᵀ ; h = q·C / max(|q·n|, e^{-m})
        if state is None:
            state = mlstm_init_state(cfg, b, x.dtype)
        C, n, m = state["C"], state["n"], state["m"]
        lit = log_i[..., 0]
        lft = log_f[..., 0]
        m_new = jnp.maximum(lft + m, lit)
        fw = jnp.exp(lft + m - m_new)[..., None]
        iw = jnp.exp(lit - m_new)[..., None]
        kt, vt, qt = k[:, :, 0], v[:, :, 0], q[:, :, 0]
        C = C * fw[..., None] + iw[..., None] * kt[..., :, None] * vt[..., None, :]
        n = n * fw + iw * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C) * (dh ** -0.5)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
                          * (dh ** -0.5), jnp.exp(-m_new))
        hout = (num / den[..., None])[:, :, None]               # (B,H,1,dh)
        new_state = {"C": C, "n": n, "m": m_new, "conv": conv_state}

    hout = hout.transpose(0, 2, 1, 3).reshape(b, t, di).astype(x.dtype)
    hout = apply_norm(p["gn"], hout, "rms")
    y = dense(p["down"], hout * jax.nn.silu(x2))
    return y, new_state


def mlstm_init_state(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    di = int(d * cfg.expand)
    h = cfg.n_heads
    dh = di // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
    }


# ---------------------------------------------------------------- sLSTM
def slstm_block_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 11)
    p: Params = {"gn": norm_init(d, "rms", dtype)}
    for gi, g in enumerate(("i", "f", "z", "o")):
        p[f"w_{g}"] = dense_init(ks[gi], d, d, dtype)
        # block-diagonal recurrent weights: (H, dh, dh)
        p[f"r_{g}"] = (jax.random.normal(ks[4 + gi], (h, dh, dh), jnp.float32)
                       * dh ** -0.5).astype(dtype)
    p["f_bias"] = jnp.full((d,), 3.0, jnp.float32)
    dff = int(d * 4 / 3)
    p["ffn_gate"] = dense_init(ks[8], d, dff, dtype)
    p["ffn_up"] = dense_init(ks[9], d, dff, dtype)
    p["ffn_down"] = dense_init(ks[10], dff, d, dtype)
    return p


def slstm_block_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                      state: Optional[Params] = None):
    """Sequential scan over time (memory mixing forbids parallel forms)."""
    b, t, d = x.shape
    h = cfg.n_heads
    dh = d // h
    wx = {g: dense(p[f"w_{g}"], x).astype(jnp.float32)
          for g in ("i", "f", "z", "o")}
    wx["f"] = wx["f"] + p["f_bias"]
    rw = {g: p[f"r_{g}"].astype(jnp.float32) for g in ("i", "f", "z", "o")}

    if state is None:
        h0 = jnp.zeros((b, d), jnp.float32)
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.ones((b, d), jnp.float32)
        m0 = jnp.zeros((b, d), jnp.float32)
    else:
        h0, c0, n0, m0 = (state[s].astype(jnp.float32)
                          for s in ("h", "c", "n", "m"))

    def rmul(w, hv):    # block-diag recurrent matmul: (B,d)×(H,dh,dh)
        return jnp.einsum("bhd,hde->bhe",
                          hv.reshape(b, h, dh), w).reshape(b, d)

    def step(carry, xs):
        hp, cp, np_, mp = carry
        xi, xf, xz, xo = xs
        it = xi + rmul(rw["i"], hp)
        ft = xf + rmul(rw["f"], hp)
        zt = jnp.tanh(xz + rmul(rw["z"], hp))
        ot = jax.nn.sigmoid(xo + rmul(rw["o"], hp))
        mt = jnp.maximum(jax.nn.log_sigmoid(ft) + mp, it)
        iw = jnp.exp(it - mt)
        fw = jnp.exp(jax.nn.log_sigmoid(ft) + mp - mt)
        ct = fw * cp + iw * zt
        nt = fw * np_ + iw
        ht = ot * ct / jnp.maximum(nt, 1.0)
        return (ht, ct, nt, mt), ht

    xs = tuple(jnp.moveaxis(wx[g], 1, 0) for g in ("i", "f", "z", "o"))
    (hT, cT, nT, mT), hs = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    hout = jnp.moveaxis(hs, 0, 1).astype(x.dtype)               # (B,T,D)
    hout = apply_norm(p["gn"], hout, "rms")
    y = (jax.nn.silu(dense(p["ffn_gate"], hout)) * dense(p["ffn_up"], hout))
    y = dense(p["ffn_down"], y)
    new_state = {"h": hT.astype(x.dtype), "c": cT.astype(x.dtype),
                 "n": nT.astype(x.dtype), "m": mT.astype(x.dtype)}
    return y, new_state


def slstm_init_state(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    return {s: (jnp.ones((batch, d), dtype) if s == "n"
                else jnp.zeros((batch, d), dtype))
            for s in ("h", "c", "n", "m")}


def rglru_init_state(cfg: ArchConfig, batch: int, dtype):
    dr = int(cfg.d_model * cfg.expand)
    return {"h": jnp.zeros((batch, dr), dtype),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), dtype)}
