"""Mixture-of-Experts layer (Arctic dense+MoE hybrid, DeepSeek shared
experts) with capacity-bounded einsum dispatch.

Dispatch is the mesh-TF/MaxText one-hot formulation: static shapes, so it
pjit-shards cleanly (experts over the "model" axis → XLA inserts the
token all_to_all). Tokens over capacity are dropped (standard; the
capacity_factor config bounds drop probability).

The router's top-k over expert logits is the same streaming-top-k problem
as the PGBJ reducer — on TPU both lower onto the kernels' merge network.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import dense, dense_init, mlp_apply, mlp_init

Params = Dict[str, Any]


def moe_init(key, cfg: ArchConfig, dtype):
    mo = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    shapes = {"up": (mo.n_experts, d, mo.expert_ff),
              "down": (mo.n_experts, mo.expert_ff, d)}
    p: Params = {
        "router": dense_init(ks[0], d, mo.n_experts, dtype, scale=0.02),
        "up": (jax.random.normal(ks[1], shapes["up"], jnp.float32)
               * d ** -0.5).astype(dtype),
        "down": (jax.random.normal(ks[2], shapes["down"], jnp.float32)
                 * mo.expert_ff ** -0.5).astype(dtype),
    }
    if mult == 3:
        p["gate"] = (jax.random.normal(ks[3], shapes["up"], jnp.float32)
                     * d ** -0.5).astype(dtype)
    if mo.n_shared:
        p["shared"] = [mlp_init(k, cfg, mo.expert_ff, dtype)
                       for k in jax.random.split(ks[4], mo.n_shared)]
    if mo.dense_residual_ff:
        p["dense"] = mlp_init(ks[5], cfg, mo.dense_residual_ff, dtype)
    return p


_MOE_CHUNK = 4096   # tokens per dispatch block (see moe_apply docstring)


def moe_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """x (B, T, D) → (B, T, D). Auxiliary-loss-free (bias-based balancing
    is a training detail; the dry-run cares about dataflow + flops).

    Token-chunked dispatch: the one-hot dispatch tensor is (N, E, C) with
    C ∝ N/E — i.e. O(N²) bytes in the token count. At train microbatches
    (N ≈ 4k) that is immaterial, but a 32k-token prefill with B=32 is N≈1M
    and the dispatch alone would be hundreds of GiB (observed: 422 GiB on
    deepseek-v2-lite prefill_32k). Scanning over ≤4096-token chunks keeps
    the live dispatch at chunk·E·C_chunk — capacity semantics become
    per-chunk, which if anything balances better (shorter reorder window).
    """
    mo = cfg.moe
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)
    if n > _MOE_CHUNK and n % _MOE_CHUNK == 0:
        nc = n // _MOE_CHUNK
        y = jax.lax.map(
            lambda xc: _moe_tokens(p, xc, cfg), xf.reshape(nc, _MOE_CHUNK, d))
        y = y.reshape(b, t, d)
    else:
        y = _moe_tokens(p, xf, cfg).reshape(b, t, d)

    for sp in p.get("shared", []):
        y = y + mlp_apply(sp, x, cfg.act)
    if "dense" in p:
        y = y + mlp_apply(p["dense"], x, cfg.act)
    return y


def _moe_tokens(p: Params, xf: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Routed-expert compute for a flat (N, D) token block."""
    mo = cfg.moe
    n, d = xf.shape
    e, k = mo.n_experts, mo.top_k
    cap = max(1, int(k * n * mo.capacity_factor / e))
    logits = dense(p["router"], xf).astype(jnp.float32)         # (N, E)
    gates = jax.nn.softmax(logits, -1)
    topw, tope = jax.lax.top_k(gates, k)                        # (N, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer;
    # choice-major priority like mesh-TF (all 1st choices before 2nd)
    sel = jax.nn.one_hot(tope, e, dtype=jnp.float32)            # (N, k, E)
    sel_flat = sel.transpose(1, 0, 2).reshape(k * n, e)
    pos_flat = (jnp.cumsum(sel_flat, axis=0) - 1.0)             # (kN, E)
    pos = (pos_flat * sel_flat).sum(-1).reshape(k, n).T         # (N, k)
    keep = pos < cap
    w = topw * keep

    # dispatch (N, E, C) / combine — one-hot expansions, static shapes
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=xf.dtype)
    disp = jnp.einsum("nke,nkc->nec", sel.astype(xf.dtype) * keep[..., None],
                      pos_oh)
    comb = jnp.einsum("nke,nkc->nec",
                      (sel * w[..., None]).astype(xf.dtype), pos_oh)

    xe = jnp.einsum("nec,nd->ecd", disp, xf)                    # (E, C, D)
    if "gate" in p:
        he = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"]))
              * jnp.einsum("ecd,edf->ecf", xe, p["up"]))
    else:
        he = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", xe, p["up"])))
    ye = jnp.einsum("ecf,efd->ecd", he, p["down"])              # (E, C, D)
    return jnp.einsum("nec,ecd->nd", comb, ye)
