"""LM substrate: composable model definitions for the assigned archs."""
from .model import (
    ModelOptions, count_params, encode, forward, init_cache, init_params)

__all__ = ["ModelOptions", "count_params", "encode", "forward",
           "init_cache", "init_params"]
