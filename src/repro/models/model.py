"""Full LM assembly: embedding → scanned super-blocks → norm → head.

The layer stack follows ``cfg.layout()``: each (unit, reps) group is one
``jax.lax.scan`` over params stacked along a leading ``reps`` axis —
constant-size HLO regardless of depth (an 88-layer granite compiles as
fast as a 2-layer smoke config). ``jax.checkpoint`` wraps the scan body in
train mode (per-layer remat; the gradient-accumulation loop in
train/train_step.py handles the batch dimension of memory).

Modes:
  train/prefill — full sequence, cache optional (prefill fills it)
  decode        — seq == 1 against a cache/state
Enc-dec (whisper): ``enc_frames`` (stub frontend output) is encoded once;
decoder cross-attends. VLM (qwen2-vl): ``vision_embeds`` overwrite the
first n_vision positions (stub vision tower).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ATTN_BIDIR
from repro.distributed.sharding import shard
from .blocks import block_apply, block_init, init_block_cache
from .layers import apply_norm, dense, norm_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    dtype: Any = jnp.bfloat16
    remat: bool = True
    chunk_q: int = 2048      # q-chunked attention above this seq length
    max_abs_pos: int = 4096  # learned pos-embed table (rope == "none")
    # serving layout: decode treats big KV caches as read-only inputs and
    # returns fresh kv for out-of-band append (length-sharded caches never
    # round-trip through dynamic-update-slice) — see layers.attn_apply
    readonly_cache: bool = False


def _stack_init(unit, reps, key, cfg, dtype):
    """Params for one scan group: each leaf gains a leading (reps,) axis."""
    def init_one(k):
        ks = jax.random.split(k, len(unit))
        return {f"l{j}_{kind}": block_init(kind, ks[j], cfg, dtype)
                for j, kind in enumerate(unit)}
    return jax.vmap(init_one)(jax.random.split(key, reps))


def init_params(cfg: ArchConfig, key, opts: ModelOptions = ModelOptions()):
    dtype = opts.dtype
    ks = jax.random.split(key, 8)
    params: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if cfg.rope == "none" and cfg.abs_pos:
        params["pos_embed"] = (jax.random.normal(
            ks[1], (opts.max_abs_pos, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            ks[2], (cfg.d_model, cfg.vocab), jnp.float32)
            * cfg.d_model ** -0.5).astype(dtype)
    groups = []
    gkeys = jax.random.split(ks[3], len(list(cfg.layout())))
    for gk, (unit, reps) in zip(gkeys, cfg.layout()):
        groups.append(_stack_init(unit, reps, gk, cfg, dtype))
    params["groups"] = groups
    if cfg.n_enc_layers:
        params["encoder"] = _stack_init(
            (ATTN_BIDIR,), cfg.n_enc_layers, ks[4], cfg, dtype)
        params["enc_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
        params["enc_pos_embed"] = (jax.random.normal(
            ks[5], (cfg.enc_len, cfg.d_model), jnp.float32) * 0.02
            ).astype(dtype)
    return params


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               opts: ModelOptions = ModelOptions()):
    """Stacked decode caches mirroring the params group structure."""
    groups = []
    for unit, reps in cfg.layout():
        one = {f"l{j}_{kind}": init_block_cache(
            kind, cfg, batch, cache_len, opts.dtype)
            for j, kind in enumerate(unit)}
        groups.append(jax.tree_util.tree_map(
            lambda x: jnp.tile(x, (reps,) + (1,) * x.ndim), one))
    return groups


def _scan_group(unit, gparams, x, cfg, *, positions, gcache, enc_out,
                chunk_q, remat, readonly=False):
    """Scan one (unit, reps) group; cache (if any) rides as scan xs/ys."""
    def body(carry, xs):
        h = carry
        lp, lc = xs
        new_lc = {} if lc is not None else None
        for j, kind in enumerate(unit):
            name = f"l{j}_{kind}"
            c = None if lc is None else lc[name]
            h, nc = block_apply(
                kind, lp[name], h, cfg, positions=positions, cache=c,
                enc_out=enc_out, chunk_q=chunk_q, readonly=readonly)
            if new_lc is not None:
                new_lc[name] = nc
        return h, new_lc

    wrapped = jax.checkpoint(body) if remat else body
    x, new_cache = jax.lax.scan(wrapped, x, (gparams, gcache))
    return x, new_cache


def encode(params: Params, cfg: ArchConfig, enc_frames: jnp.ndarray,
           opts: ModelOptions = ModelOptions()):
    """Whisper encoder over stub frame embeddings (B, enc_len, D)."""
    x = enc_frames.astype(opts.dtype) + params["enc_pos_embed"][None]
    pos = jnp.broadcast_to(jnp.arange(cfg.enc_len)[None],
                           (x.shape[0], cfg.enc_len))
    x, _ = _scan_group((ATTN_BIDIR,), params["encoder"], x, cfg,
                       positions=pos, gcache=None, enc_out=None,
                       chunk_q=opts.chunk_q, remat=opts.remat)
    return apply_norm(params["enc_norm"], x, cfg.norm)


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,                    # (B, T) int32
    *,
    positions: Optional[jnp.ndarray] = None,  # (B,T) or (3,B,T); default iota
    cache: Optional[Any] = None,
    enc_frames: Optional[jnp.ndarray] = None,
    enc_out: Optional[jnp.ndarray] = None,
    vision_embeds: Optional[jnp.ndarray] = None,
    opts: ModelOptions = ModelOptions(),
    mode: str = "train",
) -> Tuple[jnp.ndarray, Optional[Any]]:
    """Returns (logits (B,T,V) float32, new_cache)."""
    b, t = tokens.shape
    if positions is None:
        base = jnp.arange(t, dtype=jnp.int32)[None]
        if cache is not None and mode == "decode":
            base = base + _cache_pos(cache)
        positions = jnp.broadcast_to(base, (b, t))
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, t))

    x = jnp.take(params["embed"], tokens, axis=0).astype(opts.dtype)
    x = shard(x, "batch", None, None)
    if cfg.rope == "none" and cfg.abs_pos:
        pos2 = positions if positions.ndim == 2 else positions[0]
        x = x + jnp.take(params["pos_embed"], pos2, axis=0).astype(opts.dtype)
    if (vision_embeds is not None and cfg.n_vision_embeds
            and mode != "decode"):
        nv = cfg.n_vision_embeds
        x = jnp.concatenate(
            [vision_embeds.astype(opts.dtype), x[:, nv:]], axis=1)

    if cfg.n_enc_layers and enc_out is None:
        assert enc_frames is not None, "enc-dec arch needs enc_frames"
        enc_out = encode(params, cfg, enc_frames, opts)

    chunk_q = opts.chunk_q if t > opts.chunk_q else 0
    remat = opts.remat and mode == "train"
    new_groups = []
    cache = cache if cache is not None else [None] * len(list(cfg.layout()))
    for gi, (unit, reps) in enumerate(cfg.layout()):
        x, nc = _scan_group(
            unit, params["groups"][gi], x, cfg, positions=positions,
            gcache=cache[gi], enc_out=enc_out, chunk_q=chunk_q, remat=remat,
            readonly=opts.readonly_cache and mode == "decode")
        new_groups.append(nc)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = (x @ head).astype(jnp.float32)
    logits = shard(logits, "batch", None, "model")
    return logits, (new_groups if any(c is not None for c in new_groups)
                    else None)


def _cache_pos(cache):
    """Current decode position from the first attention-style cache."""
    for g in cache:
        if g is None:
            continue
        for layer in jax.tree_util.tree_leaves(
                g, is_leaf=lambda n: isinstance(n, dict) and "pos" in n):
            if isinstance(layer, dict) and "pos" in layer:
                return layer["pos"][0] if layer["pos"].ndim else layer["pos"]
    return jnp.zeros((), jnp.int32)


def count_params(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
