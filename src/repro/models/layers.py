"""Primitive layers for the LM substrate (pure-functional, pjit-friendly).

Parameters are nested dicts of jnp arrays. Initializers take an explicit
PRNG key and dtype; applies are shape-polymorphic over batch/seq so the
same code serves train (full seq), prefill, and decode (seq=1 + cache).

The attention here is the jnp path the dry-run lowers; on real TPUs the
Pallas flash kernel (repro.kernels) slots in via ``impl`` — identical math.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Params = Dict[str, Any]


# ----------------------------------------------------------------- utils
def dense_init(key, d_in: int, d_out: int, dtype, *, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
                  * scale).astype(dtype)}


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"]


def norm_init(d: int, kind: str, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "ln":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6):
    """Statistics in f32, elementwise math in the input dtype.

    The f32 upcast feeds ONLY the reductions (so it fuses into them and is
    never materialized). An `x.astype(f32)` with multiple consumers gets
    hoisted by XLA out of the layer scan's backward into a bulk f32 copy
    of the whole saved-residual stack — +8.25 GiB on granite-34b train
    (EXPERIMENTS.md §Perf iter 4)."""
    if kind == "rms":
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * p["scale"]
    mu32 = jnp.mean(x.astype(jnp.float32), -1, keepdims=True)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True) \
        - jnp.square(mu32)
    inv = jax.lax.rsqrt(jnp.maximum(var, 0.0) + eps).astype(x.dtype)
    return (x - mu32.astype(x.dtype)) * inv * p["scale"] + p["bias"]


# ------------------------------------------------------------------ RoPE
def rope_freqs(dh_half: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(dh_half, dtype=jnp.float32) / dh_half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x (B, T, H, dh), positions (B, T) int32 → rotated x (split halves)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh // 2, theta)                          # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (B, T, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]):
    """M-RoPE (Qwen2-VL): positions (3, B, T) — temporal/height/width ids
    drive disjoint frequency sections of the half-dim."""
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    freqs = rope_freqs(dh // 2, theta)                          # (dh/2,)
    # pick which position axis (t/h/w) drives each frequency slot
    sect_id = jnp.repeat(jnp.arange(len(sections)),
                         jnp.asarray(sections), total_repeat_length=dh // 2)
    pos_all = jnp.moveaxis(positions, 0, -1).astype(jnp.float32)  # (B,T,3)
    pos_slot = jnp.take_along_axis(
        pos_all,
        jnp.broadcast_to(sect_id[None, None, :],
                         pos_all.shape[:-1] + (dh // 2,)),
        axis=-1)                                                # (B,T,dh/2)
    angles = pos_slot * freqs                                   # (B, T, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLP
def mlp_init(key, cfg: ArchConfig, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = {"down": dense_init(ks[0], d_ff, d, dtype)}
    if cfg.act in ("swiglu", "geglu"):
        p["gate"] = dense_init(ks[1], d, d_ff, dtype)
        p["up"] = dense_init(ks[2], d, d_ff, dtype)
    else:
        p["up"] = dense_init(ks[1], d, d_ff, dtype)
    return p


def mlp_apply(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    elif act == "geglu":
        h = jax.nn.gelu(dense(p["gate"], x)) * dense(p["up"], x)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(dense(p["up"], x)))
    elif act == "gelu":
        h = jax.nn.gelu(dense(p["up"], x))
    else:
        raise ValueError(act)
    return dense(p["down"], h)


# ------------------------------------------------------- core attention
def _sdpa(q, k, v, *, causal: bool, window: Optional[int],
          q_offset, softcap: float, chunk_q: int = 0):
    """Softmax attention. q (B,T,H,dh); k,v (B,C,H,dh) (kv already
    head-repeated). ``q_offset``: position of q[0] on the kv timeline —
    int or (B,) array. Full-logit path, optionally scanned over q chunks."""
    b, tq, h, dh = q.shape
    scale = dh ** -0.5

    def block(qc, off_extra):
        # qc (B, tc, H, dh); off_extra: static int chunk offset
        logits = jnp.einsum("bqhd,bkhd->bhqk", qc, k).astype(jnp.float32)
        logits *= scale
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        qpos = jnp.arange(qc.shape[1])[:, None] + off_extra       # (tc,1)
        if isinstance(q_offset, jnp.ndarray) and q_offset.ndim == 1:
            qpos = qpos[None] + q_offset[:, None, None]           # (B,tc,1)
        else:
            qpos = (qpos + q_offset)[None]                        # (1,tc,1)
        kpos = jnp.arange(k.shape[1])[None, None, :]              # (1,1,C)
        mask = jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[:, None], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)

    dv = v.shape[-1]                    # may differ from dh (MLA)
    if chunk_q and tq > chunk_q and tq % chunk_q == 0:
        qs = q.reshape(b, tq // chunk_q, chunk_q, h, dh)

        def body(_, it):
            qc, i = it
            return None, jax.checkpoint(
                lambda qq: block(qq, i * chunk_q))(qc)

        _, out = jax.lax.scan(
            body, None, (jnp.moveaxis(qs, 1, 0),
                         jnp.arange(tq // chunk_q)))
        return jnp.moveaxis(out, 0, 1).reshape(b, tq, h, dv)
    return block(q, 0)


def repeat_kv(x: jnp.ndarray, rep: int) -> jnp.ndarray:
    if rep == 1:
        return x
    return jnp.repeat(x, rep, axis=2)


# ------------------------------------------------------------ attention
def attn_init(key, cfg: ArchConfig, dtype, *, cross: bool = False):
    d, dh = cfg.d_model, cfg.dh
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "q": dense_init(ks[0], d, h * dh, dtype),
        "k": dense_init(ks[1], d, kvh * dh, dtype),
        "v": dense_init(ks[2], d, kvh * dh, dtype),
        "o": dense_init(ks[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(dh, "rms", dtype)
        p["k_norm"] = norm_init(dh, "rms", dtype)
    return p


def attn_apply(
    p: Params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    *,
    positions: jnp.ndarray,            # (B, T) or (3, B, T) for mrope
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[Params] = None,    # {"k","v","pos"} decode cache
    xattn_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    chunk_q: int = 0,
    readonly: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Self- or cross-attention with optional KV cache update."""
    b, t, d = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = dense(p["q"], x).reshape(b, t, h, dh)
    if xattn_kv is not None:
        k, v = xattn_kv                                  # precomputed (B,C,kvh,dh)
        new_cache = None
        q_off = 0
        causal, window = False, None
    else:
        k = dense(p["k"], x).reshape(b, t, kvh, dh)
        v = dense(p["v"], x).reshape(b, t, kvh, dh)
        if cfg.qk_norm:
            q = apply_norm(p["q_norm"], q, "rms")
            k = apply_norm(p["k_norm"], k, "rms")
        if cfg.rope == "std":
            pos2 = positions if positions.ndim == 2 else positions[0]
            q = apply_rope(q, pos2, cfg.rope_theta)
            k = apply_rope(k, pos2, cfg.rope_theta)
        elif cfg.rope == "mrope":
            assert positions.ndim == 3, "mrope needs (3, B, T) positions"
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        if cache is not None and window is not None and cache["k"].shape[1] <= window:
            # ring-buffer cache for local attention (decode, t == 1):
            # slot j holds global index pos - ((pos - j) mod W)
            assert t == 1, "ring cache supports single-step decode only"
            w_sz = cache["k"].shape[1]
            pos = cache["pos"]
            slot = jnp.mod(pos, w_sz)
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
            new_cache = {"k": ck, "v": cv, "pos": pos + 1}
            slot_idx = jnp.arange(w_sz)
            global_idx = pos - jnp.mod(pos - slot_idx, w_sz)    # (W,)
            valid = global_idx >= 0
            q = q.astype(jnp.float32)
            logits = jnp.einsum(
                "bqhd,bkhd->bhqk", q,
                repeat_kv(ck, h // kvh).astype(jnp.float32)) * (dh ** -0.5)
            if cfg.attn_logit_softcap > 0:
                logits = jnp.tanh(logits / cfg.attn_logit_softcap) \
                    * cfg.attn_logit_softcap
            logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
            probs = jax.nn.softmax(logits, -1)
            out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(x.dtype),
                             repeat_kv(cv, h // kvh))
            return dense(p["o"], out.reshape(b, t, h * dh)), new_cache
        elif cache is not None and readonly:
            # serving layout: the big cache is a read-only input (sharded
            # along its length on the model axis); the step's fresh kv is
            # returned for out-of-band append (vLLM-style page write).
            # Softmax merges the two pieces — the cache is never gathered.
            assert t == 1, "readonly cache is a decode-only path"
            pos = cache["pos"]
            ck, cv = cache["k"], cache["v"]
            qf = q.astype(jnp.float32)
            scale = dh ** -0.5
            lc = jnp.einsum("bqhd,bkhd->bhqk", qf,
                            repeat_kv(ck, h // kvh).astype(jnp.float32))
            lc = lc * scale
            kpos = jnp.arange(ck.shape[1])[None, None, None]
            lc = jnp.where(kpos < pos, lc, -jnp.inf)
            ln = jnp.einsum("bqhd,bkhd->bhqk", qf,
                            repeat_kv(k, h // kvh).astype(jnp.float32))
            ln = ln * scale
            if cfg.attn_logit_softcap > 0:
                cap = cfg.attn_logit_softcap
                lc, ln = jnp.tanh(lc / cap) * cap, jnp.tanh(ln / cap) * cap
            m = jnp.maximum(jnp.max(lc, -1, keepdims=True),
                            jnp.max(ln, -1, keepdims=True))
            pc, pn = jnp.exp(lc - m), jnp.exp(ln - m)
            denom = pc.sum(-1, keepdims=True) + pn.sum(-1, keepdims=True)
            out = (jnp.einsum("bhqk,bkhd->bqhd", pc / denom,
                              repeat_kv(cv, h // kvh).astype(jnp.float32))
                   + jnp.einsum("bhqk,bkhd->bqhd", pn / denom,
                                repeat_kv(v, h // kvh).astype(jnp.float32))
                   ).astype(x.dtype)
            new_cache = {"k_new": k, "v_new": v, "pos": pos + t}
            return dense(p["o"], out.reshape(b, t, h * dh)), new_cache
        elif cache is not None:
            # decode: write new kv at index `pos` (same for whole batch)
            pos = cache["pos"]                            # scalar int32
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, 1)
            new_cache = {"k": ck, "v": cv, "pos": pos + t}
            k, v = ck, cv
            q_off = pos
        else:
            new_cache = None
            q_off = 0
    out = _sdpa(q, repeat_kv(k, h // k.shape[2]), repeat_kv(v, h // v.shape[2]),
                causal=causal, window=window, q_offset=q_off,
                softcap=cfg.attn_logit_softcap, chunk_q=chunk_q)
    return dense(p["o"], out.reshape(b, t, h * dh)), new_cache


# ------------------------------------------------------------------ MLA
def mla_init(key, cfg: ArchConfig, dtype):
    c = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        # queries: full-rank (lite model has no q-lora)
        "q": dense_init(ks[0], d, h * (c.qk_nope_head_dim + c.rope_head_dim),
                        dtype),
        # compressed kv + shared rope key
        "dkv": dense_init(ks[1], d, c.kv_lora_rank, dtype),
        "k_rope": dense_init(ks[2], d, c.rope_head_dim, dtype),
        "kv_norm": norm_init(c.kv_lora_rank, "rms", dtype),
        # up-projections out of the latent
        "uk": dense_init(ks[3], c.kv_lora_rank, h * c.qk_nope_head_dim, dtype),
        "uv": dense_init(ks[4], c.kv_lora_rank, h * c.v_head_dim, dtype),
        "o": dense_init(ks[5], h * c.v_head_dim, d, dtype),
    }


def mla_apply(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
    positions: jnp.ndarray, cache: Optional[Params] = None,
    chunk_q: int = 0, absorb: bool = True, readonly: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Multi-head Latent Attention (DeepSeek-V2). The decode path uses the
    weight-absorbed form: scores come from the *compressed* cache directly,
    so per-step work is O(C · kv_lora) not O(C · H · dh)."""
    c = cfg.mla
    b, t, d = x.shape
    h = cfg.n_heads
    dq = c.qk_nope_head_dim + c.rope_head_dim

    q = dense(p["q"], x).reshape(b, t, h, dq)
    q_nope, q_rope = q[..., :c.qk_nope_head_dim], q[..., c.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = apply_norm(p["kv_norm"], dense(p["dkv"], x), "rms")   # (B,T,L)
    k_rope = dense(p["k_rope"], x).reshape(b, t, 1, c.rope_head_dim)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]

    if cache is not None and readonly:
        # serving layout: compressed cache is read-only (sharded on length);
        # fresh latent is returned for out-of-band append.
        assert t == 1
        pos = cache["pos"]
        wuk = p["uk"]["w"].reshape(c.kv_lora_rank, h, c.qk_nope_head_dim)
        wuv = p["uv"]["w"].reshape(c.kv_lora_rank, h, c.v_head_dim)
        scale = (c.qk_nope_head_dim + c.rope_head_dim) ** -0.5
        q_abs = jnp.einsum("bthd,lhd->bthl", q_nope, wuk)
        lc = (jnp.einsum("bthl,bcl->bhtc", q_abs, cache["ckv"])
              + jnp.einsum("bthd,bcd->bhtc", q_rope, cache["k_rope"])
              ).astype(jnp.float32) * scale
        kpos = jnp.arange(cache["ckv"].shape[1])[None, None, None]
        lc = jnp.where(kpos < pos, lc, -jnp.inf)
        ln = (jnp.einsum("bthl,bcl->bhtc", q_abs, ckv)
              + jnp.einsum("bthd,bcd->bhtc", q_rope, k_rope)
              ).astype(jnp.float32) * scale
        m = jnp.maximum(jnp.max(lc, -1, keepdims=True),
                        jnp.max(ln, -1, keepdims=True))
        pc, pn = jnp.exp(lc - m), jnp.exp(ln - m)
        denom = pc.sum(-1, keepdims=True) + pn.sum(-1, keepdims=True)
        o_lat = (jnp.einsum("bhtc,bcl->bthl", (pc / denom).astype(x.dtype),
                            cache["ckv"])
                 + jnp.einsum("bhtc,bcl->bthl", (pn / denom).astype(x.dtype),
                              ckv))
        out = jnp.einsum("bthl,lhv->bthv", o_lat, wuv)
        new_cache = {"ckv_new": ckv, "k_rope_new": k_rope, "pos": pos + t}
        return dense(p["o"], out.reshape(b, t, h * c.v_head_dim)), new_cache

    if cache is not None:
        pos = cache["pos"]
        ckv_all = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, pos, 1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, pos, 1)
        new_cache = {"ckv": ckv_all, "k_rope": kr_all, "pos": pos + t}
        q_off = pos
    else:
        ckv_all, kr_all = ckv, k_rope
        new_cache = None
        q_off = 0

    scale = (c.qk_nope_head_dim + c.rope_head_dim) ** -0.5
    if cache is not None and absorb:
        # absorbed decode: q_abs (B,T,H,L); scores vs latent cache directly
        wuk = p["uk"]["w"].reshape(c.kv_lora_rank, h, c.qk_nope_head_dim)
        q_abs = jnp.einsum("bthd,lhd->bthl", q_nope, wuk)
        logits = (jnp.einsum("bthl,bcl->bhtc", q_abs, ckv_all)
                  + jnp.einsum("bthd,bcd->bhtc", q_rope, kr_all)
                  ).astype(jnp.float32) * scale
        qpos = jnp.arange(t)[None, :, None] + q_off
        kpos = jnp.arange(ckv_all.shape[1])[None, None, :]
        logits = jnp.where((kpos <= qpos)[:, None], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, -1).astype(x.dtype)
        o_lat = jnp.einsum("bhtc,bcl->bthl", probs, ckv_all)    # (B,T,H,L)
        wuv = p["uv"]["w"].reshape(c.kv_lora_rank, h, c.v_head_dim)
        out = jnp.einsum("bthl,lhv->bthv", o_lat, wuv)
    else:
        # train/prefill: expand latent to per-head K/V (flops-optimal here)
        k_nope = dense(p["uk"], ckv_all).reshape(b, -1, h, c.qk_nope_head_dim)
        v = dense(p["uv"], ckv_all).reshape(b, -1, h, c.v_head_dim)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None],
                                      kr_all.shape[:2] + (h, c.rope_head_dim))],
            axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _sdpa(q_full, k_full, v, causal=True, window=None,
                    q_offset=q_off, softcap=0.0, chunk_q=chunk_q)
    return dense(p["o"], out.reshape(b, t, h * c.v_head_dim)), new_cache
