"""Transformer/recurrent blocks — one (init, apply) pair per layer kind.

Every block is pre-norm residual. ``apply`` returns (x, new_cache); cache
pytrees are kind-specific and stacked along the scan axis by model.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ArchConfig, ATTN, ATTN_BIDIR, LOCAL, MLSTM, RGLRU, SLSTM, XATTN)
from repro.distributed.sharding import shard
from . import recurrent as R
from .layers import (
    attn_apply, attn_init, apply_norm, dense, mla_apply, mla_init,
    mlp_apply, mlp_init, norm_init)
from .moe import moe_apply, moe_init

Params = Dict[str, Any]


def _ffn_init(key, cfg: ArchConfig, dtype, *, dense_ff: int = 0):
    """MoE or dense FFN depending on the arch (dense_ff overrides MoE)."""
    if cfg.moe is not None and not dense_ff:
        return {"moe": moe_init(key, cfg, dtype)}
    return {"mlp": mlp_init(key, cfg, dense_ff or cfg.d_ff, dtype)}


def _ffn_apply(p: Params, x, cfg: ArchConfig):
    if "moe" in p:
        return moe_apply(p["moe"], x, cfg)
    return mlp_apply(p["mlp"], x, cfg.act)


def block_init(kind: str, key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    base = kind.replace("_dense", "")
    dense_ff = (cfg.moe.first_dense_ff
                if (cfg.moe and kind.endswith("_dense")) else 0)
    if base in (ATTN, ATTN_BIDIR, LOCAL):
        attn = (mla_init(ks[0], cfg, dtype) if cfg.mla is not None
                else attn_init(ks[0], cfg, dtype))
        return {
            "norm1": norm_init(cfg.d_model, cfg.norm, dtype),
            "attn": attn,
            "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
            **_ffn_init(ks[1], cfg, dtype, dense_ff=dense_ff),
        }
    if base == XATTN:
        return {
            "norm1": norm_init(cfg.d_model, cfg.norm, dtype),
            "attn": attn_init(ks[0], cfg, dtype),
            "normx": norm_init(cfg.d_model, cfg.norm, dtype),
            "xattn": attn_init(ks[1], cfg, dtype),
            "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
            **_ffn_init(ks[2], cfg, dtype, dense_ff=dense_ff),
        }
    if base == RGLRU:
        return {
            "norm1": norm_init(cfg.d_model, cfg.norm, dtype),
            "rnn": R.rglru_block_init(ks[0], cfg, dtype),
            "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
            **_ffn_init(ks[1], cfg, dtype, dense_ff=dense_ff),
        }
    if base == MLSTM:
        return {"norm1": norm_init(cfg.d_model, cfg.norm, dtype),
                "cell": R.mlstm_block_init(ks[0], cfg, dtype)}
    if base == SLSTM:
        return {"norm1": norm_init(cfg.d_model, cfg.norm, dtype),
                "cell": R.slstm_block_init(ks[0], cfg, dtype)}
    raise ValueError(kind)


def block_apply(
    kind: str,
    p: Params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    *,
    positions: jnp.ndarray,
    cache: Optional[Params] = None,
    enc_out: Optional[jnp.ndarray] = None,
    chunk_q: int = 0,
    readonly: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    base = kind.replace("_dense", "")
    new_cache: Optional[Params] = None
    if base in (ATTN, ATTN_BIDIR, LOCAL):
        h = apply_norm(p["norm1"], x, cfg.norm)
        if cfg.mla is not None:
            a, new_cache = mla_apply(
                p["attn"], h, cfg, positions=positions, cache=cache,
                chunk_q=chunk_q, readonly=readonly)
        else:
            a, new_cache = attn_apply(
                p["attn"], h, cfg, positions=positions,
                causal=base != ATTN_BIDIR,
                window=cfg.local_window if base == LOCAL else None,
                cache=cache, chunk_q=chunk_q, readonly=readonly)
        x = x + a
        x = shard(x, "batch", None, None)
        x = x + _ffn_apply(p, apply_norm(p["norm2"], x, cfg.norm), cfg)
        x = shard(x, "batch", None, None)
        return x, new_cache
    if base == XATTN:
        h = apply_norm(p["norm1"], x, cfg.norm)
        self_cache = None if cache is None else cache.get("self")
        a, new_self = attn_apply(
            p["attn"], h, cfg, positions=positions, causal=True,
            cache=self_cache, chunk_q=chunk_q, readonly=readonly)
        x = x + a
        hx = apply_norm(p["normx"], x, cfg.norm)
        if enc_out is None and cache is not None and "xk" in cache:
            # decode without the encoder: reuse prefill's projected enc kv
            xk, xv = cache["xk"], cache["xv"]
        else:
            b = x.shape[0]
            kvh, dh = cfg.n_kv_heads, cfg.dh
            xk = dense(p["xattn"]["k"], enc_out).reshape(b, -1, kvh, dh)
            xv = dense(p["xattn"]["v"], enc_out).reshape(b, -1, kvh, dh)
        xa, _ = attn_apply(
            p["xattn"], hx, cfg, positions=positions, xattn_kv=(xk, xv))
        x = x + xa
        x = x + _ffn_apply(p, apply_norm(p["norm2"], x, cfg.norm), cfg)
        if cache is not None or new_self is not None:
            new_cache = {"self": new_self, "xk": xk, "xv": xv}
        return x, new_cache
    if base == RGLRU:
        h = apply_norm(p["norm1"], x, cfg.norm)
        a, new_cache = R.rglru_block_apply(p["rnn"], h, cfg, state=cache)
        x = x + a
        x = x + _ffn_apply(p, apply_norm(p["norm2"], x, cfg.norm), cfg)
        return x, new_cache
    if base == MLSTM:
        h = apply_norm(p["norm1"], x, cfg.norm)
        a, new_cache = R.mlstm_block_apply(p["cell"], h, cfg, state=cache)
        return x + a, new_cache
    if base == SLSTM:
        h = apply_norm(p["norm1"], x, cfg.norm)
        a, new_cache = R.slstm_block_apply(p["cell"], h, cfg, state=cache)
        return x + a, new_cache
    raise ValueError(kind)


def init_block_cache(kind: str, cfg: ArchConfig, batch: int, cache_len: int,
                     dtype) -> Optional[Params]:
    """Decode-time cache pytree for one layer of this kind."""
    base = kind.replace("_dense", "")
    kvh, dh = cfg.n_kv_heads, cfg.dh
    if base in (ATTN, ATTN_BIDIR):
        if cfg.mla is not None:
            c = cfg.mla
            return {"ckv": jnp.zeros((batch, cache_len, c.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((batch, cache_len, c.rope_head_dim),
                                        dtype),
                    "pos": jnp.zeros((), jnp.int32)}
        return {"k": jnp.zeros((batch, cache_len, kvh, dh), dtype),
                "v": jnp.zeros((batch, cache_len, kvh, dh), dtype),
                "pos": jnp.zeros((), jnp.int32)}
    if base == LOCAL:
        w = min(cfg.local_window, cache_len)
        return {"k": jnp.zeros((batch, w, kvh, dh), dtype),
                "v": jnp.zeros((batch, w, kvh, dh), dtype),
                "pos": jnp.zeros((), jnp.int32)}
    if base == XATTN:
        return {
            "self": {"k": jnp.zeros((batch, cache_len, kvh, dh), dtype),
                     "v": jnp.zeros((batch, cache_len, kvh, dh), dtype),
                     "pos": jnp.zeros((), jnp.int32)},
            "xk": jnp.zeros((batch, cfg.enc_len, kvh, dh), dtype),
            "xv": jnp.zeros((batch, cfg.enc_len, kvh, dh), dtype),
        }
    if base == RGLRU:
        return R.rglru_init_state(cfg, batch, dtype)
    if base == MLSTM:
        return R.mlstm_init_state(cfg, batch, dtype)
    if base == SLSTM:
        return R.slstm_init_state(cfg, batch, dtype)
    raise ValueError(kind)
