"""Exporters for the flight recorder: JSONL spans, Chrome trace events
(Perfetto-loadable), Prometheus text metrics, and the per-query
``explain`` span-tree reconstruction.

All exporters are read-only views over ``Tracer.spans()`` /
``Registry`` snapshots — nothing here touches devices or the serving
hot path.
"""
from __future__ import annotations

import json
from typing import IO, List, Optional, Sequence, Union

from .metrics import Histogram, Registry
from .trace import Span, Tracer, current

__all__ = ["ExplainNode", "chrome_trace", "explain", "format_explain",
           "render_prometheus", "spans_to_jsonl", "write_chrome_trace",
           "write_jsonl"]


def _json_safe(v):
    """Span attributes may carry numpy scalars and tuples; make them
    JSON-clean without importing numpy (duck-typed via ``item()``)."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return _json_safe(v.item())
        except (ValueError, TypeError):
            pass
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    return repr(v)


# ---------------------------------------------------------------------------
# JSONL span dump


def spans_to_jsonl(spans: Sequence[Span]) -> str:
    """One JSON object per line per span — the grep/jq-friendly dump."""
    lines = []
    for sp in spans:
        d = sp.to_dict()
        d["attrs"] = _json_safe(d["attrs"])
        lines.append(json.dumps(d, sort_keys=True))
    return "\n".join(lines) + ("\n" if lines else "")


def write_jsonl(spans: Sequence[Span], path_or_file: Union[str, IO]) -> None:
    text = spans_to_jsonl(spans)
    if hasattr(path_or_file, "write"):
        path_or_file.write(text)
        return
    with open(path_or_file, "w") as fh:
        fh.write(text)


# ---------------------------------------------------------------------------
# Chrome trace-event format (load in Perfetto / chrome://tracing)


def chrome_trace(spans: Sequence[Span]) -> dict:
    """Complete ("X"-phase) trace events, microsecond timestamps, one
    Perfetto track per recording thread. Instants (zero-duration spans)
    render as "i"-phase marks so failovers/deadline-rechecks show up as
    flags on the timeline."""
    events = []
    for sp in spans:
        args = _json_safe(sp.attrs) or {}
        args["span_id"] = sp.span_id
        if sp.parent_id:
            args["parent_id"] = sp.parent_id
        ev = dict(name=sp.name, pid=0, tid=sp.thread,
                  ts=sp.t0 * 1e6, args=args)
        if sp.t1 > sp.t0:
            ev["ph"] = "X"
            ev["dur"] = (sp.t1 - sp.t0) * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"          # thread-scoped instant
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans: Sequence[Span],
                       path_or_file: Union[str, IO]) -> None:
    doc = chrome_trace(spans)
    if hasattr(path_or_file, "write"):
        json.dump(doc, path_or_file)
        return
    with open(path_or_file, "w") as fh:
        json.dump(doc, fh)


# ---------------------------------------------------------------------------
# Prometheus text rendering


def render_prometheus(registry: Registry) -> str:
    """Prometheus exposition text format v0.0.4: counters/gauges as-is,
    histograms as cumulative ``_bucket{le=...}`` series + ``_sum`` /
    ``_count`` (quantiles are the scraper's job there; use
    ``Registry.snapshot()`` for the precomputed p50/p99/p999)."""
    out: List[str] = []
    seen_types = set()
    for m in registry.metrics():
        if m.name not in seen_types:
            out.append(f"# TYPE {m.name} {m.kind}")
            seen_types.add(m.name)
        labels = dict(m.labels)
        if isinstance(m, Histogram):
            cum = 0
            counts = m.bucket_counts()
            for bound, c in zip(m.bounds, counts):
                cum += c
                lab = _fmt_labels({**labels, "le": _fmt_float(bound)})
                out.append(f"{m.name}_bucket{lab} {cum}")
            cum += counts[-1]
            lab = _fmt_labels({**labels, "le": "+Inf"})
            out.append(f"{m.name}_bucket{lab} {cum}")
            base = _fmt_labels(labels)
            out.append(f"{m.name}_sum{base} {_fmt_float(m.sum)}")
            out.append(f"{m.name}_count{base} {m.count}")
        else:
            out.append(f"{m.name}{_fmt_labels(labels)} "
                       f"{_fmt_float(m.value)}")
    return "\n".join(out) + ("\n" if out else "")


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_float(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


# ---------------------------------------------------------------------------
# per-query explain: reconstruct one request's span tree


class ExplainNode:
    """One span plus its children, ordered by start time."""

    __slots__ = ("span", "children")

    def __init__(self, span: Span):
        self.span = span
        self.children: List[ExplainNode] = []

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()


def _matches_ticket(sp: Span, tid: int) -> bool:
    a = sp.attrs
    if a.get("ticket") == tid:
        return True
    ts = a.get("tickets")
    return ts is not None and tid in ts


def explain(ticket, spans: Optional[Sequence[Span]] = None, *,
            tracer: Optional[Tracer] = None) -> List[ExplainNode]:
    """Reconstruct one request's span tree from the flight recorder.

    ``ticket`` is a ``serve.scheduler.Ticket`` or its integer
    ``ticket_id``. Spans whose ``ticket``/``tickets`` attribute names
    the request are selected as anchors, then every recorded descendant
    (engine stages, collectives, fault events — which carry no ticket
    attribution of their own but parent-link into the scheduler spans)
    is pulled in. Returns the roots in start order — typically
    ``serve.admission`` → ``serve.coalesce`` → one ``serve.attempt``
    per dispatch (with megastep/sharded/quant stages below each) →
    retry / failover entries, reading as the request's life story.

    Raises ``ValueError`` when no tracer is available (spans must come
    from somewhere: pass ``spans=``, ``tracer=``, or have one
    installed)."""
    tid = getattr(ticket, "ticket_id", ticket)
    if not isinstance(tid, int):
        raise TypeError(f"want a Ticket or int ticket_id, got {ticket!r}")
    if spans is None:
        tr = tracer or current()
        if tr is None:
            raise ValueError(
                "no spans to explain from: no tracer installed — wrap "
                "the request in repro.obs.capture() (or pass spans=)")
        spans = tr.spans()
    anchors = {sp.span_id for sp in spans if _matches_ticket(sp, tid)}
    if not anchors:
        return []
    # pull in descendants of anchored spans (children carry parent_id
    # but no ticket attribution of their own)
    children_of: dict = {}
    for sp in spans:
        children_of.setdefault(sp.parent_id, []).append(sp)
    selected = set(anchors)
    frontier = list(anchors)
    while frontier:
        pid = frontier.pop()
        for ch in children_of.get(pid, ()):
            if ch.span_id not in selected:
                selected.add(ch.span_id)
                frontier.append(ch.span_id)
    chosen = [sp for sp in spans if sp.span_id in selected]
    nodes = {sp.span_id: ExplainNode(sp) for sp in chosen}
    roots: List[ExplainNode] = []
    for sp in sorted(chosen, key=lambda s: (s.t0, s.span_id)):
        parent = nodes.get(sp.parent_id)
        if parent is not None and sp.parent_id != sp.span_id:
            parent.children.append(nodes[sp.span_id])
        else:
            roots.append(nodes[sp.span_id])
    return roots


def format_explain(roots: Sequence[ExplainNode]) -> str:
    """Render an :func:`explain` forest as an indented text tree with
    durations and attributes — the human-readable incident-audit form."""
    lines: List[str] = []

    def fmt_attrs(attrs: dict) -> str:
        if not attrs:
            return ""
        parts = []
        for k in sorted(attrs):
            v = attrs[k]
            if isinstance(v, float):
                v = f"{v:.4g}"
            parts.append(f"{k}={v}")
        return "  [" + " ".join(parts) + "]"

    def walk(node: ExplainNode, depth: int) -> None:
        sp = node.span
        dur = (f"{sp.duration_s * 1e3:.3f}ms" if sp.t1 > sp.t0
               else "instant")
        lines.append(f"{'  ' * depth}{sp.name}  {dur}"
                     f"{fmt_attrs(sp.attrs)}")
        for c in node.children:
            walk(c, depth + 1)

    for r in roots:
        walk(r, 0)
    return "\n".join(lines)
