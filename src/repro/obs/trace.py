"""Span tracer — the serving stack's flight recorder.

A :class:`Tracer` records structured **spans** (named, wall-clocked,
attributed, parent-linked) into a bounded ring buffer. Production code
brackets its stages with :func:`span` / stamps instants with
:func:`event`; both are **off by default** and cost one module-global
load plus a ``None`` check when no tracer is installed — the same
contract as ``serve.faultinject.fire``, so the instrumentation can live
permanently on the hot path.

Design constraints, in order:

* **zero-steady-state-host-sync safe** — recording a span touches the
  monotonic clock and a deque, never a device value. Attribute values
  must already be host-side Python/ints (callers attach sizes, config
  knobs and ``JoinStats`` fields — never ``jax.Array``\\ s, which would
  force a fetch inside the fused path).
* **thread-safe** — the serving loop spans from the consumer thread
  while ``submit`` spans from callers; ``deque.append`` with ``maxlen``
  is atomic under the GIL and the per-thread open-span stack lives in
  ``threading.local``. Parent links therefore never cross threads —
  cross-thread causality is carried by the ``tickets`` attribute
  instead (see ``obs.export.explain``).
* **bounded** — the ring buffer drops the *oldest* spans past
  ``capacity``; a forgotten enabled tracer degrades to a sliding
  window, never to unbounded growth.

Usage::

    import repro.obs as obs

    with obs.capture() as tr:                 # install + auto-uninstall
        scheduler.join_now(q)
    obs.export.write_chrome_trace(tr.spans(), "trace.json")

    with obs.trace.span("my.stage", rows=n) as sp:   # in production code
        ...
        sp.set(outcome="ok")                  # attach attrs discovered late
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["NULL_SPAN", "Span", "Tracer", "capture", "current", "enabled",
           "event", "install", "span", "uninstall"]


class Span:
    """One recorded operation: ``[t0, t1)`` on the monotonic clock, with
    a name, an id, a same-thread parent id (0 = root) and a free-form
    attribute dict. Mutable while open (``set``), frozen by convention
    once it lands in the ring buffer."""

    __slots__ = ("name", "span_id", "parent_id", "t0", "t1", "thread",
                 "attrs")

    def __init__(self, name: str, span_id: int, parent_id: int,
                 t0: float, thread: int, attrs: dict):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1 = t0
        self.thread = thread
        self.attrs = attrs

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered after the span opened (stage
        outcomes, per-attempt ``JoinStats`` numbers)."""
        self.attrs.update(attrs)
        return self

    def to_dict(self) -> dict:
        return dict(name=self.name, span_id=self.span_id,
                    parent_id=self.parent_id, t0=self.t0, t1=self.t1,
                    thread=self.thread, attrs=dict(self.attrs))

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.duration_s * 1e6:.1f}us, "
                f"attrs={self.attrs!r})")


class _SpanCtx:
    """Context manager that opens a :class:`Span` on ``__enter__`` and
    records it on ``__exit__`` (ring-buffer append, stack pop)."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self._span = Span(name, next(tracer._ids),
                          tracer._stack_top(), 0.0,
                          threading.get_ident(), attrs)

    def __enter__(self) -> Span:
        sp = self._span
        self._tracer._push(sp)
        sp.t0 = sp.t1 = time.perf_counter()
        return sp

    def __exit__(self, exc_type, exc, tb) -> bool:
        sp = self._span
        sp.t1 = time.perf_counter()
        if exc_type is not None and "outcome" not in sp.attrs:
            sp.attrs["outcome"] = f"error:{exc_type.__name__}"
        self._tracer._pop(sp)
        return False


class _NullSpan:
    """The disabled path's shared no-op: context manager and ``set``
    sink in one. A single instance serves every call site."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Tracer:
    """Ring-buffer span recorder. Create one per capture (or one
    long-lived per process) and :func:`install` it; ``capacity`` bounds
    retained spans (oldest dropped first)."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._buf: deque = deque(maxlen=self.capacity)
        self._ids = itertools.count(1)
        self._local = threading.local()

    # ---- per-thread open-span stack (parent linkage) ----------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _stack_top(self) -> int:
        st = getattr(self._local, "stack", None)
        return st[-1].span_id if st else 0

    def _push(self, sp: Span) -> None:
        self._stack().append(sp)

    def _pop(self, sp: Span) -> None:
        st = self._stack()
        if st and st[-1] is sp:
            st.pop()
        elif sp in st:                       # unbalanced exit: best effort
            st.remove(sp)
        self._buf.append(sp)

    # ---- recording ---------------------------------------------------

    def span(self, name: str, **attrs) -> _SpanCtx:
        """Open a timed span: ``with tracer.span("stage", n=5) as sp:``."""
        return _SpanCtx(self, name, attrs)

    def event(self, name: str, **attrs) -> Span:
        """Record an instant (zero-duration span) immediately."""
        sp = Span(name, next(self._ids), self._stack_top(),
                  time.perf_counter(), threading.get_ident(), attrs)
        self._buf.append(sp)
        return sp

    # ---- inspection --------------------------------------------------

    def spans(self) -> List[Span]:
        """Snapshot of recorded spans, oldest first (open spans are not
        included — they land on exit)."""
        return list(self._buf)

    def clear(self) -> None:
        self._buf.clear()

    def __len__(self) -> int:
        return len(self._buf)


# ---------------------------------------------------------------------------
# module-global installation — the production hook side

_TRACER: Optional[Tracer] = None


def install(tracer: Optional[Tracer] = None) -> Tracer:
    """Install ``tracer`` (a fresh default one when ``None``) as the
    process-global recorder. Returns it. Nested installs replace."""
    global _TRACER
    if tracer is None:
        tracer = Tracer()
    _TRACER = tracer
    return tracer


def uninstall() -> None:
    """Disable tracing: every later :func:`span`/:func:`event` goes back
    to the one-``None``-check fast path."""
    global _TRACER
    _TRACER = None


def current() -> Optional[Tracer]:
    return _TRACER


def enabled() -> bool:
    return _TRACER is not None


def span(name: str, **attrs):
    """Production-side hook: a timed span when a tracer is installed,
    the shared :data:`NULL_SPAN` no-op otherwise."""
    tr = _TRACER
    if tr is None:
        return NULL_SPAN
    return tr.span(name, **attrs)


def event(name: str, **attrs) -> Optional[Span]:
    """Production-side hook: record an instant when tracing is enabled;
    free (one ``None`` check) otherwise."""
    tr = _TRACER
    if tr is None:
        return None
    return tr.event(name, **attrs)


class capture:
    """Scoped tracing: installs a fresh :class:`Tracer` on entry and
    uninstalls on exit — the test/bench form.

    ::

        with obs.capture() as tr:
            sched.join_now(q)
        assert any(s.name == "serve.attempt" for s in tr.spans())
    """

    def __init__(self, capacity: int = 65536):
        self.tracer = Tracer(capacity)
        self._prev: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        global _TRACER
        self._prev = _TRACER
        _TRACER = self.tracer
        return self.tracer

    def __exit__(self, *exc) -> bool:
        global _TRACER
        _TRACER = self._prev
        return False
