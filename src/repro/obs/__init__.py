"""Observability for the serving stack — the flight recorder.

Three pieces, stdlib-only (importable before jax, safe from any thread):

* :mod:`repro.obs.trace` — ring-buffer span tracer, off by default,
  one ``None``-check when disabled. Production code brackets stages
  with ``trace.span(...)`` / stamps instants with ``trace.event(...)``;
  ``obs.capture()`` scopes a recording.
* :mod:`repro.obs.metrics` — always-on counters / gauges / fixed-bucket
  histograms (p50/p99/p999 without stored samples) published into the
  process-global ``metrics.REGISTRY`` by the scheduler, the engines,
  shard health, the mutable index, and fault injection.
* :mod:`repro.obs.export` — JSONL span dump, Chrome trace-event JSON
  (Perfetto-loadable), Prometheus text rendering, and the per-query
  ``explain(ticket)`` span-tree reconstruction.

The hard invariant the instrumentation honors everywhere: **zero
steady-state host syncs**. Span timings come from wall-clock brackets
around boundaries that already synchronize (dispatch host work, the
finalize fetch); span attributes carry only host-side values (sizes,
config knobs, per-attempt ``JoinStats`` fields) — never a ``jax.Array``
a recorder would have to fetch. The CI bench guard pins this with the
``traced_steady_state_syncs`` hard-zero row next to the untraced one.
"""
from . import export, metrics, trace
from .export import (chrome_trace, explain, format_explain,
                     render_prometheus, spans_to_jsonl, write_chrome_trace,
                     write_jsonl)
from .metrics import Registry
from .trace import Tracer, capture, enabled, event, install, span, uninstall

# the live default registry is ``metrics.REGISTRY`` — accessed through
# the module on purpose, so ``metrics.scoped()`` (tests/benches) can
# swap it; a frozen re-export here would silently go stale
__all__ = [
    "Registry", "Tracer", "capture", "chrome_trace",
    "enabled", "event", "explain", "export", "format_explain", "install",
    "metrics", "render_prometheus", "span", "spans_to_jsonl", "trace",
    "uninstall", "write_chrome_trace", "write_jsonl",
]
