"""Metrics registry — counters, gauges, fixed-bucket histograms.

The always-on half of the observability layer (the tracer is opt-in;
counters are cheap enough to publish unconditionally): every serving
component increments named metrics here, and a scrape renders them in
Prometheus text format (``repro.obs.export.render_prometheus``) or as a
flat dict (:meth:`Registry.snapshot`).

Histograms use **fixed log-spaced buckets**: p50/p99/p999 come from
cumulative bucket counts with linear interpolation inside the landing
bucket — O(buckets) memory, no stored samples, mergeable across
scrapes. That is the trade a serving system wants: a bounded-error
quantile forever beats an exact quantile that OOMs the recorder.

Publication discipline: one update per *batch or request*, never per
row — the hot path pays a dict ``get`` plus a lock-free-read /
locked-write pair per update, which is noise against a device batch but
would not be against a per-row loop.

The process-global :data:`REGISTRY` is what production code publishes
into; tests scope themselves with :func:`scoped` or call
:meth:`Registry.reset`.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "REGISTRY", "Registry",
           "default_latency_buckets", "scoped"]

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: dict) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def default_latency_buckets() -> Tuple[float, ...]:
    """Log-spaced seconds, 10µs → ~84s at ×2 per bucket: wide enough
    for a device batch and a hung collective in the same histogram,
    with ≤ ×2 relative quantile error."""
    return tuple(1e-5 * (2.0 ** i) for i in range(24))


class Counter:
    """Monotonic counter. ``inc`` only; never reset in production."""

    __slots__ = ("name", "labels", "_lock", "_v")

    kind = "counter"

    def __init__(self, name: str, labels: _LabelKey = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._v = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up — use a Gauge")
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v


class Gauge:
    """Point-in-time value (queue depth, failed shards, generation)."""

    __slots__ = ("name", "labels", "_lock", "_v")

    kind = "gauge"

    def __init__(self, name: str, labels: _LabelKey = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._v = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v


class Histogram:
    """Fixed-bucket histogram: ``observe`` lands each sample in the
    first bucket whose upper bound covers it (overflow past the last
    bound goes to a +inf bucket); quantiles interpolate linearly inside
    the landing bucket. Bounds are upper edges, ascending."""

    __slots__ = ("name", "labels", "bounds", "_lock", "_counts", "_sum",
                 "_n")

    kind = "histogram"

    def __init__(self, name: str, labels: _LabelKey = (),
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.labels = labels
        bounds = tuple(buckets) if buckets is not None \
            else default_latency_buckets()
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError("histogram buckets must be ascending")
        self.bounds = bounds
        self._lock = threading.Lock()
        self._counts = [0] * (len(bounds) + 1)     # +1: overflow (+inf)
        self._sum = 0.0
        self._n = 0

    def observe(self, v: float) -> None:
        # binary search for the landing bucket (bounds are upper edges)
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        with self._lock:
            self._counts[lo] += 1
            self._sum += v
            self._n += 1

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    def bucket_counts(self) -> List[int]:
        with self._lock:
            return list(self._counts)

    def quantile(self, p: float) -> float:
        """Estimated p-quantile (p in [0, 1]); NaN when empty, the last
        finite bound when the quantile lands in the overflow bucket."""
        if not 0.0 <= p <= 1.0:
            raise ValueError("p must be in [0, 1]")
        with self._lock:
            counts, n = list(self._counts), self._n
        if n == 0:
            return float("nan")
        rank = p * n
        seen = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if seen + c >= rank:
                if i >= len(self.bounds):       # overflow bucket
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                frac = (rank - seen) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            seen += c
        return self.bounds[-1]


class Registry:
    """Named get-or-create home for metrics. Lookups of existing
    metrics are a lock-free dict ``get`` (GIL-consistent); creation
    takes the registry lock once per (name, labels)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[tuple, object] = {}

    def _get_or_create(self, cls, name: str, labels: dict, **kw):
        key = (cls.kind, name, _label_key(labels))
        m = self._metrics.get(key)
        if m is not None:
            return m
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[2], **kw)
                self._metrics[key] = m
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_create(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self._get_or_create(Histogram, name, labels,
                                   buckets=buckets)

    def metrics(self) -> List[object]:
        """All registered metrics, sorted by (name, labels) for stable
        rendering."""
        with self._lock:
            ms = list(self._metrics.values())
        return sorted(ms, key=lambda m: (m.name, m.labels))

    def snapshot(self) -> Dict[str, float]:
        """Flat {rendered-name: value}; histograms contribute ``_count``
        / ``_sum`` / ``_p50`` / ``_p99`` / ``_p999`` entries."""
        out: Dict[str, float] = {}
        for m in self.metrics():
            base = m.name + _render_labels(m.labels)
            if isinstance(m, Histogram):
                out[base + "_count"] = float(m.count)
                out[base + "_sum"] = m.sum
                out[base + "_p50"] = m.quantile(0.50)
                out[base + "_p99"] = m.quantile(0.99)
                out[base + "_p999"] = m.quantile(0.999)
            else:
                out[base] = m.value
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


def _render_labels(labels: _LabelKey) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


REGISTRY = Registry()


class scoped:
    """Swap a fresh registry in for a ``with`` block (tests / benches):
    publications inside the block land in the scoped registry, the
    process-global one is restored on exit."""

    def __init__(self):
        self.registry = Registry()
        self._prev: Optional[Registry] = None

    def __enter__(self) -> Registry:
        global REGISTRY
        self._prev = REGISTRY
        REGISTRY = self.registry
        return self.registry

    def __exit__(self, *exc) -> bool:
        global REGISTRY
        REGISTRY = self._prev
        return False
