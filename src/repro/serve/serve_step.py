"""Serving: prefill + decode steps and a batched request loop.

``make_serve_step`` returns the two jitted stages the dry-run lowers:
  prefill_step(params, tokens, cache, ...) → (logits_last, cache)
  decode_step(params, token, cache, ...)   → (logits, cache)
The continuous-batching loop (host-side) slots requests into fixed batch
lanes — XLA-friendly static shapes; done lanes are refilled in place.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import ModelOptions, forward, init_cache


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    cache_len: int = 2048
    temperature: float = 0.0      # 0 → greedy
    eos_id: int = -1              # -1 → run to max_new_tokens


def make_serve_step(cfg: ArchConfig, scfg: ServeConfig,
                    opts: ModelOptions = ModelOptions()):
    def prefill_step(params, tokens, cache, **extra):
        """tokens (B, T_prompt); fills cache, returns last-pos logits."""
        logits, cache = forward(params, cfg, tokens, cache=cache,
                                opts=opts, mode="prefill", **extra)
        return logits[:, -1], cache

    def decode_step(params, token, cache, **extra):
        """token (B, 1); one step against the cache."""
        logits, cache = forward(params, cfg, token, cache=cache,
                                opts=opts, mode="decode", **extra)
        return logits[:, -1], cache

    return prefill_step, decode_step


def make_knn_hook(store, kcfg, vocab: int, *, scheduler=None,
                  deadline_s: Optional[float] = None,
                  query_fn: Optional[Callable] = None) -> Callable:
    """Build a ``logits_hook`` for :class:`BatchedServer` that
    interpolates each decode step's logits with kNN-LM retrieval from
    ``store`` (a ``serve.Datastore``) — optionally *through* a
    ``serve.scheduler.ServeScheduler``, which is how a deployment puts
    admission control, deadlines and graceful degradation in front of
    the retrieval join: an overloaded or past-deadline step falls back
    to the LM distribution alone instead of stalling the decode lane.

    ``query_fn(logits, cache) -> (B, D) float32`` maps the decode state
    to retrieval queries; the default uses the leading logit slice the
    launch example uses (stand-in for the pre-softmax hidden state).
    """
    from .retrieval import interpolate, knn_logits

    if query_fn is None:
        dim = store.keys.shape[1]

        def query_fn(logits, cache):
            return np.asarray(logits)[:, :dim].astype(np.float32)

    def hook(logits, cache):
        q = query_fn(logits, cache)
        lg = knn_logits(q, store, kcfg, vocab, scheduler=scheduler,
                        deadline_s=deadline_s)
        return interpolate(logits, lg, kcfg.lam)

    return hook


def sample(logits: jnp.ndarray, temperature: float, key) -> jnp.ndarray:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


class BatchedServer:
    """Host-side continuous batching over fixed lanes (static shapes)."""

    def __init__(self, cfg: ArchConfig, scfg: ServeConfig, params,
                 opts: ModelOptions = ModelOptions(),
                 logits_hook: Optional[Callable] = None):
        self.cfg, self.scfg, self.opts = cfg, scfg, opts
        self.params = params
        self.prefill_step, self.decode_step = make_serve_step(cfg, scfg, opts)
        self._jit_decode = jax.jit(self.decode_step)
        self.logits_hook = logits_hook   # e.g. kNN-LM interpolation
        self.key = jax.random.PRNGKey(0)

    def generate(self, prompts: List[np.ndarray], max_new_tokens: int
                 ) -> List[np.ndarray]:
        """Generate for all prompts, scfg.batch lanes at a time."""
        out: List[np.ndarray] = [None] * len(prompts)
        queue = list(enumerate(prompts))
        while queue:
            wave = queue[: self.scfg.batch]
            queue = queue[self.scfg.batch:]
            ids = [i for i, _ in wave]
            toks = [np.asarray(p, np.int32) for _, p in wave]
            tmax = max(len(t) for t in toks)
            b = len(wave)
            pad = np.zeros((b, tmax), np.int32)
            for r, t in enumerate(toks):
                pad[r, tmax - len(t):] = t   # left-pad → aligned last pos
            cache = init_cache(self.cfg, b,
                               tmax + max_new_tokens, self.opts)
            logits, cache = jax.jit(self.prefill_step)(
                self.params, jnp.asarray(pad), cache)
            gen = np.zeros((b, max_new_tokens), np.int32)
            tok = None
            for step in range(max_new_tokens):
                if self.logits_hook is not None:
                    logits = self.logits_hook(logits, cache)
                self.key, sub = jax.random.split(self.key)
                tok = sample(logits, self.scfg.temperature, sub)
                gen[:, step] = np.asarray(tok)
                logits, cache = self._jit_decode(
                    self.params, tok[:, None], cache)
            for r, i in enumerate(ids):
                out[i] = gen[r]
        return out
