from .serve_step import BatchedServer, ServeConfig, make_serve_step, sample
from .retrieval import Datastore, KnnLMConfig, interpolate, knn_logits

__all__ = ["BatchedServer", "ServeConfig", "make_serve_step", "sample",
           "Datastore", "KnnLMConfig", "interpolate", "knn_logits"]
