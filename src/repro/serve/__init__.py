"""Serving: batched generation, the kNN-LM datastore, and the
overload-robust scheduler/fault-injection runtime in front of them.

Lazy (PEP 562) exports: ``core.megastep`` fires `faultinject` hook
sites, so importing this package must stay light — `serve_step` pulls
the model stack, and eager imports here would make every core join
import transformers-sized modules (and a circular import to boot).
"""
import importlib

_EXPORTS = {
    "BatchedServer": "serve_step",
    "ServeConfig": "serve_step",
    "make_serve_step": "serve_step",
    "make_knn_hook": "serve_step",
    "sample": "serve_step",
    "Datastore": "retrieval",
    "KnnLMConfig": "retrieval",
    "interpolate": "retrieval",
    "knn_logits": "retrieval",
    "Arrival": "scheduler",
    "LoadReport": "scheduler",
    "Priority": "scheduler",
    "SchedulerConfig": "scheduler",
    "SchedulerStats": "scheduler",
    "ServeScheduler": "scheduler",
    "Ticket": "scheduler",
    "VirtualClock": "scheduler",
    "bursty_times": "scheduler",
    "poisson_times": "scheduler",
    "run_open_loop": "scheduler",
    "FaultPlan": "faultinject",
    "InjectedFault": "faultinject",
    "ShardFault": "faultinject",
    "ShardFailedError": "faultinject",
}

__all__ = sorted(_EXPORTS) + ["faultinject", "scheduler"]


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(f".{mod}", __name__), name)


def __dir__():
    return __all__
