"""Overload-robust request scheduling in front of the join engines.

The compute side of serving (PR 1–5: pruned schedules, the fused
megastep, the certified int8 tier) executes whatever batch it is
handed; this module decides *what gets handed to it* when demand
exceeds capacity. A ``ServeScheduler`` sits in front of a
``StreamJoinEngine`` (or a ``serve.Datastore``'s resident engine) and
provides:

* **bounded admission with backpressure** — queued rows are capped;
  a request that does not fit is rejected *explicitly* (``Ticket.status
  == "rejected"``) instead of growing an unbounded queue. Interactive
  requests may evict queued bulk work to get in.
* **per-request deadlines, enforced before dispatch** — a deadline
  propagates from submit through batch formation to the device call;
  an expired request is shed *before* it reaches the engine, never
  after (``SchedulerStats.n_expired_dispatched`` counts violations of
  this invariant and is pinned to zero by the CI bench guard).
* **priority lanes** — latency-sensitive decode traffic
  (``Priority.INTERACTIVE``) always dispatches ahead of bulk/backfill
  (``Priority.BULK``); under overload, bulk is shed first.
* **coalescing** — ragged arrivals are packed into one engine batch up
  to ``SchedulerConfig.batch_rows``, so the pow2 padding the megastep
  applies per batch pads *one* coalesced batch instead of every tiny
  request. Exactness makes this free: every engine's per-query result
  is independent of batch composition (the bitwise batched==one-shot
  contract, tests/test_stream.py), so coalesced results split back to
  requests unchanged.
* **graceful degradation instead of collapse** — the ladder is
  exact → certified-approximate → shed. When the backlog passes
  ``degrade_queued_rows`` and a quantized engine is available, batches
  run the coarse-only path (``QuantMegastepEngine.join_batch_approx``):
  no oracle fallback re-runs, and every response carries a *certified*
  per-query recall lower bound derived from the PR-5 ε machinery
  (contrast with AkNN systems that approximate silently). Past
  ``shed_queued_rows``, queued bulk is shed with an explicit rejection.
* **fault-injected retries** — transient failures (device OOM on
  payload upload, failed fetch, poisoned batch — see
  ``serve.faultinject`` for the hook sites) are retried with capped
  exponential backoff onto the *host-planned oracle path*
  (``StreamJoinEngine.join_batch_host``), which owns no device payload
  and therefore cannot re-hit an upload fault. Deadlines keep being
  enforced across backoff: a request that expires while backing off is
  shed, not dispatched.
* **shard failover, deadline-checked** — a sharded engine that loses a
  shard raises ``ShardFailedError`` *after* updating its serving view;
  the scheduler re-enters the engine rung (the next attempt runs on
  the failed-over view — bitwise while replicas cover every pivot
  group), re-checking deadlines at that failover instant so
  ``n_expired_dispatched`` stays 0 across the failure window. Once
  coverage itself is degraded (a populated pivot group lost its last
  replica) batches run ``join_batch_covered`` and every response
  carries the engine's *sound* per-query recall lower bound — the rung
  between certified-approximate and shed on the degradation ladder.

The scheduler is step-driven and clock-injectable: ``step()`` forms and
executes one batch, ``drain()`` runs until idle, ``serve_forever()``
spawns the single consumer thread a live deployment uses. ``submit``
is thread-safe. The open-loop bench (``benchmarks.kernel_bench.
serving_under_load_bench``) drives the same scheduler under a
``VirtualClock`` — Poisson/bursty arrivals in virtual time, *measured*
wall time per executed batch — recording p50/p99/p999 latency, goodput,
shed rate and degraded fraction vs offered load.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
import time
from collections import deque
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.core.types import JoinStats

from . import faultinject

__all__ = [
    "Arrival", "LoadReport", "Priority", "SchedulerConfig",
    "SchedulerStats", "ServeScheduler", "Ticket", "VirtualClock",
    "bursty_times", "poisson_times", "run_open_loop",
]


class Priority(enum.IntEnum):
    """Lanes, dispatched in ascending order; bulk sheds first."""

    INTERACTIVE = 0        # latency-sensitive decode traffic
    BULK = 1               # backfill / batch re-scoring


# process-wide request ids: the flight recorder's correlation key
# (``repro.obs.explain(ticket)`` reconstructs one request's span tree
# by matching these against span ``ticket``/``tickets`` attributes)
_TICKET_IDS = itertools.count(1)


def _join_attrs(js: JoinStats) -> dict:
    """The paper's §6 metrics (plus serving-state fields) as span
    attributes — host-side ints/floats only, attached after the engine
    call returned (so nothing here ever forces a device fetch)."""
    out = dict(tiles_total=js.tiles_total, tiles_visited=js.tiles_visited,
               tiles_pruned=js.tiles_total - js.tiles_visited,
               selectivity=js.selectivity, replicas=js.replicas_s,
               pivot_pairs=js.pivot_pairs_computed,
               n_segments=js.n_segments, n_tombstones=js.n_tombstones)
    if js.n_shards:
        out.update(n_shards=js.n_shards,
                   n_failed_shards=js.n_failed_shards,
                   coverage_bound=js.coverage_bound)
    if js.quant_mode:
        out.update(quant_mode=js.quant_mode, quant_mp=js.quant_mp,
                   n_quant_fallback=js.n_quant_fallback)
    if js.n_degraded:
        out.update(n_degraded=js.n_degraded,
                   recall_bound=js.recall_bound)
    return out


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission, coalescing and degradation knobs.

    The watermarks form the degradation ladder: backlog ≤
    ``degrade_queued_rows`` serves exact; above it, batches run the
    certified-approximate path (when a quantized engine exists); above
    ``shed_queued_rows``, queued bulk is shed; above
    ``max_queued_rows``, admission itself rejects.
    """

    batch_rows: int = 256            # coalescing target per dispatch
    max_queued_rows: int = 4096      # admission bound (all lanes)
    default_deadline_s: float = 1.0  # used when submit passes none
    degrade_queued_rows: int = 1024  # ladder rung 1: go coarse-only
    shed_queued_rows: int = 2048     # ladder rung 2: shed bulk
    max_retries: int = 3             # transient-fault retries per batch
    backoff_base_s: float = 0.02     # capped exponential backoff
    backoff_cap_s: float = 0.5
    # double-buffered dispatch: >1 keeps that many megasteps in flight
    # (dispatch batch N+1 before fetching batch N's results, overlapping
    # host-side batch formation with device compute). 1 = synchronous
    # step semantics (dispatch + fetch inside one step). Needs an engine
    # with the async ``dispatch``/``finalize`` split — the scheduler
    # silently stays synchronous otherwise. Deadlines are re-checked at
    # the dispatch instant either way: n_expired_dispatched stays 0.
    max_inflight: int = 1

    def __post_init__(self):
        if self.batch_rows < 1:
            raise ValueError("batch_rows must be >= 1")
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if not (self.degrade_queued_rows <= self.shed_queued_rows
                <= self.max_queued_rows):
            raise ValueError(
                "degradation ladder out of order: need degrade_queued_rows"
                " <= shed_queued_rows <= max_queued_rows, got "
                f"{self.degrade_queued_rows} / {self.shed_queued_rows} / "
                f"{self.max_queued_rows}")
        if self.max_retries < 0 or self.backoff_base_s < 0:
            raise ValueError("max_retries/backoff_base_s must be >= 0")


@dataclasses.dataclass
class Ticket:
    """One submitted request and (eventually) its outcome.

    ``status``: ``queued`` → ``done`` | ``shed`` | ``rejected`` |
    ``failed``. ``reason`` explains non-``done`` outcomes (``deadline``,
    ``queue_full``, ``overload``, ``fault``). A ``done`` ticket carries
    ``distances``/``indices`` (the engine contract: true distances
    ascending, int64 global ids) and ``recall_bound`` — per-query
    certified recall lower bounds, all-ones on the exact path,
    the ε-certificate bound when ``degraded``.
    """

    rows: np.ndarray = dataclasses.field(repr=False)
    n: int = 0
    ticket_id: int = 0
    priority: Priority = Priority.INTERACTIVE
    arrival: float = 0.0
    deadline: float = 0.0
    status: str = "queued"
    reason: str = ""
    degraded: bool = False
    attempts: int = 0
    dispatched_at: Optional[float] = None
    completed_at: Optional[float] = None
    distances: Optional[np.ndarray] = None
    indices: Optional[np.ndarray] = None
    recall_bound: Optional[np.ndarray] = None

    @property
    def done(self) -> bool:
        return self.status == "done"


@dataclasses.dataclass
class SchedulerStats:
    """Serving-runtime counters (requests unless suffixed ``_rows``).

    ``n_expired_dispatched`` is the hard invariant: the number of
    requests whose deadline had already passed at the moment they were
    handed to an engine. The scheduler sheds expired requests at batch
    formation *and* re-checks across retry backoff, so this must stay
    0 — the CI bench guard fails on any nonzero value.

    Concurrency: the background ``serve_forever()`` worker mutates
    these fields (and folds per-attempt ``JoinStats`` into ``join``)
    under the scheduler's lock — read through
    :meth:`ServeScheduler.snapshot` from any other thread; a bare
    ``sched.stats`` read races the worker.
    """

    n_submitted: int = 0
    n_completed: int = 0
    n_rejected: int = 0
    n_shed_deadline: int = 0
    n_shed_overload: int = 0
    n_failed: int = 0
    n_degraded_requests: int = 0
    n_dispatches: int = 0
    n_retries: int = 0
    n_expired_dispatched: int = 0
    # batches re-entered after a ShardFailedError (the engine failed
    # over its serving view; the retry ran on the updated view)
    n_failovers: int = 0
    rows_submitted: int = 0
    rows_completed: int = 0
    rows_shed: int = 0
    join: JoinStats = dataclasses.field(default_factory=JoinStats)

    @property
    def n_shed(self) -> int:
        return self.n_shed_deadline + self.n_shed_overload


class ServeScheduler:
    """Admission control + deadlines + degradation in front of one
    engine. See the module docstring for the policy; see
    :meth:`for_datastore` for the serving wiring.

    ``engine`` is anything with ``join_batch(q, stats=)`` — normally a
    ``core.StreamJoinEngine``. ``degraded_engine="auto"`` picks up the
    engine's quantized megastep (``join_batch_approx``) when present;
    pass ``None`` to disable the certified-approximate rung (overload
    then goes straight to shedding). ``host_join`` is the retry target
    for transient faults — defaults to the engine's host-planned oracle
    path. ``clock``/``sleep`` are injectable for deterministic tests
    and the virtual-time bench.

    Concurrency contract: ``submit`` may be called from any thread;
    ``step``/``drain`` must run on a single consumer thread (use
    :meth:`serve_forever` for the background-worker form).
    """

    def __init__(self, engine, *, degraded_engine: object = "auto",
                 host_join: Optional[Callable] = None,
                 config: Optional[SchedulerConfig] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.engine = engine
        me = getattr(engine, "megastep_engine", None)
        if degraded_engine == "auto":
            degraded_engine = me if hasattr(me, "join_batch_approx") \
                else None
        self.degraded_engine = degraded_engine
        # the degraded-coverage rung: a sharded engine that certifies
        # per-query recall bounds once shard loss uncovers pivot groups
        self._coverage_engine = me if hasattr(me, "join_batch_covered") \
            else None
        if host_join is None:
            host_join = getattr(engine, "join_batch_host", None) \
                or engine.join_batch
        self._host_join = host_join
        self.config = config or SchedulerConfig()
        self._clock = clock
        self._sleep = sleep
        self.stats = SchedulerStats()
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._lanes = {p: [] for p in Priority}
        self._queued_rows = 0
        self._worker: Optional[threading.Thread] = None
        self._stop = False
        # double-buffered dispatch (config.max_inflight > 1): batches
        # handed to the engine's async dispatch() whose results have not
        # been fetched yet, oldest first. Only the consumer thread
        # touches this deque.
        self._inflight: deque = deque()
        self._pipelined = (self.config.max_inflight > 1
                           and bool(getattr(engine, "can_dispatch", False)))

    @classmethod
    def for_datastore(cls, store, k: Optional[int] = None, **kw
                      ) -> "ServeScheduler":
        """Scheduler over a ``serve.Datastore``'s resident engine: the
        exact path is whatever the store serves (quantized-certified or
        fp32 megastep), the degraded rung is the store's quantized
        engine when it has one, and fault retries land on the
        host-planned oracle over the same mutable index."""
        return cls(store.engine(k), **kw)

    # ---- admission --------------------------------------------------

    def submit(self, queries: np.ndarray, *,
               deadline_s: Optional[float] = None,
               priority: Priority = Priority.INTERACTIVE,
               arrival: Optional[float] = None) -> Ticket:
        """Admit one request (a block of query rows). Returns its
        ``Ticket`` immediately — ``rejected`` (queue full) is decided
        here; everything else resolves when a later ``step`` processes
        it. ``arrival`` backdates the request (open-loop drivers stamp
        the true arrival time so queueing during a busy step still
        counts against latency and the deadline)."""
        q = np.ascontiguousarray(queries, np.float32)
        if q.ndim != 2 or q.shape[0] == 0:
            raise ValueError(f"submit wants (n>0, dim) rows, got {q.shape}")
        now = self._clock()
        arr = now if arrival is None else float(arrival)
        dls = self.config.default_deadline_s if deadline_s is None \
            else float(deadline_s)
        t = Ticket(rows=q, n=q.shape[0], ticket_id=next(_TICKET_IDS),
                   priority=priority, arrival=arr, deadline=arr + dls)
        n_evicted = 0
        with self._lock:
            self.stats.n_submitted += 1
            self.stats.rows_submitted += t.n
            cap = self.config.max_queued_rows
            if self._queued_rows + t.n > cap \
                    and priority == Priority.INTERACTIVE:
                # interactive may evict queued bulk (newest first): the
                # lowest-priority work is shed to make room, explicitly
                bulk = self._lanes[Priority.BULK]
                while bulk and self._queued_rows + t.n > cap:
                    victim = bulk.pop()
                    self._mark_shed_locked(victim, "overload")
                    self._drop_rows_locked(victim.n)
                    n_evicted += 1
            if self._queued_rows + t.n > cap:
                t.status, t.reason = "rejected", "queue_full"
                self.stats.n_rejected += 1
                self.stats.rows_shed += t.n
                reg = obs.metrics.REGISTRY
                reg.counter("serve_submitted_total").inc()
                reg.counter("serve_rejected_total").inc()
                obs.event("serve.admission", ticket=t.ticket_id, rows=t.n,
                          priority=int(priority), outcome="rejected")
                return t
            self._lanes[priority].append(t)
            self._queued_rows += t.n
            queued = self._queued_rows
            self._work.notify()
        reg = obs.metrics.REGISTRY
        reg.counter("serve_submitted_total").inc()
        reg.gauge("serve_queued_rows").set(queued)
        obs.event("serve.admission", ticket=t.ticket_id, rows=t.n,
                  priority=int(priority), outcome="admitted",
                  evicted_bulk=n_evicted, queued_rows=queued)
        return t

    def snapshot(self) -> SchedulerStats:
        """Consistent copy of :attr:`stats` taken under the scheduler
        lock — the race-free read for benches, guards and dashboards
        while ``serve_forever()`` mutates the originals. The returned
        object (including its ``join``) is detached: mutating it never
        touches the live counters, and the live counters never mutate
        it."""
        with self._lock:
            return dataclasses.replace(
                self.stats, join=dataclasses.replace(self.stats.join))

    @property
    def queued_rows(self) -> int:
        return self._queued_rows

    @property
    def has_work(self) -> bool:
        return self._queued_rows > 0 or bool(self._inflight)

    @property
    def inflight_batches(self) -> int:
        """Dispatched-but-unfetched megasteps (0 on the sync path)."""
        return len(self._inflight)

    # ---- batch formation (lock held) --------------------------------

    def _mark_shed_locked(self, t: Ticket, reason: str) -> None:
        t.status, t.reason = "shed", reason
        t.completed_at = self._clock()
        if reason == "deadline":
            self.stats.n_shed_deadline += 1
        else:
            self.stats.n_shed_overload += 1
        self.stats.rows_shed += t.n
        obs.metrics.REGISTRY.counter("serve_shed_total",
                                     reason=reason).inc()
        obs.event("serve.shed", ticket=t.ticket_id, reason=reason)

    def _drop_rows_locked(self, n: int) -> None:
        self._queued_rows -= n

    def _form_batch_locked(self, now: float) -> List[Ticket]:
        cfg = self.config
        # 1. deadline sheds — expired requests leave the queue here,
        # before any of them could reach a device
        for lane in self._lanes.values():
            kept = []
            for t in lane:
                if t.deadline < now:
                    self._mark_shed_locked(t, "deadline")
                    self._drop_rows_locked(t.n)
                else:
                    kept.append(t)
            lane[:] = kept
        # 2. overload sheds — past the shed watermark, bulk goes first
        # (newest first: oldest queued bulk keeps its place in line)
        bulk = self._lanes[Priority.BULK]
        while self._queued_rows > cfg.shed_queued_rows and bulk:
            victim = bulk.pop()
            self._mark_shed_locked(victim, "overload")
            self._drop_rows_locked(victim.n)
        # 3. coalesce — fill one batch, interactive first, FIFO per lane
        batch: List[Ticket] = []
        rows = 0
        for p in Priority:
            lane = self._lanes[p]
            while lane and (rows == 0 or rows + lane[0].n <= cfg.batch_rows):
                t = lane.pop(0)
                self._drop_rows_locked(t.n)
                batch.append(t)
                rows += t.n
            if rows >= cfg.batch_rows:
                break
        return batch

    # ---- execution --------------------------------------------------

    def step(self) -> int:
        """Form one coalesced batch and execute it (with degradation
        and fault retries). Returns the number of query rows processed
        (completed, shed, or — in double-buffered mode — dispatched);
        0 when there was nothing to do.

        With ``max_inflight > 1`` and a dispatch-capable engine, the
        batch is *dispatched* (device work starts) and the oldest
        previously dispatched batch is fetched only once the in-flight
        window is full — batch N's device pass overlaps batch N+1's
        formation + dispatch. An empty queue drains the window.
        """
        now = self._clock()
        with self._lock:
            pressure = self._queued_rows
            batch = self._form_batch_locked(now)
        if batch and obs.enabled():
            obs.event("serve.coalesce",
                      tickets=tuple(t.ticket_id for t in batch),
                      rows=sum(t.n for t in batch),
                      queued_rows=pressure)
        obs.metrics.REGISTRY.gauge("serve_queued_rows") \
            .set(self._queued_rows)
        degraded = (self.degraded_engine is not None
                    and pressure > self.config.degrade_queued_rows)
        # degraded coverage (shard loss with no live replica) routes
        # through the blocking covered call so responses carry the
        # engine's certified recall bounds — skip the pipelined path,
        # whose finalize drops them
        covered = (self._coverage_engine is not None
                   and self._coverage_engine.coverage_degraded)
        if self._pipelined and not degraded and not covered:
            n = self._dispatch_pipelined(batch) if batch else 0
            # keep up to max_inflight-1 megasteps in flight across
            # steps while new work keeps arriving; drain when idle
            keep = (self.config.max_inflight - 1) if batch else 0
            while len(self._inflight) > keep:
                n += self._finalize_oldest()
            return n
        # sync path (or the degraded rung, which is a blocking engine
        # call): flush any in-flight work first so results stay FIFO
        n = 0
        while self._inflight:
            n += self._finalize_oldest()
        if not batch:
            return n
        self._execute(batch, degraded)
        return n + sum(t.n for t in batch)

    def drain(self) -> None:
        """Step until no queued work remains (tests / shutdown flush)."""
        while self.step():
            pass

    def join_now(self, queries: np.ndarray, **kw) -> Ticket:
        """Submit + pump until this request resolves — the synchronous
        convenience the kNN-LM decode hook uses. Requests queued ahead
        are served first (FIFO is preserved)."""
        t = self.submit(queries, **kw)
        while t.status == "queued":
            self.step()
        return t

    # ---- double-buffered dispatch (consumer thread only) ------------

    def _dispatch_pipelined(self, batch: List[Ticket]) -> int:
        """Hand one coalesced batch to the engine's async ``dispatch``
        and park the handle in the in-flight window. Deadlines are
        re-checked at the dispatch instant (the clock may have advanced
        since batch formation), so the n_expired_dispatched == 0
        invariant holds on this path exactly as on the sync one. A
        dispatch fault falls back to the synchronous retry ladder
        (host-planned oracle) for this batch alone."""
        now = self._clock()
        live, dead = [], []
        for t in batch:
            (live if t.deadline >= now else dead).append(t)
        if dead:
            with self._lock:
                for t in dead:
                    self._mark_shed_locked(t, "deadline")
        if not live:
            return sum(t.n for t in dead)
        q = live[0].rows if len(live) == 1 else \
            np.concatenate([t.rows for t in live], axis=0)
        dispatch_at = self._clock()
        n_exp = sum(1 for t in live if t.deadline < dispatch_at)
        with self._lock:
            self.stats.n_dispatches += 1
            self.stats.n_expired_dispatched += n_exp
        reg = obs.metrics.REGISTRY
        reg.counter("serve_dispatch_total").inc()
        if n_exp:
            reg.counter("serve_expired_dispatched_total").inc(n_exp)
        for t in live:
            t.dispatched_at = dispatch_at
            t.attempts += 1
        # per-batch JoinStats: engine stamps land here and are *merged*
        # into the aggregate (JoinStats.merged) instead of overwriting a
        # shared object from a worker thread
        js = JoinStats()
        tks = tuple(t.ticket_id for t in live) if obs.enabled() else ()
        try:
            with obs.span("serve.attempt", tickets=tks, attempt=0,
                          rung="engine", pipelined=True) as sp:
                try:
                    faultinject.fire("sched.dispatch")
                    handle = self.engine.dispatch(q, stats=js)
                except faultinject.ShardFailedError as e:
                    sp.set(outcome="shard_failed", shard=e.shard)
                    raise
                sp.set(outcome="dispatched", **_join_attrs(js))
        except faultinject.ShardFailedError as e:
            # the engine failed over its serving view: re-enter the
            # engine rung (not the host oracle) — _execute re-checks
            # deadlines at this failover instant before dispatching
            with self._lock:
                self.stats.n_failovers += 1
                self.stats.join = self.stats.join.merged(js)
            reg.counter("serve_failovers_total").inc()
            obs.event("serve.failover", tickets=tks, shard=e.shard)
            self._execute(live, False)
            return sum(t.n for t in batch)
        except Exception:    # noqa: BLE001 — transient-fault ladder
            with self._lock:
                self.stats.join = self.stats.join.merged(js)
            self._execute(live, False, first_attempt=1)
            return sum(t.n for t in batch)
        with self._lock:
            self.stats.join = self.stats.join.merged(js)
        self._inflight.append((handle, live))
        return sum(t.n for t in batch)

    def _finalize_oldest(self) -> int:
        """Fetch + complete the oldest in-flight batch. A finalize
        fault (failed fetch, poisoned result) re-runs the batch's
        tickets through the synchronous retry ladder."""
        handle, live = self._inflight.popleft()
        js = JoinStats()
        tks = tuple(t.ticket_id for t in live) if obs.enabled() else ()
        try:
            with obs.span("serve.finalize", tickets=tks) as sp:
                try:
                    d, i = self.engine.finalize(handle, stats=js)
                except faultinject.ShardFailedError as e:
                    sp.set(outcome="shard_failed", shard=e.shard)
                    raise
                sp.set(outcome="done", **_join_attrs(js))
        except faultinject.ShardFailedError as e:
            # failover: re-run on the engine's updated serving view,
            # deadlines re-checked at the failover instant
            with self._lock:
                self.stats.n_failovers += 1
                self.stats.join = self.stats.join.merged(js)
            obs.metrics.REGISTRY.counter("serve_failovers_total").inc()
            obs.event("serve.failover", tickets=tks, shard=e.shard)
            self._execute(live, False)
            return sum(t.n for t in live)
        except Exception:    # noqa: BLE001 — transient-fault ladder
            with self._lock:
                self.stats.join = self.stats.join.merged(js)
            self._execute(live, False, first_attempt=1)
            return sum(t.n for t in live)
        with self._lock:
            self.stats.join = self.stats.join.merged(js)
        self._complete(live, d, i, None)
        return sum(t.n for t in live)

    # ---- synchronous execution with retries -------------------------

    def _complete(self, live: List[Ticket], d, i, rb) -> None:
        done_at = self._clock()
        lo = 0
        with self._lock:
            for t in live:
                t.distances = d[lo:lo + t.n]
                t.indices = i[lo:lo + t.n]
                t.recall_bound = (rb[lo:lo + t.n] if rb is not None
                                  else np.ones(t.n, np.float32))
                t.degraded = rb is not None
                t.status = "done"
                t.completed_at = done_at
                lo += t.n
                self.stats.n_completed += 1
                self.stats.rows_completed += t.n
                if t.degraded:
                    self.stats.n_degraded_requests += 1
        reg = obs.metrics.REGISTRY
        lat = reg.histogram("serve_latency_s")
        reg.counter("serve_completed_total").inc(len(live))
        if rb is not None:
            reg.counter("serve_degraded_total").inc(len(live))
        for t in live:
            lat.observe(max(0.0, done_at - t.arrival))
        if obs.enabled():
            obs.event("serve.complete",
                      tickets=tuple(t.ticket_id for t in live),
                      rows=sum(t.n for t in live),
                      degraded=rb is not None)

    def _execute(self, batch: List[Ticket], degraded: bool, *,
                 first_attempt: int = 0) -> None:
        """Blocking execute with the capped-backoff retry ladder.
        ``first_attempt > 0`` enters the ladder at that rung — the
        double-buffered path uses it to route a batch whose async
        dispatch/finalize faulted straight onto the host-planned oracle
        (its rung-0 engine call is what just failed), with the retry
        budget reduced accordingly."""
        cfg = self.config
        live = list(batch)

        def attempt_fn(attempt: int):
            nonlocal live, degraded
            attempt += first_attempt
            now = self._clock()
            still, dead = [], []
            for t in live:
                (still if t.deadline >= now else dead).append(t)
            # re-check at the attempt instant — the one place this event
            # is emitted, so a traced request shows exactly one
            # deadline_recheck per (re)attempt of the synchronous ladder
            obs.event("serve.deadline_recheck",
                      tickets=tuple(t.ticket_id for t in live)
                      if obs.enabled() else (),
                      attempt=attempt, shed=len(dead))
            if dead:
                # expired mid-backoff: shed now — never dispatched
                with self._lock:
                    for t in dead:
                        self._mark_shed_locked(t, "deadline")
                live = still
            if not live:
                return None
            q = live[0].rows if len(live) == 1 else \
                np.concatenate([t.rows for t in live], axis=0)
            dispatch_at = self._clock()
            n_exp = sum(1 for t in live if t.deadline < dispatch_at)
            with self._lock:
                self.stats.n_dispatches += 1
                self.stats.n_expired_dispatched += n_exp
                if attempt > 0:
                    self.stats.n_retries += 1
            reg = obs.metrics.REGISTRY
            reg.counter("serve_dispatch_total").inc()
            if n_exp:
                reg.counter("serve_expired_dispatched_total").inc(n_exp)
            if attempt > 0:
                reg.counter("serve_retries_total").inc()
            for t in live:
                t.dispatched_at = dispatch_at
                t.attempts += 1
            # per-attempt JoinStats, merged into the aggregate on every
            # exit path — retried/failed-over attempts no longer
            # overwrite each other's engine stamps
            js = JoinStats()
            rung = ("degraded" if attempt == 0 and degraded else
                    "covered" if attempt == 0
                    and self._coverage_engine is not None else
                    "engine" if attempt == 0 else "host")
            tks = tuple(t.ticket_id for t in live) if obs.enabled() \
                else ()
            try:
                with obs.span("serve.attempt", tickets=tks,
                              attempt=attempt, rung=rung) as sp:
                    faultinject.fire("sched.dispatch")
                    if attempt == 0:
                        if degraded:
                            d, i, rb = \
                                self.degraded_engine.join_batch_approx(
                                    q, stats=js)
                            sp.set(outcome="ok", **_join_attrs(js))
                            return d, i, rb
                        ce = self._coverage_engine
                        if ce is not None:
                            # engine rung via the covered call:
                            # surviving shards answer and each response
                            # carries a certified per-query recall lower
                            # bound. The bound is kept only when the
                            # batch actually ran on a degraded-coverage
                            # view — a mid-call failover past the last
                            # replica flips ``coverage_degraded``, and
                            # the engine's internal retry already
                            # computed the batch (and its bound) on that
                            # updated view.
                            d, i, rb = ce.join_batch_covered(q, stats=js)
                            sp.set(outcome="ok", **_join_attrs(js))
                            if ce.coverage_degraded:
                                return d, i, rb
                            return d, i, None
                        d, i = self.engine.join_batch(q, stats=js)
                        sp.set(outcome="ok", **_join_attrs(js))
                        return d, i, None
                    # retry rung: the host-planned oracle — exact, no
                    # resident device payload to re-fault on
                    degraded = False
                    d, i = self._host_join(q, stats=js)
                    sp.set(outcome="ok", **_join_attrs(js))
                    return d, i, None
            finally:
                with self._lock:
                    self.stats.join = self.stats.join.merged(js)

        try:
            out = faultinject.retry_with_backoff(
                attempt_fn,
                max_retries=max(0, cfg.max_retries - first_attempt),
                base_s=cfg.backoff_base_s, cap_s=cfg.backoff_cap_s,
                sleep=self._sleep)
        except Exception as e:   # noqa: BLE001 — overload robustness:
            # a poisoned batch must not take the scheduler down
            with self._lock:
                for t in live:
                    t.status, t.reason = "failed", f"fault: {e!r}"
                    t.completed_at = self._clock()
                    self.stats.n_failed += 1
            obs.metrics.REGISTRY.counter("serve_failed_total") \
                .inc(len(live))
            if obs.enabled():
                obs.event("serve.failed",
                          tickets=tuple(t.ticket_id for t in live),
                          error=type(e).__name__)
            return
        if out is None:
            return                      # everything expired pre-dispatch
        d, i, rb = out
        self._complete(live, d, i, rb)

    # ---- background worker ------------------------------------------

    def serve_forever(self) -> threading.Thread:
        """Spawn the single consumer thread: steps whenever work is
        queued, sleeps on the condition variable otherwise. Idempotent;
        ``shutdown()`` stops it."""
        if self._worker is not None and self._worker.is_alive():
            return self._worker
        self._stop = False

        def loop():
            while True:
                with self._work:
                    # _inflight is consumer-thread-only state: reading
                    # it here (the consumer) needs no extra locking
                    while not self._queued_rows and not self._inflight \
                            and not self._stop:
                        self._work.wait(timeout=0.1)
                    if self._stop:
                        return
                self.step()

        self._worker = threading.Thread(target=loop, daemon=True,
                                        name="serve-scheduler")
        self._worker.start()
        return self._worker

    def shutdown(self, *, drain: bool = True) -> None:
        """Stop the worker; by default flush remaining work first."""
        if self._worker is None:
            if drain:
                self.drain()
            return
        if drain:
            while self.has_work and self._worker.is_alive():
                time.sleep(0.005)
        with self._work:
            self._stop = True
            self._work.notify_all()
        self._worker.join(timeout=5.0)
        self._worker = None


# ---------------------------------------------------------------------------
# open-loop load harness: virtual clock, arrival processes, reporting


class VirtualClock:
    """Deterministic clock for the open-loop bench and tests: arrivals
    happen in virtual time, executed batches advance it by their real
    measured cost. Pass ``clock=vc.now, sleep=vc.advance`` to the
    scheduler so deadlines and backoff live in the same timeline."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("time only moves forward")
        self._t += dt


def poisson_times(rate_per_s: float, duration_s: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Arrival instants of a Poisson process on [0, duration)."""
    if rate_per_s <= 0:
        return np.zeros((0,), np.float64)
    n_max = int(rate_per_s * duration_s * 3 + 16)
    gaps = rng.exponential(1.0 / rate_per_s, n_max)
    t = np.cumsum(gaps)
    return t[t < duration_s]


def bursty_times(rate_per_s: float, duration_s: float,
                 rng: np.random.Generator, *, burst: int = 8
                 ) -> np.ndarray:
    """Bursty arrivals at the same average rate: bursts of ``burst``
    back-to-back requests at Poisson epochs of rate ``rate/burst`` —
    the adversarial arrival pattern for queue watermarks."""
    epochs = poisson_times(rate_per_s / burst, duration_s, rng)
    return np.repeat(epochs, burst)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop arrival: request rows landing at virtual time t."""

    t: float
    rows: np.ndarray
    priority: Priority = Priority.INTERACTIVE
    deadline_s: Optional[float] = None


def run_open_loop(sched: ServeScheduler, arrivals: Sequence[Arrival],
                  clock: VirtualClock, *,
                  measure: Callable[[], float] = time.perf_counter
                  ) -> List[Ticket]:
    """Drive ``sched`` open-loop: requests arrive at their own pace
    (offered load does not slow down because the server is busy — the
    regime a million-user deployment is judged on), service costs are
    the real measured wall time of each executed batch. Returns every
    ticket, resolved."""
    arrivals = sorted(arrivals, key=lambda a: a.t)
    tickets: List[Ticket] = []
    i = 0
    while i < len(arrivals) or sched.has_work:
        now = clock.now()
        while i < len(arrivals) and arrivals[i].t <= now:
            a = arrivals[i]
            i += 1
            tickets.append(sched.submit(
                a.rows, deadline_s=a.deadline_s, priority=a.priority,
                arrival=a.t))
        if not sched.has_work:
            if i < len(arrivals):
                clock.advance(arrivals[i].t - clock.now())
            continue
        t0 = measure()
        sched.step()
        clock.advance(measure() - t0)
    return tickets


@dataclasses.dataclass
class LoadReport:
    """Aggregates one open-loop run: the numbers the ROADMAP's serving
    milestone is judged on."""

    n_requests: int
    n_completed: int
    n_shed: int
    n_rejected: int
    n_failed: int
    n_degraded: int
    rows_total: int
    rows_goodput: int
    duration_s: float
    goodput_rows_s: float
    p50_s: float
    p99_s: float
    p999_s: float
    shed_rate: float
    degraded_frac: float
    n_expired_dispatched: int
    recall_bound_min: float

    @classmethod
    def from_tickets(cls, tickets: Sequence[Ticket],
                     stats: SchedulerStats) -> "LoadReport":
        done = [t for t in tickets if t.done]
        lat = np.sort(np.asarray(
            [t.completed_at - t.arrival for t in done], np.float64))

        def pct(p: float) -> float:
            if lat.size == 0:
                return float("inf")
            return float(lat[min(lat.size - 1, int(p * lat.size))])

        t_end = max((t.completed_at for t in tickets
                     if t.completed_at is not None), default=0.0)
        t0 = min((t.arrival for t in tickets), default=0.0)
        dur = max(t_end - t0, 1e-9)
        good = sum(t.n for t in done if t.completed_at <= t.deadline)
        rows_total = sum(t.n for t in tickets)
        shed = [t for t in tickets if t.status == "shed"]
        rej = [t for t in tickets if t.status == "rejected"]
        degraded = [t for t in done if t.degraded]
        rb_min = min((float(t.recall_bound.min()) for t in degraded),
                     default=1.0)
        return cls(
            n_requests=len(tickets), n_completed=len(done),
            n_shed=len(shed), n_rejected=len(rej),
            n_failed=sum(t.status == "failed" for t in tickets),
            n_degraded=len(degraded),
            rows_total=rows_total, rows_goodput=good,
            duration_s=dur, goodput_rows_s=good / dur,
            p50_s=pct(0.50), p99_s=pct(0.99), p999_s=pct(0.999),
            shed_rate=(sum(t.n for t in shed) + sum(t.n for t in rej))
            / max(rows_total, 1),
            degraded_frac=sum(t.n for t in degraded)
            / max(sum(t.n for t in done), 1),
            n_expired_dispatched=stats.n_expired_dispatched,
            recall_bound_min=rb_min)
