"""Fault-injection hooks for the serving runtime.

``distributed/fault.py`` ports MapReduce's resilience model (idempotent
re-execution, speculation) to the *offline* join; this module extends
the same philosophy into the serving loop, where failures are transient
device-side events — an OOM on payload upload, a failed result fetch, a
poisoned batch — and the recovery is a capped-backoff retry onto the
host-planned oracle path (every engine's results are deterministic
functions of (query rows, index), so re-execution on any path is always
safe, exactly the §2.2 JobTracker contract).

Production code *fires* named hook sites; tests and chaos drills *arm*
a :class:`FaultPlan` that decides what happens there. With no plan
armed (the default), every site is a no-op costing one ``None`` check.

Hook sites wired into this codebase:

* ``megastep.payload_upload`` — fired by ``core.megastep.MegastepEngine
  ._refresh`` when the device-resident index payload is (re)built and
  uploaded; failing it simulates a device OOM at upload time.
* ``megastep.fetch`` — fired just before a device→host result fetch
  (``MegastepEngine.join_batch`` and the quantized tier's
  ``coarse_shortlist``); failing it simulates a lost fetch.
* ``sched.dispatch`` — fired by ``serve.scheduler.ServeScheduler`` just
  before a formed batch is handed to an engine; failing it simulates a
  poisoned batch.
* ``quant.eps_inflation`` — a *transform* site over the quantized
  tier's certified lower bounds (``QuantMegastepEngine
  .coarse_shortlist``): shrinking them is exactly what inflated ε
  errors would do, so a transform here forces certificate failures and
  exercises the fp32-oracle fallback deliberately
  (tests/test_quant.py pins that the output stays bitwise-exact).

Usage::

    with FaultPlan().fail("megastep.payload_upload", times=2):
        scheduler.step()          # first 2 uploads raise InjectedFault

    with FaultPlan().transform("quant.eps_inflation",
                               lambda lb: lb - 1e9):
        engine.join_batch(q)      # every certificate fails -> fallback
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional

__all__ = ["FaultPlan", "InjectedFault", "fire", "transform_value",
           "retry_with_backoff"]


class InjectedFault(RuntimeError):
    """Raised by an armed hook site — the serving loop treats it exactly
    like the real transient failure it stands in for."""

    def __init__(self, site: str, message: Optional[str] = None):
        super().__init__(message or f"injected fault at {site!r}")
        self.site = site


class FaultPlan:
    """A per-site schedule of injected failures and value transforms.

    Context-manager armed: sites fire only while the plan is active, and
    ``fired`` counts every hook crossing (armed or not scheduled), so
    tests can assert a site was actually reached. Thread-safe — the
    serving loop fires from worker threads.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._fail: Dict[str, list] = {}        # site -> [remaining, exc]
        self._transform: Dict[str, Callable] = {}
        self.fired: Dict[str, int] = {}

    # ---- arming ----------------------------------------------------

    def fail(self, site: str, *, times: int = 1,
             exc: Optional[Exception] = None) -> "FaultPlan":
        """The next ``times`` crossings of ``site`` raise (``exc`` or an
        :class:`InjectedFault`); later crossings pass."""
        self._fail[site] = [int(times), exc]
        return self

    def transform(self, site: str, fn: Callable[[Any], Any]) -> "FaultPlan":
        """Every crossing of the transform site maps its value through
        ``fn`` (e.g. deflate certified bounds = inflate ε)."""
        self._transform[site] = fn
        return self

    # ---- the hook side ---------------------------------------------

    def _fire(self, site: str) -> None:
        with self._lock:
            self.fired[site] = self.fired.get(site, 0) + 1
            ent = self._fail.get(site)
            if ent is None or ent[0] <= 0:
                return
            ent[0] -= 1
            exc = ent[1]
        raise exc if exc is not None else InjectedFault(site)

    def _transform_value(self, site: str, value):
        with self._lock:
            self.fired[site] = self.fired.get(site, 0) + 1
            fn = self._transform.get(site)
        return value if fn is None else fn(value)

    # ---- arming scope ----------------------------------------------

    def __enter__(self) -> "FaultPlan":
        global _PLAN
        if _PLAN is not None:
            raise RuntimeError("a FaultPlan is already armed")
        _PLAN = self
        return self

    def __exit__(self, *exc) -> bool:
        global _PLAN
        _PLAN = None
        return False


_PLAN: Optional[FaultPlan] = None


def fire(site: str) -> None:
    """Production-side hook: raise if an armed plan scheduled a failure
    here; free (one None check) otherwise."""
    plan = _PLAN
    if plan is not None:
        plan._fire(site)


def transform_value(site: str, value):
    """Production-side transform hook: map ``value`` through the armed
    plan's transform for ``site`` (identity when unarmed)."""
    plan = _PLAN
    if plan is None:
        return value
    return plan._transform_value(site, value)


def retry_with_backoff(fn: Callable[[int], Any], *, max_retries: int,
                       base_s: float, cap_s: float,
                       sleep: Callable[[float], None] = time.sleep,
                       retriable: tuple = (Exception,)):
    """Capped-exponential-backoff retry driver — the serving-loop
    analogue of ``distributed.fault.GroupExecutor``'s bounded re-issue.

    Calls ``fn(attempt)`` (attempt 0 = first try); on a retriable
    failure sleeps ``min(base_s * 2**attempt, cap_s)`` and re-calls
    with the next attempt number — the callee routes later attempts
    onto a safer path (the host-planned oracle). Raises the last error
    after ``max_retries`` retries.
    """
    attempt = 0
    while True:
        try:
            return fn(attempt)
        except retriable:
            if attempt >= max_retries:
                raise
            sleep(min(base_s * (2.0 ** attempt), cap_s))
            attempt += 1
