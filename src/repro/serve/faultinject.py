"""Fault-injection hooks for the serving runtime.

``distributed/fault.py`` ports MapReduce's resilience model (idempotent
re-execution, speculation) to the *offline* join; this module extends
the same philosophy into the serving loop, where failures are transient
device-side events — an OOM on payload upload, a failed result fetch, a
poisoned batch — and the recovery is a capped-backoff retry onto the
host-planned oracle path (every engine's results are deterministic
functions of (query rows, index), so re-execution on any path is always
safe, exactly the §2.2 JobTracker contract).

Production code *fires* named hook sites; tests and chaos drills *arm*
a :class:`FaultPlan` that decides what happens there. With no plan
armed (the default), every site is a no-op costing one ``None`` check.

Hook sites wired into this codebase:

* ``megastep.payload_upload`` — fired by ``core.megastep.MegastepEngine
  ._refresh`` when the device-resident index payload is (re)built and
  uploaded; failing it simulates a device OOM at upload time.
* ``megastep.fetch`` — fired just before a device→host result fetch
  (``MegastepEngine.join_batch`` and the quantized tier's
  ``coarse_shortlist``); failing it simulates a lost fetch.
* ``sched.dispatch`` — fired by ``serve.scheduler.ServeScheduler`` just
  before a formed batch is handed to an engine; failing it simulates a
  poisoned batch.
* ``quant.eps_inflation`` — a *transform* site over the quantized
  tier's certified lower bounds (``QuantMegastepEngine
  .coarse_shortlist``): shrinking them is exactly what inflated ε
  errors would do, so a transform here forces certificate failures and
  exercises the fp32-oracle fallback deliberately
  (tests/test_quant.py pins that the output stays bitwise-exact).
* ``sharded.shard_upload`` — fired by the sharded engines'
  ``_put_shard`` whenever a shard-partitioned payload piece is
  committed to the mesh; failing it (with a :class:`ShardFault` naming
  the shard) simulates a device lost during payload upload.
* ``sharded.shard_compute`` — fired just before the sharded SPMD
  megastep launch (``ShardedMegastepEngine.dispatch``); a
  :class:`ShardFault` here simulates a shard dying mid-stream.
* ``sharded.collective`` — a combined :func:`cross` site over the
  fetched cross-shard merge result (``ShardedMegastepEngine
  .finalize``): ``.fail`` simulates a poisoned all-gather, while a
  sleeping ``.transform`` simulates a *hung* collective — which the
  engine's bounded ``attempt_timeout`` must convert into a
  :class:`ShardFailedError` instead of hanging ``serve_forever()``.

All sites compose in one armed plan: a mixed-site ``FaultPlan`` fires
each site independently, exactly as armed (pinned by
tests/test_shard_failover.py).

Usage::

    with FaultPlan().fail("megastep.payload_upload", times=2):
        scheduler.step()          # first 2 uploads raise InjectedFault

    with FaultPlan().transform("quant.eps_inflation",
                               lambda lb: lb - 1e9):
        engine.join_batch(q)      # every certificate fails -> fallback
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional

__all__ = ["FaultPlan", "InjectedFault", "ShardFault", "ShardFailedError",
           "fire", "transform_value", "cross", "retry_with_backoff"]


class InjectedFault(RuntimeError):
    """Raised by an armed hook site — the serving loop treats it exactly
    like the real transient failure it stands in for."""

    def __init__(self, site: str, message: Optional[str] = None):
        super().__init__(message or f"injected fault at {site!r}")
        self.site = site


class ShardFault(InjectedFault):
    """An injected fault attributed to one mesh shard (pass as ``exc=``
    to :meth:`FaultPlan.fail` on a ``sharded.*`` site). The sharded
    engines convert it into a :class:`ShardFailedError` after marking
    the shard failed in their health tracker — anonymous
    :class:`InjectedFault`\\ s on the same sites stay generic transients
    handled by the retry ladder instead."""

    def __init__(self, site: str, *, shard: Optional[int] = None,
                 message: Optional[str] = None):
        super().__init__(site, message
                         or f"injected shard fault at {site!r} "
                            f"(shard {shard})")
        self.shard = shard


class ShardFailedError(RuntimeError):
    """A sharded engine detected a failed/hung shard and updated its
    serving view (failover). Unlike a generic transient, retrying the
    *same* engine is the right response: the next attempt runs on the
    updated owner view (replica failover — still bitwise — or certified
    degraded coverage), not on the host-oracle path. The scheduler
    re-checks deadlines at that failover instant."""

    def __init__(self, shard: Optional[int], message: str):
        super().__init__(message)
        self.shard = shard


class FaultPlan:
    """A per-site schedule of injected failures and value transforms.

    Context-manager armed: sites fire only while the plan is active, and
    ``fired`` counts every hook crossing (armed or not scheduled), so
    tests can assert a site was actually reached. Thread-safe — the
    serving loop fires from worker threads.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._fail: Dict[str, list] = {}        # site -> [remaining, exc]
        self._transform: Dict[str, Callable] = {}
        self.fired: Dict[str, int] = {}

    # ---- arming ----------------------------------------------------

    def fail(self, site: str, *, times: int = 1,
             exc: Optional[Exception] = None) -> "FaultPlan":
        """The next ``times`` crossings of ``site`` raise (``exc`` or an
        :class:`InjectedFault`); later crossings pass."""
        self._fail[site] = [int(times), exc]
        return self

    def transform(self, site: str, fn: Callable[[Any], Any]) -> "FaultPlan":
        """Every crossing of the transform site maps its value through
        ``fn`` (e.g. deflate certified bounds = inflate ε)."""
        self._transform[site] = fn
        return self

    # ---- the hook side ---------------------------------------------

    def _fire(self, site: str) -> None:
        from repro import obs
        with self._lock:
            self.fired[site] = self.fired.get(site, 0) + 1
            ent = self._fail.get(site)
            if ent is None or ent[0] <= 0:
                obs.metrics.REGISTRY.counter(
                    "fault_crossings_total", site=site).inc()
                return
            ent[0] -= 1
            exc = ent[1]
        reg = obs.metrics.REGISTRY
        reg.counter("fault_crossings_total", site=site).inc()
        reg.counter("fault_injected_total", site=site).inc()
        obs.event("fault.injected", site=site)
        raise exc if exc is not None else InjectedFault(site)

    def _transform_value(self, site: str, value):
        from repro import obs
        with self._lock:
            self.fired[site] = self.fired.get(site, 0) + 1
            fn = self._transform.get(site)
        reg = obs.metrics.REGISTRY
        reg.counter("fault_crossings_total", site=site).inc()
        if fn is None:
            return value
        reg.counter("fault_injected_total", site=site).inc()
        obs.event("fault.injected", site=site, kind="transform")
        return fn(value)

    def _cross(self, site: str, value):
        """fire + transform as ONE counted crossing (see :func:`cross`):
        a scheduled failure wins; otherwise an armed transform maps the
        value through (and may sleep — a hang — or raise itself)."""
        from repro import obs
        exc = fn = None
        with self._lock:
            self.fired[site] = self.fired.get(site, 0) + 1
            ent = self._fail.get(site)
            if ent is not None and ent[0] > 0:
                ent[0] -= 1
                exc = ent[1] if ent[1] is not None else InjectedFault(site)
            else:
                fn = self._transform.get(site)
        reg = obs.metrics.REGISTRY
        reg.counter("fault_crossings_total", site=site).inc()
        if exc is not None:
            reg.counter("fault_injected_total", site=site).inc()
            obs.event("fault.injected", site=site)
            raise exc
        if fn is None:
            return value
        reg.counter("fault_injected_total", site=site).inc()
        obs.event("fault.injected", site=site, kind="transform")
        return fn(value)

    # ---- arming scope ----------------------------------------------

    def __enter__(self) -> "FaultPlan":
        global _PLAN
        if _PLAN is not None:
            raise RuntimeError("a FaultPlan is already armed")
        _PLAN = self
        return self

    def __exit__(self, *exc) -> bool:
        global _PLAN
        _PLAN = None
        return False


_PLAN: Optional[FaultPlan] = None


def fire(site: str) -> None:
    """Production-side hook: raise if an armed plan scheduled a failure
    here; free (one None check) otherwise."""
    plan = _PLAN
    if plan is not None:
        plan._fire(site)


def transform_value(site: str, value):
    """Production-side transform hook: map ``value`` through the armed
    plan's transform for ``site`` (identity when unarmed)."""
    plan = _PLAN
    if plan is None:
        return value
    return plan._transform_value(site, value)


def cross(site: str, value=None):
    """Combined production-side hook for sites that can both *fail*
    (``FaultPlan.fail``) and be *value-warped or delayed*
    (``FaultPlan.transform``) — e.g. ``sharded.collective``, where a
    fail is a poisoned all-gather and a sleeping transform is a hung
    one. One counted crossing either way; identity when unarmed."""
    plan = _PLAN
    if plan is None:
        return value
    return plan._cross(site, value)


def retry_with_backoff(fn: Callable[[int], Any], *, max_retries: int,
                       base_s: float, cap_s: float,
                       sleep: Callable[[float], None] = time.sleep,
                       retriable: tuple = (Exception,)):
    """Capped-exponential-backoff retry driver — the serving-loop
    analogue of ``distributed.fault.GroupExecutor``'s bounded re-issue.

    Calls ``fn(attempt)`` (attempt 0 = first try); on a retriable
    failure sleeps ``min(base_s * 2**attempt, cap_s)`` and re-calls
    with the next attempt number — the callee routes later attempts
    onto a safer path (the host-planned oracle). Raises the last error
    after ``max_retries`` retries.
    """
    attempt = 0
    while True:
        try:
            return fn(attempt)
        except retriable:
            if attempt >= max_retries:
                raise
            sleep(min(base_s * (2.0 ** attempt), cap_s))
            attempt += 1
