"""kNN-LM retrieval — the paper's join as a first-class serving feature.

Datastore: (keys (N, D) hidden states, values (N,) next tokens). At each
decode step the batch of hidden states is the R side (|R| = batch) and
the datastore is the S side of an `R ⋉ S` kNN join — |R| ≪ |S| is
exactly the regime where shipping S subsets instead of all of S pays
(paper §3).

The datastore is **mutable while it serves**: it holds a segmented
``core.segments.MutableIndex``, so ``add_entries`` can ingest new
(key, value) pairs mid-decode — they land in a write buffer that seals
into a small delta segment, and S-side phase 1 never re-runs on
pre-existing segments — and ``remove_entries`` tombstones stale entries
without touching any segment. ``compact()`` folds segments + tombstones
back into one base between decode steps and remaps the row-aligned
``keys``/``values`` tables to the re-based id space.

Steady-state retrieval runs the **fused megastep** (`core.megastep`):
the datastore keeps one ``StreamJoinEngine(megastep=...)`` per k, whose
device-resident index payload and compiled step persist across decode
steps — each batch is one upload, one jitted
assign→bounds→schedule→gather-top-k→merge pass over all live segments,
one fetch. No per-batch host planning: the old per-decode
``plan_queries`` round-trip exists only on the (still available)
host-planned oracle path.

p(token) = (1−λ) p_LM + λ softmax(−d²/τ) aggregated over retrieved
neighbors (Khandelwal et al. 2020), with PGBJ supplying the neighbors.
Both neighbor paths (the PGBJ join and the raw `distance_topk` kernel)
return **true** distances; `knn_logits` converts them to one comparable
space via `core.metrics.to_cmp` before the softmax, so the two paths
produce identical retrieval distributions (pinned by a regression test).
Padding slots (id −1 / +inf distance — fewer than k live neighbors) are
masked out of the softmax explicitly: they carry zero weight instead of
wrapping around the value table, and a query with zero finite neighbors
degrades to the log-floor distribution rather than NaN.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import JoinConfig, MutableIndex, StreamJoinEngine
from repro.core.index import as_float32_rows
from repro.core.metrics import to_cmp
from repro.kernels import distance_topk


@dataclasses.dataclass
class Datastore:
    keys: np.ndarray       # (N_alloc, D) float32, row g = global id g
    values: np.ndarray     # (N_alloc,) int32 token ids, aligned to keys
    index: MutableIndex    # segmented mutable S side (base + deltas)
    config: JoinConfig
    # shard the resident payload across a mesh of this many devices and
    # serve through the sharded megastep (core.sharded); 0 = one device
    n_shards: int = 0
    # place every pivot group on this many shards (primary + r−1
    # backups) so serving survives shard loss bitwise (core.sharded
    # failover; fp32 sharded path only — ignored single-device)
    replication: int = 1
    # one resident engine per k: the megastep's uploaded index payload
    # and compiled step live here and survive across decode steps
    _engines: dict = dataclasses.field(default_factory=dict, repr=False)
    # guards every mutation (add/remove/compact), the engine cache, and
    # — via each engine's ``refresh_lock`` — the megastep payload
    # rebuild, so a mutation racing a query can never tear the
    # (segments, tombstones, version) read a payload is built from.
    # Queries themselves run lock-free with an optimistic version check
    # (``retrieve``): they hold the lock only for snapshot/recheck.
    _lock: object = dataclasses.field(default_factory=threading.RLock,
                                      repr=False)

    @property
    def quantized(self) -> bool:
        """Whether retrieval serves through the quantized tier
        (repro.quant): derived from ``config.quantize`` — the single
        source of truth the segments are built with — so a directly
        constructed Datastore can never carry int8 codes it then
        ignores."""
        return self.config.quantize != "none"

    @classmethod
    def build(cls, keys, values, *, k: int = 8, n_pivots: int = 256,
              n_groups: int = 8, seed: int = 0, seal_threshold: int = 4096,
              quantized: bool = False, n_shards: int = 0,
              replication: int = 1):
        """S-side phase 1, once, over the initial keys: after this,
        serving touches pre-existing keys only through the segments'
        packed layouts — growth happens in delta segments.

        ``keys`` may be model-emitted bfloat16/float16 hidden states —
        cast to float32 once here. ``quantized=True`` stamps
        ``quantize="int8"`` into the config, so every segment (base,
        sealed deltas, compacted rebuilds) carries its int8 codes and
        retrieval serves through the quantized tier. ``n_shards=N``
        partitions the resident payload across an N-device mesh and
        serves through the sharded megastep — same bits, N× the HBM.
        ``replication=r`` (fp32 sharded serving) keeps every pivot
        group on r shards so serving survives shard loss bitwise."""
        keys = as_float32_rows(keys, what="datastore keys")
        cfg = JoinConfig(k=k, n_pivots=min(n_pivots, keys.shape[0]),
                         n_groups=n_groups, grouping="geometric", seed=seed,
                         quantize="int8" if quantized else "none")
        return cls(keys=keys, values=np.asarray(values, np.int32),
                   index=MutableIndex.build(keys, cfg,
                                            seal_threshold=seal_threshold),
                   config=cfg, n_shards=int(n_shards),
                   replication=int(replication))

    @property
    def n_entries(self) -> int:
        """Live (key, value) pairs."""
        return self.index.n_s

    def add_entries(self, keys, values) -> np.ndarray:
        """Ingest new (key, value) pairs mid-decode; returns their global
        ids. Buffered immediately (queryable from the next batch on),
        sealed into a delta segment past the threshold — phase 1 never
        re-runs on pre-existing segments. Accepts bfloat16/float16
        hidden states (models emit bf16 — see `launch/serve.py`): cast
        to float32 once at this boundary, never silently widened to
        float64; non-float dtypes raise."""
        keys = as_float32_rows(keys, what="datastore keys")
        values = np.atleast_1d(np.asarray(values, np.int32))
        if keys.shape[0] != values.shape[0]:
            raise ValueError(
                f"{keys.shape[0]} keys but {values.shape[0]} values")
        with self._lock:
            ids = self.index.insert(keys)
            self.keys = np.concatenate([self.keys, keys], axis=0)
            self.values = np.concatenate([self.values, values])
        return ids

    def remove_entries(self, ids) -> None:
        """Tombstone entries by global id — O(|ids|), no segment touched;
        the rows stop being retrievable from the next batch on."""
        with self._lock:
            self.index.delete(ids)

    def compact(self) -> np.ndarray:
        """Fold segments + tombstones into one rebuilt base (between
        decode steps); re-bases ids to ``0..n_live-1`` and remaps the
        row-aligned keys/values tables. Returns the old ids in new-id
        order."""
        with self._lock:
            old_ids = self.index.compact()
            self.keys = np.ascontiguousarray(self.keys[old_ids])
            self.values = np.ascontiguousarray(self.values[old_ids])
        return old_ids

    def engine(self, k: Optional[int] = None) -> StreamJoinEngine:
        """The resident streaming engine for ``k`` (≤ the live row
        count), created once and cached: repeat decode steps reuse the
        megastep's device-resident payload and compiled step instead of
        re-padding and re-planning. Mutations are picked up through the
        index version — no engine invalidation needed (the engine's
        payload rebuild shares this store's lock, so it can never cache
        a half-swapped snapshot under a valid version key)."""
        kk = self.config.k if k is None else int(k)
        with self._lock:
            eng = self._engines.get(kk)
            if eng is None:
                cfg = self.config if kk == self.config.k \
                    else dataclasses.replace(self.config, k=kk)
                rep = self.replication if (self.n_shards
                                           and not self.quantized) else 1
                eng = StreamJoinEngine(self.index, cfg, megastep="auto",
                                       quantized=self.quantized,
                                       n_shards=self.n_shards or None,
                                       replication=rep)
                me = eng.megastep_engine
                if me is not None:
                    me.refresh_lock = self._lock
                self._engines[kk] = eng
        return eng

    def recover_shards(self, *, wait: bool = False) -> list:
        """Re-admit failed shards on every cached sharded engine:
        rebuild + re-upload the shard-partitioned payloads and reset
        health (`core.sharded.ShardedMegastepEngine.recover`). With
        ``wait=False`` (the serving default) recovery runs in daemon
        threads behind each engine's refresh lock — serving keeps
        answering on the degraded views meanwhile. Returns the recovery
        threads (empty when nothing sharded is cached or failed)."""
        with self._lock:
            engines = list(self._engines.values())
        out = []
        for eng in engines:
            me = eng.megastep_engine
            if me is not None and hasattr(me, "recover"):
                t = me.recover(wait=wait)
                if t is not None:
                    out.append(t)
        return out

    def retrieve(self, queries: np.ndarray, k: Optional[int] = None, *,
                 stats=None, max_retries: int = 8):
        """Join one batch against the live index with a *consistent*
        snapshot: returns ``(dists, ids, values)`` where ``values`` is
        the value table matching exactly the index version the results
        came from — a mutation racing the query can never yield a mixed
        answer (ids from one version looked up in another's table).

        Optimistic concurrency: snapshot (version, values, engine) under
        the lock, join lock-free, recheck the version; on a concurrent
        mutation retry, and after ``max_retries`` collisions finish the
        join while *holding* the lock (writers block briefly — bounded
        starvation instead of unbounded retries)."""
        from repro import obs
        reg = obs.metrics.REGISTRY
        reg.counter("retrieval_joins_total").inc()
        queries = np.ascontiguousarray(queries, np.float32)
        for _ in range(max_retries):
            with self._lock:
                v0 = self.index.version
                values = self.values
                eng = self.engine(k)
            try:
                d, idx = eng.join_batch(queries, stats=stats)
            except Exception:
                with self._lock:
                    if self.index.version != v0:
                        reg.counter(
                            "retrieval_version_retries_total").inc()
                        continue     # mutated mid-join; retry, not a fault
                raise
            with self._lock:
                if self.index.version == v0:
                    return d, idx, values
            reg.counter("retrieval_version_retries_total").inc()
        with self._lock:             # write-heavy: serialize this one
            d, idx = self.engine(k).join_batch(queries, stats=stats)
            return d, idx, self.values

    def lookup_tokens(self, ids: np.ndarray,
                      values: Optional[np.ndarray] = None) -> np.ndarray:
        """Map global ids → tokens against ``values`` (a snapshot from
        :meth:`retrieve`) or the current table; padding ids (−1) map to
        token 0 — callers mask their weight anyway."""
        if values is None:
            with self._lock:
                values = self.values
        toks = values[np.clip(ids, 0, values.shape[0] - 1)]
        return np.where(ids >= 0, toks, 0)


@dataclasses.dataclass(frozen=True)
class KnnLMConfig:
    lam: float = 0.25
    tau: float = 10.0
    k: int = 8


_LOG_FLOOR = np.float32(np.log(1e-9))


def knn_logits(queries: np.ndarray, store: Datastore, kcfg: KnnLMConfig,
               vocab: int, *, use_kernel: bool = False,
               scheduler=None, deadline_s: Optional[float] = None,
               ) -> np.ndarray:
    """Retrieval distribution per query, (B, vocab) log-space.

    ``use_kernel=False`` (default) runs the batch through the
    datastore's resident engine — the fused megastep over the segmented
    index: one jitted assign→bounds→schedule→gather-top-k→merge pass,
    no per-batch host planning (the PGBJ serve path);
    ``use_kernel=True`` runs the brute-force `distance_topk` kernel over
    the store's live rows. Both return true distances, normalized to
    comparable space (`to_cmp`: squared for L2) before
    ``softmax(−d_cmp/τ)``; padded slots (id −1 / non-finite distance)
    are excluded from the softmax, and a query with zero finite
    neighbors gets the flat log-floor row (never NaN, never a wraparound
    read of ``values[-1]``).

    ``scheduler`` (a ``serve.scheduler.ServeScheduler``) routes the
    batch through admission control instead of calling the engine
    directly: under overload the result may be certified-approximate,
    and a shed/rejected batch degrades to the log-floor rows — the
    interpolation then falls back to the LM distribution alone, which
    is the graceful failure mode for retrieval under pressure.
    ``deadline_s`` bounds the retrieval's staleness in that path.
    """
    queries = np.ascontiguousarray(queries, np.float32)
    nq = queries.shape[0]
    k_eff = min(kcfg.k, store.index.n_s)
    if k_eff == 0:
        return np.full((nq, vocab), _LOG_FLOOR, np.float32)
    values = None
    if scheduler is not None:
        t = scheduler.join_now(queries, deadline_s=deadline_s)
        if not t.done:               # shed/rejected: LM-only this step
            return np.full((nq, vocab), _LOG_FLOOR, np.float32)
        d, idx = t.distances, t.indices
    elif use_kernel:
        rows_dev, gids = store.index.live_device_rows()
        d, local = distance_topk(jnp.asarray(queries), rows_dev, k_eff)
        d = np.asarray(d)
        local = np.asarray(local)
        idx = np.where(local >= 0,
                       gids[np.clip(local, 0, gids.shape[0] - 1)], -1)
    else:
        d, idx, values = store.retrieve(queries, k_eff)
    valid = (idx >= 0) & np.isfinite(d)
    x = np.where(valid, -to_cmp(d, store.config.metric) / kcfg.tau,
                 -np.inf).astype(np.float32)
    # masked softmax: padded slots carry zero weight; an all-masked row
    # (no finite neighbors) yields all-zero weights, not 0/0
    m = np.max(x, axis=1, keepdims=True)
    m = np.where(np.isfinite(m), m, np.float32(0.0))
    e = np.where(valid, np.exp(x - m), np.float32(0.0)).astype(np.float32)
    z = e.sum(axis=1, keepdims=True)
    w = e / np.maximum(z, np.float32(1e-30))
    toks = store.lookup_tokens(idx, values)     # (B, k); masked: w is 0
    probs = np.zeros((nq, vocab), np.float32)
    np.add.at(probs, (np.arange(nq)[:, None], toks), w)
    return np.log(np.maximum(probs, 1e-9))


def interpolate(lm_logits: jnp.ndarray, knn_log: np.ndarray,
                lam: float) -> jnp.ndarray:
    """(1-λ)·p_LM + λ·p_kNN, done in probability space, returned as logits."""
    p_lm = jax.nn.softmax(lm_logits, axis=-1)
    p_knn = jnp.exp(jnp.asarray(knn_log))
    p_knn = p_knn / jnp.maximum(p_knn.sum(-1, keepdims=True), 1e-9)
    return jnp.log(jnp.maximum((1 - lam) * p_lm + lam * p_knn, 1e-9))
