"""kNN-LM retrieval — the paper's join as a first-class serving feature.

Datastore: (keys (N, D) hidden states, values (N,) next tokens). At each
decode step the batch of hidden states is the R side (|R| = batch) and the
datastore is the S side of an `R ⋉ S` kNN join. The PGBJ machinery applies
unchanged: Voronoi partitioning of S, θ/LB bounds, and (multi-device) the
group shuffle — |R| ≪ |S| is exactly the regime where shipping S subsets
instead of all of S pays (paper §3).

p(token) = (1−λ) p_LM + λ softmax(-d_i²/τ) aggregated over retrieved
neighbors (Khandelwal et al. 2020), with PGBJ supplying the neighbors.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import JoinConfig, knn_join, plan_join
from repro.core.api import JoinPlan
from repro.kernels import distance_topk


@dataclasses.dataclass
class Datastore:
    keys: np.ndarray       # (N, D) float32
    values: np.ndarray     # (N,) int32 token ids
    plan: Optional[JoinPlan] = None
    config: Optional[JoinConfig] = None

    @classmethod
    def build(cls, keys, values, *, k: int = 8, n_pivots: int = 256,
              n_groups: int = 8, seed: int = 0):
        keys = np.ascontiguousarray(keys, np.float32)
        cfg = JoinConfig(k=k, n_pivots=min(n_pivots, keys.shape[0]),
                         n_groups=n_groups, grouping="geometric", seed=seed)
        # S-side phase-1 runs once at build; R (queries) arrive per step.
        return cls(keys=keys, values=np.asarray(values, np.int32),
                   config=cfg)

    def prepare(self, sample_queries: np.ndarray):
        """Plan the join once against representative queries (pivots are
        selected from R per the paper; serving uses a warmup query set)."""
        self.plan = plan_join(sample_queries.astype(np.float32),
                              self.keys, self.config)


@dataclasses.dataclass(frozen=True)
class KnnLMConfig:
    lam: float = 0.25
    tau: float = 10.0
    k: int = 8


def knn_logits(queries: np.ndarray, store: Datastore, kcfg: KnnLMConfig,
               vocab: int, *, use_kernel: bool = True) -> np.ndarray:
    """Retrieval distribution per query, (B, vocab) log-space."""
    if store.plan is not None:
        res = knn_join(queries.astype(np.float32), store.keys,
                       k=kcfg.k, config=store.config)
        d, idx = res.distances, res.indices
    elif use_kernel:
        d, idx = distance_topk(jnp.asarray(queries, jnp.float32),
                               jnp.asarray(store.keys), kcfg.k)
        d, idx = np.asarray(d), np.asarray(idx)
    else:
        raise ValueError("datastore not prepared")
    w = jax.nn.softmax(jnp.asarray(-(d ** 2) / kcfg.tau), axis=-1)  # (B,k)
    toks = store.values[idx]                                        # (B,k)
    probs = np.zeros((queries.shape[0], vocab), np.float32)
    np.add.at(probs, (np.arange(queries.shape[0])[:, None], toks),
              np.asarray(w))
    return np.log(np.maximum(probs, 1e-9))


def interpolate(lm_logits: jnp.ndarray, knn_log: np.ndarray,
                lam: float) -> jnp.ndarray:
    """(1-λ)·p_LM + λ·p_kNN, done in probability space, returned as logits."""
    p_lm = jax.nn.softmax(lm_logits, axis=-1)
    p_knn = jnp.exp(jnp.asarray(knn_log))
    p_knn = p_knn / jnp.maximum(p_knn.sum(-1, keepdims=True), 1e-9)
    return jnp.log(jnp.maximum((1 - lam) * p_lm + lam * p_knn, 1e-9))
