"""kNN-LM retrieval — the paper's join as a first-class serving feature.

Datastore: (keys (N, D) hidden states, values (N,) next tokens). At each
decode step the batch of hidden states is the R side (|R| = batch) and
the datastore is the S side of an `R ⋉ S` kNN join — |R| ≪ |S| is
exactly the regime where shipping S subsets instead of all of S pays
(paper §3).

The build-once/query-many split (core.index) is what makes this a
serving primitive: ``Datastore.build`` runs S-side phase 1 once —
pivots, Voronoi assignment, T_S, the pivot-sorted packed rows — and
every decode step's batch is planned fresh by the streaming engine
(``core.stream.StreamJoinEngine``): jitted R assignment + θ/LB, then
the per-group join against the resident index. No warmup-query
planning, no stale θ from a representative sample — the bounds each
step prunes with are derived from that step's actual hidden states.

p(token) = (1−λ) p_LM + λ softmax(−d²/τ) aggregated over retrieved
neighbors (Khandelwal et al. 2020), with PGBJ supplying the neighbors.
Both neighbor paths (the PGBJ join and the raw `distance_topk` kernel)
return **true** distances; `knn_logits` converts them to one comparable
space via `core.metrics.to_cmp` before the softmax, so the two paths
produce identical retrieval distributions (pinned by a regression test).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import JoinConfig, StreamJoinEngine, build_index
from repro.core.index import SIndex
from repro.core.metrics import to_cmp
from repro.kernels import distance_topk


@dataclasses.dataclass
class Datastore:
    keys: np.ndarray       # (N, D) float32
    values: np.ndarray     # (N,) int32 token ids
    index: SIndex          # build-once S side (pivots, T_S, packed rows)
    config: JoinConfig

    @classmethod
    def build(cls, keys, values, *, k: int = 8, n_pivots: int = 256,
              n_groups: int = 8, seed: int = 0):
        """S-side phase 1, once: after this, serving never touches the
        keys again except through the index's packed layout."""
        keys = np.ascontiguousarray(keys, np.float32)
        cfg = JoinConfig(k=k, n_pivots=min(n_pivots, keys.shape[0]),
                         n_groups=n_groups, grouping="geometric", seed=seed)
        return cls(keys=keys, values=np.asarray(values, np.int32),
                   index=build_index(keys, cfg), config=cfg)

    def engine(self, k: Optional[int] = None) -> StreamJoinEngine:
        """A streaming engine over the resident index (optionally with a
        per-caller k — the index's T_S supports any k ≤ build k)."""
        cfg = self.config if k is None or k == self.config.k \
            else dataclasses.replace(self.config, k=k)
        return StreamJoinEngine(self.index, cfg)


@dataclasses.dataclass(frozen=True)
class KnnLMConfig:
    lam: float = 0.25
    tau: float = 10.0
    k: int = 8


def knn_logits(queries: np.ndarray, store: Datastore, kcfg: KnnLMConfig,
               vocab: int, *, use_kernel: bool = False) -> np.ndarray:
    """Retrieval distribution per query, (B, vocab) log-space.

    ``use_kernel=False`` (default) plans + joins the batch against the
    datastore index (the PGBJ serve path); ``use_kernel=True`` runs the
    brute-force `distance_topk` kernel over the index's device-resident
    packed rows. Both return true distances, normalized to comparable
    space (`to_cmp`: squared for L2) before ``softmax(−d_cmp/τ)``.
    """
    queries = np.ascontiguousarray(queries, np.float32)
    if use_kernel:
        d, local = distance_topk(jnp.asarray(queries),
                                 store.index.device_rows(), kcfg.k)
        d = np.asarray(d)
        idx = store.index.s_ids_sorted[np.asarray(local)]
    else:
        d, idx = store.engine(kcfg.k).join_batch(queries)
    w = jax.nn.softmax(
        jnp.asarray(-to_cmp(d, store.config.metric) / kcfg.tau), axis=-1)
    toks = store.values[idx]                                        # (B,k)
    probs = np.zeros((queries.shape[0], vocab), np.float32)
    np.add.at(probs, (np.arange(queries.shape[0])[:, None], toks),
              np.asarray(w))
    return np.log(np.maximum(probs, 1e-9))


def interpolate(lm_logits: jnp.ndarray, knn_log: np.ndarray,
                lam: float) -> jnp.ndarray:
    """(1-λ)·p_LM + λ·p_kNN, done in probability space, returned as logits."""
    p_lm = jax.nn.softmax(lm_logits, axis=-1)
    p_knn = jnp.exp(jnp.asarray(knn_log))
    p_knn = p_knn / jnp.maximum(p_knn.sum(-1, keepdims=True), 1e-9)
    return jnp.log(jnp.maximum((1 - lam) * p_lm + lam * p_knn, 1e-9))
