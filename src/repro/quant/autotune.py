"""Measured tuning table for the quantized tier (ROADMAP: "autotune block
shapes/`mp` shortlist per (dim, n, k) with a cached tuning table").

The int8 coarse pass only pays off when the shortlist `mp`, the tile
shapes, and — most importantly — the *choice to use int8 at all* match
the hardware. On a TPU the int8 MXU dot plus the ~3.7× DMA reduction is
a wall-clock win; on CPU the coarse pass's extra elementwise ε/bound
work can cost more than the fp32 scan it replaces. Rather than hardcode
either answer, we measure: :func:`sweep_config` times the fp32 megastep
against the forced-int8 engine across candidate shortlist sizes and
records the winner as a :class:`TunedConfig` in a JSON
:class:`TuningTable` keyed on ``(backend, dim, n_rows, k)``.

The table is persisted to disk (``TUNE_quant.json`` next to this module
by default; override with ``REPRO_QUANT_TUNE_TABLE``) so CI and serving
never run a sweep in the hot path — `QuantMegastepEngine` just looks up
its shape at construction time and either runs int8 with the tuned
``mp``/tile shapes or falls back to the exact fp32 megastep. An explicit
``quant_slack`` (or a forced ``impl=``) always wins over the table: it
pins classic int8 behavior for tests and for operators who know better.

Regenerate with ``python -m benchmarks.tune_quant``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, Optional

__all__ = [
    "TunedConfig", "TuningTable", "table_key", "default_table",
    "default_table_path", "lookup", "sweep_config", "reset_default_table",
]

_ENV_TABLE = "REPRO_QUANT_TUNE_TABLE"


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One measured decision for one (backend, dim, n-bucket, k) cell.

    ``mode`` is the headline: ``"int8"`` means the coarse int8 scan +
    exact re-rank beat the fp32 megastep on this shape; ``"fp32"`` means
    it lost and the engine should run the plain fp32 scan (still exact,
    trivially certified). ``mp``/``bm``/``bn`` only apply in int8 mode;
    zeros mean "keep the engine default". The timing fields document the
    measurement that justified the decision.
    """

    mode: str                      # "int8" | "fp32"
    mp: int = 0                    # shortlist size (pow2); 0 = default
    bm: int = 0                    # query-tile rows cap; 0 = default
    bn: int = 0                    # S-tile rows; 0 = config.tile_s
    int8_batch_s: float = math.nan
    fp32_batch_s: float = math.nan

    def __post_init__(self):
        if self.mode not in ("int8", "fp32"):
            raise ValueError(f"mode must be int8|fp32, got {self.mode!r}")
        for name in ("mp", "bm", "bn"):
            v = getattr(self, name)
            if v and v != _next_pow2(v):
                raise ValueError(f"{name} must be a power of two, got {v}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TunedConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def table_key(dim: int, n_rows: int, k: int, backend: str) -> str:
    """Cells bucket ``n_rows`` to the next power of two — the engine pads
    payloads anyway, and it keeps nearby corpus sizes sharing one sweep."""
    return f"{backend}|d{int(dim)}|n{_next_pow2(max(1, int(n_rows)))}|k{int(k)}"


class TuningTable:
    """A {key: TunedConfig} map with JSON round-trip."""

    def __init__(self, entries: Optional[Dict[str, TunedConfig]] = None):
        self.entries: Dict[str, TunedConfig] = dict(entries or {})

    def get(self, dim: int, n_rows: int, k: int,
            backend: str) -> Optional[TunedConfig]:
        return self.entries.get(table_key(dim, n_rows, k, backend))

    def put(self, dim: int, n_rows: int, k: int, backend: str,
            cfg: TunedConfig) -> None:
        self.entries[table_key(dim, n_rows, k, backend)] = cfg

    def to_json(self) -> str:
        body = {k: v.to_dict() for k, v in sorted(self.entries.items())}
        return json.dumps({"version": 1, "entries": body}, indent=2) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "TuningTable":
        doc = json.loads(text)
        ents = {k: TunedConfig.from_dict(v)
                for k, v in doc.get("entries", {}).items()}
        return cls(ents)

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        with open(path) as fh:
            return cls.from_json(fh.read())


def default_table_path() -> str:
    env = os.environ.get(_ENV_TABLE)
    if env:
        return env
    return os.path.join(os.path.dirname(__file__), "TUNE_quant.json")


_DEFAULT: Optional[TuningTable] = None
_DEFAULT_PATH: Optional[str] = None


def default_table() -> TuningTable:
    """The process-wide table, loaded once from :func:`default_table_path`
    (empty if the file is missing or unreadable — the engine then uses
    its classic int8 heuristics)."""
    global _DEFAULT, _DEFAULT_PATH
    path = default_table_path()
    if _DEFAULT is None or path != _DEFAULT_PATH:
        try:
            _DEFAULT = TuningTable.load(path)
        except (OSError, ValueError, KeyError):
            _DEFAULT = TuningTable()
        _DEFAULT_PATH = path
    return _DEFAULT


def reset_default_table() -> None:
    """Drop the cached table (tests that point ``REPRO_QUANT_TUNE_TABLE``
    somewhere else mid-process call this)."""
    global _DEFAULT, _DEFAULT_PATH
    _DEFAULT = None
    _DEFAULT_PATH = None


def lookup(dim: int, n_rows: int, k: int,
           backend: Optional[str] = None) -> Optional[TunedConfig]:
    if backend is None:
        import jax
        backend = jax.default_backend()
    return default_table().get(dim, n_rows, k, backend)


# ---------------------------------------------------------------------------
# the sweep


def _time_join(engine, q, *, iters: int) -> float:
    best = math.inf
    engine.join_batch(q)                      # warm: traces + payload upload
    for _ in range(iters):
        t0 = time.perf_counter()
        engine.join_batch(q)
        best = min(best, time.perf_counter() - t0)
    return best


def sweep_config(index, config=None, *, batch: int = 256, iters: int = 3,
                 mps=None, bns=None, impl=None) -> TunedConfig:
    """Measure fp32-vs-int8 for ``index``'s shape and return the winner.

    Times the exact fp32 ``MegastepEngine`` and a forced-int8
    ``QuantMegastepEngine`` (resident re-rank, ``tune=False`` so the
    table being regenerated can't influence its own sweep) for each
    candidate ``mp`` (and optionally each S-tile size ``bn``), on a
    deterministic query batch drawn from the indexed rows themselves.
    int8 wins only if its best configuration is strictly faster
    end-to-end — including any certification-failure fallbacks, which
    naturally penalize too-small shortlists.
    """
    import numpy as np

    from repro.core.megastep import MegastepEngine
    from repro.quant.engine import QuantMegastepEngine

    cfg = config if config is not None else index.config
    k = cfg.k
    if mps is None:
        lo = _next_pow2(max(2 * k, 16))
        mps = sorted({lo, _next_pow2(4 * k), max(_next_pow2(4 * k), 128)})
    if bns is None:
        bns = (0,)

    rng = np.random.default_rng(0)
    rows = getattr(index, "s_sorted", None)
    if rows is None or len(rows) == 0:
        raise ValueError("sweep_config needs a built SIndex (s_sorted)")
    sel = rng.integers(0, rows.shape[0], size=min(batch, rows.shape[0]))
    q = np.ascontiguousarray(rows[sel], dtype=np.float32)
    q = q + rng.normal(0, 1e-3, q.shape).astype(np.float32)

    fp32_s = _time_join(MegastepEngine(index, cfg), q, iters=iters)

    best_s, best_mp, best_bn = math.inf, 0, 0
    for bn in bns:
        for mp in mps:
            slack = max(int(mp) - k, 0)
            eng = QuantMegastepEngine(index, cfg, slack=slack, impl=impl,
                                      tune=False, tune_bn=int(bn) or None)
            t = _time_join(eng, q, iters=iters)
            if t < best_s:
                best_s, best_mp, best_bn = t, int(mp), int(bn)

    mode = "int8" if best_s < fp32_s else "fp32"
    return TunedConfig(mode=mode, mp=best_mp if mode == "int8" else 0,
                       bn=best_bn if mode == "int8" else 0,
                       int8_batch_s=best_s, fp32_batch_s=fp32_s)
