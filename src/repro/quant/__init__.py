"""Error-bounded quantized index tier: int8 coarse scan + exact fp32
re-rank (see `repro.quant.quantize` for the representation and
`repro.quant.engine` for the two-tier execution engine)."""
from .quantize import QuantizedRows, quantize_rows, quantize_queries_np
from .engine import (QuantMegastepEngine, ShardedQuantMegastepEngine,
                     quantize_queries_jnp)

__all__ = ["QuantizedRows", "quantize_rows", "quantize_queries_np",
           "QuantMegastepEngine", "ShardedQuantMegastepEngine",
           "quantize_queries_jnp"]
