"""Symmetric per-tile int8 quantization of the packed S rows, with
per-row reconstruction-error bounds.

The paper's machinery (Thm 2/3, Cor. 1) prunes with *distance bounds*;
this module extends the same idea to compression. Each ``bn``-row tile
of the pivot-sorted packed layout (`SIndex.s_sorted`) is quantized
symmetrically to int8 — one float32 scale per tile, codes in
[-127, 127] — and every row carries an upper bound ε on its
reconstruction error ``‖s − ŝ‖₂`` (ŝ = code · scale). By the triangle
inequality, for any query q and any metric's true distance d:

    |d(q, ŝ) − d(q, s)| ≤ ‖s − ŝ‖ ≤ ε

so a coarse pass over the int8 codes can prune and shortlist *exactly*:
``d(q, ŝ) − ε`` is a certified lower bound on the true distance, and no
true neighbor is ever lost as long as selection keys and θ thresholds
are inflated by ε (see `repro.quant.engine`). ε is computed in float64
against the float32 scale actually used at serve time, then rounded
*up* into float16 storage — the stored bound always dominates the real
error, never undershoots it.

Tile granularity matches the engines' S-tile size (``JoinConfig.
tile_s``), so the Pallas coarse kernel rescales once per (query tile,
S tile) step: int8 dot → int32 accumulate → one float32 rescale.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["QuantizedRows", "quantize_rows", "quantize_queries_np",
           "resident_extra_bytes"]


def resident_extra_bytes(n_rows: int, dim: int) -> int:
    """HBM cost of the device-resident re-rank variant *on top of* the
    int8 codes: the fp32 packed rows (4·dim B/row) plus the (hi, lo)
    int32 global-id pair (8 B/row) the fused gather resolves ids with.
    `QuantMegastepEngine` compares this against
    ``REPRO_QUANT_RESIDENT_MAX_BYTES`` to auto-pick resident vs
    host-gather."""
    return int(n_rows) * (4 * int(dim) + 8)


@dataclasses.dataclass
class QuantizedRows:
    """Int8 codes + per-tile scales + per-row error bounds for one packed
    row block, padded to a whole number of ``bn``-row tiles (padding rows
    are exact zeros: code 0, ε 0 — engines mask them via liveness)."""

    q: np.ndarray        # (n_tiles * bn, dim) int8 codes, packed layout
    scales: np.ndarray   # (n_tiles,) float32 — one symmetric scale per tile
    eps: np.ndarray      # (n_tiles * bn,) float16 — ‖s − ŝ‖₂ rounded UP
    bn: int              # rows per tile
    n_rows: int          # real rows (pre-padding)

    @property
    def n_tiles(self) -> int:
        return int(self.scales.shape[0])

    @property
    def dim(self) -> int:
        return int(self.q.shape[1])

    def nbytes(self) -> int:
        """Resident bytes of the compressed representation (codes +
        scales + error bounds) — what `SIndex.nbytes_resident` reports
        for the quantized tier."""
        return int(self.q.nbytes + self.scales.nbytes + self.eps.nbytes)

    def dequantized(self) -> np.ndarray:
        """float32 reconstruction ŝ (padded layout) — the rows the
        coarse pass effectively measures distances to."""
        s = np.repeat(self.scales, self.bn).astype(np.float32)
        return self.q.astype(np.float32) * s[:, None]


def _round_up_f16(x64: np.ndarray) -> np.ndarray:
    """float64 → float16, rounded toward +inf so the stored bound can
    only be looser than the exact one."""
    x16 = x64.astype(np.float16)
    lossy = x16.astype(np.float64) < x64
    return np.where(lossy, np.nextafter(x16, np.float16(np.inf)), x16)


def quantize_rows(rows: np.ndarray, bn: int) -> QuantizedRows:
    """Quantize ``(n, dim)`` float32 rows per ``bn``-row tile.

    Symmetric: scale = amax(|tile|)/127 (1.0 for an all-zero tile, so
    codes are well-defined), code = round(row / scale) clipped to
    [-127, 127]. ε per row is the exact float64 ‖s − ŝ‖₂ against the
    float32 scale, rounded up into float16.
    """
    rows = np.ascontiguousarray(rows, np.float32)
    if bn < 1:
        raise ValueError("bn must be >= 1")
    n, dim = rows.shape
    n_tiles = max(1, -(-n // bn))
    pad = n_tiles * bn - n
    r = np.pad(rows, ((0, pad), (0, 0))) if pad else rows
    tiles = r.reshape(n_tiles, bn, dim)
    amax = np.abs(tiles).max(axis=(1, 2))
    scales = np.where(amax > 0, amax / np.float32(127.0),
                      np.float32(1.0)).astype(np.float32)
    codes = np.clip(np.rint(tiles / scales[:, None, None]),
                    -127, 127).astype(np.int8)
    recon = codes.astype(np.float64) * scales.astype(np.float64)[:, None, None]
    err = np.sqrt(((tiles.astype(np.float64) - recon) ** 2).sum(axis=2))
    eps = _round_up_f16(err.reshape(n_tiles * bn)).astype(np.float16)
    return QuantizedRows(q=np.ascontiguousarray(codes.reshape(-1, dim)),
                         scales=scales, eps=eps, bn=int(bn), n_rows=int(n))


def quantize_queries_np(q: np.ndarray):
    """Per-row symmetric int8 quantization of a query batch (numpy twin
    of the in-jit `repro.quant.engine.quantize_queries_jnp`).

    Returns ``(codes int8 (n, dim), scales f32 (n,), eps f32 (n,))``
    with ε = ‖q − q̂‖₂ computed in float64 and rounded up — the
    query-side term of the coarse pass's total error budget.
    """
    q = np.ascontiguousarray(q, np.float32)
    amax = np.abs(q).max(axis=1)
    scales = np.where(amax > 0, amax / np.float32(127.0),
                      np.float32(1.0)).astype(np.float32)
    codes = np.clip(np.rint(q / scales[:, None]), -127, 127).astype(np.int8)
    recon = codes.astype(np.float64) * scales.astype(np.float64)[:, None]
    err = np.sqrt(((q.astype(np.float64) - recon) ** 2).sum(axis=1))
    eps32 = err.astype(np.float32)
    lossy = eps32.astype(np.float64) < err
    eps32 = np.where(lossy, np.nextafter(eps32, np.float32(np.inf)), eps32)
    return codes, scales, eps32.astype(np.float32)
