"""Two-tier quantized query engine: int8 coarse scan → exact fp32
re-rank, bitwise-identical to the fp32 oracle.

The fused megastep (core.megastep) made the per-batch serving path
compute-lean; this engine makes it *memory*-lean. The device-resident
index payload holds int8 codes + per-tile scales + per-row error bounds
ε instead of fp32 rows (≈ 4× fewer resident bytes, `SIndex.
nbytes_resident`), and each batch runs:

1. **plan** (shared jit graph with the fp32 megastep —
   `core.megastep._assign_bounds_schedule`): assignment, union θ
   (tombstone-widened), compacted Cor. 1 / Thm 2 tile schedule. All of
   it uses *exact* pivot geometry, so no ε enters here.
2. **coarse int8 scan** (`kernels.quant_topk`, jnp twin
   `kernels.ref.quant_coarse_topk_ref`): over the scheduled tiles only,
   int8 dot → int32 accumulate → fp32 rescale. Selection key per row is
   the certified lower bound ``lb = max(d_coarse − ε_total, 0)`` with
   ``ε_total = ε_s + ε_q + ε_num``; candidates with ``lb > θ`` are
   masked — θ effectively *inflated by ε*, so a true neighbor
   (distance ≤ θ ⇒ lb ≤ θ) is never pruned. The smallest
   ``mp = pow2(k + slack)`` lower bounds survive as the shortlist.
3. **exact re-rank**: the shortlisted rows are gathered from the
   host-side fp32 packed rows and re-ranked through
   ``metrics.canonical_topk`` — the *same* canonical distance graph
   every other engine reports — so the quantized path emits the exact
   bits the oracle does.
4. **certification**: per query, let L = the mp-th (largest) shortlist
   lower bound (+inf if the shortlist wasn't filled) and τ̂ = the k-th
   smallest exact re-ranked distance. Every coarse candidate *outside*
   the shortlist has lb ≥ L; if ``L ≥ τ̂`` no excluded row can beat the
   reported k-th neighbor, so the result is provably the true top-k.
   The (rare — adversarial near-ties at the shortlist boundary) queries
   that fail re-run through the fp32 host oracle
   (`JoinStats.n_quant_fallback` counts them). Exactness is therefore
   **unconditional**, not probabilistic.

The bitwise contract carries the same caveat as every other engine pair
in this codebase (see `core.segments`): when *distinct* rows tie at
exactly the same float32 canonical distance, which tied id is reported
(or their order) may differ from the oracle's — the distances
themselves are still bitwise-equal, and both answers are exact kNN
sets; only the tie-break differs (here: shortlist order vs the oracle
engine's selection order).

Soundness of the lower bound (the ε lemma, hypothesis-tested in
tests/test_quant.py): with ŝ = code·scale and q̂ the int8-quantized
query, the triangle inequality gives |d(q̂, ŝ) − d(q, s)| ≤
‖q − q̂‖ + ‖s − ŝ‖ ≤ ε_q + ε_s, and ε_num (see `kernels.quant_topk`)
dominates the float32 rescale rounding of d(q̂, ŝ) itself.

Trade-off vs the fp32 megastep: the shortlist gather is a host
round-trip per batch (the fp32 rows deliberately do **not** live in
HBM), so the quantized tier trades the zero-sync steady state for a 4×
smaller resident datastore — the regime where |S| per device, not
per-batch latency, is the binding constraint.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import numpy as np

from repro.core.megastep import MegastepEngine, _assign_bounds_schedule
from repro.core.metrics import canonical_topk
from repro.core.types import JoinConfig, JoinStats
from repro.kernels.sorted_merge import next_pow2

__all__ = ["QuantMegastepEngine", "quantize_queries_jnp"]


def quantize_queries_jnp(q):
    """Per-row symmetric int8 query quantization, in-jit.

    Returns ``(codes int8, scales f32, eps f32)`` with eps an upper
    bound on ‖q − q̂‖₂: the float32-computed norm is inflated by a
    relative + absolute margin that dwarfs its own rounding error
    (mirrors the rounded-up storage of the S-side ε).
    """
    import jax.numpy as jnp

    amax = jnp.max(jnp.abs(q), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    codes = jnp.clip(jnp.round(q / scale[:, None]), -127, 127)
    recon = codes * scale[:, None]
    err = jnp.sqrt(jnp.sum(jnp.square(q - recon), axis=1))
    eps = err * np.float32(1.0 + 1e-5) + np.float32(1e-7)
    return codes.astype(jnp.int8), scale, eps.astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("mp", "k", "bm", "bn", "metric", "dim",
                     "n_finite_total", "seg_meta", "primary", "impl"))
def _quant_coarse(q, n_valid, dead_total, segs, tiles, *,
                  mp: int, k: int, bm: int, bn: int, metric: str,
                  dim: int, n_finite_total: int, seg_meta: tuple,
                  primary: int, impl: str):
    """plan (shared with the fp32 megastep) → int8 coarse shortlist.

    Returns ``(lb (B, mp) ascending certified lower bounds,
    pos (B, mp) int32 rows into the packed layout)`` in the original
    query order; empty slots are (+inf, -1).
    """
    from repro.kernels import ops

    qs, _, _, _, inv, th_q, sched, cnt = _assign_bounds_schedule(
        q, n_valid, dead_total, segs, tiles["center"], k=k, bm=bm,
        metric=metric, n_finite_total=n_finite_total, seg_meta=seg_meta,
        primary=primary)
    qi, qscale, qeps = quantize_queries_jnp(qs)
    # one dispatch for every impl (pallas / interpret / ref_sched /
    # dense ref) — the registered op, traced into this jit. θ is
    # ulp-padded (bounds.pad_theta) like every other prune site: the
    # certified lb can equal the true distance exactly, and θ's fp
    # value may round below the real Thm-3 bound.
    from repro.core.bounds import pad_theta
    lb, pos = ops.quant_coarse_topk(
        qi, qscale, qeps, pad_theta(th_q), tiles["sq"], tiles["sscale"],
        tiles["seps"], tiles["alive"], mp, schedule=sched, counts=cnt,
        bm=bm, bn=bn, impl=impl)
    return lb[inv], pos[inv]


class QuantMegastepEngine(MegastepEngine):
    """Memory-lean drop-in for `MegastepEngine`: same index kinds
    (``SIndex`` or ``MutableIndex`` with live tombstones), same exact
    bitwise results, int8-resident payload. Reached via
    ``knn_join(..., quantized=True)``, ``knn_join_batched(...,
    quantized=True)``, ``StreamJoinEngine(..., quantized=True)`` and
    ``serve.Datastore(quantized=True)``. L2 only, like the megastep.
    """

    def __init__(self, index, config: Optional[JoinConfig] = None, *,
                 slack: Optional[int] = None, bucket_min: int = 16,
                 impl: Optional[str] = None):
        if impl not in (None, "pallas", "pallas_interpret", "ref",
                        "ref_sched"):
            raise ValueError(f"unknown quant coarse impl {impl!r}")
        cfg = config or index.config
        if cfg.metric != "l2":
            raise ValueError(
                f"the quantized tier supports metric='l2' only, got "
                f"{cfg.metric!r}; use the fp32 host engines "
                f"(JoinConfig(quantize=...) rejects this combination at "
                f"construction)")
        super().__init__(index, config, bucket_min=bucket_min)
        self.impl = impl
        self._upload_fp32 = False
        self._upload_ids = False       # ids resolve host-side via gids
        k = self.config.k
        if slack is None:
            slack = self.config.quant_slack
        if slack is None or slack < 0:
            # auto: certification needs the shortlist boundary to clear
            # the k-th neighbor by ~2·(ε_s + ε_q); on concentrated
            # high-dim data that takes a rank gap of ~10×k (the
            # kernel_quant_coarse_vs_fp32 bench pins certified_frac=1.0
            # here, vs 0.05 at a bare 2k shortlist)
            self.mp = max(next_pow2(4 * k), 128)
        else:
            self.mp = next_pow2(max(k + int(slack), k, 1))

    # ---- device payload: int8 codes + scales + ε instead of fp32 rows

    def _build_struct(self, segs, bn: int, k: int) -> dict:
        import jax.numpy as jnp

        st = super()._build_struct(segs, bn, k)
        q_parts, sc_parts, eps_parts = [], [], []
        for si, _off in segs:
            qr = si.ensure_quant(bn)
            q_parts.append(qr.q)
            sc_parts.append(qr.scales)
            eps_parts.append(qr.eps)
        st["tiles_dev"]["sq"] = jnp.asarray(np.concatenate(q_parts, axis=0))
        st["tiles_dev"]["sscale"] = jnp.asarray(np.concatenate(sc_parts))
        # ε stays f16-resident (2 bytes/row); upcast is in-graph
        st["tiles_dev"]["seps"] = jnp.asarray(np.concatenate(eps_parts))
        return st

    # ---- two-tier query path

    def coarse_shortlist(self, queries: np.ndarray):
        """The int8 pass alone: ``(lb, pos, ids)`` for one batch —
        ascending certified lower bounds, packed-row positions and their
        global ids (−1 on empty slots). Exposed for benches/tests; the
        exact path is :meth:`join_batch`."""
        from repro.kernels import ops

        q = np.ascontiguousarray(queries, np.float32)
        n = q.shape[0]
        payload = self._refresh()
        qd, nv = self.enqueue(q)
        bucket = int(qd.shape[0])
        bm = min(bucket, 1 << (int(self.config.tile_r).bit_length() - 1))
        impl = self.impl or ("pallas" if ops.use_pallas() else "ref")
        lb, pos = _quant_coarse(
            qd, nv, payload.dead_total, payload.segs, payload.tiles,
            mp=self.mp, k=self.config.k, bm=bm, bn=self.config.tile_s,
            metric=self.config.metric, dim=payload.dim,
            n_finite_total=payload.n_finite_total,
            seg_meta=payload.seg_meta, primary=payload.primary, impl=impl)
        from repro.serve import faultinject
        faultinject.fire("megastep.fetch")     # simulated lost fetch
        lb = np.asarray(lb)[:n]
        pos = np.asarray(pos)[:n]
        # chaos site: deflating the certified lower bounds is exactly
        # what inflated ε would do — downstream certification then fails
        # and the fp32-oracle fallback must keep the output bitwise
        lb = faultinject.transform_value("quant.eps_inflation", lb)
        gids = self._struct[1]["gids"]
        ids = np.where(pos >= 0,
                       gids[np.clip(pos, 0, gids.shape[0] - 1)], -1)
        return lb, pos, ids

    def join_batch(
        self, queries: np.ndarray, *, stats: Optional[JoinStats] = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """(dists, int64 global ids): coarse int8 shortlist → exact fp32
        canonical re-rank → per-query certification (fp32-oracle
        fallback for the failures). Bitwise the oracle's output, up to
        float-tie id ordering (module docstring)."""
        q = np.ascontiguousarray(queries, np.float32)
        n = q.shape[0]
        k = self.config.k
        if k > self.index.n_s:
            raise ValueError(f"k={k} > |S|={self.index.n_s}")
        if n == 0:
            return (np.zeros((0, k), np.float32),
                    np.full((0, k), -1, np.int64))
        out_d, out_i, lm = self._rerank_shortlist(q, stats=stats)
        # certification: excluded coarse candidates all carry lb ≥ the
        # run's last (largest) slot; +inf there means nothing was
        # excluded at all. τ̂ is the exact reported k-th distance.
        tau = out_d[:, k - 1]
        bad = ~(lm >= tau)                   # NaN-safe: fail on weirdness
        if bad.any():
            fb_d, fb_i = self._oracle_join(q[bad])
            out_d[bad] = fb_d
            out_i[bad] = fb_i
            if stats is not None:
                stats.n_quant_fallback += int(bad.sum())
        return out_d, out_i

    def _rerank_shortlist(self, q: np.ndarray, *,
                          stats: Optional[JoinStats] = None):
        """Coarse shortlist → host gather → exact canonical re-rank.

        Returns ``(out_d, out_i, lm)``: the exact-re-ranked top-k over
        the shortlist and the per-query exclusion bound ``lm`` — every
        row *not* in the shortlist has true distance ≥ ``lm`` (+inf when
        the shortlist wasn't filled, i.e. nothing was excluded). Shared
        by the certified-exact :meth:`join_batch` and the
        certified-approximate :meth:`join_batch_approx`.
        """
        k = self.config.k
        n = q.shape[0]
        lb, pos, ids = self.coarse_shortlist(q)
        payload = self._payload[1]
        if stats is not None:
            stats.n_segments = len(payload.seg_meta)
            stats.n_tombstones = int(np.asarray(payload.dead_total))
            stats.pivot_pairs_computed += n * sum(
                m for m, _, _ in payload.seg_meta)
        rows_host = self._struct[1]["rows_host"]
        neigh = rows_host[np.clip(pos, 0, rows_host.shape[0] - 1)]
        d_all, ids_all = canonical_topk(q, ids, neigh, self.config.metric)
        out_d = np.ascontiguousarray(d_all[:, :k])
        out_i = np.ascontiguousarray(ids_all[:, :k])
        return out_d, out_i, lb[:, -1]

    def join_batch_approx(
        self, queries: np.ndarray, *, stats: Optional[JoinStats] = None,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Coarse-only certified-*approximate* join — the serving
        scheduler's degraded rung. Same coarse shortlist + exact re-rank
        as :meth:`join_batch`, but certification failures do **not**
        re-run through the fp32 oracle; instead every query reports a
        *certified recall lower bound* derived from the ε machinery.

        Returns ``(dists, ids, recall_bound)`` with ``recall_bound[i] =
        #{j : dists[i, j] ≤ lm_i} / k``, where ``lm_i`` bounds every
        excluded row's true distance from below. A reported neighbor
        with distance ≤ lm has global rank ≤ its shortlist rank ≤ k, so
        it provably belongs to the true top-k — the bound counts only
        such neighbors and is therefore sound, never optimistic. An
        unfilled shortlist (lm = +inf) excluded nothing: the result is
        exact and the bound is 1. Reported distances are always exact
        (the re-rank is fp32-canonical); only *membership* of the true
        top-k is approximate.
        """
        q = np.ascontiguousarray(queries, np.float32)
        n = q.shape[0]
        k = self.config.k
        if k > self.index.n_s:
            raise ValueError(f"k={k} > |S|={self.index.n_s}")
        if n == 0:
            return (np.zeros((0, k), np.float32),
                    np.full((0, k), -1, np.int64),
                    np.ones((0,), np.float32))
        out_d, out_i, lm = self._rerank_shortlist(q, stats=stats)
        with np.errstate(invalid="ignore"):
            proven = out_d <= lm[:, None]      # NaN-safe: counts False
        recall = proven.sum(axis=1).astype(np.float32) / np.float32(k)
        if stats is not None:
            stats.n_degraded += n
            stats.recall_bound = min(stats.recall_bound,
                                     float(recall.min()))
        return out_d, out_i, recall

    def _oracle_join(self, q: np.ndarray):
        """The fp32 host-planned oracle for certification failures —
        reports through the same canonical distance graph, so patched
        rows are bitwise what a full oracle run would emit."""
        from repro.core.api import execute_join
        from repro.core.index import plan_queries
        from repro.core.segments import MutableIndex

        if isinstance(self.index, MutableIndex):
            return self.index.join_batch(q, config=self.config)
        return execute_join(
            q, self.index, plan_queries(q, self.index, self.config))

    def join_batch_device(self, q_dev, n_valid_dev, *, state=None):
        raise NotImplementedError(
            "the quantized tier re-ranks via a host-side shortlist "
            "gather (its fp32 rows are deliberately not device-resident)"
            " — use join_batch, or the fp32 MegastepEngine for the "
            "zero-host-transfer device API")
