"""Launchers: mesh construction, dry-run, train/serve/join drivers."""
