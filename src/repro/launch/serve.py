"""Serving launcher: batched generation for any --arch, optional kNN-LM
retrieval backed by the PGBJ join.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --requests 8 --new-tokens 16 [--retrieval]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch, get_reduced
from repro.models import ModelOptions, init_params
from repro.serve import (
    BatchedServer, Datastore, KnnLMConfig, ServeConfig, interpolate,
    knn_logits)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    opts = ModelOptions(dtype=jnp.float32 if args.reduced else jnp.bfloat16,
                        remat=False, max_abs_pos=4096)
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    rng = np.random.default_rng(0)

    hook = None
    if args.retrieval:
        keys = rng.normal(size=(2048, 32)).astype(np.float32)
        vals = rng.integers(0, cfg.vocab, 2048).astype(np.int32)
        store = Datastore.build(keys, vals, k=8, n_pivots=128, n_groups=8)
        kcfg = KnnLMConfig(lam=0.2, tau=50.0, k=8)

        def hook(logits, cache):
            q = np.asarray(logits)[:, :32]
            return interpolate(logits, knn_logits(q, store, kcfg, cfg.vocab),
                               kcfg.lam)

    srv = BatchedServer(
        cfg, ServeConfig(batch=args.batch, temperature=args.temperature),
        params, opts, logits_hook=hook)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 16)))
               for _ in range(args.requests)]
    t0 = time.time()
    outs = srv.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    total = sum(len(o) for o in outs)
    print(f"{args.requests} requests × {args.new_tokens} tokens in {dt:.2f}s"
          f" ({total/dt:.1f} tok/s){' with kNN-LM retrieval' if hook else ''}")
    for i, o in enumerate(outs[:4]):
        print(f"  req {i}: {list(o)[:10]}{'…' if len(o) > 10 else ''}")


if __name__ == "__main__":
    main()
