"""Production meshes. Functions, not constants — importing this module
never touches jax device state (device count is locked at first use)."""
from __future__ import annotations

import jax

from repro.core.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 v5e pod (data, model); 2 pods add a leading "pod" axis (DP
    across the DCI — gradients cross pods once per step)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, name: str = "data"):
    """Small mesh over the actually-present devices (tests, examples)."""
    n = n or len(jax.devices())
    return make_mesh((n,), (name,))
