"""Post-SPMD HLO cost accounting with while-loop trip-count scaling.

``compiled.cost_analysis()`` counts every computation ONCE — a scan over
88 layers × 16 accumulation steps under-reports flops/collective bytes by
~3 orders of magnitude. This walks the HLO call graph instead:

  total(comp) = Σ own ops + Σ fusion/call children + trip_count × while body

Trip counts come from XLA's own loop analysis (``known_trip_count`` in the
while op's backend_config — present for all lax.scan/fori lowered loops).

Accounting rules (per device — the module is already partitioned):
  flops       — dot ops: 2 · |result| · |contraction dims|
  hbm bytes   — fusion/dot/collective/copy/DUS/gather ops: operands+result
                (assumes each fused region reads inputs / writes outputs
                once — the standard roofline approximation)
  collectives — result-shape bytes per op kind, trip-scaled
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class OpRecord:
    kind: str
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Tuple[str, float]] = None
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class CompTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across JAX versions.

    Newer JAX returns the properties dict directly; older JAX returned a
    one-element list of per-computation dicts. Normalize to a dict (empty
    when the backend reports nothing).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def cpu_bf16_convert_staging_bytes(hlo: str, min_bytes: int = 1 << 28) -> int:
    """Bytes of bulk bf16→f32 staging buffers XLA-CPU inserts because its
    dot kernels take f32 operands. A TPU feeds bf16 to the MXU directly, so
    these buffers don't exist on the target — the dry-run reports peak both
    raw and with this artifact removed (EXPERIMENTS.md §Dry-run).

    Detection: top-level convert ops (or convert-only fusions — XLA names
    them `wrapped_convert*`) producing an f32 tensor ≥ min_bytes."""
    total = 0
    seen_shapes = set()
    for line in hlo.splitlines():
        m = re.match(
            r"\s*(?:ROOT\s+)?%([\w.\-]*convert[\w.\-]*)\s*=\s*"
            r"(f32\[[0-9,]+\])[^=]*\b(?:convert|fusion)\(", line)
        if not m:
            continue
        shape = m.group(2)
        if shape in seen_shapes:
            continue  # same-shape converts share one reused allocation
        nb = _shape_bytes(shape)
        if nb >= min_bytes:
            seen_shapes.add(shape)
            total += nb
    return total


_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_KIND_RE = re.compile(r"^((?:\([^)]*\)|\S+?))\s+([\w\-]+)\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

# ops whose operand/result traffic counts toward HBM bytes
_MEM_OPS = {"fusion", "dot", "copy", "dynamic-update-slice", "dynamic-slice",
            "gather", "scatter", "convolution", "transpose", "reshape",
            "broadcast", "iota", "reduce", "sort", "concatenate", "pad",
            "select-and-scatter", "custom-call"}
# cheap ops fused on TPU; standalone on CPU-HLO — counting them would
# overstate HBM traffic badly, so only count when they stand alone AND are
# "large" (heuristic threshold below)
_LIGHT_OPS = {"transpose", "reshape", "broadcast", "iota", "pad",
              "concatenate"}


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.rstrip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", s)
        if header and not s.startswith(" "):
            cur = header.group(1)
            comps[cur] = []
            if s.strip().startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in s:
            comps[cur].append(s.strip())
    return comps


def _operands(rest: str) -> List[str]:
    """Names referenced inside the op's first balanced paren group."""
    start = rest.find("(")
    if start < 0:
        return []
    depth, i = 0, start
    for i in range(start, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
    inner = rest[start + 1:i]
    return re.findall(r"%([\w.\-]+)", inner)


def analyze(hlo: str) -> CompTotals:
    comps = parse_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # per-computation result-shape map (for operand shape resolution)
    shapes: Dict[str, Dict[str, str]] = {}
    for cname, lines in comps.items():
        if cname == "__entry__":
            continue
        m: Dict[str, str] = {}
        for ln in lines:
            om = _OP_RE.match(ln)
            if om:
                rest = om.group(2)
                km = _KIND_RE.match(rest)
                m[om.group(1)] = km.group(1) if km else rest.split()[0]
        shapes[cname] = m

    memo: Dict[str, CompTotals] = {}
    body_bytes_memo: Dict[str, float] = {}
    _SLICERS = ("dynamic-slice", "gather", "slice")

    def fusion_body_bytes(cname: str) -> float:
        """Operand traffic of one fusion execution, resolved inside the
        body: a parameter consumed only by slice-like ops contributes its
        *slice* bytes, not its full (possibly layer-stacked) size."""
        if cname in body_bytes_memo:
            return body_bytes_memo[cname]
        lines = comps.get(cname, [])
        smap = shapes.get(cname, {})
        params: Dict[str, str] = {}
        for ln in lines:
            om = _OP_RE.match(ln)
            if om and " parameter(" in om.group(2):
                km = _KIND_RE.match(om.group(2))
                params[om.group(1)] = km.group(1) if km else ""
        total = 0.0
        for pname, pshape in params.items():
            ref = re.compile(r"%" + re.escape(pname) + r"\b")
            consumers = []
            for ln in lines:
                om = _OP_RE.match(ln)
                if not om or om.group(1) == pname:
                    continue
                if ref.search(om.group(2)):
                    km = _KIND_RE.match(om.group(2))
                    if km:
                        consumers.append((km.group(2), km.group(1)))
            if consumers and all(k in _SLICERS for k, _ in consumers):
                total += sum(_shape_bytes(rs) for _, rs in consumers)
            else:
                total += _shape_bytes(pshape)
        body_bytes_memo[cname] = total
        return total

    def visit(cname: str) -> CompTotals:
        if cname in memo:
            return memo[cname]
        total = CompTotals()
        memo[cname] = total
        smap = shapes.get(cname, {})
        for ln in comps.get(cname, []):
            om = _OP_RE.match(ln)
            if not om:
                continue
            rest = om.group(2)
            km = _KIND_RE.match(rest)
            if not km:
                continue
            rshape, kind = km.group(1), km.group(2)

            if kind == "while":
                trip = 1.0
                tm = _TRIP_RE.search(rest)
                if tm:
                    trip = float(tm.group(1))
                bm = _CALLS_RE.search(rest)
                if bm:
                    sub = visit(bm.group(1))
                    total.flops += trip * sub.flops
                    total.bytes += trip * sub.bytes
                    for k, v in sub.coll.items():
                        total.coll[k] = total.coll.get(k, 0) + trip * v
                continue
            if kind == "conditional":
                bm = _BRANCHES_RE.search(rest)
                if bm:
                    subs = [visit(b.strip().lstrip("%"))
                            for b in bm.group(1).split(",")]
                    # worst-case branch
                    best = max(subs, key=lambda s: s.flops + s.bytes,
                               default=None)
                    if best:
                        total.flops += best.flops
                        total.bytes += best.bytes
                        for k, v in best.coll.items():
                            total.coll[k] = total.coll.get(k, 0) + v
                continue
            if kind in ("fusion", "call", "async-start"):
                bm = _CALLS_RE.search(rest)
                body = bm.group(1) if bm and bm.group(1) in comps else None
                if body is not None:
                    sub = visit(body)
                    total.flops += sub.flops
                    for k, v in sub.coll.items():
                        total.coll[k] = total.coll.get(k, 0) + v
                if kind == "fusion" and body is not None:
                    # operand traffic resolved inside the body: slice-only
                    # parameters (scan weight indexing) count slice bytes
                    total.bytes += _shape_bytes(rshape) \
                        + fusion_body_bytes(body)
                else:
                    total.bytes += _shape_bytes(rshape) + sum(
                        _shape_bytes(smap.get(o, ""))
                        for o in _operands(rest))
                continue

            base = kind.replace("-start", "")
            if base in _COLL_KINDS:
                nb = _shape_bytes(rshape)
                total.coll[base] = total.coll.get(base, 0) + nb
                total.bytes += nb + sum(_shape_bytes(smap.get(o, ""))
                                        for o in _operands(rest))
                continue
            if kind == "dot":
                out_elems = _shape_elems(rshape)
                contract = 1
                cm = _CONTRACT_RE.search(rest)
                ops = _operands(rest)
                if cm and ops:
                    lhs_shape = smap.get(ops[0], "")
                    sm = _SHAPE_RE.search(lhs_shape)
                    if sm:
                        dims = [int(d) for d in sm.group(2).split(",") if d]
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                contract *= dims[int(idx)]
                total.flops += 2.0 * out_elems * contract
                total.bytes += _shape_bytes(rshape) + sum(
                    _shape_bytes(smap.get(o, "")) for o in ops)
                continue
            if kind in ("dynamic-slice", "gather", "slice"):
                # reads only the sliced region — counting the full operand
                # inflates scan weight-indexing by the layer count
                # (observed 100× on granite-34b train)
                total.bytes += 2.0 * _shape_bytes(rshape)
                continue
            if kind == "dynamic-update-slice":
                # in-place update: read+write of the updated region only;
                # the region size is the update operand (second operand)
                ops = _operands(rest)
                upd = _shape_bytes(smap.get(ops[1], "")) if len(ops) > 1 \
                    else _shape_bytes(rshape)
                total.bytes += 2.0 * upd
                continue
            if kind in _MEM_OPS:
                nb = _shape_bytes(rshape)
                if kind in _LIGHT_OPS and nb < (1 << 20):
                    continue
                total.bytes += nb + sum(_shape_bytes(smap.get(o, ""))
                                        for o in _operands(rest))
        return total

    # find the entry computation's real name
    for cname, lines in comps.items():
        if cname != "__entry__" and lines is entry:
            return visit(cname)
    raise ValueError("entry not resolved")
