"""Production training launcher: ``--arch <id>`` on the current device
topology (or the production mesh under the dry-run device forcing).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --steps 100 --seq 512 --batch 16 [--ckpt-dir …] [--restart]

On a real pod each host runs this same script (jax.distributed handles
process groups); here it drives the host mesh end-to-end: sharded params,
gradient accumulation, checkpoint/restart, stateless data replay.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch, get_reduced
from repro.data import DataConfig, synthetic_lm_batch
from repro.distributed.sharding import axis_rules, param_shardings
from repro.launch.mesh import make_host_mesh
from repro.models import ModelOptions, count_params, init_params
from repro.train import OptConfig, TrainConfig, checkpoint, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restart", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    opts = ModelOptions(dtype=jnp.float32 if args.reduced else jnp.bfloat16,
                        remat=not args.reduced,
                        max_abs_pos=max(4096, args.seq))
    mesh = make_host_mesh()
    tcfg = TrainConfig(opt=OptConfig(lr=args.lr, warmup_steps=10,
                                     decay_steps=args.steps),
                       accum=args.accum)
    opt_init, step_fn = make_train_step(cfg, tcfg, opts)

    with mesh, axis_rules(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0), opts)
        params = jax.device_put(params, param_shardings(params, mesh))
        opt = opt_init(params)
        print(f"{cfg.name}: {count_params(params)/1e6:.1f}M params on "
              f"{len(jax.devices())} devices")
        start = 0
        if args.restart and args.ckpt_dir and \
                checkpoint.latest_step(args.ckpt_dir) is not None:
            avals = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                {"params": params, "opt": opt})
            shardings = jax.tree_util.tree_map(
                lambda x: x.sharding, {"params": params, "opt": opt})
            restored, start = checkpoint.restore(
                args.ckpt_dir, avals, shardings=shardings)
            params, opt = restored["params"], restored["opt"]
            print(f"restored step {start}")

        dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch * max(1, args.accum))
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        t0 = time.time()
        for i in range(start, args.steps):
            raw = synthetic_lm_batch(dcfg, i)
            if args.accum > 1:
                raw = {k: v.reshape(args.accum, args.batch, -1)
                       for k, v in raw.items()}
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            params, opt, m = jstep(params, opt, batch)
            if (i + 1) % 10 == 0:
                print(f"step {i+1:5d} loss {float(m['loss']):.4f} "
                      f"({(time.time()-t0)/10:.2f}s/step)")
                t0 = time.time()
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, i + 1,
                                {"params": params, "opt": opt})
    print("done")


if __name__ == "__main__":
    main()
