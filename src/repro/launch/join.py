"""kNN-join launcher: the paper's workload as a CLI.

  PYTHONPATH=src python -m repro.launch.join --dataset forest --n 20000 \
      --k 10 --pivots 256 --groups 9 [--grouping greedy] [--distributed]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (
    JoinConfig, brute_force_knn, hbrj_join, knn_join, pbj_join, plan_join)
from repro.data import expand_dataset, forest_like, osm_like


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=["forest", "osm"], default="forest")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--expand", type=int, default=1)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--pivots", type=int, default=256)
    ap.add_argument("--groups", type=int, default=9)
    ap.add_argument("--pivot-strategy", default="random",
                    choices=["random", "farthest", "kmeans"])
    ap.add_argument("--grouping", default="geometric",
                    choices=["geometric", "greedy", "none"])
    ap.add_argument("--method", default="pgbj",
                    choices=["pgbj", "pbj", "hbrj"])
    ap.add_argument("--distributed", action="store_true",
                    help="shard_map execution over the host devices")
    ap.add_argument("--verify", action="store_true")
    args = ap.parse_args(argv)

    data = (forest_like(args.n, args.dim) if args.dataset == "forest"
            else osm_like(args.n))
    data = expand_dataset(data, args.expand)
    cfg = JoinConfig(k=args.k, n_pivots=args.pivots, n_groups=args.groups,
                     pivot_strategy=args.pivot_strategy,
                     grouping=args.grouping)
    t0 = time.perf_counter()
    if args.method == "pgbj":
        if args.distributed:
            import jax
            from repro.core.distributed import distributed_knn_join
            from repro.core.jax_compat import make_mesh
            n_dev = len(jax.devices())
            cfg = JoinConfig(k=args.k, n_pivots=args.pivots, n_groups=n_dev,
                             pivot_strategy=args.pivot_strategy,
                             grouping=args.grouping)
            plan = plan_join(data, data, cfg)
            mesh = make_mesh((n_dev,), ("data",))
            res = distributed_knn_join(data, data, plan, mesh)
        else:
            res = knn_join(data, data, config=cfg)
    elif args.method == "pbj":
        res = pbj_join(data, data, args.k, cfg, n_reducers=args.groups)
    else:
        res = hbrj_join(data, data, args.k, n_reducers=args.groups)
    dt = time.perf_counter() - t0

    s = res.stats
    print(f"{args.method} on {args.dataset} n={data.shape[0]} k={args.k}: "
          f"{dt:.2f}s")
    print(f"  selectivity={s.selectivity:.4f} shuffle={s.shuffle_tuples} "
          f"alpha={s.replicas_s/max(s.n_s,1):.2f}")
    if args.verify:
        sample = np.random.default_rng(0).choice(
            data.shape[0], min(500, data.shape[0]), replace=False)
        bd, _ = brute_force_knn(data[sample], data, args.k)
        ok = np.allclose(res.distances[sample], bd, atol=1e-2)
        print(f"  verified vs brute force on {len(sample)} samples: {ok}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
