import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM, or unsupported collectives fail here.
Outputs per cell: memory_analysis (fits/doesn't), cost_analysis flops &
bytes, and collective-operand bytes parsed from the post-SPMD HLO — the
three §Roofline terms derive from exactly this record.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-34b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_arch, runnable_cells
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

# v5e-class hardware constants (per chip) for the roofline terms
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # B/s
LINK_BW = 50e9           # B/s per ICI link


_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.

    Works on the per-device (partitioned) module: shapes are shard-local,
    so the totals are per-device collective traffic per step.
    """
    out = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)", line)
        if not m:
            continue
        kind = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
    out["total"] = sum(out.values())
    return out


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    arch = get_arch(arch_name)
    cell = build_cell(arch, shape_name, mesh)
    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "chips": int(np.prod(list(mesh.shape.values()))),
        "kind": cell.kind, "notes": cell.notes,
    }
    t0 = time.time()
    donate = (0, 1) if cell.kind == "train" else ()
    with mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*cell.abstract_args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes
                          + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes
                          - ma.alias_size_in_bytes),
    }
    # XLA-CPU stages bf16 scan stacks as bulk f32 buffers before its f32
    # dot kernels; TPU MXUs take bf16 directly, so subtract the artifact
    # for the fits-HBM verdict (both numbers are recorded).
    staging = hlo_analysis.cpu_bf16_convert_staging_bytes(compiled.as_text())
    rec["memory"]["cpu_convert_staging_bytes"] = int(staging)
    # floor at live arguments+outputs: the staging estimate can exceed the
    # true overlap when distinct-shape staging buffers are not co-live
    rec["memory"]["peak_bytes_tpu_adj"] = int(max(
        rec["memory"]["peak_bytes"] - staging,
        rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
        - rec["memory"]["alias_bytes"]))
    rec["memory"]["fits_hbm_16g"] = \
        rec["memory"]["peak_bytes_tpu_adj"] <= 16 * 2**30
    ca = hlo_analysis.cost_analysis_dict(compiled)
    rec["cost_analysis_raw"] = {"flops": float(ca.get("flops", 0.0)),
                                "bytes": float(ca.get("bytes accessed", 0.0))}
    # trip-count-scaled accounting (cost_analysis counts loop bodies once)
    totals = hlo_analysis.analyze(compiled.as_text())
    rec["cost"] = {"flops": totals.flops, "bytes": totals.bytes}
    rec["collectives"] = dict(totals.coll)
    rec["collectives"]["total"] = sum(totals.coll.values())

    chips = rec["chips"]
    flops, hbm_b = rec["cost"]["flops"], rec["cost"]["bytes"]
    coll_b = rec["collectives"]["total"]
    # cost_analysis is per-device on the partitioned module
    rec["roofline"] = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_b / HBM_BW,
        "collective_s": coll_b / LINK_BW,
    }
    rec["roofline"]["bottleneck"] = max(
        rec["roofline"], key=lambda k: rec["roofline"][k])

    # model flops (per device): 6·N_active·tokens / chips
    shape = SHAPES[shape_name]
    tokens = shape.global_batch * shape.seq_len
    n_active = arch.active_param_count()
    mf = 6.0 * n_active * tokens
    if cell.kind != "train":
        mf /= 3.0                      # forward only
    if cell.kind == "decode":
        # decode flops ≈ 2·N_active per token + attention over the cache
        mf = 2.0 * n_active * shape.global_batch
    rec["model_flops_per_chip"] = mf / chips
    rec["useful_flop_ratio"] = (mf / chips) / max(flops, 1.0)

    if verbose:
        r = rec["roofline"]
        print(f"[{arch_name} × {shape_name} @ {rec['mesh']}] "
              f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
              f"compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"bottleneck={r['bottleneck']} "
              f"useful={rec['useful_flop_ratio']:.2f} "
              f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
              f"({rec['notes']})", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in runnable_cells(get_arch(a)):
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]

    results, failures = [], []
    for a, s in cells:
        try:
            results.append(run_cell(a, s, multi_pod=args.multi_pod))
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            traceback.print_exc()
            failures.append({"arch": a, "shape": s, "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
