"""Per-(arch × shape) step builders + ShapeDtypeStruct input specs.

``build_cell`` returns everything the dry-run (and a real launch) needs:
the step function, abstract inputs, and input shardings — no allocation
(weak-type-correct ShapeDtypeStructs throughout).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES
from repro.distributed.sharding import (
    axis_rules, param_shardings, logical_to_pspec)
from repro.models import ModelOptions, forward, init_cache, init_params
from repro.train import OptConfig, TrainConfig, make_train_step

# archs whose AdamW state cannot fit one pod (12 B/param > HBM) use
# adafactor + bf16 grad accumulation — recorded in EXPERIMENTS.md §Dry-run
_ADAFACTOR_ABOVE = 100e9


@dataclasses.dataclass
class Cell:
    arch: ArchConfig
    shape: ShapeConfig
    opts: ModelOptions
    step_fn: Any                 # jit-able python callable
    abstract_args: Tuple         # ShapeDtypeStructs, positional
    in_shardings: Tuple
    kind: str                    # train | prefill | decode
    notes: str = ""


def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def model_options(arch: ArchConfig, shape: ShapeConfig) -> ModelOptions:
    return ModelOptions(
        dtype=jnp.bfloat16,
        remat=shape.kind == "train",
        chunk_q=2048,
        max_abs_pos=max(4096, shape.seq_len + shape.cache_len + 1),
        readonly_cache=shape.kind == "decode",
    )


def abstract_params(arch: ArchConfig, opts: ModelOptions):
    return jax.eval_shape(
        lambda k: init_params(arch, k, opts), jax.random.PRNGKey(0))


def _kv_divides(arch: ArchConfig, mesh: Mesh) -> bool:
    tp = mesh.shape.get("model", 1)
    return arch.n_kv_heads % tp == 0


def _extras_specs(arch: ArchConfig, lead: Tuple[int, ...], seq: int,
                  batch_axes, *, for_train: bool):
    """(avals, shardings-spec) for enc_frames / vision / mrope positions."""
    av: Dict[str, Any] = {}
    sp: Dict[str, Any] = {}
    nb = len(lead)
    bspec = (None,) * (nb - 1) + (batch_axes,)
    if arch.n_enc_layers:
        av["enc_frames"] = _sds(lead + (arch.enc_len, arch.d_model),
                                jnp.bfloat16)
        sp["enc_frames"] = P(*bspec, None, None)
    if arch.rope == "mrope":
        # (…, 3, B, T) positions; scanned micro-axis leads in train mode
        if for_train:
            av["positions"] = _sds((lead[0], 3, lead[1], seq), jnp.int32)
            sp["positions"] = P(None, None, batch_axes, None)
        else:
            av["positions"] = _sds((3,) + lead + (seq,), jnp.int32)
            sp["positions"] = P(None, batch_axes, None)
        av["vision_embeds"] = _sds(lead + (arch.n_vision_embeds,
                                           arch.d_model), jnp.bfloat16)
        sp["vision_embeds"] = P(*bspec, None, None)
    return av, sp


def build_train_cell(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh
                     ) -> Cell:
    opts = model_options(arch, shape)
    baxes = _batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    mb_global = nb                      # 1 sequence per data replica
    accum = max(1, shape.global_batch // mb_global)
    big = arch.param_count() > _ADAFACTOR_ABOVE
    tcfg = TrainConfig(
        opt=OptConfig(name="adafactor" if big else "adamw"),
        accum=accum,
        accum_dtype=jnp.bfloat16 if big else jnp.float32)
    opt_init, train_step = make_train_step(arch, tcfg, opts)

    params_av = abstract_params(arch, opts)
    opt_av = jax.eval_shape(opt_init, params_av)
    lead = (accum, mb_global)
    batch_av = {
        "tokens": _sds(lead + (shape.seq_len,), jnp.int32),
        "labels": _sds(lead + (shape.seq_len,), jnp.int32),
    }
    batch_sp = {
        "tokens": P(None, baxes, None),
        "labels": P(None, baxes, None),
    }
    eav, esp = _extras_specs(arch, lead, shape.seq_len, baxes, for_train=True)
    batch_av.update(eav)
    batch_sp.update(esp)

    kvd = _kv_divides(arch, mesh)
    p_sh = param_shardings(params_av, mesh, kv_heads_divide=kvd,
                           fsdp_over_pod=big)
    o_sh = param_shardings(opt_av, mesh, kv_heads_divide=kvd,
                           fsdp_over_pod=big)
    b_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), batch_sp,
        is_leaf=lambda x: isinstance(x, P))

    def step(params, opt_state, batch):
        with axis_rules(mesh):
            return train_step(params, opt_state, batch)

    notes = f"accum={accum} mb={mb_global} opt={tcfg.opt.name}"
    return Cell(arch, shape, opts, step,
                (params_av, opt_av, batch_av), (p_sh, o_sh, b_sh),
                "train", notes)


def build_prefill_cell(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh
                       ) -> Cell:
    opts = model_options(arch, shape)
    baxes = _batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    b = shape.global_batch
    bspec = baxes if b % max(nb, 1) == 0 and b >= nb else None
    lead = (b,)
    tokens_av = _sds(lead + (shape.seq_len,), jnp.int32)
    params_av = abstract_params(arch, opts)
    eav, esp = _extras_specs(arch, lead, shape.seq_len, bspec,
                             for_train=False)

    def step(params, tokens, extras):
        with axis_rules(mesh):
            logits, _ = forward(params, arch, tokens, opts=opts,
                                mode="prefill", **extras)
            return logits[:, -1]       # serving returns last-position logits

    p_sh = param_shardings(params_av, mesh, mode="serve",
                           kv_heads_divide=_kv_divides(arch, mesh))
    t_sh = NamedSharding(mesh, P(bspec, None))
    e_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), esp,
        is_leaf=lambda x: isinstance(x, P))
    return Cell(arch, shape, opts, step, (params_av, tokens_av, eav),
                (p_sh, t_sh, e_sh), "prefill", f"B={b} T={shape.seq_len}")


def _cache_pspec(path_str: str, leaf, baxes) -> P:
    """Sharding for decode caches: batch on batch axes; the *length* dim of
    big attention caches on "model" (the serving layout the readonly path
    assumes); small/recurrent state replicated across model."""
    nd = leaf.ndim
    if nd == 0:
        return P()
    big = any(s in path_str for s in ("/k", "/v", "ckv", "k_rope"))
    ring = "local" in path_str
    spec = [None] * nd
    # leading axis is the scan stack (reps); batch is axis 1
    if nd >= 2:
        spec[1] = baxes
    if big and not ring and nd >= 3 and leaf.shape[2] % 16 == 0:
        spec[2] = "model"
    return P(*spec)


def build_decode_cell(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh
                      ) -> Cell:
    opts = model_options(arch, shape)
    baxes = _batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    b = shape.global_batch
    bspec = baxes if b % max(nb, 1) == 0 and b >= nb else None
    params_av = abstract_params(arch, opts)
    cache_av = jax.eval_shape(
        lambda: init_cache(arch, b, shape.cache_len, opts))
    # decode enters with a full cache (pos = cache_len - 1 headroom)
    token_av = _sds((b, 1), jnp.int32)
    eav, esp = _extras_specs(arch, (b,), 1, bspec, for_train=False)
    eav.pop("vision_embeds", None)  # vision merged at prefill only
    esp.pop("vision_embeds", None)

    def step(params, token, cache, extras):
        with axis_rules(mesh):
            logits, new_cache = forward(
                params, arch, token, cache=cache, opts=opts,
                mode="decode", **extras)
            return logits[:, -1], new_cache

    p_sh = param_shardings(params_av, mesh, mode="serve",
                           kv_heads_divide=_kv_divides(arch, mesh))
    t_sh = NamedSharding(mesh, P(bspec, None))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_av)
    c_sh = jax.tree_util.tree_unflatten(treedef, [
        NamedSharding(mesh, _cache_pspec(
            "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                     for e in path), leaf, bspec))
        for path, leaf in flat])
    e_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), esp,
        is_leaf=lambda x: isinstance(x, P))
    return Cell(arch, shape, opts, step,
                (params_av, token_av, cache_av, eav),
                (p_sh, t_sh, c_sh, e_sh), "decode",
                f"B={b} C={shape.cache_len} readonly")


def build_cell(arch: ArchConfig, shape_name: str, mesh: Mesh) -> Cell:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_cell(arch, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_cell(arch, shape, mesh)
    return build_decode_cell(arch, shape, mesh)
