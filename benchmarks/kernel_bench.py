"""Kernel micro-benchmarks: jnp reference path wall-time on host + the
roofline-relevant derived quantities. (Pallas runs interpret-mode on CPU,
so wall-time here benchmarks the *reference*; kernel perf is assessed
structurally via the dry-run HLO — see EXPERIMENTS.md §Roofline.)

``distance_topk_gather_bench`` measures what the pruned-schedule path is
for: on clustered data the compacted schedule visits a fraction of the
dense tile grid (the Figure-9 "pruning power" as executed tiles, not
counters), and ``pack_send_buffers_bench`` pits the vectorized
lexsort+scatter shuffle packing against the seed's per-row Python loop.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import Row


def _bench(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def distance_topk_bench() -> List[Row]:
    rows = []
    rng = np.random.default_rng(0)
    for (nr, ns, d, k) in [(1024, 8192, 10, 10), (4096, 16384, 2, 10)]:
        r = jnp.asarray(rng.normal(size=(nr, d)).astype(np.float32))
        s = jnp.asarray(rng.normal(size=(ns, d)).astype(np.float32))
        secs = _bench(ops.distance_topk, r, s, k, impl="ref")
        flops = 2.0 * nr * ns * d
        rows.append(Row("kernel_distance_topk", f"{nr}x{ns}x{d},k={k}",
                        secs, {"gflops_s": flops / secs / 1e9}))
    return rows


def assign_bench() -> List[Row]:
    rng = np.random.default_rng(1)
    rows = []
    for (n, m, d) in [(65536, 256, 10), (16384, 1024, 2)]:
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        p = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        secs = _bench(ops.assign, x, p, impl="ref")
        rows.append(Row("kernel_assign", f"{n}x{m}x{d}", secs,
                        {"gflops_s": 2.0 * n * m * d / secs / 1e9}))
    return rows


def flash_attention_bench() -> List[Row]:
    rng = np.random.default_rng(2)
    rows = []
    for (b, t, h, kvh, dh) in [(1, 1024, 8, 2, 64)]:
        q = jnp.asarray(rng.normal(size=(b, t, h, dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, t, kvh, dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, t, kvh, dh)).astype(np.float32))
        secs = _bench(ops.flash_attention, q, k, v, impl="ref")
        flops = 4.0 * b * h * t * t * dh
        rows.append(Row("kernel_flash_attention", f"b{b}t{t}h{h}", secs,
                        {"gflops_s": flops / secs / 1e9}))
    return rows


from repro.data import clustered_like as _clustered  # noqa: E402


def distance_topk_gather_bench(n: int = 20000) -> List[Row]:
    """Dense vs pruned-schedule reducer on clustered data (host engines —
    identical tile arithmetic, so the wall-time ratio isolates the
    schedule; on TPU the same schedule also skips the DMA)."""
    from repro.core import JoinConfig, plan_join
    from repro.core.join import join_group_dense, join_group_gather
    from repro.core.schedule import build_tile_schedule

    n_r, n_s, dim, k = n, 2 * n, 8, 10
    bm, bn = 64, 256
    r = _clustered(n_r, dim, seed=0)
    s = _clustered(n_s, dim, seed=1)
    cfg = JoinConfig(k=k, n_pivots=24, n_groups=1, seed=3,
                     tile_r=bm, tile_s=bn)
    plan = plan_join(r, s, cfg)

    ord_r = np.argsort(plan.r_part, kind="stable")
    rr = np.ascontiguousarray(r[ord_r])
    ord_s = np.lexsort((plan.s_dist, plan.s_part))
    ss = np.ascontiguousarray(s[ord_s])
    sids = np.arange(n_s, dtype=np.int64)[ord_s]

    sched = build_tile_schedule(
        rr, plan.r_part[ord_r], plan.s_part[ord_s], plan.s_dist[ord_s],
        plan.pivots, plan.pivd, plan.theta, bm=bm, bn=bn,
        knn_dists=plan.t_s.knn_dists, k=k)
    tiles_dense = sched.nr_tiles * sched.ns_tiles

    t0 = time.perf_counter()
    dd, di = join_group_dense(rr, ss, sids, k, tile_r=bm, tile_s=bn)
    t_dense = time.perf_counter() - t0
    t0 = time.perf_counter()
    gd, gi = join_group_gather(rr, ss, sids, k, sched)
    t_gather = time.perf_counter() - t0
    if not np.allclose(gd, dd, atol=1e-3):
        raise AssertionError("gather schedule lost true neighbors")

    return [
        Row("kernel_distance_topk_dense_vs_gather",
            f"{n_r}x{n_s}x{dim},k={k},bm={bm},bn={bn}", t_gather,
            {"dense_s": t_dense, "gather_s": t_gather,
             "speedup": t_dense / t_gather,
             "tiles_dense": float(tiles_dense),
             "tiles_gather": float(sched.n_visits),
             "visit_frac": sched.density}),
    ]


def index_build_vs_batch_plan_bench(n: int = 20000,
                                    batches: int = 8) -> List[Row]:
    """The build-once amortization claim, measured: one ``SIndex`` build
    (S-side phase 1 + pivot-sorted packing) vs per-micro-batch query
    planning (jitted assignment + θ/LB + grouping) vs the per-batch join
    itself. Build cost is paid once; each R micro-batch pays only
    plan+join — ``build_over_plan`` says how many batch-plans one build
    is worth."""
    from repro.core import JoinConfig, build_index, execute_join, plan_queries

    n_s, dim, k = n, 8, 10
    batch = max(64, n // 40)
    s = _clustered(n_s, dim, seed=0)
    cfg = JoinConfig(k=k, n_pivots=64, n_groups=8, seed=3)

    t0 = time.perf_counter()
    index = build_index(s, cfg)
    t_build = time.perf_counter() - t0

    # warm the jitted planner (tracing is a one-time cost, not the
    # steady-state per-batch price this row is about)
    warm = _clustered(batch, dim, seed=9)
    execute_join(warm, index, plan_queries(warm, index, cfg))

    t_plan = t_join = 0.0
    for i in range(batches):
        r = _clustered(batch, dim, seed=10 + i)
        t0 = time.perf_counter()
        qplan = plan_queries(r, index, cfg)
        t_plan += time.perf_counter() - t0
        t0 = time.perf_counter()
        execute_join(r, index, qplan)
        t_join += time.perf_counter() - t0
    plan_s = t_plan / batches
    join_s = t_join / batches
    return [
        Row("kernel_index_build_amortization",
            f"ns={n_s}x{dim},k={k},batch={batch},batches={batches}",
            t_build,
            {"index_build_s": t_build, "plan_batch_s": plan_s,
             "join_batch_s": join_s,
             "build_over_plan": t_build / plan_s,
             "plan_frac_of_batch": plan_s / (plan_s + join_s)}),
    ]


def streaming_vs_oneshot_bench(n: int = 20000,
                               batches: int = 8) -> List[Row]:
    """knn_join_batched (micro-batched, bounded working set) vs one-shot
    knn_join against the same prebuilt index. The headline
    ``overhead_frac`` is measured on the fused megastep path (one jitted
    device pass per batch, no host planning); the host-planned streaming
    engine is kept as ``hostplanned_*`` — its overhead is what the
    megastep deletes."""
    from repro.core import JoinConfig, build_index, knn_join, knn_join_batched

    n_s, dim, k = n, 8, 10
    n_r = max(256, n // 10)
    s = _clustered(n_s, dim, seed=0)
    r = _clustered(n_r, dim, seed=1)
    cfg = JoinConfig(k=k, n_pivots=64, n_groups=8, seed=3)
    index = build_index(s, cfg)
    bs = -(-n_r // batches)
    # warm every jitted stage at the shapes the timed runs will hit
    # (assignment, θ/LB, merges, and the megastep at the batch bucket)
    knn_join_batched(r[:bs], index=index, config=cfg, batch_size=bs)
    knn_join_batched(r[:64], index=index, config=cfg, batch_size=64)
    knn_join(r[:64], config=cfg, index=index)
    knn_join_batched(r[:bs], index=index, config=cfg, batch_size=bs,
                     megastep=True)

    t0 = time.perf_counter()
    one = knn_join(r, config=cfg, index=index)
    t_one = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = knn_join_batched(r, index=index, config=cfg, batch_size=bs)
    t_host = time.perf_counter() - t0
    t0 = time.perf_counter()
    mega = knn_join_batched(r, index=index, config=cfg, batch_size=bs,
                            megastep=True)
    t_mega = time.perf_counter() - t0
    if not np.array_equal(res.distances, one.distances):
        raise AssertionError("streaming result diverged from one-shot")
    _check_agree(mega.distances, mega.indices, one.distances, one.indices,
                 "megastep streaming vs one-shot")
    return [
        Row("kernel_streaming_vs_oneshot",
            f"nr={n_r},ns={n_s}x{dim},k={k},batches={batches}", t_mega,
            {"oneshot_s": t_one, "streaming_s": t_mega,
             "megastep_s": t_mega,
             # overhead_frac is clamped at 0: the megastep is routinely
             # *faster* than one-shot here, and a negative baseline made
             # the guard's 2x-ratio math meaningless (a -0.49 baseline
             # "allowed" any regression). The signed value survives in
             # overhead_frac_raw; the absolute streaming_s row is what
             # the guard now watches.
             "overhead_frac": max((t_mega - t_one) / t_one, 0.0),
             "overhead_frac_raw": (t_mega - t_one) / t_one,
             "hostplanned_s": t_host,
             "hostplanned_overhead_frac": (t_host - t_one) / t_one}),
    ]


def mutable_index_bench(n: int = 20000, batches: int = 4) -> List[Row]:
    """Online mutability (core.segments.MutableIndex): insert+seal and
    delete throughput, multi-segment query latency while deltas and
    tombstones are live, compaction cost, and the post-compaction query
    latency the compaction buys back. Embedded correctness check: the
    live set is unchanged by compact(), so pre/post query distances must
    match bitwise."""
    from repro.core import JoinConfig, MutableIndex

    dim, k, nq = 8, 10, 512
    ins_batch = max(256, n // 10)
    n_del = max(64, n // 10)
    base = _clustered(n, dim, seed=0)
    q = _clustered(nq, dim, seed=1)
    cfg = JoinConfig(k=k, n_pivots=64, n_groups=8, seed=3)
    mi = MutableIndex.build(base, cfg, seal_threshold=ins_batch)
    mi.join_batch(q)   # warm the jitted planner + merge stages

    # first insert+seal cycle pays the one-time trace cost of the seal
    # path's fused assign+summarize+sort jit (plus pivot selection at
    # the delta shape) — report it separately; the guarded
    # insert_rows_per_s is the steady state every later seal runs at
    t0 = time.perf_counter()
    mi.insert(_clustered(ins_batch, dim, seed=9))
    t_first_seal = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(batches):
        mi.insert(_clustered(ins_batch, dim, seed=10 + i))
    t_insert = time.perf_counter() - t0          # includes the seals

    rng = np.random.default_rng(7)
    doomed = rng.choice(n, n_del, replace=False)
    t0 = time.perf_counter()
    mi.delete(doomed)
    t_delete = time.perf_counter() - t0

    n_segments_pre = mi.n_segments
    t0 = time.perf_counter()
    d_pre, i_pre = mi.join_batch(q)
    t_q_pre = time.perf_counter() - t0

    # the fused megastep over the same multi-segment + tombstoned state:
    # one device pass fans over every segment, bitwise the host result
    from repro.core import StreamJoinEngine
    meng = StreamJoinEngine(mi, cfg, megastep=True)
    d_mega, i_mega = meng.join_batch(q)     # warm (trace + payload upload)
    _check_agree(d_mega, i_mega, d_pre, i_pre, "megastep vs host fan-out")
    t0 = time.perf_counter()
    meng.join_batch(q)
    t_q_pre_mega = time.perf_counter() - t0

    t0 = time.perf_counter()
    mi.compact()
    t_compact = time.perf_counter() - t0

    t0 = time.perf_counter()
    d_post, _ = mi.join_batch(q)
    t_q_post = time.perf_counter() - t0
    if not np.array_equal(d_pre, d_post):
        raise AssertionError("query distances changed across compaction")
    meng.join_batch(q)                      # warm post-compaction payload
    t0 = time.perf_counter()
    meng.join_batch(q)
    t_q_post_mega = time.perf_counter() - t0

    return [
        Row("kernel_mutable_index",
            f"n={n},ins={batches}x{ins_batch},del={n_del},q={nq},k={k}",
            t_compact,
            {"insert_rows_per_s": batches * ins_batch / t_insert,
             "first_insert_seal_s": t_first_seal,
             "delete_ids_per_s": n_del / t_delete,
             "query_pre_compact_s": t_q_pre,
             "query_post_compact_s": t_q_post,
             "query_pre_compact_megastep_s": t_q_pre_mega,
             "query_post_compact_megastep_s": t_q_post_mega,
             "megastep_s": t_q_pre_mega,
             "post_over_pre": t_q_post / t_q_pre,
             "compact_s": t_compact,
             "segments_pre_compact": float(n_segments_pre)}),
    ]


def _check_agree(d1, i1, d2, i2, what):
    """Embedded equality check for the megastep benches. Clustered data
    packs near-ties at the rank-k boundary, where the two paths' float32
    selection metrics may legitimately resolve a ~1e-5 gap differently —
    so the bench gate is allclose distances + ≥99.9% identical ids; the
    bitwise contract is pinned on well-separated data in
    tests/test_megastep.py."""
    if not np.allclose(d1, d2, atol=1e-3):
        raise AssertionError(f"{what}: distances diverged")
    if (np.asarray(i1) == np.asarray(i2)).mean() < 0.999:
        raise AssertionError(f"{what}: id agreement below 99.9%")


class _fetch_counter:
    """Counts device→host fetches in a scope — the host-sync metric:
    every ``np.asarray``/``np.array`` over a ``jax.Array`` is a blocking
    host round-trip (the conversion path this codebase uses throughout).
    Patches the numpy module attributes; ArrayImpl itself is a C type
    and cannot be instrumented."""

    def __enter__(self):
        import jax

        self._asarray = np.asarray
        self._array = np.array
        self.count = 0

        def wrap(fn):
            def inner(obj=None, *a, **kw):
                if isinstance(obj, jax.Array):
                    self.count += 1
                return fn(obj, *a, **kw)
            return inner

        np.asarray = wrap(self._asarray)
        np.array = wrap(self._array)
        return self

    def __exit__(self, *exc):
        np.asarray = self._asarray
        np.array = self._array
        return False


def megastep_vs_hostplanned_bench(n: int = 20000,
                                  batches: int = 8) -> List[Row]:
    """The fused megastep against the host-planned per-batch path on the
    same resident index: steady-state per-batch latency, speedup, and the
    host-sync count (device→host fetches per batch — the round-trips the
    megastep collapses; its device-level API performs zero between
    enqueue and fetch, verified here with the counter *and* the JAX
    transfer guard)."""
    import jax

    from repro.core import JoinConfig, StreamJoinEngine, build_index

    n_s, dim, k = n, 8, 10
    batch = max(64, n // 40)
    s = _clustered(n_s, dim, seed=0)
    cfg = JoinConfig(k=k, n_pivots=64, n_groups=8, seed=3)
    index = build_index(s, cfg)
    host_eng = StreamJoinEngine(index, cfg)
    mega_eng = StreamJoinEngine(index, cfg, megastep=True)
    qs = [_clustered(batch, dim, seed=10 + i) for i in range(batches)]
    hd, hi = host_eng.join_batch(qs[0])                     # warm both
    md, mi = mega_eng.join_batch(qs[0])
    _check_agree(md, mi, hd, hi, "megastep vs host-planned")

    t0 = time.perf_counter()
    for q in qs:
        host_eng.join_batch(q)
    t_host = (time.perf_counter() - t0) / batches
    t0 = time.perf_counter()
    for q in qs:
        mega_eng.join_batch(q)
    t_mega = (time.perf_counter() - t0) / batches

    with _fetch_counter() as fc:
        host_eng.join_batch(qs[0])
    syncs_host = fc.count
    if syncs_host == 0:
        raise AssertionError("sync counter is vacuous — host path must "
                             "fetch at least its plan artifacts")
    with _fetch_counter() as fc:
        mega_eng.join_batch(qs[0])
    syncs_mega = fc.count
    # device-level steady state: zero transfers between enqueue and fetch
    me = mega_eng.megastep_engine
    qd, nv = me.enqueue(qs[0])
    jax.block_until_ready(me.join_batch_device(qd, nv))
    with _fetch_counter() as fc, jax.transfer_guard("disallow"):
        jax.block_until_ready(me.join_batch_device(qd, nv))
    if fc.count:
        raise AssertionError(
            f"megastep steady state fetched {fc.count} arrays")

    return [
        Row("kernel_megastep_vs_hostplanned",
            f"ns={n_s}x{dim},k={k},batch={batch},batches={batches}", t_mega,
            {"megastep_batch_s": t_mega, "hostplanned_batch_s": t_host,
             "speedup": t_host / t_mega,
             "host_syncs_hostplanned": float(syncs_host),
             "host_syncs_megastep": float(syncs_mega),
             "device_steady_state_syncs": float(fc.count)}),
    ]


def sharded_vs_single_bench(n: int = 20000, batches: int = 8) -> List[Row]:
    """Sharded megastep (core.sharded) against the single-device megastep
    on the same index: per-batch latency, speedup, the per-shard vs
    whole-index resident bytes (what the mesh buys in HBM), the
    cross-shard merge overhead (sharded-over-N vs sharded-over-1 — the
    all-gather + tree-merge cost isolated from the shard_map machinery),
    and two guarded invariants: **bitwise equality** with the
    single-device engine on every batch (HARD_ONE) and **zero host
    syncs** in the transfer-guarded steady state (HARD_ZERO).

    The shard count is whatever the process sees (1 on a plain CPU run —
    speedup ≈ 1, overhead 0; the CI mesh step re-runs this with 8 forced
    host devices). Simulated-mesh wall-clock oversubscribes host threads,
    so the timing rows are informational there; the bitwise and sync
    rows are the real gates.
    """
    import jax

    from repro.core import JoinConfig, build_index
    from repro.core.megastep import MegastepEngine
    from repro.core.sharded import ShardedMegastepEngine

    n_s, dim, k = n, 8, 10
    batch = max(64, n // 40)
    n_sh = len(jax.devices())
    s = _clustered(n_s, dim, seed=0)
    cfg = JoinConfig(k=k, n_pivots=64, n_groups=8, seed=3)
    index = build_index(s, cfg)
    single = MegastepEngine(index, cfg)
    sharded = ShardedMegastepEngine(index, cfg, n_shards=n_sh)
    one = (sharded if n_sh == 1
           else ShardedMegastepEngine(index, cfg, n_shards=1))
    qs = [_clustered(batch, dim, seed=10 + i) for i in range(batches)]

    # the bitwise gate covers every batch and both shard counts
    for q in qs:
        sd_, si_ = single.join_batch(q)
        dd, di = sharded.join_batch(q)
        d1, i1 = one.join_batch(q)
        if not (np.array_equal(dd, sd_) and np.array_equal(di, si_)
                and np.array_equal(d1, sd_) and np.array_equal(i1, si_)):
            raise AssertionError(
                f"sharded megastep ({n_sh} shards) diverged bitwise from "
                f"the single-device engine")

    t0 = time.perf_counter()
    for q in qs:
        single.join_batch(q)
    t_single = (time.perf_counter() - t0) / batches
    t0 = time.perf_counter()
    for q in qs:
        sharded.join_batch(q)
    t_sharded = (time.perf_counter() - t0) / batches
    t0 = time.perf_counter()
    for q in qs:
        one.join_batch(q)
    t_one = (time.perf_counter() - t0) / batches

    # steady state: everything is mesh-committed at enqueue/refresh, so
    # the jitted call moves zero bytes — counter AND transfer guard
    qd, nv = sharded.enqueue(qs[0])
    jax.block_until_ready(sharded.join_batch_device(qd, nv))
    with _fetch_counter() as fc, jax.transfer_guard("disallow"):
        jax.block_until_ready(sharded.join_batch_device(qd, nv))
    if fc.count:
        raise AssertionError(
            f"sharded steady state fetched {fc.count} arrays")

    per_shard = sharded.nbytes_per_shard()
    whole = index.nbytes_resident()
    return [
        Row("kernel_sharded_vs_single",
            f"ns={n_s}x{dim},k={k},batch={batch},shards={n_sh}", t_sharded,
            {"n_shards": float(n_sh),
             "single_batch_s": t_single,
             "sharded_batch_s": t_sharded,
             "shard_speedup": t_single / t_sharded,
             "merge_overhead_frac": (max(t_sharded / t_one - 1.0, 0.0)
                                     if n_sh > 1 else 0.0),
             "per_shard_bytes": float(per_shard.max()),
             "whole_bytes": float(whole),
             "shard_balance": float(per_shard.min() / max(per_shard.max(),
                                                          1)),
             "sharded_steady_state_syncs": float(fc.count),
             "bitwise_equal": 1.0}),
    ]


def quant_coarse_vs_fp32_bench(n: int = 20000, batches: int = 8) -> List[Row]:
    """Quantized tier (repro.quant) vs the fp32 megastep on the same
    index: resident bytes/row (the 4× claim `SIndex.nbytes_resident`
    reports), coarse-pass and end-to-end per-batch latency, shortlist
    hit-rate, certification rate — and an embedded **bitwise** equality
    gate (the quantized tier's contract is exactness, so the bench
    fails CI outright on any divergence; no tolerance).

    Two engines run here. The *tuned* engine (default construction)
    resolves its mode from the committed tuning table
    (`repro.quant.autotune`) — on backends where the int8 coarse pass
    cannot beat fp32 it runs the fp32 megastep, so ``endtoend_speedup``
    is the speedup of the path the engine actually picks (≈1.0 when the
    tuned fallback engages, >1 when int8 wins). The *forced-int8*
    engine (``tune=False``) measures the coarse/resident machinery
    itself regardless of the tuner's verdict, including the
    transfer-guarded zero-host-sync check on the device-resident
    re-rank path.

    dim=32: wide enough that codes dominate the ε/scale metadata (the
    bytes_ratio acceptance floor is 3.5×).
    """
    import jax

    from repro.core import JoinConfig, JoinStats, StreamJoinEngine, \
        build_index
    from repro.quant.engine import QuantMegastepEngine

    n_s, dim, k = n, 32, 10
    batch = max(64, n // 40)
    s = _clustered(n_s, dim, seed=0)
    cfg = JoinConfig(k=k, n_pivots=64, n_groups=8, seed=3)
    index = build_index(s, cfg)
    fp_eng = StreamJoinEngine(index, cfg, megastep=True)
    q_eng = StreamJoinEngine(index, cfg, quantized=True)
    qeng = q_eng.megastep_engine     # tuned QuantMegastepEngine
    # forced-int8 twin: ignores the tuning table's mode verdict (but
    # not its tile shapes) — measures the coarse+resident machinery
    qeng8 = QuantMegastepEngine(index, cfg, tune=False)
    qs = [_clustered(batch, dim, seed=10 + i) for i in range(batches)]

    fd, fi = fp_eng.join_batch(qs[0])            # warm all three engines
    stats = JoinStats()
    qd, qi = q_eng.join_batch(qs[0], stats=stats)
    q8d, q8i = qeng8.join_batch(qs[0])
    for (dd, ii, what) in ((qd, qi, "tuned"), (q8d, q8i, "forced-int8")):
        if not (np.array_equal(dd, fd) and np.array_equal(ii, fi)):
            raise AssertionError(
                f"quantized path ({what}) diverged bitwise from the "
                f"fp32 megastep")

    # shortlist hit-rate: fraction of the true top-k already inside the
    # coarse int8 shortlist (before the exact re-rank / fallback)
    _, _, short_ids = qeng8.coarse_shortlist(qs[0])
    hits = np.fromiter(
        (np.isin(fi[j], short_ids[j]).mean() for j in range(batch)),
        np.float64, batch)

    # the equality gate covers EVERY batch the sweep touches and BOTH
    # engines, not just the warm-up — a regression that corrupts
    # results only after the first batch must not slip past HARD_ONE
    for q in qs[1:]:
        fd2, fi2 = fp_eng.join_batch(q)
        qd2, qi2 = q_eng.join_batch(q)
        q8d2, q8i2 = qeng8.join_batch(q)
        if not (np.array_equal(qd2, fd2) and np.array_equal(qi2, fi2)
                and np.array_equal(q8d2, fd2)
                and np.array_equal(q8i2, fi2)):
            raise AssertionError(
                "quantized path diverged bitwise from the fp32 megastep")

    t0 = time.perf_counter()
    for q in qs:
        fp_eng.join_batch(q)
    t_fp = (time.perf_counter() - t0) / batches
    t0 = time.perf_counter()
    for q in qs:
        qeng8.coarse_shortlist(q)
    t_coarse = (time.perf_counter() - t0) / batches
    st_all = JoinStats()
    t0 = time.perf_counter()
    for q in qs:
        q_eng.join_batch(q, stats=st_all)
    t_quant = (time.perf_counter() - t0) / batches
    st8 = JoinStats()
    t0 = time.perf_counter()
    for q in qs:
        qeng8.join_batch(q, stats=st8)
    t_int8 = (time.perf_counter() - t0) / batches

    # device-resident re-rank steady state: zero host syncs between
    # enqueue and fetch (the fp32 megastep's invariant, restored for
    # the int8 tier by the fused shortlist-gather + re-rank)
    resident_syncs = -1.0
    if qeng8.resident:
        qdv, nv = qeng8.enqueue(qs[0])
        jax.block_until_ready(qeng8.join_batch_device(qdv, nv))
        with _fetch_counter() as fc, jax.transfer_guard("disallow"):
            jax.block_until_ready(qeng8.join_batch_device(qdv, nv))
        resident_syncs = float(fc.count)
        if resident_syncs:
            raise AssertionError(
                f"resident re-rank steady state fetched {fc.count} arrays")

    bpr_fp32 = index.nbytes_resident(quantized=False) / n_s
    bpr_int8 = index.nbytes_resident(quantized=True) / n_s
    cert8 = 1.0 - st8.n_quant_fallback / (batches * batch)
    return [
        Row("kernel_quant_coarse_vs_fp32",
            f"ns={n_s}x{dim},k={k},batch={batch},mp={qeng8.mp}", t_quant,
            {"bytes_per_row_fp32": bpr_fp32,
             "bytes_per_row_int8": bpr_int8,
             "bytes_ratio": bpr_fp32 / bpr_int8,
             "fp32_batch_s": t_fp,
             "quant_coarse_s": t_coarse,
             "quant_batch_s": t_quant,
             "int8_batch_s": t_int8,
             "coarse_speedup": t_fp / t_coarse,
             "endtoend_speedup": t_fp / t_quant,
             "int8_endtoend_speedup": t_fp / t_int8,
             "tuned_int8": 1.0 if qeng.mode == "int8" else 0.0,
             "tuned_autotuned": 1.0 if qeng.autotuned else 0.0,
             "tuned_mp": float(qeng8.mp),
             "resident_rerank": 1.0 if qeng8.resident else 0.0,
             "resident_steady_state_syncs": max(resident_syncs, 0.0),
             "shortlist_hit_rate": float(hits.mean()),
             "certified_frac": cert8,
             "bitwise_equal": 1.0}),
    ]


def _pack_send_buffers_loop(rows, aux, dest, src_of_row, n_src, n_dst, cap):
    """The seed's per-row packing loop, kept as the microbench baseline."""
    nbuf = {k: np.zeros((n_src, n_dst, cap) + v.shape[1:], v.dtype)
            for k, v in aux.items()}
    buf = np.zeros((n_src, n_dst, cap, rows.shape[1]), rows.dtype)
    valid = np.zeros((n_src, n_dst, cap), bool)
    slot = np.zeros((n_src, n_dst), np.int64)
    for i in range(rows.shape[0]):
        s, d = src_of_row[i], dest[i]
        j = slot[s, d]
        buf[s, d, j] = rows[i]
        for k, v in aux.items():
            nbuf[k][s, d, j] = v[i]
        valid[s, d, j] = True
        slot[s, d] = j + 1
    return buf, nbuf, valid


def shard_failover_bench(n: int = 20000, batches: int = 6) -> List[Row]:
    """Shard fault tolerance (core.sharded + serve.scheduler): what a
    mid-stream shard loss costs and what it is allowed to change.

    Three arms on one clustered index (kmeans pivots — the regime where
    the degraded-coverage certificate is non-vacuous):

    * **r=2 failover** — warm replicated engine, one shard killed
      mid-stream via an armed :class:`ShardFault`; the internal
      failover retry, every post-failover batch, and the post-
      ``recover()`` batches must all stay **bitwise** the single-device
      engine (``failover_bitwise_equal``, HARD_ONE — replica placement
      serves each pivot group exactly once, so the shard-invariance
      argument applies verbatim). Reports failover/recovery latency
      and the r× HBM cost of replication.
    * **r=1 degraded coverage** — the same loss with no replica left:
      the surviving shards answer with per-query certified recall
      lower bounds, checked *sound* here against the brute-force
      oracle (the bench raises on any violation).
    * **scheduler failover** — a double-buffered scheduler hits the
      shard failure at finalize; the batch re-enters the engine rung
      and completes bitwise, and ``n_expired_dispatched_failover``
      (HARD_ZERO) pins that the deadline re-check at the failover
      instant never lets an expired request reach a device.

    Empty on <2 devices (plain CPU run) — the CI mesh step re-runs it
    under 8 forced host devices, like the sharded bench above.
    """
    import jax

    from repro.core import JoinConfig, StreamJoinEngine, build_index
    from repro.core.megastep import MegastepEngine
    from repro.core.sharded import ShardedMegastepEngine
    from repro.serve.faultinject import FaultPlan, ShardFault
    from repro.serve.scheduler import SchedulerConfig, ServeScheduler

    n_sh = len(jax.devices())
    if n_sh < 2:
        return []

    n_s, dim, k = n, 8, 10
    batch = max(64, n // 40)
    s = _clustered(n_s, dim, seed=0)
    cfg = JoinConfig(k=k, n_pivots=64, n_groups=8, seed=3,
                     pivot_strategy="kmeans")
    index = build_index(s, cfg)
    single = MegastepEngine(index, cfg)
    qs = [_clustered(batch, dim, seed=10 + i) for i in range(batches)]
    oracle = [single.join_batch(q) for q in qs]

    def _bitwise(got, want) -> float:
        return float(np.array_equal(got[0], want[0])
                     and np.array_equal(got[1], want[1]))

    # ---- arm 1: r=2 replicated engine, mid-stream shard loss --------
    eng = ShardedMegastepEngine(index, cfg, n_shards=n_sh, replication=2)
    eng.join_batch(qs[0])                                   # warm
    t0 = time.perf_counter()
    for q in qs:
        eng.join_batch(q)
    t_healthy = (time.perf_counter() - t0) / batches

    victim = n_sh // 2
    bitwise = 1.0
    with FaultPlan().fail(
            "sharded.shard_compute", times=1,
            exc=ShardFault("sharded.shard_compute", shard=victim)):
        t0 = time.perf_counter()
        out = eng.join_batch(qs[0])
        t_failover = time.perf_counter() - t0
    bitwise *= _bitwise(out, oracle[0])
    if eng.health.failed != frozenset({victim}):
        raise AssertionError(
            f"failover did not mark shard {victim}: {eng.health.failed}")
    if eng.coverage_degraded:
        raise AssertionError(
            "r=2 lost one shard but reported degraded coverage — "
            "replica placement must keep every pivot group covered")
    t0 = time.perf_counter()
    for q, want in zip(qs, oracle):          # steady failed-over serving
        bitwise *= _bitwise(eng.join_batch(q), want)
    t_failed_over = (time.perf_counter() - t0) / batches

    t0 = time.perf_counter()
    eng.recover(wait=True)
    t_recover = time.perf_counter() - t0
    if eng.health.failed:
        raise AssertionError("recover(wait=True) left shards failed")
    bitwise *= _bitwise(eng.join_batch(qs[1]), oracle[1])

    per_shard_r2 = eng.nbytes_per_shard()
    per_shard_r1 = index.shard_packing(n_sh).nbytes_per_shard()

    # ---- arm 2: r=1, certified degraded coverage --------------------
    e1 = ShardedMegastepEngine(index, cfg, n_shards=n_sh, replication=1)
    with FaultPlan().fail(
            "sharded.shard_compute", times=1,
            exc=ShardFault("sharded.shard_compute", shard=victim)):
        d1, i1, rb = e1.join_batch_covered(qs[0])
    coverage = e1.coverage_fraction()
    q0 = qs[0].astype(np.float64)
    s64 = s.astype(np.float64)
    dmat = np.sqrt(np.maximum(
        (q0 * q0).sum(1)[:, None] + (s64 * s64).sum(1)[None, :]
        - 2.0 * (q0 @ s64.T), 0.0))
    true_ids = np.argsort(dmat, axis=1, kind="stable")[:, :k]
    true_recall = np.array([
        len(set(i1[r].tolist()) & set(true_ids[r].tolist())) / k
        for r in range(q0.shape[0])])
    if not (true_recall >= rb - 1e-6).all():
        worst = int(np.argmin(true_recall - rb))
        raise AssertionError(
            f"degraded recall bound unsound: query {worst} certified "
            f"{rb[worst]:.3f} but true recall {true_recall[worst]:.3f}")

    # ---- arm 3: scheduler failover, deadline invariant --------------
    sj = StreamJoinEngine(index, cfg, megastep=True, n_shards=n_sh,
                          replication=2)
    sched = ServeScheduler(sj, config=SchedulerConfig(max_inflight=2))
    sched.join_now(qs[0])                                    # warm
    with FaultPlan().fail(
            "sharded.collective", times=1,
            exc=ShardFault("sharded.collective", shard=victim)):
        t = sched.join_now(qs[1], deadline_s=120.0)
    if not t.done or t.degraded:
        raise AssertionError(
            f"scheduler failover ticket ended {t.status!r} "
            f"(degraded={t.degraded}) — r=2 failover must stay exact")
    bitwise *= float(np.array_equal(t.distances, oracle[1][0])
                     and np.array_equal(t.indices, oracle[1][1]))

    return [
        Row("kernel_shard_failover",
            f"ns={n_s}x{dim},k={k},batch={batch},shards={n_sh},r=2",
            t_failover,
            {"n_shards": float(n_sh),
             "healthy_batch_s": t_healthy,
             "failover_s": t_failover,
             "failed_over_batch_s": t_failed_over,
             "recover_s": t_recover,
             "replication_hbm_ratio": float(per_shard_r2.sum())
             / float(max(per_shard_r1.sum(), 1)),
             "degraded_coverage_frac": float(coverage),
             "recall_bound_min": float(rb.min()),
             "recall_bound_mean": float(rb.mean()),
             "frac_fully_certified": float((rb == 1.0).mean()),
             "scheduler_failovers": float(sched.snapshot().n_failovers),
             "n_expired_dispatched_failover":
                 float(sched.snapshot().n_expired_dispatched),
             "failover_bitwise_equal": bitwise}),
    ]


def pack_send_buffers_bench(n: int = 100_000) -> List[Row]:
    """Shuffle-packing throughput: vectorized lexsort+scatter vs the
    per-row loop, at n shuffled rows (dim=8, 8×8 device edges)."""
    from repro.core.distributed import _pack_send_buffers

    rng = np.random.default_rng(0)
    n_dev, dim = 8, 8
    rows = rng.normal(size=(n, dim)).astype(np.float32)
    aux = {"id": np.arange(n, dtype=np.int32)}
    dest = rng.integers(0, n_dev, n)
    src = (np.arange(n) * n_dev) // n
    cnt = np.zeros((n_dev, n_dev), np.int64)
    np.add.at(cnt, (src, dest), 1)
    cap = int(cnt.max())

    t0 = time.perf_counter()
    vb, vn, vv = _pack_send_buffers(rows, aux, dest, src, n_dev, n_dev, cap)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    lb, ln, lv = _pack_send_buffers_loop(rows, aux, dest, src,
                                         n_dev, n_dev, cap)
    t_loop = time.perf_counter() - t0
    if not ((vb == lb).all() and (vv == lv).all()
            and (vn["id"] == ln["id"]).all()):
        raise AssertionError("vectorized packing diverged from the loop")

    return [
        Row("kernel_pack_send_buffers", f"n={n},edges={n_dev}x{n_dev}",
            t_vec,
            {"loop_s": t_loop, "vectorized_s": t_vec,
             "speedup": t_loop / t_vec,
             "rows_per_s": n / t_vec}),
    ]


def serving_under_load_bench(n: int = 20000, batches: int = 8
                             ) -> List[Row]:
    """Open-loop latency/goodput of the serving scheduler
    (`serve.scheduler.ServeScheduler`) at 0.8× and 2× of measured
    saturation capacity — the ROADMAP's serving-runtime milestone.

    Arrivals are Poisson (with a bursty interactive/bulk mix) in
    *virtual* time; each executed batch advances the virtual clock by
    its real measured wall time, so the numbers reflect genuine service
    costs without the bench sleeping through real seconds. Guarded
    rows: p99 at 0.8× must stay bounded, goodput at 2× overload must
    stay nonzero (shedding + certified-approximate degradation engage
    instead of collapse), and ``deadline_violations_dispatched`` — the
    count of requests handed to an engine after their deadline — is a
    hard zero. An embedded bitwise gate pins the scheduler's exact path
    to the engine's own output.
    """
    from repro.core import JoinConfig, StreamJoinEngine, build_index
    from repro.serve.scheduler import (
        Arrival, LoadReport, Priority, SchedulerConfig, ServeScheduler,
        VirtualClock, poisson_times, run_open_loop)

    n_s, dim, k, req = n, 16, 8, 16
    batch_rows = 256
    s = _clustered(n_s, dim, seed=0)
    cfg = JoinConfig(k=k, n_pivots=64, n_groups=8, seed=3,
                     quantize="int8")
    index = build_index(s, cfg)
    engine = StreamJoinEngine(index, cfg, quantized=True)
    rng = np.random.default_rng(7)

    # bitwise gate: the scheduler's exact path is the engine verbatim —
    # on the synchronous path AND through the double-buffered
    # dispatch/finalize split
    probe = _clustered(64, dim, seed=99)
    gate = ServeScheduler(engine, degraded_engine=None)
    tk = gate.join_now(probe)
    gd, gi = engine.join_batch(probe)
    _check_agree(tk.distances, tk.indices, gd, gi,
                 "scheduler exact path vs engine")
    gate2 = ServeScheduler(engine, degraded_engine=None,
                           config=SchedulerConfig(max_inflight=2))
    tk2 = gate2.join_now(probe)
    if not (np.array_equal(tk2.distances, gd)
            and np.array_equal(tk2.indices, gi)):
        raise AssertionError(
            "double-buffered scheduler path diverged from the engine")

    # warm every pow2 coalescing bucket the runs can form, so measured
    # service times are steady-state, not trace time
    b = 16
    while b <= batch_rows:
        engine.join_batch(_clustered(b, dim, seed=50 + b))
        engine.megastep_engine.join_batch_approx(
            _clustered(b, dim, seed=70 + b))
        b *= 2

    # saturation capacity: exact full batches, steady state
    wq = _clustered(batch_rows, dim, seed=42)
    t0 = time.perf_counter()
    for _ in range(3):
        engine.join_batch(wq)
    t_batch = (time.perf_counter() - t0) / 3
    capacity_rows_s = batch_rows / t_batch
    deadline_s = 30.0 * t_batch
    total_rows = min(n_s, batches * 512)

    def one_run(load: float, rows_mult: int = 1, max_inflight: int = 1):
        vc = VirtualClock()
        sched = ServeScheduler(
            engine,
            config=SchedulerConfig(
                batch_rows=batch_rows,
                degrade_queued_rows=2 * batch_rows,
                shed_queued_rows=6 * batch_rows,
                max_queued_rows=10 * batch_rows,
                default_deadline_s=deadline_s,
                max_inflight=max_inflight),
            clock=vc.now, sleep=vc.advance)
        rate = load * capacity_rows_s / req
        duration = rows_mult * total_rows / (load * capacity_rows_s)
        times = poisson_times(rate, duration, rng)
        arrivals = [Arrival(t=float(t),
                            rows=_clustered(req, dim, seed=1000 + j),
                            priority=(Priority.BULK if j % 4 == 0
                                      else Priority.INTERACTIVE),
                            deadline_s=(4 * deadline_s if j % 4 == 0
                                        else deadline_s))
                    for j, t in enumerate(times)]
        tickets = run_open_loop(sched, arrivals, vc)
        # snapshot(): locked, immutable copy — never read .stats live
        st = sched.snapshot()
        return LoadReport.from_tickets(tickets, st), st

    rep08, st08 = one_run(0.8)
    # the overload run is longer (same wall cost — excess rows shed):
    # the backlog needs time to cross the degrade/shed watermarks, which
    # is the regime this row exists to measure
    rep20, st20 = one_run(2.0, rows_mult=3)
    # double-buffered dispatch (max_inflight=2): batch N's device pass
    # overlaps batch N+1's formation+dispatch — same arrival processes
    # (fresh rng streams), deadline re-check still enforced at the
    # dispatch instant (the hard-zero below covers these runs too)
    rep08p, st08p = one_run(0.8, max_inflight=2)
    rep20p, st20p = one_run(2.0, rows_mult=3, max_inflight=2)

    # ---- tracing arm: the flight recorder rides the same hot path ----
    # steady-state engine batches, min-of-reps, untraced vs traced: the
    # fractional overhead the always-on instrumentation plus an
    # *enabled* tracer costs (guarded ≤ 5%)
    import jax

    from repro import obs

    def loop_time(reps: int = 3, inner: int = 4) -> float:
        best = float("inf")
        for _ in range(reps):
            lt0 = time.perf_counter()
            for _ in range(inner):
                engine.join_batch(wq)
            best = min(best, (time.perf_counter() - lt0) / inner)
        return best

    t_plain = loop_time()
    with obs.capture(capacity=1 << 18) as tr:
        t_traced = loop_time()
        # traced steady state stays zero-sync: same device-level loop
        # the megastep bench pins, now with the tracer installed — span
        # recording must not fetch anything
        me = engine.megastep_engine
        qd, nv = me.enqueue(wq)
        jax.block_until_ready(me.join_batch_device(qd, nv))
        with _fetch_counter() as fc, jax.transfer_guard("disallow"):
            jax.block_until_ready(me.join_batch_device(qd, nv))
        traced_syncs = fc.count
        # one traced scheduler run → the Perfetto-loadable CI artifact
        tr.clear()
        one_run(0.8)
        obs.write_chrome_trace(tr.spans(), "bench-serving-trace.json")
    trace_overhead_frac = max(
        0.0, t_traced / max(t_plain, 1e-12) - 1.0)
    if traced_syncs:
        raise AssertionError(
            f"traced steady state fetched {traced_syncs} arrays — "
            f"instrumentation broke the zero-sync invariant")

    return [
        Row("kernel_serving_under_load",
            f"ns={n_s}x{dim},k={k},req={req},batch={batch_rows}",
            rep08.p99_s,
            {"capacity_rows_s": capacity_rows_s,
             "p50_0p8x_s": rep08.p50_s,
             "p99_0p8x_s": rep08.p99_s,
             "p999_0p8x_s": rep08.p999_s,
             "goodput_0p8x_rows_s": rep08.goodput_rows_s,
             "shed_rate_0p8x": rep08.shed_rate,
             "p50_2x_s": rep20.p50_s,
             "goodput_2x_rows_s": rep20.goodput_rows_s,
             "shed_rate_2x": rep20.shed_rate,
             "degraded_frac_2x": rep20.degraded_frac,
             "recall_bound_min_2x": rep20.recall_bound_min,
             "p99_0p8x_pipelined_s": rep08p.p99_s,
             "goodput_0p8x_pipelined_rows_s": rep08p.goodput_rows_s,
             "goodput_2x_pipelined_rows_s": rep20p.goodput_rows_s,
             "pipeline_goodput_2x_ratio":
                 rep20p.goodput_rows_s / max(rep20.goodput_rows_s, 1e-9),
             "deadline_violations_dispatched": float(
                 st08.n_expired_dispatched + st20.n_expired_dispatched
                 + st08p.n_expired_dispatched + st20p.n_expired_dispatched),
             "trace_overhead_frac": trace_overhead_frac,
             "traced_steady_state_syncs": float(traced_syncs),
             "bitwise_equal": 1.0}),
    ]


ALL = [distance_topk_bench, distance_topk_gather_bench,
       index_build_vs_batch_plan_bench, streaming_vs_oneshot_bench,
       megastep_vs_hostplanned_bench, sharded_vs_single_bench,
       shard_failover_bench, mutable_index_bench,
       quant_coarse_vs_fp32_bench, serving_under_load_bench,
       pack_send_buffers_bench, assign_bench, flash_attention_bench]
