"""Kernel micro-benchmarks: jnp reference path wall-time on host + the
roofline-relevant derived quantities. (Pallas runs interpret-mode on CPU,
so wall-time here benchmarks the *reference*; kernel perf is assessed
structurally via the dry-run HLO — see EXPERIMENTS.md §Roofline.)"""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import Row


def _bench(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def distance_topk_bench() -> List[Row]:
    rows = []
    rng = np.random.default_rng(0)
    for (nr, ns, d, k) in [(1024, 8192, 10, 10), (4096, 16384, 2, 10)]:
        r = jnp.asarray(rng.normal(size=(nr, d)).astype(np.float32))
        s = jnp.asarray(rng.normal(size=(ns, d)).astype(np.float32))
        secs = _bench(ops.distance_topk, r, s, k, impl="ref")
        flops = 2.0 * nr * ns * d
        rows.append(Row("kernel_distance_topk", f"{nr}x{ns}x{d},k={k}",
                        secs, {"gflops_s": flops / secs / 1e9}))
    return rows


def assign_bench() -> List[Row]:
    rng = np.random.default_rng(1)
    rows = []
    for (n, m, d) in [(65536, 256, 10), (16384, 1024, 2)]:
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        p = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        secs = _bench(ops.assign, x, p, impl="ref")
        rows.append(Row("kernel_assign", f"{n}x{m}x{d}", secs,
                        {"gflops_s": 2.0 * n * m * d / secs / 1e9}))
    return rows


def flash_attention_bench() -> List[Row]:
    rng = np.random.default_rng(2)
    rows = []
    for (b, t, h, kvh, dh) in [(1, 1024, 8, 2, 64)]:
        q = jnp.asarray(rng.normal(size=(b, t, h, dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, t, kvh, dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, t, kvh, dh)).astype(np.float32))
        secs = _bench(ops.flash_attention, q, k, v, impl="ref")
        flops = 4.0 * b * h * t * t * dh
        rows.append(Row("kernel_flash_attention", f"b{b}t{t}h{h}", secs,
                        {"gflops_s": flops / secs / 1e9}))
    return rows


ALL = [distance_topk_bench, assign_bench, flash_attention_bench]
