"""Benchmark regression guard for CI.

Compares the key perf-contract metrics of a fresh ``benchmarks.run
--json`` record against a committed baseline and fails loudly on a >2×
regression. The guarded rows are the ones this repo's serving-path
claims rest on:

* ``kernel_streaming_vs_oneshot`` / ``overhead_frac`` — the megastep
  acceptance metric (streaming must stay near one-shot cost);
* ``kernel_index_build_amortization`` / ``plan_frac_of_batch`` — the
  host planner's per-batch share;
* ``kernel_megastep_vs_hostplanned`` / ``speedup`` — fused megastep vs
  host-planned per-batch latency;
* ``kernel_megastep_vs_hostplanned`` / ``device_steady_state_syncs`` —
  hard invariant: the device-level steady state performs **zero** host
  syncs, any nonzero value fails regardless of the baseline.
* ``kernel_sharded_vs_single`` / ``bitwise_equal`` and
  ``sharded_steady_state_syncs`` — the sharded megastep's contract:
  shard count never changes the output (bitwise, HARD_ONE) and the
  mesh-partitioned steady state moves zero bytes per shard (HARD_ZERO);
  ``shard_speedup`` is guarded loosely (simulated-mesh timing is
  noise).
* ``kernel_quant_coarse_vs_fp32`` / ``bytes_per_row_int8``,
  ``coarse_speedup`` and ``endtoend_speedup`` — the quantized tier's
  memory, coarse-pass and tuned end-to-end contracts (repro.quant);
  ``resident_steady_state_syncs`` is the hard-zero twin of the fp32
  megastep's sync invariant, on the device-resident re-rank path;
* ``kernel_quant_coarse_vs_fp32`` / ``bitwise_equal`` — hard invariant:
  the quantized path must be bitwise the fp32 oracle's output; anything
  but 1.0 fails regardless of the baseline (the bench itself also
  raises on divergence, this guards a silently-edited record).
* ``kernel_serving_under_load`` — the serving scheduler's overload
  contract: bounded ``p99_0p8x_s``, non-collapsing
  ``goodput_2x_rows_s``, ``bitwise_equal`` on the exact path, and the
  hard-zero ``deadline_violations_dispatched`` invariant (no request is
  ever dispatched to a device after its deadline).
* ``kernel_shard_failover`` — the fault-tolerance contract
  (mesh runs only; the row is absent on a 1-device sweep):
  ``failover_bitwise_equal`` (HARD_ONE — losing a shard with a live
  replica never changes a bit, through failover, degraded-view serving
  and recovery) and ``n_expired_dispatched_failover`` (HARD_ZERO — the
  deadline re-check at the failover instant holds).

Baselines: ``BENCH_kernels.json`` records the full-size sweep;
``BENCH_kernels_fast.json`` records the ``--fast`` (CI-sized) sweep —
compare like against like, the metrics are workload-size dependent.

Usage:  python -m benchmarks.guard --baseline BENCH_kernels_fast.json \
            --current bench-fast.json
"""
from __future__ import annotations

import argparse
import json
import sys

# (bench row, metric, direction): "lower" metrics regress by growing,
# "higher" metrics regress by shrinking. ``slack`` is an absolute
# allowance on top of the 2× ratio so near-zero baselines don't turn
# CI-machine noise into failures.
CHECKS = [
    # overhead_frac is clamped at 0 in the bench (the megastep is
    # routinely faster than one-shot; a negative baseline made the 2x
    # ratio meaningless) — the absolute streaming_s row carries the
    # real regression signal
    ("kernel_streaming_vs_oneshot", "overhead_frac", "lower", 0.10),
    ("kernel_streaming_vs_oneshot", "streaming_s", "lower", 0.05),
    ("kernel_index_build_amortization", "plan_frac_of_batch", "lower", 0.05),
    ("kernel_megastep_vs_hostplanned", "speedup", "higher", 2.0),
    # sharded megastep vs single-device: the speedup on a simulated mesh
    # is thread-oversubscribed noise (the real gates are the bitwise and
    # hard-zero rows below), so the slack is generous — this row only
    # catches a wholesale collapse of the sharded dispatch path
    ("kernel_sharded_vs_single", "shard_speedup", "higher", 1.0),
    # quantized tier: resident bytes/row must not bloat (>2× = someone
    # fattened the codes/metadata), the coarse pass must not collapse,
    # and the tuned engine's end-to-end path must never lose to the
    # plain fp32 megastep beyond noise (the autotuner's whole job)
    ("kernel_quant_coarse_vs_fp32", "bytes_per_row_int8", "lower", 1.0),
    ("kernel_quant_coarse_vs_fp32", "coarse_speedup", "higher", 0.05),
    ("kernel_quant_coarse_vs_fp32", "endtoend_speedup", "higher", 0.05),
    # mutable index: steady-state insert+seal throughput (first-seal
    # trace cost is reported separately and not guarded)
    ("kernel_mutable_index", "insert_rows_per_s", "higher", 1000.0),
    # serving runtime (serve.scheduler): p99 at 0.8× saturation must
    # stay bounded (absolute slack absorbs CI timer noise on a ~10ms
    # metric) on both the sync and double-buffered paths, and goodput
    # under 2× overload must not collapse — the degradation ladder is
    # supposed to shed/degrade, not stall
    ("kernel_serving_under_load", "p99_0p8x_s", "lower", 0.10),
    ("kernel_serving_under_load", "p99_0p8x_pipelined_s", "lower", 0.10),
    ("kernel_serving_under_load", "goodput_2x_rows_s", "higher", 100.0),
    ("kernel_serving_under_load", "goodput_2x_pipelined_rows_s",
     "higher", 100.0),
    # flight recorder (repro.obs): an *enabled* tracer on the serving
    # hot path may cost at most 5% per steady-state batch (the baseline
    # value is ~0, so the 2× ratio is vacuous and the absolute slack is
    # the binding limit: max(base,0)*2 + 0.05)
    ("kernel_serving_under_load", "trace_overhead_frac", "lower", 0.05),
]
HARD_ZERO = [("kernel_megastep_vs_hostplanned", "device_steady_state_syncs"),
             # the int8 tier's device-resident re-rank restores the same
             # invariant: zero host syncs between enqueue and fetch
             ("kernel_quant_coarse_vs_fp32", "resident_steady_state_syncs"),
             # ...and the sharded megastep keeps it per shard: the whole
             # mesh-partitioned payload is committed at enqueue/refresh
             ("kernel_sharded_vs_single", "sharded_steady_state_syncs"),
             # a request whose deadline passed may NEVER reach a device:
             # the scheduler sheds at batch formation and re-checks
             # across retry backoff — any nonzero count is a policy bug
             ("kernel_serving_under_load", "deadline_violations_dispatched"),
             # tracing must never add a host sync to the fused device
             # path: the same enqueue→device-step loop the megastep row
             # pins, re-measured with the flight recorder installed
             ("kernel_serving_under_load", "traced_steady_state_syncs"),
             # the same invariant across shard failover: the scheduler
             # re-checks deadlines at the failover instant, so a request
             # whose deadline passed during the failure window is shed,
             # never re-dispatched (kernel_bench.shard_failover_bench)
             ("kernel_shard_failover", "n_expired_dispatched_failover")]
# metrics that must be exactly 1.0 in the current sweep, baseline or not
HARD_ONE = [("kernel_quant_coarse_vs_fp32", "bitwise_equal"),
            # the scheduler's exact (non-degraded) path is the engine
            # verbatim — bitwise, not approximately
            ("kernel_serving_under_load", "bitwise_equal"),
            # shard count must never change the output — the sharded
            # megastep's whole contract (core.sharded module docstring)
            ("kernel_sharded_vs_single", "bitwise_equal"),
            # ...and neither may losing a shard while a live replica
            # remains: r=2 failover, post-failover serving, and
            # post-recovery serving are all bitwise the single-device
            # engine (shard_failover_bench folds every gate into this)
            ("kernel_shard_failover", "failover_bitwise_equal")]


def _rows(records: list, bench: str) -> list:
    return [r for r in records if r.get("bench") == bench]


def check(baseline: list, current: list, *,
          subset: bool = False) -> list[str]:
    """Returns a list of human-readable failure messages (empty = pass).

    ``subset=True`` is for guarding a ``--only``-filtered sweep (the CI
    mesh steps): benches absent from the current record are simply not
    compared instead of counting as crashed — the ratio CHECKS still
    apply to rows that are present, and the HARD_ZERO / HARD_ONE
    invariants always apply to every current row.
    """
    failures = []
    for bench, metric, direction, slack in CHECKS:
        base_rows = _rows(baseline, bench)
        cur_rows = _rows(current, bench)
        if not base_rows:
            continue   # metric not in the committed baseline yet
        if not cur_rows:
            if not subset:
                failures.append(
                    f"{bench}: row missing from the current sweep (the "
                    f"bench crashed or was removed) — baseline has it")
            continue
        if metric not in base_rows[0]:
            continue   # metric newer than the committed baseline
        if metric not in cur_rows[0]:
            failures.append(
                f"{bench}.{metric} missing from the current sweep — the "
                f"baseline records it, so the bench stopped reporting a "
                f"guarded metric")
            continue
        base = float(base_rows[0][metric])
        cur = float(cur_rows[0][metric])
        if direction == "lower":
            # a negative baseline (streaming faster than one-shot) would
            # make the 2x ratio nonsensical — clamp at 0 so the limit is
            # always "at most 2x the (non-negative) baseline + slack"
            limit = max(base, 0.0) * 2.0 + slack
            if cur > limit:
                failures.append(
                    f"{bench}.{metric} regressed: {cur:.4f} vs baseline "
                    f"{base:.4f} (limit {limit:.4f} = 2x + {slack} slack). "
                    f"Lower is better here — the per-batch overhead the "
                    f"megastep is supposed to keep down has grown >2x.")
        else:
            limit = max(base / 2.0 - slack, 0.0)
            if cur < limit:
                failures.append(
                    f"{bench}.{metric} regressed: {cur:.4f} vs baseline "
                    f"{base:.4f} (limit {limit:.4f} = baseline/2). Higher "
                    f"is better here — the megastep speedup collapsed.")
    for bench, metric in HARD_ZERO:
        for row in _rows(current, bench):
            if float(row.get(metric, 0.0)) != 0.0:
                failures.append(
                    f"{bench}.{metric} = {row[metric]} — the megastep "
                    f"steady state must perform zero host syncs; something "
                    f"reintroduced a device→host round-trip.")
    for bench, metric in HARD_ONE:
        for row in _rows(current, bench):
            # a MISSING key fails too: this is exactly the
            # silently-edited-record case the invariant exists for
            if float(row.get(metric, 0.0)) != 1.0:
                failures.append(
                    f"{bench}.{metric} = {row.get(metric, '<missing>')} — "
                    f"this row's contract is bitwise equality with the "
                    f"exact oracle path; an inexact (or unreported) "
                    f"result is a correctness bug, not a perf regression.")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed JSON record (match the sweep size: "
                         "BENCH_kernels_fast.json for --fast runs)")
    ap.add_argument("--current", required=True,
                    help="fresh benchmarks.run --json output")
    ap.add_argument("--subset", action="store_true",
                    help="the current record is an --only-filtered sweep: "
                         "don't treat benches it never ran as crashed")
    args = ap.parse_args()
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.current) as fh:
        current = json.load(fh)
    failures = check(baseline, current, subset=args.subset)
    if failures:
        print("benchmark regression guard FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("benchmark regression guard: all key rows within 2x of baseline")


if __name__ == "__main__":
    main()
