"""Benchmarks reproducing every paper table/figure (§6), scaled to one
host. Each function returns a list of Rows; run.py prints the CSV.

Paper experiment → function index
  Table 2  partition-size stats per pivot strategy/count → table2_partition_stats
  Table 3  group-size stats                              → table3_group_stats
  Fig 6    execution time vs (strategy × #pivots)        → fig6_tuning
  Fig 7    selectivity & replication vs #pivots          → fig7_selectivity_replication
  Fig 8    effect of k (Forest-like)                     → fig8_effect_k_forest
  Fig 9    effect of k (OSM-like)                        → fig9_effect_k_osm
  Fig 10   effect of dimensionality                      → fig10_dimensionality
  Fig 11   scalability with data size                    → fig11_scalability
  Fig 12   speedup with #nodes                           → fig12_speedup
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import (
    JoinConfig, brute_force_knn, hbrj_join, knn_join, pbj_join, plan_join,
    select_pivots, assign_to_pivots)
from repro.data import expand_dataset
from .common import Row, default_forest, default_osm, timed


def table2_partition_stats(n=20000, pivot_counts=(64, 128, 256, 512)
                           ) -> List[Row]:
    data = default_forest(n)
    rows = []
    for strategy in ("random", "farthest", "kmeans"):
        for m in pivot_counts:
            (pivots), secs = timed(
                select_pivots, data, m, strategy, sample=4096, seed=1)
            part, _ = assign_to_pivots(data, pivots)
            counts = np.bincount(part, minlength=m)
            rows.append(Row(
                "table2_partition_stats", f"{strategy},M={m}", secs,
                {"min": counts.min(), "max": counts.max(),
                 "avg": counts.mean(), "dev": counts.std()}))
    return rows


def table3_group_stats(n=20000, pivot_counts=(64, 128, 256),
                       n_groups=9) -> List[Row]:
    data = default_forest(n)
    rows = []
    for strategy in ("random", "farthest", "kmeans"):
        for m in pivot_counts:
            cfg = JoinConfig(k=10, n_pivots=m, n_groups=n_groups,
                             pivot_strategy=strategy, grouping="geometric")
            plan, secs = timed(plan_join, data, data, cfg)
            sizes = np.bincount(plan.group_of_r(), minlength=n_groups)
            rows.append(Row(
                "table3_group_stats", f"{strategy},M={m}", secs,
                {"min": sizes.min(), "max": sizes.max(),
                 "avg": sizes.mean(), "dev": sizes.std()}))
    return rows


def fig6_tuning(n=12000, pivot_counts=(64, 128, 256)) -> List[Row]:
    """Execution time by phase for the 6 strategy combinations (RGE, FGE,
    KGE, RGR, FGR, KGR)."""
    data = default_forest(n)
    rows = []
    combos = [(p, g) for p in ("random", "farthest", "kmeans")
              for g in ("geometric", "greedy")]
    for pivot_s, group_s in combos:
        for m in pivot_counts:
            tag = f"{pivot_s[0].upper()}G{group_s[0].upper()},M={m}"
            cfg = JoinConfig(k=10, n_pivots=m, n_groups=9,
                             pivot_strategy=pivot_s, grouping=group_s)
            plan, t_plan = timed(plan_join, data, data, cfg)
            res, t_join = timed(knn_join, data, data, config=cfg, plan=plan)
            rows.append(Row(
                "fig6_tuning", tag, t_plan + t_join,
                {"plan_s": t_plan, "join_s": t_join,
                 "selectivity": res.stats.selectivity}))
    return rows


def fig7_selectivity_replication(n=12000, pivot_counts=(32, 64, 128, 256)
                                 ) -> List[Row]:
    data = default_forest(n)
    rows = []
    for grouping in ("geometric", "greedy"):
        for m in pivot_counts:
            cfg = JoinConfig(k=10, n_pivots=m, n_groups=9, grouping=grouping)
            res, secs = timed(knn_join, data, data, config=cfg)
            rows.append(Row(
                "fig7_selectivity_replication", f"{grouping},M={m}", secs,
                {"selectivity": res.stats.selectivity,
                 "avg_replicas": res.stats.replicas_s / n,
                 "tile_selectivity": res.stats.tile_selectivity}))
    return rows


def _three_way(data, k, n_reducers=9, m=128):
    cfg = JoinConfig(k=k, n_pivots=m, n_groups=n_reducers)
    pgbj, t_pgbj = timed(knn_join, data, data, config=cfg)
    pbj, t_pbj = timed(pbj_join, data, data, k,
                       JoinConfig(k=k, n_pivots=m), n_reducers=n_reducers)
    hbrj, t_hbrj = timed(hbrj_join, data, data, k, n_reducers=n_reducers)
    return (pgbj, t_pgbj), (pbj, t_pbj), (hbrj, t_hbrj)


def fig8_effect_k_forest(n=8000, ks=(10, 20, 30, 40, 50)) -> List[Row]:
    data = default_forest(n)
    rows = []
    for k in ks:
        (pg, tg), (pb, tb), (hb, th) = _three_way(data, k)
        rows.append(Row("fig8_effect_k_forest", f"k={k}", tg + tb + th, {
            "pgbj_s": tg, "pbj_s": tb, "hbrj_s": th,
            "pgbj_sel": pg.stats.selectivity,
            "pbj_sel": pb.stats.selectivity,
            "hbrj_sel": hb.stats.selectivity,
            "pgbj_shuffle": pg.stats.shuffle_tuples,
            "pbj_shuffle": pb.stats.shuffle_tuples,
            "hbrj_shuffle": hb.stats.shuffle_tuples}))
    return rows


def fig9_effect_k_osm(n=8000, ks=(10, 30, 50)) -> List[Row]:
    data = default_osm(n)
    rows = []
    for k in ks:
        (pg, tg), (pb, tb), (hb, th) = _three_way(data, k)
        rows.append(Row("fig9_effect_k_osm", f"k={k}", tg + tb + th, {
            "pgbj_s": tg, "pbj_s": tb, "hbrj_s": th,
            "pgbj_sel": pg.stats.selectivity,
            "hbrj_sel": hb.stats.selectivity}))
    return rows


def fig10_dimensionality(n=8000, dims=(2, 4, 6, 8, 10)) -> List[Row]:
    rows = []
    for d in dims:
        data = default_forest(n, dim=d, seed=d)
        (pg, tg), (pb, tb), (hb, th) = _three_way(data, 10)
        rows.append(Row("fig10_dimensionality", f"dim={d}", tg + tb + th, {
            "pgbj_s": tg, "pbj_s": tb, "hbrj_s": th,
            "pgbj_sel": pg.stats.selectivity,
            "pgbj_shuffle": pg.stats.shuffle_tuples,
            "hbrj_shuffle": hb.stats.shuffle_tuples}))
    return rows


def fig11_scalability(base_n=4000, factors=(1, 2, 4)) -> List[Row]:
    base = default_forest(base_n)
    rows = []
    for t in factors:
        data = expand_dataset(base, t, seed=0) if t > 1 else base
        (pg, tg), (pb, tb), (hb, th) = _three_way(data, 10)
        rows.append(Row("fig11_scalability", f"x{t}", tg + tb + th, {
            "n": data.shape[0],
            "pgbj_s": tg, "pbj_s": tb, "hbrj_s": th,
            "pgbj_sel": pg.stats.selectivity,
            "hbrj_sel": hb.stats.selectivity,
            "pgbj_shuffle": pg.stats.shuffle_tuples}))
    return rows


def fig12_speedup(n=12000, nodes=(4, 9, 16, 36)) -> List[Row]:
    """Simulated cluster speedup: makespan = max per-group work."""
    data = default_forest(n)
    rows = []
    for nn in nodes:
        cfg = JoinConfig(k=10, n_pivots=128, n_groups=nn)
        plan, _ = timed(plan_join, data, data, cfg)
        res, secs = timed(knn_join, data, data, config=cfg, plan=plan)
        # per-group work = |R_g| × |S_g| (pairs before pruning)
        g_r = plan.group_of_r()
        work = []
        for g in range(plan.n_groups):
            rg = (g_r == g).sum()
            sg = plan.s_replica_mask(g).sum()
            work.append(rg * sg)
        total, mx = float(np.sum(work)), float(np.max(work))
        rows.append(Row("fig12_speedup", f"nodes={nn}", secs, {
            "sim_speedup": total / mx if mx else 0.0,
            "ideal": nn,
            "efficiency": (total / mx) / nn if mx else 0.0,
            "shuffle": res.stats.shuffle_tuples}))
    return rows


ALL = [
    table2_partition_stats,
    table3_group_stats,
    fig6_tuning,
    fig7_selectivity_replication,
    fig8_effect_k_forest,
    fig9_effect_k_osm,
    fig10_dimensionality,
    fig11_scalability,
    fig12_speedup,
]
