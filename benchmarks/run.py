# One function per paper table. Print ``name,us_per_call,derived`` CSV;
# ``--json PATH`` additionally records the rows as a JSON list.
import argparse
import inspect
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark function names")
    ap.add_argument("--fast", action="store_true",
                    help="smaller sizes (CI mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON records to PATH")
    args = ap.parse_args()

    from . import kernel_bench, paper_tables, roofline
    from .common import HEADER

    fns = list(paper_tables.ALL) + list(kernel_bench.ALL) + list(roofline.ALL)
    if args.only:
        fns = [f for f in fns if args.only in f.__name__]

    print(HEADER)
    failures = 0
    records = []
    for fn in fns:
        try:
            kwargs = {}
            if args.fast:
                sig = inspect.signature(fn)
                if "n" in sig.parameters:
                    kwargs["n"] = 3000
                if "base_n" in sig.parameters:
                    kwargs["base_n"] = 1500
                # index-build / streaming benches: fewer micro-batches
                if "batches" in sig.parameters:
                    kwargs["batches"] = 3
            for row in fn(**kwargs):
                print(row.csv(), flush=True)
                # numpy scalars (int64/float32) are not JSON serializable
                records.append({"bench": row.bench, "params": row.params,
                                "seconds": float(row.seconds),
                                **{k: float(v)
                                   for k, v in row.derived.items()}})
        except Exception:  # noqa: BLE001 — keep the suite going
            failures += 1
            print(f"# FAILED {fn.__name__}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(records, fh, indent=2)
            fh.write("\n")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
