"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import JoinConfig, knn_join, plan_join
from repro.core.api import JoinPlan
from repro.data import expand_dataset, forest_like, osm_like


@dataclasses.dataclass
class Row:
    bench: str
    params: str
    seconds: float
    derived: Dict[str, float]

    def csv(self) -> str:
        d = ";".join(f"{k}={v:.6g}" for k, v in self.derived.items())
        return f"{self.bench},{self.params},{self.seconds * 1e6:.1f},{d}"


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def default_forest(n: int = 20000, dim: int = 10, seed: int = 0):
    """Stand-in for 'Forest×10' at laptop scale (paper: 5.8M × 10 attrs)."""
    return forest_like(n, dim, seed)


def default_osm(n: int = 20000, seed: int = 0):
    return osm_like(n, seed)


HEADER = "name,us_per_call,derived"
