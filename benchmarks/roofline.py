"""Roofline table assembly: reads the dry-run sweeps (results/*.json) and
prints the per-(arch × shape × mesh) three-term roofline with bottleneck
and useful-flop ratio. Does not compile anything itself — run
``python -m repro.launch.dryrun --all [--multi-pod] --out …`` first."""
from __future__ import annotations

import json
import os
from typing import List

from .common import Row

RESULTS = ("results/dryrun_single_pod.json", "results/dryrun_multi_pod.json")


def roofline_rows() -> List[Row]:
    rows = []
    for path in RESULTS:
        if not os.path.exists(path):
            continue
        data = json.load(open(path))
        for rec in data.get("results", []):
            r = rec["roofline"]
            rows.append(Row(
                "roofline", f"{rec['arch']}:{rec['shape']}@{rec['mesh']}",
                max(r["compute_s"], r["memory_s"], r["collective_s"]),
                {
                    "compute_s": r["compute_s"],
                    "memory_s": r["memory_s"],
                    "collective_s": r["collective_s"],
                    "bottleneck": {"compute_s": 0, "memory_s": 1,
                                   "collective_s": 2}[r["bottleneck"]],
                    "useful_ratio": rec["useful_flop_ratio"],
                    "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
                    "fits_16g": int(rec["memory"].get("fits_hbm_16g", 0)),
                }))
    return rows


ALL = [roofline_rows]
