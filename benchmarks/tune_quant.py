"""Regenerate the quantized tier's tuning table (`repro.quant.autotune`).

Sweeps fp32-vs-int8 (and the int8 shortlist size ``mp``) at the corpus
shapes the benches and the serving bench actually hit, and writes the
winners to the committed table (``src/repro/quant/TUNE_quant.json`` by
default, override with ``REPRO_QUANT_TUNE_TABLE``). CI never sweeps —
it ships this artifact; rerun this module when the kernels, the
hardware, or the bench shapes change:

    PYTHONPATH=src python -m benchmarks.tune_quant [--out PATH] [--fast]

The table is keyed on ``(backend, dim, pow2-bucketed n_rows, k)``, so
one run on a CPU host and one on a TPU host can share a file — entries
for other backends are preserved, only the current backend's cells are
refreshed.
"""
from __future__ import annotations

import argparse
import os
import time


# (dim, n_rows, k) cells to sweep: the kernel_quant_coarse_vs_fp32 bench
# shape (full + --fast size) and the serving_under_load bench shape.
SHAPES = [
    (32, 20000, 10),   # quant bench, full sweep
    (32, 3000, 10),    # quant bench, --fast (CI) sweep
    (16, 20000, 8),    # serving bench, full sweep
    (16, 3000, 8),     # serving bench, --fast (CI) sweep
]


def main() -> None:
    import jax

    from repro.core import JoinConfig, build_index
    from repro.data import clustered_like
    from repro.quant import autotune

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="table path (default: the committed "
                         "src/repro/quant/TUNE_quant.json)")
    ap.add_argument("--fast", action="store_true",
                    help="sweep only the CI-sized (n=3000) cells")
    ap.add_argument("--iters", type=int, default=3,
                    help="timing iterations per candidate (best-of)")
    args = ap.parse_args()

    path = args.out or autotune.default_table_path()
    backend = jax.default_backend()
    table = (autotune.TuningTable.load(path) if os.path.exists(path)
             else autotune.TuningTable())

    shapes = [s for s in SHAPES if not args.fast or s[1] <= 4096]
    for dim, n_rows, k in shapes:
        cfg = JoinConfig(k=k, n_pivots=64, n_groups=8, seed=3)
        s = clustered_like(n_rows, dim, seed=0)
        index = build_index(s, cfg)
        t0 = time.perf_counter()
        tuned = autotune.sweep_config(index, cfg, iters=args.iters)
        dt = time.perf_counter() - t0
        key = autotune.table_key(dim, n_rows, k, backend)
        table.entries[key] = tuned
        print(f"{key}: mode={tuned.mode} mp={tuned.mp or '-'} "
              f"int8={tuned.int8_batch_s * 1e3:.2f}ms "
              f"fp32={tuned.fp32_batch_s * 1e3:.2f}ms "
              f"(swept in {dt:.1f}s)")

    table.save(path)
    autotune.reset_default_table()
    print(f"wrote {len(table.entries)} entries to {path}")


if __name__ == "__main__":
    main()
