"""Distance-based outlier detection via kNN self-join — the paper's §1
motivating application (Knorr & Ng; LOF-style k-distance scores).

An object is an outlier if its distance to its k-th nearest neighbor is
large; one PGBJ self-join computes every object's score in one pass.

Run:  PYTHONPATH=src python examples/outlier_detection.py
"""
import numpy as np

from repro.core import JoinConfig, knn_join
from repro.data import forest_like


def main():
    rng = np.random.default_rng(0)
    data = forest_like(12000, dim=8, seed=0)
    # plant 20 outliers far outside the clusters
    outliers = rng.uniform(3000, 4000, (20, 8)).astype(np.float32)
    full = np.concatenate([data, outliers]).astype(np.float32)

    k = 10
    res = knn_join(full, full, config=JoinConfig(
        k=k + 1, n_pivots=128, n_groups=9))   # +1: self at distance 0
    k_dist = res.distances[:, -1]              # distance to k-th true NN

    thresh = np.quantile(k_dist[:len(data)], 0.999) * 2
    flagged = np.where(k_dist > thresh)[0]
    planted = set(range(len(data), len(full)))
    found = planted & set(flagged.tolist())
    print(f"self-join over {len(full)} objects, k={k}")
    print(f"  selectivity   : {res.stats.selectivity:.4f}")
    print(f"  flagged       : {len(flagged)} objects above 2×p99.9 k-distance")
    print(f"  planted found : {len(found)}/20")
    assert len(found) == 20, "all planted outliers must be detected"
    precision = len(found) / max(len(flagged), 1)
    print(f"  precision     : {precision:.2f}")
    print("outlier detection via one kNN join ✓")


if __name__ == "__main__":
    main()
