"""Paper §6 experiment in miniature: Forest-like self-join comparing
PGBJ / PBJ / H-BRJ on time, selectivity, and shuffling cost — then the
distributed (shard_map) execution of the same join on a host mesh.

Run:  PYTHONPATH=src python examples/forest_selfjoin.py [--n 20000]
"""
import argparse
import time

import numpy as np

from repro.core import (
    JoinConfig, brute_force_knn, hbrj_join, knn_join, pbj_join, plan_join)
from repro.data import forest_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    data = forest_like(args.n, 10, seed=0)
    k = args.k
    print(f"Forest-like self-join  n={args.n}  k={k}\n")
    print(f"{'method':8s} {'time_s':>8s} {'selectivity':>12s} {'shuffle':>10s}")

    cfg = JoinConfig(k=k, n_pivots=min(256, args.n // 50), n_groups=9)
    t0 = time.perf_counter()
    pgbj = knn_join(data, data, config=cfg)
    t_pgbj = time.perf_counter() - t0
    print(f"{'PGBJ':8s} {t_pgbj:8.2f} {pgbj.stats.selectivity:12.4f} "
          f"{pgbj.stats.shuffle_tuples:10d}")

    t0 = time.perf_counter()
    pbj = pbj_join(data, data, k, JoinConfig(k=k, n_pivots=cfg.n_pivots),
                   n_reducers=9)
    t_pbj = time.perf_counter() - t0
    print(f"{'PBJ':8s} {t_pbj:8.2f} {pbj.stats.selectivity:12.4f} "
          f"{pbj.stats.shuffle_tuples:10d}")

    t0 = time.perf_counter()
    hbrj = hbrj_join(data, data, k, n_reducers=9)
    t_hbrj = time.perf_counter() - t0
    print(f"{'H-BRJ':8s} {t_hbrj:8.2f} {hbrj.stats.selectivity:12.4f} "
          f"{hbrj.stats.shuffle_tuples:10d}")

    # exactness cross-check on a sample
    sample = np.random.default_rng(0).choice(args.n, 500, replace=False)
    bd, _ = brute_force_knn(data[sample], data, k)
    assert np.allclose(pgbj.distances[sample], bd, atol=1e-2)
    assert np.allclose(pbj.distances[sample], bd, atol=1e-2)
    assert np.allclose(hbrj.distances[sample], bd, atol=1e-2)
    print("\nall three methods exact ✓")


if __name__ == "__main__":
    main()
