"""Distributed PGBJ join over an SPMD device mesh with fault-tolerant
group execution (retries + speculative backup tasks).

Run:  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
          python examples/distributed_join.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core import JoinConfig, brute_force_knn, plan_join
from repro.core.distributed import distributed_knn_join
from repro.core.jax_compat import make_mesh
from repro.data import forest_like
from repro.distributed.fault import GroupExecutor, regroup


def main():
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    R = forest_like(4000, 8, seed=0)
    S = forest_like(6000, 8, seed=1)
    cfg = JoinConfig(k=10, n_pivots=64, n_groups=n_dev)
    plan = plan_join(R, S, cfg)
    mesh = make_mesh((n_dev,), ("data",))
    res = distributed_knn_join(R, S, plan, mesh)
    bd, _ = brute_force_knn(R, S, 10)
    assert np.allclose(res.distances, bd, atol=1e-2)
    print(f"distributed join exact on {n_dev}-device mesh ✓  "
          f"(replicas shipped: {res.stats.replicas_s})")

    # elastic: re-run on half the devices without re-planning phase 1
    half = n_dev // 2
    plan_h = regroup(plan, half)
    mesh_h = make_mesh((half,), ("data",))
    res_h = distributed_knn_join(R, S, plan_h, mesh_h)
    assert np.allclose(res_h.distances, bd, atol=1e-2)
    print(f"elastic shrink {n_dev}→{half} devices, still exact ✓")

    # fault-tolerant group execution with injected failures
    import threading
    fails = {1: 1}
    lock = threading.Lock()

    def group_fn(g):
        with lock:
            if fails.get(g, 0) > 0:
                fails[g] -= 1
                raise RuntimeError("injected node failure")
        mask = plan.s_replica_mask(g)
        return int(mask.sum())

    ex = GroupExecutor(max_retries=2, speculate=True)
    runs = ex.run(group_fn, list(range(plan.n_groups)))
    print("group execution with injected failure:",
          {g: (r.attempts, r.result) for g, r in sorted(runs.items())})
    print("fault-tolerant execution ✓")


if __name__ == "__main__":
    main()
