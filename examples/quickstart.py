"""Quickstart: exact kNN join in five lines, verified against brute force.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import JoinConfig, brute_force_knn, knn_join
from repro.data import forest_like


def main():
    # R ⋉ S: for every row of R, the k nearest rows of S
    R = forest_like(10000, dim=10, seed=0)
    S = forest_like(16000, dim=10, seed=1)
    cfg = JoinConfig(k=10, n_pivots=256, n_groups=9,
                     pivot_strategy="random", grouping="geometric")
    res = knn_join(R, S, config=cfg)

    bd, _ = brute_force_knn(R, S, 10)
    assert np.allclose(res.distances, bd, atol=1e-2)
    print(f"joined |R|={len(R)} × |S|={len(S)}, k=10  — exact ✓")
    print(f"  computation selectivity : {res.stats.selectivity:.4f}  (Eq. 13)")
    print(f"  shuffle tuples          : {res.stats.shuffle_tuples}"
          f"  (naive: {len(R) + cfg.n_groups * len(S)})")
    print(f"  avg replicas of S       : {res.stats.replicas_s / len(S):.2f}")
    print(f"  tile selectivity        : {res.stats.tile_selectivity:.4f}")


if __name__ == "__main__":
    main()
