"""Serving with kNN-LM retrieval: the paper's join as a serving feature.

A small LM serves batched requests; at each decode step the batch's hidden
states are joined (R ⋉ S, |R| = batch) against a datastore of key
embeddings using the PGBJ machinery, and the retrieval distribution is
interpolated with the LM head.

The datastore is mutable while it serves: between request waves new
(key, value) pairs are ingested with ``add_entries`` (they seal into a
delta segment — S-side phase 1 never re-runs on the existing base),
stale ones are tombstoned with ``remove_entries``, and ``compact()``
folds everything back into one base between decode steps.

Retrieval runs through a ``ServeScheduler`` (admission control +
deadlines), so the flight recorder's metrics registry fills up as the
demo serves — a live summary (qps, p99, shed/degraded fractions, the
paper's pruning selectivity) prints at exit.

Run:  PYTHONPATH=src python examples/serve_retrieval.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_reduced
from repro.models import ModelOptions, forward, init_params
from repro.serve import (
    BatchedServer, Datastore, KnnLMConfig, ServeConfig, ServeScheduler,
    interpolate, knn_logits)


def main():
    cfg = dataclasses.replace(get_reduced("llama3.2-3b"), vocab=512)
    opts = ModelOptions(dtype=jnp.float32, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    rng = np.random.default_rng(0)

    # build a datastore of (hidden state → next token) pairs from "corpus"
    corpus = rng.integers(0, cfg.vocab, (64, 48), dtype=np.int32)
    hs, _ = forward(params, cfg, jnp.asarray(corpus), opts=opts)
    # use final logits' pre-head hidden? for the demo: token embeddings of
    # contexts ≈ the model's own representations via the lm head weights
    keys = np.asarray(hs[:, :-1].reshape(-1, cfg.vocab))[:, :64]  # (N, 64)
    vals = corpus[:, 1:].reshape(-1)
    # S-side phase 1 runs once here; each decode step's hidden-state batch
    # is planned fresh against the resident index (no warmup queries).
    # quantized=True would serve the same bits from an int8-resident
    # index at ~4x less device memory (repro.quant)
    store = Datastore.build(keys, vals, k=8, n_pivots=64, n_groups=4)
    kcfg = KnnLMConfig(lam=0.3, tau=100.0, k=8)
    # retrieval through admission control: every decode step's join is a
    # scheduled request, so the obs metrics registry sees real serving
    # traffic (latency histogram, shed/degraded counters, §6 join stats)
    sched = ServeScheduler.for_datastore(store, kcfg.k)
    t_serve0 = time.perf_counter()

    def hook(logits, cache):
        q = np.asarray(logits)[:, :64]
        kl = knn_logits(q, store, kcfg, vocab=cfg.vocab,
                        scheduler=sched, deadline_s=5.0)
        return interpolate(logits, kl, kcfg.lam)

    srv = BatchedServer(cfg, ServeConfig(batch=4, temperature=0.0),
                        params, opts, logits_hook=hook)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, 12))
               for _ in range(6)]
    outs = srv.generate(prompts, max_new_tokens=8)
    for i, (p, o) in enumerate(zip(prompts, outs)):
        print(f"req {i}: prompt={list(p)[:6]}… → {list(o)}")
    print("\nserved 6 requests in 2 batched waves with kNN-LM retrieval ✓")
    print(f"datastore: {store.n_entries} live entries, "
          f"{store.config.n_pivots} pivots, {store.config.n_groups} groups")

    # --- online update between waves: ingest a fresh corpus chunk and
    # retire the oldest entries — no phase-1 re-run on existing segments
    corpus2 = rng.integers(0, cfg.vocab, (16, 48), dtype=np.int32)
    hs2, _ = forward(params, cfg, jnp.asarray(corpus2), opts=opts)
    new_keys = np.asarray(hs2[:, :-1].reshape(-1, cfg.vocab))[:, :64]
    new_vals = corpus2[:, 1:].reshape(-1)
    ids = store.add_entries(new_keys, new_vals)
    store.remove_entries(np.arange(128))        # oldest 128 pairs
    print(f"after update: {store.n_entries} live entries in "
          f"{store.index.n_segments} segments "
          f"({store.index.n_tombstones} tombstones), "
          f"new ids {ids[0]}..{ids[-1]}")

    outs = srv.generate(prompts[:2], max_new_tokens=8)
    print(f"re-served 2 requests against the updated store ✓")

    store.compact()                             # between decode steps
    print(f"compacted to {store.index.n_segments} segment, "
          f"{store.n_entries} live entries "
          f"({store.index.last_compact_s * 1e3:.1f} ms)")

    # --- live metrics summary: what the flight recorder saw ----------
    elapsed = time.perf_counter() - t_serve0
    st = sched.snapshot()
    ms = obs.metrics.REGISTRY.snapshot()
    qps = st.n_completed / max(elapsed, 1e-9)
    shed_frac = st.n_shed / max(st.n_submitted, 1)
    degraded_frac = st.n_degraded_requests / max(st.n_completed, 1)
    print("\n-- serving metrics (repro.obs) --")
    print(f"requests: {st.n_submitted} submitted, "
          f"{st.n_completed} completed ({qps:.1f} req/s), "
          f"{st.n_retries} retries, {st.n_failovers} failovers")
    print(f"latency: p50={ms.get('serve_latency_s_p50', float('nan')) * 1e3:.2f}ms "
          f"p99={ms.get('serve_latency_s_p99', float('nan')) * 1e3:.2f}ms")
    print(f"shed fraction: {shed_frac:.3f}, "
          f"degraded fraction: {degraded_frac:.3f}")
    print(f"pruning: selectivity={st.join.selectivity:.4f} (Eq. 13), "
          f"tile selectivity={st.join.tile_selectivity:.3f} "
          f"({st.join.tiles_visited}/{st.join.tiles_total} tiles), "
          f"index compactions="
          f"{int(ms.get('index_compact_total', 0))}")


if __name__ == "__main__":
    main()
