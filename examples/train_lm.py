"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on synthetic data with checkpoint/restart.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      (add --restart to resume from the last checkpoint)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import DataConfig, synthetic_lm_batch
from repro.models import ModelOptions, count_params, init_params
from repro.train import OptConfig, TrainConfig, checkpoint, make_train_step


def small_llama():
    """~100M-param llama3-family config (same code path as llama3.2-3b)."""
    base = get_arch("llama3.2-3b")
    return dataclasses.replace(
        base, name="llama-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab=32000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--restart", action="store_true")
    args = ap.parse_args()

    cfg = small_llama()
    opts = ModelOptions(dtype=jnp.float32, remat=False)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=30,
                                     decay_steps=args.steps), accum=1)
    opt_init, step_fn = make_train_step(cfg, tcfg, opts)
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    print(f"model {cfg.name}: {count_params(params)/1e6:.1f}M params")
    opt = opt_init(params)
    start = 0
    if args.restart and checkpoint.latest_step(args.ckpt_dir) is not None:
        avals = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params, "opt": opt})
        restored, start = checkpoint.restore(args.ckpt_dir, avals)
        params, opt = restored["params"], restored["opt"]
        print(f"restored checkpoint at step {start}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_lm_batch(dcfg, i).items()}
        params, opt, m = jstep(params, opt, batch)
        if (i + 1) % 20 == 0:
            tok_s = args.batch * args.seq * 20 / (time.time() - t0)
            t0 = time.time()
            print(f"step {i+1:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}"
                  f"  {tok_s:.0f} tok/s")
        if (i + 1) % args.ckpt_every == 0:
            path = checkpoint.save(args.ckpt_dir, i + 1,
                                   {"params": params, "opt": opt})
            print(f"checkpoint → {path}")
    print("done")


if __name__ == "__main__":
    main()
